"""End-to-end ingestion quickstart: raw documents -> job queue -> live NKS.

The paper's Flickr scenario (§I) from the front: instead of a pre-built
dataset, raw "photos" (feature payloads + tag strings + price/category
attrs, split across two tenants) enter a persistent job queue and a small
worker fleet pulls them through the embed stage into a WAL-backed engine —
each batch committed under one group-commit fsync barrier. A fault plan
kills one worker mid-batch on the way, exercising the lease-reclaim path.
At the end, the pipeline-built corpus answers filtered multi-tenant queries
identically to a fresh static engine over the same documents.

    PYTHONPATH=src python examples/ingest_corpus.py
"""
import os
import tempfile

from repro.data.ingest import (
    IngestPipeline, JobStore, ProjectionEmbedder, corpus_from_documents,
    flickr_like_documents,
)
from repro.serve.engine import NKSEngine
from repro.serve.faults import FaultPlan


def main():
    # Raw documents: 32-dim feature payloads, Zipf-popular tag strings,
    # price/category attrs, two tenants. The vocabulary maps tag strings to
    # (tenant-local) keyword ids.
    docs, vocab = flickr_like_documents(2_000, d_raw=32, u=40, t=4, seed=1,
                                        tenants=("alice", "bob"))
    embedder = ProjectionEmbedder(8, vocab, d_raw=32, seed=1)

    # The engine needs a seed corpus (it fixes the tenant namespaces and the
    # attribute schema); the rest of the documents arrive through the queue.
    seed_docs, stream_docs = docs[:400], docs[400:]
    seed_ds, _ = corpus_from_documents(seed_docs, embedder)
    root = tempfile.mkdtemp(prefix="nks-ingest-demo-")
    engine = NKSEngine(seed_ds, m=2, n_scales=5, seed=0)
    engine.attach_wal(os.path.join(root, "wal"))

    # Persistent job queue + 4 workers; the fault plan crashes whichever
    # worker performs the 5th insert — its lease expires and survivors
    # reclaim and finish the batch (the journal and WAL make this safe).
    store = JobStore(os.path.join(root, "jobs.jsonl"), lease_s=0.5,
                     backoff_s=0.01, max_attempts=6)
    store.add(stream_docs)
    faults = FaultPlan(crash={"insert": 5})
    pipeline = IngestPipeline(store, engine, embedder, workers=4,
                              batch_docs=32, faults=faults)
    pipeline.recover()                     # no-op on a fresh queue
    report = pipeline.run(timeout_s=120.0)
    print(f"ingested {report['docs_done']}/{len(stream_docs)} docs in "
          f"{report['wall_s']:.2f}s ({report['docs_per_s']:.0f} docs/s), "
          f"retries={report['retries']} reclaims={report['reclaims']} "
          f"dead_workers={report['dead_workers']}")
    assert report["drained"] and report["docs_failed"] == 0

    # Differential: the pipeline-built engine vs a fresh static build over
    # the same documents. Tenant-scoped filtered queries use tenant-LOCAL
    # keyword ids; answers are compared by optimal diameter.
    ref_ds, _ = corpus_from_documents(docs, embedder)
    ref = NKSEngine(ref_ds, m=2, n_scales=5, seed=0)
    for q, flt in [([4, 11], {"tenant": "alice"}),
                   ([7, 15], {"tenant": "bob"}),
                   ([2, 9], {"tenant": "alice",
                             "where": [["price", "<", 50.0]]})]:
        mine = engine.query(q, k=2, tier="exact", filter=flt)
        them = ref.query(q, k=2, tier="exact", filter=flt)
        diam = [round(c.diameter, 6) for c in mine.candidates]
        assert diam == [round(c.diameter, 6) for c in them.candidates]
        print(f"query {q} {flt}: diameters {diam} (matches static build)")

    engine.close()
    store.close()
    print(f"journal + WAL kept under {root} — rerun JobStore/NKSEngine."
          f"recover to resume")


if __name__ == "__main__":
    main()
