"""Image-search scenario (the paper's Flickr use case, §I): find the tightest
cluster of photos containing a given set of tags, across the engine's three
quality/latency tiers.

    PYTHONPATH=src python examples/image_search.py
"""
import numpy as np

from repro.core import brute_force
from repro.data.flickr_like import flickr_like_dataset
from repro.data.synthetic import random_queries
from repro.serve.engine import NKSEngine


def main():
    # "Photos": clustered histogram features with Zipf-popular tags.
    ds = flickr_like_dataset(n=8_000, d=32, u=300, t=6, n_clusters=24, seed=1)
    engine = NKSEngine(ds, m=2, n_scales=5)
    print(f"corpus: {ds.n} images, {ds.n_keywords} tags, d={ds.dim}")

    queries = random_queries(ds, q=3, n_queries=5, seed=9)
    for tier in ("exact", "approx", "device"):
        lat, ratios = [], []
        for q in queries:
            res = engine.query(q, k=1, tier=tier)
            lat.append(res.latency_s)
            truth = brute_force.search(ds, q, k=1).items[0]
            if truth.diameter > 1e-9 and res.candidates:
                ratios.append(res.candidates[0].diameter / truth.diameter)
        print(f"tier={tier:7s} mean_latency={np.mean(lat) * 1e3:7.2f} ms  "
              f"AAR={np.mean(ratios):.3f}")


if __name__ == "__main__":
    main()
