"""Distributed NKS serving on a device mesh (8 forced host devices).

Demonstrates the sharded serving plane (``core.device_plane``): one
:class:`DevicePlane` carries every tier — the anchor-star shard_map program
(relevant-point groups sharded over ``data``, anchors local, candidates
merged via the replicated top-k collective) *and* the batched exact/approx
pipeline, whose size-binned join dispatches shard over the same mesh when
the engine is built with ``mesh=...``.

    PYTHONPATH=src python examples/distributed_serve.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax.numpy as jnp
import numpy as np

from repro.core import brute_force
from repro.core.device_plane import DevicePlane
from repro.data.flickr_like import flickr_like_dataset
from repro.data.synthetic import random_queries
from repro.launch.mesh import make_serving_mesh
from repro.serve.engine import NKSEngine


def main():
    plane = DevicePlane(make_serving_mesh(data=8))
    ds = flickr_like_dataset(n=20_000, d=32, u=300, t=4, n_clusters=32, seed=0)
    print(f"corpus: {ds.n} points on a {plane.n_shards}-shard serving plane")

    # device tier: the anchor-star shard_map program
    for query in random_queries(ds, q=3, n_queries=3, seed=4):
        pg = plane.pack_groups(ds, query)
        t0 = time.perf_counter()
        diams, cand_ids = plane.nks_topk(jnp.asarray(pg.groups),
                                         jnp.asarray(pg.mask),
                                         jnp.asarray(pg.ids), k=3)
        np.asarray(diams)
        dt = time.perf_counter() - t0
        truth = brute_force.search(ds, query, k=1).items[0]
        best = float(diams[0])
        print(f"query {query}: device top-1 diameter={best:.2f} "
              f"(truth {truth.diameter:.2f}, ratio {best / max(truth.diameter, 1e-9):.3f}) "
              f"ids={sorted(set(int(i) for i in cand_ids[0]))} [{dt * 1e3:.1f} ms]")

    # exact tier on the same plane: sharded size-binned join dispatches
    engine = NKSEngine(ds, m=2, n_scales=5, seed=0, build_approx=False,
                       mesh=plane)
    queries = random_queries(ds, q=3, n_queries=8, seed=5)
    out = engine.query_batch(queries, k=2, tier="exact", backend="pallas")
    st = engine.last_batch_stats
    print(f"exact batch: {len(out)} queries, "
          f"{st.sharded_dispatches}/{st.total_dispatches} dispatches sharded, "
          f"per-device counts {st.shard_dispatches}, "
          f"shard utilisation {st.shard_utilisation}")


if __name__ == "__main__":
    main()
