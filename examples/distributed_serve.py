"""Distributed NKS serving on a device mesh (8 forced host devices).

Demonstrates the DESIGN.md §5 serving path: the relevant-point groups are
sharded over the ``data`` axis, anchors stay local, candidates merge via a
global top-k — all inside one shard_map program.

    PYTHONPATH=src python examples/distributed_serve.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax.numpy as jnp
import numpy as np

from repro.core import brute_force
from repro.core.distributed import distributed_nks_topk, pack_groups
from repro.data.flickr_like import flickr_like_dataset
from repro.data.synthetic import random_queries
from repro.launch.mesh import make_local_mesh


def main():
    mesh = make_local_mesh(data=8, model=1)
    ds = flickr_like_dataset(n=20_000, d=32, u=300, t=4, n_clusters=32, seed=0)
    print(f"corpus: {ds.n} points sharded over {mesh.shape['data']} devices")

    for query in random_queries(ds, q=3, n_queries=3, seed=4):
        groups, mask, ids = pack_groups(ds, query)
        with mesh:
            t0 = time.perf_counter()
            diams, cand_ids = distributed_nks_topk(
                mesh, jnp.asarray(groups), jnp.asarray(mask),
                jnp.asarray(ids), k=3)
            diams.block_until_ready()
            dt = time.perf_counter() - t0
        truth = brute_force.search(ds, query, k=1).items[0]
        best = float(diams[0])
        print(f"query {query}: device top-1 diameter={best:.2f} "
              f"(truth {truth.diameter:.2f}, ratio {best / max(truth.diameter, 1e-9):.3f}) "
              f"ids={sorted(set(int(i) for i in cand_ids[0]))} [{dt * 1e3:.1f} ms]")


if __name__ == "__main__":
    main()
