"""Quickstart: build a tagged dataset, index it, run NKS queries.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import brute_force, build_index, promish_a, promish_e
from repro.data.synthetic import random_queries, synthetic_dataset


def main():
    # A tagged multi-dimensional dataset (paper fig. 1 setting).
    ds = synthetic_dataset(n=5_000, d=16, u=50, t=2, seed=0)
    print(f"dataset: N={ds.n} d={ds.dim} U={ds.n_keywords}")

    # Multi-scale hash indices (paper defaults m=2, L=5).
    idx_e = build_index(ds, m=2, n_scales=5, exact=True, seed=0)
    idx_a = build_index(ds, m=2, n_scales=5, exact=False, seed=0)
    print(f"index: L={idx_e.n_scales} scales, w0={idx_e.w0:.1f}, "
          f"E={idx_e.nbytes() / 1e6:.1f}MB A={idx_a.nbytes() / 1e6:.1f}MB")

    for query in random_queries(ds, q=3, n_queries=3, seed=42):
        exact = promish_e.search(ds, idx_e, query, k=2)
        approx = promish_a.search(ds, idx_a, query, k=2)
        truth = brute_force.search(ds, query, k=2)
        print(f"\nquery {query}")
        for name, pq in (("ProMiSH-E", exact), ("ProMiSH-A", approx),
                         ("oracle   ", truth)):
            top = pq.items[0]
            print(f"  {name}: ids={top.ids} diameter={top.diameter:.2f}")
        assert abs(exact.items[0].diameter - truth.items[0].diameter) < 1e-3

    # Batched serving path: one fused device dispatch per scale for the whole
    # batch (see repro.serve.engine / core.plan / core.backend).
    from repro.serve.engine import NKSEngine
    engine = NKSEngine(ds, m=2, n_scales=5, seed=0)
    batch = random_queries(ds, q=3, n_queries=8, seed=7)
    results = engine.query_batch(batch, k=1, tier="exact", backend="numpy")
    stats = engine.last_batch_stats
    print(f"\nbatched: {len(results)} queries, "
          f"{sum(s.tasks_searched for s in stats.scales)} subsets, "
          f"dispatches/scale={stats.dispatches_per_scale}")


if __name__ == "__main__":
    main()
