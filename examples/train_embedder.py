"""End-to-end driver: train a ~100M-param LM embedder, checkpoint/resume,
then index its embeddings with ProMiSH and answer NKS queries.

    PYTHONPATH=src python examples/train_embedder.py            # quick (CPU)
    PYTHONPATH=src python examples/train_embedder.py --steps 300  # full run

This is the framework's full stack in one script: config -> model -> WSD
optimizer -> fault-tolerant loop -> ProMiSH ingestion -> NKS serving.
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.data.token_pipeline import PipelineConfig, TokenPipeline
from repro.models.api import count_params, model_api
from repro.serve.engine import NKSEngine
from repro.train.optimizer import OptimizerConfig, adamw_update, init_opt_state
from repro.train.train_loop import LoopConfig, TrainLoop

# ~100M-param llama-style config (12L x 768, vocab 32k)
EMBEDDER = ArchConfig(
    name="embedder-100m", family="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=4, d_ff=2048, vocab_size=32_000, head_dim=64,
    mlp="swiglu", norm="rmsnorm", schedule="wsd", tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = EMBEDDER if args.steps >= 100 else EMBEDDER.smoke()
    api = model_api(cfg)
    print(f"model: {cfg.name}  params={count_params(cfg) / 1e6:.1f}M")

    opt_cfg = OptimizerConfig(peak_lr=3e-4, warmup_steps=max(args.steps // 10, 2),
                              total_steps=args.steps, schedule="wsd")
    pipe = TokenPipeline(PipelineConfig(vocab_size=cfg.vocab_size,
                                        global_batch=args.batch,
                                        seq_len=args.seq, seed=0))

    def init_state():
        params = api.init(jax.random.PRNGKey(0))
        return {"params": params, "opt": init_opt_state(params, opt_cfg)}

    @jax.jit
    def step(state, batch):
        batch = jax.tree.map(jnp.asarray, batch)
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: api.loss(p, batch), has_aux=True)(state["params"])
        params, opt, om = adamw_update(state["params"], grads, state["opt"],
                                       opt_cfg)
        return {"params": params, "opt": opt}, {"loss": loss, **om}

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="embedder-ckpt-")
    loop = TrainLoop(LoopConfig(total_steps=args.steps, ckpt_dir=ckpt_dir,
                                ckpt_every=max(args.steps // 3, 2)),
                     step, pipe, init_state)
    state, hist = loop.run()
    print(f"trained {len(hist)} steps: loss {hist[0]['loss']:.3f} -> "
          f"{hist[-1]['loss']:.3f} (ckpts in {ckpt_dir})")

    # ---- embed a corpus and serve NKS queries over it ----------------------
    rng = np.random.default_rng(1)
    n_docs, n_tags = 48, 10
    batches, keywords = [], []
    for lo in range(0, n_docs, 8):
        toks = rng.integers(0, cfg.vocab_size, (8, args.seq))
        batches.append({"tokens": jnp.asarray(toks, jnp.int32)})
        keywords.extend(sorted(rng.choice(n_tags, size=2, replace=False).tolist())
                        for _ in range(8))
    engine = NKSEngine.ingest_embeddings(api, state["params"], batches,
                                         keywords, n_scales=4)
    query = [0, 1]
    res = engine.query(query, k=1, tier="exact")
    print(f"NKS query {query} -> ids={res.candidates[0].ids} "
          f"diameter={res.candidates[0].diameter:.3f} "
          f"({res.latency_s * 1e3:.1f} ms)")


if __name__ == "__main__":
    main()
