"""Batched pipeline parity: ``NKSEngine.query_batch`` on the plan/backend
layers must reproduce the per-query searches exactly — including with the
fp32 Pallas distance backend (interpret=True on CPU), whose blocks are a
pruning filter re-scored through the float64 path."""
import numpy as np
import pytest

from repro.core import brute_force, promish_a, promish_e
from repro.core.backend import NumpyBackend, PallasBackend, get_backend
from repro.core.types import make_dataset
from repro.data.synthetic import random_queries, synthetic_dataset
from repro.serve.engine import NKSEngine

UNUSED_KW = 19   # keyword present in the dictionary but tagging no point


def _diams(cands):
    return [c.diameter for c in cands]


@pytest.fixture(scope="module")
def ds():
    base = synthetic_dataset(n=220, d=6, u=18, t=2, seed=7)
    # re-wrap with one extra, never-used keyword for the empty-group edge case
    kws = [base.kw.row(i).tolist() for i in range(base.n)]
    return make_dataset(base.points, kws, n_keywords=UNUSED_KW + 1)


@pytest.fixture(scope="module")
def engine(ds):
    return NKSEngine(ds, m=2, n_scales=5, seed=0)


@pytest.fixture(scope="module")
def batch32(ds):
    qs = random_queries(ds, 2, 16, seed=3) + random_queries(ds, 3, 16, seed=4)
    assert len(qs) == 32
    return qs


@pytest.mark.parametrize("backend", ["numpy", "pallas"])
def test_exact_batch_matches_per_query_and_oracle(ds, engine, batch32, backend):
    """Acceptance: 32-query exact batch == per-query ProMiSH-E == brute force."""
    be = get_backend(backend, interpret=True) if backend == "pallas" \
        else get_backend(backend)
    out = engine.query_batch(batch32, k=2, tier="exact", backend=be)
    assert len(out) == 32
    for q, res in zip(batch32, out):
        per = promish_e.search(ds, engine.index_e, q, k=2)
        truth = brute_force.search(ds, q, k=2)
        np.testing.assert_allclose(_diams(res.candidates), _diams(per.items),
                                   rtol=1e-9, err_msg=f"query={q}")
        np.testing.assert_allclose(_diams(res.candidates), _diams(truth.items),
                                   rtol=1e-5, err_msg=f"query={q}")


def test_pallas_backend_amortised_dispatches(engine, batch32):
    """Acceptance: the fused pipeline amortises device traffic — a handful of
    size-binned dispatches per scale (bounded by the number of pow2 size
    classes), never one per subset, and scale 0 (fresh exact queues, every
    pruning radius infinite) skips the device entirely: an inf-radius join
    mask is all-ones by construction."""
    be = PallasBackend(interpret=True)
    engine.query_batch(batch32, k=2, tier="exact", backend=be)
    stats = engine.last_batch_stats
    assert stats.tier == "exact" and stats.backend == "pallas"
    assert stats.batch_size == 32
    assert len(stats.scales) >= 1
    assert stats.scales[0].dispatches == 0          # inf radii -> no device
    assert sum(s.dispatches for s in stats.scales) > 0
    for s in stats.scales:
        assert s.dispatches <= 12, \
            f"scale {s.scale}: {s.dispatches} dispatches for {s.tasks_searched} tasks"
        if s.tasks_searched > 24:
            assert s.dispatches < s.tasks_searched // 2
    assert stats.total_dispatches == be.stats.dispatches
    assert stats.fallback_dispatches <= 12
    assert be.stats.subsets > 0 and be.stats.points_packed > 0


def test_numpy_backend_dispatches_per_subset(engine, batch32):
    """The loop baseline the fused path amortises: one dispatch per subset."""
    be = NumpyBackend()
    engine.query_batch(batch32, k=2, tier="exact", backend=be)
    stats = engine.last_batch_stats
    assert sum(s.dispatches for s in stats.scales) == \
        sum(s.tasks_searched for s in stats.scales)


@pytest.mark.parametrize("backend", ["numpy", "pallas"])
def test_approx_batch_matches_per_query(ds, engine, backend):
    be = get_backend(backend, interpret=True) if backend == "pallas" \
        else get_backend(backend)
    queries = random_queries(ds, 3, 8, seed=11)
    out = engine.query_batch(queries, k=3, tier="approx", backend=be)
    for q, res in zip(queries, out):
        per = promish_a.search(ds, engine.index_a, q, k=3)
        np.testing.assert_allclose(_diams(res.candidates), _diams(per.items),
                                   rtol=1e-9, err_msg=f"query={q}")


@pytest.mark.parametrize("backend", ["numpy", "pallas"])
def test_edge_cases_q1_and_empty_group(ds, engine, backend):
    """q=1 queries return diameter-0 singletons; a query containing a keyword
    that tags no point has no candidate set at all — batched alongside
    regular queries."""
    be = get_backend(backend, interpret=True) if backend == "pallas" \
        else get_backend(backend)
    populated = random_queries(ds, 2, 1, seed=1)[0]
    queries = [[populated[0]],                 # q = 1
               [UNUSED_KW, populated[0]],      # empty keyword group
               populated]                      # regular
    out = engine.query_batch(queries, k=2, tier="exact", backend=be)
    assert all(c.diameter == 0.0 and len(c.ids) == 1
               for c in out[0].candidates) and out[0].candidates
    assert out[1].candidates == []
    per = promish_e.search(ds, engine.index_e, populated, k=2)
    np.testing.assert_allclose(_diams(out[2].candidates), _diams(per.items),
                               rtol=1e-9)


def test_candidate_id_sets_match_per_query(ds, engine, batch32):
    """Beyond diameters: the actual result id-sets agree with ProMiSH-E
    (modulo equal-diameter ties, which the synthetic data avoids at fp64)."""
    be = PallasBackend(interpret=True)
    out = engine.query_batch(batch32[:8], k=1, tier="exact", backend=be)
    for q, res in zip(batch32[:8], out):
        per = promish_e.search(ds, engine.index_e, q, k=1)
        assert [c.ids for c in res.candidates] == [c.ids for c in per.items]


def test_batch_of_one_and_empty_batch(ds, engine):
    q = random_queries(ds, 2, 1, seed=2)[0]
    out = engine.query_batch([q], k=1, tier="exact", backend="numpy")
    per = promish_e.search(ds, engine.index_e, q, k=1)
    np.testing.assert_allclose(_diams(out[0].candidates), _diams(per.items))
    assert engine.query_batch([], k=1, tier="exact", backend="numpy") == []


def test_unknown_backend_rejected(engine):
    with pytest.raises(ValueError):
        engine.query_batch([[0]], tier="exact", backend="cuda")


def test_pallas_memory_budget_chunks_dispatches(ds, engine, batch32):
    """A tiny max_block_bytes splits a scale into several size-bounded
    dispatches without changing any result."""
    be = PallasBackend(interpret=True, max_block_bytes=4 << 10)
    out = engine.query_batch(batch32[:6], k=1, tier="exact", backend=be)
    stats = engine.last_batch_stats
    assert any(s.dispatches > 1 for s in stats.scales if s.tasks_searched > 1)
    for q, res in zip(batch32[:6], out):
        per = promish_e.search(ds, engine.index_e, q, k=1)
        np.testing.assert_allclose(_diams(res.candidates), _diams(per.items),
                                   rtol=1e-9)


def test_device_tier_records_batch_stats(engine, batch32):
    """The device tier flows through the same dispatch layer as exact/approx
    now: query_batch records fresh PipelineStats (one anchor-star dispatch
    per query, on the default device when no mesh is attached) instead of
    clearing them."""
    engine.query_batch(batch32[:2], k=1, tier="exact", backend="numpy")
    assert engine.last_batch_stats.tier == "exact"
    engine.query_batch(batch32[:1], k=1, tier="device")
    stats = engine.last_batch_stats
    assert stats is not None and stats.tier == "device"
    assert stats.backend == "anchor" and stats.batch_size == 1
    assert stats.shard_dispatches == [1]
    assert stats.sharded_dispatches == 0
