"""Drives tests/multidev_script.py in a subprocess with 8 forced host devices
(device count is locked at first jax init, so in-process forcing is unsafe)."""
import os
import subprocess
import sys

import pytest


@pytest.mark.timeout(600)
def test_multidevice_suite():
    script = os.path.join(os.path.dirname(__file__), "multidev_script.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, script], env=env,
                          capture_output=True, text=True, timeout=580)
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "ALL MULTIDEV OK" in proc.stdout
