"""The assigned architecture numbers, verbatim from the assignment table."""
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import supported_cells

EXPECT = {
    "minicpm-2b": dict(n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36,
                       d_ff=5760, vocab_size=122_753, schedule="wsd"),
    "qwen3-32b": dict(n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8,
                      d_ff=25_600, vocab_size=151_936, qk_norm=True),
    "codeqwen1.5-7b": dict(n_layers=32, d_model=4096, n_heads=32,
                           n_kv_heads=32, d_ff=13_440, vocab_size=92_416,
                           attn_bias=True),
    "starcoder2-7b": dict(n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4,
                          d_ff=18_432, vocab_size=49_152),
    "mamba2-2.7b": dict(n_layers=64, d_model=2560, vocab_size=50_280,
                        family="ssm"),
    "olmoe-1b-7b": dict(n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
                        d_ff=1024, vocab_size=50_304),
    "llama4-maverick-400b-a17b": dict(n_layers=48, d_model=5120, n_heads=40,
                                      n_kv_heads=8, vocab_size=202_048),
    "hymba-1.5b": dict(n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
                       d_ff=5504, vocab_size=32_001, hybrid=True),
    "llama-3.2-vision-90b": dict(n_layers=100, d_model=8192, n_heads=64,
                                 n_kv_heads=8, d_ff=28_672,
                                 vocab_size=128_256, cross_attn_every=5),
    "whisper-large-v3": dict(n_layers=32, d_model=1280, n_heads=20,
                             n_kv_heads=20, d_ff=5120, vocab_size=51_866,
                             enc_layers=32),
}


def test_all_ten_archs_registered():
    assert len(ARCH_IDS) == 10
    assert set(EXPECT) == set(ARCH_IDS)


@pytest.mark.parametrize("arch", sorted(EXPECT))
def test_exact_assignment_numbers(arch):
    cfg = get_config(arch)
    for field, want in EXPECT[arch].items():
        assert getattr(cfg, field) == want, f"{arch}.{field}"


def test_ssm_state_sizes():
    assert get_config("mamba2-2.7b").ssm.d_state == 128
    assert get_config("hymba-1.5b").ssm.d_state == 16


def test_moe_shapes():
    o = get_config("olmoe-1b-7b").moe
    assert (o.n_experts, o.top_k, o.d_ff_expert) == (64, 8, 1024)
    l4 = get_config("llama4-maverick-400b-a17b").moe
    assert (l4.n_experts, l4.top_k, l4.d_ff_expert) == (128, 1, 8192)
    assert l4.shared_expert


def test_llama4_total_and_active_params():
    """~400B total / ~17B active per the model card."""
    from repro.models.api import active_params, count_params
    cfg = get_config("llama4-maverick-400b-a17b")
    total = count_params(cfg)
    active = active_params(cfg)
    assert 3.5e11 < total < 4.5e11, total
    assert 1.2e10 < active < 2.2e10, active


def test_long_500k_only_for_subquadratic():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        names = [c.name for c in supported_cells(cfg)]
        if arch in ("mamba2-2.7b", "hymba-1.5b"):
            assert "long_500k" in names
        else:
            assert "long_500k" not in names
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(names)


def test_vision_and_audio_stubs():
    v = get_config("llama-3.2-vision-90b")
    assert v.vision_tokens == 1601 and v.vision_dim == 7680
    a = get_config("whisper-large-v3")
    assert a.audio_frames == 1500
