"""Flexible query semantics (ISSUE 9): m-of-k partial coverage, per-keyword
weights, scored top-k.

Structure mirrors the repo's differential discipline: unit tests for the
semantics/queue primitives, then seeded differential suites asserting the
fast paths (promish_e / promish_a / batched engine on both numpy and pallas
routes) against the extended brute-force oracle ``search_flex``, and the
degeneracy contract — ``m = |Q|`` + unit weights + no scoring must be
*bit-identical* to the classic path on the same route.
"""
import math

import numpy as np
import pytest

from repro.core import brute_force, promish_a, promish_e
from repro.core.index import build_index
from repro.core.semantics import (MAX_SUBQUERIES, QuerySemantics,
                                  parse_weighted_keywords, weighted_pair_sq)
from repro.core.types import Candidate, ScoredTopK, TopK, make_dataset
from repro.serve.engine import NKSEngine


def _corpus(seed, n=90, d=4, u=10):
    rng = np.random.default_rng(seed)
    pts = rng.uniform(0, 1000, (n, d)).astype(np.float32)
    kws = [rng.choice(u, size=rng.integers(1, 4), replace=False).tolist()
           for _ in range(n)]
    return make_dataset(pts, kws, n_keywords=u)


def _queries(ds, n_queries, qlen, seed):
    rng = np.random.default_rng(seed)
    populated = np.flatnonzero(np.diff(ds.ikp.offsets) > 0)
    return [sorted(rng.choice(populated, size=qlen, replace=False).tolist())
            for _ in range(n_queries)]


# The semantics variants every differential suite sweeps. Weights are keyed
# by query position (resolved to the drawn keyword ids per query) so each
# variant is meaningful for any query.
def _variants(query):
    q = list(query)
    return [
        {"m": max(1, len(q) - 1)},
        {"m": 1},
        {"weights": {q[0]: 3.0, q[-1]: 1.5}},
        {"m": max(1, len(q) - 1), "weights": {q[0]: 2.0}},
        {"m": 1, "score": True, "alpha": 0.5},
        {"score": True},
    ]


# --------------------------------------------------------------- unit tests
def test_semantics_validation_errors():
    with pytest.raises(ValueError, match="weight"):
        QuerySemantics(weights={3: 0.5})
    with pytest.raises(ValueError, match="weight"):
        QuerySemantics.coerce({"weights": {"3": float("nan")}})
    with pytest.raises(ValueError, match="m must be"):
        QuerySemantics(m=0)
    with pytest.raises(ValueError, match="alpha"):
        QuerySemantics(alpha=0.0)
    with pytest.raises(ValueError, match="unknown semantics key"):
        QuerySemantics.coerce({"mm": 2})
    with pytest.raises(ValueError, match="dict or QuerySemantics"):
        QuerySemantics.coerce([2])
    with pytest.raises(ValueError, match="exceeds"):
        QuerySemantics(m=5).trivial_for([1, 2])
    with pytest.raises(ValueError, match="cap"):
        QuerySemantics(m=1).expand_subqueries(list(range(12)))


def test_coerce_and_canonical_key():
    sem = QuerySemantics.coerce({"m": 2, "weights": {"7": 4}, "score": True})
    assert sem.m == 2 and sem.weights == {7: 4.0} and sem.score
    assert QuerySemantics.coerce(None) is None
    assert QuerySemantics.coerce(sem) is sem
    # canonical_key is order-insensitive over weights and distinguishes knobs
    a = QuerySemantics(weights={3: 2.0, 7: 4.0}).canonical_key()
    b = QuerySemantics(weights={7: 4.0, 3: 2.0}).canonical_key()
    assert a == b
    assert QuerySemantics(m=2).canonical_key() != \
        QuerySemantics(m=1).canonical_key()
    assert QuerySemantics(score=True, alpha=0.5).canonical_key() != \
        QuerySemantics(score=True, alpha=1.0).canonical_key()


def test_trivial_for():
    assert QuerySemantics().trivial_for([1, 2, 3])
    assert QuerySemantics(m=3).trivial_for([1, 2, 3])
    assert QuerySemantics(weights={9: 4.0}).trivial_for([1, 2])  # off-query
    assert not QuerySemantics(m=2).trivial_for([1, 2, 3])
    assert not QuerySemantics(weights={1: 2.0}).trivial_for([1, 2])
    assert not QuerySemantics(score=True).trivial_for([1, 2])


def test_expand_subqueries():
    assert QuerySemantics().expand_subqueries([3, 1]) == [[1, 3]]
    subs = QuerySemantics(m=1).expand_subqueries([1, 2, 3])
    assert subs[0] == [1, 2, 3]                    # largest first
    assert len(subs) == 7
    assert [len(s) for s in subs] == sorted([len(s) for s in subs],
                                            reverse=True)
    assert len({tuple(s) for s in subs}) == 7      # distinct
    assert MAX_SUBQUERIES == 512


def test_parse_weighted_keywords_grammar():
    kws, w = parse_weighted_keywords(["3", "7^4", 12, "5^1.5"])
    assert kws == [3, 7, 12, 5]
    assert w == {7: 4.0, 5: 1.5}
    assert parse_weighted_keywords([1, 2]) == ([1, 2], {})


def test_resolve_keywords_maps_weight_keys():
    sem = QuerySemantics(m=1, weights={3: 2.0})
    out = sem.resolve_keywords(lambda kw: kw + 100)
    assert out.weights == {103: 2.0} and out.m == 1
    assert QuerySemantics(m=2).resolve_keywords(lambda kw: kw + 1).m == 2


def test_topk_tie_open_admits_equal_cost():
    strict, open_ = TopK(2, init_full=True), TopK(2, init_full=True,
                                                  tie_open=True)
    for pq in (strict, open_):
        pq.offer(Candidate(ids=(5,), diameter=0.0))
        pq.offer(Candidate(ids=(9,), diameter=0.0))
    kth = strict.kth_diameter()
    assert kth == 0.0
    assert open_.kth_diameter() == math.nextafter(0.0, math.inf)
    # an equal-cost candidate with a better id tie-break must displace (9,)
    open_.offer(Candidate(ids=(2,), diameter=0.0))
    assert [c.ids for c in open_.items] == [(2,), (5,)]


def test_scored_topk_ranks_by_score_and_bounds_cost():
    cov = lambda ids: float(len(ids))              # noqa: E731
    pq = ScoredTopK(2, total_weight=3.0, alpha=1.0, coverage=cov,
                    init_full=True)
    assert pq.kth_diameter() == float("inf")       # not full yet
    pq.offer(Candidate(ids=(1, 2, 3), diameter=2.0))   # score 3/(1+2) = 1.0
    pq.offer(Candidate(ids=(4,), diameter=0.0))        # score 1/(1+0) = 1.0
    pq.offer(Candidate(ids=(5, 6), diameter=0.5))      # score 2/1.5 ~= 1.33
    items = pq.items
    # the 1.0-score tie breaks on diameter: (4,) at cost 0 beats (1,2,3)
    assert [c.ids for c in items] == [(5, 6), (4,)]
    assert items[0].score == pytest.approx(2.0 / 1.5)
    # kth score 1.0 -> cost bound (3.0/1.0 - 1)/1.0 = 2.0 (+ulp tie-opening)
    assert pq.kth_diameter() == math.nextafter(2.0, math.inf)


def test_weighted_set_cost_matches_manual():
    ds = _corpus(0)
    wvec = np.ones(ds.n)
    wvec[[3, 7]] = [2.0, 3.0]
    ids = [3, 7, 11]
    pts = ds.points[np.asarray(ids)].astype(np.float64)
    diff = pts[:, None] - pts[None, :]
    d2 = (diff * diff).sum(-1)
    want = float(np.sqrt(weighted_pair_sq(d2, wvec[np.asarray(ids)]).max()))
    got = brute_force.weighted_set_cost(ids, ds, wvec)
    assert got == want
    assert brute_force.weighted_set_cost([5], ds, wvec) == 0.0


# ------------------------------------------------- per-query search parity
def test_promish_e_flex_matches_oracle():
    """Exact tier == oracle (ids and costs) for every semantics variant."""
    for seed in (1, 2):
        ds = _corpus(seed)
        idx = build_index(ds, m=2, n_scales=4, exact=True, seed=seed)
        for query in _queries(ds, 2, 3, seed + 50):
            for var in _variants(query):
                sem = QuerySemantics.coerce(var)
                want = brute_force.search_flex(ds, query, k=2, semantics=sem)
                got = promish_e.search(ds, idx, query, k=2,
                                       semantics=sem).items
                assert [c.ids for c in got] == [c.ids for c in want], var
                np.testing.assert_allclose([c.diameter for c in got],
                                           [c.diameter for c in want],
                                           rtol=1e-9)
                if sem.score:
                    np.testing.assert_allclose(
                        [c.score for c in got], [c.score for c in want],
                        rtol=1e-9)


def test_promish_a_flex_candidates_feasible():
    """Approx tier: every candidate comes from the flexible universe with
    the exact weighted cost (and score, when scoring)."""
    seed = 3
    ds = _corpus(seed)
    idx = build_index(ds, m=2, n_scales=4, exact=False, seed=seed)
    for query in _queries(ds, 2, 3, seed + 50):
        for var in _variants(query):
            sem = QuerySemantics.coerce(var)
            wvec = sem.weight_vector(ds, query)
            universe = set(brute_force.enumerate_candidates_flex(
                ds, sorted(query), sem))
            got = promish_a.search(ds, idx, query, k=2, semantics=sem).items
            for c in got:
                assert c.ids in universe, var
                np.testing.assert_allclose(
                    c.diameter,
                    brute_force.weighted_set_cost(c.ids, ds, wvec),
                    rtol=1e-9)
                if sem.score:
                    cov = sem.coverage_fn(ds, query)
                    np.testing.assert_allclose(
                        c.score,
                        cov(c.ids) / (1.0 + sem.alpha * c.diameter),
                        rtol=1e-9)


def test_degenerate_semantics_bit_identical_per_query():
    """m = |Q|, unit weights, no scoring: promish_e/a results are bitwise
    equal to a semantics-free run (the degeneracy contract)."""
    seed = 4
    ds = _corpus(seed)
    degenerate = [None,
                  {"m": 3},
                  {"weights": {0: 1.0}},
                  {"m": 3, "weights": {999: 7.0}, "alpha": 2.0}]
    for exact, mod in ((True, promish_e), (False, promish_a)):
        idx = build_index(ds, m=2, n_scales=4, exact=exact, seed=seed)
        for query in _queries(ds, 2, 3, seed + 60):
            base = mod.search(ds, idx, query, k=2).items
            for var in degenerate:
                got = mod.search(ds, idx, query, k=2, semantics=var).items
                assert [(c.ids, c.diameter) for c in got] == \
                    [(c.ids, c.diameter) for c in base], var


# ------------------------------------------------------------ engine parity
def test_engine_flex_matches_oracle():
    ds = _corpus(5)
    eng = NKSEngine(ds, m=2, n_scales=4, seed=5)
    queries = _queries(ds, 3, 3, 77)
    for var in _variants(queries[0]):
        sem = QuerySemantics.coerce(var)
        res = eng.query_batch(queries, k=2, tier="exact", backend="numpy",
                              semantics=sem)
        for q, r in zip(queries, res):
            want = brute_force.search_flex(ds, q, k=2, semantics=sem)
            assert [c.ids for c in r.candidates] == [c.ids for c in want], var
            np.testing.assert_allclose([c.diameter for c in r.candidates],
                                       [c.diameter for c in want], rtol=1e-9)


def test_engine_degenerate_bit_identical_per_route():
    """On each backend route, a degenerate semantics batch is bitwise equal
    to the classic batch (same route)."""
    ds = _corpus(6)
    eng = NKSEngine(ds, m=2, n_scales=4, seed=6)
    queries = _queries(ds, 3, 3, 88)
    for backend in ("numpy", "pallas"):
        base = eng.query_batch(queries, k=2, tier="exact", backend=backend)
        got = eng.query_batch(queries, k=2, tier="exact", backend=backend,
                              semantics={"m": 3, "weights": {0: 1.0}})
        for b, g in zip(base, got):
            assert [(c.ids, c.diameter) for c in g.candidates] == \
                [(c.ids, c.diameter) for c in b.candidates]


def test_engine_backend_parity_flex():
    """numpy and pallas routes agree on flexible batches (ids exactly,
    costs to settlement tolerance)."""
    ds = _corpus(7)
    eng = NKSEngine(ds, m=2, n_scales=4, seed=7)
    queries = _queries(ds, 3, 3, 99)
    for var in ({"m": 2}, {"weights": {queries[0][0]: 2.5}},
                {"m": 2, "score": True}):
        a = eng.query_batch(queries, k=2, tier="exact", backend="numpy",
                            semantics=var)
        b = eng.query_batch(queries, k=2, tier="exact", backend="pallas",
                            semantics=var)
        for ra, rb in zip(a, b):
            assert [c.ids for c in ra.candidates] == \
                [c.ids for c in rb.candidates], var
            np.testing.assert_allclose([c.diameter for c in ra.candidates],
                                       [c.diameter for c in rb.candidates],
                                       rtol=1e-9)


def test_engine_query_scored_and_subquery_stats():
    ds = _corpus(8)
    eng = NKSEngine(ds, m=2, n_scales=4, seed=8)
    query = _queries(ds, 1, 3, 111)[0]
    res = eng.query(query, k=2, tier="exact",
                    semantics={"m": 1, "score": True})
    assert res.candidates and all(c.score is not None
                                  for c in res.candidates)
    scores = [c.score for c in res.candidates]
    assert scores == sorted(scores, reverse=True)
    # one 3-kw query at m=2 plans C(3,3) + C(3,2) = 4 subqueries
    eng.query_batch([query], k=1, tier="exact", backend="numpy",
                    semantics={"m": 2})
    assert eng.last_batch_stats.subqueries == 4
    # classic batch: one subquery per query
    eng.query_batch([query], k=1, tier="exact", backend="numpy")
    assert eng.last_batch_stats.subqueries == 1


def test_engine_device_tier_rejects_flex():
    ds = _corpus(9)
    eng = NKSEngine(ds, m=2, n_scales=4, seed=9)
    query = _queries(ds, 1, 2, 5)[0]
    with pytest.raises(ValueError, match="device tier"):
        eng.query(query, tier="device", semantics={"m": 1})
    # degenerate semantics on the device tier are fine (classic path)
    eng.query(query, tier="device", semantics={"m": 2})


def test_engine_approx_flex_feasible():
    ds = _corpus(10)
    eng = NKSEngine(ds, m=2, n_scales=4, build_exact=False, build_approx=True,
                    seed=10)
    query = _queries(ds, 1, 3, 6)[0]
    sem = QuerySemantics(m=2)
    universe = set(brute_force.enumerate_candidates_flex(ds, query, sem))
    res = eng.query(query, k=2, tier="approx", semantics=sem)
    for c in res.candidates:
        assert c.ids in universe


# -------------------------------------------------------- runtime & launcher
def test_runtime_batch_key_separates_semantics():
    from repro.serve.runtime import _semantics_key
    assert _semantics_key(None) == ""
    a = _semantics_key({"m": 2, "weights": {"3": 2.0}})
    b = _semantics_key({"weights": {"3": 2.0}, "m": 2})
    assert a == b                                   # key-order insensitive
    assert _semantics_key({"m": 1}) != _semantics_key({"m": 2})
    assert _semantics_key(QuerySemantics(m=2)) == \
        QuerySemantics(m=2).canonical_key()


def test_launcher_grammar_and_score_rows():
    from repro.launch.serve import (_to_runtime_request, handle_request_safe)
    ds = _corpus(11, n=200)
    eng = NKSEngine(ds, m=2, n_scales=4, seed=11)
    query = _queries(ds, 1, 3, 7)[0]
    kw_wire = [str(query[0]), f"{query[1]}^3", query[2]]

    out = handle_request_safe(eng, {"keywords": kw_wire, "m": 1,
                                    "score": True, "k": 2},
                              tier="exact", k=1)
    assert out["keywords"] == query
    assert out["results"] and all("score" in r for r in out["results"])

    # classic rows carry no score field
    classic = handle_request_safe(eng, {"keywords": query}, tier="exact", k=1)
    assert all("score" not in r for r in classic["results"])

    # oracle agreement through the launcher surface
    sem = {"m": 1, "score": True,
           "weights": {query[1]: 3.0}}
    want = brute_force.search_flex(ds, query, k=2,
                                   semantics=sem)
    assert [r["ids"] for r in out["results"]] == \
        [list(c.ids) for c in want]

    # runtime conversion embeds the parsed semantics
    rt = _to_runtime_request(eng, {"keywords": kw_wire, "m": 1,
                                   "alpha": 0.5}, tier="exact", k=1)
    assert rt["keywords"] == query
    assert rt["semantics"] == {"m": 1, "weights": {query[1]: 3.0},
                               "alpha": 0.5}
    assert _to_runtime_request(eng, {"keywords": query}, tier="exact",
                               k=1)["semantics"] is None

    # invalid semantics become an error envelope, never a crash
    bad = handle_request_safe(eng, {"keywords": query, "m": 99},
                              tier="exact", k=1)
    assert bad["status"] == "error" and "exceeds" in bad["error"]


def test_launcher_explicit_weights_merge_with_boosts():
    from repro.launch.serve import _parse_query_semantics
    kws, sem = _parse_query_semantics(
        {"keywords": ["3^4", 7], "weights": {"3": 2.0, "7": 1.5}})
    assert kws == [3, 7]
    assert sem == {"weights": {3: 4.0, 7: 1.5}}    # inline boost wins


def test_runtime_end_to_end_semantics():
    """Semantics survive the async runtime: coalescing keys keep mixed
    batches apart and scored rows round-trip."""
    from repro.serve.runtime import RuntimeConfig, ServingRuntime
    ds = _corpus(12, n=200)
    eng = NKSEngine(ds, m=2, n_scales=4, seed=12)
    queries = _queries(ds, 2, 3, 8)
    rt = ServingRuntime(eng, RuntimeConfig(tier="exact", k=2))
    try:
        t1 = rt.submit({"op": "query", "keywords": queries[0],
                        "semantics": {"m": 1, "score": True}})
        t2 = rt.submit({"op": "query", "keywords": queries[1]})
        r1, r2 = t1.result(), t2.result()
    finally:
        rt.close()
    assert r1.status == "ok" and r2.status == "ok"
    assert all(c.score is not None for c in r1.payload["candidates"])
    assert all(c.score is None for c in r2.payload["candidates"])
    want = brute_force.search_flex(ds, queries[0], k=2,
                                   semantics={"m": 1, "score": True})
    assert [c.ids for c in r1.payload["candidates"]] == \
        [c.ids for c in want]


def test_semantics_module_has_no_heavy_imports():
    """semantics.py is imported by the launcher's request path — keep it
    free of jax/pallas imports (numpy-only)."""
    import repro.core.semantics as mod
    src = open(mod.__file__).read()
    assert "import jax" not in src
