"""Unit tests for the HLO census — the §Roofline measurement backbone."""
import textwrap

from repro.launch.hlo_census import census, dot_flops, parse_hlo

HLO = textwrap.dedent("""
HloModule test

%body (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %p = (s32[], f32[8,128]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,128]{1,0} get-tuple-element(%p), index=1
  %w = f32[32,128,128]{2,1,0} parameter(1)
  %wslice = f32[1,128,128]{2,1,0} dynamic-slice(%w, %i), dynamic_slice_sizes={1,128,128}
  %wmat = f32[128,128]{1,0} bitcast(%wslice)
  %y = f32[8,128]{1,0} dot(%x, %wmat), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %red = f32[8,128]{1,0} all-reduce(%y), replica_groups={}, to_apply=%add
  %one = s32[] constant(1)
  %inext = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,128]{1,0}) tuple(%inext, %red)
}

%cond (pc: (s32[], f32[8,128])) -> pred[] {
  %pc = (s32[], f32[8,128]{1,0}) parameter(0)
  %ic = s32[] get-tuple-element(%pc), index=0
  %n = s32[] constant(32)
  ROOT %lt = pred[] compare(%ic, %n), direction=LT
}

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (x0: f32[8,128]) -> (s32[], f32[8,128]) {
  %x0 = f32[8,128]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,128]{1,0}) tuple(%zero, %x0)
  ROOT %loop = (s32[], f32[8,128]{1,0}) while(%init), condition=%cond, body=%body
}
""")


def test_parse_finds_computations():
    comps = parse_hlo(HLO)
    assert "body" in comps and "cond" in comps and "main" in comps


def test_trip_count_from_condition_constant():
    out = census(HLO)
    # dot: 2 * 8*128 * 128 flops, x32 trips
    assert out["dot_flops_scaled"] == 2 * 8 * 128 * 128 * 32


def test_collective_bytes_scaled_by_trips():
    out = census(HLO)
    # all-reduce result f32[8,128] = 4096 bytes, x32
    assert out["bytes_scaled"]["all-reduce"] == 8 * 128 * 4 * 32
    assert out["bytes_raw"]["all-reduce"] == 8 * 128 * 4


def test_dot_flops_uses_contracting_dims():
    comps = parse_hlo(HLO)
    assert dot_flops(comps["body"]) == 2 * 8 * 128 * 128


def test_fallback_trip_count_from_dynamic_slice():
    # strip the condition constant -> falls back to ds leading dim (32)
    hlo2 = HLO.replace("%n = s32[] constant(32)", "%n = s32[] parameter(1)")
    out = census(hlo2)
    assert out["dot_flops_scaled"] == 2 * 8 * 128 * 128 * 32


def test_out_bytes_excludes_bookkeeping():
    comps = parse_hlo(HLO)
    body = comps["body"]
    # parameter/GTE/tuple/bitcast excluded; ds+dot+all-reduce+add counted
    expected = (1 * 128 * 128 * 4      # dynamic-slice
                + 8 * 128 * 4          # dot
                + 8 * 128 * 4          # all-reduce
                + 4)                   # inext add (s32[])
    assert body.out_bytes == expected
