"""Fault-injection suite for the async serving runtime.

Every named fault point from ``serve.faults`` is exercised — transient
dispatch raise, compaction crash mid-rebuild, kill between WAL append and
ack — plus queue overflow (the admission queue's designed backpressure, not
a fault). The invariants under test:

  * **coalescing parity** — answers through the runtime are bit-identical to
    direct ``query_batch`` calls, batched or not, degraded or not;
  * **bounded retries** — a transient dispatch failure retries with backoff
    at most ``max_retries`` times, then fails the batch loudly;
  * **no torn generation** — a compaction crash mid-rebuild leaves the old
    generation fully intact (nothing swapped) and, with a WAL attached,
    recovery replays the acked ops to a bit-identical state;
  * **no acknowledged write lost** — every op whose ticket resolved ``ok``
    is visible to later queries and survives recovery;
  * **orderly overload** — past ``max_queue`` requests are rejected
    immediately; past the degrade watermark, exact-tier requests are shed to
    the approx tier and say so per-response.
"""
import threading
import time

import numpy as np
import pytest

from repro.data.synthetic import random_queries, synthetic_dataset
from repro.serve.engine import NKSEngine
from repro.serve.faults import FaultPlan, InjectedCrash
from repro.serve.runtime import RuntimeConfig, ServingRuntime


def _corpus(n=300, d=5, u=24, seed=0):
    return synthetic_dataset(n=n, d=d, u=u, t=2, seed=seed)


def _keys(candidates):
    return [c.key() for c in candidates]


def _wait(pred, timeout=5.0):
    t0 = time.monotonic()
    while not pred():
        if time.monotonic() - t0 > timeout:
            raise AssertionError("condition not reached in time")
        time.sleep(0.002)


@pytest.fixture
def engine():
    return NKSEngine(_corpus(), seed=3, compact_min=10_000)


# ----------------------------------------------------------------- coalescing
def test_coalesced_batch_parity(engine):
    queries = random_queries(engine.dataset, 2, 24, seed=5)
    ref = engine.query_batch(queries, k=3, tier="exact")
    with ServingRuntime(engine, RuntimeConfig(max_batch=8,
                                              batch_window_s=0.01)) as rt:
        tickets = [rt.submit({"op": "query", "keywords": q, "k": 3,
                              "tier": "exact"}) for q in queries]
        results = [t.result(10) for t in tickets]
    assert all(r.ok for r in results)
    for got, want in zip(results, ref):
        assert _keys(got.payload["candidates"]) == _keys(want.candidates)
    assert rt.stats.batches < len(queries)          # coalescing happened
    assert rt.stats.batched_queries == len(queries)


def test_mixed_keys_still_parity(engine):
    """Different (tier, k) buckets interleaved: each request is answered at
    its own key, bit-identical to a direct call."""
    queries = random_queries(engine.dataset, 2, 12, seed=8)
    specs = [(q, ("exact" if i % 2 else "approx"), 1 + i % 3)
             for i, q in enumerate(queries)]
    with ServingRuntime(engine, RuntimeConfig(batch_window_s=0.005)) as rt:
        tickets = [rt.submit({"op": "query", "keywords": q, "k": k,
                              "tier": tier}) for q, tier, k in specs]
        results = [t.result(10) for t in tickets]
    for (q, tier, k), got in zip(specs, results):
        want = engine.query([int(v) for v in q], k=k, tier=tier)
        assert _keys(got.payload["candidates"]) == _keys(want.candidates)


def test_consecutive_ingest_ops_coalesce_into_one_run(tmp_path):
    """Back-to-back ingest ops at the queue head drain as one run behind a
    single WAL group-commit barrier: every ticket still acks only after the
    shared fsync, and the run counter proves the coalescing happened."""
    engine = NKSEngine(_corpus(), seed=3, compact_min=10_000)
    engine.attach_wal(str(tmp_path / "wal"))
    rng = np.random.default_rng(4)
    batches = [(rng.standard_normal((4, engine.dataset.dim))
                .astype(np.float32), [[0, 1]] * 4) for _ in range(5)]
    with ServingRuntime(engine, RuntimeConfig(batch_window_s=0.05)) as rt:
        with rt._engine_lock:                       # stall the worker
            tickets = [rt.submit({"op": "insert", "points": pts,
                                  "keywords": kws}) for pts, kws in batches]
        results = [t.result(10) for t in tickets]
    assert all(r.ok for r in results)
    assert rt.stats.ingest_runs >= 1                # multi-op run happened
    assert rt.stats.ingest_ops == len(batches)
    st = engine.wal_stats
    assert st.group_commits >= 1
    assert st.appends == len(batches)
    # Coalescing must amortize the barrier: fewer fsyncs than acked ops.
    assert st.fsyncs < len(batches)
    engine.close()


def test_ingest_barrier_not_reordered(engine):
    """A query admitted after an insert observes it: coalescing never hoists
    a query past an earlier ingest op."""
    rng = np.random.default_rng(2)
    pts = rng.standard_normal((5, engine.dataset.dim)).astype(np.float32)
    kws = [[0, 1]] * 5
    with ServingRuntime(engine, RuntimeConfig(batch_window_s=0.05)) as rt:
        with rt._engine_lock:                       # stall the worker
            t_q1 = rt.submit({"op": "query", "keywords": [0, 1], "k": 5,
                              "tier": "exact"})
            t_ins = rt.submit({"op": "insert", "points": pts,
                               "keywords": kws})
            t_q2 = rt.submit({"op": "query", "keywords": [0, 1], "k": 5,
                              "tier": "exact"})
        ids = t_ins.result(10).payload["ids"]
        after = t_q2.result(10)
        t_q1.result(10)
    got_ids = {i for c in after.payload["candidates"] for i in c.ids}
    # the inserted identical points dominate k=5 for their own keywords
    assert set(ids) & got_ids


# -------------------------------------------------------------------- retries
def test_transient_dispatch_retries_then_succeeds(engine):
    queries = random_queries(engine.dataset, 2, 4, seed=9)
    ref = engine.query_batch(queries, k=2, tier="exact")
    faults = FaultPlan(transient={"dispatch": (1, 2)})
    with ServingRuntime(engine, RuntimeConfig(retry_backoff_s=0.001),
                        faults=faults) as rt:
        tickets = [rt.submit({"op": "query", "keywords": q, "k": 2,
                              "tier": "exact"}) for q in queries]
        results = [t.result(10) for t in tickets]
    assert all(r.ok for r in results)
    assert rt.stats.dispatch_retries == 2           # bounded, counted
    assert faults.fired["dispatch"] == 2
    for got, want in zip(results, ref):
        assert _keys(got.payload["candidates"]) == _keys(want.candidates)


def test_retries_are_bounded(engine):
    faults = FaultPlan(transient={"dispatch": tuple(range(1, 20))})
    with ServingRuntime(engine, RuntimeConfig(max_retries=2,
                                              retry_backoff_s=0.001),
                        faults=faults) as rt:
        r = rt.submit({"op": "query", "keywords": [0, 1], "k": 1}).result(10)
    assert r.status == "error" and "3 attempts" in r.error
    assert rt.stats.dispatch_failures == 1
    assert faults.fired["dispatch"] == 3            # initial + 2 retries


def test_bad_request_isolated_from_batchmates(engine):
    with ServingRuntime(engine, RuntimeConfig(batch_window_s=0.02)) as rt:
        with rt._engine_lock:
            bad = rt.submit({"op": "query", "keywords": [99999], "k": 1})
            good = rt.submit({"op": "query", "keywords": [0, 1], "k": 1})
        rb, rg = bad.result(10), good.result(10)
    assert rb.status == "error" and "ValueError" in rb.error
    assert rg.ok


# ------------------------------------------------------- overload + deadlines
def test_queue_overflow_rejects_immediately(engine):
    cfg = RuntimeConfig(max_queue=4, batch_window_s=0.0)
    with ServingRuntime(engine, cfg) as rt:
        with rt._engine_lock:                       # worker blocks on first
            first = rt.submit({"op": "query", "keywords": [0], "k": 1})
            _wait(lambda: len(rt._queue) == 0)      # worker picked it up
            held = [rt.submit({"op": "query", "keywords": [0], "k": 1})
                    for _ in range(4)]
            over = rt.submit({"op": "query", "keywords": [0], "k": 1})
            assert over.done()                      # rejected synchronously
            assert over.result().status == "rejected"
            assert "full" in over.result().error
        results = [t.result(10) for t in [first, *held]]
    assert all(r.ok for r in results)               # accepted work unharmed
    assert rt.stats.rejected_full == 1


def test_deadline_expires_queued_request(engine):
    with ServingRuntime(engine, RuntimeConfig(batch_window_s=0.0)) as rt:
        with rt._engine_lock:
            first = rt.submit({"op": "query", "keywords": [0], "k": 1})
            _wait(lambda: len(rt._queue) == 0)
            doomed = rt.submit({"op": "query", "keywords": [0], "k": 1},
                               deadline_s=0.01)
            time.sleep(0.05)                        # deadline passes queued
        assert first.result(10).ok
        r = doomed.result(10)
    assert r.status == "timeout"
    assert rt.stats.expired == 1


def test_overload_sheds_exact_to_approx(engine):
    queries = random_queries(engine.dataset, 2, 5, seed=4)
    ref = engine.query_batch(queries, k=2, tier="approx")
    cfg = RuntimeConfig(max_queue=8, degrade_watermark=0.5,
                        batch_window_s=0.0)
    with ServingRuntime(engine, cfg) as rt:
        with rt._engine_lock:
            first = rt.submit({"op": "query", "keywords": queries[0],
                               "k": 2, "tier": "exact"})
            _wait(lambda: len(rt._queue) == 0)
            held = [rt.submit({"op": "query", "keywords": q, "k": 2,
                               "tier": "exact"}) for q in queries]
        assert first.result(10).degraded is False   # dispatched pre-overload
        results = [t.result(10) for t in held]
    # 5 queued >= 0.5 * 8: the batch was shed to approx, and says so.
    assert all(r.ok and r.degraded and r.tier == "approx" for r in results)
    assert rt.stats.degraded_queries == len(queries)
    for got, want in zip(results, ref):
        assert _keys(got.payload["candidates"]) == _keys(want.candidates)


# ---------------------------------------------------------------- compaction
def test_background_compaction_keeps_parity(engine):
    """Cadence-triggered off-thread compaction: ingest acks never wait for
    the rebuild, the swap is atomic, and post-swap answers match a reference
    engine that compacted synchronously."""
    engine.compact_min = 60                          # small cadence
    ref = NKSEngine(engine.dataset, seed=3, compact_min=60)
    rng = np.random.default_rng(6)
    queries = random_queries(engine.dataset, 2, 6, seed=7)
    with ServingRuntime(engine, RuntimeConfig(batch_window_s=0.0)) as rt:
        for _ in range(4):
            pts = rng.standard_normal((25, engine.dataset.dim)) \
                .astype(np.float32)
            kws = [sorted(rng.choice(24, 2, replace=False).tolist())
                   for _ in range(25)]
            assert rt.submit({"op": "insert", "points": pts,
                              "keywords": kws}).result(10).ok
            ref.insert(pts, kws)
        _wait(lambda: not rt._compacting and rt.stats.bg_compactions >= 1)
        tickets = [rt.submit({"op": "query", "keywords": q, "k": 2,
                              "tier": "exact"}) for q in queries]
        results = [t.result(10) for t in tickets]
    assert engine.corpus_generation >= 1
    want = ref.query_batch(queries, k=2, tier="exact")
    for got, w in zip(results, want):
        assert _keys(got.payload["candidates"]) == _keys(w.candidates)


def test_compaction_defers_ingest_not_queries(engine):
    """While a rebuild is in flight, ingest is parked (and acked after the
    swap); queries keep flowing against the old generation."""
    engine.compact_min = 40
    engine.compact_ratio = 0.05
    rng = np.random.default_rng(1)
    gate = threading.Event()
    orig_prepare = engine.compact_prepare

    def slow_prepare():
        gate.wait(5)
        return orig_prepare()
    engine.compact_prepare = slow_prepare
    try:
        with ServingRuntime(engine, RuntimeConfig(batch_window_s=0.0)) as rt:
            pts = rng.standard_normal((50, engine.dataset.dim)) \
                .astype(np.float32)
            kws = [[0, 1]] * 50
            assert rt.submit({"op": "insert", "points": pts,
                              "keywords": kws}).result(10).ok
            _wait(lambda: rt._compacting)           # rebuild gated open
            parked = rt.submit({"op": "insert", "points": pts[:3],
                                "keywords": kws[:3]})
            q = rt.submit({"op": "query", "keywords": [0, 1], "k": 1,
                           "tier": "exact"})
            assert q.result(10).ok                  # queries never stall
            _wait(lambda: rt.stats.deferred_ingest >= 1)
            assert not parked.done()                # ack waits for the swap
            gate.set()
            assert parked.result(10).ok             # flushed after commit
        assert rt.stats.bg_compactions == 1
        assert engine.corpus_generation == 1
    finally:
        engine.compact_prepare = orig_prepare


def test_worker_survives_empty_coalesce(engine):
    """The batch-window wait releases the lock; a deferred-ingest flush in
    that window puts an ingest op at the queue head and the barrier keeps
    everything — _gather_locked returns []. The worker must treat that as a
    spurious wakeup, not dispatch an empty batch and die."""
    with ServingRuntime(engine, RuntimeConfig(batch_window_s=0.0)) as rt:
        orig = rt._gather_locked
        calls = {"n": 0}

        def racy_gather():
            calls["n"] += 1
            if calls["n"] == 1:
                return []                       # simulate the lost race
            return orig()
        rt._gather_locked = racy_gather
        r = rt.submit({"op": "query", "keywords": [0, 1], "k": 1}).result(5)
        assert r.ok                             # worker looped, then served
        assert calls["n"] >= 2
        assert rt._worker.is_alive()


def test_compactor_survives_unexpected_exception(engine):
    """A real (non-injected) rebuild exception must not kill the compactor
    thread: the old generation keeps serving, the error is surfaced in
    stats/health, and the next churn trigger retries successfully."""
    engine.compact_min = 40
    engine.compact_ratio = 0.05
    rng = np.random.default_rng(12)
    orig_prepare = engine.compact_prepare
    state = {"boom": True}

    def buggy_prepare():
        if state["boom"]:
            state["boom"] = False
            raise ValueError("rebuild bug")
        return orig_prepare()
    engine.compact_prepare = buggy_prepare
    try:
        with ServingRuntime(engine, RuntimeConfig(batch_window_s=0.0)) as rt:
            def feed():
                pts = rng.standard_normal((50, engine.dataset.dim)) \
                    .astype(np.float32)
                return rt.submit({"op": "insert", "points": pts,
                                  "keywords": [[0, 1]] * 50}).result(10)
            assert feed().ok
            _wait(lambda: rt.stats.bg_compaction_errors == 1)
            assert rt._compactor.is_alive()     # survived the bug
            assert engine.corpus_generation == 0        # nothing swapped
            assert "ValueError" in rt.health()["last_compaction_error"]
            assert feed().ok                    # serving continues
            _wait(lambda: rt.stats.bg_compactions == 1)  # retry succeeds
        assert engine.corpus_generation == 1
    finally:
        engine.compact_prepare = orig_prepare


def test_close_drain_never_strands_deferred_ingest(engine):
    """close(drain=True) while a compaction is in flight: the worker drains
    the queue and exits, then the compactor flushes deferred ingest into a
    queue nobody serves. Those tickets must still resolve — a caller blocked
    in result() with no timeout must never hang forever."""
    engine.compact_min = 40
    engine.compact_ratio = 0.05
    rng = np.random.default_rng(15)
    gate = threading.Event()
    orig_prepare = engine.compact_prepare

    def slow_prepare():
        gate.wait(5)
        return orig_prepare()
    engine.compact_prepare = slow_prepare
    try:
        rt = ServingRuntime(engine, RuntimeConfig(batch_window_s=0.0))
        pts = rng.standard_normal((50, engine.dataset.dim)) \
            .astype(np.float32)
        assert rt.submit({"op": "insert", "points": pts,
                          "keywords": [[0, 1]] * 50}).result(10).ok
        _wait(lambda: rt._compacting)           # rebuild gated open
        parked = rt.submit({"op": "insert", "points": pts[:2],
                            "keywords": [[0, 1]] * 2})
        _wait(lambda: rt.stats.deferred_ingest >= 1)
        closer = threading.Thread(target=rt.close)
        closer.start()
        _wait(lambda: not rt._worker.is_alive())    # worker drained + exited
        gate.set()                              # now the compactor commits
        closer.join(10)
        assert not closer.is_alive()
        r = parked.result(1)                    # resolved, never stranded
        assert r.status == "rejected"           # unacked: rejection is safe
    finally:
        engine.compact_prepare = orig_prepare


def test_compaction_crash_leaves_no_torn_generation(tmp_path):
    """InjectedCrash mid-rebuild (after the compacted dataset materialises,
    before the new indices exist): nothing is swapped — the old generation
    keeps answering bit-identically — and WAL recovery replays the acked ops
    to a state matching an uninterrupted reference."""
    ds = _corpus(n=200)
    faults = FaultPlan(crash={"compact": 1})
    engine = NKSEngine(ds, seed=3, compact_min=40, faults=faults)
    engine.attach_wal(str(tmp_path / "wal"))
    ref = NKSEngine(ds, seed=3, compact_min=40, auto_compact=False)
    rng = np.random.default_rng(9)
    queries = random_queries(ds, 2, 6, seed=3)
    pts = rng.standard_normal((50, ds.dim)).astype(np.float32)
    kws = [sorted(rng.choice(24, 2, replace=False).tolist())
           for _ in range(50)]

    rt = ServingRuntime(engine, RuntimeConfig(batch_window_s=0.0))
    try:
        assert rt.submit({"op": "insert", "points": pts,
                          "keywords": kws}).result(10).ok   # acked
        ref.insert(pts, kws)
        _wait(lambda: rt.health()["crashed"])       # compactor died
        assert rt.stats.bg_compactions == 0
        # No torn generation: nothing swapped, old generation intact and
        # bit-identical (the engine object itself is still coherent).
        assert engine.corpus_generation == 0
        for got, want in zip(engine.query_batch(queries, k=2, tier="exact"),
                             ref.query_batch(queries, k=2, tier="exact")):
            assert _keys(got.candidates) == _keys(want.candidates)
        # Post-crash submissions are refused, not silently dropped.
        r = rt.submit({"op": "query", "keywords": [0], "k": 1}).result(10)
        assert r.status == "rejected" and "down" in r.error
    finally:
        rt.close()
    engine.close()

    # Process restart: WAL replay reaches the same acked state (the crashed
    # compaction was never logged — it never committed).
    rec = NKSEngine.recover(str(tmp_path / "wal"))
    assert rec.ingest.replayed_ops == 1
    for got, want in zip(rec.query_batch(queries, k=2, tier="exact"),
                         ref.query_batch(queries, k=2, tier="exact")):
        assert _keys(got.candidates) == _keys(want.candidates)
    rec.close()


def test_transient_compaction_fault_retries_on_next_trigger(engine):
    engine.compact_min = 40
    engine.compact_ratio = 0.05
    faults = FaultPlan(transient={"compact": 1})
    engine._faults = faults
    rng = np.random.default_rng(4)
    with ServingRuntime(engine, RuntimeConfig(batch_window_s=0.0),
                        faults=faults) as rt:
        def feed():
            pts = rng.standard_normal((50, engine.dataset.dim)) \
                .astype(np.float32)
            return rt.submit({"op": "insert", "points": pts,
                              "keywords": [[0, 1]] * 50}).result(10)
        assert feed().ok
        _wait(lambda: rt.stats.bg_compaction_faults == 1)
        assert engine.corpus_generation == 0        # rebuild failed, no swap
        assert feed().ok                            # serving continues
        _wait(lambda: rt.stats.bg_compactions == 1)  # next trigger succeeds
    assert engine.corpus_generation == 1


# ------------------------------------------------------------- wal_ack crash
def test_wal_ack_crash_through_runtime(tmp_path):
    """Kill between WAL append and ack, driven through the runtime: the
    caller sees ``crashed`` (no ack), recovery applies the durable op, and
    every op acked before the crash survives."""
    ds = _corpus(n=150)
    faults = FaultPlan(crash={"wal_ack": 2})
    engine = NKSEngine(ds, seed=1, compact_min=10_000, faults=faults)
    engine.attach_wal(str(tmp_path / "wal"))
    rng = np.random.default_rng(3)
    b1 = (rng.standard_normal((6, ds.dim)).astype(np.float32), [[0, 1]] * 6)
    b2 = (rng.standard_normal((4, ds.dim)).astype(np.float32), [[2, 3]] * 4)
    queries = random_queries(ds, 2, 5, seed=6)

    rt = ServingRuntime(engine, RuntimeConfig(batch_window_s=0.0))
    try:
        acked = rt.submit({"op": "insert", "points": b1[0],
                           "keywords": b1[1]}).result(10)
        assert acked.ok                             # durable + acknowledged
        unacked = rt.submit({"op": "insert", "points": b2[0],
                             "keywords": b2[1]}).result(10)
        assert unacked.status == "crashed"          # durable, never acked
        assert rt.health()["crashed"]
    finally:
        rt.close()

    rec = NKSEngine.recover(str(tmp_path / "wal"))
    ref = NKSEngine(ds, seed=1, compact_min=10_000)
    ref.insert(*b1)
    ref.insert(*b2)        # at-least-once below the ack horizon
    assert rec.ingest.replayed_ops == 2
    for tier in ("exact", "approx"):
        for got, want in zip(rec.query_batch(queries, k=2, tier=tier),
                             ref.query_batch(queries, k=2, tier=tier)):
            assert _keys(got.candidates) == _keys(want.candidates)
    # No acknowledged write lost: b1's points are all live and queryable.
    got = rec.query([0, 1], k=6, tier="exact")
    assert {i for c in got.candidates for i in c.ids} \
        .intersection(range(ds.n, ds.n + 6))
    rec.close()


# -------------------------------------------------------------------- health
def test_health_and_close_restores_engine(engine):
    was = engine.auto_compact
    rt = ServingRuntime(engine)
    h = rt.submit({"op": "health"}).result(1)
    assert h.ok and h.payload["queue_depth"] == 0
    assert h.payload["generation"] == 0
    assert h.payload["degraded"] is False
    assert h.payload["wal_attached"] is False
    assert engine.auto_compact is False             # runtime owns cadence
    rt.close()
    assert engine.auto_compact is was               # returned on close
