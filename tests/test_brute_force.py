"""Unit tests for the brute-force NKS oracle itself.

The oracle anchors every parity suite (filtered, streaming, sharded), so it
must be trusted independently: hand-checkable instances with known answers,
internal consistency between its three entry points, and — for the filtered
variant — equivalence with materialising the eligible sub-corpus and running
the unfiltered oracle there (the definitional ground truth).
"""
import numpy as np
import pytest

from repro.core import brute_force
from repro.core.filters import Filter, where
from repro.core.subset_search import is_minimal_candidate, pairwise_l2_numpy
from repro.core.types import make_dataset, merge_tenants
from repro.data.synthetic import attach_attrs, random_queries, synthetic_dataset


def _hand_dataset():
    """Five points on a line, two keywords; distances are the id gaps * 10."""
    pts = np.array([[0.0], [10.0], [20.0], [30.0], [40.0]], np.float32)
    kws = [[0], [1], [0], [1], [0, 1]]
    return make_dataset(pts, kws, n_keywords=2)


def test_hand_instance_known_answer():
    ds = _hand_dataset()
    pq = brute_force.search(ds, [0, 1], k=3)
    # Point 4 covers both keywords alone: diameter 0 is the unique optimum.
    assert pq.items[0].ids == (4,) and pq.items[0].diameter == 0.0
    # Next best: adjacent {0,1}, {1,2}, {2,3}, {3,4}... all at diameter 10,
    # k=3 keeps two of them (ordered by ids on the tie).
    assert [c.diameter for c in pq.items] == [0.0, 10.0, 10.0]
    for c in pq.items[1:]:
        assert len(c.ids) == 2 and abs(c.ids[0] - c.ids[1]) == 1


def test_enumerate_candidates_minimal_and_covering():
    ds = _hand_dataset()
    cands = list(brute_force.enumerate_candidates(ds, [0, 1]))
    assert (4,) in cands
    for ids in cands:
        kws = set()
        for i in ids:
            kws.update(ds.kw.row(i).tolist())
        assert {0, 1} <= kws
        assert is_minimal_candidate(ids, [0, 1], ds)
    # {0, 4} is NOT minimal (4 alone covers): must not be enumerated.
    assert (0, 4) not in cands
    assert brute_force.count_candidates(ds, [0, 1]) == len(cands)


def test_search_matches_enumeration_ranking():
    """search() top-k == sorting the exhaustive enumeration by the paper's
    (diameter, cardinality) key."""
    ds = synthetic_dataset(n=40, d=3, u=5, t=2, seed=3)
    for q in random_queries(ds, 2, 4, seed=1):
        pq = brute_force.search(ds, q, k=3)
        ranked = sorted(
            ((brute_force.set_diameter(ids, ds), len(ids))
             for ids in brute_force.enumerate_candidates(ds, q)))
        got = [(c.diameter, len(c.ids)) for c in pq.items]
        np.testing.assert_allclose([g[0] for g in got],
                                   [r[0] for r in ranked[:len(got)]], rtol=1e-5)
        assert [g[1] for g in got] == [r[1] for r in ranked[:len(got)]]


def test_empty_keyword_group_yields_empty_topk():
    ds = _hand_dataset()
    ds2 = make_dataset(ds.points, [[0], [1], [0], [1], [0, 1]], n_keywords=3)
    pq = brute_force.search(ds2, [0, 2], k=2)    # keyword 2 tags nothing
    assert pq.items == []
    assert list(brute_force.enumerate_candidates(ds2, [0, 2])) == []


def test_max_tuples_guard():
    ds = synthetic_dataset(n=200, d=2, u=2, t=1, seed=0)
    with pytest.raises(ValueError, match="infeasible"):
        brute_force.search(ds, [0, 1], k=1, max_tuples=100)


# ------------------------------------------------------------ filtered oracle
def _subcorpus_reference(ds, query, eligible, k):
    """The definitional filtered answer: materialise the eligible sub-corpus
    (remapping ids) and run the unfiltered oracle there."""
    keep = np.flatnonzero(eligible)
    sub = make_dataset(ds.points[keep],
                       [ds.kw.row(int(i)).tolist() for i in keep],
                       n_keywords=ds.n_keywords)
    pq = brute_force.search(sub, query, k=k)
    return [(tuple(int(keep[j]) for j in c.ids), c.diameter) for c in pq.items]


@pytest.mark.parametrize("sel", [1.0, 0.6, 0.25, 0.05, 0.0])
def test_filtered_search_equals_subcorpus_oracle(sel):
    ds = attach_attrs(synthetic_dataset(n=60, d=4, u=8, t=2, seed=11), seed=2)
    flt = where(("price", "<", 100.0 * sel))
    eligible = flt.evaluate(ds)
    assert abs(eligible.mean() - sel) < 0.2
    for q in random_queries(ds, 2, 4, seed=5):
        got = brute_force.search(ds, q, k=2, eligible=eligible)
        want = _subcorpus_reference(ds, q, eligible, k=2)
        np.testing.assert_allclose([c.diameter for c in got.items],
                                   [w[1] for w in want], rtol=1e-5)
        # id sets match too: the sub-corpus remap preserves the tie-break
        # ordering only up to equal keys, so compare as sets of frozensets
        # within each diameter class.
        assert {frozenset(c.ids) for c in got.items} == \
            {frozenset(w[0]) for w in want}
        for c in got.items:
            assert all(eligible[i] for i in c.ids)


def test_filtered_enumeration_is_subset_of_unfiltered():
    ds = attach_attrs(synthetic_dataset(n=40, d=3, u=6, t=2, seed=4), seed=3)
    eligible = ds.attrs["price"] < 50.0
    q = random_queries(ds, 2, 1, seed=2)[0]
    filt = set(brute_force.enumerate_candidates(ds, q, eligible=eligible))
    for ids in filt:
        assert all(eligible[i] for i in ids)
    # Every filtered candidate is minimal+covering, hence also a candidate of
    # the unfiltered instance.
    full = set(brute_force.enumerate_candidates(ds, q))
    assert filt <= full


def test_search_filtered_wrapper_tenant_scoping():
    mt = merge_tenants({
        "acme": {"points": np.array([[0.0], [10.0]], np.float32),
                 "keywords": [[0], [1]], "n_keywords": 2},
        "globex": {"points": np.array([[1.0], [2.0]], np.float32),
                   "keywords": [[0], [1]], "n_keywords": 2},
    })
    # Tenant-local query [0, 1]: acme's pair is 10 apart, globex's 1 apart —
    # scoping must keep each tenant inside its own namespace and points.
    got_a = brute_force.search_filtered(mt, [0, 1], Filter(tenant="acme"), k=1)
    got_g = brute_force.search_filtered(mt, [0, 1], {"tenant": "globex"}, k=1)
    assert got_a.items[0].ids == (0, 1) and got_a.items[0].diameter == 10.0
    assert got_g.items[0].ids == (2, 3) and got_g.items[0].diameter == 1.0
    # no filter -> plain search (coerce passes None through)
    plain = brute_force.search_filtered(mt, [0, 1], None, k=1)
    assert plain.items == brute_force.search(mt, [0, 1], k=1).items


def test_zero_and_full_selectivity():
    ds = attach_attrs(synthetic_dataset(n=30, d=3, u=5, t=2, seed=6), seed=1)
    q = random_queries(ds, 2, 1, seed=0)[0]
    none_elig = np.zeros(ds.n, dtype=bool)
    assert brute_force.search(ds, q, k=2, eligible=none_elig).items == []
    all_elig = np.ones(ds.n, dtype=bool)
    a = brute_force.search(ds, q, k=2, eligible=all_elig)
    b = brute_force.search(ds, q, k=2)
    assert [(c.ids, c.diameter) for c in a.items] == \
        [(c.ids, c.diameter) for c in b.items]


def test_set_diameter_matches_pairwise():
    ds = synthetic_dataset(n=20, d=4, u=4, t=1, seed=8)
    ids = [2, 7, 11]
    d = brute_force.set_diameter(ids, ds)
    ref = pairwise_l2_numpy(ds.points[ids], ds.points[ids]).max()
    np.testing.assert_allclose(d, ref, rtol=1e-12)
    assert brute_force.set_diameter([3], ds) == 0.0
