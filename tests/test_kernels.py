"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp oracles,
swept over shapes (incl. non-multiple-of-block tails) and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES_MN = [(8, 8, 4), (128, 128, 16), (130, 70, 33), (257, 129, 64), (64, 300, 8)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("m,n,d", SHAPES_MN)
@pytest.mark.parametrize("dtype", DTYPES)
def test_pairwise_l2_join_matches_ref(m, n, d, dtype):
    key = jax.random.PRNGKey(m * 1000 + n)
    a = jax.random.normal(key, (m, d), dtype=dtype) * 10
    b = jax.random.normal(jax.random.fold_in(key, 1), (n, d), dtype=dtype) * 10
    r = 15.0
    sq, cnt = ops.pairwise_l2_join(a, b, r, bm=64, bn=64, interpret=True)
    sq_ref, cnt_ref = ref.pairwise_l2_join_ref(a, b, r)
    tol = 1e-4 if dtype == jnp.float32 else 2e-1
    np.testing.assert_allclose(np.asarray(sq), np.asarray(sq_ref), rtol=tol, atol=tol)
    # counts can differ by boundary ties under bf16 rounding; exact for fp32
    if dtype == jnp.float32:
        assert int(cnt.sum()) == int(cnt_ref)


def test_pairwise_l2_join_count_blocks_sum():
    key = jax.random.PRNGKey(7)
    a = jax.random.normal(key, (200, 12)) * 5
    sq, cnt = ops.pairwise_l2_join(a, a, 4.0, bm=64, bn=64, interpret=True)
    assert cnt.shape == (4, 4)
    _, cnt_ref = ref.pairwise_l2_join_ref(a, a, 4.0)
    assert int(cnt.sum()) == int(cnt_ref)


def test_pairwise_l2_self_diagonal_zeroish():
    """fp32 ||a||^2+||b||^2-2ab cancels on the diagonal; the error must stay
    within a few ulps of the squared-norm scale (the pruning-filter contract —
    exact rescoring runs in float64 on the control plane)."""
    a = jax.random.normal(jax.random.PRNGKey(0), (96, 24)) * 100
    sq, _ = ops.pairwise_l2_join(a, a, 1.0, interpret=True)
    scale = float(jnp.max(jnp.sum(a * a, -1)))
    assert float(jnp.diagonal(sq).max()) < 32 * np.finfo(np.float32).eps * scale


@pytest.mark.parametrize("n,d,m", [(16, 8, 2), (300, 33, 2), (128, 64, 4), (70, 16, 3)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_project_and_bin_matches_ref(n, d, m, dtype):
    key = jax.random.PRNGKey(n + d)
    x = (jax.random.uniform(key, (n, d), dtype=jnp.float32) * 1000).astype(dtype)
    z = jax.random.normal(jax.random.fold_in(key, 2), (m, d), dtype=jnp.float32)
    z = (z / jnp.linalg.norm(z, axis=1, keepdims=True)).astype(dtype)
    w, c = 37.5, 1 << 20
    h1, h2, p = ops.project_and_bin(x, z, w, c, bn=64, interpret=True)
    h1r, h2r, pr = ref.project_and_bin_ref(x, z, w, c)
    np.testing.assert_allclose(np.asarray(p), np.asarray(pr),
                               rtol=1e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-4)
    if dtype == jnp.float32:
        # bin ids may legitimately differ on exact bin boundaries; require
        # near-total agreement and never more than 1 bin apart.
        agree = np.mean(np.asarray(h1) == np.asarray(h1r))
        assert agree > 0.999
        assert np.abs(np.asarray(h1) - np.asarray(h1r)).max() <= 1
        assert np.abs(np.asarray(h2) - np.asarray(h2r)).max() <= 1


@pytest.mark.parametrize("t,q,d", [(4, 2, 8), (100, 3, 16), (130, 5, 7), (257, 9, 32)])
def test_tuple_diameters_matches_ref(t, q, d):
    key = jax.random.PRNGKey(t * 7 + q)
    pts = jax.random.normal(key, (t, q, d)) * 20
    got = ops.tuple_diameters(pts, bt=64, interpret=True)
    want = ref.tuple_diameters_ref(pts)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-3)


def test_tuple_diameters_padded_duplicates():
    """Padding a tuple by repeating a point must not change its diameter."""
    pts = jax.random.normal(jax.random.PRNGKey(3), (10, 3, 8)) * 10
    padded = jnp.concatenate([pts, pts[:, :1, :]], axis=1)   # (10, 4, 8)
    np.testing.assert_allclose(
        np.asarray(ops.tuple_diameters(pts, interpret=True)),
        np.asarray(ops.tuple_diameters(padded, interpret=True)), rtol=1e-5, atol=1e-4)


def test_kernel_vs_numpy_control_plane():
    """Kernel path agrees with the float64 control-plane distances within the
    backend's published fp32 cancellation bound — the slack the enumeration
    stage prunes with before exact rescoring (the pruning-filter contract)."""
    from repro.core.backend import PallasBackend
    from repro.core.subset_search import pairwise_l2_numpy
    rng = np.random.default_rng(0)
    a = rng.uniform(0, 100, (50, 16)).astype(np.float32)
    sq, _ = ops.pairwise_l2_join(jnp.asarray(a), jnp.asarray(a), 1.0, interpret=True)
    d_np = pairwise_l2_numpy(a, a)
    err = np.abs(np.sqrt(np.asarray(sq, np.float64)) - d_np).max()
    assert err < PallasBackend._slack(a)     # the published contract bound
    assert err < 0.3   # regression guard: ~2x the observed worst case (0.125)


def test_pairwise_l2_join_runtime_r_no_recompile():
    """r is a traced SMEM scalar: sweeping thresholds reuses one compiled fn."""
    key = jax.random.PRNGKey(11)
    a = jax.random.normal(key, (90, 12)) * 10
    b = jax.random.normal(jax.random.fold_in(key, 1), (70, 12)) * 10
    f = jax.jit(lambda a, b, r: ops.pairwise_l2_join(
        a, b, r, bm=64, bn=64, interpret=True)[1].sum())
    for r in (20.0, 45.0, 70.0):
        _, want = ref.pairwise_l2_join_ref(a, b, r)
        assert int(f(a, b, jnp.float32(r))) == int(want)
    assert f._cache_size() == 1


@pytest.mark.parametrize("s,p,d,bm", [(3, 10, 8, 16), (5, 37, 9, 16),
                                      (2, 200, 12, 128), (9, 7, 33, 128)])
def test_pairwise_l2_join_batched_matches_ref(s, p, d, bm):
    rng = np.random.default_rng(s * 100 + p)
    x = rng.uniform(0, 100, (s, p, d)).astype(np.float32)
    lens = rng.integers(1, p + 1, size=s).astype(np.int32)
    radii = rng.uniform(0, 150, size=s).astype(np.float32)
    radii[0] = np.inf
    sq, cnt = ops.pairwise_l2_join_batched(
        jnp.asarray(x), jnp.asarray(lens), jnp.asarray(radii),
        bm=bm, bn=bm, interpret=True)
    sq_ref, cnt_ref = ref.pairwise_l2_join_batched_ref(jnp.asarray(x), lens, radii)
    assert sq.shape == (s, p, p)
    np.testing.assert_allclose(np.asarray(sq), np.asarray(sq_ref),
                               rtol=1e-4, atol=0.5)
    np.testing.assert_array_equal(np.asarray(cnt).sum(axis=(1, 2)),
                                  np.asarray(cnt_ref))


@pytest.mark.parametrize("s,p,d,bm", [(3, 10, 8, 16), (5, 37, 9, 16),
                                      (2, 200, 12, 128), (9, 7, 33, 128)])
def test_pairwise_l2_join_batched_masked_matches_ref(s, p, d, bm):
    """Packed-bitmask output: Pallas kernel (interpret) == jnp reference ==
    the XLA serving lowering, bit for bit, including r = inf and zero-length
    (empty) subsets."""
    rng = np.random.default_rng(s * 100 + p)
    x = rng.uniform(0, 100, (s, p, d)).astype(np.float32)
    lens = rng.integers(0, p + 1, size=s).astype(np.int32)
    lens[-1] = 0                                     # empty subset
    radii = rng.uniform(0, 150, size=s).astype(np.float32)
    radii[0] = np.inf
    bn = max(32, bm)
    m_pl, c_pl = ops.pairwise_l2_join_batched_masked(
        jnp.asarray(x), lens, radii, bm=bm, bn=bn, impl="pallas",
        interpret=True)
    m_ref, c_ref = ref.pairwise_l2_join_batched_masked_ref(
        jnp.asarray(x), lens, radii)
    m_xla, c_xla = ops.pairwise_l2_join_batched_masked(
        jnp.asarray(x), lens, radii, impl="xla")
    assert m_pl.shape == (s, p, (p + 31) // 32)
    np.testing.assert_array_equal(np.asarray(m_pl), np.asarray(m_ref))
    np.testing.assert_array_equal(np.asarray(m_xla), np.asarray(m_ref))
    np.testing.assert_array_equal(np.asarray(c_pl), np.asarray(c_ref))
    np.testing.assert_array_equal(np.asarray(c_xla), np.asarray(c_ref))


def test_pairwise_l2_join_batched_masked_bits_match_dense():
    """Every mask bit equals thresholding the kernel's own dense sq block —
    including pad columns (always 0) and the fmax-masked tail under r=inf."""
    rng = np.random.default_rng(3)
    s, p, d = 4, 21, 6
    x = rng.uniform(0, 50, (s, p, d)).astype(np.float32)
    lens = np.array([21, 7, 1, 0], np.int32)
    radii = np.array([30.0, np.inf, 10.0, 5.0], np.float32)
    mask, cnt, sq = ops.pairwise_l2_join_batched_masked(
        jnp.asarray(x), lens, radii, bm=16, bn=32, impl="pallas",
        interpret=True, with_sq=True)
    mask, sq = np.asarray(mask), np.asarray(sq)
    cols = np.arange(p)
    for si in range(s):
        n = int(lens[si])
        dense = np.zeros((p, p), bool)
        dense[:n, :n] = sq[si, :n, :n] <= np.float32(radii[si]) ** 2
        unpacked = ((mask[si][:, cols // 32]
                     >> (cols % 32).astype(np.uint32)) & 1).astype(bool)
        np.testing.assert_array_equal(unpacked, dense, err_msg=f"subset {si}")
        assert int(np.asarray(cnt)[si]) == int(dense.sum())


def test_pairwise_l2_join_batched_masks_padding():
    """Rows/cols past each subset's length are fmax and never counted."""
    x = np.ones((2, 8, 4), np.float32)
    lens = np.array([3, 0], np.int32)
    sq, cnt = ops.pairwise_l2_join_batched(
        jnp.asarray(x), jnp.asarray(lens), 1.0, bm=8, bn=8, interpret=True)
    sq = np.asarray(sq)
    fmax = np.finfo(np.float32).max
    assert np.all(sq[0, :3, :3] == 0.0)
    assert np.all(sq[0, 3:, :] == fmax) and np.all(sq[0, :, 3:] == fmax)
    assert np.all(sq[1] == fmax)
    assert np.asarray(cnt).sum(axis=(1, 2)).tolist() == [9, 0]


# ----------------------------------------------------------- flash attention
@pytest.mark.parametrize("s,t,h,hd,causal,window", [
    (64, 64, 2, 16, True, None),
    (128, 128, 1, 32, True, None),
    (96, 96, 2, 16, False, None),
    (64, 64, 2, 16, True, 32),
    (72, 72, 3, 8, True, None),       # non-multiple-of-block tails
])
def test_flash_attention_matches_ref(s, t, h, hd, causal, window):
    from repro.kernels.flash_attention import flash_attention
    key = jax.random.PRNGKey(s + t)
    q = jax.random.normal(key, (2, s, h, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, t, h, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, t, h, hd), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          bq=32, bk=32, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_matches_model_attention():
    """The kernel agrees with the model's jnp blockwise path (its fallback)."""
    from repro.kernels.flash_attention import flash_attention
    from repro.models.common import blockwise_attention
    key = jax.random.PRNGKey(0)
    b, s, h, hd = 2, 64, 2, 16
    q = jax.random.normal(key, (b, s, h, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, hd), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    want = blockwise_attention(q, k, v, pos, pos, causal=True, window=None,
                               block=16)
    got = flash_attention(q, k, v, causal=True, bq=16, bk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_pairwise_l2_join_batched_masked_eligibility_fold():
    """The device-side eligibility fold (filtered NKS, ISSUE 5): packed
    eligibility words AND into the mask identically on both lowerings, the
    output keeps the unfiltered (S, P, ceil(P/32)) layout, and counts become
    eligible-pair popcounts."""
    from repro.core.subset_search import pack_join_mask, unpack_join_mask
    rng = np.random.default_rng(9)
    s, p, d = 5, 37, 6
    x = rng.uniform(0, 50, (s, p, d)).astype(np.float32)
    lens = np.array([37, 20, 7, 1, 0], np.int32)
    radii = np.array([30.0, np.inf, 10.0, 5.0, 8.0], np.float32)
    el = rng.random((s, p)) < 0.5
    elig = pack_join_mask(el)                          # (s, ceil(p/32))

    m_plain, c_plain = ops.pairwise_l2_join_batched_masked(
        jnp.asarray(x), lens, radii, impl="xla")
    m_xla, c_xla = ops.pairwise_l2_join_batched_masked(
        jnp.asarray(x), lens, radii, jnp.asarray(elig), impl="xla")
    m_pl, c_pl = ops.pairwise_l2_join_batched_masked(
        jnp.asarray(x), lens, radii, jnp.asarray(elig), bm=16, bn=32,
        impl="pallas", interpret=True)
    assert m_xla.shape == m_plain.shape                # layout unchanged
    np.testing.assert_array_equal(np.asarray(m_pl), np.asarray(m_xla))
    np.testing.assert_array_equal(np.asarray(c_pl), np.asarray(c_xla))
    for si in range(s):
        ref = (unpack_join_mask(np.asarray(m_plain)[si], p).astype(bool)
               & el[si][:, None] & el[si][None, :])
        got = unpack_join_mask(np.asarray(m_xla)[si], p).astype(bool)
        np.testing.assert_array_equal(got, ref, err_msg=f"subset {si}")
        assert int(np.asarray(c_xla)[si]) == int(ref.sum())


# ------------------------------------------------------------- cascade tier 0
@pytest.mark.parametrize("dtype", ["bf16", "int8"])
@pytest.mark.parametrize("s,p,d", [(4, 37, 8), (6, 64, 16), (3, 130, 5)])
def test_join_batched_counts_superset_of_f64(s, p, d, dtype):
    """Safety contract of the coarse prune tier: at the error-widened coarse
    radius, the low-precision count can never miss a pair the exact join at
    the base radius would find. (Coarse count <= diagonal bound therefore
    proves the fp32 join empty.)"""
    rng = np.random.default_rng(s * 10 + p + d)
    x = rng.uniform(-20, 20, (s, p, d)).astype(np.float32)
    lens = rng.integers(1, p + 1, size=s).astype(np.int32)
    lens[-1] = 0
    radii = rng.uniform(1.0, 25.0, size=s).astype(np.float32)
    # Coarse widening mirrors the backend: bf16 coordinate rounding on top of
    # the fp32-identity slack, times (1 + eps) headroom.
    norms = np.sqrt((x.astype(np.float64) ** 2).sum(-1)).max()
    rc = ((radii + 2 * 2.0 ** -8 * norms) * 1.05).astype(np.float32)
    cnt = np.asarray(ops.pairwise_l2_join_batched_counts(
        jnp.asarray(x), lens, rc, dtype=dtype, impl="xla"))
    for si in range(s):
        n = int(lens[si])
        pts = x[si, :n].astype(np.float64)
        d2 = ((pts[:, None] - pts[None, :]) ** 2).sum(-1)
        exact = int((np.sqrt(d2) <= radii[si]).sum())
        assert cnt[si] >= exact, f"subset {si}: {cnt[si]} < {exact}"


def test_join_batched_counts_pallas_matches_xla():
    """The Mosaic bf16 lowering and the XLA lowering agree bit-for-bit on
    counts (same bf16 rounding, same fp32 accumulation order contract)."""
    rng = np.random.default_rng(11)
    s, p, d = 5, 70, 12
    x = rng.uniform(-10, 10, (s, p, d)).astype(np.float32)
    lens = np.array([70, 33, 16, 1, 0], np.int32)
    radii = np.array([8.0, np.inf, 4.0, 1.0, 2.0], np.float32)
    c_xla = ops.pairwise_l2_join_batched_counts(
        jnp.asarray(x), lens, radii, dtype="bf16", impl="xla")
    c_pl = ops.pairwise_l2_join_batched_counts(
        jnp.asarray(x), lens, radii, dtype="bf16", bm=32, bn=32,
        impl="pallas", interpret=True)
    np.testing.assert_array_equal(np.asarray(c_pl), np.asarray(c_xla))


@pytest.mark.parametrize("dtype", ["bf16", "int8"])
def test_join_batched_counts_eligibility_fold(dtype):
    """Folded counts equal the eligible-pair count of the folded masked join
    — the prune tier sees the same filtered world as tier 1."""
    from repro.core.subset_search import pack_join_mask
    rng = np.random.default_rng(13)
    s, p, d = 4, 45, 7
    x = rng.uniform(-5, 5, (s, p, d)).astype(np.float32)
    lens = np.array([45, 20, 3, 0], np.int32)
    radii = np.array([4.0, 2.0, np.inf, 1.0], np.float32)
    el = rng.random((s, p)) < 0.5
    elig = jnp.asarray(pack_join_mask(el))
    cnt = np.asarray(ops.pairwise_l2_join_batched_counts(
        jnp.asarray(x), lens, radii, elig, dtype=dtype, impl="xla"))
    cnt_plain = np.asarray(ops.pairwise_l2_join_batched_counts(
        jnp.asarray(x), lens, radii, dtype=dtype, impl="xla"))
    for si in range(s):
        n = int(lens[si])
        assert cnt[si] <= cnt_plain[si]
        if n and np.isinf(radii[si]):
            assert cnt[si] == int(el[si, :n].sum()) ** 2


@pytest.mark.parametrize("dtype", ["bf16", "int8"])
def test_join_batched_counts_adversarial_boundary(dtype):
    """Seeded adversarial construction for the cascade error bound: pairs
    placed within r*(1 +/- eps) of the threshold, where bf16's 8-bit mantissa
    (or int8's 7-bit grid) rounds distances across the boundary. Every pair
    at true distance <= r must be counted at the widened coarse radius; pairs
    just outside may be over-counted (settled later by the float64 rescore)
    but never under-counted."""
    d = 8
    for seed, r in ((0, 1.0), (1, 7.3), (2, 123.0)):
        rng = np.random.default_rng(seed)
        base = rng.uniform(-1, 1, d)
        base /= np.linalg.norm(base)
        pts = [rng.uniform(-r, r, d).astype(np.float32)]
        # straddle the threshold at +/- k ulps of the bf16 grid
        for k in (-4, -1, 0, 1, 4):
            delta = r * (1.0 + k * 2.0 ** -9)
            pts.append((pts[0] + base * delta).astype(np.float32))
        x = np.stack(pts)[None].astype(np.float32)     # (1, 6, d)
        lens = np.array([x.shape[1]], np.int32)
        pf = x[0].astype(np.float64)
        d2 = ((pf[:, None] - pf[None, :]) ** 2).sum(-1)
        exact = int((np.sqrt(d2) <= r).sum())
        norms = np.sqrt((pf ** 2).sum(-1)).max()
        rc = np.array([(r + 2 * 2.0 ** -8 * norms) * 1.05], np.float32)
        cnt = int(np.asarray(ops.pairwise_l2_join_batched_counts(
            jnp.asarray(x), lens, rc, dtype=dtype, impl="xla"))[0])
        assert cnt >= exact, f"seed={seed} r={r}: {cnt} < {exact}"
