"""§IX disk extension: save/load round-trip; mmap'd queries == in-memory."""
import numpy as np

from repro.core import brute_force, promish_e
from repro.core.disk import load_index, save_index
from repro.core.index import build_index
from repro.data.synthetic import random_queries, synthetic_dataset


def test_disk_roundtrip_query_equivalence(tmp_path):
    ds = synthetic_dataset(n=400, d=8, u=20, t=2, seed=3)
    idx = build_index(ds, m=2, n_scales=4, exact=True, seed=1)
    save_index(str(tmp_path / "ix"), ds, idx)
    ds2, idx2 = load_index(str(tmp_path / "ix"), mmap=True)

    assert ds2.n == ds.n and ds2.dim == ds.dim
    np.testing.assert_array_equal(np.asarray(ds2.points), ds.points)
    for query in random_queries(ds, 3, 4, seed=7):
        mem = promish_e.search(ds, idx, query, k=2)
        dsk = promish_e.search(ds2, idx2, query, k=2)
        truth = brute_force.search(ds, query, k=2)
        np.testing.assert_allclose([c.diameter for c in dsk.items],
                                   [c.diameter for c in mem.items], rtol=1e-6)
        np.testing.assert_allclose([c.diameter for c in dsk.items],
                                   [c.diameter for c in truth.items], rtol=1e-4)


def test_disk_is_mmapped(tmp_path):
    ds = synthetic_dataset(n=100, d=4, u=10, t=1, seed=0)
    idx = build_index(ds, m=2, n_scales=3, exact=False, seed=0)
    save_index(str(tmp_path / "ix"), ds, idx)
    ds2, idx2 = load_index(str(tmp_path / "ix"), mmap=True)
    assert isinstance(ds2.points, np.memmap)
    assert isinstance(idx2.structures[0].table.values, np.memmap)
