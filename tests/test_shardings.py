"""Sharding-rule coverage: every param/cache leaf of every arch gets a spec
(KeyError here means a new layer type is missing a rule), and divisibility
nulling behaves."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import DECODE_32K
from repro.launch import shardings as sh
from repro.models.api import model_api, params_specs


@pytest.fixture(scope="module")
def mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_rules_cover_all_leaves(arch, mesh11):
    cfg = get_config(arch)
    specs = sh.param_specs(params_specs(cfg), mesh11)   # KeyError on gaps
    n_leaves = len(jax.tree.leaves(params_specs(cfg)))
    n_specs = len(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)))
    assert n_specs == n_leaves


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_cache_rules_cover_all_leaves(arch, mesh11):
    cfg = get_config(arch)
    api = model_api(cfg)
    cache = jax.eval_shape(lambda: api.init_cache(DECODE_32K.global_batch, 128))
    specs = sh.cache_specs_tree(cfg, DECODE_32K, mesh11, cache)
    assert len(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))) == \
        len(jax.tree.leaves(cache))


def test_divisibility_nulling():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = sh._divisible(("model", None), (36, 64), mesh)   # 36 % 1 == 0
    assert spec == P("model", None)
    # simulate axis size 16 via a fake mesh-shape mapping
    class FakeMesh:
        shape = {"data": 16, "model": 16}
    spec = sh._divisible(("model", "data"), (36, 64), FakeMesh)
    assert spec == P(None, "data")                          # 36 % 16 != 0


def test_batch_specs_long500k_replicates_batch(mesh11):
    from repro.configs.base import LONG_500K
    cfg = get_config("mamba2-2.7b")
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    specs = sh.batch_specs(cfg, LONG_500K, FakeMesh)
    assert specs["tokens"] == P(None)  # B=1 cannot shard over dp=16
