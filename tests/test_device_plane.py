"""Device-plane unit tests that run on the default single CPU device: the
plane's shard_map machinery works on a 1-shard mesh (identical math, no
forced device count), pack_groups truncation accounting, and backend/engine
plane wiring. The real 8-device parity lives in tests/sharded_script.py."""
import numpy as np
import pytest

from repro.core.backend import PallasBackend, get_backend
from repro.core.device_plane import (DevicePlane, PackedGroups, get_plane,
                                     pack_groups)
from repro.data.synthetic import random_queries, synthetic_dataset
from repro.serve.engine import NKSEngine


@pytest.fixture(scope="module")
def plane():
    from repro.launch.mesh import make_local_mesh
    return DevicePlane(make_local_mesh(data=1, model=1))


@pytest.fixture(scope="module")
def ds():
    return synthetic_dataset(n=260, d=6, u=16, t=2, seed=7)


def test_pack_groups_counts_truncation(ds):
    query = random_queries(ds, 2, 1, seed=1)[0]
    full = pack_groups(ds, query)
    assert isinstance(full, PackedGroups) and full.truncated == 0
    groups, mask, ids = full        # legacy 3-tuple unpacking still works
    assert groups.shape[0] == len(query) and groups.shape[1] % 128 == 0
    assert mask.shape == ids.shape == groups.shape[:2]

    tight = pack_groups(ds, query, r_max=4)
    assert tight.truncated == sum(max(s - 4, 0) for s in tight.group_sizes)
    assert tight.truncated > 0
    with pytest.raises(ValueError, match="truncated"):
        pack_groups(ds, query, r_max=4, strict=True)


def test_plane_pack_groups_shard_aligned(ds, plane):
    query = random_queries(ds, 2, 1, seed=2)[0]
    pg = plane.pack_groups(ds, query, r_max=7)
    assert pg.groups.shape[1] % plane.n_shards == 0
    assert pg.truncated == sum(max(s - 7, 0) for s in pg.group_sizes)


def test_shard_pad_and_axis_validation(plane):
    assert plane.n_shards == 1
    assert plane.shard_pad(5) == 5
    from repro.launch.mesh import make_local_mesh
    with pytest.raises(ValueError, match="no 'nope' axis"):
        DevicePlane(make_local_mesh(data=1, model=1), axis="nope")


def test_sharded_join_matches_single_device(plane):
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    s, p, d = 4, 32, 5
    x = rng.standard_normal((s, p, d)).astype(np.float32)
    lengths = np.array([32, 17, 0, 9], np.int32)
    r = np.array([1.5, 2.0, 1.0, 0.0], np.float32)
    m1, c1 = ops.pairwise_l2_join_batched_masked(x, lengths, r)
    mp, cp = plane.join_batched_masked(x, lengths, r)
    np.testing.assert_array_equal(np.asarray(mp), np.asarray(m1))
    np.testing.assert_array_equal(np.asarray(cp), np.asarray(c1))
    with pytest.raises(ValueError, match="S % n_shards"):
        DevicePlane.join_batched_masked(
            _FakeTwoShardPlane(plane), x[:3], lengths[:3], r[:3])


class _FakeTwoShardPlane:
    """Duck-typed plane with n_shards=2 for the divisibility check."""

    def __init__(self, plane):
        self.mesh, self.axis, self._join_fns = plane.mesh, plane.axis, {}

    n_shards = 2


def test_backend_plane_route_parity(ds, plane):
    rng = np.random.default_rng(3)
    id_lists = [np.sort(rng.choice(ds.n, n, replace=False)).astype(np.int64)
                for n in (30, 12, 25)]
    radii = [2.0, float("inf"), 1.5]
    keys = [i.tobytes() for i in id_lists]
    # route="device": the test exercises the plane route; auto cost-model
    # routing would host-route these thin interpret-mode bins.
    single = PallasBackend(interpret=True, route="device")
    routed = PallasBackend(interpret=True, plane=plane, route="device")
    b1 = single.self_join_blocks(ds.points, id_lists, radii, keys=keys)
    b2 = routed.self_join_blocks(ds.points, id_lists, radii, keys=keys)
    for x, y in zip(b1, b2):
        assert x.n == y.n and x.join_count == y.join_count
        if x.mask is None:
            assert y.mask is None
        else:
            np.testing.assert_array_equal(y.mask, x.mask)
    assert routed.stats.sharded_dispatches > 0
    assert routed.stats.shard_dispatches and routed.stats.t_collective_s > 0
    assert routed.stats.shard_total_cells[0] > routed.stats.shard_valid_cells[0]


def test_budget_demotes_sharded_bin_to_single_device(ds):
    """A bin whose minimal shard-rounded block exceeds max_block_bytes drops
    to the single-device route instead of blowing the budget (the clamp runs
    after shard rounding)."""

    class TwoShards:
        n_shards = 2

        @staticmethod
        def shard_pad(n):
            return ((n + 1) // 2) * 2

        def join_batched_masked(self, *a, **kw):   # pragma: no cover
            raise AssertionError("sharded route must have been demoted")

        put_sharded = join_batched_masked

    rng = np.random.default_rng(4)
    id_lists = [np.sort(rng.choice(ds.n, n, replace=False)).astype(np.int64)
                for n in (20, 22, 21)]
    radii = [2.0, 2.0, 2.0]
    be = PallasBackend(interpret=True, plane=TwoShards(),
                       max_block_bytes=4 << 10, route="device")
    ref = PallasBackend(interpret=True, route="device")
    got = be.self_join_blocks(ds.points, id_lists, radii)
    want = ref.self_join_blocks(ds.points, id_lists, radii)
    for x, y in zip(want, got):
        assert x.join_count == y.join_count
        np.testing.assert_array_equal(y.mask, x.mask)
    assert be.stats.sharded_dispatches == 0


def test_get_backend_accepts_plane(plane):
    be = get_backend("pallas", plane=plane)
    assert isinstance(be, PallasBackend) and be.plane is plane
    assert get_backend("pallas").plane is None


def test_get_plane_resolution(plane):
    assert get_plane(plane) is plane
    assert get_plane(plane.mesh).mesh is plane.mesh


def test_engine_mesh_plumbs_plane_and_stats(ds, plane):
    eng = NKSEngine(ds, m=2, n_scales=4, seed=0)
    eng_p = NKSEngine(ds, m=2, n_scales=4, seed=0, mesh=plane)
    assert eng.plane is None and eng_p.plane is plane
    queries = random_queries(ds, 2, 6, seed=5)
    # the string spec resolves to a plane-bound backend on a mesh engine
    assert eng_p._resolve_backend("pallas").plane is plane
    r1 = eng.query_batch(queries, k=2, tier="exact", backend="pallas")
    r2 = eng_p.query_batch(queries, k=2, tier="exact", backend="pallas")
    for a, b in zip(r1, r2):
        assert [(c.ids, c.diameter) for c in a.candidates] == \
               [(c.ids, c.diameter) for c in b.candidates]
    # sharded-dispatch accounting needs the device route pinned: on this
    # host-platform mesh the cost model (rightly) routes every bin to the
    # exact host path, which never touches the plane.
    eng_p.query_batch(queries, k=2, tier="exact",
                      backend=PallasBackend(plane=plane, route="device"))
    st = eng_p.last_batch_stats
    assert st.sharded_dispatches > 0
    assert len(st.shard_dispatches) == 1
    assert st.shard_utilisation and 0.0 < st.shard_utilisation[0] <= 1.0
    assert st.phases["collective_s"] >= 0.0
    assert st.sharding["sharded_dispatches"] == st.sharded_dispatches
    # an explicit backend instance wins over the engine's plane
    own = PallasBackend(interpret=True)
    eng_p.query_batch(queries[:2], k=1, tier="exact", backend=own)
    assert eng_p.last_batch_stats.sharded_dispatches == 0


def test_device_tier_records_plane_stats(ds, plane):
    eng_p = NKSEngine(ds, m=2, n_scales=4, seed=0, build_exact=False,
                      build_approx=False, mesh=plane)
    queries = random_queries(ds, 2, 2, seed=6)
    out = eng_p.query_batch(queries, k=1, tier="device")
    st = eng_p.last_batch_stats
    assert st is not None and st.tier == "device"
    assert st.backend == "device-plane"
    assert st.shard_dispatches == [2]
    assert st.sharded_dispatches == 2
    assert all(r.tier == "device" for r in out)
