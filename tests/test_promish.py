"""End-to-end correctness of ProMiSH-E (exactness), ProMiSH-A (quality), and
the Virtual bR*-Tree baseline, all against the brute-force oracle."""
import numpy as np
import pytest

from repro.core import brute_force, promish_a, promish_e
from repro.core.baseline_tree import VirtualBRTree
from repro.core.index import build_index
from repro.core.promish_e import SearchStats
from repro.data.synthetic import random_queries, synthetic_dataset


def _diams(pq):
    return [c.diameter for c in pq.items]


@pytest.fixture(scope="module")
def ds():
    return synthetic_dataset(n=250, d=6, u=20, t=2, seed=5)


@pytest.fixture(scope="module")
def idx_e(ds):
    return build_index(ds, m=2, n_scales=5, exact=True, seed=0)


@pytest.fixture(scope="module")
def idx_a(ds):
    return build_index(ds, m=2, n_scales=5, exact=False, seed=0)


@pytest.mark.parametrize("qsize,k,seed", [(2, 1, 0), (2, 3, 1), (3, 1, 2),
                                          (3, 5, 3), (4, 2, 4)])
def test_promish_e_exact_vs_oracle(ds, idx_e, qsize, k, seed):
    for query in random_queries(ds, qsize, 4, seed=seed):
        truth = brute_force.search(ds, query, k=k)
        got = promish_e.search(ds, idx_e, query, k=k)
        np.testing.assert_allclose(_diams(got), _diams(truth), rtol=1e-5,
                                   err_msg=f"query={query}")


def test_promish_e_top1_sets_match_oracle(ds, idx_e):
    for query in random_queries(ds, 3, 6, seed=9):
        truth = brute_force.search(ds, query, k=1).items[0]
        got = promish_e.search(ds, idx_e, query, k=1).items[0]
        assert got.diameter == pytest.approx(truth.diameter, rel=1e-5)


def test_promish_e_stats_instrumentation(ds, idx_e):
    stats = SearchStats()
    promish_e.search(ds, idx_e, [0, 1], k=1, stats=stats)
    assert stats.scales_visited >= 1
    assert stats.subsets_searched + stats.duplicate_subsets >= 0


def test_promish_a_quality_clustered():
    """AAR of ProMiSH-A on clustered (real-like) data — the paper's fig. 7
    regime, where AAR < 1.5. Uniform data admits worse ratios (the paper only
    claims the bound on its real datasets)."""
    from repro.data.flickr_like import flickr_like_dataset
    ds = flickr_like_dataset(n=3000, d=16, u=25, t=3, n_clusters=12, seed=2)
    idx = build_index(ds, m=2, n_scales=5, exact=False, seed=0)
    ratios = []
    for query in random_queries(ds, 3, 6, seed=21):
        truth = brute_force.search(ds, query, k=1).items[0]
        got = promish_a.search(ds, idx, query, k=1)
        assert got.full(), "ProMiSH-A must return k results"
        if truth.diameter > 0:
            ratios.append(got.items[0].diameter / truth.diameter)
    assert np.mean(ratios) < 1.6


def test_promish_a_never_better_than_truth(ds, idx_a):
    for query in random_queries(ds, 2, 6, seed=33):
        truth = brute_force.search(ds, query, k=1).items[0]
        got = promish_a.search(ds, idx_a, query, k=1).items[0]
        assert got.diameter >= truth.diameter - 1e-4


def test_virtual_brtree_exact(ds):
    tree = VirtualBRTree(ds, leaf_size=32, fanout=8)
    for query in random_queries(ds, 2, 4, seed=17):
        truth = brute_force.search(ds, query, k=1)
        pq, timed_out, _ = tree.search(query, k=1)
        assert not timed_out
        np.testing.assert_allclose(_diams(pq), _diams(truth), rtol=1e-5)


def test_virtual_brtree_topk(ds):
    tree = VirtualBRTree(ds, leaf_size=32, fanout=8)
    query = random_queries(ds, 2, 1, seed=41)[0]
    truth = brute_force.search(ds, query, k=4)
    pq, timed_out, _ = tree.search(query, k=4)
    assert not timed_out
    np.testing.assert_allclose(_diams(pq), _diams(truth), rtol=1e-5)


def test_single_keyword_query(ds, idx_e):
    pq = promish_e.search(ds, idx_e, [3], k=2)
    assert all(c.diameter == 0.0 and len(c.ids) == 1 for c in pq.items)


def test_query_with_shared_point(ds, idx_e):
    """A point tagged with both query keywords should be the top-1 (diam 0)."""
    # find a point with >= 2 keywords
    for pid in range(ds.n):
        kws = ds.kw.row(pid)
        if len(kws) >= 2:
            query = [int(kws[0]), int(kws[1])]
            break
    truth = brute_force.search(ds, query, k=1).items[0]
    got = promish_e.search(ds, idx_e, query, k=1).items[0]
    assert truth.diameter == 0.0
    assert got.diameter == 0.0 and len(got.ids) == 1


def test_empty_keyword_raises(ds, idx_e):
    with pytest.raises(ValueError):
        promish_e.search(ds, idx_e, [ds.n_keywords + 4], k=1)
