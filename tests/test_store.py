"""Out-of-core store suite: the disk-vs-RAM differential contract.

The tentpole invariant (ISSUE 8): an engine opened over the on-disk columnar
store (``NKSEngine.from_store``, memory-mapped leaves) answers
**bit-identically** to an in-RAM engine built with the same pinned geometry —
across exact/approx tiers, predicate and tenant filters, and streaming
insert/delete/compact interleavings. On top of parity:

  * torn or truncated store leaves surface as ``IOError`` at load, never as
    silently wrong answers (manifest shape check + opt-in checksums);
  * zone-map pruning (``ZoneMapPruner`` consulted at plan time) and the
    dispatcher's radius substitution are pure work-skips — prune-on vs
    prune-off results are bit-identical while the counters prove the prunes
    actually fired;
  * queries over a memory-mapped corpus account their cold-tier gathers
    (``cold_bytes_read``);
  * a randomized hypothesis harness checks the disk engine against the
    brute-force oracle over the eligible sub-corpus.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import brute_force
from repro.core import store as storemod
from repro.core.types import make_dataset
from repro.data.synthetic import (attach_attrs, random_queries,
                                  synthetic_dataset, synthetic_tenants)
from repro.serve.engine import NKSEngine

BUILD = dict(m=2, n_scales=5, seed=0)


def _answers(engine, queries, k=2, **kw):
    """Candidate keys across both tiers — the bit-parity fingerprint."""
    out = []
    for tier in ("exact", "approx"):
        for r in engine.query_batch(queries, k=k, tier=tier, **kw):
            out.append([c.key() for c in r.candidates])
    return out


@pytest.fixture(scope="module")
def corpus():
    return attach_attrs(synthetic_dataset(n=300, d=8, u=12, t=2, seed=7),
                        seed=1)


@pytest.fixture(scope="module")
def store_root(corpus, tmp_path_factory):
    root = tmp_path_factory.mktemp("store") / "tree"
    storemod.build_store(str(root), corpus, **BUILD)
    return str(root)


@pytest.fixture(scope="module")
def ram_engine(corpus):
    return NKSEngine(corpus, synopsis=True, **BUILD)


@pytest.fixture(scope="module")
def disk_engine(store_root):
    return NKSEngine.from_store(store_root, mmap=True)


# ------------------------------------------------------------------ round-trip
def test_store_roundtrip_mmap_layout(corpus, store_root):
    st = storemod.load_store(store_root, mmap=True)
    assert isinstance(st["dataset"].points, np.memmap)
    np.testing.assert_array_equal(np.asarray(st["dataset"].points),
                                  corpus.points)
    np.testing.assert_array_equal(np.asarray(st["dataset"].kw.values),
                                  corpus.kw.values)
    np.testing.assert_array_equal(np.asarray(st["dataset"].attrs["price"]),
                                  corpus.attrs["price"])
    for flavour in ("index_e", "index_a"):
        idx = st[flavour]
        assert idx is not None
        for hi in idx.structures:
            assert hi.synopsis is not None
            assert len(hi.synopsis.radius) == hi.n_buckets
            assert "price" in hi.synopsis.attr_min
    assert st["build_params"]["m"] == BUILD["m"]
    assert st["build_params"]["synopsis"] is True
    # Opt-in integrity audit: every leaf checksums clean after a round-trip.
    storemod.load_store(store_root, mmap=False, verify=True)


def test_store_nbytes_accounts_leaves(store_root, corpus):
    total = storemod.store_nbytes(store_root)
    assert total > corpus.points.nbytes   # points leaf plus CSR/index leaves


# ------------------------------------------------------------------ bit parity
def test_disk_matches_ram_bit_identical(ram_engine, disk_engine, corpus):
    assert isinstance(disk_engine.dataset.points, np.memmap)
    queries = random_queries(corpus, 2, 6, seed=3) + \
        random_queries(corpus, 3, 4, seed=4)
    assert _answers(disk_engine, queries) == _answers(ram_engine, queries)


@pytest.mark.parametrize("sel", (0.9, 0.3, 0.05))
def test_disk_matches_ram_filtered(ram_engine, disk_engine, corpus, sel):
    queries = random_queries(corpus, 2, 5, seed=int(sel * 100))
    flt = {"where": [["price", "<", 100.0 * sel]]}
    assert _answers(disk_engine, queries, filter=flt) == \
        _answers(ram_engine, queries, filter=flt)


def test_disk_matches_ram_tenants(tmp_path):
    ds = synthetic_tenants({"acme": 150, "globex": 120}, d=6, u=10, t=2,
                           seed=5)
    root = str(tmp_path / "tree")
    storemod.build_store(root, ds, **BUILD)
    ram = NKSEngine(ds, synopsis=True, **BUILD)
    disk = NKSEngine.from_store(root, mmap=True)
    queries = [[0, 1], [1, 2], [0, 3]]
    for tenant in ("acme", "globex"):
        flt = {"tenant": tenant}
        assert _answers(disk, queries, filter=flt) == \
            _answers(ram, queries, filter=flt)
        flt = {"tenant": tenant, "where": [["price", "<", 40.0]]}
        assert _answers(disk, queries, filter=flt) == \
            _answers(ram, queries, filter=flt)


# ------------------------------------------------------- streaming + compaction
def test_streaming_compaction_parity(corpus, store_root):
    """Insert/delete/compact interleavings: the from_store engine tracks a
    RAM twin op for op, through delta answers (where zone maps must fall
    through for buckets with delta members) and a full compaction rebuild."""
    ram = NKSEngine(corpus, synopsis=True, auto_compact=False, **BUILD)
    disk = NKSEngine.from_store(store_root, mmap=True, auto_compact=False)
    rng = np.random.default_rng(11)
    queries = random_queries(corpus, 2, 4, seed=6)
    flt = {"where": [["price", "<", 50.0]]}

    for r in range(3):
        pts = rng.standard_normal((20, corpus.dim)).astype(np.float32)
        kws = [sorted(rng.choice(corpus.n_keywords, size=2,
                                 replace=False).tolist()) for _ in range(20)]
        attrs = {"price": rng.uniform(0.0, 100.0, size=20),
                 "category": rng.integers(0, 8, size=20)}
        for eng in (ram, disk):
            eng.insert(pts, kws, attrs=attrs)
        if r:
            dead = np.arange(corpus.n + (r - 1) * 20,
                             corpus.n + (r - 1) * 20 + 5)
            for eng in (ram, disk):
                eng.delete(dead)
        assert _answers(disk, queries) == _answers(ram, queries)
        assert _answers(disk, queries, filter=flt) == \
            _answers(ram, queries, filter=flt)

    for eng in (ram, disk):
        assert eng.compact()
    assert _answers(disk, queries) == _answers(ram, queries)
    # Compaction rebuilds with the pinned build params — synopses included.
    assert disk.index_e.structures[0].synopsis is not None
    assert disk.index_a.structures[0].synopsis is not None


# ------------------------------------------------------------------- corruption
def test_truncated_leaf_raises(corpus, tmp_path):
    root = str(tmp_path / "tree")
    storemod.build_store(root, corpus, **BUILD)
    path = f"{root}/points.npy"
    import os
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(size // 2)
    with pytest.raises(IOError):
        storemod.load_store(root, mmap=True)


def test_tampered_leaf_shape_raises(corpus, tmp_path):
    root = str(tmp_path / "tree")
    storemod.build_store(root, corpus, **BUILD)
    # Swap a leaf for a well-formed but wrong-shape array: the manifest's
    # recorded shape catches it even without checksumming.
    np.save(f"{root}/points.npy", corpus.points[: corpus.n // 2])
    with pytest.raises(IOError, match="truncated or tampered"):
        storemod.load_store(root, mmap=True)


def test_missing_leaf_raises(corpus, tmp_path):
    root = str(tmp_path / "tree")
    storemod.build_store(root, corpus, **BUILD)
    import os
    os.remove(f"{root}/kw.values.npy")
    with pytest.raises(IOError, match="unreadable"):
        storemod.load_store(root, mmap=True)


def test_corrupt_payload_fails_checksum(corpus, tmp_path):
    root = str(tmp_path / "tree")
    storemod.build_store(root, corpus, **BUILD)
    path = f"{root}/points.npy"
    import os
    with open(path, "r+b") as f:
        f.seek(os.path.getsize(path) - 8)
        f.write(b"\xff" * 8)
    with pytest.raises(IOError, match="checksum"):
        storemod.load_store(root, mmap=False, verify=True)


# -------------------------------------------------------------- pruning parity
def _spatial_corpus(n=500, d=4, u=10, seed=5):
    """Uniform low-d corpus with a price column tracking coordinate 0: the
    random projections stay correlated with the attribute, so bucket zone
    maps are tight enough for a threshold clause to prune."""
    ds = synthetic_dataset(n=n, d=d, u=u, t=2, seed=seed)
    price = (ds.points[:, 0] / 100.0).astype(np.float64)
    return dataclasses.replace(ds, attrs={"price": price})


def test_zone_prune_bit_identical_with_counters(tmp_path):
    ds = _spatial_corpus()
    plain = NKSEngine(ds, synopsis=False, **BUILD)
    synop = NKSEngine(ds, synopsis=True, **BUILD)
    root = str(tmp_path / "tree")
    storemod.build_store(root, ds, **BUILD)
    disk = NKSEngine.from_store(root, mmap=True)
    queries = random_queries(ds, 2, 6, seed=2)
    flt = {"where": [["price", "<", 25.0]]}

    base = _answers(plain, queries, filter=flt)
    assert plain.last_batch_stats.buckets_pruned_zonemap == 0
    pruned_total = 0
    for eng in (synop, disk):
        assert _answers(eng, queries, filter=flt) == base
        pruned_total += eng.last_batch_stats.buckets_pruned_zonemap
    # The counters prove the zone maps actually skipped buckets somewhere in
    # the tier sweep (the last_batch_stats here reflect the approx batch).
    assert pruned_total > 0


def test_zone_prune_erosion_under_longlived_delta(tmp_path):
    """Regression pin for the documented zone-map erosion mode: a bucket
    holding *delta* members cannot be zone-rejected (its synopsis describes
    only the bulk generation), so a long-lived delta erodes pruning — the
    counters sag while answers stay bit-identical — and compaction rebuilds
    the synopses, restoring the prunes. Pins the behavior until incremental
    synopses land (ROADMAP)."""
    ds = _spatial_corpus()
    synop = NKSEngine(ds, synopsis=True, auto_compact=False, **BUILD)
    plain = NKSEngine(ds, synopsis=False, auto_compact=False, **BUILD)
    queries = random_queries(ds, 2, 6, seed=2)
    flt = {"where": [["price", "<", 25.0]]}

    def pruned(eng):
        total = 0
        for tier in ("exact", "approx"):
            eng.query_batch(queries, k=2, tier=tier, filter=flt)
            total += eng.last_batch_stats.buckets_pruned_zonemap
        return total

    p_clean = pruned(synop)
    assert p_clean > 0                       # zone maps prune a clean corpus
    assert pruned(plain) == 0

    # Insert copies of points from the *ineligible* region (price >= 25 ⇔
    # coordinate 0 >= 2500): they land in exactly the buckets the zone maps
    # were rejecting, which must now fall through.
    rng = np.random.default_rng(8)
    hot = np.flatnonzero(ds.points[:, 0] >= 2500.0)
    picks = rng.choice(hot, size=60, replace=False)
    pts = ds.points[picks]
    kws = [sorted(int(v) for v in ds.keywords_of(int(i))) for i in picks]
    attrs = {"price": (pts[:, 0] / 100.0).astype(np.float64)}
    for eng in (synop, plain):
        eng.insert(pts, kws, attrs=attrs)

    p_delta = pruned(synop)
    assert p_delta < p_clean                 # erosion: rejected buckets now
    assert synop.delta_points == 60          # hold delta members
    # ... but it is a pure work-skip delta: answers are still bit-identical
    # to the synopsis-off twin that applied the same ops.
    assert _answers(synop, queries, filter=flt) == \
        _answers(plain, queries, filter=flt)

    # Compaction folds the delta into a fresh generation and rebuilds the
    # synopses: pruning recovers, parity holds.
    assert synop.compact() and plain.compact()
    assert synop.delta_points == 0
    p_compacted = pruned(synop)
    assert p_compacted > p_delta
    assert _answers(synop, queries, filter=flt) == \
        _answers(plain, queries, filter=flt)


def _clustered_corpus(n_centers=30, per=8, jitter=2.0, spread=200.0, d=4,
                      u=8, seed=0):
    """Tight clusters far apart: fine-scale buckets isolate a cluster, so
    their synopsis radii bound subset diameters well below a live r_k."""
    rng = np.random.default_rng(seed)
    centers = rng.uniform(0, spread, (n_centers, d)).astype(np.float32)
    pts, kws = [], []
    for c in centers:
        for j in range(per):
            pts.append(c + rng.standard_normal(d).astype(np.float32) * jitter)
            kws.append(sorted({j % 2,
                               int(rng.integers(2, u))}))
    return make_dataset(np.asarray(pts, np.float32), kws, n_keywords=u)


def test_radius_substitution_bit_identical_with_counters():
    """diam_ub <= r_k => the dispatcher substitutes an infinite pruning
    radius (skipping the device mask); results must not move."""
    ds = _clustered_corpus()
    build = dict(m=2, n_scales=8, seed=0, w0=0.5)
    plain = NKSEngine(ds, synopsis=False, **build)
    synop = NKSEngine(ds, synopsis=True, **build)
    queries = [[0, 1]] * 4

    base = _answers(plain, queries, k=2)
    assert plain.last_batch_stats.buckets_pruned_radius == 0
    assert _answers(synop, queries, k=2) == base
    # The counter lives on the multi-scale exact batch (the approx tier
    # terminates at scale 0, where every radius is still infinite).
    synop.query_batch(queries, k=2, tier="exact")
    assert synop.last_batch_stats.buckets_pruned_radius > 0


# ------------------------------------------------------------------- cold tier
def test_cold_tier_reads_accounted(disk_engine, corpus):
    queries = random_queries(corpus, 2, 4, seed=9)
    disk_engine.query_batch(queries, k=2, tier="exact", backend="numpy")
    st = disk_engine.last_batch_stats
    assert st.cold_bytes_read > 0
    assert st.tiering["cold_bytes_read"] == st.cold_bytes_read


def test_resident_budget_reaches_backend(store_root, corpus):
    budget = max(1, corpus.points.nbytes // 4)
    eng = NKSEngine.from_store(store_root, mmap=True,
                               resident_budget_bytes=budget)
    assert eng.resident_budget_bytes == budget
    queries = random_queries(corpus, 2, 3, seed=13)
    # The pallas backend's tile LRU is capped at the budget: the corpus is
    # 4x the hot tier, so serving must go through the mmap cold path. k=3
    # keeps some pruning radii finite past scale 0 — the inf-radius fast
    # path never touches point rows, so a k=1 batch would read nothing.
    eng.query_batch(queries, k=3, tier="exact", backend="pallas")
    assert eng.last_batch_stats.cold_bytes_read > 0


# ------------------------------------------------------------------ hypothesis
# Only the randomized differential needs hypothesis: guard it alone so the
# rest of the store contract still runs where the package is absent.
try:
    from hypothesis import given, settings, strategies as hs
except ImportError:
    given = None


def _oracle_differential(disk_engine, corpus, q, k, cut):
    flt = {"where": [["price", "<", cut]]}
    res = disk_engine.query_batch([q], k=k, tier="exact", filter=flt)[0]
    truth = brute_force.search_filtered(corpus, q, flt, k=k)
    got = [c.diameter for c in res.candidates]
    want = [c.diameter for c in truth.items]
    assert len(got) == len(want)
    np.testing.assert_allclose(got, want, rtol=1e-5)
    assert [len(c.ids) for c in res.candidates] == \
        [len(c.ids) for c in truth.items]


if given is not None:
    @settings(max_examples=15, deadline=None)
    @given(data=hs.data())
    def test_disk_engine_matches_oracle_randomized(disk_engine, corpus, data):
        """Randomized differential: the mmap-backed engine vs the brute-force
        oracle over the eligible sub-corpus, at drawn query/k/selectivity."""
        q = data.draw(hs.lists(hs.integers(0, corpus.n_keywords - 1),
                               min_size=1, max_size=3, unique=True),
                      label="query")
        k = data.draw(hs.integers(1, 3), label="k")
        cut = data.draw(hs.floats(5.0, 100.0), label="price_cut")
        _oracle_differential(disk_engine, corpus, q, k, cut)
else:
    @pytest.mark.parametrize("seed", range(8))
    def test_disk_engine_matches_oracle_randomized(disk_engine, corpus, seed):
        """Seeded stand-in for the hypothesis harness (package absent):
        same differential, fixed draws."""
        rng = np.random.default_rng(seed)
        q = sorted(rng.choice(corpus.n_keywords,
                              size=int(rng.integers(1, 4)),
                              replace=False).tolist())
        _oracle_differential(disk_engine, corpus, q,
                             int(rng.integers(1, 4)),
                             float(rng.uniform(5.0, 100.0)))
