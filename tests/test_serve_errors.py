"""Serve-loop error paths: a bad request yields a structured error envelope
and the stream survives — in both the synchronous loop
(``handle_request_safe``) and the async runtime path
(``serve_with_runtime``). Covers the satellite checklist: malformed line,
unknown op, insert with mismatched attrs schema, tenant-unknown keyword."""
import numpy as np
import pytest

from repro.data.synthetic import attach_attrs, synthetic_tenants
from repro.launch.serve import (handle_request_safe, serve_with_runtime)
from repro.serve.engine import NKSEngine
from repro.serve.runtime import RuntimeConfig, ServingRuntime


@pytest.fixture(scope="module")
def engine():
    ds = attach_attrs(synthetic_tenants({"acme": 120, "globex": 80},
                                        d=4, u=16, t=2, seed=2), seed=2)
    return NKSEngine(ds, seed=1, compact_min=10_000)


BAD_REQUESTS = [
    # (request, expected op in envelope, error fragment)
    ({"__parse_error__": "malformed JSON: boom"}, "parse", "malformed"),
    ("not a dict", "parse", "JSON object"),
    ({"op": "frobnicate"}, "frobnicate", "unknown op"),
    ({"op": "query"}, "query", "keywords"),                  # missing field
    ({"keywords": [99999]}, "query", ""),                    # out-of-dict kw
    # attrs schema mismatch: corpus has price+category, insert omits one
    ({"op": "insert", "points": [[0.0] * 4], "keywords": [[0]],
      "attrs": {"price": [1.0]}, "tenant": "acme"}, "insert", ""),
    # tenant-unknown keyword: local id beyond the tenant's namespace
    ({"op": "insert", "points": [[0.0] * 4], "keywords": [[4000]],
      "tenant": "acme"}, "insert", ""),
    # unknown tenant name
    ({"op": "insert", "points": [[0.0] * 4], "keywords": [[0]],
      "tenant": "hooli"}, "insert", ""),
    # snapshot without a WAL attached
    ({"op": "snapshot"}, "snapshot", "WAL"),
]

GOOD = {"keywords": [0, 1], "k": 1, "filter": {"tenant": "acme"}}


def _check_envelope(out, op, frag):
    assert out.get("status", "ok") == "error" or "error" in out
    assert out["op"] == op
    assert frag.lower() in out["error"].lower()


def test_sync_loop_survives_every_bad_request(engine):
    for req, op, frag in BAD_REQUESTS:
        out = handle_request_safe(engine, req, tier="exact", k=1)
        _check_envelope(out, op, frag)
        # the stream is alive: a good request right after still answers
        ok = handle_request_safe(engine, GOOD, tier="exact", k=1)
        assert "error" not in ok and ok["results"]


def test_runtime_loop_survives_every_bad_request(engine):
    reqs = []
    for req, _, _ in BAD_REQUESTS:
        reqs.append(req)
        reqs.append(GOOD)
    rt = ServingRuntime(engine, RuntimeConfig(batch_window_s=0.0))
    try:
        outs = list(serve_with_runtime(rt, engine, reqs, tier="exact", k=1))
    finally:
        rt.close()
    assert len(outs) == len(reqs)
    for i, (_, op, frag) in enumerate(BAD_REQUESTS):
        _check_envelope(outs[2 * i], op, frag)
        assert "error" not in outs[2 * i + 1] and outs[2 * i + 1]["results"]
    # no bad request crashed the runtime itself
    assert not rt.health()["crashed"]


def test_sync_and_runtime_answers_agree(engine):
    """The two serving paths format identical results for the same stream
    (modulo latency), including tenant-resolved inserts."""
    rng = np.random.default_rng(8)
    stream = [
        {"keywords": [0, 1], "k": 2, "filter": {"tenant": "acme"}},
        {"op": "insert",
         "points": rng.standard_normal((3, 4)).astype(np.float32).tolist(),
         "keywords": [[0, 1]] * 3,
         "attrs": {"price": [1.0, 2.0, 3.0], "category": [0, 1, 0]},
         "tenant": "acme"},
        {"keywords": [0, 1], "k": 3, "filter": {"tenant": "acme"}},
        {"op": "delete", "ids": [0]},
        {"keywords": [0, 1], "k": 3, "filter": {"tenant": "acme"}},
        {"op": "health"},
    ]

    def strip(out):
        out = {k: v for k, v in out.items() if k != "latency_ms"}
        return out

    ds = engine.dataset
    sync_eng = NKSEngine(ds, seed=1, compact_min=10_000)
    sync = [strip(handle_request_safe(sync_eng, r, tier="exact", k=1))
            for r in stream]
    rt_eng = NKSEngine(ds, seed=1, compact_min=10_000)
    rt = ServingRuntime(rt_eng, RuntimeConfig(batch_window_s=0.0))
    try:
        asynchronous = [strip(o) for o in
                        serve_with_runtime(rt, rt_eng, stream,
                                           tier="exact", k=1)]
    finally:
        rt.close()
    # health payloads legitimately differ (queue stats); compare the rest.
    for s, a in zip(sync[:-1], asynchronous[:-1]):
        assert s == a
    assert asynchronous[-1]["op"] == "health"
    assert asynchronous[-1]["generation"] == sync[-1]["generation"]
