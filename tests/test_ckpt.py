"""ckpt/checkpoint.py coverage: atomic save/restore round-trip, checksum
verification, shape guard, find_latest, and the rolling CheckpointManager."""
import json
import os

import numpy as np
import pytest

from repro.ckpt.checkpoint import (CheckpointManager, find_latest,
                                   load_checkpoint, save_checkpoint)


def _state(seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": rng.standard_normal((4, 3)).astype(np.float32) * scale,
                   "b": rng.standard_normal(3).astype(np.float32)},
        "opt": {"mu": np.zeros((4, 3), np.float32),
                "count": np.asarray(7, np.int32)},
    }


def _assert_tree_equal(got, want):
    assert set(got) == set(want)
    for k in want:
        if isinstance(want[k], dict):
            _assert_tree_equal(got[k], want[k])
        else:
            np.testing.assert_array_equal(np.asarray(got[k]),
                                          np.asarray(want[k]))


def test_roundtrip_and_extra(tmp_path):
    state = _state()
    path = save_checkpoint(str(tmp_path), 40, state, extra={"loss": 0.5})
    got, step, extra = load_checkpoint(path, _state(seed=9))
    assert step == 40 and extra == {"loss": 0.5}
    _assert_tree_equal(got, state)


def test_checksum_verification(tmp_path):
    state = _state()
    path = save_checkpoint(str(tmp_path), 1, state)
    man_path = os.path.join(path, "manifest.json")
    man = json.load(open(man_path))
    next(iter(man["leaves"].values()))["sha256"] = "0" * 64
    json.dump(man, open(man_path, "w"))
    with pytest.raises(IOError, match="checksum"):
        load_checkpoint(path, _state())
    # verify=False bypasses (e.g. trusted local restore)
    load_checkpoint(path, _state(), verify=False)


def test_shape_mismatch_rejected(tmp_path):
    path = save_checkpoint(str(tmp_path), 1, _state())
    wrong = _state()
    wrong["params"]["w"] = np.zeros((5, 3), np.float32)
    with pytest.raises(ValueError, match="shape"):
        load_checkpoint(path, wrong)


def test_save_is_atomic_and_replaces(tmp_path):
    path1 = save_checkpoint(str(tmp_path), 3, _state(scale=1.0))
    path2 = save_checkpoint(str(tmp_path), 3, _state(scale=2.0))
    assert path1 == path2
    got, _, _ = load_checkpoint(path2, _state())
    _assert_tree_equal(got, _state(scale=2.0))
    assert not [d for d in os.listdir(tmp_path) if d.startswith(".tmp-")]


def test_find_latest(tmp_path):
    assert find_latest(str(tmp_path / "missing")) is None
    for step in (5, 20, 10):
        save_checkpoint(str(tmp_path), step, _state())
    assert find_latest(str(tmp_path)).endswith("step_00000020")


def test_manager_cadence_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, every=10)
    assert mgr.maybe_save(0, _state()) is None          # step 0 skipped
    assert mgr.maybe_save(7, _state()) is None          # off-cadence
    assert mgr.maybe_save(7, _state(), force=True)      # forced saves land
    for step in (10, 20, 30):
        assert mgr.maybe_save(step, _state())
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert kept == ["step_00000020", "step_00000030"]   # keep=2 rolled
    assert mgr.latest().endswith("step_00000030")
