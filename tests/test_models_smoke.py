"""Per-architecture smoke tests: REDUCED same-family configs, one forward /
train-loss / prefill+decode step on CPU, asserting shapes + finiteness.
(The FULL configs are exercised only via the dry-run.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.api import input_specs, model_api
from repro.configs.base import TRAIN_4K

jax.config.update("jax_platforms", "cpu")


def _smoke_batch(cfg, bsz=2, seq=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (bsz, seq)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (bsz, seq)), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((bsz, cfg.vision_tokens, cfg.vision_dim)), jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((bsz, cfg.audio_frames, cfg.d_model)), jnp.bfloat16)
    return batch


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    cfg = get_config(request.param).smoke()
    api = model_api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


def test_train_loss_finite(arch_setup):
    cfg, api, params = arch_setup
    batch = _smoke_batch(cfg)
    loss, metrics = jax.jit(lambda p, b: api.loss(p, b))(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{cfg.name}: loss={loss}"
    assert jnp.isfinite(metrics["xent"])


def test_grads_finite(arch_setup):
    cfg, api, params = arch_setup
    batch = _smoke_batch(cfg)
    grads = jax.jit(jax.grad(lambda p, b: api.loss(p, b)[0]))(params, batch)
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat), f"{cfg.name}: nan grads"
    # at least one nonzero gradient leaf
    assert any(float(jnp.abs(g).max()) > 0 for g in flat)


def test_prefill_then_decode(arch_setup):
    cfg, api, params = arch_setup
    bsz, seq, max_seq = 2, 8, 12
    batch = _smoke_batch(cfg, bsz=bsz, seq=seq)
    logits, cache = jax.jit(
        lambda p, b: api.prefill(p, b, max_seq=max_seq))(params, batch)
    assert logits.shape == (bsz, cfg.vocab_size)
    assert jnp.isfinite(logits.astype(jnp.float32)).all()
    assert int(cache["pos"]) == seq
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache2 = jax.jit(api.decode)(params, cache, tok)
    assert logits2.shape == (bsz, cfg.vocab_size)
    assert jnp.isfinite(logits2.astype(jnp.float32)).all()
    assert int(cache2["pos"]) == seq + 1


def test_decode_matches_full_forward(arch_setup):
    """Teacher-forced decode must reproduce the full forward logits (the
    KV-cache/state correctness invariant)."""
    cfg, api, params = arch_setup
    bsz, seq = 1, 6
    batch = _smoke_batch(cfg, bsz=bsz, seq=seq, seed=3)
    mod_loss, _ = api.loss(params, batch, remat=False)
    # full forward logits
    from repro.models import audio as audio_lib
    from repro.models import transformer as tf_lib
    mod = audio_lib if cfg.family == "audio" else tf_lib
    extra = None
    if cfg.family == "vlm":
        extra = {"patches": batch["patches"]}
    if cfg.family == "audio":
        extra = {"frames": batch["frames"]}
    full_logits, _ = mod.forward_train(params, cfg, batch["tokens"],
                                       extra=extra, remat=False)
    # prefill on the first token only, then decode the rest one by one
    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, :1]
    logits, cache = api.prefill(params, pre_batch, max_seq=seq)
    got = [logits]
    for i in range(1, seq):
        logits, cache = api.decode(params, cache, batch["tokens"][:, i:i + 1])
        got.append(logits)
    got = jnp.stack(got, axis=1).astype(jnp.float32)       # (B, S, V)
    want = full_logits.astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-2, atol=5e-2,
                               err_msg=f"{cfg.name} decode != forward")


def test_embed_interface(arch_setup):
    """ProMiSH integration point: pooled embeddings are finite (B, D)."""
    cfg, api, params = arch_setup
    batch = _smoke_batch(cfg)
    emb = api.embed(params, batch)
    assert emb.shape == (2, cfg.d_model)
    assert jnp.isfinite(emb.astype(jnp.float32)).all()


def test_input_specs_complete(arch_setup):
    cfg, api, params = arch_setup
    specs = input_specs(get_config(cfg.name.replace("-smoke", "")), TRAIN_4K)
    assert specs["tokens"].shape == (256, 4096)
    assert "targets" in specs
