"""Durability suite: WAL framing, torn tails, kill-after-append, snapshot
rolling, and the recovery-parity invariant.

The contract under test (README "Serving runtime"): every insert/delete/
compact is fsync'd to the WAL *before* it is acknowledged, so

  * no acknowledged write is ever lost — ``NKSEngine.recover(root)`` replays
    the latest snapshot + log suffix to a state whose answers are
    **bit-identical** to an uninterrupted engine that executed the same
    acknowledged op sequence;
  * a crash between the fsync and the ack may re-apply the unacked tail op
    on recovery (at-least-once below the ack horizon, exactly-once above);
  * a torn tail (crash mid-append) truncates cleanly — mid-file corruption
    of acknowledged records is a hard ``TornRecordError``, never a silent
    skip.
"""
import numpy as np
import pytest

from repro.data.synthetic import random_queries, synthetic_dataset
from repro.serve import wal as walmod
from repro.serve.engine import NKSEngine
from repro.serve.faults import FaultPlan, InjectedCrash


def _corpus(n=260, d=6, u=24, seed=3):
    return synthetic_dataset(n=n, d=d, u=u, t=2, seed=seed)


def _stream(rng, n_batches, batch, d, u):
    out = []
    for _ in range(n_batches):
        pts = rng.standard_normal((batch, d)).astype(np.float32)
        kws = [sorted(rng.choice(u, size=2, replace=False).tolist())
               for _ in range(batch)]
        out.append((pts, kws))
    return out


def _answers(engine, queries, k=2):
    out = []
    for tier in ("exact", "approx"):
        for r in engine.query_batch(queries, k=k, tier=tier):
            out.append([c.key() for c in r.candidates])
    return out


# ------------------------------------------------------------------- framing
def test_wal_roundtrip_and_stats(tmp_path):
    path = str(tmp_path / "w.log")
    log = walmod.WriteAheadLog(path)
    recs = [{"op": "insert", "i": i, "blob": "x" * i} for i in range(7)]
    for r in recs:
        log.append(r)
    log.close()
    stats = walmod.WalStats()
    assert list(walmod.WriteAheadLog.replay(path, stats)) == recs
    assert stats.replayed == 7 and not stats.torn_tail
    assert log.stats.appends == 7


def test_wal_torn_tail_stops_cleanly(tmp_path):
    path = str(tmp_path / "w.log")
    log = walmod.WriteAheadLog(path)
    for i in range(3):
        log.append({"i": i})
    log.close()
    blob = open(path, "rb").read()
    open(path, "wb").write(blob[:-5])        # crash mid-append of record 2
    stats = walmod.WalStats()
    assert [r["i"] for r in walmod.WriteAheadLog.replay(path, stats)] == [0, 1]
    assert stats.torn_tail


def test_wal_midfile_corruption_raises(tmp_path):
    path = str(tmp_path / "w.log")
    log = walmod.WriteAheadLog(path)
    for i in range(3):
        log.append({"i": i, "pad": "p" * 50})
    log.close()
    blob = bytearray(open(path, "rb").read())
    blob[12] ^= 0xFF                          # inside record 0's payload
    open(path, "wb").write(bytes(blob))
    with pytest.raises(walmod.TornRecordError):
        list(walmod.WriteAheadLog.replay(path))


# ------------------------------------------------------------------ recovery
def test_recovery_parity_bit_identical(tmp_path):
    """Crash after a mixed acked op sequence (inserts, deletes, logged
    auto-compactions): the recovered engine answers bit-identically to an
    uninterrupted reference on both tiers, and keeps doing so as the
    stream continues after recovery."""
    ds = _corpus()
    rng = np.random.default_rng(11)
    stream = _stream(rng, 6, 30, ds.dim, ds.n_keywords)
    queries = random_queries(ds, 2, 6, seed=13)

    wal_eng = NKSEngine(ds, seed=5, compact_min=70, compact_ratio=0.05)
    wal_eng.attach_wal(str(tmp_path / "wal"))
    ref_eng = NKSEngine(ds, seed=5, compact_min=70, compact_ratio=0.05)
    acked = []
    for i, (pts, kws) in enumerate(stream):
        ids = wal_eng.insert(pts, kws)
        acked.append(("insert", pts, kws, ids))
        if i % 2:
            dead = [int(ids[0]), int(ids[-1])]
            wal_eng.delete(dead)
            acked.append(("delete", dead))
    assert wal_eng.ingest.compactions >= 1      # cadence actually exercised
    assert wal_eng.wal_stats.appends == wal_eng.ingest.wal_appends
    wal_eng.close()                             # simulated process death

    rec = NKSEngine.recover(str(tmp_path / "wal"))
    for op in acked:                            # reference applies acked ops
        if op[0] == "insert":
            ref_ids = ref_eng.insert(op[1], op[2])
            np.testing.assert_array_equal(ref_ids, op[3])
        else:
            ref_eng.delete(op[1])
    assert rec.ingest.replayed_ops == len(acked) + rec.ingest.compactions
    assert rec.corpus_generation == ref_eng.corpus_generation
    assert _answers(rec, queries) == _answers(ref_eng, queries)

    # The stream continues: recovered engine keeps id-sequence + parity.
    pts, kws = _stream(rng, 1, 25, ds.dim, ds.n_keywords)[0]
    np.testing.assert_array_equal(rec.insert(pts, kws),
                                  ref_eng.insert(pts, kws))
    assert _answers(rec, queries) == _answers(ref_eng, queries)
    rec.close()


def test_kill_between_append_and_ack(tmp_path):
    """The wal_ack crash window: the op is durable but never acknowledged.
    Recovery applies it (at-least-once below the ack horizon) and every
    *acknowledged* op survives — none lost."""
    ds = _corpus(n=150)
    rng = np.random.default_rng(7)
    stream = _stream(rng, 4, 10, ds.dim, ds.n_keywords)
    queries = random_queries(ds, 2, 5, seed=1)

    faults = FaultPlan(crash={"wal_ack": 3})
    eng = NKSEngine(ds, seed=2, compact_min=10_000)
    eng.attach_wal(str(tmp_path / "wal"), faults=faults)
    eng.insert(*stream[0])
    eng.insert(*stream[1])
    with pytest.raises(InjectedCrash):
        eng.insert(*stream[2])                 # durable, never acked
    assert faults.fired["wal_ack"] == 1

    rec = NKSEngine.recover(str(tmp_path / "wal"))
    ref = NKSEngine(ds, seed=2, compact_min=10_000)
    for pts, kws in stream[:3]:                # acked + the durable tail op
        ref.insert(pts, kws)
    assert rec.ingest.replayed_ops == 3
    assert _answers(rec, queries) == _answers(ref, queries)
    # No acknowledged write lost: both acked batches are fully live.
    n_acked = len(stream[0][0]) + len(stream[1][0])
    assert rec._next_ext >= ds.n + n_acked
    rec.close()


# -------------------------------------------------------------- group commit
def test_group_commit_one_fsync_per_group(tmp_path):
    """``ingest_group()`` coalesces a run of durable ops behind a single
    fsync barrier: one group of N inserts costs one fsync, the stats expose
    the amortization, and recovery replays the whole group."""
    ds = _corpus(n=150)
    rng = np.random.default_rng(9)
    stream = _stream(rng, 5, 8, ds.dim, ds.n_keywords)
    queries = random_queries(ds, 2, 5, seed=2)

    eng = NKSEngine(ds, seed=2, compact_min=10_000)
    eng.attach_wal(str(tmp_path / "wal"))
    f0 = eng.wal_stats.fsyncs
    with eng.ingest_group():
        for pts, kws in stream:
            eng.insert(pts, kws)
    st = eng.wal_stats
    assert st.fsyncs - f0 == 1                 # the group barrier, nothing else
    assert st.group_commits == 1
    assert st.group_committed == len(stream)
    assert st.group_commit_batch == float(len(stream))
    # Nested groups share the outermost barrier.
    tail = _stream(rng, 1, 4, ds.dim, ds.n_keywords)[0]
    with eng.ingest_group():
        with eng.ingest_group():
            eng.insert(*tail)
        assert eng.wal_stats.group_commits == 1    # inner exit: no barrier yet
    assert eng.wal_stats.group_commits == 2
    eng.close()

    rec = NKSEngine.recover(str(tmp_path / "wal"))
    ref = NKSEngine(ds, seed=2, compact_min=10_000)
    for pts, kws in stream + [tail]:
        ref.insert(pts, kws)
    assert rec.ingest.replayed_ops == len(stream) + 1
    assert _answers(rec, queries) == _answers(ref, queries)
    rec.close()


def test_group_commit_crash_at_barrier(tmp_path):
    """A crash at the group's fsync barrier: every record in the group is
    durable but none was acknowledged — recovery replays them all
    (at-least-once below the ack horizon, same contract as per-op sync)."""
    ds = _corpus(n=150)
    rng = np.random.default_rng(13)
    stream = _stream(rng, 3, 6, ds.dim, ds.n_keywords)
    queries = random_queries(ds, 2, 5, seed=3)

    faults = FaultPlan(crash={"wal_ack": 1})
    eng = NKSEngine(ds, seed=2, compact_min=10_000)
    eng.attach_wal(str(tmp_path / "wal"), faults=faults)
    with pytest.raises(InjectedCrash):
        with eng.ingest_group():
            for pts, kws in stream:            # deferred: no wal_ack window yet
                eng.insert(pts, kws)
    assert faults.fired["wal_ack"] == 1
    assert eng.wal_stats.fsyncs == 1           # the barrier ran before the kill

    rec = NKSEngine.recover(str(tmp_path / "wal"))
    ref = NKSEngine(ds, seed=2, compact_min=10_000)
    for pts, kws in stream:
        ref.insert(pts, kws)
    assert rec.ingest.replayed_ops == len(stream)
    assert _answers(rec, queries) == _answers(ref, queries)
    rec.close()


def test_recover_append_recover_after_torn_tail(tmp_path):
    """Crash mid-append, recover, keep writing, crash again: the first
    recovery must truncate the torn tail before reopening the segment for
    append — otherwise the post-recovery acknowledged writes land after the
    torn bytes and the *second* recovery dies on a mid-file CRC mismatch,
    losing them."""
    import os
    ds = _corpus(n=150)
    rng = np.random.default_rng(17)
    stream = _stream(rng, 3, 8, ds.dim, ds.n_keywords)
    queries = random_queries(ds, 2, 5, seed=4)
    root = str(tmp_path / "wal")

    eng = NKSEngine(ds, seed=6, compact_min=10_000)
    eng.attach_wal(root)
    eng.insert(*stream[0])                     # acked
    eng.insert(*stream[1])                     # crash tears this one below
    eng.close()
    path = walmod.wal_path(root, 0)
    blob = open(path, "rb").read()
    open(path, "wb").write(blob[:-5])          # crash mid-append of op 2

    rec1 = NKSEngine.recover(root)
    assert rec1.ingest.replayed_ops == 1       # torn op never acked, skipped
    assert rec1.wal_stats.torn_tail
    tail = os.path.getsize(path)               # truncated to last whole rec
    rec1.insert(*stream[2])                    # acked post-recovery
    assert os.path.getsize(path) > tail        # appended after clean tail
    rec1.close()

    rec2 = NKSEngine.recover(root)             # must NOT TornRecordError
    assert rec2.ingest.replayed_ops == 2
    ref = NKSEngine(ds, seed=6, compact_min=10_000)
    ref.insert(*stream[0])
    ref.insert(*stream[2])
    assert _answers(rec2, queries) == _answers(ref, queries)
    rec2.close()


def test_snapshot_rolls_log_and_gcs(tmp_path):
    ds = _corpus(n=120)
    rng = np.random.default_rng(3)
    stream = _stream(rng, 5, 12, ds.dim, ds.n_keywords)
    queries = random_queries(ds, 2, 5, seed=2)
    root = str(tmp_path / "wal")

    eng = NKSEngine(ds, seed=9, compact_min=10_000)
    ref = NKSEngine(ds, seed=9, compact_min=10_000)
    eng.attach_wal(root)
    for pts, kws in stream[:3]:
        eng.insert(pts, kws)
        ref.insert(pts, kws)
    snap = eng.snapshot()
    assert eng.ingest.snapshots == 1
    for pts, kws in stream[3:]:
        eng.insert(pts, kws)
        ref.insert(pts, kws)
    eng.close()

    assert walmod.read_manifest(root)["epoch"] == 1
    import os
    assert not os.path.exists(walmod.snap_dir(root, 0))    # GC'd
    assert not os.path.exists(walmod.wal_path(root, 0))
    assert os.path.isdir(snap)

    rec = NKSEngine.recover(root)
    # The ack horizon moved: only the post-snapshot suffix replays.
    assert rec.ingest.replayed_ops == 2
    assert _answers(rec, queries) == _answers(ref, queries)
    rec.close()


def test_recovery_preserves_attrs_and_tenants(tmp_path):
    from repro.data.synthetic import attach_attrs, synthetic_tenants
    ds = attach_attrs(synthetic_tenants({"a": 70, "b": 50}, d=5, u=15, t=2,
                                        seed=6), seed=6)
    rng = np.random.default_rng(5)
    pts = rng.standard_normal((8, ds.dim)).astype(np.float32)
    kws = [ds.tenants.resolve("a", [0, 1]) for _ in range(8)]
    attrs = {"price": rng.uniform(0, 100, 8),
             "category": rng.integers(0, 5, 8)}
    flt = {"tenant": "a", "where": [["price", "<", 200]]}

    eng = NKSEngine(ds, seed=4, compact_min=10_000)
    eng.attach_wal(str(tmp_path / "wal"))
    eng.insert(pts, kws, attrs=attrs, tenant="a")
    ref = NKSEngine(ds, seed=4, compact_min=10_000)
    ref.insert(pts, kws, attrs=attrs, tenant="a")
    eng.close()

    rec = NKSEngine.recover(str(tmp_path / "wal"))
    got = rec.query([0, 1], k=3, tier="exact", filter=flt)
    want = ref.query([0, 1], k=3, tier="exact", filter=flt)
    assert [c.key() for c in got.candidates] == \
        [c.key() for c in want.candidates]
    rec.close()


def test_group_commit_crash_at_barrier_attrs_tenants(tmp_path):
    """The crash-at-barrier window with *attributed, tenanted* batches: the
    whole group is durable-but-unacknowledged, and recovery must replay not
    just the points but the attribute columns and tenant ids bit-identically
    — filtered tenant-scoped answers and the raw recovered columns both
    match a reference engine that applied the same ops."""
    from repro.data.synthetic import attach_attrs, synthetic_tenants
    ds = attach_attrs(synthetic_tenants({"a": 70, "b": 50}, d=5, u=15, t=2,
                                        seed=6), seed=6)
    rng = np.random.default_rng(21)
    batches = []
    for tenant in ("a", "b", "a"):
        pts = rng.standard_normal((6, ds.dim)).astype(np.float32)
        kws = [ds.tenants.resolve(tenant, sorted(rng.choice(15, 2,
                                                            replace=False)))
               for _ in range(6)]
        attrs = {"price": rng.uniform(0, 100, 6),
                 "category": rng.integers(0, 5, 6)}
        batches.append((tenant, pts, kws, attrs))

    faults = FaultPlan(crash={"wal_ack": 1})
    eng = NKSEngine(ds, seed=4, compact_min=10_000)
    eng.attach_wal(str(tmp_path / "wal"), faults=faults)
    with pytest.raises(InjectedCrash):
        with eng.ingest_group():
            for tenant, pts, kws, attrs in batches:
                eng.insert(pts, kws, attrs=attrs, tenant=tenant)
    assert faults.fired["wal_ack"] == 1
    assert eng.wal_stats.fsyncs == 1           # one barrier for the group

    rec = NKSEngine.recover(str(tmp_path / "wal"))
    ref = NKSEngine(ds, seed=4, compact_min=10_000)
    for tenant, pts, kws, attrs in batches:
        ref.insert(pts, kws, attrs=attrs, tenant=tenant)
    assert rec.ingest.replayed_ops == len(batches)

    # raw recovered state is bit-identical: points, columns, tenant ids
    np.testing.assert_array_equal(rec.dataset.points, ref.dataset.points)
    for col in ("price", "category"):
        np.testing.assert_array_equal(rec.dataset.attr_column(col),
                                      ref.dataset.attr_column(col))
    np.testing.assert_array_equal(rec.dataset.tenant_ids,
                                  ref.dataset.tenant_ids)

    # ... and so are tenant-scoped filtered answers over the replayed delta
    for flt in ({"tenant": "a", "where": [["price", "<", 60.0]]},
                {"tenant": "b"},
                {"tenant": "a", "where": [["category", "in", [0, 1, 2]]]}):
        got = rec.query([0, 1], k=3, tier="exact", filter=flt)
        want = ref.query([0, 1], k=3, tier="exact", filter=flt)
        assert [c.key() for c in got.candidates] == \
            [c.key() for c in want.candidates]
    rec.close()


def test_attach_wal_requires_clean_start(tmp_path):
    ds = _corpus(n=100)
    eng = NKSEngine(ds, seed=1)
    eng.attach_wal(str(tmp_path / "wal"))
    with pytest.raises(RuntimeError):
        eng.attach_wal(str(tmp_path / "other"))
    eng.close()
