"""Shared fixtures. NOTE: XLA_FLAGS device-count forcing is deliberately NOT
set here — smoke tests and benchmarks must see the real single CPU device;
only launch/dryrun.py forces 512 placeholder devices (in its own process)."""
import pytest

from repro.data.synthetic import synthetic_dataset
from repro.data.flickr_like import flickr_like_dataset


@pytest.fixture(scope="session")
def small_synth():
    """Small uniform dataset: exhaustive oracle is feasible."""
    return synthetic_dataset(n=300, d=8, u=12, t=2, seed=7)


@pytest.fixture(scope="session")
def small_flickr():
    return flickr_like_dataset(n=400, d=16, u=40, t=4, seed=3)


@pytest.fixture(scope="session")
def med_synth():
    return synthetic_dataset(n=5_000, d=16, u=60, t=2, seed=11)
