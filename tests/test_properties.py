"""Property-based tests (hypothesis) for the system's core invariants,
including the randomized differential oracle harness for filtered /
multi-tenant NKS: random corpora x random predicate/tenant filters x random
streaming interleavings, each asserting promish == brute-force oracle (exact)
or feasibility containment (approx) across selectivities 0-100%.

Profiles: "ci" is the default; the dedicated CI hypothesis leg sets
HYPOTHESIS_PROFILE=ci-heavy for more examples with an explicit deadline."""
import os

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed in this env")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import brute_force, promish_a, promish_e
from repro.core import projection as proj
from repro.core.filters import Filter, where
from repro.core.index import build_index
from repro.core.subset_search import is_minimal_candidate, pairwise_l2_numpy
from repro.core.types import Candidate, TopK, make_dataset
from repro.train.grad_compress import _quantize
from repro.utils.csr import csr_from_lists, invert_csr

settings.register_profile("ci", max_examples=25, deadline=None)
# The dedicated hypothesis matrix leg: more examples, explicit per-example
# deadline (these properties are pure numpy — no jit warmup to absorb), and
# suppression of the too-slow health check on the heavier differential
# strategies (corpus construction dominates, not the search under test).
settings.register_profile(
    "ci-heavy", max_examples=100, deadline=2000,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large,
                           HealthCheck.filter_too_much])
# `or "ci"`, not a get() default: the CI matrix exports the variable as an
# empty string on legs that don't set a profile, and load_profile("") raises.
settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE") or "ci")

pts_strategy = st.integers(5, 40)


@given(n=pts_strategy, d=st.integers(2, 24), seed=st.integers(0, 10_000))
def test_lemma1_projection_contracts(n, d, seed):
    """Lemma 1: |z.o1 - z.o2| <= ||o1 - o2|| for unit z."""
    rng = np.random.default_rng(seed)
    pts = rng.uniform(-100, 100, (n, d)).astype(np.float32)
    z = proj.sample_unit_vectors(rng, 4, d)
    p = proj.project(pts, z)                     # (n, 4)
    dist = pairwise_l2_numpy(pts, pts)
    for v in range(4):
        gaps = np.abs(p[:, v][:, None] - p[:, v][None, :])
        assert (gaps <= dist + 1e-3).all()


@given(n=st.integers(2, 12), d=st.integers(2, 16), seed=st.integers(0, 10_000),
       factor=st.floats(2.0, 8.0))
def test_lemma2_overlapping_bins_contain_set(n, d, seed, factor):
    """Lemma 2: bins of width w >= 2r contain any diameter-r set in ONE bin of
    the overlapping pair planes."""
    rng = np.random.default_rng(seed)
    pts = rng.uniform(-50, 50, (n, d)).astype(np.float32)
    r = float(pairwise_l2_numpy(pts, pts).max())
    w = max(factor * max(r, 1e-3), 1e-3)
    z = proj.sample_unit_vectors(rng, 3, d)
    p = proj.project(pts, z)
    keys = proj.bin_keys_overlapping(p, w)       # (n, m, 2)
    for v in range(3):
        h1_same = len(np.unique(keys[:, v, 0])) == 1
        h2_same = len(np.unique(keys[:, v, 1])) == 1
        assert h1_same or h2_same, (r, w)


@given(items=st.lists(st.tuples(st.floats(0, 100, allow_nan=False),
                                st.integers(1, 6)), min_size=1, max_size=30),
       k=st.integers(1, 5))
def test_topk_invariants(items, k):
    pq = TopK(k, init_full=True)
    for i, (diam, card) in enumerate(items):
        ids = tuple(range(i, i + card))
        pq.offer(Candidate(ids=ids, diameter=float(diam)))
    got = pq.items
    assert len(got) <= k
    keys = [c.key() for c in got]
    assert keys == sorted(keys)
    assert len({c.ids for c in got}) == len(got)          # dedup
    if len(items) >= k:
        best = sorted(d for d, _ in items)[:k]
        np.testing.assert_allclose([c.diameter for c in got], best, rtol=1e-6)


@given(lists=st.lists(st.lists(st.integers(0, 9), max_size=5), min_size=1,
                      max_size=20))
def test_csr_invert_roundtrip(lists):
    csr = csr_from_lists([sorted(set(row)) for row in lists])
    inv = invert_csr(csr, 10)
    # membership is preserved both ways
    for row_id in range(csr.n_rows):
        for v in csr.row(row_id):
            assert row_id in inv.row(int(v))
    for v in range(10):
        for row_id in inv.row(v):
            assert v in csr.row(int(row_id))


@given(n=st.integers(20, 80), seed=st.integers(0, 5000), q=st.integers(2, 3),
       k=st.integers(1, 3))
@settings(max_examples=10, deadline=None)
def test_promish_e_exact_random_instances(n, seed, q, k):
    """ProMiSH-E == brute force on arbitrary random instances (the paper's
    100%-accuracy claim as a property)."""
    rng = np.random.default_rng(seed)
    pts = rng.uniform(0, 1000, (n, 4)).astype(np.float32)
    u = 6
    kws = [rng.choice(u, size=2, replace=False).tolist() for _ in range(n)]
    ds = make_dataset(pts, kws, n_keywords=u)
    idx = build_index(ds, m=2, n_scales=4, exact=True, seed=seed % 7)
    query = list(rng.choice(u, size=q, replace=False))
    truth = brute_force.search(ds, query, k=k)
    got = promish_e.search(ds, idx, query, k=k)
    np.testing.assert_allclose([c.diameter for c in got.items],
                               [c.diameter for c in truth.items], rtol=1e-4)


@given(vals=st.lists(st.floats(-1e4, 1e4, allow_nan=False,
                               allow_infinity=False, width=32),
                     min_size=1, max_size=100))
def test_int8_quantization_error_bound(vals):
    import jax.numpy as jnp
    g = jnp.asarray(np.asarray(vals, np.float32))
    q, scale = _quantize(g)
    deq = np.asarray(q, np.float32) * float(scale)
    amax = float(np.abs(np.asarray(g)).max())
    assert np.abs(deq - np.asarray(g)).max() <= amax / 127.0 + 1e-6


@given(n=st.integers(2, 10), d=st.integers(2, 8), seed=st.integers(0, 1000))
def test_diameter_monotone_under_insertion(n, d, seed):
    """Adding a point never decreases a set's diameter."""
    rng = np.random.default_rng(seed)
    pts = rng.uniform(-10, 10, (n + 1, d)).astype(np.float32)
    base = pairwise_l2_numpy(pts[:n], pts[:n]).max()
    grown = pairwise_l2_numpy(pts, pts).max()
    assert grown >= base - 1e-6


@given(n=st.integers(1, 50), seed=st.integers(0, 1000))
def test_hash_bucket_determinism_across_orderings(n, seed):
    """Bucket ids are a pure function of signatures — shard-order independent
    (the multi-pod index agreement property, DESIGN A3)."""
    from repro.core import signatures as sig
    rng = np.random.default_rng(seed)
    sigs = rng.integers(-10_000, 10_000, size=(n, 2)).astype(np.int64)
    perm = rng.permutation(n)
    b = sig.hash_signatures(sigs, 4096)
    b_perm = sig.hash_signatures(sigs[perm], 4096)
    np.testing.assert_array_equal(b[perm], b_perm)


# ---------------------------------------------------------------------------
# Randomized differential oracle harness: filtered & multi-tenant NKS.
# ---------------------------------------------------------------------------
def _random_corpus(rng, n, d, u, with_attrs=True):
    pts = rng.uniform(0, 1000, (n, d)).astype(np.float32)
    kws = [rng.choice(u, size=rng.integers(1, 3), replace=False).tolist()
           for _ in range(n)]
    attrs = None
    if with_attrs:
        attrs = {"price": rng.uniform(0.0, 100.0, n),
                 "category": rng.integers(0, 4, n).astype(np.int64)}
    return make_dataset(pts, kws, n_keywords=u, attrs=attrs)


def _draw_filter(draw, kind=None):
    """A random predicate spanning the whole selectivity range, including the
    degenerate 0% (price < 0) and 100% (price < 101) endpoints."""
    if kind is None:
        kind = draw(st.sampled_from(
            ["all", "empty", "price", "category", "both"]))
    if kind == "all":
        return where(("price", "<", 101.0))
    if kind == "empty":
        return where(("price", "<", -1.0))
    if kind == "price":
        return where(("price", "<", draw(st.floats(0.0, 100.0))))
    if kind == "category":
        cats = draw(st.lists(st.integers(0, 3), min_size=1, max_size=3,
                             unique=True))
        return where(("category", "in", cats))
    return where(("price", "<", draw(st.floats(10.0, 90.0))),
                 ("category", "in",
                  draw(st.lists(st.integers(0, 3), min_size=1, max_size=3,
                                unique=True))))


@st.composite
def filtered_instances(draw):
    seed = draw(st.integers(0, 10_000))
    rng = np.random.default_rng(seed)
    ds = _random_corpus(rng, draw(st.integers(15, 45)),
                        draw(st.integers(2, 5)), draw(st.integers(4, 8)))
    q = draw(st.integers(2, 3))
    populated = np.flatnonzero(np.diff(ds.ikp.offsets) > 0)
    if len(populated) < q:
        q = max(len(populated), 1)
    query = sorted(rng.choice(populated, size=q, replace=False).tolist())
    return ds, query, _draw_filter(draw), seed


@given(inst=filtered_instances())
def test_filtered_promish_e_equals_oracle(inst):
    """Filtered parity, exact tier: for any random corpus + predicate (0-100%
    selectivity), ProMiSH-E over the eligibility mask ranks identically to
    the brute-force oracle over the eligible sub-corpus, and only ever
    returns eligible minimal candidates."""
    ds, query, flt, seed = inst
    eligible = flt.evaluate(ds)
    idx = build_index(ds, m=2, n_scales=4, exact=True, seed=seed % 7)
    got = promish_e.search(ds, idx, query, k=2, eligible=eligible)
    want = brute_force.search(ds, query, k=2, eligible=eligible)
    np.testing.assert_allclose([c.diameter for c in got.items],
                               [c.diameter for c in want.items], rtol=1e-4)
    assert [len(c.ids) for c in got.items] == \
        [len(c.ids) for c in want.items]
    for c in got.items:
        assert all(eligible[i] for i in c.ids)
        assert is_minimal_candidate(c.ids, query, ds)
    if not eligible.any():
        assert got.items == []


@given(inst=filtered_instances())
def test_filtered_promish_a_subset_of_feasible(inst):
    """Filtered containment, approx tier: every ProMiSH-A candidate under a
    predicate is drawn from the feasible set — eligible points only, covers
    the query, minimal, diameter exact — and 0% selectivity yields empty."""
    ds, query, flt, seed = inst
    eligible = flt.evaluate(ds)
    idx = build_index(ds, m=2, n_scales=4, exact=False, seed=seed % 5)
    got = promish_a.search(ds, idx, query, k=2, eligible=eligible)
    feasible = set(brute_force.enumerate_candidates(ds, query,
                                                    eligible=eligible))
    for c in got.items:
        assert all(eligible[i] for i in c.ids)
        assert c.ids in feasible
        np.testing.assert_allclose(
            c.diameter, brute_force.set_diameter(c.ids, ds), rtol=1e-9)
    if not eligible.any():
        assert got.items == []


@given(inst=filtered_instances())
@settings(deadline=None)
def test_engine_filtered_batch_equals_oracle(inst):
    """The whole serving pipeline (plan -> backend -> enumeration) under a
    filter matches the oracle — the engine-level restatement of the parity
    contract, exercising bucket pruning and group restriction."""
    from repro.serve.engine import NKSEngine
    ds, query, flt, seed = inst
    eng = NKSEngine(ds, m=2, n_scales=4, seed=seed % 7)
    res = eng.query_batch([query], k=2, tier="exact", backend="numpy",
                          filter=flt)[0]
    want = brute_force.search_filtered(ds, query, flt, k=2)
    np.testing.assert_allclose([c.diameter for c in res.candidates],
                               [c.diameter for c in want.items], rtol=1e-4)
    assert [len(c.ids) for c in res.candidates] == \
        [len(c.ids) for c in want.items]


@st.composite
def tenant_instances(draw):
    seed = draw(st.integers(0, 10_000))
    rng = np.random.default_rng(seed)
    from repro.core.types import merge_tenants
    u = draw(st.integers(3, 6))
    corpora = {}
    for name in ("acme", "globex"):
        n = draw(st.integers(8, 25))
        pts = rng.uniform(0, 1000, (n, 3)).astype(np.float32)
        kws = [rng.choice(u, size=rng.integers(1, 3), replace=False).tolist()
               for _ in range(n)]
        corpora[name] = {"points": pts, "keywords": kws, "n_keywords": u,
                         "attrs": {"price": rng.uniform(0, 100, n),
                                   "category": rng.integers(0, 4, n)
                                   .astype(np.int64)}}
    ds = merge_tenants(corpora)
    tenant = draw(st.sampled_from(["acme", "globex"]))
    query = sorted(rng.choice(u, size=min(2, u), replace=False).tolist())
    return ds, tenant, query, seed


@given(inst=tenant_instances())
@settings(deadline=None)
def test_tenant_scoping_isolates_and_matches_oracle(inst):
    """Multi-tenant parity + isolation: a tenant-scoped query (tenant-local
    keyword ids) matches the oracle over that tenant's sub-corpus and can
    never return another tenant's points."""
    from repro.serve.engine import NKSEngine
    ds, tenant, query, seed = inst
    flt = Filter(tenant=tenant)
    eng = NKSEngine(ds, m=2, n_scales=4, seed=seed % 5)
    res = eng.query_batch([query], k=2, tier="exact", backend="numpy",
                          filter=flt)[0]
    want = brute_force.search_filtered(ds, query, flt, k=2)
    np.testing.assert_allclose([c.diameter for c in res.candidates],
                               [c.diameter for c in want.items], rtol=1e-4)
    tid = ds.tenants.id_of(tenant)
    for c in res.candidates:
        assert all(ds.tenant_of[i] == tid for i in c.ids), \
            f"tenant isolation violated: {tenant} -> {c.ids}"


@st.composite
def streaming_scripts(draw):
    """A random interleaving of insert/delete(/compact) ops plus a filtered
    query load."""
    seed = draw(st.integers(0, 10_000))
    n_ops = draw(st.integers(1, 4))
    ops = [draw(st.sampled_from(["insert", "delete", "compact"]))
           for _ in range(n_ops)]
    return seed, ops, _draw_filter(draw)


@given(script=streaming_scripts())
@settings(deadline=None, max_examples=20)
def test_streaming_filtered_interleaving_parity(script):
    """Streaming x filtering: after any random interleaving of inserts,
    deletes, and compactions, a filtered exact query answers identically
    (external ids and diameters) to a fresh engine over the equivalent
    static corpus."""
    from repro.serve.engine import NKSEngine
    seed, ops, flt = script
    rng = np.random.default_rng(seed)
    u, d = 6, 3
    base = _random_corpus(rng, 30, d, u)
    probe = build_index(base, m=2, n_scales=4, exact=True, seed=0)
    pinned = dict(m=2, n_scales=4, seed=0, w0=probe.w0,
                  n_buckets=probe.structures[0].n_buckets)
    eng = NKSEngine(base, auto_compact=False, **pinned)

    pts = [base.points[i].copy() for i in range(base.n)]
    kws = [base.kw.row(i).tolist() for i in range(base.n)]
    price = list(base.attrs["price"])
    cat = list(base.attrs["category"])
    alive = {i: True for i in range(base.n)}

    for op in ops:
        live_ids = [i for i, a in alive.items() if a]
        if op == "insert":
            b = int(rng.integers(1, 5))
            np_pts = rng.uniform(0, 1000, (b, d)).astype(np.float32)
            np_kws = [rng.choice(u, size=rng.integers(1, 3),
                                 replace=False).tolist() for _ in range(b)]
            np_price = rng.uniform(0, 100, b)
            np_cat = rng.integers(0, 4, b).astype(np.int64)
            ext = eng.insert(np_pts, np_kws,
                             attrs={"price": np_price, "category": np_cat})
            for j, e in enumerate(ext):
                alive[int(e)] = True
                pts.append(np_pts[j]); kws.append(np_kws[j])
                price.append(np_price[j]); cat.append(np_cat[j])
        elif op == "delete" and len(live_ids) > 3:
            doomed = rng.choice(live_ids, size=min(2, len(live_ids) - 3),
                                replace=False)
            eng.delete(sorted(int(i) for i in doomed))
            for i in doomed:
                alive[int(i)] = False
        elif op == "compact" and (eng.delta_points or eng.tombstone_count):
            if eng.tombstone_count < eng.dataset.n:
                eng.compact()

    keep = np.asarray(sorted(i for i, a in alive.items() if a))
    fresh_ds = make_dataset(
        np.stack([pts[i] for i in keep]), [kws[int(i)] for i in keep],
        n_keywords=u,
        attrs={"price": np.asarray([price[i] for i in keep]),
               "category": np.asarray([cat[i] for i in keep])})
    fresh = NKSEngine(fresh_ds, **pinned)
    populated = np.flatnonzero(np.diff(fresh_ds.ikp.offsets) > 0)
    if len(populated) < 2:
        return
    query = sorted(rng.choice(populated, size=2, replace=False).tolist())

    got = eng.query_batch([query], k=2, tier="exact", backend="numpy",
                          filter=flt)[0]
    want = fresh.query_batch([query], k=2, tier="exact", backend="numpy",
                             filter=flt)[0]
    ext_want = [tuple(int(keep[j]) for j in c.ids) for c in want.candidates]
    assert [c.ids for c in got.candidates] == ext_want, (ops, query)
    np.testing.assert_allclose([c.diameter for c in got.candidates],
                               [c.diameter for c in want.candidates],
                               rtol=1e-9)


# ------------------------------------------- flexible semantics (ISSUE 9)
@st.composite
def semantics_instances(draw):
    """Random corpus x random query x random semantics draw spanning the
    whole knob space: m over [1, |Q|] or None, weights on a random query
    subset, scored or not. Trivial draws (m = |Q|, unit weights, no score)
    are kept in-distribution on purpose — they pin the degeneracy contract."""
    from repro.core.semantics import QuerySemantics
    seed = draw(st.integers(0, 10_000))
    rng = np.random.default_rng(seed)
    ds = _random_corpus(rng, draw(st.integers(15, 40)),
                        draw(st.integers(2, 4)), draw(st.integers(4, 7)),
                        with_attrs=False)
    populated = np.flatnonzero(np.diff(ds.ikp.offsets) > 0)
    q = min(draw(st.integers(2, 3)), max(len(populated), 1))
    query = sorted(rng.choice(populated, size=q, replace=False).tolist())
    m = draw(st.one_of(st.none(), st.integers(1, q)))
    weights = {int(v): draw(st.floats(1.0, 8.0))
               for v in query if draw(st.booleans())}
    sem = QuerySemantics(m=m, weights=weights or None,
                         score=draw(st.booleans()),
                         alpha=draw(st.floats(0.1, 2.0)))
    return ds, query, sem, seed


def _assert_flex_parity(got, want, ds, query, sem):
    """Non-trivial semantics: exact id-sequence parity with the oracle.
    Trivial draws go through the untouched classic path, which keeps its
    historical (arbitrary) equal-diameter tie resolution — there the
    contract is cost parity + universe membership."""
    if sem.trivial_for(query):
        np.testing.assert_allclose([c.diameter for c in got],
                                   [c.diameter for c in want], rtol=1e-9)
        universe = set(brute_force.enumerate_candidates(ds, query))
        for c in got:
            assert c.ids in universe
    else:
        assert [c.ids for c in got] == [c.ids for c in want]
        np.testing.assert_allclose([c.diameter for c in got],
                                   [c.diameter for c in want], rtol=1e-9)
        if sem.score:
            np.testing.assert_allclose(
                [c.score for c in got], [c.score for c in want], rtol=1e-9)


@given(inst=semantics_instances())
@settings(deadline=None)
def test_flex_promish_e_equals_oracle(inst):
    """Flexible parity, exact tier: for any random (m, weights, score) draw,
    ProMiSH-E ranks identically to the extended brute-force oracle."""
    ds, query, sem, seed = inst
    idx = build_index(ds, m=2, n_scales=4, exact=True, seed=seed % 7)
    got = promish_e.search(ds, idx, query, k=2, semantics=sem).items
    want = brute_force.search_flex(ds, query, k=2, semantics=sem)
    _assert_flex_parity(got, want, ds, query, sem)


@given(inst=semantics_instances())
@settings(deadline=None)
def test_flex_promish_a_subset_of_feasible(inst):
    """Flexible containment, approx tier: every candidate is drawn from the
    m-of-k universe with the exact weighted cost (and score)."""
    ds, query, sem, seed = inst
    idx = build_index(ds, m=2, n_scales=4, exact=False, seed=seed % 5)
    got = promish_a.search(ds, idx, query, k=2, semantics=sem).items
    wvec = sem.weight_vector(ds, query)
    feasible = set(brute_force.enumerate_candidates_flex(ds, query, sem))
    for c in got:
        assert c.ids in feasible
        np.testing.assert_allclose(
            c.diameter, brute_force.weighted_set_cost(c.ids, ds, wvec),
            rtol=1e-9)
        if sem.score:
            cov = sem.coverage_fn(ds, query)
            np.testing.assert_allclose(
                c.score, cov(c.ids) / (1.0 + sem.alpha * c.diameter),
                rtol=1e-9)


@given(inst=semantics_instances())
@settings(deadline=None)
def test_flex_engine_parity_and_degeneracy(inst):
    """The batched engine under flexible semantics matches the oracle, and a
    degenerate semantics object (m = |Q|) is *bit-identical* to the
    semantics-free batch on the same route — the contract that guards every
    pre-existing caller."""
    from repro.serve.engine import NKSEngine
    ds, query, sem, seed = inst
    eng = NKSEngine(ds, m=2, n_scales=4, seed=seed % 5)
    got = eng.query_batch([query], k=2, tier="exact", backend="numpy",
                          semantics=sem)[0].candidates
    want = brute_force.search_flex(ds, query, k=2, semantics=sem)
    _assert_flex_parity(got, want, ds, query, sem)
    base = eng.query_batch([query], k=2, tier="exact", backend="numpy")[0]
    deg = eng.query_batch([query], k=2, tier="exact", backend="numpy",
                          semantics={"m": len(query)})[0]
    assert [(c.ids, c.diameter) for c in deg.candidates] == \
        [(c.ids, c.diameter) for c in base.candidates]


# ---------------------------------------------------------- cascade tier 0
@st.composite
def cascade_instances(draw):
    """Adversarial-leaning instances for the mixed-precision prune bound:
    clustered points with pair distances concentrated near the threshold
    (scaled offsets of +/- a few bf16 ulps), random dtype, random radius
    scale spanning three orders of magnitude."""
    rng = np.random.default_rng(draw(st.integers(0, 10_000)))
    d = draw(st.integers(2, 16))
    n = draw(st.integers(2, 24))
    r = draw(st.floats(0.5, 500.0))
    dtype = draw(st.sampled_from(["bf16", "int8"]))
    base = rng.uniform(-1, 1, d)
    base /= np.linalg.norm(base)
    anchor = rng.uniform(-r, r, d).astype(np.float32)
    pts = [anchor]
    for _ in range(n - 1):
        if rng.random() < 0.5:
            # boundary pair: distance r * (1 + k * 2^-9), k in [-8, 8]
            k = rng.integers(-8, 9)
            pts.append((anchor + base * (r * (1.0 + k * 2.0 ** -9)))
                       .astype(np.float32))
        else:
            pts.append(rng.uniform(-2 * r, 2 * r, d).astype(np.float32))
    return np.stack(pts), np.float32(r), dtype


@given(inst=cascade_instances())
@settings(deadline=None)
def test_cascade_coarse_count_never_undercounts(inst):
    """Tier-0 safety: the low-precision count at the error-widened coarse
    radius dominates the exact float64 count at the base radius — so a
    coarse count at the diagonal bound proves the fp32 join empty, and the
    cascade can never drop a result (the float64 rescore settles the
    over-counted boundary pairs)."""
    import jax.numpy as jnp
    from repro.kernels import ops
    x, r, dtype = inst
    n, d = x.shape
    pf = x.astype(np.float64)
    d2 = ((pf[:, None] - pf[None, :]) ** 2).sum(-1)
    exact = int((np.sqrt(d2) <= r).sum())
    norms = np.sqrt((pf ** 2).sum(-1)).max()
    rc = np.array([(r + 2 * 2.0 ** -8 * norms) * 1.05], np.float32)
    cnt = int(np.asarray(ops.pairwise_l2_join_batched_counts(
        jnp.asarray(x[None]), np.array([n], np.int32), rc,
        dtype=dtype, impl="xla"))[0])
    assert cnt >= exact
