"""Property-based tests (hypothesis) for the system's core invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed in this env")
from hypothesis import given, settings, strategies as st

from repro.core import brute_force, promish_e
from repro.core import projection as proj
from repro.core.index import build_index
from repro.core.subset_search import pairwise_l2_numpy
from repro.core.types import Candidate, TopK, make_dataset
from repro.train.grad_compress import _quantize
from repro.utils.csr import csr_from_lists, invert_csr

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

pts_strategy = st.integers(5, 40)


@given(n=pts_strategy, d=st.integers(2, 24), seed=st.integers(0, 10_000))
def test_lemma1_projection_contracts(n, d, seed):
    """Lemma 1: |z.o1 - z.o2| <= ||o1 - o2|| for unit z."""
    rng = np.random.default_rng(seed)
    pts = rng.uniform(-100, 100, (n, d)).astype(np.float32)
    z = proj.sample_unit_vectors(rng, 4, d)
    p = proj.project(pts, z)                     # (n, 4)
    dist = pairwise_l2_numpy(pts, pts)
    for v in range(4):
        gaps = np.abs(p[:, v][:, None] - p[:, v][None, :])
        assert (gaps <= dist + 1e-3).all()


@given(n=st.integers(2, 12), d=st.integers(2, 16), seed=st.integers(0, 10_000),
       factor=st.floats(2.0, 8.0))
def test_lemma2_overlapping_bins_contain_set(n, d, seed, factor):
    """Lemma 2: bins of width w >= 2r contain any diameter-r set in ONE bin of
    the overlapping pair planes."""
    rng = np.random.default_rng(seed)
    pts = rng.uniform(-50, 50, (n, d)).astype(np.float32)
    r = float(pairwise_l2_numpy(pts, pts).max())
    w = max(factor * max(r, 1e-3), 1e-3)
    z = proj.sample_unit_vectors(rng, 3, d)
    p = proj.project(pts, z)
    keys = proj.bin_keys_overlapping(p, w)       # (n, m, 2)
    for v in range(3):
        h1_same = len(np.unique(keys[:, v, 0])) == 1
        h2_same = len(np.unique(keys[:, v, 1])) == 1
        assert h1_same or h2_same, (r, w)


@given(items=st.lists(st.tuples(st.floats(0, 100, allow_nan=False),
                                st.integers(1, 6)), min_size=1, max_size=30),
       k=st.integers(1, 5))
def test_topk_invariants(items, k):
    pq = TopK(k, init_full=True)
    for i, (diam, card) in enumerate(items):
        ids = tuple(range(i, i + card))
        pq.offer(Candidate(ids=ids, diameter=float(diam)))
    got = pq.items
    assert len(got) <= k
    keys = [c.key() for c in got]
    assert keys == sorted(keys)
    assert len({c.ids for c in got}) == len(got)          # dedup
    if len(items) >= k:
        best = sorted(d for d, _ in items)[:k]
        np.testing.assert_allclose([c.diameter for c in got], best, rtol=1e-6)


@given(lists=st.lists(st.lists(st.integers(0, 9), max_size=5), min_size=1,
                      max_size=20))
def test_csr_invert_roundtrip(lists):
    csr = csr_from_lists([sorted(set(row)) for row in lists])
    inv = invert_csr(csr, 10)
    # membership is preserved both ways
    for row_id in range(csr.n_rows):
        for v in csr.row(row_id):
            assert row_id in inv.row(int(v))
    for v in range(10):
        for row_id in inv.row(v):
            assert v in csr.row(int(row_id))


@given(n=st.integers(20, 80), seed=st.integers(0, 5000), q=st.integers(2, 3),
       k=st.integers(1, 3))
@settings(max_examples=10, deadline=None)
def test_promish_e_exact_random_instances(n, seed, q, k):
    """ProMiSH-E == brute force on arbitrary random instances (the paper's
    100%-accuracy claim as a property)."""
    rng = np.random.default_rng(seed)
    pts = rng.uniform(0, 1000, (n, 4)).astype(np.float32)
    u = 6
    kws = [rng.choice(u, size=2, replace=False).tolist() for _ in range(n)]
    ds = make_dataset(pts, kws, n_keywords=u)
    idx = build_index(ds, m=2, n_scales=4, exact=True, seed=seed % 7)
    query = list(rng.choice(u, size=q, replace=False))
    truth = brute_force.search(ds, query, k=k)
    got = promish_e.search(ds, idx, query, k=k)
    np.testing.assert_allclose([c.diameter for c in got.items],
                               [c.diameter for c in truth.items], rtol=1e-4)


@given(vals=st.lists(st.floats(-1e4, 1e4, allow_nan=False,
                               allow_infinity=False, width=32),
                     min_size=1, max_size=100))
def test_int8_quantization_error_bound(vals):
    import jax.numpy as jnp
    g = jnp.asarray(np.asarray(vals, np.float32))
    q, scale = _quantize(g)
    deq = np.asarray(q, np.float32) * float(scale)
    amax = float(np.abs(np.asarray(g)).max())
    assert np.abs(deq - np.asarray(g)).max() <= amax / 127.0 + 1e-6


@given(n=st.integers(2, 10), d=st.integers(2, 8), seed=st.integers(0, 1000))
def test_diameter_monotone_under_insertion(n, d, seed):
    """Adding a point never decreases a set's diameter."""
    rng = np.random.default_rng(seed)
    pts = rng.uniform(-10, 10, (n + 1, d)).astype(np.float32)
    base = pairwise_l2_numpy(pts[:n], pts[:n]).max()
    grown = pairwise_l2_numpy(pts, pts).max()
    assert grown >= base - 1e-6


@given(n=st.integers(1, 50), seed=st.integers(0, 1000))
def test_hash_bucket_determinism_across_orderings(n, seed):
    """Bucket ids are a pure function of signatures — shard-order independent
    (the multi-pod index agreement property, DESIGN A3)."""
    from repro.core import signatures as sig
    rng = np.random.default_rng(seed)
    sigs = rng.integers(-10_000, 10_000, size=(n, 2)).astype(np.int64)
    perm = rng.permutation(n)
    b = sig.hash_signatures(sigs, 4096)
    b_perm = sig.hash_signatures(sigs[perm], 4096)
    np.testing.assert_array_equal(b[perm], b_perm)
