"""Multi-device correctness script, run in a subprocess with 8 forced host
devices (tests/test_multidevice.py drives it). Asserts:

  1. distributed_nks_topk (shard_map over data axis) == single-device
     anchor-star result;
  2. compressed_psum over the pod axis == exact mean within int8 quant error;
  3. pipeline_forward (ppermute GPipe) == sequential layer application;
  4. the dryrun entry-point machinery works on a small mesh (sanity).
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"


import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.distributed import (distributed_nks_topk, nks_anchor_topk,
                                    pack_groups)
from repro.data.synthetic import random_queries, synthetic_dataset
from repro.launch.mesh import make_local_mesh
from repro.train.grad_compress import compressed_psum
from repro.train.pipeline_parallel import pipeline_forward


def test_distributed_nks():
    """Parity on a forced 8-device CPU mesh: distributed_nks_topk (now
    rebuilt on core.device_plane) == the single-device anchor-star kernel,
    and == DevicePlane.nks_topk (the wrapper and the plane share one
    program)."""
    from repro.core.device_plane import DevicePlane
    mesh = make_local_mesh(data=8, model=1)
    plane = DevicePlane(mesh)
    ds = synthetic_dataset(n=2000, d=12, u=20, t=2, seed=1)
    for query in random_queries(ds, 3, 3, seed=5):
        groups, mask, ids = pack_groups(ds, query, r_max=256)
        # single device
        d1, c1 = nks_anchor_topk(jnp.asarray(groups), jnp.asarray(mask),
                                 jnp.asarray(ids), k=3)
        # sharded, via the compatibility wrapper and via the plane directly
        with mesh:
            d8, c8 = distributed_nks_topk(mesh, jnp.asarray(groups),
                                          jnp.asarray(mask), jnp.asarray(ids),
                                          k=3)
        dp, cp = plane.nks_topk(jnp.asarray(groups), jnp.asarray(mask),
                                jnp.asarray(ids), k=3)
        np.testing.assert_allclose(np.asarray(d8), np.asarray(d1), rtol=1e-5,
                                   err_msg=f"query={query}")
        np.testing.assert_array_equal(np.asarray(dp), np.asarray(d8),
                                      err_msg=f"query={query}")
        np.testing.assert_array_equal(np.asarray(cp), np.asarray(c8),
                                      err_msg=f"query={query}")
    print("distributed_nks ok")


def test_compressed_psum():
    mesh = make_local_mesh(data=1, model=1, pod=8)
    rng = np.random.default_rng(0)
    g_all = jnp.asarray(rng.standard_normal((8, 32)), jnp.float32)

    def body(g):
        buf = {"g": jnp.zeros_like(g)}
        red, _ = compressed_psum({"g": g}, buf, "pod")
        return red["g"]

    fn = shard_map(body, mesh=mesh, in_specs=(P("pod", None),),
                   out_specs=P("pod", None), check_rep=False)
    with mesh:
        out = fn(g_all)
    true_mean = np.asarray(g_all).mean(axis=0)
    got = np.asarray(out)[0]
    amax = np.abs(np.asarray(g_all)).max()
    assert np.abs(got - true_mean).max() <= amax / 127.0 + 1e-6
    # every shard holds the same reduced value
    np.testing.assert_allclose(np.asarray(out), np.tile(got, (8, 1)), rtol=1e-6)
    print("compressed_psum ok")


def test_pipeline_forward():
    mesh = make_local_mesh(data=1, model=1, pod=8)
    n_stages, m, dim = 8, 16, 32
    rng = np.random.default_rng(2)
    w_all = jnp.asarray(rng.standard_normal((n_stages, dim, dim)) * 0.2,
                        jnp.float32)
    x = jnp.asarray(rng.standard_normal((m, dim)), jnp.float32)

    def stage_fn_factory(w_local):
        def stage_fn(h, t):
            del t
            return jnp.tanh(h @ w_local[0])
        return stage_fn

    def body(w_local, mb):
        out = pipeline_forward(stage_fn_factory(w_local), w_local, mb,
                               axis_name="pod")
        return out[None]                      # add the stage axis for out_specs

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P("pod", None, None), P(None, None)),
                   out_specs=P("pod", None, None), check_rep=False)
    with mesh:
        out = fn(w_all, x)                    # (8, M, dim) per stage
    got = np.asarray(out)[-1]                 # last stage's outputs
    # sequential reference
    ref = np.asarray(x)
    for s in range(n_stages):
        ref = np.tanh(ref @ np.asarray(w_all[s]))
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-5)
    print("pipeline_forward ok")


def test_search_step_lowering():
    """The distributed NKS serve step lowers+compiles on a (data, model) mesh."""
    mesh = make_local_mesh(data=8, model=1)
    from repro.core.distributed import search_step_specs
    structs, specs = search_step_specs(q=4, r_total=1024, d=64, k=5)
    with mesh:
        def fn(g, m_, i):
            return distributed_nks_topk(mesh, g, m_, i, k=5)
        from jax.sharding import NamedSharding
        shardings = tuple(NamedSharding(mesh, s) for s in specs)
        lowered = jax.jit(fn, in_shardings=shardings).lower(*structs)
        compiled = lowered.compile()
        assert compiled.cost_analysis() is not None
    print("search_step lowering ok")


def test_flash_attention_shardmap():
    """The shard_map-wrapped Pallas flash path (interpret) == the jnp scan,
    on a real (data, model) mesh — validates the TPU wiring end to end."""
    import jax
    from repro.models import hints
    from repro.models.common import blockwise_attention

    mesh = make_local_mesh(data=4, model=2)
    b, s, h, hd = 4, 64, 2, 16
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, s, h, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, hd), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def attn(q, k, v):
        return blockwise_attention(q, k, v, pos, pos, causal=True,
                                   window=None, block=16)

    want = np.asarray(attn(q, k, v))                  # jnp path (no flash)
    os.environ["REPRO_FLASH_INTERPRET"] = "1"
    hints.enable_hints_mesh(mesh, ("data",), "model")
    try:
        with mesh:
            got = np.asarray(jax.jit(attn)(q, k, v))
    finally:
        del os.environ["REPRO_FLASH_INTERPRET"]
        hints.disable_hints()
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)
    print("flash shard_map ok")


if __name__ == "__main__":
    test_distributed_nks()
    test_compressed_psum()
    test_pipeline_forward()
    test_search_step_lowering()
    test_flash_attention_shardmap()
    print("ALL MULTIDEV OK")
