"""Optimizer, schedules, checkpointing, grad compression, data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import (CheckpointManager, find_latest,
                                   load_checkpoint, save_checkpoint)
from repro.data.token_pipeline import PipelineConfig, TokenPipeline
from repro.train.grad_compress import ef_compress, init_error_buf
from repro.train.optimizer import (OptimizerConfig, adamw_update,
                                   init_opt_state, lr_at)


# ------------------------------------------------------------- schedules
def test_wsd_schedule_shape():
    cfg = OptimizerConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                          decay_frac=0.2, schedule="wsd", min_lr_frac=0.1)
    assert float(lr_at(cfg, 0)) == pytest.approx(0.0)
    assert float(lr_at(cfg, 10)) == pytest.approx(1.0)
    assert float(lr_at(cfg, 50)) == pytest.approx(1.0)      # stable phase
    assert float(lr_at(cfg, 79)) == pytest.approx(1.0)
    assert float(lr_at(cfg, 100)) == pytest.approx(0.1, abs=1e-6)
    mid = float(lr_at(cfg, 90))
    assert 0.1 < mid < 1.0


def test_cosine_schedule_monotone_decay():
    cfg = OptimizerConfig(peak_lr=1.0, warmup_steps=5, total_steps=50,
                          schedule="cosine", min_lr_frac=0.0)
    vals = [float(lr_at(cfg, s)) for s in range(5, 51)]
    assert all(a >= b - 1e-7 for a, b in zip(vals, vals[1:]))
    assert vals[-1] == pytest.approx(0.0, abs=1e-6)


# ------------------------------------------------------------- optimizer
@pytest.mark.parametrize("state_dtype", ["float32", "bfloat16"])
def test_adamw_minimizes_quadratic(state_dtype):
    cfg = OptimizerConfig(peak_lr=0.1, warmup_steps=0, total_steps=200,
                          weight_decay=0.0, state_dtype=state_dtype)
    params = {"x": jnp.array([3.0, -2.0])}
    opt = init_opt_state(params, cfg)

    @jax.jit
    def step(params, opt):
        grads = jax.grad(lambda p: jnp.sum(p["x"] ** 2))(params)
        return adamw_update(params, grads, opt, cfg)

    for _ in range(200):
        params, opt, metrics = step(params, opt)
    assert float(jnp.abs(params["x"]).max()) < 0.05
    assert int(opt["step"]) == 200
    assert np.isfinite(float(metrics["grad_norm"]))


def test_adamw_grad_clipping():
    cfg = OptimizerConfig(peak_lr=1e-3, warmup_steps=0, clip_norm=1.0)
    params = {"x": jnp.zeros(3)}
    opt = init_opt_state(params, cfg)
    huge = {"x": jnp.full(3, 1e6)}
    new_params, _, m = adamw_update(params, huge, opt, cfg)
    assert float(m["grad_norm"]) > 1e6
    assert np.isfinite(np.asarray(new_params["x"])).all()
    assert float(jnp.abs(new_params["x"]).max()) < 1.0


# ------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "opt": {"step": jnp.asarray(7, jnp.int32)}}
    p = save_checkpoint(str(tmp_path), 7, state, extra={"next_step": 8})
    restored, step, extra = load_checkpoint(p, state)
    assert step == 7 and extra["next_step"] == 8
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_checkpoint_detects_corruption(tmp_path):
    state = {"w": jnp.ones(4)}
    p = save_checkpoint(str(tmp_path), 1, state)
    # corrupt the payload
    import json
    man = json.load(open(os.path.join(p, "manifest.json")))
    man["leaves"]["w"]["sha256"] = "0" * 64
    json.dump(man, open(os.path.join(p, "manifest.json"), "w"))
    with pytest.raises(IOError):
        load_checkpoint(p, state)


def test_checkpoint_manager_rolls(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, every=1)
    state = {"w": jnp.ones(2)}
    for s in range(1, 6):
        mgr.maybe_save(s, state)
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert kept == ["step_00000004", "step_00000005"]
    assert find_latest(str(tmp_path)).endswith("step_00000005")


def test_checkpoint_atomic_no_partials(tmp_path):
    state = {"w": jnp.ones(8)}
    save_checkpoint(str(tmp_path), 3, state)
    entries = os.listdir(tmp_path)
    assert all(not e.startswith(".tmp") for e in entries)


# ---------------------------------------------------------- compression
def test_ef_compress_bounded_error_and_feedback():
    rng = np.random.default_rng(0)
    g = {"a": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)}
    buf = init_error_buf(g)
    deq, err = ef_compress(g, buf)
    amax = float(jnp.abs(g["a"]).max())
    assert float(jnp.abs(deq["a"] - g["a"]).max()) <= amax / 127.0
    # error feedback: accumulated error is re-injected -> running mean of
    # dequantised values converges to the true mean
    total_true = np.zeros((8,), np.float32)
    total_deq = np.zeros((8,), np.float32)
    buf = init_error_buf({"a": jnp.zeros(8)})
    for i in range(100):
        gi = {"a": jnp.asarray(rng.standard_normal(8) * 0.1, jnp.float32)}
        deq, buf = ef_compress(gi, buf)
        total_true += np.asarray(gi["a"])
        total_deq += np.asarray(deq["a"])
    # cumulative sums agree to within one final quantisation step
    assert np.abs(total_true - total_deq).max() < 0.05


# ------------------------------------------------------------- pipeline
def test_pipeline_deterministic_and_elastic():
    cfg = PipelineConfig(vocab_size=100, global_batch=8, seq_len=16, seed=3)
    pipe = TokenPipeline(cfg)
    b1 = pipe.batch_at(5, 0, 1)
    b2 = pipe.batch_at(5, 0, 1)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # elastic: global batch content identical under any dp_size partition
    parts = [pipe.batch_at(5, r, 4)["tokens"] for r in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts, 0), b1["tokens"])
    # different steps differ
    assert not np.array_equal(pipe.batch_at(6, 0, 1)["tokens"], b1["tokens"])
    # targets are next-token shifted
    full = pipe.batch_at(5, 0, 1)
    np.testing.assert_array_equal(full["tokens"][:, 1:], full["targets"][:, :-1])


def test_train_loop_end_to_end(tmp_path):
    """Tiny real loop: loss decreases, checkpoint resume continues exactly."""
    from repro.train.train_loop import LoopConfig, TrainLoop

    cfg = OptimizerConfig(peak_lr=0.05, warmup_steps=2, total_steps=30,
                          weight_decay=0.0)
    pipe = TokenPipeline(PipelineConfig(vocab_size=50, global_batch=4,
                                        seq_len=8, seed=0))
    w_key = jax.random.PRNGKey(0)

    def init_state():
        # Init far from the uniform-logit optimum: targets are random tokens
        # (irreducible loss = log V), so a near-uniform 0.1-scale init leaves
        # nothing to learn and step noise dominates the loss trend. A unit
        # scale gives a large removable excess => a robustly decreasing loss.
        return {"params": {"emb": jax.random.normal(w_key, (50, 16)),
                           "out": jax.random.normal(w_key, (16, 50))},
                "opt": None}

    def loss_fn(params, batch):
        x = params["emb"][batch["tokens"]]
        logits = x @ params["out"]
        lse = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, batch["targets"][..., None], -1)[..., 0]
        return (lse - gold).mean()

    opt0 = init_opt_state(init_state()["params"], cfg)

    @jax.jit
    def step(state, batch):
        batch = jax.tree.map(jnp.asarray, batch)
        params = state["params"]
        opt = state["opt"] if state["opt"] is not None else opt0
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt, m = adamw_update(params, grads, opt, cfg)
        return {"params": params, "opt": opt}, {"loss": loss, **m}

    def stepper(state, batch):
        if state["opt"] is None:
            state = {"params": state["params"], "opt": opt0}
        return step(state, batch)

    loop_cfg = LoopConfig(total_steps=15, ckpt_dir=str(tmp_path / "ck"),
                          ckpt_every=5)
    loop = TrainLoop(loop_cfg, stepper, pipe, init_state)
    state, hist = loop.run()
    assert hist[-1]["loss"] < hist[0]["loss"]
    # resume: extend to 30 steps from the saved checkpoint
    loop2 = TrainLoop(LoopConfig(total_steps=30, ckpt_dir=str(tmp_path / "ck"),
                                 ckpt_every=5), stepper, pipe, init_state)
    state2, hist2 = loop2.run()
    assert hist2[0]["step"] == 15          # resumed, not restarted
    assert hist2[-1]["loss"] < hist[-1]["loss"] + 0.5
