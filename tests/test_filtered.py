"""Filtered & multi-tenant NKS: seeded differential suite.

The filtered parity contract (ISSUE 5): for any predicate/tenant filter at
any selectivity (0–100%), the exact tier matches the brute-force oracle over
the eligible sub-corpus, the approx tier only ever returns eligible feasible
candidates, both pallas and numpy backends agree bit-identically with each
other, the device stays free of new D2H traffic (eligibility rides the
packed join bitmask), and the whole thing composes with streaming ingest.

These tests are seeded (no hypothesis dependency) so the contract is
exercised in every environment; ``tests/test_properties.py`` layers the
randomized hypothesis harness on top in CI.
"""
import json

import numpy as np
import pytest

from repro.core import brute_force
from repro.core.backend import NumpyBackend, PallasBackend
from repro.core.filters import Clause, Filter, where
from repro.core.subset_search import is_minimal_candidate, unpack_join_mask
from repro.core.types import make_dataset
from repro.data.synthetic import (attach_attrs, random_queries,
                                  synthetic_attrs, synthetic_dataset,
                                  synthetic_tenants)
from repro.serve.engine import NKSEngine

SELECTIVITIES = (1.0, 0.5, 0.1, 0.01, 0.0)


@pytest.fixture(scope="module")
def corpus():
    return attach_attrs(synthetic_dataset(n=300, d=8, u=12, t=2, seed=7),
                        seed=1)


@pytest.fixture(scope="module")
def engine(corpus):
    return NKSEngine(corpus, m=2, n_scales=5, seed=0)


@pytest.fixture(scope="module")
def queries(corpus):
    return random_queries(corpus, 2, 6, seed=3) + \
        random_queries(corpus, 3, 4, seed=4)


def assert_same_ranking(got, want, ctx=""):
    """Engine result == oracle result under the paper's (diameter,
    cardinality) ranking. Ids are compared only through feasibility: at equal
    keys the tie-break between distinct-but-equivalent candidate sets is
    unspecified (the oracle enumerates in id order, the search in discovery
    order), and the oracle stores float32 diameters (rtol 1e-5, the repo's
    established oracle tolerance)."""
    assert len(got) == len(want), f"{ctx}: {got} != {want}"
    np.testing.assert_allclose([c.diameter for c in got],
                               [c.diameter for c in want], rtol=1e-5,
                               err_msg=ctx)
    assert [len(c.ids) for c in got] == [len(c.ids) for c in want], ctx


@pytest.mark.parametrize("sel", SELECTIVITIES)
@pytest.mark.parametrize("backend", ["numpy", "pallas"])
def test_exact_tier_matches_filtered_oracle(engine, corpus, queries, sel,
                                            backend):
    flt = where(("price", "<", 100.0 * sel))
    eligible = flt.evaluate(corpus)
    res = engine.query_batch(queries, k=2, tier="exact", backend=backend,
                             filter=flt)
    for q, r in zip(queries, res):
        truth = brute_force.search_filtered(corpus, q, flt, k=2)
        assert_same_ranking(r.candidates, truth.items,
                            f"sel={sel} backend={backend} q={q}")
        for c in r.candidates:
            assert all(eligible[i] for i in c.ids)
            assert is_minimal_candidate(c.ids, q, corpus)
    st = engine.last_batch_stats
    assert st.eligible_points == int(eligible.sum())
    assert st.filter_selectivity == pytest.approx(eligible.mean(), abs=1e-6)
    if sel < 1.0:
        assert st.filtered_subsets > 0


@pytest.mark.parametrize("sel", SELECTIVITIES)
def test_approx_tier_subset_of_feasible(engine, corpus, queries, sel):
    """approx ⊆ feasible: every candidate is eligible, covers the query, and
    is minimal — including the empty-result path at 0% selectivity."""
    flt = where(("price", "<", 100.0 * sel))
    eligible = flt.evaluate(corpus)
    for backend in ("numpy", "pallas"):
        res = engine.query_batch(queries, k=2, tier="approx", backend=backend,
                                 filter=flt)
        for q, r in zip(queries, res):
            if sel == 0.0:
                assert r.candidates == []
            for c in r.candidates:
                assert all(eligible[i] for i in c.ids)
                covered = set()
                for i in c.ids:
                    covered.update(corpus.kw.row(i).tolist())
                assert set(q) <= covered
                assert is_minimal_candidate(c.ids, q, corpus)


@pytest.mark.parametrize("sel", [0.5, 0.05])
def test_backends_agree_under_filter(engine, queries, sel):
    """pallas and numpy agree candidate-for-candidate: same ids, same order,
    diameters equal to float64 accumulation-order noise (the two paths run
    the same enumeration over the same filtered groups; the device mask is a
    rescored superset). Bit-exactness is a *same-backend* contract across
    routes — asserted by the sharded/streaming scripts."""
    flt = where(("price", "<", 100.0 * sel))
    for tier in ("exact", "approx"):
        a = engine.query_batch(queries, k=2, tier=tier, backend="numpy",
                               filter=flt)
        b = engine.query_batch(queries, k=2, tier=tier, backend="pallas",
                               filter=flt)
        for q, x, y in zip(queries, a, b):
            assert [c.ids for c in x.candidates] == \
                [c.ids for c in y.candidates], (tier, q)
            np.testing.assert_allclose(
                [c.diameter for c in x.candidates],
                [c.diameter for c in y.candidates], rtol=1e-9,
                err_msg=f"{tier}/{q}")


def test_single_query_path_matches_batch(engine, corpus, queries):
    flt = where(("price", "between", (20.0, 70.0)),
                ("category", "in", [0, 1, 2, 3, 4]))
    for tier in ("exact", "approx"):
        batch = engine.query_batch(queries[:4], k=2, tier=tier, filter=flt)
        for q, want in zip(queries[:4], batch):
            got = engine.query(q, k=2, tier=tier, filter=flt)
            assert [(c.ids, c.diameter) for c in got.candidates] == \
                [(c.ids, c.diameter) for c in want.candidates]


def test_device_tier_respects_filter(engine, corpus, queries):
    flt = where(("price", "<", 40.0))
    eligible = flt.evaluate(corpus)
    res = engine.query_batch(queries[:3], k=2, tier="device", filter=flt)
    for r in res:
        for c in r.candidates:
            assert all(eligible[i] for i in c.ids)
    assert engine.last_batch_stats.eligible_points == int(eligible.sum())
    # 0% selectivity: the dispatch is skipped, results empty
    zero = engine.query_batch(queries[:2], k=1, tier="device",
                              filter=where(("price", "<", -1.0)))
    assert all(r.candidates == [] for r in zero)


# --------------------------------------------------------------- device fold
def test_eligibility_fold_no_new_d2h():
    """The acceptance criterion's transfer contract, at the backend: folding
    eligibility changes zero D2H bytes (the mask rides the existing packed
    layout), adds only the packed eligibility words H2D, and the folded mask
    equals the host-side AND of the unfiltered mask."""
    rng = np.random.default_rng(0)
    points = rng.standard_normal((500, 10))
    sizes = [40, 37, 20, 9, 64]
    id_lists = [np.sort(rng.choice(500, n, replace=False)).astype(np.int64)
                for n in sizes]
    radii = [2.5, 3.0, 2.0, float("inf"), 2.8]
    keys = [ids.tobytes() for ids in id_lists]
    eligible = rng.random(500) < 0.4

    # route="device": this test asserts the *device* fold's transfer
    # contract; auto cost-model routing may legitimately send thin bins to
    # the host path, which has no D2H at all.
    be = PallasBackend(route="device")
    plain = be.self_join_blocks(points, id_lists, radii, keys=keys)
    h2d0, d2h0 = be.stats.h2d_bytes, be.stats.d2h_bytes
    assert d2h0 > 0
    filt = be.self_join_blocks(points, id_lists, radii, keys=keys,
                               eligible=eligible)
    h2d1 = be.stats.h2d_bytes - h2d0
    d2h1 = be.stats.d2h_bytes - d2h0
    assert d2h1 == d2h0, "eligibility fold added D2H traffic"
    # tiles were cached from the unfiltered call: the filtered repeat ships
    # only radii + eligibility words
    assert 0 < h2d1 < h2d0
    assert be.stats.cache_hits > 0

    for i, (p, f) in enumerate(zip(plain, filt)):
        el = eligible[id_lists[i]]
        assert f.n_eligible == int(el.sum())
        if p.mask is None:               # r=inf device skip on both routes
            assert f.mask is None
            assert f.join_count == f.n_eligible ** 2
            continue
        adj = unpack_join_mask(p.mask, p.n).astype(bool)
        ref = adj & el[:, None] & el[None, :]
        np.testing.assert_array_equal(
            unpack_join_mask(f.mask, f.n).astype(bool), ref,
            err_msg=f"subset {i}")
        assert f.join_count == int(ref.sum())


def test_numpy_backend_eligible_counts():
    rng = np.random.default_rng(1)
    points = rng.standard_normal((60, 4))
    ids = np.arange(30, dtype=np.int64)
    eligible = np.zeros(60, dtype=bool)
    eligible[::3] = True
    be = NumpyBackend()
    (block,) = be.self_join_blocks(points, [ids], [2.0], eligible=eligible)
    el = eligible[ids]
    dist = np.sqrt(((points[ids][:, None] - points[ids][None, :]) ** 2
                    ).sum(-1))
    want = int(((dist <= 2.0) & el[:, None] & el[None, :]).sum())
    assert block.join_count == want
    assert block.n_eligible == int(el.sum())


# ----------------------------------------------------------------- streaming
def _streaming_rig(seed=0):
    base = attach_attrs(synthetic_dataset(n=260, d=6, u=12, t=2, seed=seed),
                        seed=seed + 1)
    pool = synthetic_dataset(n=120, d=6, u=12, t=2, seed=seed + 2)
    pattrs = synthetic_attrs(120, seed=seed + 3)
    return base, pool, pattrs


def _equivalent_static(base, pool, pattrs, inserted, deleted):
    pts = np.concatenate([base.points, pool.points[:inserted]])
    kws = [base.kw.row(i).tolist() for i in range(base.n)] + \
        [pool.kw.row(i).tolist() for i in range(inserted)]
    attrs = {k: np.concatenate([base.attrs[k], pattrs[k][:inserted]])
             for k in base.attrs}
    live = np.ones(base.n + inserted, dtype=bool)
    live[list(deleted)] = False
    keep = np.flatnonzero(live)
    ds = make_dataset(pts[keep], [kws[int(i)] for i in keep],
                      n_keywords=base.n_keywords,
                      attrs={k: v[keep] for k, v in attrs.items()})
    return ds, keep


def test_streaming_filtered_parity_interleaved():
    """Filtered queries under insert/delete/compact interleavings answer
    identically (same ids via the external-id map, same diameters) to a
    fresh engine over the equivalent static corpus."""
    base, pool, pattrs = _streaming_rig(seed=21)
    pinned_probe = NKSEngine(base, m=2, n_scales=5, seed=0,
                             build_approx=False)
    pinned = dict(m=2, n_scales=5, seed=0, w0=pinned_probe.index_e.w0,
                  n_buckets=pinned_probe.index_e.structures[0].n_buckets)
    eng = NKSEngine(base, auto_compact=False, **pinned)
    queries = random_queries(base, 2, 6, seed=9)
    flt = where(("price", "<", 55.0))
    inserted, deleted = 0, set()

    def check(tag):
        ds, keep = _equivalent_static(base, pool, pattrs, inserted, deleted)
        fresh = NKSEngine(ds, **pinned)
        for tier in ("exact", "approx"):
            got = eng.query_batch(queries, k=2, tier=tier, backend="numpy",
                                  filter=flt)
            want = fresh.query_batch(queries, k=2, tier=tier,
                                     backend="numpy", filter=flt)
            for q, a, b in zip(queries, got, want):
                ext = [tuple(int(keep[j]) for j in c.ids) for c in b.candidates]
                assert [c.ids for c in a.candidates] == ext, (tag, tier, q)
                np.testing.assert_allclose(
                    [c.diameter for c in a.candidates],
                    [c.diameter for c in b.candidates], rtol=1e-9,
                    err_msg=f"{tag}/{tier}/{q}")

    def ingest(lo, hi):
        nonlocal inserted
        eng.insert(pool.points[lo:hi],
                   [pool.kw.row(i).tolist() for i in range(lo, hi)],
                   attrs={k: v[lo:hi] for k, v in pattrs.items()})
        inserted = hi

    check("static")
    ingest(0, 40)
    check("insert")
    eng.delete([3, 17, 270])
    deleted |= {3, 17, 270}
    check("delete")
    assert eng.compact()
    check("compact")
    ingest(40, 80)
    eng.delete([8, 300])
    deleted |= {8, 300}
    check("post-compact churn")


def test_streaming_attr_schema_validation():
    base, pool, pattrs = _streaming_rig(seed=5)
    eng = NKSEngine(base, m=2, n_scales=3, seed=0, build_approx=False,
                    auto_compact=False)
    pts = pool.points[:4]
    kws = [pool.kw.row(i).tolist() for i in range(4)]
    with pytest.raises(ValueError, match="schema"):
        eng.insert(pts, kws)                      # missing attrs
    with pytest.raises(ValueError, match="schema"):
        eng.insert(pts, kws, attrs={"price": pattrs["price"][:4]})
    with pytest.raises(ValueError, match="must be"):
        eng.insert(pts, kws, attrs={"price": pattrs["price"][:3],
                                    "category": pattrs["category"][:4]})
    assert eng.delta_points == 0, "rejected batches must not mutate"
    # tenant on a tenant-less corpus
    with pytest.raises(ValueError, match="tenant"):
        eng.insert(pts, kws, attrs={k: v[:4] for k, v in pattrs.items()},
                   tenant="acme")
    # attrs survive compaction
    eng.insert(pts, kws, attrs={k: v[:4] for k, v in pattrs.items()})
    assert eng.compact()
    assert eng.dataset.attrs["price"].shape == (base.n + 4,)
    np.testing.assert_allclose(eng.dataset.attrs["price"][-4:],
                               pattrs["price"][:4])


# -------------------------------------------------------------- multi-tenant
def test_tenant_scoping_matches_oracle_and_isolates():
    mt = synthetic_tenants({"acme": 140, "globex": 160}, d=6, u=10, t=2,
                           seed=5)
    eng = NKSEngine(mt, m=2, n_scales=5, seed=0)
    ns = mt.tenants
    for tname in ("acme", "globex"):
        tid = ns.id_of(tname)
        for q in ([0, 3], [1, 2, 4]):
            flt = Filter(tenant=tname)
            for tier, backend in (("exact", "numpy"), ("exact", "pallas"),
                                  ("approx", "numpy")):
                r = eng.query_batch([q], k=2, tier=tier, backend=backend,
                                    filter=flt)[0]
                for c in r.candidates:
                    assert all(mt.tenant_of[i] == tid for i in c.ids), \
                        f"tenant isolation violated: {tname} got {c.ids}"
            r = eng.query_batch([q], k=2, tier="exact", backend="numpy",
                                filter=flt)[0]
            truth = brute_force.search_filtered(mt, q, flt, k=2)
            assert_same_ranking(r.candidates, truth.items,
                                f"tenant={tname} q={q}")


def test_tenant_namespace_resolution_and_validation():
    mt = synthetic_tenants({"acme": 60, "globex": 60}, d=4, u=6, t=2, seed=2)
    eng = NKSEngine(mt, m=2, n_scales=3, seed=0, build_approx=False)
    ns = mt.tenants
    # local ids resolve into the tenant's global slot range
    assert ns.resolve("globex", [0, 5]) == [6, 11]
    with pytest.raises(ValueError, match="outside tenant"):
        ns.resolve("acme", [6])
    with pytest.raises(KeyError, match="unknown tenant"):
        eng.query_batch([[0]], tier="exact", filter=Filter(tenant="nobody"))
    # a tenant-scoped query cannot escape its dictionary even with ids that
    # are valid globally
    with pytest.raises(ValueError, match="outside tenant"):
        eng.query_batch([[7]], tier="exact", filter=Filter(tenant="acme"))
    # tenant scoping combines with attribute clauses
    flt = where(("price", "<", 70.0), tenant="acme")
    r = eng.query_batch([[0, 1]], k=1, tier="exact", filter=flt)[0]
    elig = flt.evaluate(mt)
    for c in r.candidates:
        assert all(elig[i] for i in c.ids)


def test_tenant_streaming_insert_and_query():
    mt = synthetic_tenants({"acme": 80, "globex": 80}, d=4, u=6, t=2, seed=3)
    eng = NKSEngine(mt, m=2, n_scales=4, seed=0, auto_compact=False)
    rng = np.random.default_rng(0)
    pts = rng.uniform(0, 10_000, (5, 4)).astype(np.float32)
    kws = [mt.tenants.resolve("acme", [i % 6]) for i in range(5)]
    attrs = {"price": np.full(5, 1.0), "category": np.zeros(5, np.int64)}
    eng.insert(pts, kws, attrs=attrs, tenant="acme")
    r = eng.query_batch([[0]], k=3, tier="exact", backend="numpy",
                        filter=Filter(tenant="acme"))[0]
    tid = mt.tenants.id_of("acme")
    merged_tids = eng.dataset.tenant_ids
    for c in r.candidates:
        assert all(merged_tids[i] == tid for i in c.ids)
    # inserting without a tenant on a multi-tenant corpus is rejected
    with pytest.raises(ValueError, match="tenant"):
        eng.insert(pts, kws, attrs=attrs)


# ----------------------------------------------------------- filter grammar
def test_filter_grammar_and_json_roundtrip():
    flt = where(("price", "<", 50.0), ("category", "in", [2, 1, 2]),
                ("price", ">=", 5.0), tenant="acme")
    spec = flt.as_json()
    back = Filter.from_json(json.loads(json.dumps(spec)))
    assert back == flt
    assert Filter.coerce(None) is None
    assert Filter.coerce(Filter()) is None          # empty filter == None
    assert Filter.coerce({"where": [["price", "<", 1]]})

    with pytest.raises(ValueError, match="unknown predicate op"):
        Clause("price", "~", 3)
    with pytest.raises(ValueError, match="value list"):
        Clause("price", "in", 3)
    with pytest.raises(ValueError, match="lo, hi"):
        Clause("price", "between", [1])
    with pytest.raises(ValueError, match="unknown filter keys"):
        Filter.from_json({"tenant": "a", "wher": []})


def test_filter_evaluate_errors(corpus):
    with pytest.raises(KeyError, match="unknown attribute"):
        where(("nope", "<", 1)).evaluate(corpus)
    strcorp = make_dataset(
        np.zeros((4, 2), np.float32), [[0]] * 4, n_keywords=1,
        attrs={"label": np.array(["a", "b", "a", "c"])})
    with pytest.raises(ValueError, match="non-numeric"):
        where(("label", "<", "b")).evaluate(strcorp)
    # equality / set ops on string columns are fine
    np.testing.assert_array_equal(
        where(("label", "==", "a")).evaluate(strcorp), [1, 0, 1, 0])
    np.testing.assert_array_equal(
        where(("label", "in", ["b", "c"])).evaluate(strcorp), [0, 1, 0, 1])
    with pytest.raises(ValueError, match="no tenant column"):
        Filter(tenant="acme").evaluate(corpus)
    bare = synthetic_dataset(n=10, d=2, u=3, t=1, seed=0)
    with pytest.raises(KeyError, match="unknown attribute"):
        where(("price", "<", 1)).evaluate(bare)


def test_filter_evaluate_ops(corpus):
    price = corpus.attrs["price"]
    cat = corpus.attrs["category"]
    cases = [
        (where(("price", "<", 30.0)), price < 30.0),
        (where(("price", ">=", 30.0)), price >= 30.0),
        (where(("category", "==", 3)), cat == 3),
        (where(("category", "!=", 3)), cat != 3),
        (where(("category", "in", [1, 4])), np.isin(cat, [1, 4])),
        (where(("price", "between", (10.0, 20.0))),
         (price >= 10.0) & (price <= 20.0)),
        (where(("price", "<", 50.0), ("category", "==", 0)),
         (price < 50.0) & (cat == 0)),
    ]
    for flt, want in cases:
        np.testing.assert_array_equal(flt.evaluate(corpus), want, err_msg=str(flt))


# ------------------------------------------------------------------ serving
def test_serve_filter_requests(tmp_path):
    from repro.launch.serve import handle_request
    ds = attach_attrs(synthetic_dataset(n=120, d=4, u=8, t=2, seed=1), seed=2)
    eng = NKSEngine(ds, m=2, n_scales=3, seed=0, build_exact=False)
    q = random_queries(ds, 2, 1, seed=0)[0]
    out = handle_request(
        eng, {"keywords": q, "k": 2,
              "filter": {"where": [["price", "<", 60.0]]}},
        tier="approx", k=1)
    assert out["filter"] == {"where": [["price", "<", 60.0]]}
    elig = ds.attrs["price"] < 60.0
    for res in out["results"]:
        assert all(elig[i] for i in res["ids"])
    ins = handle_request(
        eng, {"op": "insert", "points": ds.points[:2].tolist(),
              "keywords": [[0], [1]],
              "attrs": {"price": [1.0, 2.0], "category": [0, 1]}},
        tier="approx", k=1)
    assert len(ins["ids"]) == 2 and ins["delta_points"] == 2
    out2 = handle_request(
        eng, {"keywords": [0], "k": 1,
              "filter": {"where": [["price", "<", 1.5]]}},
        tier="approx", k=1)
    assert out2["results"], "freshly inserted eligible point not found"
    assert out2["results"][0]["ids"] == [int(ins["ids"][0])]


def test_serve_tenant_insert_roundtrip():
    """The serving layer speaks tenant-LOCAL keyword ids on BOTH sides:
    a tenant's insert must be reachable by that tenant's own queries (the
    launcher resolves insert keywords through the namespace exactly as the
    engine resolves query keywords)."""
    from repro.launch.serve import handle_request
    mt = synthetic_tenants({"acme": 60, "globex": 60}, d=4, u=6, t=2, seed=4)
    eng = NKSEngine(mt, m=2, n_scales=3, seed=0, build_exact=False)
    pt = np.full((1, 4), 7.0, np.float32).tolist()
    # price -5 makes the new point the ONLY one matching price < 0, so the
    # roundtrip query below has exactly one feasible answer
    ins = handle_request(
        eng, {"op": "insert", "points": pt, "keywords": [[3]],
              "tenant": "globex",
              "attrs": {"price": [-5.0], "category": [0]}},
        tier="approx", k=1)
    new_id = int(ins["ids"][0])
    # globex finds its point under its local id 3...
    got = handle_request(
        eng, {"keywords": [3], "k": 3,
              "filter": {"tenant": "globex", "where": [["price", "<", 0]]}},
        tier="approx", k=1)
    assert [res["ids"] for res in got["results"]] == [[new_id]], got
    # ...and acme (whose namespace also contains a local id 3) cannot see
    # it: had the insert skipped namespace resolution, global slot 3 would
    # lie in acme's namespace and this query would return the point
    other = handle_request(
        eng, {"keywords": [3], "k": 3,
              "filter": {"tenant": "acme", "where": [["price", "<", 0]]}},
        tier="approx", k=1)
    assert other["results"] == [], other
    # per-point tenant lists resolve row by row
    ins2 = handle_request(
        eng, {"op": "insert", "points": pt + pt, "keywords": [[2], [2]],
              "tenant": ["acme", "globex"],
              "attrs": {"price": [1.0, 1.0], "category": [0, 0]}},
        tier="approx", k=1)
    tids = eng.dataset.tenant_ids
    ns = mt.tenants
    internal = [np.flatnonzero(eng._ext_of == e)[0] for e in ins2["ids"]]
    assert tids[internal[0]] == ns.id_of("acme")
    assert tids[internal[1]] == ns.id_of("globex")
