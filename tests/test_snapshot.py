"""§IX disk extension, WAL-era: snapshot save/load round-trip; mmap'd
queries == in-memory; corrupted leaves are detected.

Replaces the seed-era ``tests/test_disk.py`` — the ``core/disk.py`` layout
it exercised was folded into ``serve/wal.py``'s snapshot layer (same one
``.npy`` per flat leaf + sha256 manifest idea, extended with attrs/tenant
columns and engine counters)."""
import json
import os

import numpy as np
import pytest

from repro.core import brute_force, promish_e
from repro.core.index import build_index
from repro.data.synthetic import attach_attrs, random_queries, synthetic_dataset
from repro.serve import wal as walmod


def _roundtrip(tmp_path, ds, idx, **load_kw):
    snap = str(tmp_path / "snap")
    walmod.save_snapshot(snap, dataset=ds, index_e=idx, index_a=None,
                         build_params={"m": 2}, engine_meta={"next_ext": ds.n})
    return walmod.load_snapshot(snap, **load_kw)


def test_snapshot_roundtrip_query_equivalence(tmp_path):
    ds = synthetic_dataset(n=400, d=8, u=20, t=2, seed=3)
    idx = build_index(ds, m=2, n_scales=4, exact=True, seed=1)
    out = _roundtrip(tmp_path, ds, idx, mmap=True)
    ds2, idx2 = out["dataset"], out["index_e"]

    assert out["index_a"] is None
    assert out["build_params"] == {"m": 2}
    assert out["engine"]["next_ext"] == ds.n
    assert ds2.n == ds.n and ds2.dim == ds.dim
    np.testing.assert_array_equal(np.asarray(ds2.points), ds.points)
    for query in random_queries(ds, 3, 4, seed=7):
        mem = promish_e.search(ds, idx, query, k=2)
        dsk = promish_e.search(ds2, idx2, query, k=2)
        truth = brute_force.search(ds, query, k=2)
        np.testing.assert_allclose([c.diameter for c in dsk.items],
                                   [c.diameter for c in mem.items], rtol=1e-6)
        np.testing.assert_allclose([c.diameter for c in dsk.items],
                                   [c.diameter for c in truth.items], rtol=1e-4)


def test_snapshot_is_mmapped(tmp_path):
    ds = synthetic_dataset(n=100, d=4, u=10, t=1, seed=0)
    idx = build_index(ds, m=2, n_scales=3, exact=False, seed=0)
    snap = str(tmp_path / "snap")
    walmod.save_snapshot(snap, dataset=ds, index_e=None, index_a=idx,
                         build_params={}, engine_meta={})
    out = walmod.load_snapshot(snap, mmap=True)
    assert isinstance(out["dataset"].points, np.memmap)
    assert isinstance(out["index_a"].structures[0].table.values, np.memmap)


def test_snapshot_preserves_attrs_and_tenants(tmp_path):
    from repro.data.synthetic import synthetic_tenants
    ds = attach_attrs(synthetic_tenants({"a": 60, "b": 40}, d=4, u=12, t=2,
                                        seed=5), seed=5)
    idx = build_index(ds, m=2, n_scales=3, exact=True, seed=1)
    out = _roundtrip(tmp_path, ds, idx)
    ds2 = out["dataset"]
    assert set(ds2.attrs) == set(ds.attrs)
    for name in ds.attrs:
        np.testing.assert_array_equal(np.asarray(ds2.attrs[name]),
                                      np.asarray(ds.attrs[name]))
    np.testing.assert_array_equal(np.asarray(ds2.tenant_of), ds.tenant_of)
    assert ds2.tenants.names == ds.tenants.names
    np.testing.assert_array_equal(np.asarray(ds2.tenants.kw_offsets),
                                  ds.tenants.kw_offsets)


def test_snapshot_detects_corruption(tmp_path):
    ds = synthetic_dataset(n=80, d=4, u=10, t=1, seed=2)
    idx = build_index(ds, m=2, n_scales=3, exact=True, seed=0)
    snap = str(tmp_path / "snap")
    walmod.save_snapshot(snap, dataset=ds, index_e=idx, index_a=None,
                         build_params={}, engine_meta={})
    # Flip bytes in one leaf: sha256 verification must refuse the load.
    with open(os.path.join(snap, "meta.json")) as f:
        leaf = sorted(json.load(f)["leaves"])[0]
    path = os.path.join(snap, leaf + ".npy")
    blob = bytearray(open(path, "rb").read())
    blob[-8] ^= 0xFF
    open(path, "wb").write(bytes(blob))
    with pytest.raises(IOError):
        walmod.load_snapshot(snap, verify=True)


def test_snapshot_write_is_atomic(tmp_path):
    """A snapshot over an existing directory either fully replaces it or
    leaves the old one intact — no half states (write-tmp + rename)."""
    ds = synthetic_dataset(n=60, d=4, u=10, t=1, seed=1)
    idx = build_index(ds, m=2, n_scales=3, exact=True, seed=0)
    snap = str(tmp_path / "snap")
    walmod.save_snapshot(snap, dataset=ds, index_e=idx, index_a=None,
                         build_params={"gen": 1}, engine_meta={})
    walmod.save_snapshot(snap, dataset=ds, index_e=idx, index_a=None,
                         build_params={"gen": 2}, engine_meta={})
    assert walmod.load_snapshot(snap)["build_params"] == {"gen": 2}
    leftovers = [d for d in os.listdir(tmp_path)
                 if d.startswith(".tmp-snap-")]
    assert leftovers == []
