"""Streaming-ingest parity on a forced 8-device mesh, run in a subprocess
(tests/test_streaming.py drives it; same pattern as sharded_script.py).
Asserts the acceptance criterion's multi-device half:

  1. a streaming engine whose pallas dispatches shard over the plane answers
     every interleaving of inserts/deletes/compactions bit-identically to a
     fresh mesh-attached engine on the equivalent static corpus (exact and
     approx tiers);
  2. the sharded streaming engine matches the single-device streaming engine
     bit-exactly (delta points ride the same size-binned dispatches);
  3. generation-tagged caches behave identically under sharding: absorbs
     retain the packed-tile LRU, compaction purges it once;
  4. filtered queries (ISSUE 5) hold the same parity at every step: the
     streaming sharded engine, the streaming single-device engine, and a
     fresh mesh engine over the equivalent static corpus answer filtered
     batches bit-identically — delta points carry attributes through
     absorb/compact.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np

from repro.core.backend import PallasBackend
from repro.core.device_plane import DevicePlane
from repro.core.filters import where
from repro.core.index import build_index
from repro.core.types import make_dataset
from repro.data.synthetic import (attach_attrs, random_queries,
                                  synthetic_attrs, synthetic_dataset)
from repro.launch.mesh import make_serving_mesh
from repro.serve.engine import NKSEngine

PLANE = DevicePlane(make_serving_mesh(data=8))
U = 18
FILTER = where(("price", "<", 55.0))


def cands(results):
    return [[(c.ids, c.diameter) for c in r.candidates] for r in results]


def main():
    base = attach_attrs(synthetic_dataset(n=320, d=6, u=U, t=2, seed=7),
                        seed=2)
    pool = synthetic_dataset(n=120, d=6, u=U, t=2, seed=8)
    pool_attrs = synthetic_attrs(120, seed=3)
    probe = build_index(base, m=2, n_scales=5, exact=True, seed=0)
    pinned = dict(m=2, n_scales=5, seed=0, w0=probe.w0,
                  n_buckets=probe.structures[0].n_buckets)
    queries = random_queries(base, 2, 8, seed=3) + \
        random_queries(base, 3, 8, seed=4)

    eng_mesh = NKSEngine(base, mesh=PLANE, auto_compact=False, **pinned)
    eng_one = NKSEngine(base, auto_compact=False, **pinned)
    pts = [base.points[i] for i in range(base.n)]
    kws = [base.kw.row(i).tolist() for i in range(base.n)]
    attrs = {k: list(base.attrs[k]) for k in base.attrs}
    alive = dict.fromkeys(range(base.n), True)

    # route="device": the suite asserts sharded-dispatch accounting, and on
    # a host-platform mesh the cost model (rightly) routes every bin to the
    # exact host path, which never touches the plane.
    be_mesh = PallasBackend(interpret=True, plane=PLANE, route="device")
    be_one = PallasBackend(interpret=True, route="device")

    def check(tag):
        ids = np.asarray(sorted(i for i, a in alive.items() if a))
        ds = make_dataset(np.stack([pts[i] for i in ids]),
                          [kws[i] for i in ids], n_keywords=U,
                          attrs={k: np.asarray([attrs[k][i] for i in ids])
                                 for k in attrs})
        fresh = NKSEngine(ds, mesh=PLANE, **pinned)
        for tier in ("exact", "approx"):
            for flt in (None, FILTER):
                got = eng_mesh.query_batch(queries, k=2, tier=tier,
                                           backend=be_mesh, filter=flt)
                one = eng_one.query_batch(queries, k=2, tier=tier,
                                          backend=be_one, filter=flt)
                want = fresh.query_batch(queries, k=2, tier=tier,
                                         backend=PallasBackend(interpret=True,
                                                               plane=PLANE,
                                                               route="device"),
                                         filter=flt)
                want_ext = [[(tuple(int(ids[i]) for i in c.ids), c.diameter)
                             for c in r.candidates] for r in want]
                fl = "filtered" if flt else "plain"
                assert cands(got) == want_ext, \
                    f"{tag}/{tier}/{fl}: sharded != fresh"
                assert cands(got) == cands(one), \
                    f"{tag}/{tier}/{fl}: sharded != 1-dev"
        print(f"  {tag}: parity ok incl filtered (cumulative sharded "
              f"dispatches={be_mesh.stats.sharded_dispatches})")

    def ingest(lo, hi):
        chunk = pool.points[lo:hi]
        ck = [pool.kw.row(i).tolist() for i in range(lo, hi)]
        ca = {k: v[lo:hi] for k, v in pool_attrs.items()}
        eng_mesh.insert(chunk, ck, attrs=ca)
        eng_one.insert(chunk, ck, attrs=ca)
        for j in range(lo, hi):
            alive[len(pts)] = True
            pts.append(pool.points[j])
            kws.append(pool.kw.row(j).tolist())
            for k in attrs:
                attrs[k].append(pool_attrs[k][j])

    def delete(doomed):
        eng_mesh.delete(doomed)
        eng_one.delete(doomed)
        for i in doomed:
            alive[int(i)] = False

    check("static")
    ingest(0, 50)
    check("insert")
    delete([4, 17, 325, 350])
    check("delete")

    # generation-tagged caches under sharding: absorb retains, compact purges
    h0 = be_mesh.stats.cache_hits
    eng_mesh.query_batch(queries, k=2, tier="exact", backend=be_mesh)
    assert be_mesh.stats.cache_hits > h0, "warm LRU expected after absorb"
    assert be_mesh.stats.generation_purges == 0

    assert eng_mesh.compact() and eng_one.compact()
    assert eng_mesh.corpus_generation == 1
    check("compact")
    assert be_mesh.stats.generation_purges == 1, "compaction must purge once"

    ingest(50, 90)
    delete([2, 9, 380])
    check("post-compact churn")
    assert eng_mesh.compact() and eng_one.compact()
    check("final")
    assert be_mesh.stats.sharded_dispatches > 0, \
        "streaming batches never took the sharded route"
    print("ALL STREAMING SHARDED OK")


if __name__ == "__main__":
    main()
