"""Bitmask-join enumeration: packed-mask helpers, vectorized frontier vs the
pruned recursion, greedy ordering edge cases, the empty-join short-circuit,
and the PallasBackend packed-subset LRU."""
import numpy as np
import pytest

from repro.core import subset_search as ss
from repro.core.backend import DistanceBlock, NumpyBackend, PallasBackend
from repro.core.types import TopK
from repro.data.synthetic import random_queries, synthetic_dataset


@pytest.fixture(scope="module")
def ds():
    return synthetic_dataset(n=250, d=6, u=14, t=2, seed=5)


# ------------------------------------------------------------- mask helpers
def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(0)
    for n, m in [(1, 1), (7, 31), (40, 32), (13, 100)]:
        adj = rng.random((n, m)) < 0.4
        words = ss.pack_join_mask(adj)
        assert words.shape == (n, max((m + 31) // 32, 1))
        np.testing.assert_array_equal(
            ss.unpack_join_mask(words, m).astype(bool), adj)


def test_pair_counts_matches_bruteforce():
    rng = np.random.default_rng(2)
    adj = (rng.random((30, 30)) < 0.3)
    adj = (adj | adj.T).astype(np.uint8)     # join adjacency is symmetric
    groups = [np.array([0, 3, 7]), np.array([1, 2]), np.array([5, 7, 9, 11])]
    m = ss.pair_counts(adj, groups)
    for i in range(3):
        for j in range(3):
            if i == j:
                assert m[i, j] == 0
            else:
                want = sum(int(adj[a, b]) for a in groups[i] for b in groups[j])
                assert m[i, j] == want


# ------------------------------------------------------- frontier expansion
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_frontier_matches_recursion(ds, seed):
    """The vectorized frontier and the pruned recursion produce identical
    top-k queues (diameters and id sets) on random subsets."""
    rng = np.random.default_rng(seed)
    query = list(random_queries(ds, 3, 1, seed=seed)[0])
    f_ids = np.unique(rng.integers(0, ds.n, size=60))
    gl = ss.local_groups(f_ids, query, ds)
    if gl is None:
        pytest.skip("subset misses a keyword")
    pts = ds.points[f_ids]
    dist = ss.pairwise_l2_numpy(pts, pts)
    pq_f, pq_r = TopK(3), TopK(3)
    ss.enumerate_with_distances(f_ids, gl, query, ds, pq_f, dist)
    ss.enumerate_with_distances(f_ids, gl, query, ds, pq_r, dist,
                                frontier_limit=0)     # force recursion
    assert len(pq_f.items) > 0
    assert [c.ids for c in pq_f.items] == [c.ids for c in pq_r.items]
    np.testing.assert_allclose([c.diameter for c in pq_f.items],
                               [c.diameter for c in pq_r.items], rtol=1e-12)


def test_mask_block_matches_dense_block(ds):
    """enumerate_with_block over a device-style packed mask == over the dense
    float64 block (the bitmask-join parity contract), including a pad word."""
    query = list(random_queries(ds, 2, 1, seed=7)[0])
    rng = np.random.default_rng(7)
    f_ids = np.unique(rng.integers(0, ds.n, size=40))   # > 32 -> 2 mask words
    gl = ss.local_groups(f_ids, query, ds)
    if gl is None:
        pytest.skip("subset misses a keyword")
    pts = ds.points[f_ids]
    dist = ss.pairwise_l2_numpy(pts, pts)
    n = len(f_ids)
    r = float(np.median(dist))
    dense = DistanceBlock(n=n, slack=0.0, rescore=False, join_count=n * n,
                          dist=dist)
    mask = DistanceBlock(n=n, slack=0.0, rescore=True,
                         join_count=int((dist <= r).sum()),
                         mask=ss.pack_join_mask(dist <= r))
    pq_d, pq_m = TopK(3), TopK(3)
    ss.enumerate_with_block(f_ids, gl, query, ds, pq_d, dense)
    ss.enumerate_with_block(f_ids, gl, query, ds, pq_m, mask)
    assert [c.ids for c in pq_m.items] == [c.ids for c in pq_d.items]
    np.testing.assert_allclose([c.diameter for c in pq_m.items],
                               [c.diameter for c in pq_d.items], rtol=1e-9)


def test_empty_join_short_circuit(ds):
    """join_count <= n (only diagonal pairs) must yield exactly the single
    points covering the whole query — and nothing else."""
    query = list(random_queries(ds, 2, 1, seed=9)[0])
    cov = [p for p in range(ds.n)
           if all(ds.has_keyword(p, v) for v in query)]
    if not cov:
        pytest.skip("no point covers the query")
    f_ids = np.unique(np.concatenate(
        [np.array(cov[:2]), ds.ikp.row(query[0])[:5], ds.ikp.row(query[1])[:5]]
    ).astype(np.int64))
    gl = ss.local_groups(f_ids, query, ds)
    n = len(f_ids)
    block = DistanceBlock(n=n, slack=0.0, rescore=True, join_count=n,
                          mask=ss.pack_join_mask(np.eye(n, dtype=bool)))
    pq = TopK(4)
    ss.enumerate_with_block(f_ids, gl, query, ds, pq, block)
    got = {c.ids for c in pq.items}
    want_pool = {(int(p),) for p in f_ids
                 if all(ds.has_keyword(int(p), v) for v in query)}
    assert got <= want_pool and all(c.diameter == 0.0 for c in pq.items)
    assert len(got) == min(4, len(want_pool))


# ------------------------------------------------------------ greedy order
def test_greedy_group_order_tie_breaking():
    """Equal-weight edges resolve by (i, j) index order — deterministic."""
    m = np.zeros((3, 3), dtype=np.int64)      # all edges tie at 0
    assert ss.greedy_group_order(m) == [0, 1, 2]
    m = np.array([[0, 5, 2], [5, 0, 2], [2, 2, 0]])
    # ties between (0,2) and (1,2) at weight 2: edge (0,2) wins by index
    assert ss.greedy_group_order(m) == [0, 2, 1]


def test_greedy_group_order_isolated_groups():
    """Groups with no surviving pairs still appear exactly once (Alg. 3's
    isolated-vertex sweep), and a single group is trivially [0]."""
    assert ss.greedy_group_order(np.zeros((1, 1), dtype=np.int64)) == [0]
    m = np.array([[0, 3, 0, 0], [3, 0, 0, 0], [0, 0, 0, 0], [0, 0, 0, 0]])
    order = ss.greedy_group_order(m)
    assert sorted(order) == [0, 1, 2, 3]
    assert order[:2] in ([0, 2], [0, 1])  # smallest edge first, then sweep


# ----------------------------------------------------------------- the LRU
def _subset_batch(ds, n_subsets, rng):
    ids = [np.unique(rng.integers(0, ds.n, size=12)) for _ in range(n_subsets)]
    keys = [i.tobytes() for i in ids]
    radii = [5.0] * n_subsets
    return ids, keys, radii


def test_pallas_lru_hits_and_parity(ds):
    """Second dispatch of the same subsets is served from the packed-tile
    cache (hits, no extra misses) and returns identical masks."""
    rng = np.random.default_rng(0)
    ids, keys, radii = _subset_batch(ds, 6, rng)
    be = PallasBackend(interpret=True)
    b1 = be.self_join_blocks(ds.points, ids, radii, keys=keys)
    misses1 = be.stats.cache_misses
    assert misses1 > 0 and be.stats.cache_hits == 0
    b2 = be.self_join_blocks(ds.points, ids, radii, keys=keys)
    assert be.stats.cache_misses == misses1
    assert be.stats.cache_hits > 0
    for x, y in zip(b1, b2):
        np.testing.assert_array_equal(x.mask, y.mask)
        assert x.join_count == y.join_count and x.n == y.n


def test_pallas_lru_eviction_under_tiny_budget(ds):
    """A cache too small for the working set evicts (LRU) but never changes
    results; nothing is cached above budget."""
    rng = np.random.default_rng(1)
    ids, keys, radii = _subset_batch(ds, 8, rng)
    ref = PallasBackend(interpret=True).self_join_blocks(
        ds.points, ids, radii, keys=keys)
    be = PallasBackend(interpret=True, cache_bytes=1 << 10)
    for _ in range(3):
        got = be.self_join_blocks(ds.points, ids, radii, keys=keys)
        for x, y in zip(ref, got):
            np.testing.assert_array_equal(x.mask, y.mask)
            assert x.join_count == y.join_count
    assert be.stats.cache_evictions > 0
    assert be._cache_nbytes <= be.cache_bytes


def test_pallas_uncached_without_keys(ds):
    rng = np.random.default_rng(2)
    ids, _, radii = _subset_batch(ds, 4, rng)
    be = PallasBackend(interpret=True)
    be.self_join_blocks(ds.points, ids, radii)          # keys omitted
    assert be.stats.cache_hits == 0 and be.stats.cache_misses == 0
    assert len(be._cache) == 0


def test_backends_same_top1(ds):
    """End-to-end spot check at the subset level: numpy dense blocks and
    pallas mask blocks drive enumeration to the same best candidate."""
    query = list(random_queries(ds, 2, 1, seed=13)[0])
    rng = np.random.default_rng(13)
    f_ids = np.unique(rng.integers(0, ds.n, size=80))
    gl = ss.local_groups(f_ids, query, ds)
    if gl is None:
        pytest.skip("subset misses a keyword")
    results = []
    for be in (NumpyBackend(), PallasBackend(interpret=True)):
        pq = TopK(1)
        blocks = be.self_join_blocks(ds.points, [f_ids], [np.inf],
                                     keys=[f_ids.tobytes()])
        ss.enumerate_with_block(f_ids, gl, query, ds, pq, blocks[0])
        results.append(pq.items)
    assert [c.ids for c in results[0]] == [c.ids for c in results[1]]
    np.testing.assert_allclose([c.diameter for c in results[0]],
                               [c.diameter for c in results[1]], rtol=1e-9)
