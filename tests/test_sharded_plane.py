"""Drives tests/sharded_script.py in a subprocess with 8 forced host devices
(same pattern as test_multidevice.py: the device count is locked at first jax
init, so in-process forcing is unsafe). The script asserts bit-exact parity
between the shard_map'd serving plane and single-device execution."""
import os
import subprocess
import sys

import pytest


@pytest.mark.timeout(600)
def test_sharded_plane_suite():
    script = os.path.join(os.path.dirname(__file__), "sharded_script.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, script], env=env,
                          capture_output=True, text=True, timeout=580)
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "ALL SHARDED OK" in proc.stdout
