"""Unit tests for projections, signatures, CSR and index build."""
import numpy as np
import pytest

from repro.core import projection as proj
from repro.core import signatures as sig
from repro.core.index import build_index
from repro.utils.csr import CSR, csr_from_lists, csr_from_pairs, invert_csr


def test_unit_vectors_are_unit():
    rng = np.random.default_rng(0)
    z = proj.sample_unit_vectors(rng, 4, 33)
    np.testing.assert_allclose(np.linalg.norm(z, axis=1), 1.0, atol=1e-5)


def test_projection_contracts_distances():
    """Lemma 1: |z.o1 - z.o2| <= ||o1 - o2||."""
    rng = np.random.default_rng(1)
    pts = rng.standard_normal((50, 12)).astype(np.float32)
    z = proj.sample_unit_vectors(rng, 8, 12)
    p = proj.project(pts, z)
    for _ in range(200):
        i, j = rng.integers(0, 50, 2)
        lhs = np.abs(p[i] - p[j]).max()
        rhs = np.linalg.norm(pts[i] - pts[j])
        assert lhs <= rhs + 1e-4


def test_overlapping_bins_dual_keys():
    p = np.array([[0.4], [0.6], [1.1]], dtype=np.float32)
    keys = proj.bin_keys_overlapping(p, w=1.0, c=100)
    # h1 = floor(p), h2 = floor(p - 0.5) + 100
    np.testing.assert_array_equal(keys[:, 0, 0], [0, 0, 1])
    np.testing.assert_array_equal(keys[:, 0, 1], [99, 100, 100])


def test_signature_cartesian_product():
    keys2 = np.array([[[1, 2], [3, 4]]])          # one point, m=2
    sigs = sig.signatures_overlapping(keys2)
    assert sigs.shape == (1, 4, 2)
    got = {tuple(s) for s in sigs[0]}
    assert got == {(1, 3), (2, 3), (1, 4), (2, 4)}


def test_hash_range_and_determinism():
    rng = np.random.default_rng(2)
    sigs = rng.integers(-1000, 1000, size=(100, 3)).astype(np.int64)
    b1 = sig.hash_signatures(sigs, 128)
    b2 = sig.hash_signatures(sigs, 128)
    np.testing.assert_array_equal(b1, b2)
    assert b1.min() >= 0 and b1.max() < 128


def test_csr_roundtrip_and_invert():
    lists = [[3, 1], [], [2, 2, 0]]
    csr = csr_from_lists(lists)
    assert csr.n_rows == 3
    np.testing.assert_array_equal(csr.row(0), [3, 1])
    np.testing.assert_array_equal(csr.row(1), [])
    inv = invert_csr(csr, 4)
    np.testing.assert_array_equal(inv.row(2), [2, 2])
    np.testing.assert_array_equal(inv.row(3), [0])


def test_csr_from_pairs_dedup():
    rows = np.array([1, 1, 0, 1])
    vals = np.array([5, 5, 2, 7])
    csr = csr_from_pairs(rows, vals, 2, dedup=True)
    np.testing.assert_array_equal(np.sort(csr.row(1)), [5, 7])


def test_index_build_shapes(small_synth):
    idx = build_index(small_synth, m=2, n_scales=4, exact=True, seed=0)
    assert len(idx.structures) == 4
    for s, hi in enumerate(idx.structures):
        assert hi.width == pytest.approx(idx.w0 * 2 ** s)
        # every point appears in >=1 and <= 2^m buckets
        assert hi.table.nnz >= small_synth.n
        assert hi.table.nnz <= small_synth.n * 4
        # khb covers every keyword that exists
        for v in range(small_synth.n_keywords):
            if len(small_synth.ikp.row(v)):
                assert hi.khb.row_len(v) > 0


def test_index_every_point_hashed_every_scale(small_synth):
    idx = build_index(small_synth, m=2, n_scales=3, exact=True, seed=1)
    for hi in idx.structures:
        present = np.unique(hi.table.values)
        assert len(present) == small_synth.n


def test_approx_index_single_bucket_per_point(small_synth):
    idx = build_index(small_synth, m=2, n_scales=3, exact=False, seed=1)
    for hi in idx.structures:
        assert hi.table.nnz == small_synth.n


def test_num_scales_eq3():
    assert proj.num_scales(32.0, 1.0) == 5
    assert proj.num_scales(33.0, 1.0) == 6
