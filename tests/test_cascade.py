"""Raw-speed campaign (PR 6) contracts at the backend level:

  * quantile size-class edges — DP segmentation edge cases, the pow2
    padded-cell guard, and recomputation across streaming generations;
  * cost-model dispatch routing — host-routed bins carry the settlement's
    exact float64 distances (routing is invisible in engine results),
    device parity regardless of route;
  * mixed-precision prune tier — forced-on cascade is bit-identical to the
    fp32-only route, filtered included, on adversarial boundary subsets;
  * eligible-dense packing — low-selectivity filters pack eligible rows
    densely and the block row map reproduces the folded results;
  * snake shard placement — permuted tiles produce bit-identical blocks.

Everything here runs on CPU (interpret / XLA lowerings); the same contracts
run against real meshes in tests/sharded_script.py.
"""
import numpy as np
import pytest

from repro.core.backend import (DispatchCostModel, NumpyBackend,
                                PallasBackend, _dp_segment)
from repro.core.subset_search import unpack_join_mask

# A cost model with an absurdly expensive device: route="auto" must send
# every bin to the host. The platform is "cpu" so the prune tier stays off.
HOST_WINS = DispatchCostModel(platform="cpu", d=0, dev_fixed_s=10.0,
                              dev_cell_s=1.0, prune_cell_s=1.0,
                              host_fixed_s=1e-9, host_cell_s=1e-12)
# The opposite: free device, costly host — auto must keep every bin on
# device even for tiny bins.
DEV_WINS = DispatchCostModel(platform="cpu", d=0, dev_fixed_s=1e-12,
                             dev_cell_s=1e-15, prune_cell_s=1e-15,
                             host_fixed_s=10.0, host_cell_s=1.0)


def _mk(seed=0, n=400, d=6, sizes=(40, 37, 20, 9, 64, 12, 33)):
    rng = np.random.default_rng(seed)
    points = rng.standard_normal((n, d))
    id_lists = [np.sort(rng.choice(n, s, replace=False)).astype(np.int64)
                for s in sizes]
    radii = [float(r) for r in rng.uniform(1.5, 3.0, len(sizes))]
    keys = [ids.tobytes() for ids in id_lists]
    return points, id_lists, radii, keys


# ----------------------------------------------------------- DP segmentation
def test_dp_segment_all_equal_lengths():
    edges = _dp_segment(np.array([64]), np.array([10]), cap=6)
    assert list(edges) == [64]


def test_dp_segment_single_value_per_bin():
    vals = np.array([8, 64, 512])
    edges = _dp_segment(vals, np.array([1, 1, 1]), cap=6)
    # with cap >= #distinct the zero-waste segmentation keeps every value
    assert set(vals).issubset(set(edges.tolist()))


def test_dp_segment_cap_merges():
    vals = np.arange(8, 8 * 30 + 1, 8)
    counts = np.ones(len(vals), np.int64)
    edges = _dp_segment(vals, counts, cap=4)
    assert len(edges) <= 4 + 1 or len(edges) <= len(vals)
    assert edges[-1] == vals[-1]            # the max length is always covered
    cls = edges[np.searchsorted(edges, vals)]
    assert (cls >= vals).all()              # every length fits its class


def test_quantile_edges_never_worse_than_pow2():
    """The guard contract: total padded cells under the quantile edges are
    <= pow2 on any length distribution (pow2 is a feasible DP choice)."""
    be = PallasBackend(route="device")
    rng = np.random.default_rng(5)
    for trial in range(20):
        sizes = rng.integers(1, 600, size=rng.integers(1, 40))
        edges = be._quantile_edges(sizes)
        q = be.quantum
        vals = np.maximum(((np.maximum(sizes, 1) + q - 1) // q) * q,
                          be._min_class).astype(np.int64)
        cls_q = edges[np.searchsorted(edges, vals)]
        cls_p = np.array([be._class_pad(int(v)) for v in vals], np.int64)
        assert int((cls_q ** 2).sum()) <= int((cls_p ** 2).sum()), \
            f"trial {trial}: {sizes}"


def test_quantile_edges_cached_and_recomputed_across_generations():
    be = PallasBackend(route="device")
    points, id_lists, radii, keys = _mk()
    be.self_join_blocks(points, id_lists, radii, keys=keys, generation=1)
    assert be._edge_cache
    sig = next(iter(be._edge_cache))
    # same generation, same lengths: cache hit (object identity preserved)
    e0 = be._edge_cache[sig]
    be.self_join_blocks(points, id_lists, radii, keys=keys, generation=1)
    assert be._edge_cache[sig] is e0
    # a generation bump purges the edge cache with the LRU: the next batch
    # recomputes edges against the new corpus' length distribution
    be.self_join_blocks(points, id_lists, radii, keys=keys, generation=2)
    assert sig not in be._edge_cache or be._edge_cache[sig] is not e0


def test_empty_and_infinite_bins():
    """r=inf subsets never reach the binner; an empty task list returns
    empty; a single subset forms a single one-class bin."""
    be = PallasBackend(route="device")
    points, id_lists, radii, keys = _mk(sizes=(20,))
    assert be.self_join_blocks(points, [], []) == []
    blocks = be.self_join_blocks(points, id_lists, [float("inf")], keys=keys)
    assert blocks[0].mask is None and blocks[0].join_count == 20 * 20
    assert be.stats.dispatches == 0
    blocks = be.self_join_blocks(points, id_lists, radii[:1], keys=keys)
    assert be.stats.dispatches == 1 and blocks[0].mask is not None


# ------------------------------------------------------- cost-model routing
def test_forced_host_route_settlement_identical():
    """Host-routed blocks carry exactly the float64 distances the device
    route's rescore stage would have produced (sqrt of the difference-based
    squared-distance table) — the arithmetic that makes routing invisible
    in search results. NumpyBackend's norms-identity distances agree only
    to ~1e-12, which is why it is *not* the reference here."""
    from repro.core.subset_search import _sq_dists_f64
    points, id_lists, radii, keys = _mk(seed=3)
    auto = PallasBackend(cost_model=HOST_WINS)
    got = auto.self_join_blocks(points, id_lists, radii, keys=keys)
    assert auto.stats.host_routed_dispatches == auto.stats.dispatches > 0
    assert auto.stats.host_routed_subsets == len(id_lists)
    assert auto.stats.t_host_s > 0.0
    for i, (y, ids, r) in enumerate(zip(got, id_lists, radii)):
        want = np.sqrt(_sq_dists_f64(points[ids]))
        assert y.n == len(ids), f"subset {i}"
        assert y.rescore is False and y.slack == 0.0
        np.testing.assert_array_equal(y.dist, want, err_msg=f"subset {i}")
        assert y.join_count == int((want <= r).sum()), f"subset {i}"


def test_host_route_bitwise_invisible_in_engine_results():
    """End-to-end: forcing every bin to the host route yields bitwise the
    same ids and diameters as the pure device route — the cost model may
    flip routing per bin without perturbing a single result."""
    from repro.data.flickr_like import flickr_like_dataset
    from repro.data.synthetic import random_queries
    from repro.serve.engine import NKSEngine

    ds = flickr_like_dataset(n=400, d=8, u=20, t=3, n_clusters=6, seed=11)
    engine = NKSEngine(ds, m=2, n_scales=4, seed=0)
    queries = random_queries(ds, 3, 6, seed=5)
    for tier in ("exact", "approx"):
        dev = engine.query_batch(queries, k=2, tier=tier,
                                 backend=PallasBackend(route="device"))
        host = engine.query_batch(queries, k=2, tier=tier,
                                  backend=PallasBackend(cost_model=HOST_WINS))
        for a, b in zip(dev, host):
            assert [(c.ids, c.diameter) for c in a.candidates] \
                == [(c.ids, c.diameter) for c in b.candidates], tier


def test_auto_route_device_parity():
    """Whatever the cost model decides, every route honours the pruning
    contract the float64 rescore depends on: the block's adjacency contains
    every true pair at radius r, and any extra pair sits within the
    published slack of the threshold. (Exact end-to-end parity across
    routes is asserted at the engine level — the enumeration stage rescores
    both forms identically.)"""
    points, id_lists, radii, keys = _mk(seed=4)
    for model in (HOST_WINS, DEV_WINS):
        auto = PallasBackend(cost_model=model)
        got = auto.self_join_blocks(points, id_lists, radii, keys=keys)
        for i, (y, ids) in enumerate(zip(got, id_lists)):
            pts = points[ids]
            diff = pts[:, None] - pts[None, :]
            dist = np.sqrt((diff * diff).sum(-1))
            exact = dist <= radii[i]
            if y.dist is not None:           # host route: exact f64 block
                a_got = y.dist <= radii[i]
                np.testing.assert_array_equal(a_got, exact,
                                              err_msg=f"subset {i}")
            else:                            # device route: fp32 + slack
                a_got = unpack_join_mask(y.mask, y.n).astype(bool)
                assert (a_got | ~exact).all(), f"subset {i}: dropped pair"
                extra = a_got & ~exact
                if extra.any():
                    assert dist[extra].min() <= radii[i] + 2 * y.slack + 1e-6
    assert PallasBackend(cost_model=DEV_WINS).self_join_blocks(
        points, id_lists, radii, keys=keys)[0].mask is not None


def test_calibrated_cost_model_memoized():
    from repro.core.backend import calibrate_cost_model
    m1 = calibrate_cost_model(6)
    m2 = calibrate_cost_model(6)
    assert m1 is m2
    assert m1.dev_fixed_s > 0 and m1.host_cell_s > 0


# ------------------------------------------------------------- prune tier
def _boundary_corpus(seed=7, n_subsets=5, d=8, r=2.0):
    """Subsets whose pair distances straddle r at +/- a few bf16 ulps —
    the adversarial regime for the coarse tier."""
    rng = np.random.default_rng(seed)
    points = []
    id_lists = []
    for s in range(n_subsets):
        base = rng.uniform(-1, 1, d)
        base /= np.linalg.norm(base)
        anchor = rng.uniform(-r, r, d)
        rows = [anchor]
        for k in range(-6, 7, 2):
            rows.append(anchor + base * (r * (1.0 + k * 2.0 ** -9)))
        start = len(points)
        points.extend(rows)
        id_lists.append(np.arange(start, start + len(rows), dtype=np.int64))
    points = np.asarray(points)
    radii = [r] * n_subsets
    keys = [ids.tobytes() for ids in id_lists]
    return points, id_lists, radii, keys


@pytest.mark.parametrize("prune_dtype", ["bf16", "int8"])
def test_prune_tier_forced_on_bit_identical(prune_dtype):
    points, id_lists, radii, keys = _boundary_corpus()
    off = PallasBackend(route="device", prune_tier="off")
    on = PallasBackend(route="device", prune_tier="on",
                       prune_dtype=prune_dtype)
    want = off.self_join_blocks(points, id_lists, radii, keys=keys)
    got = on.self_join_blocks(points, id_lists, radii, keys=keys)
    assert on.stats.prune_tier_dispatches > 0
    assert on.stats.t_prune_s > 0.0
    for i, (y, x) in enumerate(zip(got, want)):
        assert y.n == x.n and y.slack == x.slack, f"subset {i}"
        if y.mask is None:
            # pruned: the fp32 join must have been provably empty — the
            # coarse count is at or below the live diagonal, and so is the
            # fp32 count the off-route measured.
            n_live = y.n if y.n_eligible is None else y.n_eligible
            assert y.join_count <= n_live, f"subset {i}"
            assert x.join_count <= n_live, f"subset {i}"
        else:
            np.testing.assert_array_equal(y.mask, x.mask,
                                          err_msg=f"subset {i}")
            assert y.join_count == x.join_count, f"subset {i}"


def test_prune_tier_forced_on_filtered_parity():
    points, id_lists, radii, keys = _boundary_corpus(seed=9)
    rng = np.random.default_rng(1)
    eligible = rng.random(len(points)) < 0.6
    off = PallasBackend(route="device", prune_tier="off")
    on = PallasBackend(route="device", prune_tier="on")
    want = off.self_join_blocks(points, id_lists, radii, keys=keys,
                                eligible=eligible)
    got = on.self_join_blocks(points, id_lists, radii, keys=keys,
                              eligible=eligible)
    for i, (y, x) in enumerate(zip(got, want)):
        assert y.n_eligible == x.n_eligible, f"subset {i}"
        if y.mask is not None:
            np.testing.assert_array_equal(y.mask, x.mask,
                                          err_msg=f"subset {i}")
            assert y.join_count == x.join_count
        else:
            assert y.join_count <= (y.n_eligible
                                    if y.n_eligible is not None else y.n)


def test_prune_auto_off_on_cpu():
    """route-independent: prune_tier="auto" resolves to off on non-TPU
    backends without triggering a calibration."""
    be = PallasBackend(route="device")
    points, id_lists, radii, keys = _mk(seed=11)
    be.self_join_blocks(points, id_lists, radii, keys=keys)
    assert be.stats.prune_tier_dispatches == 0
    assert be._model is None                # no calibration was forced


# ------------------------------------------------- eligible-dense packing
def test_eligible_dense_pack_low_selectivity_parity():
    points, id_lists, radii, keys = _mk(seed=13, n=600,
                                        sizes=(80, 90, 70, 85, 75))
    rng = np.random.default_rng(2)
    eligible = rng.random(600) < 0.10       # far below the 0.25 threshold
    fold = PallasBackend(route="device", elig_pack_threshold=0.0)
    dense = PallasBackend(route="device", elig_pack_threshold=0.25)
    want = fold.self_join_blocks(points, id_lists, radii, keys=keys,
                                 eligible=eligible)
    got = dense.self_join_blocks(points, id_lists, radii, keys=keys,
                                 eligible=eligible)
    packed = sum(v[0] for v in dense.stats.bin_points.values())
    packed_fold = sum(v[0] for v in fold.stats.bin_points.values())
    assert packed < packed_fold             # tiles actually packed denser
    for i, (y, x) in enumerate(zip(got, want)):
        el = eligible[id_lists[i]]
        rows = np.flatnonzero(el)
        assert y.n == x.n == len(id_lists[i])
        assert y.n_eligible == x.n_eligible == len(rows)
        assert y.rows is not None
        np.testing.assert_array_equal(y.rows, rows, err_msg=f"subset {i}")
        # the dense mask over packed rows == the folded mask restricted to
        # eligible rows/cols
        a_fold = unpack_join_mask(x.mask, x.n).astype(bool)
        a_fold = a_fold[np.ix_(rows, rows)]
        a_dense = unpack_join_mask(y.mask, len(rows)).astype(bool)
        np.testing.assert_array_equal(a_dense, a_fold, err_msg=f"subset {i}")
        assert y.join_count == int(a_dense.sum())


def test_eligible_dense_zero_selectivity():
    points, id_lists, radii, keys = _mk(seed=14, sizes=(30, 25))
    eligible = np.zeros(len(points), dtype=bool)
    be = PallasBackend(route="device")
    blocks = be.self_join_blocks(points, id_lists, radii, keys=keys,
                                 eligible=eligible)
    for b in blocks:
        assert b.n_eligible == 0 and b.join_count == 0


# --------------------------------------------------------- shard placement
def test_balance_order_levels_slabs():
    from repro.core.device_plane import balance_order
    rng = np.random.default_rng(3)
    for trial in range(10):
        n_shards = int(rng.choice([2, 4, 8]))
        s = n_shards * int(rng.integers(1, 6))
        lens = rng.integers(0, 500, s)
        perm = balance_order(lens, n_shards)
        assert sorted(perm.tolist()) == list(range(s))
        slabs = lens[perm].reshape(n_shards, -1).sum(axis=1)
        # snake dealing keeps the heaviest and lightest slab within one
        # max-length of each other
        assert slabs.max() - slabs.min() <= lens.max(), \
            f"trial {trial}: {slabs}"


def test_placement_parity_single_device():
    """placement only permutes tile slots; blocks come back in task order
    and bit-identical to placement="none"."""
    points, id_lists, radii, keys = _mk(seed=15)
    a = PallasBackend(route="device", placement="sorted")
    b = PallasBackend(route="device", placement="none")
    ba = a.self_join_blocks(points, id_lists, radii, keys=keys)
    bb = b.self_join_blocks(points, id_lists, radii, keys=keys)
    for i, (y, x) in enumerate(zip(ba, bb)):
        assert y.join_count == x.join_count, f"subset {i}"
        np.testing.assert_array_equal(y.mask, x.mask, err_msg=f"subset {i}")


# ----------------------------------------------------------- stats plumbing
def test_bin_points_accumulates_per_class():
    be = PallasBackend(route="device", bin_strategy="pow2")
    points, id_lists, radii, keys = _mk(seed=16)
    be.self_join_blocks(points, id_lists, radii, keys=keys)
    assert be.stats.bin_points
    tot_valid = sum(v for v, _ in be.stats.bin_points.values())
    assert tot_valid == be.stats.points_packed
    tot_pad = sum(p for _, p in be.stats.bin_points.values())
    assert tot_pad == be.stats.points_padded
