"""Ingestion pipeline: job state machine, leases, retries, crash recovery.

Three layers of assurance, from fastest to strongest:

  1. deterministic state-machine unit tests (FakeClock drives leases and
     backoff — no sleeps, every transition and every illegal edge pinned);
  2. crash-at-each-fault-site recovery differentials: a worker is killed at
     ``claim``/``embed``/``insert``/``ack``, the *process* is recovered
     (engine from its WAL, store from its journal), and the drained corpus
     must match a fresh static build over the same documents — no lost and
     no duplicated points (at-least-once below the ack horizon, exactly-once
     above it);
  3. a hypothesis property over random interleavings of worker crashes vs.
     job progress, asserting the final corpus is permutation-identical to
     the no-fault run.

Answer comparisons are doc-id-canonicalized: pipeline insertion order is
not the reference row order, so external ids are translated to document
ids before comparing. Equal-diameter ties (several point sets at the same
cost — common at diameter 0, a single point covering the whole query) are
legitimately order-dependent, so doc-id sets are compared only at
unambiguous ranks while the diameter list itself must match exactly.
"""
from __future__ import annotations

import os
import tempfile
from collections import Counter

import numpy as np
import pytest

from repro.data.ingest import (
    CLAIMED, DONE, EMBEDDED, FAILED, INSERTED, PENDING,
    EngineSink, IngestPipeline, IngestWorker, IntentBusy, InvalidTransition,
    JobStore, LeaseLost, ProjectionEmbedder, RuntimeSink, SinkIndeterminate,
    corpus_from_documents, flickr_like_documents,
)
from repro.data.synthetic import random_queries
from repro.serve.engine import NKSEngine
from repro.serve.faults import FaultPlan, InjectedCrash, InjectedFault

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

D_RAW, D_OUT, U = 16, 6, 20
SITES = ("claim", "embed", "insert", "ack")


class FakeClock:
    def __init__(self, t: float = 1000.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += float(dt)


def _docs(n, *, tenants=None, seed=1):
    return flickr_like_documents(n, d_raw=D_RAW, u=U, t=3, seed=seed,
                                 tenants=tenants)


def _embedder(seed=2):
    vocab = [f"tag{i:03d}" for i in range(U)]
    return ProjectionEmbedder(D_OUT, vocab, d_raw=D_RAW, seed=seed)


def _engine(ds, **kw):
    kw.setdefault("compact_min", 10_000)
    return NKSEngine(ds, m=2, n_scales=4, seed=0, **kw)


def _store(path, clk, **kw):
    kw.setdefault("lease_s", 10.0)
    kw.setdefault("backoff_s", 0.5)
    return JobStore(str(path), clock=clk, **kw)


def _drive(worker, store, clk, *, limit=500):
    """Step one worker until the store drains, advancing the fake clock
    whenever no work is claimable (backoff / lease windows)."""
    for _ in range(limit):
        if store.drained():
            return
        if not worker.step():
            clk.advance(5.0)
    raise AssertionError(f"not drained after {limit} steps: "
                         f"{store.counts()}")


# ----------------------------------------------------- differential helpers
def _cases(ref_ds, *, tenanted, seed=9):
    """Query/filter cases: unfiltered global-id queries plus (on tenanted
    corpora) tenant-scoped local-id queries with an attribute predicate."""
    cases = [(q, None) for q in random_queries(ref_ds, 2, 8, seed=seed)]
    if tenanted:
        cases += [([0, 1], {"tenant": "a"}), ([1, 2], {"tenant": "a"}),
                  ([0, 2], {"tenant": "b",
                            "where": [["price", "<", 60.0]]})]
    return cases


def _canon_answers(engine, cases, ext2doc, *, k=2):
    ext = np.asarray(engine._ext_of)
    out = []
    for q, flt in cases:
        res = engine.query(q, k=k, tier="exact", filter=flt)
        out.append([(float(c.diameter),
                     tuple(sorted(ext2doc[int(ext[i])] for i in c.ids)))
                    for c in res.candidates])
    return out


def _assert_equivalent(got, want):
    """Exact-tier answers modulo legitimate equal-diameter ties: diameter
    lists must be identical; doc-id sets must match at every rank whose
    diameter is unique in the answer and strictly inside the top-k cut."""
    assert len(got) == len(want)
    for a, b in zip(got, want):
        da, db = [x[0] for x in a], [x[0] for x in b]
        assert da == db, (da, db)
        cnt = Counter(da)
        cutoff = da[-1] if da else None
        for (d1, ids1), (_, ids2) in zip(a, b):
            if cnt[d1] == 1 and d1 != cutoff:
                assert ids1 == ids2, (d1, ids1, ids2)


def _assert_corpus_matches(engine, ext2doc, docs_by_id, emb, expected_ids):
    """The no-lost-no-dup invariant plus per-row bitwise identity: every
    expected document is in the engine exactly once, and its point row,
    keyword set, attrs, and tenant are exactly what the embedder says."""
    ext = [int(e) for e in np.asarray(engine._ext_of)]
    assert len(ext) == len(set(ext)), "duplicate external ids"
    got_docs = [ext2doc[e] for e in ext]
    assert sorted(got_docs) == sorted(expected_ids)   # no lost, no dup
    ds = engine.dataset
    ns = ds.tenants
    pts = np.asarray(ds.points)
    for row, doc_id in enumerate(got_docs):
        rec = emb.extract(docs_by_id[doc_id])
        np.testing.assert_array_equal(pts[row], rec.point)
        want_kws = (ns.resolve(rec.tenant, rec.keywords) if ns is not None
                    else rec.keywords)
        assert sorted(int(v) for v in ds.kw.row(row)) == sorted(want_kws)
        if rec.attrs is not None:
            for name, val in rec.attrs.items():
                assert ds.attr_column(name)[row] == val
        if ns is not None:
            assert int(ds.tenant_ids[row]) == ns.id_of(rec.tenant)


def _setting(docs, n_seed, emb):
    """Split docs into a seed corpus (engine build) and a job stream, and
    return the static reference built over *all* docs."""
    seed_ds, seed_ids = corpus_from_documents(docs[:n_seed], emb)
    ref_ds, ref_ids = corpus_from_documents(docs, emb)
    return seed_ds, seed_ids, ref_ds, {i: d for i, d in enumerate(ref_ids)}


# ------------------------------------------------------------ embedder layer
def test_embedder_deterministic_and_validates():
    docs, vocab = _docs(5, seed=4)
    emb = _embedder()
    r1, r2 = emb.extract(docs[0]), emb.extract(docs[0])
    np.testing.assert_array_equal(r1.point, r2.point)   # bitwise
    assert r1.keywords == r2.keywords and r1.point.dtype == np.float32
    assert r1.keywords == sorted(set(r1.keywords))
    with pytest.raises(ValueError, match="unknown tag"):
        emb.extract({"doc_id": "x", "payload": docs[0]["payload"],
                     "tags": ["not-a-tag"]})
    with pytest.raises(ValueError, match="no tags"):
        emb.extract({"doc_id": "x", "payload": docs[0]["payload"],
                     "tags": []})
    with pytest.raises(ValueError, match="payload"):
        emb.extract({"doc_id": "x", "payload": [1.0, 2.0], "tags": ["tag001"]})


def test_flickr_like_documents_and_static_corpus():
    docs, vocab = _docs(40, tenants=("a", "b"), seed=3)
    assert len(vocab) == U and len(docs) == 40
    assert all(set(d) == {"doc_id", "payload", "tags", "attrs", "tenant"}
               for d in docs)
    assert {d["tenant"] for d in docs} <= {"a", "b"}
    ds, doc_ids = corpus_from_documents(docs, _embedder())
    assert ds.n == 40 and ds.dim == D_OUT
    assert ds.n_keywords == 2 * U                 # private per-tenant slots
    assert ds.tenants is not None and list(ds.tenants.names) == ["a", "b"]
    assert set(doc_ids) == {d["doc_id"] for d in docs}
    assert set(ds.attrs) == {"category", "price"}
    # mixed tenanted/untenanted input is rejected
    broken = [dict(docs[0]), dict(docs[1])]
    del broken[0]["tenant"]
    with pytest.raises(ValueError, match="mixed tenant"):
        corpus_from_documents(broken, _embedder())


# ------------------------------------------------------------- job store fsm
def test_jobstore_lifecycle_happy_path(tmp_path):
    clk = FakeClock()
    store = _store(tmp_path / "j.jsonl", clk)
    docs, _ = _docs(5, seed=2)
    ids = store.add(docs)
    assert store.counts()[PENDING] == 5 and not store.drained()

    jobs = store.claim("w0", limit=3)
    assert [j.job_id for j in jobs] == ids[:3]
    assert all(j.state == CLAIMED and j.attempts == 1 for j in jobs)
    store.mark_embedded("w0", [j.job_id for j in jobs])
    assert store.counts()[EMBEDDED] == 3

    intent = store.record_intent("w0", [j.job_id for j in jobs],
                                 horizon=100)
    assert store.counts()[INSERTED] == 3
    store.ack_intent(intent, [100, 101, 102])
    assert store.counts() == {PENDING: 2, CLAIMED: 0, EMBEDDED: 0,
                              INSERTED: 0, DONE: 3, FAILED: 0}
    assert store.open_intent() is None
    assert store.ext_map() == {100 + i: docs[i]["doc_id"] for i in range(3)}
    store.close()


def test_jobstore_illegal_edges(tmp_path):
    clk = FakeClock()
    store = _store(tmp_path / "j.jsonl", clk)
    docs, _ = _docs(4, seed=2)
    ids = store.add(docs)
    jobs = store.claim("w0", limit=2)
    jids = [j.job_id for j in jobs]

    # wrong owner / wrong state => LeaseLost
    with pytest.raises(LeaseLost):
        store.mark_embedded("w1", jids)
    with pytest.raises(LeaseLost):
        store.record_intent("w0", jids, horizon=0)     # still claimed
    store.mark_embedded("w0", jids)
    with pytest.raises(LeaseLost):
        store.mark_embedded("w0", jids)                # already embedded

    # the intent fence admits one batch at a time
    i0 = store.record_intent("w0", jids, horizon=7)
    more = store.claim("w1", limit=2)
    store.mark_embedded("w1", [j.job_id for j in more])
    with pytest.raises(IntentBusy):
        store.record_intent("w1", [j.job_id for j in more], horizon=9)
    with pytest.raises(InvalidTransition):
        store.ack_intent(i0 + 5, [7, 8])               # not the open intent
    with pytest.raises(InvalidTransition):
        store.ack_intent(i0, [7])                      # wrong cardinality
    store.ack_intent(i0, [7, 8])
    with pytest.raises(InvalidTransition):
        store.ack_intent(i0, [7, 8])                   # already resolved
    # pending jobs are not releasable by a non-owner
    with pytest.raises(LeaseLost):
        store.release("w0", [ids[3]], error="nope")
    store.close()


def test_journal_replay_roundtrip(tmp_path):
    clk = FakeClock()
    path = tmp_path / "j.jsonl"
    store = _store(path, clk, max_attempts=4)
    docs, _ = _docs(6, seed=5)
    store.add(docs)
    jobs = store.claim("w0", limit=4)
    store.mark_embedded("w0", [j.job_id for j in jobs[:3]])
    store.release("w0", [jobs[3].job_id], error="transient")
    intent = store.record_intent("w0", [j.job_id for j in jobs[:3]],
                                 horizon=50)
    store.ack_intent(intent, [50, 51, 52])
    jobs2 = store.claim("w1", limit=1)       # claims job 4 (pending, ready)
    snap = {j.job_id: (j.state, j.attempts, j.worker, j.not_before,
                       j.lease_until, j.ext_id)
            for j in store.jobs.values()}
    counts, stats = store.counts(), dataclasses_dict(store.stats)

    re = _store(path, clk, max_attempts=4)
    assert {j.job_id: (j.state, j.attempts, j.worker, j.not_before,
                       j.lease_until, j.ext_id)
            for j in re.jobs.values()} == snap
    assert re.counts() == counts
    assert dataclasses_dict(re.stats) == stats
    assert re.open_intent() is None
    # the reopened store keeps allocating fresh ids past the journal's
    new = re.add([docs[0] | {"doc_id": "doc-new"}])
    assert new[0] == max(snap) + 1
    store.close()
    re.close()
    assert jobs2[0].state == CLAIMED


def dataclasses_dict(dc):
    import dataclasses
    return dataclasses.asdict(dc)


def test_journal_torn_tail_truncated(tmp_path):
    clk = FakeClock()
    path = tmp_path / "j.jsonl"
    store = _store(path, clk)
    docs, _ = _docs(3, seed=5)
    store.add(docs)
    store.claim("w0", limit=2)
    store.close()
    size = os.path.getsize(path)
    with open(path, "ab") as f:
        f.write(b'{"t": "claim", "ids": [2], "worker": "w1", "lease')
    re = _store(path, clk)                   # torn record dropped
    assert os.path.getsize(path) == size
    assert re.counts()[CLAIMED] == 2 and re.counts()[PENDING] == 1
    # a parse-clean tail without its newline is torn too
    re.close()
    with open(path, "rb+") as f:
        f.seek(0, 2)
        f.write(b'{"t": "release", "retry": [0], "failed": [],'
                b' "error": "x", "reason": "error", "not_before": 0.0}')
    re2 = _store(path, clk)
    assert os.path.getsize(path) == size
    assert re2.counts()[CLAIMED] == 2
    re2.close()


def test_release_replay_preserves_per_job_backoff(tmp_path):
    """One release record covering jobs with different attempt counts must
    replay each job's own backoff instant, not a shared maximum — the
    reopened store's retry schedule is identical to the one that wrote the
    journal."""
    clk = FakeClock()
    path = tmp_path / "j.jsonl"
    store = _store(path, clk, backoff_s=1.0, max_attempts=10)
    docs, _ = _docs(2, seed=21)
    store.add(docs)
    store.claim("w0", limit=1)                 # job 0, attempt 1
    store.release("w0", [0], error="flaky")
    clk.advance(100.0)
    jobs = store.claim("w0", limit=2)          # job 0 attempt 2, job 1 attempt 1
    assert [j.attempts for j in jobs] == [2, 1]
    store.release("w0", [0, 1], error="flaky")  # one record, two backoffs
    nb = {j.job_id: j.not_before for j in store.jobs.values()}
    assert nb[0] == pytest.approx(clk() + 2.0)  # 1.0 * 2^(2-1)
    assert nb[1] == pytest.approx(clk() + 1.0)  # 1.0 * 2^(1-1)

    re = _store(path, clk, backoff_s=1.0, max_attempts=10)
    assert {j.job_id: j.not_before for j in re.jobs.values()} == nb
    store.close()
    re.close()


def test_record_intent_samples_horizon_under_the_fence(tmp_path):
    """The insert horizon is read inside the store lock, after the fence
    check — a concurrent batch can no longer complete a full
    intent->insert->ack cycle between a caller's pre-read and its fence
    (the stale-first_ext race), and a busy fence never samples at all."""
    clk = FakeClock()
    store = _store(tmp_path / "j.jsonl", clk)
    docs, _ = _docs(4, seed=23)
    store.add(docs)
    jobs = store.claim("w0", limit=2)
    jids = [j.job_id for j in jobs]
    store.mark_embedded("w0", jids)
    seen = []

    def horizon():
        assert store._lock._is_owned()         # atomic with the fence
        assert store._intent is None           # sampled after the busy check
        seen.append(1)
        return 42

    i0 = store.record_intent("w0", jids, horizon=horizon)
    assert store.open_intent().first_ext == 42 and seen == [1]

    more = store.claim("w1", limit=2)
    store.mark_embedded("w1", [j.job_id for j in more])

    def poisoned():
        raise AssertionError("horizon sampled despite a busy fence")

    with pytest.raises(IntentBusy):
        store.record_intent("w1", [j.job_id for j in more], horizon=poisoned)
    store.ack_intent(i0, [42, 43])

    # the sink protocol (an object with next_external_id) is accepted too
    class Sink:
        next_external_id = 7

    i1 = store.record_intent("w1", [j.job_id for j in more], horizon=Sink())
    assert store.open_intent().first_ext == 7
    store.ack_intent(i1, [7, 8])
    store.close()


def test_record_intent_refreshes_job_leases(tmp_path):
    """record_intent renews the jobs' leases alongside the intent's, so
    next_ready_at() reports the intent window, not the stale embed-stage
    lease — and the refresh survives journal replay."""
    clk = FakeClock()
    path = tmp_path / "j.jsonl"
    store = _store(path, clk, lease_s=10.0)
    docs, _ = _docs(2, seed=22)
    store.add(docs)
    jobs = store.claim("w0", limit=2)
    store.mark_embedded("w0", [j.job_id for j in jobs])
    clk.advance(6.0)                           # embed lease has 4s left
    store.record_intent("w0", [j.job_id for j in jobs], horizon=0)
    want = clk() + 10.0
    assert all(store.jobs[j.job_id].lease_until == want for j in jobs)
    assert store.next_ready_at() == want       # not the stale embed lease

    re = _store(path, clk, lease_s=10.0)       # replay mirrors the refresh
    assert all(re.jobs[j.job_id].lease_until == want for j in jobs)
    assert re.next_ready_at() == want
    store.close()
    re.close()


def test_lease_expiry_reclaim_and_lease_lost(tmp_path):
    clk = FakeClock()
    store = _store(tmp_path / "j.jsonl", clk, lease_s=10.0, max_attempts=5)
    docs, _ = _docs(4, seed=6)
    store.add(docs)
    dead = store.claim("w-dead", limit=4)
    assert store.claim("w-live", limit=4) == []       # lease held
    clk.advance(10.1)                                 # w-dead "died"
    alive = store.claim("w-live", limit=4)
    assert [j.job_id for j in alive] == [j.job_id for j in dead]
    assert all(j.worker == "w-live" and j.attempts == 2 for j in alive)
    assert store.stats.reclaims == 4
    # the zombie's writes bounce: its lease is gone
    with pytest.raises(LeaseLost):
        store.mark_embedded("w-dead", [j.job_id for j in dead])
    with pytest.raises(LeaseLost):
        store.release("w-dead", [dead[0].job_id], error="late")
    store.close()


def test_retry_backoff_schedule_and_exhaustion(tmp_path):
    clk = FakeClock()
    store = _store(tmp_path / "j.jsonl", clk, max_attempts=3, backoff_s=1.0)
    docs, _ = _docs(1, seed=7)
    store.add(docs)
    last_ready = 0.0
    for attempt in range(1, 4):
        jobs = store.claim("w0", limit=1)
        assert jobs and jobs[0].attempts == attempt
        if attempt < 3:
            store.release("w0", [0], error="flaky")
            j = store.jobs[0]
            assert j.state == PENDING
            # exponential: now + 1.0 * 2^(attempts-1)
            assert j.not_before == pytest.approx(
                clk() + 1.0 * 2.0 ** (attempt - 1))
            assert store.claim("w0", limit=1) == []   # backoff holds
            assert j.not_before > last_ready
            last_ready = j.not_before
            clk.advance(100.0)
        else:
            store.release("w0", [0], error="flaky")
    j = store.jobs[0]
    assert j.state == FAILED and "exhausted" in j.error
    assert store.stats.exhausted == 1 and store.drained()
    assert store.claim("w0", limit=1) == []           # terminal
    store.close()


def test_poison_doc_fails_without_blocking_batch(tmp_path):
    """A document the embedder rejects burns its own attempts to terminal
    ``failed``; the rest of its batch lands normally."""
    clk = FakeClock()
    docs, _ = _docs(10, seed=8)
    docs[4]["tags"] = ["never-a-tag"]
    emb = _embedder()
    seed_ds, seed_ids, _, _ = _setting(_docs(8, seed=9)[0], 8, emb)
    store = _store(tmp_path / "j.jsonl", clk, max_attempts=3)
    store.add(docs)
    eng = _engine(seed_ds)
    w = IngestWorker("w0", store, eng, emb, batch_docs=4)
    _drive(w, store, clk)
    counts = store.counts()
    assert counts[DONE] == 9 and counts[FAILED] == 1
    assert store.jobs[4].state == FAILED
    assert "unknown tag" in store.jobs[4].error
    assert w.stats.embed_failures == 3                # one per attempt
    assert eng.dataset.n == seed_ds.n + 9
    eng.close()


# --------------------------------------------------- end-to-end differential
def test_worker_end_to_end_differential(tmp_path):
    """Pipeline-ingested engine answers filtered multi-tenant queries
    equivalently to a fresh static engine over the same documents, the
    corpus is row-for-row bitwise faithful to the embedder, and each batch
    costs exactly one WAL fsync (the group-commit barrier)."""
    docs, _ = _docs(80, tenants=("a", "b"), seed=1)
    emb = _embedder()
    seed_ds, seed_ids, ref_ds, ref_table = _setting(docs, 20, emb)
    clk = FakeClock()
    store = _store(tmp_path / "j.jsonl", clk)
    store.add(docs[20:])
    eng = _engine(seed_ds)
    eng.attach_wal(str(tmp_path / "wal"))
    f0 = eng.wal_stats.fsyncs
    w = IngestWorker("w0", store, eng, emb, batch_docs=8)
    _drive(w, store, clk)
    assert store.counts()[DONE] == 60
    assert eng.wal_stats.fsyncs - f0 == w.stats.batches_inserted

    ext2doc = {i: d for i, d in enumerate(seed_ids)}
    ext2doc.update(store.ext_map())
    docs_by_id = {d["doc_id"]: d for d in docs}
    _assert_corpus_matches(eng, ext2doc, docs_by_id, emb,
                           [d["doc_id"] for d in docs])
    ref = _engine(ref_ds)
    cases = _cases(ref_ds, tenanted=True)
    _assert_equivalent(_canon_answers(eng, cases, ext2doc),
                       _canon_answers(ref, cases, ref_table))
    eng.close()
    store.close()


def test_transient_faults_reconcile_in_process(tmp_path):
    """An ``InjectedFault`` (retryable error, not a death) around the insert
    window resolves through the same horizon reconciliation as recovery:
    before the engine touched the batch => reverted + retried; after the
    barrier => acked exactly-once, no duplicate points."""
    docs, _ = _docs(30, seed=11)
    emb = _embedder()
    seed_ds, seed_ids, ref_ds, ref_table = _setting(docs, 10, emb)
    for site, field in (("insert", "reconciled_reverted"),
                        ("ack", "reconciled_applied")):
        clk = FakeClock()
        store = _store(tmp_path / f"j-{site}.jsonl", clk, max_attempts=5)
        store.add(docs[10:])
        eng = _engine(seed_ds)
        faults = FaultPlan(transient={site: 2})
        w = IngestWorker("w0", store, eng, emb, batch_docs=5, faults=faults)
        _drive(w, store, clk)
        assert faults.fired[site] == 1
        assert getattr(w.stats, field) == 1
        assert store.counts()[DONE] == 20 and store.counts()[FAILED] == 0
        assert eng.dataset.n == ref_ds.n              # no lost, no dup
        ext2doc = {i: d for i, d in enumerate(seed_ids)}
        ext2doc.update(store.ext_map())
        _assert_corpus_matches(eng, ext2doc, {d["doc_id"]: d for d in docs},
                               emb, [d["doc_id"] for d in docs])
        eng.close()
        store.close()


EXPECTED_RECOVERY = {"claim": None, "embed": None,
                     "insert": "reverted", "ack": "applied"}


@pytest.mark.parametrize("site", SITES)
def test_crash_site_recovery_differential(tmp_path, site):
    """Kill the worker at each crash site mid-run, then recover the whole
    process: engine from its WAL, job store from its journal, pipeline
    startup reconciliation for the open intent. The drained corpus must be
    indistinguishable from a no-fault build — at-least-once below the ack
    horizon, exactly-once above it."""
    docs, _ = _docs(70, tenants=("a", "b"), seed=13)
    emb = _embedder()
    seed_ds, seed_ids, ref_ds, ref_table = _setting(docs, 22, emb)
    clk = FakeClock()
    jpath, wroot = str(tmp_path / "j.jsonl"), str(tmp_path / "wal")
    store = _store(jpath, clk, lease_s=10.0)
    store.add(docs[22:])
    eng = _engine(seed_ds)
    eng.attach_wal(wroot)

    faults = FaultPlan(crash={site: 2})    # survive batch 1, die in batch 2
    w = IngestWorker("w0", store, eng, emb, batch_docs=8, faults=faults)
    with pytest.raises(InjectedCrash):
        for _ in range(100):
            if not w.step():
                clk.advance(1.0)
    assert faults.fired[site] == 1
    # The dead worker cleaned up nothing: its claim (and for insert/ack its
    # open intent) is still on the books. Simulated process death: abandon
    # both objects un-closed and rebuild from disk.
    n_before = int(eng.dataset.n)

    eng2 = NKSEngine.recover(wroot)
    assert int(eng2.dataset.n) == n_before            # WAL lost nothing
    store2 = _store(jpath, clk, lease_s=10.0)
    pipe = IngestPipeline(store2, eng2, emb, workers=1, batch_docs=8)
    assert pipe.recover() == EXPECTED_RECOVERY[site]
    assert pipe.recover() is None                     # idempotent
    if site == "ack":
        # the crashed batch was past its barrier: acked from the horizon,
        # not re-inserted
        assert store2.counts()[DONE] >= 16
    clk.advance(30.0)                                 # expire dead leases
    _drive(pipe.workers[0], store2, clk)

    counts = store2.counts()
    assert counts[FAILED] == 0 and counts[DONE] == 48
    ext2doc = {i: d for i, d in enumerate(seed_ids)}
    ext2doc.update(store2.ext_map())
    docs_by_id = {d["doc_id"]: d for d in docs}
    _assert_corpus_matches(eng2, ext2doc, docs_by_id, emb,
                           [d["doc_id"] for d in docs])
    ref = _engine(ref_ds)
    cases = _cases(ref_ds, tenanted=True)
    _assert_equivalent(_canon_answers(eng2, cases, ext2doc),
                       _canon_answers(ref, cases, ref_table))
    # ... and the *recovered* state itself recovers: one more round-trip
    eng2.close()
    eng3 = NKSEngine.recover(wroot)
    _assert_equivalent(_canon_answers(eng3, cases, ext2doc),
                       _canon_answers(ref, cases, ref_table))
    eng3.close()
    store2.close()


def test_threaded_pipeline_with_fault_plan(tmp_path):
    """Six workers race the queue while a shared fault plan kills four of
    them, one per crash site, mid-run (real clock, short leases). The
    survivors drain the store and the corpus still matches the static
    reference exactly."""
    docs, _ = _docs(90, tenants=("a", "b"), seed=17)
    emb = _embedder()
    seed_ds, seed_ids, ref_ds, ref_table = _setting(docs, 26, emb)
    store = JobStore(str(tmp_path / "j.jsonl"), lease_s=0.3,
                     backoff_s=0.01, max_attempts=10)
    store.add(docs[26:])
    eng = _engine(seed_ds)
    eng.attach_wal(str(tmp_path / "wal"))
    faults = FaultPlan(crash={"claim": 3, "embed": 5, "insert": 7, "ack": 9})
    pipe = IngestPipeline(store, eng, emb, workers=6, batch_docs=6,
                          faults=faults)
    report = pipe.run(timeout_s=60.0)
    assert report["drained"], report
    assert sorted(faults.fired) == sorted(SITES)      # all four deaths fired
    assert len(report["dead_workers"]) == 4
    assert report["docs_failed"] == 0
    assert report["docs_done"] == 64
    assert report["docs_per_s"] > 0

    ext2doc = {i: d for i, d in enumerate(seed_ids)}
    ext2doc.update(store.ext_map())
    _assert_corpus_matches(eng, ext2doc, {d["doc_id"]: d for d in docs},
                           emb, [d["doc_id"] for d in docs])
    ref = _engine(ref_ds)
    cases = _cases(ref_ds, tenanted=True)
    _assert_equivalent(_canon_answers(eng, cases, ext2doc),
                       _canon_answers(ref, cases, ref_table))
    eng.close()
    store.close()


def test_runtime_sink_coalesces_with_admission_queue(tmp_path):
    """Targeting the serving runtime instead of a bare engine: batches ride
    the admission queue as insert ops and coalesce into grouped ingest runs
    exactly like launcher ingests, and the drained corpus matches."""
    from repro.serve.runtime import RuntimeConfig, ServingRuntime

    docs, _ = _docs(50, seed=19)
    emb = _embedder()
    seed_ds, seed_ids, ref_ds, ref_table = _setting(docs, 14, emb)
    store = JobStore(str(tmp_path / "j.jsonl"), lease_s=5.0, backoff_s=0.01)
    store.add(docs[14:])
    eng = _engine(seed_ds)
    eng.attach_wal(str(tmp_path / "wal"))
    with ServingRuntime(eng, RuntimeConfig(batch_window_s=0.002)) as rt:
        pipe = IngestPipeline(store, rt, emb, workers=3, batch_docs=6)
        report = pipe.run(timeout_s=60.0)
        assert report["drained"], report
        assert rt.stats.ingest_ops >= 6               # went through the queue
    assert store.counts()[DONE] == 36
    ext2doc = {i: d for i, d in enumerate(seed_ids)}
    ext2doc.update(store.ext_map())
    _assert_corpus_matches(eng, ext2doc, {d["doc_id"]: d for d in docs},
                           emb, [d["doc_id"] for d in docs])
    ref = _engine(ref_ds)
    cases = _cases(ref_ds, tenanted=False)
    _assert_equivalent(_canon_answers(eng, cases, ext2doc),
                       _canon_answers(ref, cases, ref_table))
    eng.close()
    store.close()


class _StubRuntime:
    """Runtime double: the first submit swallows its op (an unresolved
    ticket — the op is stuck inside the runtime); later submits execute
    immediately against the real engine. ``land_lost`` applies the stuck op
    after the fact — the late-landing execution the sink/worker pair must
    survive without duplicating the batch."""

    def __init__(self, engine):
        self.engine = engine
        self.lost = None
        self.deadlines = []

    def _apply(self, req):
        with self.engine.ingest_group():
            ids = self.engine.insert(req["points"], req["keywords"],
                                     attrs=req.get("attrs"),
                                     tenant=req.get("tenant"))
        return [int(i) for i in ids]

    def submit(self, request, deadline_s=None):
        from repro.serve.runtime import RuntimeResponse, Ticket
        self.deadlines.append(deadline_s)
        t = Ticket(request, None)
        if self.lost is None:
            self.lost = request                # black hole: never resolves
            return t
        t._resolve(RuntimeResponse(op="insert", status="ok",
                                   payload={"ids": self._apply(request)}))
        return t

    def land_lost(self):
        self._apply(self.lost)


def test_runtime_sink_terminal_status_contract():
    """insert() submits with an admission deadline and waits the ticket to a
    terminal status, then classifies it: ok returns ids;
    timeout/rejected/error raise plainly (the op provably never mutated the
    engine — safe to reconcile immediately); crashed, or a ticket that never
    resolves, raise SinkIndeterminate (fate unknown — the intent must stay
    open). Giving up on a still-queued op is no longer possible, which is
    what made the duplicate-insert race reachable."""
    from repro.serve.runtime import RuntimeResponse, Ticket

    class OneShot:
        engine = None

        def __init__(self, resp):
            self.resp = resp
            self.deadline = "unset"

        def submit(self, request, deadline_s=None):
            self.deadline = deadline_s
            t = Ticket(request, None)
            if self.resp is not None:
                t._resolve(self.resp)
            return t

    pts = np.zeros((1, D_OUT), np.float32)
    rt = OneShot(RuntimeResponse(op="insert", status="ok",
                                 payload={"ids": [5]}))
    assert RuntimeSink(rt, timeout_s=0.4).insert(pts, [[0]], None, None) == [5]
    assert rt.deadline == pytest.approx(0.4)   # admission deadline attached

    for status in ("timeout", "rejected", "error"):
        rt = OneShot(RuntimeResponse(op="insert", status=status, error="x"))
        with pytest.raises(RuntimeError, match=status) as ei:
            RuntimeSink(rt, timeout_s=0.4).insert(pts, [[0]], None, None)
        assert type(ei.value) is RuntimeError  # NOT indeterminate

    rt = OneShot(RuntimeResponse(op="insert", status="crashed", error="boom"))
    with pytest.raises(SinkIndeterminate):
        RuntimeSink(rt, timeout_s=0.4).insert(pts, [[0]], None, None)

    rt = OneShot(None)                         # ticket never resolves
    with pytest.raises(SinkIndeterminate):
        RuntimeSink(rt, timeout_s=0.01, grace_s=0.02).insert(
            pts, [[0]], None, None)


def test_lost_insert_op_cannot_duplicate_batch(tmp_path):
    """The duplicate-insert race, end to end: the runtime holds an insert op
    past the sink's patience, the op lands *late*, and the batch must still
    end up in the corpus exactly once. The sink raises SinkIndeterminate,
    the worker leaves the intent open (no early release, so no retry racing
    the in-flight op), and the expired-lease reconciliation acks the batch
    from the moved horizon instead of re-inserting it."""
    docs, _ = _docs(14, seed=27)
    emb = _embedder()
    seed_ds, seed_ids, _, _ = _setting(docs, 6, emb)
    clk = FakeClock()
    store = _store(tmp_path / "j.jsonl", clk, lease_s=10.0)
    store.add(docs[6:])
    eng = _engine(seed_ds)
    rt = _StubRuntime(eng)
    sink = RuntimeSink(rt, timeout_s=0.01, grace_s=0.02)
    w = IngestWorker("w0", store, sink, emb, batch_docs=4, clock=clk)

    assert w.step()                            # batch 1: op swallowed
    assert w.stats.sink_indeterminate == 1
    assert rt.deadlines[0] == pytest.approx(0.01)
    assert store.open_intent() is not None     # intent stays open; jobs are
    assert store.counts()[INSERTED] == 4       # NOT released for a retry

    rt.land_lost()                             # the stuck op executes late
    assert not w.step()                        # batch 2 staged; fence live
    assert w.stats.intent_busy == 1
    clk.advance(10.1)                          # intent lease expires
    _drive(w, store, clk)
    assert w.stats.reconciled_applied == 1     # batch 1 acked, not re-run
    assert store.counts()[DONE] == 8 and store.counts()[FAILED] == 0

    ext2doc = {i: d for i, d in enumerate(seed_ids)}
    ext2doc.update(store.ext_map())
    _assert_corpus_matches(eng, ext2doc, {d["doc_id"]: d for d in docs},
                           emb, [d["doc_id"] for d in docs])
    eng.close()
    store.close()


@pytest.mark.parametrize("lands", [True, False])
def test_indeterminate_final_batch_reconciles_without_new_work(tmp_path,
                                                               lands):
    """A SinkIndeterminate on the *last* batch leaves the intent open with
    nothing left to claim; the worker's idle path must still reconcile it
    after lease expiry (applied if the stuck op landed late, reverted and
    retried if it never did) or the store would never drain."""
    docs, _ = _docs(10, seed=29)
    emb = _embedder()
    seed_ds, seed_ids, _, _ = _setting(docs, 6, emb)
    clk = FakeClock()
    store = _store(tmp_path / "j.jsonl", clk, lease_s=10.0)
    store.add(docs[6:])
    eng = _engine(seed_ds)
    rt = _StubRuntime(eng)
    w = IngestWorker("w0", store,
                     RuntimeSink(rt, timeout_s=0.01, grace_s=0.02),
                     emb, batch_docs=4, clock=clk)

    assert w.step()                            # the only batch: op swallowed
    assert w.stats.sink_indeterminate == 1
    assert not w.step()                        # nothing claimable, fence live
    if lands:
        rt.land_lost()
    clk.advance(10.1)                          # intent lease expires
    _drive(w, store, clk)
    assert store.counts()[DONE] == 4 and store.counts()[FAILED] == 0
    if lands:
        assert w.stats.reconciled_applied == 1
    else:
        assert w.stats.reconciled_reverted == 1 and store.stats.retries == 4

    ext2doc = {i: d for i, d in enumerate(seed_ids)}
    ext2doc.update(store.ext_map())
    _assert_corpus_matches(eng, ext2doc, {d["doc_id"]: d for d in docs},
                           emb, [d["doc_id"] for d in docs])
    eng.close()
    store.close()


# ----------------------------------------------------- interleaving property
if HAVE_HYPOTHESIS:

    @settings(deadline=None, max_examples=25)
    @given(st.data())
    def test_random_crash_interleavings_converge(data):
        """Random interleavings of worker progress, clock advances, and
        crashes at random sites: the store always drains and the final
        corpus is permutation-identical (per-document bitwise) to the
        no-fault build. Workers are threads of one process here, so a death
        leaves its lease and intent on the books for *survivors* to reap —
        the in-process mirror of the recovery differential above."""
        n_docs = data.draw(st.integers(8, 24), label="n_docs")
        docs, _ = _docs(n_docs + 6, tenants=("a", "b"),
                        seed=data.draw(st.integers(0, 50), label="seed"))
        emb = _embedder()
        seed_ds, seed_ids, _, _ = _setting(docs, 6, emb)
        crashes = data.draw(
            st.lists(st.sampled_from(SITES), max_size=4), label="crashes")

        root = tempfile.mkdtemp(prefix="ingest-prop-")
        clk = FakeClock()
        store = _store(os.path.join(root, "j.jsonl"), clk, lease_s=10.0,
                       backoff_s=0.5, max_attempts=50)
        store.add(docs[6:])
        eng = _engine(seed_ds)                        # volatile: no WAL
        try:
            plans = [FaultPlan(crash={site: data.draw(
                st.integers(1, 3), label=f"hit-{i}")})
                for i, site in enumerate(crashes)]
            workers, spawned = [], 0

            def spawn():
                nonlocal spawned
                plan = plans[spawned] if spawned < len(plans) else None
                w = IngestWorker(f"w{spawned}", store, eng, emb,
                                 batch_docs=data.draw(
                                     st.integers(2, 7),
                                     label=f"batch-{spawned}"),
                                 faults=plan or FaultPlan())
                spawned += 1
                workers.append(w)

            spawn()
            for _ in range(60 * (n_docs + 4)):
                if store.drained():
                    break
                act = data.draw(st.integers(0, 6))
                if act == 0 and len(workers) < 4:
                    spawn()
                    continue
                if act == 1:
                    clk.advance(data.draw(
                        st.sampled_from([0.5, 5.0, 20.0])))
                    continue
                if not workers:
                    spawn()
                w = workers[data.draw(st.integers(0, len(workers) - 1))]
                try:
                    if not w.step():
                        clk.advance(5.0)
                except InjectedCrash:
                    workers.remove(w)                 # thread died mid-batch
            else:
                # drain deterministically with a fresh clean worker
                w = IngestWorker("w-final", store, eng, emb, batch_docs=4)
                _drive(w, store, clk, limit=80 * (n_docs + 4))
            if not store.drained():
                w = IngestWorker("w-final", store, eng, emb, batch_docs=4)
                _drive(w, store, clk, limit=80 * (n_docs + 4))

            assert store.counts()[FAILED] == 0
            ext2doc = {i: d for i, d in enumerate(seed_ids)}
            ext2doc.update(store.ext_map())
            _assert_corpus_matches(
                eng, ext2doc, {d["doc_id"]: d for d in docs}, emb,
                [d["doc_id"] for d in docs])
        finally:
            eng.close()
            store.close()
