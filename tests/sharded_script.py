"""Sharded serving-plane correctness script, run in a subprocess with 8
forced host devices (tests/test_sharded_plane.py drives it; same pattern as
tests/multidev_script.py). Asserts:

  1. the plane's shard_map batched masked join is BIT-EXACT against the
     single-device dispatch (packed bitmasks and join counts), including
     zero-length subsets (empty shard slabs);
  2. PallasBackend(plane=...) produces bit-exact DistanceBlocks across
     uneven size bins — classes thinner than the mesh fall back to the
     single-device route, r=inf subsets skip the device entirely;
  3. NKSEngine(mesh=...) answers exact and approx query batches identically
     to the single-device engine, and records per-device dispatch counts +
     shard utilisation in PipelineStats;
  4. the device tier through the plane matches the single-device anchor-star
     kernel (the distributed parity contract, rebuilt on the plane);
  5. pack_groups truncation accounting survives the plane's shard-aligned
     repacking;
  6. filtered queries (attribute predicates, ISSUE 5) are bit-identical
     across the single-device and sharded routes — the packed eligibility
     words shard with the tile, and the folded masks come back bit-exact;
  7. flexible semantics (m-of-k / weighted / scored, ISSUE 9) answer
     bit-identically on the sharded and single-device routes, and the
     degenerate case is bit-identical to the classic sharded batch.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax.numpy as jnp
import numpy as np

from repro.core.backend import PallasBackend
from repro.core.device_plane import DevicePlane, pack_groups
from repro.core.distributed import nks_anchor_topk
from repro.core.filters import where
from repro.data.synthetic import (attach_attrs, random_queries,
                                  synthetic_dataset)
from repro.kernels import ops
from repro.launch.mesh import make_serving_mesh
from repro.serve.engine import NKSEngine

PLANE = DevicePlane(make_serving_mesh(data=8))


def test_sharded_join_bit_exact():
    rng = np.random.default_rng(0)
    s, p, d = 16, 64, 8
    x = rng.standard_normal((s, p, d)).astype(np.float32)
    lengths = rng.integers(1, p + 1, s).astype(np.int32)
    lengths[3] = 0          # a fully padded subset
    lengths[8:10] = 0       # an all-empty shard slab (shard 4)
    r = rng.uniform(0.5, 4.0, s).astype(np.float32)
    r[5] = 0.0
    m1, c1 = ops.pairwise_l2_join_batched_masked(x, lengths, r)
    m8, c8 = PLANE.join_batched_masked(x, lengths, r)
    np.testing.assert_array_equal(np.asarray(m8), np.asarray(m1))
    np.testing.assert_array_equal(np.asarray(c8), np.asarray(c1))
    print("sharded join bit-exact ok")


def test_backend_sharded_parity():
    rng = np.random.default_rng(1)
    points = rng.standard_normal((600, 10))
    # Uneven bins: one pow2 class with >= 8 subsets (sharded route), one
    # thin class (< 8, single-device remainder fallback), plus r=inf
    # subsets that never reach a device.
    sizes = [40, 44, 37, 41, 39, 45, 42, 38, 40, 43,    # class 64, sharded
             9, 11, 10]                                 # class 16, remainder
    id_lists = [np.sort(rng.choice(600, n, replace=False)).astype(np.int64)
                for n in sizes]
    radii = [2.5] * 10 + [3.0, float("inf"), 2.0]
    keys = [ids.tobytes() for ids in id_lists]

    # Pinned knobs: these asserts encode the pow2 class layout and the
    # device route's dispatch/shard counters, so auto cost-model routing and
    # quantile re-binning are out of scope here.
    single = PallasBackend(route="device", bin_strategy="pow2")
    shard = PallasBackend(plane=PLANE, route="device", bin_strategy="pow2")
    b1 = single.self_join_blocks(points, id_lists, radii, keys=keys)
    b8 = shard.self_join_blocks(points, id_lists, radii, keys=keys)
    for i, (x, y) in enumerate(zip(b1, b8)):
        assert x.n == y.n and x.join_count == y.join_count, f"subset {i}"
        assert x.slack == y.slack, f"subset {i}"
        if x.mask is None:
            assert y.mask is None, f"subset {i}"       # r=inf skip on both
        else:
            np.testing.assert_array_equal(y.mask, x.mask,
                                          err_msg=f"subset {i}")
    assert shard.stats.sharded_dispatches >= 1
    assert shard.stats.dispatches > shard.stats.sharded_dispatches, \
        "remainder bin should have dispatched single-device"
    assert len(shard.stats.shard_dispatches) == 8
    assert sum(shard.stats.shard_dispatches[1:]) > 0
    assert shard.stats.t_collective_s > 0.0
    # cached-tile path stays sharded and bit-exact
    b8b = shard.self_join_blocks(points, id_lists, radii, keys=keys)
    for x, y in zip(b8, b8b):
        if x.mask is not None:
            np.testing.assert_array_equal(y.mask, x.mask)
    assert shard.stats.cache_hits > 0
    # a tight memory budget (chunking + shard rounding vs the clamp) keeps
    # bit-exact parity too
    tight = PallasBackend(plane=PLANE, max_block_bytes=256 << 10,
                          route="device", bin_strategy="pow2")
    bt = tight.self_join_blocks(points, id_lists, radii, keys=keys)
    for i, (x, y) in enumerate(zip(b1, bt)):
        assert x.join_count == y.join_count, f"subset {i}"
        if x.mask is not None:
            np.testing.assert_array_equal(y.mask, x.mask, err_msg=f"subset {i}")
    print("backend sharded parity ok")


def test_engine_batch_parity():
    ds = synthetic_dataset(n=500, d=8, u=20, t=2, seed=3)
    eng1 = NKSEngine(ds, m=2, n_scales=5, seed=0)
    eng8 = NKSEngine(ds, m=2, n_scales=5, seed=0, mesh=PLANE.mesh)
    queries = random_queries(ds, 2, 24, seed=5) + \
        random_queries(ds, 3, 24, seed=6)
    for tier in ("exact", "approx"):
        r1 = eng1.query_batch(queries, k=2, tier=tier, backend="pallas")
        r8 = eng8.query_batch(queries, k=2, tier=tier, backend="pallas")
        for q, a, b in zip(queries, r1, r8):
            assert [(c.ids, c.diameter) for c in a.candidates] == \
                   [(c.ids, c.diameter) for c in b.candidates], \
                   f"tier={tier} query={q}"
        st = eng8.last_batch_stats
        assert st.backend == "pallas" and st.batch_size == len(queries)
        if st.sharded_dispatches:
            assert len(st.shard_dispatches) == 8
            util = st.shard_utilisation
            assert len(util) == 8 and all(0.0 <= u <= 1.0 for u in util)
            assert st.t_collective_s > 0.0
            assert st.t_collective_s <= st.t_dispatch_s + 1e-9
    assert eng8.last_batch_stats is not None
    print("engine batch parity ok (exact+approx)")


def test_device_tier_parity():
    ds = synthetic_dataset(n=800, d=10, u=24, t=2, seed=4)
    eng8 = NKSEngine(ds, m=2, n_scales=3, seed=0, build_exact=False,
                     build_approx=False, mesh=PLANE.mesh)
    for query in random_queries(ds, 3, 3, seed=7):
        pg = PLANE.pack_groups(ds, query)
        d1, _ = nks_anchor_topk(jnp.asarray(pg.groups), jnp.asarray(pg.mask),
                                jnp.asarray(pg.ids), k=3)
        res = eng8.query(query, k=3, tier="device")
        got = [c.diameter for c in res.candidates]
        want = [float(v) for v in np.asarray(d1) if np.isfinite(v)]
        np.testing.assert_allclose(got, want, rtol=1e-5,
                                   err_msg=f"query={query}")
    out = eng8.query_batch(random_queries(ds, 3, 2, seed=8), k=2,
                           tier="device")
    st = eng8.last_batch_stats
    assert st is not None and st.tier == "device"
    assert st.backend == "device-plane"
    assert st.shard_dispatches == [2] * 8
    assert st.sharded_dispatches == 2 and st.t_collective_s > 0.0
    assert all(r.candidates for r in out)
    print("device tier parity ok")


def test_filtered_sharded_parity():
    """ISSUE-5 forced-8-device leg: filtered dispatches and filtered engine
    batches are bit-identical between the sharded and single-device routes."""
    rng = np.random.default_rng(11)
    points = rng.standard_normal((600, 10))
    sizes = [40, 44, 37, 41, 39, 45, 42, 38, 40, 43,    # class 64, sharded
             9, 11, 10]                                 # class 16, remainder
    id_lists = [np.sort(rng.choice(600, n, replace=False)).astype(np.int64)
                for n in sizes]
    radii = [2.5] * 10 + [3.0, float("inf"), 2.0]
    keys = [ids.tobytes() for ids in id_lists]
    eligible = rng.random(600) < 0.5

    single = PallasBackend(route="device", bin_strategy="pow2")
    shard = PallasBackend(plane=PLANE, route="device", bin_strategy="pow2")
    b1 = single.self_join_blocks(points, id_lists, radii, keys=keys,
                                 eligible=eligible)
    d2h_before = shard.stats.d2h_bytes
    b8 = shard.self_join_blocks(points, id_lists, radii, keys=keys,
                                eligible=eligible)
    for i, (x, y) in enumerate(zip(b1, b8)):
        assert x.join_count == y.join_count, f"subset {i}"
        assert x.n_eligible == y.n_eligible == int(eligible[id_lists[i]].sum())
        if x.mask is None:
            assert y.mask is None
        else:
            np.testing.assert_array_equal(y.mask, x.mask,
                                          err_msg=f"subset {i}")
    assert shard.stats.sharded_dispatches >= 1
    # the sharded filtered dispatch reads back exactly what the unfiltered
    # one would: the fold rides the packed mask layout
    plain = PallasBackend(plane=PLANE, route="device", bin_strategy="pow2")
    plain.self_join_blocks(points, id_lists, radii, keys=keys)
    assert shard.stats.d2h_bytes - d2h_before == plain.stats.d2h_bytes

    ds = attach_attrs(synthetic_dataset(n=500, d=8, u=20, t=2, seed=3),
                      seed=9)
    eng1 = NKSEngine(ds, m=2, n_scales=5, seed=0)
    eng8 = NKSEngine(ds, m=2, n_scales=5, seed=0, mesh=PLANE.mesh)
    queries = random_queries(ds, 2, 24, seed=5) + \
        random_queries(ds, 3, 24, seed=6)
    for flt in (where(("price", "<", 55.0)),
                where(("price", "<", 8.0), ("category", "in", [0, 1, 2])),
                where(("price", "<", -1.0))):        # 0% selectivity
        for tier in ("exact", "approx"):
            r1 = eng1.query_batch(queries, k=2, tier=tier, backend="pallas",
                                  filter=flt)
            r8 = eng8.query_batch(queries, k=2, tier=tier, backend="pallas",
                                  filter=flt)
            for q, a, b in zip(queries, r1, r8):
                assert [(c.ids, c.diameter) for c in a.candidates] == \
                       [(c.ids, c.diameter) for c in b.candidates], \
                       f"tier={tier} query={q} filter={flt}"
        st = eng8.last_batch_stats
        assert st.eligible_points is not None
    print("filtered sharded parity ok (backend + engine, 0-100% selectivity)")


def test_semantics_sharded_parity():
    """ISSUE-9 forced-8-device leg: flexible semantics answer bit-identically
    on the sharded and single-device routes; degenerate semantics are
    bit-identical to the classic sharded batch."""
    ds = synthetic_dataset(n=500, d=8, u=20, t=2, seed=3)
    eng1 = NKSEngine(ds, m=2, n_scales=5, seed=0)
    eng8 = NKSEngine(ds, m=2, n_scales=5, seed=0, mesh=PLANE.mesh)
    queries = random_queries(ds, 3, 12, seed=12)
    for sem in ({"m": 2}, {"weights": {queries[0][0]: 2.5}},
                {"m": 2, "score": True}):
        for tier in ("exact", "approx"):
            r1 = eng1.query_batch(queries, k=2, tier=tier, backend="pallas",
                                  semantics=sem)
            r8 = eng8.query_batch(queries, k=2, tier=tier, backend="pallas",
                                  semantics=sem)
            for q, a, b in zip(queries, r1, r8):
                assert [(c.ids, c.diameter, c.score) for c in a.candidates] \
                    == [(c.ids, c.diameter, c.score) for c in b.candidates], \
                    f"tier={tier} query={q} sem={sem}"
    base = eng8.query_batch(queries, k=2, tier="exact", backend="pallas")
    deg = eng8.query_batch(queries, k=2, tier="exact", backend="pallas",
                           semantics={"m": 3, "weights": {0: 1.0}})
    for a, b in zip(base, deg):
        assert [(c.ids, c.diameter) for c in a.candidates] == \
               [(c.ids, c.diameter) for c in b.candidates]
    print("semantics sharded parity ok")


def test_pack_groups_on_plane():
    ds = synthetic_dataset(n=300, d=8, u=12, t=2, seed=7)
    query = random_queries(ds, 2, 1, seed=1)[0]
    pg = PLANE.pack_groups(ds, query, r_max=10)
    assert pg.groups.shape[1] % 8 == 0
    assert pg.truncated == sum(max(s - 10, 0) for s in pg.group_sizes)
    try:
        PLANE.pack_groups(ds, query, r_max=1, strict=True)
    except ValueError:
        pass
    else:
        raise AssertionError("strict pack_groups did not raise")
    print("plane pack_groups ok")


if __name__ == "__main__":
    import jax
    assert jax.local_device_count() == 8, jax.local_device_count()
    test_sharded_join_bit_exact()
    test_backend_sharded_parity()
    test_engine_batch_parity()
    test_device_tier_parity()
    test_filtered_sharded_parity()
    test_semantics_sharded_parity()
    test_pack_groups_on_plane()
    print("ALL SHARDED OK")
