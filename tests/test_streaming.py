"""Streaming ingest: incremental index maintenance must be invisible to
search. For any interleaving of inserts/deletes/compactions, ``query_batch``
results are bit-identical (exact tier) / candidate-set identical (approx
tier) to a fresh engine built on the equivalent static corpus — asserted
here on one device and, via ``tests/streaming_script.py``, on a forced
8-device mesh. Also covers the generation-tagged backend caches: delta
absorbs keep the packed-subset/tile LRU warm, compaction purges it, and a
pre-generation entry is never served."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.backend import PallasBackend
from repro.core.index import build_index
from repro.core.types import make_dataset
from repro.data.synthetic import random_queries, synthetic_dataset
from repro.serve.engine import NKSEngine

U = 18


class Tracked:
    """Ground-truth mirror of the streaming engine: the live corpus in
    external-id order, for building equivalent static engines."""

    def __init__(self, ds, pinned):
        self.pts = [ds.points[i] for i in range(ds.n)]
        self.kws = [ds.kw.row(i).tolist() for i in range(ds.n)]
        self.alive = dict.fromkeys(range(ds.n), True)
        self.pinned = pinned

    def insert(self, pts, kws):
        for p, k in zip(pts, kws):
            self.alive[len(self.pts)] = True
            self.pts.append(p)
            self.kws.append(list(k))

    def delete(self, ext_ids):
        for i in ext_ids:
            self.alive[int(i)] = False

    def fresh(self) -> tuple[NKSEngine, np.ndarray]:
        """Equivalent static engine + its row -> external-id map."""
        ids = np.asarray(sorted(i for i, a in self.alive.items() if a))
        ds = make_dataset(np.stack([self.pts[i] for i in ids]),
                          [self.kws[i] for i in ids], n_keywords=U)
        return NKSEngine(ds, **self.pinned), ids


def assert_parity(engine, tracked, queries, k=2, backend="numpy",
                  tiers=("exact", "approx")):
    fresh, ext = tracked.fresh()
    for tier in tiers:
        got = engine.query_batch(queries, k=k, tier=tier, backend=backend)
        want = fresh.query_batch(queries, k=k, tier=tier, backend=backend)
        for q, rg, rw in zip(queries, got, want):
            cg = [(c.ids, c.diameter) for c in rg.candidates]
            cw = [(tuple(int(ext[i]) for i in c.ids), c.diameter)
                  for c in rw.candidates]
            assert cg == cw, f"tier={tier} query={q}: {cg} != {cw}"


@pytest.fixture(scope="module")
def base():
    return synthetic_dataset(n=260, d=6, u=U, t=2, seed=7)


@pytest.fixture(scope="module")
def pool():
    return synthetic_dataset(n=160, d=6, u=U, t=2, seed=8)


@pytest.fixture(scope="module")
def pinned(base):
    """Hash geometry pinned across engine rebuilds: same w0/n_buckets for the
    streaming engine, its compactions, and every fresh comparison engine —
    the precondition for approx-tier (plan-level) parity."""
    probe = build_index(base, m=2, n_scales=5, exact=True, seed=0)
    return dict(m=2, n_scales=5, seed=0, w0=probe.w0,
                n_buckets=probe.structures[0].n_buckets)


@pytest.fixture
def rig(base, pool, pinned):
    eng = NKSEngine(base, auto_compact=False, **pinned)
    return eng, Tracked(base, pinned), pool


def _chunk(pool, lo, hi):
    return pool.points[lo:hi], [pool.kw.row(i).tolist() for i in range(lo, hi)]


def test_insert_parity(rig, base):
    eng, tracked, pool = rig
    queries = random_queries(base, 2, 4, seed=3) + random_queries(base, 3, 4, seed=4)
    pts, kws = _chunk(pool, 0, 60)
    ext = eng.insert(pts, kws)
    assert ext.tolist() == list(range(260, 320))
    tracked.insert(pts, kws)
    assert eng.delta_points == 60 and eng.corpus_generation == 0
    assert_parity(eng, tracked, queries)


def test_delete_parity_bulk_and_delta(rig, base):
    """Deletes tombstone both bulk and delta points; coverage drops buckets
    whose last live holder of a keyword died (the phantom/suspect path)."""
    eng, tracked, pool = rig
    queries = random_queries(base, 3, 6, seed=5)
    pts, kws = _chunk(pool, 0, 40)
    eng.insert(pts, kws)
    tracked.insert(pts, kws)
    # bulk deletes (5 incl. points that appear in results) + delta deletes
    first = eng.query_batch(queries, k=1, tier="exact", backend="numpy")
    victim = first[0].candidates[0].ids[0]
    doomed = [victim, 7, 33, 120, 261, 285]
    eng.delete(doomed)
    tracked.delete(doomed)
    assert eng.tombstone_count == 6
    assert_parity(eng, tracked, queries)
    # the deleted point never reappears in any tier's results
    for tier in ("exact", "approx"):
        for r in eng.query_batch(queries, k=2, tier=tier, backend="numpy"):
            assert all(victim not in c.ids for c in r.candidates)


def test_interleaved_ops_parity(rig, base):
    """A scripted insert/delete/compact interleaving, parity after every op
    — the acceptance-criterion scenario."""
    eng, tracked, pool = rig
    queries = random_queries(base, 2, 3, seed=6) + random_queries(base, 3, 3, seed=7)
    rng = np.random.default_rng(11)
    cursor = 0
    for step, op in enumerate(
            ["insert", "delete", "insert", "compact", "delete",
             "insert", "compact", "insert", "delete"]):
        if op == "insert":
            pts, kws = _chunk(pool, cursor, cursor + 25)
            cursor += 25
            eng.insert(pts, kws)
            tracked.insert(pts, kws)
        elif op == "delete":
            live = sorted(i for i, a in tracked.alive.items() if a)
            doomed = rng.choice(live, size=6, replace=False).tolist()
            eng.delete(doomed)
            tracked.delete(doomed)
        else:
            assert eng.compact()
            assert eng.delta_points == 0 and eng.tombstone_count == 0
        assert_parity(eng, tracked, queries, k=2)
    assert eng.corpus_generation == 2
    assert eng.ingest.compactions == 2


def test_parity_with_pallas_backend(rig, base):
    """Bit-exact streaming-vs-fresh parity holds on the device path too
    (same subset stream -> same packed dispatches -> same masks)."""
    eng, tracked, pool = rig
    queries = random_queries(base, 3, 4, seed=8)
    pts, kws = _chunk(pool, 0, 50)
    eng.insert(pts, kws)
    tracked.insert(pts, kws)
    doomed = [3, 262, 290]
    eng.delete(doomed)
    tracked.delete(doomed)
    assert_parity(eng, tracked, queries,
                  backend=PallasBackend(interpret=True))


def test_external_ids_stable_across_compaction(rig, base):
    """Compaction remaps internal rows but results keep external ids: the
    same query answers identically (ids and diameters) before and after."""
    eng, tracked, pool = rig
    queries = random_queries(base, 2, 4, seed=9)
    pts, kws = _chunk(pool, 0, 30)
    eng.insert(pts, kws)
    eng.delete([1, 2, 263])
    before = eng.query_batch(queries, k=2, tier="exact", backend="numpy")
    assert eng.compact()
    after = eng.query_batch(queries, k=2, tier="exact", backend="numpy")
    for rb, ra in zip(before, after):
        assert [(c.ids, c.diameter) for c in rb.candidates] == \
               [(c.ids, c.diameter) for c in ra.candidates]


def test_trailing_trim_compaction_keeps_external_ids(base, pool, pinned):
    """A compaction that only removed *trailing* ids leaves the map looking
    like identity, but later inserts still need externalization: the row a
    query reports must be the external id insert() returned."""
    eng = NKSEngine(base, auto_compact=False, **pinned)
    eng.delete([base.n - 1])               # trailing id only
    assert eng.compact()
    ext = eng.insert(pool.points[:1], [pool.kw.row(0).tolist()])
    assert ext.tolist() == [base.n]        # external id keeps counting
    kws = pool.kw.row(0).tolist()
    # k covers every diameter-0 singleton (points tagged with all of kws), so
    # the inserted point must appear — under its external id, not its
    # internal row (which collides with the deleted trailing point).
    singles = sum(1 for i in range(base.n - 1)
                  if set(kws) <= set(base.kw.row(i).tolist()))
    res = eng.query_batch([kws], k=singles + 2, tier="exact",
                          backend="numpy")[0]
    all_ids = {i for c in res.candidates for i in c.ids}
    assert int(ext[0]) in all_ids, \
        f"inserted point not reported under its external id: {res.candidates}"
    assert base.n - 1 not in all_ids       # the deleted id never resurfaces
    eng.delete([int(ext[0])])              # the returned id must round-trip
    assert eng.tombstone_count == 1


def test_cache_correctness_across_generations(rig, base):
    """Satellite: after insert -> query -> compact -> query, the backend LRU
    must never serve a pre-generation packed subset or device tile. Absorbs
    retain entries (hit rate survives ingest); compaction purges; the first
    post-compaction batch is parity-checked against a cold engine."""
    eng, tracked, pool = rig
    queries = random_queries(base, 3, 6, seed=10)
    be = PallasBackend(interpret=True)
    eng.query_batch(queries, k=2, tier="exact", backend=be)
    h0, m0 = be.stats.cache_hits, be.stats.cache_misses
    eng.query_batch(queries, k=2, tier="exact", backend=be)
    assert be.stats.cache_hits > h0          # steady state: warm
    assert be.stats.cache_misses == m0

    pts, kws = _chunk(pool, 0, 40)
    eng.insert(pts, kws)
    tracked.insert(pts, kws)
    h1 = be.stats.cache_hits
    eng.query_batch(queries, k=2, tier="exact", backend=be)
    # delta absorb must NOT clear the cache: unchanged subsets still hit
    assert be.stats.cache_hits > h1
    assert be.stats.generation_purges == 0

    assert eng.compact()
    h2, m2 = be.stats.cache_hits, be.stats.cache_misses
    got = eng.query_batch(queries, k=2, tier="exact", backend=be)
    # generation bump: every entry purged, nothing pre-generation served
    assert be.stats.generation_purges == 1
    assert be.stats.cache_hits == h2 and be.stats.cache_misses > m2
    cold, ext = tracked.fresh()
    want = cold.query_batch(queries, k=2, tier="exact",
                            backend=PallasBackend(interpret=True))
    for rg, rw in zip(got, want):
        assert [(c.ids, c.diameter) for c in rg.candidates] == \
               [(tuple(int(ext[i]) for i in c.ids), c.diameter)
                for c in rw.candidates]


def test_auto_compaction_cadence(base, pool, pinned):
    eng = NKSEngine(base, compact_min=50, compact_ratio=0.1, **pinned)
    pts, kws = _chunk(pool, 0, 30)
    eng.insert(pts, kws)
    assert eng.corpus_generation == 0 and eng.delta_points == 30
    pts, kws = _chunk(pool, 30, 60)
    eng.insert(pts, kws)        # churn 60 >= max(50, 26) -> compacts
    assert eng.corpus_generation == 1
    assert eng.delta_points == 0 and eng.tombstone_count == 0
    assert eng.ingest.compactions == 1 and eng.ingest.generation == 1
    # ingest counters flow into PipelineStats
    eng.query_batch(random_queries(base, 2, 2, seed=1), tier="approx",
                    backend="numpy")
    st = eng.last_batch_stats
    assert st.corpus_generation == 1 and st.compactions == 1
    assert st.delta_points == 0 and st.tombstones == 0
    assert st.ingest == {"generation": 1, "delta_points": 0,
                         "tombstones": 0, "compactions": 1}


def test_single_query_path_and_device_tier(rig, base):
    """engine.query() routes through the delta-aware pipeline while dirty,
    and the device tier packs live points only."""
    eng, tracked, pool = rig
    pts, kws = _chunk(pool, 0, 20)
    eng.insert(pts, kws)
    tracked.insert(pts, kws)
    eng.delete([0, 261])
    tracked.delete([0, 261])
    q = random_queries(base, 2, 1, seed=12)[0]
    single = eng.query(q, k=2, tier="exact")
    batch = eng.query_batch([q], k=2, tier="exact", backend="numpy")[0]
    assert [(c.ids, c.diameter) for c in single.candidates] == \
           [(c.ids, c.diameter) for c in batch.candidates]
    res = eng.query(q, k=1, tier="device")
    assert res.candidates
    assert all(0 not in c.ids and 261 not in c.ids for c in res.candidates)


def test_ingest_validation(rig):
    eng, _, pool = rig
    with pytest.raises(ValueError):
        eng.insert(np.zeros((2, 3), np.float32), [[1], [2]])   # wrong dim
    with pytest.raises(ValueError):
        eng.insert(np.zeros((1, 6), np.float32), [[U + 5]])    # unknown kw
    with pytest.raises(ValueError):
        eng.insert(np.zeros((2, 6), np.float32), [[1]])        # length mismatch
    with pytest.raises(KeyError):
        eng.delete([10_000])                                   # unknown id
    eng.delete([5])
    with pytest.raises(KeyError):
        eng.delete([5])                                        # double delete
    with pytest.raises(KeyError):
        eng.delete([6, 6])                                     # in-batch dup
    assert eng.tombstone_count == 1                            # 6 not applied
    assert eng.delete([]) == 0


def test_delete_everything_does_not_autocompact(base, pinned):
    """Deleting the last live point must succeed (tombstones apply) without
    the auto-compaction cadence trying to rebuild an empty index; an
    explicit compact on the empty corpus still refuses."""
    small = make_dataset(base.points[:8],
                         [base.kw.row(i).tolist() for i in range(8)],
                         n_keywords=U)
    eng = NKSEngine(small, compact_min=2, compact_ratio=0.1, **pinned)
    with pytest.raises(ValueError):    # failed insert mutates nothing
        eng.insert(np.zeros((1, 5), np.float32), [[0]])
    eng.delete(list(range(8)))
    assert eng.tombstone_count == 8
    for tier in ("exact", "approx"):
        assert eng.query_batch([[0, 1]], k=1, tier=tier,
                               backend="numpy")[0].candidates == []
    with pytest.raises(ValueError):
        eng.compact()
    ids = eng.insert(base.points[8:10],
                     [base.kw.row(i).tolist() for i in range(8, 10)])
    assert ids.tolist() == [8, 9]
    assert eng.compact() or eng.corpus_generation >= 1


def test_serve_launcher_ingest_ops(tmp_path):
    """The JSONL request stream interleaves queries with ingest ops."""
    reqs = [
        {"keywords": [0, 1], "k": 1},
        {"op": "insert", "points": [[5.0] * 8, [6.0] * 8],
         "keywords": [[0, 1], [1, 2]]},
        {"keywords": [0, 1], "k": 1},
        {"op": "delete", "ids": [0]},
        {"op": "compact"},
        {"keywords": [0, 1], "k": 1},
    ]
    f = tmp_path / "reqs.jsonl"
    f.write_text("".join(__import__("json").dumps(r) + "\n" for r in reqs))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--n", "300", "--d", "8",
         "--u", "30", "--t", "3", "--tier", "approx", "--requests", str(f)],
        env=env, capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stderr
    lines = [__import__("json").loads(line) for line in
             proc.stdout.strip().splitlines()]
    assert len(lines) == len(reqs)
    assert lines[1]["op"] == "insert" and lines[1]["ids"] == [300, 301]
    assert lines[1]["delta_points"] == 2
    assert lines[3]["op"] == "delete" and lines[3]["deleted"] == 1
    assert lines[4]["op"] == "compact" and lines[4]["compacted"] is True
    assert lines[4]["generation"] == 1 and lines[4]["delta_points"] == 0
    assert all(line["results"] for line in (lines[0], lines[2], lines[5]))


@pytest.mark.timeout(600)
def test_streaming_sharded_suite():
    """Acceptance: the same interleaving parity on a forced 8-device mesh
    (subprocess — the device count locks at first jax init)."""
    script = os.path.join(os.path.dirname(__file__), "streaming_script.py")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, script], env=env,
                          capture_output=True, text=True, timeout=580)
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "ALL STREAMING SHARDED OK" in proc.stdout
