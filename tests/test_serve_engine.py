"""Serving engine: tier consistency, device-tier quality, embedding ingestion."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import brute_force
from repro.data.flickr_like import flickr_like_dataset
from repro.data.synthetic import random_queries
from repro.serve.engine import NKSEngine


@pytest.fixture(scope="module")
def engine():
    ds = flickr_like_dataset(n=1_500, d=16, u=30, t=3, n_clusters=10, seed=4)
    return NKSEngine(ds, m=2, n_scales=5, seed=0)


def test_exact_tier_matches_oracle(engine):
    for query in random_queries(engine.dataset, 3, 4, seed=1):
        res = engine.query(query, k=2, tier="exact")
        truth = brute_force.search(engine.dataset, query, k=2)
        np.testing.assert_allclose([c.diameter for c in res.candidates],
                                   [c.diameter for c in truth.items], rtol=1e-5)


def test_device_tier_within_2x(engine):
    """Anchor-star kernel: 2-approximation by the triangle inequality.
    Tolerance accounts for fp32 distance noise (the tier is a fast filter;
    exact rescoring is float64 on the control plane)."""
    eps = 1.0   # fp32 sq-distance noise at this coordinate scale (~250)
    for query in random_queries(engine.dataset, 3, 6, seed=2):
        res = engine.query(query, k=1, tier="device")
        truth = brute_force.search(engine.dataset, query, k=1).items[0]
        assert res.candidates, f"no device-tier result for {query}"
        got = res.candidates[0].diameter
        assert got <= 2.0 * truth.diameter + eps
        assert got >= truth.diameter - eps


def test_approx_tier_returns_k(engine):
    for query in random_queries(engine.dataset, 2, 4, seed=3):
        res = engine.query(query, k=3, tier="approx")
        assert len(res.candidates) == 3
        diams = [c.diameter for c in res.candidates]
        assert diams == sorted(diams)


def test_query_batch(engine):
    queries = random_queries(engine.dataset, 2, 3, seed=5)
    out = engine.query_batch(queries, k=1, tier="approx")
    assert len(out) == 3
    assert all(r.latency_s >= 0 for r in out)


def test_ingest_embeddings_roundtrip():
    """Embeddings from a smoke arch flow into a queryable index."""
    from repro.configs import get_config
    from repro.models.api import model_api
    cfg = get_config("minicpm-2b").smoke()
    api = model_api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batches = [{"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 8)),
                                      jnp.int32)} for _ in range(2)]
    keywords = [[int(rng.integers(0, 5)), int(rng.integers(0, 5))]
                for _ in range(8)]
    eng = NKSEngine.ingest_embeddings(api, params, batches, keywords,
                                      n_scales=3)
    assert eng.dataset.n == 8
    assert eng.dataset.dim == cfg.d_model
    kws = sorted({k for ks in keywords for k in ks})
    res = eng.query(kws[:2], k=1, tier="exact")
    assert res.candidates and np.isfinite(res.candidates[0].diameter)
