"""Figs. 13/18 — query time vs result size k. ProMiSH linear in k."""
from __future__ import annotations

from benchmarks.common import emit, promish_suite
from repro.data.synthetic import random_queries, synthetic_dataset

KS = (1, 5, 10, 20)


def main(fast: bool = False):
    ks = KS[:2] if fast else KS
    n = 5_000 if fast else 50_000
    ds = synthetic_dataset(n=n, d=50, u=200, t=1, seed=0)
    queries = random_queries(ds, 3, 3 if fast else 5, seed=1)
    for k in ks:
        res = promish_suite(ds, queries, k=k, run_tree=False)
        emit(f"fig13.promish_e.k{k}", res["promish_e"] * 1e6, f"N={n} d=50")
        emit(f"fig13.promish_a.k{k}", res["promish_a"] * 1e6, f"N={n} d=50")


if __name__ == "__main__":
    main()
