"""Generate the final §Roofline markdown table + flash-adjusted estimates.

    PYTHONPATH=src python -m benchmarks.report artifacts/dryrun

For prefill cells it also reports a flash-adjusted memory term: the HLO
census identifies nested-loop computations containing dots (the attention
inner KV loops — the traffic the Pallas flash kernel keeps in VMEM on TPU)
and subtracts their scaled output bytes from the memory term. Train cells
are not adjusted (the forward flash kernel alone doesn't remove the
backward attention traffic).
"""
from __future__ import annotations

import glob
import gzip
import json
import os
import sys

from benchmarks.roofline import HBM_BW, roofline_row
from repro.launch.hlo_census import census, parse_hlo


def attention_loop_bytes(hlo_text: str, n_layer_scan: int) -> float:
    """Scaled out_bytes of dot-bearing loop bodies nested deeper than the
    layer scan (== attention inner KV loops in these models)."""
    comps = parse_hlo(hlo_text)
    called, fusion_targets = set(), set()
    for c in comps.values():
        for b, cond in c.while_bodies:
            called.add(b)
            called.add(cond)
        called.update(c.called)
        fusion_targets.update(c.called)
    entries = [n for n in comps if n not in called]
    mult = {n: 0.0 for n in comps}
    for e in entries:
        mult[e] = 1.0
    for _ in range(len(comps)):
        ch = False
        for name, c in comps.items():
            m = mult.get(name, 0.0)
            if m == 0.0:
                continue
            for b, cond in c.while_bodies:
                trips = max(comps[cond].int_consts) if (
                    cond in comps and comps[cond].int_consts) else (
                    comps[b].ds_lead if b in comps else 1)
                for t2, tm in ((b, m * trips), (cond, m * trips)):
                    if t2 in mult and mult[t2] < tm:
                        mult[t2] = tm
                        ch = True
            for t in c.called:
                if t in mult and mult[t] < m:
                    mult[t] = m
                    ch = True
        if not ch:
            break
    total = 0.0
    for name, c in comps.items():
        m = mult.get(name, 1.0)
        if name in fusion_targets or m <= n_layer_scan:
            continue
        if c.dots:
            total += c.out_bytes * m
    return total


def lever(row: dict) -> str:
    """One sentence per (arch, mesh): what moves the dominant term down."""
    dom, cell = row["dominant"], row["cell"]
    if cell.startswith("decode") or cell.startswith("long"):
        if dom == "memory":
            return ("batch-bound weight/cache reads: larger decode batch, "
                    "int8/KV-quant, or speculative decoding")
        return ("small-payload collectives dominate one-token steps: fuse "
                "per-layer reduces, widen decode batch")
    if dom == "memory":
        if cell.startswith("prefill"):
            return ("attention-score HBM traffic: fused flash kernel "
                    "(iter 7 — see flash-adj column)")
        return ("flash-attention backward + bf16 residual/collective dtypes "
                "(CPU census counts f32)")
    if dom == "collective":
        return ("TP output all-reduces: Megatron sequence parallelism "
                "(RS+AG), overlap with compute; pod axis -> int8 EF "
                "compression (train.grad_compress)")
    return "MXU-bound: near roofline for this shape; raise arithmetic intensity"


def main(art_dir: str = "artifacts/dryrun"):
    print("| arch | cell | mesh | compute s | memory s | collective s | "
          "dominant | useful | fraction | flash-adj mem s | lever |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        rec = json.load(open(path))
        row = roofline_row(rec)
        if row is None or row.get("error"):
            print(f"| {rec['arch']} | {rec['cell']} | {rec['mesh']} | ERROR "
                  "| | | | | | |")
            continue
        flash = ""
        hlo_path = path.replace(".json", ".hlo.gz")
        if rec["cell"].startswith("prefill") and os.path.exists(hlo_path):
            from repro.configs import get_config
            from repro.models.transformer import n_blocks
            cfg = get_config(rec["arch"])
            try:
                nb = n_blocks(cfg) if cfg.family != "audio" else cfg.n_layers
            except ValueError:
                nb = cfg.n_layers
            ab = attention_loop_bytes(gzip.open(hlo_path, "rt").read(), nb)
            adj = max(row["t_memory_s"] - ab / HBM_BW, 0.0)
            flash = f"{adj:.3f}"
        print(f"| {row['arch']} | {row['cell']} | {row['mesh']} "
              f"| {row['t_compute_s']:.4f} | {row['t_memory_s']:.4f} "
              f"| {row['t_collective_s']:.4f} | {row['dominant']} "
              f"| {row['useful_ratio']:.3f} | {row['roofline_fraction']:.4f} "
              f"| {flash} | {lever(row)} |")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun")
