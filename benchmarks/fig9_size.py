"""Fig. 9 — query time vs dataset size N (25-d synthetic, q=5, top-1).
ProMiSH linear in N; tree times out beyond small N."""
from __future__ import annotations

from benchmarks.common import emit, promish_suite
from repro.data.synthetic import random_queries, synthetic_dataset

SIZES = (2_000, 10_000, 30_000, 100_000)


def main(fast: bool = False):
    sizes = SIZES[:2] if fast else SIZES
    for n in sizes:
        ds = synthetic_dataset(n=n, d=25, u=1_000, t=1, seed=n)
        queries = random_queries(ds, 5, 3 if fast else 5, seed=n)
        res = promish_suite(ds, queries, k=1, run_tree=(n <= 10_000),
                            tree_budget=100_000)
        emit(f"fig9.promish_e.n{n}", res["promish_e"] * 1e6, "d=25")
        emit(f"fig9.promish_a.n{n}", res["promish_a"] * 1e6, "d=25")
        if "tree" in res:
            emit(f"fig9.vbrtree.n{n}", res["tree"] * 1e6,
                 f"timeouts={res['tree_timeouts']}")


if __name__ == "__main__":
    main()
