"""Fig. 9 — query time vs dataset size N, through the real serving engine.

The paper's size sweep (25-d corpus, top-1: ProMiSH linear in N, the tree
baseline times out beyond small N), upgraded from the per-query search
sketch to the batched engine — and to the out-of-core store. The corpus is
the clustered flickr-like generator (queries sampled from real tag sets) so
the filtered leg has attribute-space locality for the zone maps to exploit:

    PYTHONPATH=src python -m benchmarks.fig9_size [--fast] \
        [--store disk|ram] [--sizes N,N,...] [--store-dir DIR]

``--store ram`` builds the index in memory (synopses attached); ``--store
disk`` builds the columnar bulk store on disk (``repro.core.store``) and
opens the engine over memory-mapped leaves with a resident budget of 1/4 the
store's point bytes — the corpus is deliberately >= 4x larger than the hot
tier, so the sweep exercises the mmap cold path. Every size also runs a
filtered batch against a spatially-correlated attribute so the per-bucket
zone maps have something to prune; the trajectory records the
``buckets_pruned_zonemap`` / ``cold_bytes_read`` counters alongside QPS.

Writes ``BENCH_size.json``; the ``tiers`` entry (keyed by store mode, at the
largest size swept) feeds ``check_regression.py``'s size gate. The non-fast
sweep reaches 1M points.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import shutil
import tempfile
import time

OUT = "BENCH_size.json"
SIZES = (2_000, 10_000, 100_000, 1_000_000)
FAST_SIZES = (2_000, 10_000)


def _sized_dataset(n: int):
    """25-d clustered corpus (the flickr-like generator, dictionary scaled
    with N) with one spatially-correlated attribute: ``price`` tracks
    coordinate 0, which is near-constant within a cluster — so buckets
    (spatial cells) carry tight price zone maps and a threshold filter can
    prune whole buckets. A uniform corpus would leave zone maps vacuous;
    attribute-space locality is the precondition for any zone map to pay."""
    import numpy as np

    from repro.data.flickr_like import flickr_like_dataset

    ds = flickr_like_dataset(n=n, d=25, u=max(100, n // 100), t=3,
                             n_clusters=64, seed=n)
    price = (ds.points[:, 0] / 2.55).astype(np.float64)  # ~[0, 100]
    return dataclasses.replace(ds, attrs={"price": price})


def _point_queries(ds, n_queries: int, seed: int) -> list[list[int]]:
    """Queries sampled from real points' tag sets (the NKS workload shape:
    keywords that actually co-occur, so covering buckets exist at every N)."""
    import numpy as np

    rng = np.random.default_rng(seed)
    idx = rng.choice(ds.n, size=n_queries, replace=False)
    return [sorted(set(ds.kw.row(int(i)).tolist()))[:3] for i in idx]


def _open_engine(ds, store: str, store_dir: str | None):
    """Returns (engine, meta) for one sweep point; meta records the storage
    footprint (and, in disk mode, the budget the hot tier was capped at)."""
    from repro.core import store as storemod
    from repro.serve.engine import NKSEngine

    if store == "ram":
        engine = NKSEngine(ds, m=2, n_scales=5, seed=0, synopsis=True)
        return engine, {"resident": True}, None
    tmp = store_dir or tempfile.mkdtemp(prefix="nks-size-")
    t0 = time.perf_counter()
    storemod.build_store(os.path.join(tmp, f"store-{ds.n}"), ds,
                         m=2, n_scales=5, seed=0)
    build_s = time.perf_counter() - t0
    root = os.path.join(tmp, f"store-{ds.n}")
    point_bytes = ds.points.nbytes
    budget = max(1 << 20, point_bytes // 4)
    engine = NKSEngine.from_store(root, mmap=True,
                                  resident_budget_bytes=budget)
    meta = {
        "resident": False,
        "store_bytes": storemod.store_nbytes(root),
        "point_bytes": point_bytes,
        "resident_budget_bytes": budget,
        "corpus_over_budget": round(point_bytes / budget, 2),
        "build_store_s": round(build_s, 3),
    }
    return engine, meta, (None if store_dir else tmp)


def main(fast: bool = False, store: str = "ram",
         sizes: tuple[int, ...] | None = None,
         store_dir: str | None = None) -> dict:
    from benchmarks.common import emit

    sizes = sizes or (FAST_SIZES if fast else SIZES)
    k, q = 1, 3
    n_queries = 4 if fast else 16
    flt = {"where": [["price", "<", 30.0]]}

    points: dict[str, dict] = {}
    last: dict = {}
    for n in sizes:
        ds = _sized_dataset(n)
        queries = _point_queries(ds, n_queries, seed=n + 1)
        engine, meta, cleanup = _open_engine(ds, store, store_dir)
        try:
            row: dict = dict(meta)
            for tier in ("exact", "approx"):
                engine.query_batch(queries, k=k, tier=tier)   # warm
                t0 = time.perf_counter()
                engine.query_batch(queries, k=k, tier=tier)
                dt = time.perf_counter() - t0
                row[f"qps_{tier}"] = n_queries / dt
                row[f"us_per_query_{tier}"] = 1e6 * dt / n_queries
                emit(f"fig9.engine_{tier}.{store}.n{n}",
                     1e6 * dt / n_queries, f"d=25 B={n_queries}")
            # Filtered batch: the zone-map counters are the point — on a
            # synopsized engine a spatial-slab predicate must prune buckets.
            engine.query_batch(queries, k=k, tier="approx", filter=flt)
            st = engine.last_batch_stats
            row["filtered"] = {
                "selectivity": st.filter_selectivity,
                **st.tiering,
            }
            points[str(n)] = row
            last = row
        finally:
            if cleanup is not None:
                shutil.rmtree(cleanup, ignore_errors=True)

    results = {
        "fast": fast, "store": store, "sizes": list(sizes),
        "k": k, "q": q, "batch": n_queries,
        "points": points,
        # Gate shape: one "tier" per store mode, metrics at the largest
        # size swept (the size axis itself is the trajectory above).
        "tiers": {store: {
            "qps_exact": last.get("qps_exact"),
            "qps_approx": last.get("qps_approx"),
        }},
    }
    with open(OUT, "w") as f:
        json.dump(results, f, indent=2)
    print(f"# wrote {os.path.abspath(OUT)}")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    default=os.environ.get("BENCH_FAST", "") == "1")
    ap.add_argument("--store", choices=("ram", "disk"), default="ram")
    ap.add_argument("--sizes", type=str, default=None,
                    help="comma-separated size override")
    ap.add_argument("--store-dir", default=None,
                    help="build disk stores here (kept) instead of a "
                         "per-size tmpdir (removed)")
    args = ap.parse_args()
    main(fast=args.fast, store=args.store,
         sizes=tuple(int(s) for s in args.sizes.split(","))
         if args.sizes else None,
         store_dir=args.store_dir)
