"""Filtered-NKS benchmark: selectivity sweep per tier (ISSUE 5).

Measures the batched pipeline under attribute predicates from 100% down to
0% selectivity, per tier (exact/approx) and backend (pallas/numpy), and
records the predicate-pushdown accounting the acceptance criteria gate on:

  * QPS at each selectivity (the headline: planning prunes fully-ineligible
    subsets, the empty-join drop fires on eligible-pair counts, so lower
    selectivity should never be *slower* than unfiltered once caches warm);
  * ``filtered_subsets`` — covering-bucket subsets pruned before any pack;
  * ``d2h_bytes`` / ``h2d_bytes`` — the transfer contract: eligibility folds
    into the existing packed join bitmask, so a filtered dispatch reads back
    exactly the bytes an unfiltered one would (``d2h_per_dispatch`` constant
    across the sweep); the filter's only traffic is packed eligibility words
    H2D.

    PYTHONPATH=src python -m benchmarks.bench_filtered --fast
    PYTHONPATH=src python -m benchmarks.bench_filtered --mesh 8

Writes ``BENCH_filtered.json``; ``benchmarks/check_regression.py`` gates the
per-selectivity QPS against the committed ``BENCH_filtered_baseline.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np


def time_batch(engine, queries, k, tier, backend, flt, repeats=3):
    """Best-of-N batch wall time (same policy as bench_batch_engine)."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        engine.query_batch(queries, k=k, tier=tier, backend=backend,
                           filter=flt)
        best = min(best, time.perf_counter() - t0)
    return best


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1500)
    ap.add_argument("--d", type=int, default=16)
    ap.add_argument("--u", type=int, default=40)
    ap.add_argument("--t", type=int, default=2)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--k", type=int, default=2)
    ap.add_argument("--fast", action="store_true",
                    help="smaller corpus/batch, fewer repeats (CI)")
    ap.add_argument("--mesh", type=int, default=0,
                    help="force N host devices and attach the serving mesh")
    ap.add_argument("--out", default="BENCH_filtered.json")
    args = ap.parse_args()
    if args.fast:
        args.n, args.batch = min(args.n, 1500), min(args.batch, 16)
    if args.mesh:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.mesh} "
            + os.environ.get("XLA_FLAGS", ""))

    from repro.core.backend import PallasBackend
    from repro.core.filters import where
    from repro.data.synthetic import (attach_attrs, random_queries,
                                      synthetic_dataset)
    from repro.serve.engine import NKSEngine

    ds = attach_attrs(synthetic_dataset(n=args.n, d=args.d, u=args.u,
                                        t=args.t, seed=0), seed=1)
    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_serving_mesh
        mesh = make_serving_mesh(data=args.mesh)
    engine = NKSEngine(ds, m=2, n_scales=5, seed=0, mesh=mesh)
    queries = random_queries(ds, 2, args.batch // 2, seed=1) + \
        random_queries(ds, 3, args.batch - args.batch // 2, seed=2)
    repeats = 2 if args.fast else 3
    selectivities = [1.0, 0.5, 0.25, 0.1, 0.01, 0.0]

    out = {"n": args.n, "d": args.d, "batch": len(queries), "k": args.k,
           "fast": bool(args.fast), "mesh": args.mesh or 1, "tiers": {}}
    for tier in ("exact", "approx"):
        tier_out = {"sweep": []}
        # One backend instance per tier: the packed-subset/tile LRU carries
        # across the sweep exactly as a serving process would run it, so the
        # numbers show the cache-sharing across filters, not cold packs.
        pallas = PallasBackend(plane=engine.plane)
        # unfiltered reference point
        t_ref = time_batch(engine, queries, args.k, tier, pallas, None,
                           repeats)
        ref_stats = engine.last_batch_stats
        ref_dispatch = max(ref_stats.total_dispatches, 1)
        tier_out["unfiltered_qps"] = round(len(queries) / t_ref, 3)
        tier_out["unfiltered_d2h_bytes"] = ref_stats.d2h_bytes
        tier_out["unfiltered_d2h_per_dispatch"] = (
            round(ref_stats.d2h_bytes / ref_dispatch)
            if ref_stats.d2h_bytes else 0)
        for sel in selectivities:
            flt = where(("price", "<", 100.0 * sel))
            t_pallas = time_batch(engine, queries, args.k, tier, pallas, flt,
                                  repeats)
            st = engine.last_batch_stats
            t_numpy = time_batch(engine, queries, args.k, tier, "numpy", flt,
                                 repeats)
            dispatches = max(st.total_dispatches, 1)
            row = {
                "selectivity": sel,
                "eligible_points": st.eligible_points,
                "pallas_qps": round(len(queries) / t_pallas, 3),
                "numpy_qps": round(len(queries) / t_numpy, 3),
                "filtered_subsets": st.filtered_subsets,
                "dispatches": st.total_dispatches,
                "h2d_bytes": st.h2d_bytes,
                "d2h_bytes": st.d2h_bytes,
                "d2h_per_dispatch": (round(st.d2h_bytes / dispatches)
                                     if st.d2h_bytes else 0),
                "cache_hit_rate": st.phases["cache_hit_rate"],
                "phases": st.phases,
            }
            if args.mesh:
                row["sharding"] = st.sharding
            tier_out["sweep"].append(row)
            tier_out[f"qps@{sel}"] = row["pallas_qps"]
        # The gated aggregate: geometric-mean QPS over the sweep. Individual
        # selectivity points are microsecond-scale on the fast profile and
        # wobble several-x run to run on shared CI cores; the geomean is the
        # stable signal a pushdown regression actually moves.
        qps = [r["pallas_qps"] for r in tier_out["sweep"] if r["pallas_qps"]]
        tier_out["sweep_geomean_qps"] = round(
            float(np.exp(np.mean(np.log(qps)))), 3) if qps else 0.0
        # The transfer contract, recorded where the bench can see it whole:
        # at 100% selectivity the filter prunes nothing, so the filtered
        # batch plans the *identical* dispatch set — its D2H must match the
        # unfiltered run byte-for-byte (eligibility rides the existing
        # packed mask). check_regression hard-fails on a false here. Below
        # 100% the dispatch SET changes (pruning shrinks it, but a filter
        # can also delay Lemma-2 termination into extra scales or the
        # fallback), so total D2H is not monotone — per-dispatch layout
        # equality at full selectivity is the invariant, totals are data.
        full = tier_out["sweep"][0]
        assert full["selectivity"] == 1.0
        tier_out["d2h_match_at_full_selectivity"] = (
            full["d2h_bytes"] == ref_stats.d2h_bytes)
        if not tier_out["d2h_match_at_full_selectivity"]:
            sys.stderr.write(
                f"WARNING: {tier}: filtered-at-100% d2h "
                f"{full['d2h_bytes']} != unfiltered {ref_stats.d2h_bytes} "
                f"— eligibility fold added readback traffic\n")
        out["tiers"][tier] = tier_out
        sys.stderr.write(
            f"{tier}: unfiltered {tier_out['unfiltered_qps']} qps; " +
            "; ".join(f"{r['selectivity']:.0%}->{r['pallas_qps']}qps"
                      f"({r['filtered_subsets']}pruned)"
                      for r in tier_out["sweep"]) + "\n")

    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    sys.stderr.write(f"wrote {args.out}\n")


if __name__ == "__main__":
    main()
