"""Shared benchmark scaffolding.

Every benchmark prints ``name,us_per_call,derived`` CSV rows (one per paper
figure/table point). The reference machine is this container's single CPU
core — absolute times differ from the paper's 2010s Xeon, but the *shapes*
(linearity in N, d, q, k; orders-of-magnitude gap to the tree baseline) are
the reproduction targets.
"""
from __future__ import annotations

import time

from repro.core import promish_a, promish_e
from repro.core.baseline_tree import VirtualBRTree
from repro.core.index import build_index

HEADER = "name,us_per_call,derived"


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")


def time_queries(fn, queries, repeats: int = 1) -> float:
    """Mean seconds per query."""
    t0 = time.perf_counter()
    for _ in range(repeats):
        for q in queries:
            fn(q)
    return (time.perf_counter() - t0) / (repeats * len(queries))


def promish_suite(ds, queries, k: int = 1, *, seed: int = 0,
                  tree_budget: int = 200_000, run_tree: bool = True,
                  n_scales: int = 5):
    """Returns dict of mean query seconds for E / A (/ tree) on a dataset."""
    idx_e = build_index(ds, m=2, n_scales=n_scales, exact=True, seed=seed)
    idx_a = build_index(ds, m=2, n_scales=n_scales, exact=False, seed=seed)
    out = {
        "promish_e": time_queries(
            lambda q: promish_e.search(ds, idx_e, q, k=k), queries),
        "promish_a": time_queries(
            lambda q: promish_a.search(ds, idx_a, q, k=k), queries),
    }
    out["index_bytes_e"] = idx_e.nbytes()
    out["index_bytes_a"] = idx_a.nbytes()
    if run_tree:
        tree = VirtualBRTree(ds, leaf_size=min(1000, max(32, ds.n // 50)),
                             fanout=100)
        timeouts = 0

        def tree_q(q):
            nonlocal timeouts
            _, to, _ = tree.search(q, k=k, budget=tree_budget)
            timeouts += int(to)

        out["tree"] = time_queries(tree_q, queries)
        out["tree_timeouts"] = timeouts
        out["tree_bytes"] = tree.nbytes()
    return out
