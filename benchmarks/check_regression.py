"""CI bench-regression gate: fail the job when fast-tier QPS regresses.

Compares the freshly written benchmark trajectories against their committed
baselines and exits non-zero when any gated metric dropped by more than
``--threshold`` (default 40% — generous, because CI runs on shared runners
whose absolute throughput wobbles; the gate is meant to catch real
regressions like the pre-PR-2 41x exact-tier cliff, not scheduler noise):

    PYTHONPATH=src python -m benchmarks.check_regression \
        [--fresh BENCH_batch.json] [--baseline BENCH_baseline.json] \
        [--filtered-fresh BENCH_filtered.json] \
        [--filtered-baseline BENCH_filtered_baseline.json]

Gated metrics, batch bench: per tier — **both exact and approx** — the
batched-pipeline QPS for both backends plus the per-query loop rate.
Filtered bench (ISSUE 5): per tier, the unfiltered reference QPS and the
geometric-mean QPS over the selectivity sweep (per-point ``qps@<sel>``
values are recorded but too noisy to gate at fast-profile batch sizes),
plus a hard failure when the bench recorded
``d2h_match_at_full_selectivity: false`` — the eligibility fold must never
add readback traffic, regardless of throughput.
Serving bench (ISSUE 7): per tier, sync and runtime sustained QPS
(higher-better, ``--threshold``) and runtime p99 latency (lower-better,
``--serving-latency-threshold``), plus a hard failure when the async
runtime's QPS drops materially below the synchronous loop's.
Size sweep (ISSUE 8): per store mode, the engine QPS at the largest size
swept — warn-only until ``BENCH_size_baseline.json`` is committed.

Each section runs through one shared ``_run_gate`` helper, which owns the
warn-until-baseline-committed / warn-on-missing-fresh semantics.

The sharded (``--mesh N``) extras are deliberately NOT gated: the
forced-8-device run's top-level tier metrics still measure single-device
dispatch math (host-platform devices share one CPU), so they remain
comparable to the single-device baseline, while the ``sharded.*`` numbers
would not be. A missing fresh file is a *warning* (the bench steps are
non-blocking in CI; the gate must not mask a bench's own failure mode)
unless ``--require-fresh`` is set; a missing batch baseline is an error —
regenerate with ``bench_batch_engine --fast`` / ``bench_filtered --fast``
and commit.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# ``batch_auto_qps`` (cost-model routed engine, PR 6) joins the gate as soon
# as the committed baseline records it — ``compare`` skips metrics the
# baseline doesn't have yet, so pre-PR-6 baselines gate the original trio
# only (warn-only semantics for the new fields until baselines regenerate).
GATED = ("batch_pallas_qps", "batch_numpy_qps", "loop_qps", "batch_auto_qps")
# Filtered sweep: gate the unfiltered reference and the sweep geomean. The
# individual ``qps@<sel>`` points are recorded in the trajectory for
# inspection but not gated — at fast-profile batch sizes they wobble
# several-x run to run on shared runners, far beyond the 40% threshold's
# intent.
GATED_FILTERED = ("unfiltered_qps", "sweep_geomean_qps")
# Serving bench (ISSUE 7): sustained throughput through the async runtime
# and the synchronous reference, plus tail latency. ``p99_ms_runtime`` is
# LOWER-better — compare() inverts its ratio so one threshold governs both
# directions (a ratio of 0.5 always means "twice as bad as baseline").
GATED_SERVING = ("qps_sync", "qps_sustained_runtime")
GATED_SERVING_LOWER = ("p99_ms_runtime",)
# Out-of-core size sweep (ISSUE 8): engine QPS at the largest size swept,
# keyed by store mode ("ram"/"disk"). Armed once ``BENCH_size_baseline.json``
# was committed (ISSUE 9) — before that the gate warned and skipped.
GATED_SIZE = ("qps_exact", "qps_approx")
# Flexible semantics (ISSUE 9): classic vs m-of-k vs weighted vs scored QPS
# per tier, both backends. Hard gate since ``BENCH_semantics_baseline.json``
# was committed (ISSUE 10); the ``degenerate_parity`` contract hard-fails
# on top of the perf thresholds.
GATED_SEMANTICS = ("classic_qps", "m_of_k_qps", "weighted_qps", "scored_qps",
                   "classic_pallas_qps", "m_of_k_pallas_qps",
                   "weighted_pallas_qps", "scored_pallas_qps")
# Ingestion pipeline (ISSUE 10): sustained docs/s through the job-queue
# worker pipeline under a Poisson arrival process, plus the static-mix
# ingest tiers. Warn-only until ``BENCH_ingest_baseline.json`` is committed.
GATED_INGEST = ("docs_per_s", "qps_sustained", "qps_static")


def compare(fresh: dict, baseline: dict, threshold: float,
            metrics=GATED, lower_better=()) -> tuple[list[tuple], list[tuple]]:
    """Returns (rows, regressions); each row is
    (tier, metric, base, fresh, ratio, regressed). ``ratio`` is
    fresh/baseline for higher-better metrics and baseline/fresh for
    ``lower_better`` ones, so regression is always ratio < 1 - threshold."""
    rows, regressions = [], []
    for tier, base_metrics in baseline.get("tiers", {}).items():
        fresh_metrics = fresh.get("tiers", {}).get(tier, {})
        for metric in (*metrics, *lower_better):
            if metric not in base_metrics or metric not in fresh_metrics:
                continue
            b, f = float(base_metrics[metric]), float(fresh_metrics[metric])
            if metric in lower_better:
                ratio = b / f if f else float("inf")
            else:
                ratio = f / b if b else float("inf")
            regressed = ratio < 1.0 - threshold
            row = (tier, metric, b, f, ratio, regressed)
            rows.append(row)
            if regressed:
                regressions.append(row)
    return rows, regressions


def _print_rows(rows: list[tuple]) -> None:
    print(f"{'tier':<8}{'metric':<22}{'baseline':>12}{'fresh':>12}{'ratio':>8}")
    for tier, metric, b, f, ratio, regressed in rows:
        flag = "  << REGRESSION" if regressed else ""
        print(f"{tier:<8}{metric:<22}{b:>12.1f}{f:>12.1f}{ratio:>8.2f}{flag}")


def _run_gate(title: str, fresh_path: str, baseline_path: str, *,
              require_fresh: bool, threshold: float,
              baseline_required: bool, regen_hint: str,
              metrics=GATED, lower_better=(), lower_threshold: float = 0.0,
              require_rows: bool = False, contracts=None
              ) -> "tuple[int | None, int | None]":
    """One fresh-vs-baseline gate section: load the pair (warning until the
    baseline is committed unless ``baseline_required``), compare the gated
    metrics, print the table, run the per-tier ``contracts(fresh) -> int``
    hook. Returns ``(exit_code, failures)`` — a non-None exit code
    propagates immediately; ``failures`` is None when the gate was skipped
    (missing file with warn semantics)."""
    pair = _load_pair(fresh_path, baseline_path, require_fresh,
                      baseline_required, regen_hint)
    if isinstance(pair, int):
        return pair, None
    if pair is None:
        return None, None
    fresh, baseline = pair
    rows, regressions = compare(fresh, baseline, threshold, metrics=metrics)
    if lower_better:
        lrows, lregs = compare(fresh, baseline, lower_threshold,
                               metrics=(), lower_better=lower_better)
        rows, regressions = rows + lrows, regressions + lregs
    if require_rows and not rows:
        print("ERROR: no comparable metrics between fresh and baseline",
              file=sys.stderr)
        return 2, None
    print(f"\n== {title} ({fresh_path} vs {baseline_path})")
    _print_rows(rows)
    failures = len(regressions)
    if contracts is not None:
        failures += contracts(fresh)
    return None, failures


def _load_pair(fresh_path: str, baseline_path: str, require_fresh: bool,
               baseline_required: bool, regen_hint: str):
    """Returns (fresh, baseline) dicts, or an int exit code to propagate, or
    None to skip this comparison."""
    if not os.path.exists(baseline_path):
        if baseline_required:
            print(f"ERROR: baseline {baseline_path} missing — run "
                  f"`{regen_hint}` and commit the result as the baseline",
                  file=sys.stderr)
            return 2
        print(f"WARNING: baseline {baseline_path} not committed yet — "
              f"skipping this gate", file=sys.stderr)
        return None
    if not os.path.exists(fresh_path):
        msg = f"fresh benchmark {fresh_path} missing (did the bench step fail?)"
        if require_fresh:
            print("ERROR: " + msg, file=sys.stderr)
            return 2
        print("WARNING: " + msg + " — skipping this gate", file=sys.stderr)
        return None
    with open(fresh_path) as f:
        fresh = json.load(f)
    with open(baseline_path) as f:
        baseline = json.load(f)
    return fresh, baseline


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", default="BENCH_batch.json")
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--filtered-fresh", default="BENCH_filtered.json")
    ap.add_argument("--filtered-baseline",
                    default="BENCH_filtered_baseline.json")
    ap.add_argument("--serving-fresh", default="BENCH_serving.json")
    ap.add_argument("--serving-baseline",
                    default="BENCH_serving_baseline.json")
    ap.add_argument("--size-fresh", default="BENCH_size.json")
    ap.add_argument("--size-baseline", default="BENCH_size_baseline.json")
    ap.add_argument("--semantics-fresh", default="BENCH_semantics.json")
    ap.add_argument("--semantics-baseline",
                    default="BENCH_semantics_baseline.json")
    ap.add_argument("--ingest-fresh", default="BENCH_ingest.json")
    ap.add_argument("--ingest-baseline",
                    default="BENCH_ingest_baseline.json")
    ap.add_argument("--serving-latency-threshold", type=float, default=0.60,
                    help="maximum tolerated p99 inflation, as 1 - base/fresh "
                         "(0.60 fails past 2.5x baseline — open-loop tail "
                         "latency on shared runners wobbles more than "
                         "throughput)")
    ap.add_argument("--threshold", type=float, default=0.40,
                    help="maximum tolerated fractional QPS drop")
    ap.add_argument("--require-fresh", action="store_true",
                    help="fail (instead of warn) when a fresh benchmark "
                         "file is missing")
    args = ap.parse_args(argv)

    def batch_contracts(fresh: dict) -> int:
        bad = 0
        for tier, m in fresh.get("tiers", {}).items():
            # Hard failure regardless of throughput: the mixed-precision
            # prune tier / cost-model routing changed the result set. This
            # is a correctness contract, not a perf gate.
            if m.get("cascade_result_parity") is False:
                print(f"FAIL: {tier}: cascade changed the result set "
                      f"(cascade_result_parity=false)", file=sys.stderr)
                bad += 1
            binning = m.get("binning") or {}
            q = (binning.get("quantile") or {}).get("padded_cell_ratio")
            p = (binning.get("pow2") or {}).get("padded_cell_ratio")
            if q is not None and p is not None and q > p:
                print(f"WARNING: {tier}: quantile binning padded more than "
                      f"pow2 ({q:.4f} > {p:.4f})", file=sys.stderr)
        return bad

    def filtered_contracts(fresh: dict) -> int:
        bad = 0
        for tier, m in fresh.get("tiers", {}).items():
            if m.get("d2h_match_at_full_selectivity") is False:
                print(f"FAIL: {tier}: eligibility fold added D2H traffic "
                      f"(d2h_match_at_full_selectivity=false)",
                      file=sys.stderr)
                bad += 1
        return bad

    def serving_contracts(fresh: dict) -> int:
        bad = 0
        for tier, m in fresh.get("tiers", {}).items():
            # Contract, not a perf gate: the async runtime must at least pay
            # for the queue it adds (ISSUE 7 acceptance bar).
            ratio = m.get("runtime_vs_sync_qps")
            if ratio is not None and ratio < 1.0 - args.threshold:
                print(f"FAIL: {tier}: runtime QPS fell to {ratio:.2f}x the "
                      f"synchronous loop (must stay ~>= 1)", file=sys.stderr)
                bad += 1
        return bad

    def semantics_contracts(fresh: dict) -> int:
        bad = 0
        for tier, m in fresh.get("tiers", {}).items():
            # Correctness contract, not a perf gate: a degenerate semantics
            # object (m = |Q|, unit weights, no scoring) must leave the
            # batch bitwise unchanged.
            if m.get("degenerate_parity") is False:
                print(f"FAIL: {tier}: degenerate semantics changed the "
                      f"result set (degenerate_parity=false)",
                      file=sys.stderr)
                bad += 1
        return bad

    gates = (
        dict(title="batch pipeline", fresh_path=args.fresh,
             baseline_path=args.baseline, baseline_required=True,
             regen_hint="python -m benchmarks.bench_batch_engine --fast",
             metrics=GATED, require_rows=True, contracts=batch_contracts),
        dict(title="filtered sweep", fresh_path=args.filtered_fresh,
             baseline_path=args.filtered_baseline, baseline_required=False,
             regen_hint="python -m benchmarks.bench_filtered --fast",
             metrics=GATED_FILTERED, contracts=filtered_contracts),
        dict(title="serving runtime", fresh_path=args.serving_fresh,
             baseline_path=args.serving_baseline, baseline_required=False,
             regen_hint="python -m benchmarks.bench_serving --fast",
             metrics=GATED_SERVING, lower_better=GATED_SERVING_LOWER,
             lower_threshold=args.serving_latency_threshold,
             contracts=serving_contracts),
        dict(title="out-of-core size sweep", fresh_path=args.size_fresh,
             baseline_path=args.size_baseline, baseline_required=False,
             regen_hint="python -m benchmarks.fig9_size --fast --store disk",
             metrics=GATED_SIZE),
        dict(title="flexible semantics", fresh_path=args.semantics_fresh,
             baseline_path=args.semantics_baseline, baseline_required=True,
             regen_hint="python -m benchmarks.bench_semantics --fast",
             metrics=GATED_SEMANTICS, contracts=semantics_contracts),
        dict(title="ingestion pipeline", fresh_path=args.ingest_fresh,
             baseline_path=args.ingest_baseline, baseline_required=False,
             regen_hint="python -m benchmarks.bench_ingest --fast --pipeline",
             metrics=GATED_INGEST),
    )

    failures = 0
    compared = 0
    for gate in gates:
        code, gate_failures = _run_gate(
            require_fresh=args.require_fresh, threshold=args.threshold,
            **gate)
        if code is not None:
            return code
        if gate_failures is not None:
            compared += 1
            failures += gate_failures

    if not compared:
        # Matches the historical missing-fresh semantics: the bench steps
        # are non-blocking in CI, so an absent trajectory warns rather than
        # masking the bench's own failure behind a gate error.
        print("WARNING: nothing compared (all fresh files missing)",
              file=sys.stderr)
        return 0
    if failures:
        print(f"\nFAIL: {failures} gated metric(s)/contract(s) regressed "
              f"more than {args.threshold:.0%} vs baseline", file=sys.stderr)
        return 1
    print(f"\nOK: all gated metrics within {args.threshold:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
