"""CI bench-regression gate: fail the job when fast-tier QPS regresses.

Compares the freshly written ``BENCH_batch.json`` against the committed
``BENCH_baseline.json`` and exits non-zero when any gated metric dropped by
more than ``--threshold`` (default 40% — generous, because CI runs on shared
runners whose absolute throughput wobbles; the gate is meant to catch real
regressions like the pre-PR-2 41x exact-tier cliff, not scheduler noise):

    PYTHONPATH=src python -m benchmarks.check_regression \
        [--fresh BENCH_batch.json] [--baseline BENCH_baseline.json]

Gated metrics: per tier (exact/approx), the batched-pipeline QPS for both
backends plus the per-query loop rate. The sharded (``--mesh N``) extras are
deliberately NOT gated: the forced-8-device run's top-level tier metrics
still measure single-device dispatch math (host-platform devices share one
CPU), so they remain comparable to the single-device baseline, while the
``sharded.*`` numbers would not be. A missing fresh file is a *warning*
(the bench step is non-blocking in CI; the gate must not mask the bench's
own failure mode) unless ``--require-fresh`` is set; a missing baseline is
an error — regenerate it with ``bench_batch_engine --fast`` and commit.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

GATED = ("batch_pallas_qps", "batch_numpy_qps", "loop_qps")


def compare(fresh: dict, baseline: dict, threshold: float
            ) -> tuple[list[tuple], list[tuple]]:
    """Returns (rows, regressions); each row is
    (tier, metric, base, fresh, ratio, regressed)."""
    rows, regressions = [], []
    for tier, base_metrics in baseline.get("tiers", {}).items():
        fresh_metrics = fresh.get("tiers", {}).get(tier, {})
        for metric in GATED:
            if metric not in base_metrics or metric not in fresh_metrics:
                continue
            b, f = float(base_metrics[metric]), float(fresh_metrics[metric])
            ratio = f / b if b else float("inf")
            regressed = ratio < 1.0 - threshold
            row = (tier, metric, b, f, ratio, regressed)
            rows.append(row)
            if regressed:
                regressions.append(row)
    return rows, regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", default="BENCH_batch.json")
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--threshold", type=float, default=0.40,
                    help="maximum tolerated fractional QPS drop")
    ap.add_argument("--require-fresh", action="store_true",
                    help="fail (instead of warn) when the fresh benchmark "
                         "file is missing")
    args = ap.parse_args(argv)

    if not os.path.exists(args.baseline):
        print(f"ERROR: baseline {args.baseline} missing — run "
              f"`python -m benchmarks.bench_batch_engine --fast` and commit "
              f"the result as the baseline", file=sys.stderr)
        return 2
    if not os.path.exists(args.fresh):
        msg = (f"fresh benchmark {args.fresh} missing (did the bench step "
               f"fail?)")
        if args.require_fresh:
            print("ERROR: " + msg, file=sys.stderr)
            return 2
        print("WARNING: " + msg + " — skipping the regression gate",
              file=sys.stderr)
        return 0

    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    rows, regressions = compare(fresh, baseline, args.threshold)
    if not rows:
        print("ERROR: no comparable metrics between fresh and baseline",
              file=sys.stderr)
        return 2

    print(f"{'tier':<8}{'metric':<22}{'baseline':>12}{'fresh':>12}{'ratio':>8}")
    for tier, metric, b, f, ratio, regressed in rows:
        flag = "  << REGRESSION" if regressed else ""
        print(f"{tier:<8}{metric:<22}{b:>12.1f}{f:>12.1f}{ratio:>8.2f}{flag}")
    if regressions:
        print(f"\nFAIL: {len(regressions)} metric(s) regressed more than "
              f"{args.threshold:.0%} vs {args.baseline}", file=sys.stderr)
        return 1
    print(f"\nOK: all gated metrics within {args.threshold:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
