"""Fig. 7 — average approximation ratio (AAR) of ProMiSH-A over top-5 results
for varying query sizes on real-like (clustered, Zipf-tagged) datasets.
Paper: AAR < 1.5 on 32-d Flickr datasets. Also reports the device-tier
anchor-star kernel's AAR (beyond-paper serving path, 2-approx guarantee)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import brute_force, promish_a
from repro.core.index import build_index
from repro.data.flickr_like import flickr_like_dataset
from repro.data.synthetic import random_queries


def main(fast: bool = False):
    n = 1_000 if fast else 4_000
    qsizes = (3,) if fast else (2, 3, 4, 5)
    k = 2 if fast else 5
    ds = flickr_like_dataset(n=n, d=32, u=40, t=4, n_clusters=16, seed=7)
    idx_a = build_index(ds, m=2, n_scales=5, exact=False, seed=0)

    from repro.serve.engine import NKSEngine
    eng = NKSEngine(ds, build_exact=False, build_approx=False)
    eng.index_a = idx_a

    # Ground truth: brute force where feasible, else ProMiSH-E (exact; this
    # is the paper's own protocol — §VIII-A uses the exact methods as truth).
    from repro.core import promish_e
    idx_e = None

    def truth_of(query, k):
        nonlocal idx_e
        try:
            return brute_force.search(ds, query, k=k)
        except ValueError:
            if idx_e is None:
                idx_e = build_index(ds, m=2, n_scales=5, exact=True, seed=0)
            return promish_e.search(ds, idx_e, query, k=k)

    for q in qsizes:
        ratios_a, ratios_dev = [], []
        for query in random_queries(ds, q, 4 if fast else 8, seed=q):
            truth = truth_of(query, k)
            got = promish_a.search(ds, idx_a, query, k=k)
            dev = eng.query(query, k=k, tier="device")
            for i in range(min(len(truth.items), len(got.items))):
                tr = truth.items[i].diameter
                if tr > 1e-9:
                    ratios_a.append(got.items[i].diameter / tr)
            if truth.items and dev.candidates and truth.items[0].diameter > 1e-9:
                ratios_dev.append(dev.candidates[0].diameter
                                  / truth.items[0].diameter)
        emit(f"fig7.aar_promish_a.q{q}", float(np.mean(ratios_a)) * 1e6,
             f"AAR={np.mean(ratios_a):.3f}")
        if ratios_dev:
            emit(f"fig7.aar_device_tier.q{q}", float(np.mean(ratios_dev)) * 1e6,
                 f"AAR={np.mean(ratios_dev):.3f}")


if __name__ == "__main__":
    main()
