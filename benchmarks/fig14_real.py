"""Figs. 14-18 — real-dataset experiments, using the Flickr-like generator
(same statistics as the paper's Table III datasets: clustered histogram
features, Zipf keyword tags, t~11). Query time vs d and q; E vs A gap."""
from __future__ import annotations

from benchmarks.common import emit, promish_suite
from repro.data.flickr_like import flickr_like_dataset
from repro.data.synthetic import random_queries


def main(fast: bool = False):
    n = 2_000 if fast else 20_000
    dims = (16,) if fast else (8, 16, 32, 64)
    for d in dims:                                     # fig 14/17 axis
        ds = flickr_like_dataset(n=n, d=d, u=600, t=6, n_clusters=32, seed=d)
        queries = random_queries(ds, 4, 3 if fast else 5, seed=d)
        res = promish_suite(ds, queries, k=1, run_tree=(d <= 16 and not fast),
                            tree_budget=50_000)
        emit(f"fig14.promish_e.d{d}", res["promish_e"] * 1e6, f"real-like N={n}")
        emit(f"fig14.promish_a.d{d}", res["promish_a"] * 1e6, f"real-like N={n}")
        if "tree" in res:
            emit(f"fig14.vbrtree.d{d}", res["tree"] * 1e6,
                 f"timeouts={res['tree_timeouts']}")
    ds = flickr_like_dataset(n=n, d=16, u=600, t=6, n_clusters=32, seed=99)
    for q in ((3,) if fast else (2, 3, 4, 5)):         # fig 15 axis
        queries = random_queries(ds, q, 3 if fast else 5, seed=q)
        res = promish_suite(ds, queries, k=1, run_tree=False)
        emit(f"fig15.promish_e.q{q}", res["promish_e"] * 1e6, f"real-like N={n}")
        emit(f"fig15.promish_a.q{q}", res["promish_a"] * 1e6, f"real-like N={n}")


if __name__ == "__main__":
    main()
