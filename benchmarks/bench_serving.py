"""Serving-runtime throughput + latency under Poisson arrivals.

The ISSUE-7 serving question: what does the async runtime (admission queue,
coalesced batches, off-thread compaction) buy over the synchronous
one-request-at-a-time loop, and what latency does a client actually see
under open-loop load? The workload is a 1:``--mix`` insert:query op stream
(default 1:10, same mix as bench_ingest) drawn from the same flickr-like
generator as the resident corpus:

  * **sync leg** — ops run back-to-back against a fresh engine (the
    ``launch/serve.py`` default path): per-op service latency, closed-loop
    QPS. A synchronous loop has no queue, so Poisson arrivals would only
    add idle time — its QPS *is* its service rate.
  * **runtime leg** — the same op sequence submitted open-loop on a Poisson
    arrival schedule at ``--rate-factor``× the measured sync rate
    (saturating: the queue builds, coalescing kicks in). Latency here is
    submit→resolve (queue wait included), QPS is completions over the span
    from first submit to last resolve.

    PYTHONPATH=src python -m benchmarks.bench_serving [--fast]

Writes ``BENCH_serving.json``; CI gates ``qps_sync``,
``qps_sustained_runtime`` (higher-better) and ``p99_ms_runtime``
(lower-better) against the committed ``BENCH_serving_baseline.json`` —
see ``check_regression.py``. The acceptance bar from ISSUE 7:
``runtime_vs_sync_qps >= 1`` on the fast (approx) tier — coalescing must at
least pay for the queue it adds.
"""
from __future__ import annotations

import argparse
import json
import os
import time

OUT = "BENCH_serving.json"


def _percentiles(lat_s) -> dict:
    import numpy as np
    lat = np.asarray(lat_s) * 1e3
    return {"p50_ms": round(float(np.percentile(lat, 50)), 3),
            "p99_ms": round(float(np.percentile(lat, 99)), 3)}


def main(fast: bool = False, mix: int = 10, rate_factor: float = 1.5,
         n_ops: int | None = None, tier: str = "approx") -> dict:
    import numpy as np

    from benchmarks.common import emit
    from repro.core.types import make_dataset
    from repro.data.flickr_like import flickr_like_dataset
    from repro.data.synthetic import random_queries
    from repro.serve.engine import NKSEngine
    from repro.serve.runtime import RuntimeConfig, ServingRuntime

    n0 = 1_500 if fast else 6_000
    n_ops = n_ops or (33 * (mix + 1) if fast else 100 * (mix + 1))
    insert_batch = 5
    k = 2

    n_inserts = n_ops // (mix + 1)
    full = flickr_like_dataset(n=n0 + n_inserts * insert_batch, d=16, u=30,
                               t=3, n_clusters=12, seed=8)
    ds0 = make_dataset(full.points[:n0],
                       [full.kw.row(i).tolist() for i in range(n0)],
                       n_keywords=full.n_keywords)
    queries = random_queries(ds0, 2, n_ops, seed=3)

    # One op per arrival: every (mix+1)-th is an insert batch, the rest are
    # single queries — the bench_ingest 1:mix op mix, serialized per-request
    # the way a frontend would see it.
    ops = []
    ins = 0
    for i in range(n_ops):
        if i % (mix + 1) == mix and ins < n_inserts:
            lo = n0 + ins * insert_batch
            ops.append(("insert", full.points[lo:lo + insert_batch],
                        [full.kw.row(j).tolist()
                         for j in range(lo, lo + insert_batch)]))
            ins += 1
        else:
            ops.append(("query", queries[i]))

    def fresh_engine():
        return NKSEngine(ds0, m=2, n_scales=5, seed=0,
                         build_exact=False, build_approx=True,
                         compact_min=max(64, n_inserts * insert_batch // 2),
                         compact_ratio=0.05)

    # ---------------------------------------------------------------- sync
    engine = fresh_engine()
    engine.query_batch(queries[:8], k=k, tier=tier)         # warm
    lat_sync = []
    t0 = time.perf_counter()
    for op in ops:
        t1 = time.perf_counter()
        if op[0] == "query":
            engine.query(op[1], k=k, tier=tier)
        else:
            engine.insert(op[1], op[2])
        lat_sync.append(time.perf_counter() - t1)
    sync_wall = time.perf_counter() - t0
    qps_sync = n_ops / sync_wall
    sync_out = {"qps": qps_sync, **_percentiles(lat_sync),
                "compactions": engine.ingest.compactions}

    # -------------------------------------------------------------- runtime
    # Open-loop Poisson arrivals at rate_factor x the sync service rate: the
    # queue builds, so coalescing has material batches to work with.
    rate = qps_sync * rate_factor
    arrivals = np.cumsum(
        np.random.default_rng(5).exponential(1.0 / rate, n_ops))
    engine = fresh_engine()
    engine.query_batch(queries[:8], k=k, tier=tier)         # warm
    rt = ServingRuntime(engine, RuntimeConfig(
        max_queue=max(1024, n_ops), max_batch=32, batch_window_s=0.0,
        tier=tier, k=k))
    tickets = []
    t0 = time.perf_counter()
    for op, at in zip(ops, arrivals):
        lag = at - (time.perf_counter() - t0)
        if lag > 0:
            time.sleep(lag)
        if op[0] == "query":
            tickets.append(rt.submit({"op": "query", "keywords": op[1],
                                      "k": k, "tier": tier}))
        else:
            tickets.append(rt.submit({"op": "insert", "points": op[1],
                                      "keywords": op[2]}))
    results = [t.result(120) for t in tickets]
    rt_wall = time.perf_counter() - t0
    rt.close()

    ok = [r for r in results if r.ok]
    qps_rt = len(ok) / rt_wall
    runtime_out = {
        "qps_sustained": qps_rt,
        **_percentiles([r.latency_s for r in ok]),
        "offered_rate": rate,
        "completed": len(ok),
        "rejected": rt.stats.rejected_full,
        "errors": rt.stats.errors,
        "degraded": rt.stats.degraded_queries,
        "mean_batch": round(rt.stats.mean_batch, 2),
        "bg_compactions": rt.stats.bg_compactions,
    }

    tier_out = {
        # flat gate keys (check_regression compares per-tier flat metrics)
        "qps_sync": qps_sync,
        "qps_sustained_runtime": qps_rt,
        "p99_ms_runtime": runtime_out["p99_ms"],
        "runtime_vs_sync_qps": round(qps_rt / qps_sync, 3),
        "sync": sync_out,
        "runtime": runtime_out,
    }
    emit(f"serving.sync.{tier}", 1e6 / qps_sync, f"mix=1:{mix}")
    emit(f"serving.runtime.{tier}", 1e6 / qps_rt,
         f"mean_batch={runtime_out['mean_batch']} "
         f"p99={runtime_out['p99_ms']}ms")

    results_json = {
        "n0": n0, "fast": fast, "mix": mix, "k": k, "n_ops": n_ops,
        "insert_batch": insert_batch, "rate_factor": rate_factor,
        "arrival_process": "poisson",
        "tiers": {tier: tier_out},
    }
    with open(OUT, "w") as f:
        json.dump(results_json, f, indent=2)
    print(f"# wrote {os.path.abspath(OUT)}")
    return results_json


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    default=os.environ.get("BENCH_FAST", "") == "1")
    ap.add_argument("--mix", type=int, default=10,
                    help="queries per insert op (1:N op mix)")
    ap.add_argument("--rate-factor", type=float, default=1.5,
                    help="offered Poisson arrival rate as a multiple of the "
                         "measured sync service rate")
    ap.add_argument("--n-ops", type=int, default=None)
    ap.add_argument("--tier", default="approx",
                    choices=["approx", "exact"])
    args = ap.parse_args()
    main(fast=args.fast, mix=args.mix, rate_factor=args.rate_factor,
         n_ops=args.n_ops, tier=args.tier)
