"""Fig. 8 — query time vs dataset dimension d (E / A / Virtual bR*-Tree).

Paper: synthetic, N=100k, t=1, U=1000, q=5, top-1. ProMiSH flat-to-linear in
d; the tree collapses (hours) beyond d~10. We run a scaled N (CPU container)
with the same densities.
"""
from __future__ import annotations

from benchmarks.common import emit, promish_suite
from repro.data.synthetic import random_queries, synthetic_dataset

N = 20_000
U = 1_000
Q = 5
DIMS = (2, 5, 10, 25, 50)


def main(fast: bool = False):
    dims = DIMS[:3] if fast else DIMS
    n = 5_000 if fast else N
    for d in dims:
        ds = synthetic_dataset(n=n, d=d, u=U, t=1, seed=d)
        queries = random_queries(ds, Q, 3 if fast else 5, seed=d)
        res = promish_suite(ds, queries, k=1, run_tree=(d <= 25),
                            tree_budget=100_000)
        emit(f"fig8.promish_e.d{d}", res["promish_e"] * 1e6, f"N={n}")
        emit(f"fig8.promish_a.d{d}", res["promish_a"] * 1e6, f"N={n}")
        if "tree" in res:
            emit(f"fig8.vbrtree.d{d}", res["tree"] * 1e6,
                 f"timeouts={res['tree_timeouts']}")


if __name__ == "__main__":
    main()
