"""Ablation (beyond-paper §VIII extension): index hyper-parameters m
(projections per HI structure) and L (scales) vs query time and ProMiSH-A
quality. The paper fixes m=2, L=5; this sweep shows the trade-off surface
that motivates those defaults:

  * larger m -> tighter buckets (fewer false candidates, Pr(A|r)^m decays)
    but 2^m signatures per point in ProMiSH-E (index size + dup churn);
  * larger L -> finer initial scale (earlier termination for tight results)
    vs more structures to probe.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_queries
from repro.core import brute_force, promish_a, promish_e
from repro.core.index import build_index
from repro.data.flickr_like import flickr_like_dataset
from repro.data.synthetic import random_queries


def main(fast: bool = False):
    n = 1_500 if fast else 6_000
    ds = flickr_like_dataset(n=n, d=16, u=100, t=3, n_clusters=16, seed=5)
    queries = random_queries(ds, 3, 3 if fast else 6, seed=11)
    truths = {tuple(q): brute_force.search(ds, q, k=1).items[0].diameter
              for q in queries}

    for m in ((2,) if fast else (1, 2, 3, 4)):
        idx_e = build_index(ds, m=m, n_scales=5, exact=True, seed=0)
        idx_a = build_index(ds, m=m, n_scales=5, exact=False, seed=0)
        t_e = time_queries(lambda q: promish_e.search(ds, idx_e, q, k=1), queries)
        t_a = time_queries(lambda q: promish_a.search(ds, idx_a, q, k=1), queries)
        ratios = []
        for q in queries:
            got = promish_a.search(ds, idx_a, q, k=1).items[0].diameter
            tr = truths[tuple(q)]
            if tr > 1e-9:
                ratios.append(got / tr)
        emit(f"ablation.m{m}.promish_e", t_e * 1e6,
             f"idx_MB={idx_e.nbytes() / 1e6:.1f}")
        emit(f"ablation.m{m}.promish_a", t_a * 1e6,
             f"AAR={np.mean(ratios):.3f}")

    for levels in ((5,) if fast else (3, 5, 7)):
        idx_e = build_index(ds, m=2, n_scales=levels, exact=True, seed=0)
        t_e = time_queries(lambda q: promish_e.search(ds, idx_e, q, k=1), queries)
        emit(f"ablation.L{levels}.promish_e", t_e * 1e6,
             f"idx_MB={idx_e.nbytes() / 1e6:.1f}")


if __name__ == "__main__":
    main()
