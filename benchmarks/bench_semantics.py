"""Flexible-semantics bench (ISSUE 9): what do m-of-k expansion, weighted
objectives, and scored ranking cost relative to the classic batch?

Times the batched engine on the same query stream under four semantics:
classic (no ``semantics=``), m-of-k at ``m = |Q| - 1``, weighted keywords,
and scored top-k — per tier, both backends. Emits the usual CSV rows and
writes ``BENCH_semantics.json`` for the warn-only regression gate (no
committed baseline yet; ``check_regression`` skips it until one lands):

    PYTHONPATH=src python -m benchmarks.bench_semantics [--fast]

Numbers of note: ``m_of_k_qps / classic_qps`` is the price of planning the
subquery expansion (``subqueries`` records the fan-out actually planned);
``weighted_qps`` isolates the float64 weighted rescore; ``degenerate_parity``
is a correctness contract, not a perf number — a degenerate semantics object
must leave the batch bitwise unchanged, and the gate hard-fails on false.
"""
from __future__ import annotations

import argparse
import json
import os
import time

OUT = "BENCH_semantics.json"


def _time(fn, reps: int = 3) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def main(fast: bool = False) -> dict:
    from benchmarks.common import emit
    from repro.data.flickr_like import flickr_like_dataset
    from repro.data.synthetic import random_queries
    from repro.serve.engine import NKSEngine

    n = 1_500 if fast else 6_000
    batch = 16 if fast else 32
    ds = flickr_like_dataset(n=n, d=16, u=30, t=3, n_clusters=12, seed=4)
    engine = NKSEngine(ds, m=2, n_scales=5, seed=0)
    queries = random_queries(ds, 3, batch, seed=9)
    k = 2
    qlen = len(queries[0])

    # semantics under test; weights boost the two lowest keyword ids seen in
    # the stream so the weighted leg touches a realistic fraction of points
    boosted = sorted({v for q in queries for v in q})[:2]
    variants = {
        "classic": None,
        "m_of_k": {"m": qlen - 1},
        "weighted": {"weights": {v: 3.0 for v in boosted}},
        "scored": {"m": qlen - 1, "score": True},
    }

    results: dict = {"n": n, "d": ds.dim, "batch": batch, "k": k,
                     "fast": fast, "tiers": {}}
    for tier in ("exact", "approx"):
        tier_res: dict = {}
        for backend in ("numpy", "pallas"):
            for name, sem in variants.items():
                run = lambda: engine.query_batch(  # noqa: E731
                    queries, k=k, tier=tier, backend=backend, semantics=sem)
                run()                              # warm-up (compile, LRU)
                t = _time(run)
                key = f"{name}_qps" if backend == "numpy" \
                    else f"{name}_pallas_qps"
                tier_res[key] = batch / t
                if backend == "numpy" and name != "classic":
                    tier_res[f"{name}_subqueries"] = \
                        engine.last_batch_stats.subqueries
                emit(f"semantics.{name}.{backend}.{tier}", t / batch * 1e6,
                     f"B={batch}")
        # correctness contract: degenerate semantics leave the batch bitwise
        # unchanged on the same route
        base = engine.query_batch(queries, k=k, tier=tier, backend="numpy")
        deg = engine.query_batch(queries, k=k, tier=tier, backend="numpy",
                                 semantics={"m": qlen, "weights": {}})
        tier_res["degenerate_parity"] = all(
            [(c.ids, c.diameter) for c in a.candidates]
            == [(c.ids, c.diameter) for c in b.candidates]
            for a, b in zip(base, deg))
        results["tiers"][tier] = tier_res

    with open(OUT, "w") as f:
        json.dump(results, f, indent=2)
    print(f"# wrote {os.path.abspath(OUT)}")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    default=os.environ.get("BENCH_FAST", "") == "1")
    args = ap.parse_args()
    main(fast=args.fast)
