"""Benchmark harness entry point — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only fig8,...]

Prints ``name,us_per_call,derived`` CSV (HEADER first). ``--fast`` shrinks
datasets for CI-speed smoke runs; full runs reproduce the paper's axes.
The roofline table (§Roofline) reads the dry-run artifacts and is included
when they exist.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback

from benchmarks.common import HEADER

MODULES = [
    ("fig7", "benchmarks.fig7_aar"),
    ("fig8", "benchmarks.fig8_dim"),
    ("fig9", "benchmarks.fig9_size"),
    ("fig10", "benchmarks.fig10_qsize"),
    ("fig13", "benchmarks.fig13_topk"),
    ("fig14", "benchmarks.fig14_real"),
    ("tab2", "benchmarks.tab2_pruning"),
    ("tab4", "benchmarks.tab4_space"),
    ("build", "benchmarks.index_build"),
    ("ablation", "benchmarks.ablation_m_L"),
    ("batch", "benchmarks.bench_batch_engine"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    default=os.environ.get("BENCH_FAST", "") == "1")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    only = set(args.only.split(",")) if args.only else None
    print(HEADER)
    failures = 0
    for tag, modname in MODULES:
        if only and tag not in only:
            continue
        t0 = time.time()
        try:
            mod = __import__(modname, fromlist=["main"])
            mod.main(fast=args.fast)
            print(f"# {tag} done in {time.time() - t0:.1f}s", file=sys.stderr)
        except Exception:
            failures += 1
            print(f"{tag}.ERROR,0.0,{traceback.format_exc(limit=1)!r}")
    # roofline table (if dry-run artifacts exist)
    art = os.environ.get("DRYRUN_ARTIFACTS", "artifacts/dryrun")
    if (only is None or (only and "roofline" in only)) and os.path.isdir(art):
        print("# --- roofline (see EXPERIMENTS.md) ---")
        from benchmarks import roofline
        roofline.main(art)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
