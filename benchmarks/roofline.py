"""§Roofline — derive the three roofline terms per (arch x cell x mesh) from
the dry-run artifacts (deliverable g), plus roofline rows for the NKS join
kernels themselves.

    compute term    = HLO_FLOPs / peak_FLOP/s            (per chip — the
    memory term     = HLO_bytes / HBM_bw                  compiled module is
    collective term = collective_bytes / link_bw          already per-device)

Hardware constants (assignment): TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.

Also reports MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) per device and
the usefulness ratio MODEL_FLOPS / HLO_FLOPs (catches remat/redundancy
waste; >1 means HLO under-counts, <1 means recompute/overhead).

The **kernel section** AOT-lowers the batched threshold-join ops (the fp32
masked join and the bf16 coarse-count prune tier) at representative bin
shapes and prices XLA's own cost analysis against the v5e constants. Off
TPU this measures the XLA lowering — the interpret-validated stand-in for
the Mosaic kernel — so CI can track the numbers until real-TPU validation
lands (ROADMAP raw-speed campaign):

    PYTHONPATH=src python -m benchmarks.roofline [--fast] \
        [--art-dir artifacts/dryrun] [--out BENCH_roofline.json]

``--fast`` trims the shape sweep to the two bin shapes the fast bench
actually exercises; ``--out`` writes every row (cells + kernels) as JSON so
a CI leg can upload the trajectory as an artifact.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

# (S, P, d) batched-join bin shapes: the fast-bench pair first (quantile
# classes on the flickr-like corpus land near these), then the larger bins
# the full profile / fallback stage reaches.
KERNEL_SHAPES_FAST = [(64, 128, 16), (16, 512, 16)]
KERNEL_SHAPES = KERNEL_SHAPES_FAST + [(256, 128, 16), (64, 256, 32),
                                      (8, 1024, 64)]

CELL_TOKENS = {"train_4k": 4096 * 256, "prefill_32k": 32768 * 32,
               "decode_32k": 128, "long_500k": 1}
TRAIN_MULT = {"train_4k": 3, "prefill_32k": 1, "decode_32k": 1, "long_500k": 1}


def model_flops_global(arch: str, cell: str) -> float:
    from repro.configs import get_config
    from repro.models.api import active_params
    cfg = get_config(arch)
    n_active = active_params(cfg)
    tokens = CELL_TOKENS[cell]
    # 6ND fwd+bwd for train; 2ND forward-only for serving cells
    mult = 6 if cell == "train_4k" else 2
    return mult * n_active * tokens


def load_records(art_dir: str) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def roofline_row(rec: dict) -> dict | None:
    if not rec.get("ok"):
        return {"arch": rec["arch"], "cell": rec["cell"], "mesh": rec["mesh"],
                "error": True}
    cost = rec.get("cost", {})
    cc = rec.get("collectives", {})
    # census-scaled values (trip-count-aware) preferred; raw cost_analysis
    # numbers (which count while bodies once) kept as fallback.
    flops = float(cc.get("dot_flops_scaled", 0.0)) or float(cost.get("flops", 0.0))
    byts = float(cc.get("out_bytes_scaled", 0.0)) or \
        float(cost.get("bytes accessed", 0.0))
    coll = float(cc.get("total_scaled", 0.0))
    t_compute = flops / PEAK_FLOPS
    t_memory = byts / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    devices = rec.get("devices", 512 if rec["mesh"] == "pod2" else 256)
    mf = model_flops_global(rec["arch"], rec["cell"]) / devices
    useful = mf / flops if flops else 0.0
    bound = max(terms.values())
    frac = (mf / PEAK_FLOPS) / bound if bound > 0 else 0.0
    return {"arch": rec["arch"], "cell": rec["cell"], "mesh": rec["mesh"],
            "t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "dominant": dominant,
            "model_flops_per_dev": mf, "hlo_flops_per_dev": flops,
            "useful_ratio": useful, "roofline_fraction": frac}


def _cost_dict(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):   # older jax returns [dict]
        ca = ca[0] if ca else {}
    return dict(ca or {})


def kernel_row(op: str, s: int, p: int, d: int) -> dict:
    """AOT-lower one batched-join op at one (S, P, d) bin shape and price
    XLA's cost analysis against the v5e roofline constants."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops

    x = jax.ShapeDtypeStruct((s, p, d), jnp.float32)
    lens = jax.ShapeDtypeStruct((s,), jnp.int32)
    r = jax.ShapeDtypeStruct((s,), jnp.float32)
    if op == "join_masked_fp32":
        fn = jax.jit(lambda xx, ll, rr: ops.join_batched_masked_local(
            xx, ll, rr, interpret=False))
    elif op == "join_counts_bf16":
        fn = jax.jit(lambda xx, ll, rr: ops.join_batched_counts_local(
            xx, ll, rr, dtype="bf16", interpret=False))
    else:
        raise ValueError(op)
    cost = _cost_dict(fn.lower(x, lens, r).compile())
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    t_compute = flops / PEAK_FLOPS
    t_memory = byts / HBM_BW
    # matmul-equivalent useful work: the norms-identity join is one S
    # batched (P, d)x(d, P) product — 2·S·P²·d MACs-as-flops.
    mf = 2.0 * s * p * p * d
    return {"op": op, "S": s, "P": p, "d": d,
            "hlo_flops": flops, "hlo_bytes": byts,
            "t_compute_s": t_compute, "t_memory_s": t_memory,
            "dominant": "compute" if t_compute >= t_memory else "memory",
            "useful_ratio": mf / flops if flops else 0.0,
            "arithmetic_intensity": flops / byts if byts else 0.0,
            "backend": jax.default_backend()}


def kernel_rows(fast: bool = False) -> list[dict]:
    rows = []
    for s, p, d in (KERNEL_SHAPES_FAST if fast else KERNEL_SHAPES):
        for op in ("join_masked_fp32", "join_counts_bf16"):
            rows.append(kernel_row(op, s, p, d))
    return rows


def main(art_dir: str = "artifacts/dryrun", fast: bool = False,
         out: str | None = None) -> dict:
    results: dict = {"cells": [], "kernels": []}
    recs = load_records(art_dir)
    if not recs:
        print("roofline.no_artifacts,0.0,run repro.launch.dryrun first")
    else:
        print("arch,cell,mesh,t_compute_s,t_memory_s,t_collective_s,dominant,"
              "useful_ratio,roofline_fraction")
        for rec in recs:
            row = roofline_row(rec)
            if row is None or row.get("error"):
                print(f"{rec['arch']},{rec['cell']},{rec['mesh']},ERROR,,,,,")
                continue
            results["cells"].append(row)
            print(f"{row['arch']},{row['cell']},{row['mesh']},"
                  f"{row['t_compute_s']:.4e},{row['t_memory_s']:.4e},"
                  f"{row['t_collective_s']:.4e},{row['dominant']},"
                  f"{row['useful_ratio']:.3f},{row['roofline_fraction']:.3f}")
    print("op,S,P,d,hlo_flops,hlo_bytes,t_compute_s,t_memory_s,dominant,"
          "useful_ratio,backend")
    for row in kernel_rows(fast):
        results["kernels"].append(row)
        print(f"{row['op']},{row['S']},{row['P']},{row['d']},"
              f"{row['hlo_flops']:.3e},{row['hlo_bytes']:.3e},"
              f"{row['t_compute_s']:.4e},{row['t_memory_s']:.4e},"
              f"{row['dominant']},{row['useful_ratio']:.3f},{row['backend']}")
    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# wrote {os.path.abspath(out)}")
    return results


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("art_dir", nargs="?", default="artifacts/dryrun")
    ap.add_argument("--art-dir", dest="art_dir_opt", default=None)
    ap.add_argument("--fast", action="store_true",
                    help="fast-bench bin shapes only")
    ap.add_argument("--out", default=None,
                    help="write all rows (cells + kernels) as JSON")
    args = ap.parse_args()
    main(args.art_dir_opt or args.art_dir, fast=args.fast, out=args.out)
