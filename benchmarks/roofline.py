"""§Roofline — derive the three roofline terms per (arch x cell x mesh) from
the dry-run artifacts (deliverable g).

    compute term    = HLO_FLOPs / peak_FLOP/s            (per chip — the
    memory term     = HLO_bytes / HBM_bw                  compiled module is
    collective term = collective_bytes / link_bw          already per-device)

Hardware constants (assignment): TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.

Also reports MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) per device and
the usefulness ratio MODEL_FLOPS / HLO_FLOPs (catches remat/redundancy
waste; >1 means HLO under-counts, <1 means recompute/overhead).
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

CELL_TOKENS = {"train_4k": 4096 * 256, "prefill_32k": 32768 * 32,
               "decode_32k": 128, "long_500k": 1}
TRAIN_MULT = {"train_4k": 3, "prefill_32k": 1, "decode_32k": 1, "long_500k": 1}


def model_flops_global(arch: str, cell: str) -> float:
    from repro.configs import get_config
    from repro.models.api import active_params
    cfg = get_config(arch)
    n_active = active_params(cfg)
    tokens = CELL_TOKENS[cell]
    # 6ND fwd+bwd for train; 2ND forward-only for serving cells
    mult = 6 if cell == "train_4k" else 2
    return mult * n_active * tokens


def load_records(art_dir: str) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def roofline_row(rec: dict) -> dict | None:
    if not rec.get("ok"):
        return {"arch": rec["arch"], "cell": rec["cell"], "mesh": rec["mesh"],
                "error": True}
    cost = rec.get("cost", {})
    cc = rec.get("collectives", {})
    # census-scaled values (trip-count-aware) preferred; raw cost_analysis
    # numbers (which count while bodies once) kept as fallback.
    flops = float(cc.get("dot_flops_scaled", 0.0)) or float(cost.get("flops", 0.0))
    byts = float(cc.get("out_bytes_scaled", 0.0)) or \
        float(cost.get("bytes accessed", 0.0))
    coll = float(cc.get("total_scaled", 0.0))
    t_compute = flops / PEAK_FLOPS
    t_memory = byts / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    devices = rec.get("devices", 512 if rec["mesh"] == "pod2" else 256)
    mf = model_flops_global(rec["arch"], rec["cell"]) / devices
    useful = mf / flops if flops else 0.0
    bound = max(terms.values())
    frac = (mf / PEAK_FLOPS) / bound if bound > 0 else 0.0
    return {"arch": rec["arch"], "cell": rec["cell"], "mesh": rec["mesh"],
            "t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "dominant": dominant,
            "model_flops_per_dev": mf, "hlo_flops_per_dev": flops,
            "useful_ratio": useful, "roofline_fraction": frac}


def main(art_dir: str = "artifacts/dryrun", fast: bool = False):
    recs = load_records(art_dir)
    if not recs:
        print("roofline.no_artifacts,0.0,run repro.launch.dryrun first")
        return
    print("arch,cell,mesh,t_compute_s,t_memory_s,t_collective_s,dominant,"
          "useful_ratio,roofline_fraction")
    for rec in recs:
        row = roofline_row(rec)
        if row is None or row.get("error"):
            print(f"{rec['arch']},{rec['cell']},{rec['mesh']},ERROR,,,,,")
            continue
        print(f"{row['arch']},{row['cell']},{row['mesh']},"
              f"{row['t_compute_s']:.4e},{row['t_memory_s']:.4e},"
              f"{row['t_collective_s']:.4e},{row['dominant']},"
              f"{row['useful_ratio']:.3f},{row['roofline_fraction']:.3f}")


if __name__ == "__main__":
    import sys
    main(sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun")
