"""Fig. 10 / Fig. 11 — query time vs query size q (and the large-q scaling
regime of fig. 11). ProMiSH linear in q."""
from __future__ import annotations

from benchmarks.common import emit, promish_suite
from repro.data.synthetic import random_queries, synthetic_dataset

QSIZES = (2, 3, 5, 7, 9)


def main(fast: bool = False):
    qsizes = QSIZES[:3] if fast else QSIZES
    n = 5_000 if fast else 50_000
    ds = synthetic_dataset(n=n, d=10, u=200, t=1, seed=0)
    for q in qsizes:
        queries = random_queries(ds, q, 3 if fast else 5, seed=q)
        res = promish_suite(ds, queries, k=1, run_tree=(q <= 3 and not fast),
                            tree_budget=100_000)
        emit(f"fig10.promish_e.q{q}", res["promish_e"] * 1e6, f"N={n} d=10")
        emit(f"fig10.promish_a.q{q}", res["promish_a"] * 1e6, f"N={n} d=10")
        if "tree" in res:
            emit(f"fig10.vbrtree.q{q}", res["tree"] * 1e6,
                 f"timeouts={res['tree_timeouts']}")


if __name__ == "__main__":
    main()
