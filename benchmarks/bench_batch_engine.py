"""Batched engine throughput — loop vs the staged plan/backend pipeline,
single-device vs the sharded serving plane.

The serving question behind the ROADMAP north star: given B concurrent
queries, how much does amortising bucket selection + dedup + device dispatch
buy over the per-query loop — and what does sharding the size-binned join
dispatches over the mesh add on top? Emits the usual CSV rows *and* writes
``BENCH_batch.json`` so the perf trajectory is recorded across PRs:

    PYTHONPATH=src python -m benchmarks.bench_batch_engine [--fast] [--mesh N]

``--mesh N`` forces N host devices (XLA_FLAGS is set before the first jax
computation, so it must be the same process from the start — the module
imports no jax at import time) and adds a sharded-vs-single-device
comparison per tier: QPS, per-device dispatch counts, and per-shard
padded-cell utilisation from ``PipelineStats.sharding``.

Numbers of note: ``*_qps`` (queries/sec) per strategy, the pipeline's
per-scale dispatch counts (the fused path should show exactly one device
dispatch per live scale, vs one per subset for the loop), and
``sharded.shard_utilisation`` (valid-cell fraction per shard — the
complement is pad waste shipped to that device).
"""
from __future__ import annotations

import argparse
import json
import os
import time

OUT = "BENCH_batch.json"


def _time(fn, reps: int = 3) -> float:
    """Best-of-``reps`` wall time: this box is small and noisy; taking the
    minimum suppresses scheduler interference, and every strategy is measured
    the same way, so the reported QPS are comparable best-case rates."""
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def main(fast: bool = False, mesh: int = 0) -> dict:
    if mesh > 1 and "xla_force_host_platform_device_count" \
            not in os.environ.get("XLA_FLAGS", ""):
        # Must land before the first jax computation: the device count is
        # locked at backend init. Heavy imports are deferred for the same
        # reason. An externally forced count (e.g. the CI matrix) wins.
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={mesh}").strip()
    from benchmarks.common import emit
    from repro.core.backend import NumpyBackend, PallasBackend
    from repro.data.flickr_like import flickr_like_dataset
    from repro.data.synthetic import random_queries
    from repro.serve.engine import NKSEngine

    if mesh > 1:
        # Fail fast, before minutes of single-device timing: the device
        # count is locked at backend init, so a short environment (external
        # XLA_FLAGS with a smaller count, or jax touched before main) can't
        # be fixed later in the run.
        import jax
        if jax.local_device_count() < mesh:
            raise RuntimeError(
                f"--mesh {mesh} needs {mesh} devices but jax sees "
                f"{jax.local_device_count()} (was a jax computation issued "
                f"before this process set XLA_FLAGS?)")

    n = 1_500 if fast else 6_000
    batch = 16 if fast else 32
    ds = flickr_like_dataset(n=n, d=16, u=30, t=3, n_clusters=12, seed=4)
    engine = NKSEngine(ds, m=2, n_scales=5, seed=0)
    queries = random_queries(ds, 3, batch, seed=9)
    k = 2

    results: dict = {"n": n, "d": ds.dim, "batch": batch, "k": k,
                     "fast": fast, "mesh": mesh if mesh > 1 else 1,
                     "tiers": {}}
    for tier in ("exact", "approx"):
        t_loop = _time(lambda: [engine.query(q, k=k, tier=tier)
                                for q in queries])
        t_np = _time(lambda: engine.query_batch(queries, k=k, tier=tier,
                                                backend=NumpyBackend()))
        np_stats = engine.last_batch_stats
        # batch_pallas_qps measures the default-constructed backend — the
        # shipping configuration, as every baseline before it did. Since
        # PR 6 that default is the full cascade: quantile bins, prune tier
        # (auto), and cost-model routing that sends bins below the device
        # break-even to the exact host path. The device-dispatch pipeline
        # itself is pinned and measured separately as batch_device_qps
        # (that's also the comparable number for the sharded leg).
        pallas = PallasBackend()
        # one warm-up to amortise tracing/compile out of the steady-state rate
        engine.query_batch(queries, k=k, tier=tier, backend=pallas)
        # cache-cold rate: a fresh backend per rep (compile stays warm —
        # it is process-global — but every subset re-packs and re-ships),
        # vs the steady-state rate where the packed-tile LRU is hot. Real
        # serving with repeated keyword sets sits between the two.
        t_pl_cold = _time(lambda: engine.query_batch(
            queries, k=k, tier=tier, backend=PallasBackend()))
        t_pl = _time(lambda: engine.query_batch(queries, k=k, tier=tier,
                                                backend=pallas))
        pl_res = engine.query_batch(queries, k=k, tier=tier, backend=pallas)
        pl_stats = engine.last_batch_stats
        device = PallasBackend(route="device")
        engine.query_batch(queries, k=k, tier=tier, backend=device)
        t_dev = _time(lambda: engine.query_batch(queries, k=k, tier=tier,
                                                 backend=device))
        dev_stats = engine.last_batch_stats
        # The cascade contract, checked on the bench corpus itself: the
        # mixed-precision prune tier, quantile re-binning, and cost-model
        # host routing must not change a single result (ids and diameters,
        # bitwise) vs the cascade-off device route. NumpyBackend is *not*
        # the reference here — its dense-f64 path rounds differently at the
        # last ulp by design; the cascade is judged against its own route.
        plain = PallasBackend(route="device", prune_tier="off",
                              bin_strategy="pow2")
        plain_res = engine.query_batch(queries, k=k, tier=tier, backend=plain)
        dev_res = engine.query_batch(queries, k=k, tier=tier, backend=device)

        def _same(r1, r2):
            return all(
                [(c.ids, c.diameter) for c in a.candidates]
                == [(c.ids, c.diameter) for c in b.candidates]
                for a, b in zip(r1, r2))

        parity = _same(pl_res, plain_res) and _same(dev_res, plain_res)
        tier_res = {
            "loop_qps": batch / t_loop,
            "batch_numpy_qps": batch / t_np,
            "batch_pallas_qps": batch / t_pl,
            "batch_pallas_cold_qps": batch / t_pl_cold,
            # alias of batch_pallas_qps since the auto-routed cascade became
            # the default; kept as its own gated field so a future default
            # change can't silently drop the auto route from the gate.
            "batch_auto_qps": batch / t_pl,
            "batch_device_qps": batch / t_dev,
            "cascade_result_parity": bool(parity),
            "numpy_dispatches": np_stats.total_dispatches,
            "pallas_dispatches": pl_stats.total_dispatches,
            "pallas_dispatches_per_scale": pl_stats.dispatches_per_scale,
            # Per-phase wall breakdown (plan / pack / dispatch / enumerate)
            # plus the packed-subset LRU hit rate, so future perf PRs can see
            # where batch time goes without re-instrumenting.
            "numpy_phases": np_stats.phases,
            "pallas_phases": pl_stats.phases,
            # Cascade split (prune / fp32 join / host route / f64 rescore)
            # and the padding the binning left on the device.
            "pallas_cascade": pl_stats.cascade,
            "device_cascade": dev_stats.cascade,
            "auto_routing": {
                "host_routed_dispatches": pl_stats.host_routed_dispatches,
                "host_routed_subsets": pl_stats.host_routed_subsets,
            },
        }
        # Quantile-vs-pow2 padded-cell ratio on the same task stream: fresh
        # backend per strategy so bin occupancy is measured cache-cold.
        binning = {}
        for strat in ("quantile", "pow2"):
            sb = PallasBackend(route="device", bin_strategy=strat)
            engine.query_batch(queries, k=k, tier=tier, backend=sb)
            binning[strat] = engine.last_batch_stats.binning
        tier_res["binning"] = binning
        results["tiers"][tier] = tier_res
        emit(f"batch.loop.{tier}", t_loop / batch * 1e6, f"B={batch}")
        emit(f"batch.numpy.{tier}", t_np / batch * 1e6,
             f"dispatches={np_stats.total_dispatches}")
        emit(f"batch.pallas.{tier}", t_pl / batch * 1e6,
             f"dispatches={pl_stats.total_dispatches}")

    if mesh > 1:
        from repro.core.device_plane import DevicePlane
        from repro.launch.mesh import make_serving_mesh
        plane = DevicePlane(make_serving_mesh(data=mesh))
        for tier in ("exact", "approx"):
            # route="device": the sharded number is compared against the
            # single-device batch_device_qps, which is also device-pinned
            # (auto routing would bypass the plane on host-platform meshes).
            shard_be = PallasBackend(plane=plane, route="device")
            engine.query_batch(queries, k=k, tier=tier, backend=shard_be)
            t_sh = _time(lambda: engine.query_batch(
                queries, k=k, tier=tier, backend=shard_be))
            st = engine.last_batch_stats
            single_qps = results["tiers"][tier]["batch_device_qps"]
            results["tiers"][tier]["sharded"] = {
                "mesh": mesh,
                "batch_pallas_sharded_qps": batch / t_sh,
                "speedup_vs_single": (batch / t_sh) / single_qps,
                "sharded_dispatches": st.sharded_dispatches,
                "shard_dispatches": list(st.shard_dispatches),
                "shard_utilisation": st.shard_utilisation,
                "padded_cell_ratio": [round(1.0 - u, 4)
                                      for u in st.shard_utilisation],
                "phases": st.phases,
            }
            emit(f"batch.pallas_sharded.{tier}", t_sh / batch * 1e6,
                 f"mesh={mesh} sharded={st.sharded_dispatches}"
                 f"/{st.total_dispatches}")

    with open(OUT, "w") as f:
        json.dump(results, f, indent=2)
    print(f"# wrote {os.path.abspath(OUT)}")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    default=os.environ.get("BENCH_FAST", "") == "1")
    ap.add_argument("--mesh", type=int, default=0,
                    help="force N host devices and add the sharded-vs-single"
                         " comparison (serving plane over the data axis)")
    args = ap.parse_args()
    main(fast=args.fast, mesh=args.mesh)
