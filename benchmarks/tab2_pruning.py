"""Table II — percentage ratio N_p/N_n of expected explored candidates to
total candidates, vs dataset dimension (the §VII pruning-power model).
Paper: 0.007% at d=2 rising to ~47% at d=32."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import theory
from repro.data.synthetic import random_queries, synthetic_dataset

DIMS = (2, 4, 8, 16, 32)


def main(fast: bool = False):
    dims = DIMS[:3] if fast else DIMS
    n = 1_000 if fast else 1_500     # oracle is exponential in q; keep group
    for d in dims:                   # sizes ~50 so eq.7's MC stays feasible
        ds = synthetic_dataset(n=n, d=d, u=30, t=1, seed=d)
        ratios = []
        for query in random_queries(ds, 3, 2 if fast else 4, seed=d):
            # width = 2 r* (the model's bin width)
            from repro.core import brute_force
            r_star = brute_force.search(ds, query, k=1).items[0].diameter
            if r_star <= 0:
                continue
            n_p, n_n = theory.expected_explored(
                ds, query, m=2, width=2 * r_star,
                n_vectors=128 if fast else 512,
                max_candidates=2_000 if fast else 10_000, seed=d)
            if n_n:
                ratios.append(100.0 * n_p / n_n)
        emit(f"tab2.pruning_ratio.d{d}", float(np.mean(ratios)) * 1e6,
             f"Np/Nn_pct={np.mean(ratios):.4f}")


if __name__ == "__main__":
    main()
