"""Index-build throughput: the fused Pallas projection+binning kernel
(interpret mode on CPU) validated against the numpy control plane, plus
end-to-end HI-structure build rate (points/s) — the §III build path."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import projection as proj
from repro.core.index import build_index
from repro.data.synthetic import synthetic_dataset
from repro.kernels import ops


def main(fast: bool = False):
    n = 2_000 if fast else 20_000
    d = 32
    ds = synthetic_dataset(n=n, d=d, u=100, t=2, seed=0)
    rng = np.random.default_rng(0)
    z = proj.sample_unit_vectors(rng, 2, d)

    # numpy control plane
    t0 = time.perf_counter()
    p = proj.project(ds.points, z)
    proj.bin_keys_overlapping(p, 100.0)
    t_np = time.perf_counter() - t0
    emit("build.project_bin.numpy", t_np * 1e6, f"N={n}")

    # Pallas kernel (interpret on CPU; Mosaic on TPU)
    x_j = jnp.asarray(ds.points)
    z_j = jnp.asarray(z)
    h1, h2, pj = ops.project_and_bin(x_j, z_j, 100.0, 1 << 20)  # compile
    t0 = time.perf_counter()
    h1, h2, pj = ops.project_and_bin(x_j, z_j, 100.0, 1 << 20)
    h1.block_until_ready()
    t_k = time.perf_counter() - t0
    emit("build.project_bin.pallas", t_k * 1e6, f"N={n} interpret")
    np.testing.assert_allclose(np.asarray(pj), p, atol=1e-3)

    # full multi-scale index build
    t0 = time.perf_counter()
    build_index(ds, m=2, n_scales=5, exact=True, seed=0)
    t_idx = time.perf_counter() - t0
    emit("build.index_e.full", t_idx * 1e6, f"pts_per_s={n / t_idx:.0f}")


if __name__ == "__main__":
    main()
