"""Table IV — index-space / dataset-space ratios for ProMiSH-E, ProMiSH-A and
Virtual bR*-Tree across d, N, U (analytic §VII/§VIII-D formulas + measured
footprints of the actual structures at a reference size)."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core.baseline_tree import VirtualBRTree, space_cost_model
from repro.core.index import build_index
from repro.data.synthetic import synthetic_dataset

E_BYTES = 4


def analytic(n: int, d: int, u: int, *, m: int = 2, levels: int = 5,
             buckets: int = 10_000, t: int = 1, q: int = 5):
    ds_bytes = (d + t) * n * E_BYTES
    ikp = n * E_BYTES * t
    h_e = (2 ** m) * n * E_BYTES
    h_a = n * E_BYTES
    import math
    ikhb = u * buckets * math.log2(max(buckets, 2)) / 8
    pe = (ikp + levels * (h_e + ikhb)) / ds_bytes
    pa = (ikp + levels * (h_a + ikhb)) / ds_bytes
    tree = space_cost_model(n, d, u, q, t, E_BYTES) / ds_bytes
    return pe, pa, tree


def main(fast: bool = False):
    for d in ((8, 32) if fast else (8, 16, 32, 64, 128)):
        for n, u in (((10_000_000, 100),) if fast else
                     ((10_000_000, 100), (10_000_000, 1000),
                      (100_000_000, 100))):
            pe, pa, tr = analytic(n, d, u)
            emit(f"tab4.ratio.d{d}.n{n}.u{u}", 0.0,
                 f"E={pe:.2f}|A={pa:.2f}|tree={tr:.2f}")
    # measured footprints at a reference size (actual structures)
    ds = synthetic_dataset(n=3_000 if fast else 20_000, d=16, u=200, t=1, seed=0)
    idx_e = build_index(ds, m=2, n_scales=5, exact=True)
    idx_a = build_index(ds, m=2, n_scales=5, exact=False)
    tree = VirtualBRTree(ds, leaf_size=256, fanout=32)
    base = ds.nbytes()
    emit("tab4.measured.promish_e", 0.0, f"ratio={idx_e.nbytes() / base:.2f}")
    emit("tab4.measured.promish_a", 0.0, f"ratio={idx_a.nbytes() / base:.2f}")
    emit("tab4.measured.vbrtree", 0.0, f"ratio={tree.nbytes() / base:.2f}")


if __name__ == "__main__":
    main()
