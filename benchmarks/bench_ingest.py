"""Streaming-ingest throughput — sustained QPS under a live insert:query mix.

The serving question behind the ISSUE-4 scenario axis: when the corpus is
mutating (inserts land in the delta, deletes tombstone, compaction rebuilds
on a cadence), how much query throughput survives, and what do the
generation-tagged caches retain across absorbs? Per tier, the workload
interleaves one insert batch with ``--mix`` query batches (the 1:10
insert:query op mix), sprinkles deletes (~10% of each absorbed batch a round
later), and lets auto-compaction fire at the configured cadence:

    PYTHONPATH=src python -m benchmarks.bench_ingest [--fast] [--mesh N]
                                                     [--pipeline]

Writes ``BENCH_ingest.json``. ``--pipeline`` adds a document-ingestion leg
(``tiers.pipeline``): raw documents under a Poisson arrival process drain
through the ``data/ingest.py`` job-queue worker pipeline, recording docs/s
plus retry/reclaim counts with armed transient faults. Numbers of note: ``qps_sustained`` vs
``qps_static`` (the ingest tax on query throughput), ``compactions`` /
``generation`` (the cadence actually exercised), and the exact tier's
``cache_hit_rate`` under churn — the retention fix means absorbs must NOT
flush the packed-subset LRU (``generation_purges`` counts only compactions).
The approx tier mostly terminates at scale 0 where infinite pruning radii
skip the device, so its cache counters are legitimately near-zero.
"""
from __future__ import annotations

import argparse
import json
import os
import time

OUT = "BENCH_ingest.json"


def main(fast: bool = False, mesh: int = 0, mix: int = 10,
         insert_batch: int | None = None, query_batch: int | None = None,
         rounds: int | None = None, pipeline: bool = False) -> dict:
    if mesh > 1 and "xla_force_host_platform_device_count" \
            not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={mesh}").strip()
    import numpy as np

    from benchmarks.common import emit
    from repro.core.backend import PallasBackend
    from repro.core.types import make_dataset
    from repro.data.flickr_like import flickr_like_dataset
    from repro.data.synthetic import random_queries
    from repro.serve.engine import NKSEngine

    plane = None
    if mesh > 1:
        import jax
        if jax.local_device_count() < mesh:
            raise RuntimeError(
                f"--mesh {mesh} needs {mesh} devices but jax sees "
                f"{jax.local_device_count()}")
        from repro.core.device_plane import DevicePlane
        from repro.launch.mesh import make_serving_mesh
        plane = DevicePlane(make_serving_mesh(data=mesh))

    n0 = 1_500 if fast else 5_000
    rounds = rounds or (8 if fast else 20)
    ib = insert_batch or (40 if fast else 100)
    qb = query_batch or (8 if fast else 16)
    stream_total = rounds * ib
    k = 2

    # One generator run for bulk + stream keeps the keyword statistics of the
    # stream identical to the resident corpus (same Zipf tails, same cluster
    # affinity) — the stream is the tail of the same "upload" process.
    full = flickr_like_dataset(n=n0 + stream_total, d=16, u=30, t=3,
                               n_clusters=12, seed=4)
    ds0 = make_dataset(full.points[:n0],
                       [full.kw.row(i).tolist() for i in range(n0)],
                       n_keywords=full.n_keywords)
    queries = random_queries(ds0, 3, qb, seed=9)

    def run_tier(tier: str) -> dict:
        # Compaction cadence sized so every run exercises a few rebuilds.
        engine = NKSEngine(ds0, m=2, n_scales=5, seed=0,
                           compact_min=max(64, stream_total // 3),
                           compact_ratio=0.05, mesh=plane)
        backend = PallasBackend(plane=plane)   # persistent: LRU must survive

        # Static reference rate: warmed engine, no churn.
        engine.query_batch(queries, k=k, tier=tier, backend=backend)
        t0 = time.perf_counter()
        static_reps = 3
        for _ in range(static_reps):
            engine.query_batch(queries, k=k, tier=tier, backend=backend)
        qps_static = qb * static_reps / (time.perf_counter() - t0)

        n_queries = 0
        t_insert = t_delete = t_query = 0.0
        deleted = 0
        t_run0 = time.perf_counter()
        for r in range(rounds):
            lo = n0 + r * ib
            pts = full.points[lo:lo + ib]
            kws = [full.kw.row(i).tolist() for i in range(lo, lo + ib)]
            t1 = time.perf_counter()
            engine.insert(pts, kws)
            t_insert += time.perf_counter() - t1
            # delete ~10% of each absorbed batch a round later (mixed churn);
            # timed separately so inserted_points_per_s stays a pure absorb
            # rate (a delete/retire regression must not read as one).
            if r:
                prev = np.arange(lo - ib, lo - ib + max(1, ib // 10))
                t1 = time.perf_counter()
                engine.delete(prev)
                t_delete += time.perf_counter() - t1
                deleted += len(prev)
            t1 = time.perf_counter()
            for _ in range(mix):
                engine.query_batch(queries, k=k, tier=tier, backend=backend)
                n_queries += qb
            t_query += time.perf_counter() - t1
        t_total = time.perf_counter() - t_run0

        st = engine.last_batch_stats
        bs = backend.stats
        probed = bs.cache_hits + bs.cache_misses
        out = {
            "qps_static": qps_static,
            "qps_sustained": n_queries / t_total,
            # Ingest tax: fraction of static query throughput lost to the
            # live mix (0 = churn is free, 1 = queries fully starved).
            "ingest_tax": round(1.0 - (n_queries / t_total) / qps_static, 4),
            "qps_query_phase": n_queries / t_query if t_query else 0.0,
            "inserted_points_per_s": stream_total / t_insert if t_insert else 0.0,
            "deleted_points_per_s": deleted / t_delete if t_delete else 0.0,
            "ingest_wall_fraction": (t_insert + t_delete) / t_total,
            "deleted_points": deleted,
            "compactions": engine.ingest.compactions,
            "generation": engine.corpus_generation,
            "delta_points_final": engine.delta_points,
            "tombstones_final": engine.tombstone_count,
            "cache_hit_rate": round(bs.cache_hits / probed, 4) if probed else None,
            "generation_purges": bs.generation_purges,
            "last_batch_phases": st.phases,
            "last_batch_ingest": st.ingest,
        }
        if mesh > 1:
            out["sharding"] = st.sharding
        emit(f"ingest.static.{tier}", 1e6 / qps_static, f"B={qb}")
        emit(f"ingest.sustained.{tier}", 1e6 * t_total / max(n_queries, 1),
             f"mix=1:{mix} compactions={engine.ingest.compactions}")
        return out

    def run_wal_leg() -> dict:
        """Durable-ingest throughput: per-op fsync vs WAL group commit.

        Both modes append the identical op sequence to a fresh WAL (small
        ops, the regime where the fsync barrier dominates the absorb cost);
        ``group`` wraps each round in ``engine.ingest_group()`` so the
        round's acks share one barrier. Recovery equivalence is the WAL
        suite's job — this leg measures what the coalesced barrier buys.
        """
        import shutil
        import tempfile

        op = max(1, ib // 20)          # small durable ops: fsync-bound
        out: dict = {"op_points": op}
        for mode in ("per_op", "group"):
            root = tempfile.mkdtemp(prefix="nks-walbench-")
            try:
                engine = NKSEngine(ds0, m=2, n_scales=5, seed=0,
                                   build_approx=False, auto_compact=False)
                engine.attach_wal(root)
                t0 = time.perf_counter()
                for r in range(rounds):
                    lo = n0 + r * ib
                    pts = full.points[lo:lo + ib]
                    kws = [full.kw.row(i).tolist()
                           for i in range(lo, lo + ib)]
                    if mode == "group":
                        with engine.ingest_group():
                            for j in range(0, ib, op):
                                engine.insert(pts[j:j + op], kws[j:j + op])
                    else:
                        for j in range(0, ib, op):
                            engine.insert(pts[j:j + op], kws[j:j + op])
                dt = time.perf_counter() - t0
                st = engine.wal_stats
                out[mode] = {
                    "points_per_s": stream_total / dt,
                    "ops_per_s": st.appends / dt,
                    "fsyncs": st.fsyncs,
                    "group_commit_batch": st.group_commit_batch,
                }
                engine.close()
                emit(f"ingest.wal_{mode}", 1e6 * dt / st.appends,
                     f"fsyncs={st.fsyncs}")
            finally:
                shutil.rmtree(root, ignore_errors=True)
        out["group_commit_speedup"] = round(
            out["group"]["points_per_s"] / out["per_op"]["points_per_s"], 3)
        return out

    def run_pipeline_leg() -> dict:
        """Document-ingestion pipeline under a Poisson arrival process.

        Raw ``flickr_like`` documents arrive with exponential inter-arrival
        gaps (materialised up front as per-job ``not_before`` instants), a
        worker fleet drains the persistent job queue through the embed +
        WAL-group-committed insert stages, and a pair of armed transient
        faults forces the retry path so the recorded retry counts are
        non-trivial. ``docs_per_s`` is completion throughput including the
        arrival pacing — it tracks the offered rate while the pipeline
        keeps up, and sags below it when ingest is the bottleneck.
        """
        import shutil
        import tempfile

        from repro.data.ingest import (IngestPipeline, JobStore,
                                       ProjectionEmbedder,
                                       corpus_from_documents,
                                       flickr_like_documents)
        from repro.serve.faults import FaultPlan

        n_docs = 400 if fast else 2_000
        n_seed = 200 if fast else 600
        workers, batch_docs = 4, 32
        arrival_rate = n_docs / (1.5 if fast else 6.0)   # docs/s offered
        d_raw = 32
        docs, vocab = flickr_like_documents(n_seed + n_docs, d_raw=d_raw,
                                            u=30, t=3, seed=7)
        embedder = ProjectionEmbedder(ds0.dim, vocab, d_raw=d_raw, seed=7)
        seed_ds, _ = corpus_from_documents(docs[:n_seed], embedder)
        rng = np.random.default_rng(12)
        offsets = np.cumsum(rng.exponential(1.0 / arrival_rate, n_docs))
        root = tempfile.mkdtemp(prefix="nks-ingestbench-")
        try:
            store = JobStore(os.path.join(root, "jobs.jsonl"), lease_s=5.0,
                             backoff_s=0.005, max_attempts=8)
            engine = NKSEngine(seed_ds, m=2, n_scales=5, seed=0,
                               build_approx=False, auto_compact=False)
            engine.attach_wal(os.path.join(root, "wal"))
            faults = FaultPlan(transient={"insert": 4, "embed": 9})
            pipe = IngestPipeline(store, engine, embedder, workers=workers,
                                  batch_docs=batch_docs, faults=faults)
            store.add(docs[n_seed:],
                      not_before=store.clock() + offsets)
            report = pipe.run(timeout_s=60.0 + float(offsets[-1]))
            wal_st = engine.wal_stats
            engine.close()
            store.close()
        finally:
            shutil.rmtree(root, ignore_errors=True)
        out = {
            "docs_per_s": report["docs_per_s"],
            "arrival_rate_offered": round(arrival_rate, 2),
            "docs": n_docs, "workers": workers, "batch_docs": batch_docs,
            "drained": report["drained"],
            "docs_done": report["docs_done"],
            "failed": report["docs_failed"],
            "retries": report["retries"],
            "reclaims": report["reclaims"],
            "wall_s": round(report["wall_s"], 3),
            "wal_fsyncs": wal_st.fsyncs,
            "transient_faults_fired": sum(faults.fired.values()),
        }
        emit("ingest.pipeline", 1e6 / max(report["docs_per_s"], 1e-9),
             f"workers={workers} offered={arrival_rate:.0f}/s "
             f"retries={report['retries']}")
        return out

    results: dict = {
        "n0": n0, "d": ds0.dim, "fast": fast, "mesh": mesh if mesh > 1 else 1,
        "k": k, "rounds": rounds, "insert_batch": ib, "query_batch": qb,
        "mix": mix, "inserted_points": stream_total,
        "tiers": {tier: run_tier(tier) for tier in ("approx", "exact")},
        "wal": run_wal_leg(),
    }
    if pipeline:
        results["tiers"]["pipeline"] = run_pipeline_leg()
    # How much worse the approx tier's ingest tax is than the exact tier's:
    # the batched suspect re-verification (IndexDelta.verify_suspects) should
    # keep this near zero — both tiers share the same delta maintenance.
    results["ingest_tax_delta_approx_vs_exact"] = round(
        results["tiers"]["approx"]["ingest_tax"]
        - results["tiers"]["exact"]["ingest_tax"], 4)
    with open(OUT, "w") as f:
        json.dump(results, f, indent=2)
    print(f"# wrote {os.path.abspath(OUT)}")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    default=os.environ.get("BENCH_FAST", "") == "1")
    ap.add_argument("--mesh", type=int, default=0,
                    help="force N host devices; ingest under the sharded "
                         "serving plane")
    ap.add_argument("--mix", type=int, default=10,
                    help="query batches per insert batch (1:N op mix)")
    ap.add_argument("--insert-batch", type=int, default=None)
    ap.add_argument("--query-batch", type=int, default=None)
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--pipeline", action="store_true",
                    help="add the document-ingestion pipeline leg: Poisson "
                         "document arrivals through the job-queue worker "
                         "pipeline (data/ingest.py), recording docs/s and "
                         "retry counts")
    args = ap.parse_args()
    main(fast=args.fast, mesh=args.mesh, mix=args.mix,
         insert_batch=args.insert_batch, query_batch=args.query_batch,
         rounds=args.rounds, pipeline=args.pipeline)
