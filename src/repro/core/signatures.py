"""Signature construction and bucket hashing (paper §III).

ProMiSH-E: each point has 2 keys per projection (overlapping bins); the
cartesian product over m projections yields 2^m signatures per point.
ProMiSH-A: one key per projection -> one signature per point.

A signature is reduced to a hashtable bucket id with a fixed multiplicative
hash. The multipliers are constants (not data-dependent) so that distributed
shards agree on bucket ids (DESIGN.md A3).
"""
from __future__ import annotations

import numpy as np

# Fixed odd 64-bit multipliers (splitmix64 outputs), one per projection slot.
_MULTIPLIERS = np.array(
    [
        0x9E3779B97F4A7C15,
        0xBF58476D1CE4E5B9,
        0x94D049BB133111EB,
        0xD6E8FEB86659FD93,
        0xA5CB3B1F6E9F8B17,
        0xC2B2AE3D27D4EB4F,
        0x165667B19E3779F9,
        0x27D4EB2F165667C5,
    ],
    dtype=np.uint64,
)


def signature_table(m: int) -> np.ndarray:
    """(2^m, m) binary selector table: row j picks key h1 or h2 for each of the
    m projections — the cartesian product enumeration."""
    j = np.arange(1 << m, dtype=np.int64)[:, None]
    return ((j >> np.arange(m, dtype=np.int64)[None, :]) & 1).astype(np.int64)


def signatures_overlapping(keys2: np.ndarray) -> np.ndarray:
    """keys2: (N, m, 2) dual keys -> (N, 2^m, m) all signatures per point."""
    n, m, _ = keys2.shape
    sel = signature_table(m)                      # (2^m, m)
    idx = np.broadcast_to(sel[None], (n, 1 << m, m))
    gathered = np.take_along_axis(keys2[:, None, :, :].repeat(1 << m, axis=1),
                                  idx[..., None], axis=3)
    return gathered[..., 0]                        # (N, 2^m, m)


def hash_signatures(sigs: np.ndarray, n_buckets: int) -> np.ndarray:
    """Multiplicative hash: (sum_i key_i * mult_i) mod n_buckets.

    sigs: (..., m) int64 -> (...,) int64 bucket ids in [0, n_buckets).
    """
    m = sigs.shape[-1]
    if m > len(_MULTIPLIERS):
        raise ValueError(f"m={m} exceeds supported projections {len(_MULTIPLIERS)}")
    acc = (sigs.astype(np.uint64) * _MULTIPLIERS[:m]).sum(axis=-1)
    # 64-bit finalizer improves low-bit avalanche before the modulo.
    acc ^= acc >> np.uint64(33)
    acc *= np.uint64(0xFF51AFD7ED558CCD)
    acc ^= acc >> np.uint64(33)
    return (acc % np.uint64(n_buckets)).astype(np.int64)


def bucket_ids_overlapping(keys2: np.ndarray, n_buckets: int) -> np.ndarray:
    """(N, m, 2) -> (N, 2^m) bucket ids (ProMiSH-E: 2^m buckets per point)."""
    return hash_signatures(signatures_overlapping(keys2), n_buckets)


def bucket_ids_disjoint(keys: np.ndarray, n_buckets: int) -> np.ndarray:
    """(N, m) -> (N,) bucket ids (ProMiSH-A: one bucket per point)."""
    return hash_signatures(keys, n_buckets)
