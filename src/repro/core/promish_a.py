"""ProMiSH-A: approximate NKS search (paper §VI).

Differences from ProMiSH-E (kept faithful):
  * index uses non-overlapping bins -> one signature per point,
    so hashtables are 2^m-times smaller;
  * PQ starts empty (no +inf sentinels), so the first explored buckets set
    r_k and prune aggressively;
  * terminates after the first scale at which PQ holds k results;
  * no subset-duplicate check is needed (a point lives in exactly one bucket
    per scale, so bucket subsets within a scale are disjoint) — the plan
    layer runs with ``explored=None``.

§VI's statistical model bounding the approximation ratio is implemented in
``repro.core.theory``.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core import plan
from repro.core.index import PromishIndex
from repro.core.promish_e import SearchStats, _search_flex
from repro.core.semantics import QuerySemantics
from repro.core.subset_search import DistanceFn, pairwise_l2_numpy, search_in_subset
from repro.core.types import KeywordDataset, TopK


def search(dataset: KeywordDataset, index: PromishIndex, query: Sequence[int],
           k: int = 1, distance_fn: DistanceFn = pairwise_l2_numpy,
           stats: SearchStats | None = None,
           eligible: np.ndarray | None = None,
           semantics=None) -> TopK:
    """Approximate top-k NKS search. ``eligible`` applies a filtered query's
    point-eligibility mask: every returned candidate is drawn from eligible
    points only (the approx tier's feasibility contract), with the same
    subset-pruning and group-restriction mechanics as ProMiSH-E.
    ``semantics`` enables the flexible m-of-k/weighted/scored modes through
    the shared ``_search_flex`` loop (A semantics: empty queue, no dedup,
    stop at the first scale that fills it)."""
    if index.exact:
        raise ValueError("ProMiSH-A requires an approximate (disjoint-bin) index")
    query = sorted(set(int(v) for v in query))
    stats = stats if stats is not None else SearchStats()
    sem = QuerySemantics.coerce(semantics)
    if sem is not None and not sem.trivial_for(query):
        return _search_flex(dataset, index, query, k, sem,
                            distance_fn, stats, eligible, exact=False)

    pq = TopK(k, init_full=False)
    bitsets = [plan.query_bitset(dataset, query)]

    for s in range(index.n_scales):
        stats.scales_visited += 1
        for task in plan.plan_scale(index, s, [query], bitsets, [0],
                                    None, stats, eligible=eligible):
            stats.subsets_searched += 1
            stats.candidates_explored += search_in_subset(
                task.f_ids, query, dataset, pq, distance_fn=distance_fn,
                eligible=eligible)
        if pq.full():
            return pq

    # Fallback mirrors ProMiSH-E: guarantees an answer when the hash never
    # co-locates all keywords (rare; more likely for very selective queries).
    stats.fallback = True
    for task in plan.fallback_tasks(bitsets, [0], eligible=eligible):
        stats.candidates_explored += search_in_subset(
            task.f_ids, query, dataset, pq, distance_fn=distance_fn,
            eligible=eligible)
    return pq
