"""Virtual bR*-Tree baseline (Zhang et al. [2], [7]) — the paper's reference.

A bulk-loaded (STR) R*-style tree whose nodes carry keyword bitmaps and MBRs.
Queries run a best-first branch-and-bound over q-tuples of entries (one per
query keyword, apriori-style growth), pruning by:
  * keyword bitmaps  (a node without keyword v cannot supply group v),
  * MBR pair mindist (a tuple whose max pairwise MINDIST exceeds the current
    r_k cannot contain a better candidate).

This reproduces the reference algorithm's behaviour, including its failure
mode: in high dimensions MBRs overlap (curse of dimensionality), MINDIST
collapses to ~0, pruning stops working, and the frontier grows exponentially —
exactly the >hours runtimes in the paper's figs. 8-10. A ``budget`` caps the
number of frontier pops so benchmarks terminate; hitting it is reported as a
timeout, mirroring the paper's ">5 hours" entries.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Sequence

import numpy as np

from repro.core.subset_search import is_minimal_candidate, pairwise_l2_numpy
from repro.core.types import Candidate, KeywordDataset, TopK


@dataclasses.dataclass
class _Node:
    lo: np.ndarray              # (d,) MBR lower corner
    hi: np.ndarray              # (d,) MBR upper corner
    kw_mask: np.ndarray         # (U,) bool keyword bitmap
    children: list["_Node"] | None   # internal
    point_ids: np.ndarray | None     # leaf
    depth: int = 0

    @property
    def is_leaf(self) -> bool:
        return self.point_ids is not None


class VirtualBRTree:
    """STR-packed R-tree with keyword bitmaps (leaf_size/fanout per paper §VIII:
    1000-entry leaves, 100-entry internal nodes)."""

    def __init__(self, dataset: KeywordDataset, leaf_size: int = 1000, fanout: int = 100):
        self.dataset = dataset
        self.leaf_size = leaf_size
        self.fanout = fanout
        self.root = self._bulk_load()

    # ---------------------------------------------------------------- build
    def _make_leaf(self, ids: np.ndarray) -> _Node:
        pts = self.dataset.points[ids]
        mask = np.zeros(self.dataset.n_keywords, dtype=bool)
        for p in ids:
            mask[self.dataset.kw.row(int(p))] = True
        return _Node(lo=pts.min(0), hi=pts.max(0), kw_mask=mask,
                     children=None, point_ids=ids)

    def _str_partition(self, ids: np.ndarray, node_cap: int) -> list[np.ndarray]:
        """Sort-Tile-Recursive packing of point ids into node_cap-sized cells."""
        pts = self.dataset.points[ids]
        d = pts.shape[1]
        n_cells = int(np.ceil(len(ids) / node_cap))
        order = np.argsort(pts[:, 0], kind="stable")
        ids = ids[order]
        if d == 1 or n_cells == 1:
            return [ids[i * node_cap:(i + 1) * node_cap] for i in range(n_cells)]
        n_slabs = int(np.ceil(np.sqrt(n_cells)))
        slab_sz = int(np.ceil(len(ids) / n_slabs))
        out = []
        for s in range(n_slabs):
            slab = ids[s * slab_sz:(s + 1) * slab_sz]
            if len(slab) == 0:
                continue
            sub = slab[np.argsort(self.dataset.points[slab, 1 % d], kind="stable")]
            for i in range(0, len(sub), node_cap):
                out.append(sub[i:i + node_cap])
        return out

    def _bulk_load(self) -> _Node:
        ids = np.arange(self.dataset.n, dtype=np.int64)
        nodes = [self._make_leaf(c) for c in self._str_partition(ids, self.leaf_size)]
        depth = 1
        while len(nodes) > 1:
            centers = np.stack([(nd.lo + nd.hi) * 0.5 for nd in nodes])
            order = np.lexsort((centers[:, 1 % centers.shape[1]], centers[:, 0]))
            nodes = [nodes[i] for i in order]
            parents = []
            for i in range(0, len(nodes), self.fanout):
                ch = nodes[i:i + self.fanout]
                lo = np.min([c.lo for c in ch], axis=0)
                hi = np.max([c.hi for c in ch], axis=0)
                mask = np.any([c.kw_mask for c in ch], axis=0)
                parents.append(_Node(lo=lo, hi=hi, kw_mask=mask, children=ch,
                                     point_ids=None, depth=depth))
            nodes = parents
            depth += 1
        return nodes[0]

    def nbytes(self) -> int:
        total = 0
        stack = [self.root]
        while stack:
            nd = stack.pop()
            total += nd.lo.nbytes + nd.hi.nbytes + nd.kw_mask.nbytes // 8 + 16
            if nd.children:
                stack.extend(nd.children)
            else:
                total += nd.point_ids.nbytes
        return total

    # ---------------------------------------------------------------- query
    def _mindist_entries(self, a, b) -> float:
        """MINDIST between two entries; an entry is ('n', node) or ('p', id)."""
        lo_a, hi_a = self._bounds(a)
        lo_b, hi_b = self._bounds(b)
        gap = np.maximum(0.0, np.maximum(lo_a - hi_b, lo_b - hi_a))
        return float(np.linalg.norm(gap))

    def _bounds(self, e):
        kind, v = e
        if kind == "p":
            pt = self.dataset.points[v]
            return pt, pt
        return v.lo, v.hi

    def _has_kw(self, e, v: int) -> bool:
        kind, x = e
        if kind == "p":
            return self.dataset.has_keyword(int(x), v)
        return bool(x.kw_mask[v])

    def _tuple_lb(self, entries) -> float:
        lb = 0.0
        for i in range(len(entries)):
            for j in range(i + 1, len(entries)):
                lb = max(lb, self._mindist_entries(entries[i], entries[j]))
        return lb

    def initial_estimate(self, query: Sequence[int], samples: int = 4) -> float:
        """Greedy upper bound on r*: from a few seeds of the rarest keyword,
        chain nearest matching points for the remaining keywords."""
        ds = self.dataset
        groups = {v: ds.ikp.row(v) for v in query}
        rare = min(query, key=lambda v: len(groups[v]))
        if len(groups[rare]) == 0:
            return float("inf")
        best = float("inf")
        seeds = groups[rare][:: max(1, len(groups[rare]) // samples)][:samples]
        for seed in seeds:
            ids = [int(seed)]
            for v in query:
                if v == rare:
                    continue
                cand = groups[v]
                dmat = pairwise_l2_numpy(ds.points[np.asarray(ids)], ds.points[cand])
                ids.append(int(cand[int(np.argmin(dmat.max(axis=0)))]))
            pts = ds.points[np.asarray(ids)]
            best = min(best, float(pairwise_l2_numpy(pts, pts).max()))
        return best

    def search(self, query: Sequence[int], k: int = 1, budget: int = 2_000_000):
        """Best-first exact top-k NKS search. Returns (TopK, timed_out, pops)."""
        query = sorted(set(int(v) for v in query))
        pq = TopK(k, init_full=True)
        est = self.initial_estimate(query)

        frontier: list[tuple[float, int, tuple]] = []
        counter = itertools.count()
        root_tuple = tuple(("n", self.root) for _ in query)
        if all(self._has_kw(("n", self.root), v) for v in query):
            heapq.heappush(frontier, (0.0, next(counter), root_tuple))

        pops = 0
        while frontier:
            lb, _, entries = heapq.heappop(frontier)
            pops += 1
            r_k = min(pq.kth_diameter(), est)
            if lb > r_k:
                break                      # exact: no unexplored tuple can win
            if pops > budget:
                return pq, True, pops
            # pick the first non-point entry to expand (largest volume first
            # would also work; index order keeps tuples canonical)
            expand_i = None
            for i, e in enumerate(entries):
                if e[0] == "n":
                    expand_i = i
                    break
            if expand_i is None:
                ids = tuple(sorted(set(int(e[1]) for e in entries)))
                if is_minimal_candidate(ids, query, self.dataset):
                    pts = self.dataset.points[np.asarray(ids)]
                    diam = float(pairwise_l2_numpy(pts, pts).max()) if len(ids) > 1 else 0.0
                    pq.offer(Candidate(ids=ids, diameter=diam))
                continue
            node = entries[expand_i][1]
            kw = query[expand_i]
            if node.is_leaf:
                kids = [("p", int(p)) for p in node.point_ids
                        if self.dataset.has_keyword(int(p), kw)]
            else:
                kids = [("n", c) for c in node.children if c.kw_mask[kw]]
            for kid in kids:
                new_entries = entries[:expand_i] + (kid,) + entries[expand_i + 1:]
                new_lb = self._tuple_lb(new_entries)
                if new_lb <= min(pq.kth_diameter(), est):
                    heapq.heappush(frontier, (new_lb, next(counter), new_entries))
        return pq, False, pops


def space_cost_model(n: int, d: int, u: int, q: int, t: int = 1,
                     e_bytes: int = 4, fanout: int = 100) -> int:
    """§VIII-D analytic space cost of Virtual bR*-Tree (bytes)."""
    n_nodes = 0
    level = int(np.ceil(n / 1000))
    while level >= 1:
        n_nodes += level
        if level == 1:
            break
        level = int(np.ceil(level / fanout))
    rtree = (2 * d + fanout) * e_bytes * n_nodes
    inv = (np.log(max(n, 2)) / np.log(fanout) + 1) * t * e_bytes * n
    br = (2 * d * e_bytes + 2 * d * e_bytes * q + fanout * e_bytes + u / 8) * n_nodes
    return int(rtree + inv + br)
