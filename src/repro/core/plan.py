"""Batched query planning (Algorithm 1 steps 10-22, lifted out of the search).

The per-query recursion in ``promish_e``/``promish_a`` interleaves bucket
selection with subset search, so every query pays its own device dispatches.
This module separates the *what to search* decision from the searching: per
scale, :func:`plan_scale` collects every covering-bucket subset for a whole
batch of queries up front (bucket selection, bitset filtering, Algorithm-2
dedup keyed per query), producing a flat list of :class:`SubsetTask` that a
``DistanceBackend`` can pack into a single fused device dispatch.

Both the single-query searches (a batch of one) and the serving engine's
``query_batch`` pipeline are built on this layer.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.index import PromishIndex
from repro.core.types import KeywordDataset


@dataclasses.dataclass
class PlanStats:
    """Bucket-selection accounting. ``promish_e.SearchStats`` is a duck-typed
    superset, so the single-query searches pass their own stats object."""

    buckets_selected: int = 0
    duplicate_subsets: int = 0
    filtered_subsets: int = 0      # pruned: no point satisfied the predicate
    buckets_pruned_zonemap: int = 0  # zone map proved no eligible bulk member


@dataclasses.dataclass(frozen=True)
class SubsetTask:
    """One covering-bucket subset F' queued for search on behalf of a query.

    ``diam_ub`` bounds the diameter of any subset drawn from the source
    bucket (``2 * synopsis radius``; +inf without a synopsis or when delta
    members ride along). When the bound already beats the query's live
    ``r_k`` every pair joins, so the dispatcher can substitute an infinite
    pruning radius — the all-ones-mask fast path that skips the device —
    without changing any result (enumeration settles membership in float64
    at the live radius either way).
    """

    qidx: int            # position in the batch
    f_ids: np.ndarray    # sorted unique point ids of F'
    diam_ub: float = float("inf")


def query_bitset(dataset: KeywordDataset, query: Sequence[int]) -> np.ndarray:
    """BS: mark every point tagged with >=1 query keyword (Alg. 1 steps 4-6)."""
    bs = np.zeros(dataset.n, dtype=bool)
    for v in query:
        bs[dataset.ikp.row(v)] = True
    return bs


class BatchPlanContext:
    """Per-batch memoization shared by planning and keyword grouping.

    One batch touches the same few keywords over and over: every scale's
    covering-bucket selection re-reads the same I_khb rows, and every subset
    task re-runs a searchsorted membership test per query keyword
    (``subset_search.group_by_keyword`` — the dominant plan-stage cost in the
    batch bench). The context converts both into per-batch one-time work:

      * :meth:`kw_mask` — a boolean corpus mask per keyword, built once and
        reused by every bitset and every keyword-group restriction (a boolean
        gather per group instead of a searchsorted per (task, keyword));
      * :meth:`covering` — the per-(scale, query) covering-bucket array,
        computed once even when duplicate queries share a batch or the
        fallback stage revisits a scale.

    The context is valid for exactly one batch: the corpus is frozen while a
    batch runs (streaming absorbs land between batches), so masks never go
    stale within its lifetime. Build a fresh one per ``query_batch`` call.
    """

    def __init__(self, dataset: KeywordDataset):
        self.dataset = dataset
        self._kw_masks: dict[int, np.ndarray] = {}
        self._covers: dict[tuple, np.ndarray] = {}
        self._khb: dict[tuple, np.ndarray] = {}

    def kw_mask(self, v: int) -> np.ndarray:
        m = self._kw_masks.get(v)
        if m is None:
            m = np.zeros(self.dataset.n, dtype=bool)
            m[self.dataset.ikp.row(int(v))] = True
            self._kw_masks[v] = m
        return m

    def query_bitset(self, query: Sequence[int]) -> np.ndarray:
        bs = np.zeros(self.dataset.n, dtype=bool)
        for v in query:
            bs |= self.kw_mask(v)
        return bs

    def _khb_row(self, hi, scale: int, v: int) -> np.ndarray:
        """Per-(scale, keyword) I_khb posting row, read once per batch.
        Flexible m-of-k queries expand into overlapping keyword subsets, so
        the same row feeds many subqueries' coverage counts."""
        key = (id(hi), scale, int(v))
        row = self._khb.get(key)
        if row is None:
            row = self._khb[key] = hi.khb.row(int(v))
        return row

    def covering(self, hi, scale: int, query: Sequence[int]) -> np.ndarray:
        key = (id(hi), scale, tuple(query))
        cover = self._covers.get(key)
        if cover is None:
            # Same counting intersection as ``covering_buckets``, fed from
            # the memoized khb rows — result-identical, row reads amortised.
            counts = np.zeros(hi.n_buckets, dtype=np.int32)
            for v in query:
                counts[self._khb_row(hi, scale, v)] += 1
            cover = self._covers[key] = np.flatnonzero(counts == len(query))
        return cover


def covering_buckets(hi, query: Sequence[int]) -> np.ndarray:
    """Buckets containing all query keywords: intersect I_khb rows by counting."""
    counts = np.zeros(hi.n_buckets, dtype=np.int32)
    for v in query:
        counts[hi.khb.row(v)] += 1
    return np.flatnonzero(counts == len(query))


def plan_scale(index: PromishIndex, scale: int,
               queries: Sequence[Sequence[int]],
               bitsets: Sequence[np.ndarray],
               active: Sequence[int],
               explored: dict[int, set[bytes]] | None,
               stats: PlanStats | None = None,
               delta=None,
               eligible: np.ndarray | None = None,
               ctx: BatchPlanContext | None = None,
               zone=None) -> list[SubsetTask]:
    """Collect every subset to search at ``scale`` for the active queries.

    ``explored`` maps query index -> Algorithm-2 hash set (exact set-hash on
    sorted id bytes); pass None for ProMiSH-A semantics (disjoint bins make
    within-scale subsets distinct, and the paper does not dedup across
    scales). Task order is (query, bucket) — identical to the per-query loop,
    so a batch of one reproduces the classic search exactly.

    ``delta`` (a :class:`repro.core.index.IndexDelta`) switches the plan to
    the streaming bulk ∪ delta view: coverage comes from the merged live
    corpus (bulk khb minus dead buckets, plus delta postings) and each
    covering bucket's subset is the bulk members (tombstones already cleared
    from the bitset) concatenated with the live relevant delta members. Delta
    ids all exceed bulk ids, so the concatenation stays sorted — the emitted
    subsets are exactly what a fresh index over the live corpus would emit,
    bucket for bucket.

    ``eligible`` (an (N,) bool point-eligibility mask from
    ``core.filters.Filter.evaluate``) makes the plan *selectivity-aware*:
    subsets stay **unfiltered** — so Algorithm-2 keys and the backend's
    packed-subset/tile LRU entries are shared across filters — but a subset
    with no eligible member is pruned here, before any pack or dispatch
    (counted in ``PlanStats.filtered_subsets``). Pruning runs after the
    Algorithm-2 dedup, so a fully-ineligible subset is checked once per
    query, not once per covering bucket.

    ``zone`` (a :class:`repro.core.store.ZoneMapPruner`, requires
    ``eligible``) consults the scale's bucket synopsis *before* the member
    list is touched: a bucket whose zone map is provably disjoint from the
    filter — and that has no delta members, which the bulk-built synopsis
    cannot speak for — is skipped outright (``buckets_pruned_zonemap``),
    saving the cold-tier gather the other prunes would still pay. Since a
    zone-rejected bucket's subset is entirely ineligible, the eligibility
    prune above would have dropped it anyway: results are bit-identical with
    ``zone`` on or off, only the counters (and cold reads) differ.
    """
    hi = index.structures[scale]
    syn = getattr(hi, "synopsis", None)
    tasks: list[SubsetTask] = []
    if delta is not None and len(active):
        # Resolve suspect (keyword, bucket) coverage once for the whole
        # coalesced batch: every query sharing a keyword reuses the same
        # verification pass instead of re-running it per query.
        delta.verify_suspects(
            scale, {int(v) for qidx in active for v in queries[qidx]})
    for qidx in active:
        bs = bitsets[qidx]
        if delta is None:
            cover = ctx.covering(hi, scale, queries[qidx]) if ctx is not None \
                else covering_buckets(hi, queries[qidx])
            d_buckets = d_ids = None
        else:
            cover = delta.covering_buckets(scale, queries[qidx])
            d_buckets, d_ids = delta.scale_pairs(scale, bs)
        rej = zone.reject(syn, cover) \
            if zone is not None and eligible is not None else None
        for ci, b in enumerate(cover):
            if stats is not None:
                stats.buckets_selected += 1
            dlo = dhi_b = 0
            if d_buckets is not None and len(d_buckets):
                dlo, dhi_b = np.searchsorted(d_buckets, [b, b + 1])
            if rej is not None and rej[ci] and dhi_b == dlo:
                # The synopsis speaks for the bulk members only; with no
                # delta members riding along, every point the bucket could
                # contribute is provably ineligible — skip before the
                # (possibly cold) member-list gather.
                if stats is not None:
                    stats.buckets_pruned_zonemap += 1
                continue
            pts = hi.table.row(int(b))
            # table rows are sorted unique point ids (CSR contract), so the
            # bitset filter preserves that — no np.unique on the hot path.
            f = np.ascontiguousarray(pts[bs[pts]], dtype=np.int64)
            if dhi_b > dlo:
                f = np.concatenate([f, d_ids[dlo:dhi_b]])
            if len(f) == 0:
                continue
            if explored is not None:
                key = f.tobytes()
                if key in explored[qidx]:
                    if stats is not None:
                        stats.duplicate_subsets += 1
                    continue
                explored[qidx].add(key)
            if eligible is not None and not eligible[f].any():
                if stats is not None:
                    stats.filtered_subsets += 1
                continue
            diam_ub = 2.0 * float(syn.radius[b]) \
                if syn is not None and dlo == dhi_b else float("inf")
            tasks.append(SubsetTask(qidx=qidx, f_ids=f, diam_ub=diam_ub))
    return tasks


def fallback_tasks(bitsets: Sequence[np.ndarray],
                   active: Sequence[int],
                   eligible: np.ndarray | None = None) -> list[SubsetTask]:
    """Alg. 1 steps 33-39: the full relevant-point subset per unfinished query.

    Unlike the per-scale plan, the fallback filters ``eligible`` directly
    into the subset: fallback subsets are near-corpus-sized and unique to the
    query, so there is no cache-sharing argument for keeping ineligible
    points — shrinking the pack dominates.
    """
    tasks = []
    for qidx in active:
        f = np.flatnonzero(bitsets[qidx]).astype(np.int64)
        if eligible is not None:
            f = f[eligible[f]]
        tasks.append(SubsetTask(qidx=qidx, f_ids=f))
    return tasks
