"""Random unit-vector projections and bin-key computation (paper §III, eqs 1-2).

These are the numpy control-plane versions; the TPU data plane is
``repro.kernels.project_bin`` (a fused Pallas kernel validated against these).
"""
from __future__ import annotations

import numpy as np

# Offset separating h2 keys from h1 keys (the paper's constant C). We use a
# fixed power of two rather than the data-dependent max(h1)-min(h1)+2 so that
# every shard of a distributed index derives identical keys (DESIGN.md A3).
DEFAULT_C = 1 << 20


def sample_unit_vectors(rng: np.random.Generator, m: int, d: int) -> np.ndarray:
    """m unit vectors drawn uniformly from the (d-1)-sphere."""
    z = rng.standard_normal((m, d)).astype(np.float32)
    z /= np.linalg.norm(z, axis=1, keepdims=True)
    return z


def project(points: np.ndarray, z: np.ndarray) -> np.ndarray:
    """(N,d) x (m,d) -> (N,m) projected values z.o."""
    return points.astype(np.float32) @ z.T.astype(np.float32)


def bin_keys_overlapping(proj: np.ndarray, w: float, c: int = DEFAULT_C) -> np.ndarray:
    """ProMiSH-E dual keys (eqs 1-2): every point lies in two overlapping bins
    per projection.  Returns (N, m, 2) int64 with [..., 0]=h1, [..., 1]=h2+C.
    """
    h1 = np.floor(proj / w).astype(np.int64)
    h2 = np.floor((proj - w / 2.0) / w).astype(np.int64) + c
    return np.stack([h1, h2], axis=-1)


def bin_keys_disjoint(proj: np.ndarray, w: float) -> np.ndarray:
    """ProMiSH-A single key per projection: (N, m) int64."""
    return np.floor(proj / w).astype(np.int64)


def projection_span(proj: np.ndarray) -> float:
    """pMax — the maximum span of projected values over any unit vector
    (paper eq 3 input)."""
    return float((proj.max(axis=0) - proj.min(axis=0)).max())


def num_scales(p_max: float, w0: float) -> int:
    """Eq 3: L = ceil(log2(pMax / w0))."""
    return int(np.ceil(np.log2(max(p_max / w0, 1.0))))
