"""Distributed NKS search on the production mesh (DESIGN.md §5).

Two layers:

1. ``nks_anchor_topk`` — the TPU-native device kernel (single shard):
   anchor-star candidate generation. For each anchor point of the rarest
   query keyword, pick the nearest point per remaining keyword (one masked
   pairwise-distance matmul per keyword — the Pallas ``pairwise_l2`` hot
   spot) and score the resulting candidate by its exact diameter
   (``tuple_diameters`` kernel). By the triangle inequality the best
   anchor-star diameter is within 2x of the true optimum (each member is
   within nn-dist of the anchor, so pairwise <= 2 max nn-dist); empirically
   (tests) the ratio is ~1.0-1.3, i.e. ProMiSH-A-grade quality at full MXU
   utilisation. The exact ProMiSH-E path (host-orchestrated, repro.core)
   re-scores the returned candidates when exactness is required.

2. ``distributed_nks_topk`` — the same tier on the device plane
   (``core.device_plane``): each shard holds a slice of every keyword group,
   phase A all_gathers the (q, R, d) groups, phase B keeps anchors
   partitioned (each device scores its local anchor slice), phase C merges
   per-shard top-k through ``device_plane.replicated_topk_merge``. The mesh/
   placement logic lives in :class:`~repro.core.device_plane.DevicePlane`,
   shared with the sharded batched-join dispatch — this module keeps only
   the single-shard kernel and thin compatibility wrappers.

``pack_groups`` moved to ``core.device_plane`` (it is placement logic: the
plane rounds R up to shard multiples); re-exported here unchanged.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.core.device_plane import (DevicePlane, PackedGroups,  # noqa: F401
                                     pack_groups)

BIG = jnp.float32(3.4e38)


def _masked_sq_dists(a, b, b_mask):
    """(A,d) x (B,d) -> (A,B) squared L2 with invalid b masked to +BIG."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    sq = (jnp.sum(a * a, 1)[:, None] + jnp.sum(b * b, 1)[None, :]
          - 2.0 * (a @ b.T))
    sq = jnp.maximum(sq, 0.0)
    return jnp.where(b_mask[None, :], sq, BIG)


def nks_anchor_topk(groups, mask, ids, k: int, *, anchors=None,
                    anchor_mask=None, anchor_ids=None):
    """Anchor-star NKS top-k on one shard.

    groups (q, R, d) fp32; mask (q, R) bool; ids (q, R) int32 global ids.
    anchors (A, d) default groups[0]. Returns (diams (k,), cand_ids (k, q)).

    Points are centred before the distance math: the fp32
    ||a||^2+||b||^2-2ab identity cancels catastrophically for large
    coordinates (same contract as the Pallas join kernel — this tier is a
    fast filter; exact rescoring runs in float64 on the control plane).
    """
    q = groups.shape[0]
    center = jnp.sum(jnp.where(mask[..., None], groups, 0.0), axis=(0, 1)) \
        / jnp.maximum(jnp.sum(mask), 1)
    groups = groups - center
    if anchors is None:
        anchors, anchor_mask, anchor_ids = groups[0], mask[0], ids[0]
    else:
        anchors = anchors - center
    a = anchors.shape[0]

    members = [anchors[:, None, :]]                      # (A, 1, d)
    member_ids = [anchor_ids[:, None]]                   # (A, 1)
    worst_nn = jnp.zeros((a,), jnp.float32)
    for j in range(1, q):
        sq = _masked_sq_dists(anchors, groups[j], mask[j])   # (A, R)
        nn = jnp.argmin(sq, axis=1)                          # (A,)
        nn_d = jnp.take_along_axis(sq, nn[:, None], axis=1)[:, 0]
        worst_nn = jnp.maximum(worst_nn, nn_d)
        members.append(groups[j][nn][:, None, :])
        member_ids.append(ids[j][nn][:, None])

    tuples = jnp.concatenate(members, axis=1)            # (A, q, d)
    cand_ids = jnp.concatenate(member_ids, axis=1)       # (A, q)

    # exact diameter of each candidate (the paper's r(A) ranking)
    pts = tuples.astype(jnp.float32)
    sq = jnp.sum(pts * pts, -1)
    gram = jnp.einsum("aqd,ard->aqr", pts, pts)
    d2 = jnp.maximum(sq[:, :, None] + sq[:, None, :] - 2.0 * gram, 0.0)
    diam = jnp.sqrt(jnp.max(d2, axis=(1, 2)))

    valid = anchor_mask & (worst_nn < BIG)
    diam = jnp.where(valid, diam, jnp.inf)
    neg, idx = jax.lax.top_k(-diam, k)
    return -neg, cand_ids[idx]


_PLANES: dict[tuple, DevicePlane] = {}


def distributed_nks_topk(mesh: Mesh, groups, mask, ids, k: int,
                         axis: str = "data"):
    """Sharded NKS top-k on the device plane. ``groups`` (q, R_total, d) is
    sharded on R over ``axis``; returns (diams (k,), ids (k, q)) fully
    replicated. Compatibility wrapper over ``DevicePlane.nks_topk``; planes
    are memoised per (mesh, axis) so repeat calls reuse the compiled
    shard_map program instead of retracing."""
    plane = _PLANES.get((mesh, axis))
    if plane is None:
        plane = _PLANES[(mesh, axis)] = DevicePlane(mesh, axis=axis)
    return plane.nks_topk(groups, mask, ids, k)


def search_step_specs(q: int, r_total: int, d: int, k: int):
    """ShapeDtypeStructs + PartitionSpecs for dry-running the serve step."""
    structs = (jax.ShapeDtypeStruct((q, r_total, d), jnp.float32),
               jax.ShapeDtypeStruct((q, r_total), jnp.bool_),
               jax.ShapeDtypeStruct((q, r_total), jnp.int32))
    specs = (P(None, "data", None), P(None, "data"), P(None, "data"))
    return structs, specs
