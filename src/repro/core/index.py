"""ProMiSH index build (paper §III): multi-scale HI structures.

Each HI structure at scale ``s`` is:
  * a hashtable  H  : bucket id -> point ids     (CSR ``table``)
  * an inverted  I_khb: keyword -> bucket ids    (CSR ``khb``)
built from bin width ``w = w0 * 2^s``.

The keyword->point inverted index I_kp lives on the dataset itself
(:class:`repro.core.types.KeywordDataset`).

Build cost is one matmul (projections — the Pallas-accelerated hot spot), one
floor per bin plane, and two sorts per scale; everything is flat-array math so
the same code path drives both the host build and the sharded device build.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import projection as proj
from repro.core import signatures as sig
from repro.core.types import KeywordDataset
from repro.utils.csr import CSR, csr_from_pairs


@dataclasses.dataclass(frozen=True)
class HIStructure:
    """Hashtable + keyword->bucket inverted index at one scale."""

    scale: int
    width: float
    n_buckets: int
    table: CSR      # bucket -> point ids (a point appears once per distinct bucket)
    khb: CSR        # keyword -> bucket ids containing >=1 point with that keyword

    def nbytes(self) -> int:
        return self.table.nbytes() + self.khb.nbytes()


@dataclasses.dataclass(frozen=True)
class PromishIndex:
    """The full multi-scale index (either flavour).

    exact=True  -> ProMiSH-E (overlapping bins, 2^m signatures/point)
    exact=False -> ProMiSH-A (disjoint bins, 1 signature/point)
    """

    z: np.ndarray                  # (m, d) unit random vectors
    w0: float
    n_scales: int
    exact: bool
    structures: tuple[HIStructure, ...]
    p_max: float

    @property
    def m(self) -> int:
        return int(self.z.shape[0])

    def width_at(self, s: int) -> float:
        return self.w0 * (2.0 ** s)

    def nbytes(self) -> int:
        return self.z.nbytes + sum(h.nbytes() for h in self.structures)


def _build_scale(dataset: KeywordDataset, projected: np.ndarray, scale: int,
                 width: float, n_buckets: int, exact: bool) -> HIStructure:
    n = dataset.n
    if exact:
        keys2 = proj.bin_keys_overlapping(projected, width)
        buckets = sig.bucket_ids_overlapping(keys2, n_buckets)       # (N, 2^m)
        point_ids = np.repeat(np.arange(n, dtype=np.int32), buckets.shape[1])
        flat_buckets = buckets.reshape(-1)
    else:
        keys = proj.bin_keys_disjoint(projected, width)
        flat_buckets = sig.bucket_ids_disjoint(keys, n_buckets)       # (N,)
        point_ids = np.arange(n, dtype=np.int32)

    # A point may receive duplicate bucket ids from distinct signatures
    # (overlap or hash collision) — dedup so each bucket lists a point once.
    table = csr_from_pairs(flat_buckets, point_ids, n_buckets, dedup=True)

    # I_khb: for every (bucket, point) entry expand the point's keywords and
    # dedup (keyword, bucket) pairs (vectorised: gather each point's kw slice).
    pts = table.values                                                # points in bucket order
    bkt_of_entry = np.repeat(np.arange(n_buckets, dtype=np.int64), np.diff(table.offsets))
    kw_counts = np.diff(dataset.kw.offsets)[pts]                      # kws per entry
    bk_rep = np.repeat(bkt_of_entry, kw_counts)
    starts = dataset.kw.offsets[pts]
    # ragged gather of keyword slices
    total = int(kw_counts.sum())
    idx = np.repeat(starts, kw_counts) + _ragged_arange(kw_counts, total)
    kws = dataset.kw.values[idx].astype(np.int64)
    khb = csr_from_pairs(kws, bk_rep.astype(np.int32),
                         dataset.n_keywords, dedup=True)
    return HIStructure(scale=scale, width=width, n_buckets=n_buckets, table=table, khb=khb)


def _ragged_arange(counts: np.ndarray, total: int | None = None) -> np.ndarray:
    """[0..c0), [0..c1), ... concatenated."""
    if total is None:
        total = int(counts.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    ends = np.cumsum(counts)
    starts = ends - counts
    out = np.arange(total, dtype=np.int64)
    out -= np.repeat(starts, counts)
    return out


def build_index(dataset: KeywordDataset, *, m: int = 2, n_scales: int = 5,
                w0: float | None = None, exact: bool = True,
                buckets_per_point: float = 1.0,
                seed: int = 0) -> PromishIndex:
    """Build a ProMiSH index (paper defaults: m=2, L=5, w0=pMax/2^L).

    ``buckets_per_point`` sizes the hashtable: n_buckets ~= N * factor
    (the paper uses a fixed table size; we scale with N, power-of-two).
    """
    rng = np.random.default_rng(seed)
    z = proj.sample_unit_vectors(rng, m, dataset.dim)
    projected = proj.project(dataset.points, z)
    p_max = proj.projection_span(projected)
    if w0 is None:
        w0 = p_max / (2.0 ** n_scales)
    n_buckets = max(64, 1 << int(np.ceil(np.log2(max(dataset.n * buckets_per_point, 1)))))
    structures = []
    for s in range(n_scales):
        width = w0 * (2.0 ** s)
        # Fewer, larger buckets are expected at coarse scales; halve the table.
        nb = max(64, n_buckets >> s) if not exact else n_buckets
        structures.append(_build_scale(dataset, projected, s, width, nb, exact))
    return PromishIndex(z=z, w0=float(w0), n_scales=n_scales, exact=exact,
                        structures=tuple(structures), p_max=p_max)
