"""ProMiSH index build (paper §III): multi-scale HI structures.

Each HI structure at scale ``s`` is:
  * a hashtable  H  : bucket id -> point ids     (CSR ``table``)
  * an inverted  I_khb: keyword -> bucket ids    (CSR ``khb``)
built from bin width ``w = w0 * 2^s``.

The keyword->point inverted index I_kp lives on the dataset itself
(:class:`repro.core.types.KeywordDataset`).

Build cost is one matmul (projections — the Pallas-accelerated hot spot), one
floor per bin plane, and two sorts per scale; everything is flat-array math so
the same code path drives both the host build and the sharded device build.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import projection as proj
from repro.core import signatures as sig
from repro.core.types import KeywordDataset
from repro.utils.csr import CSR, csr_from_pairs, ragged_arange, sorted_member


@dataclasses.dataclass(frozen=True)
class BucketSynopsis:
    """Per-bucket summary table of one scale's hashtable (zone maps).

    Everything here is a *conservative superset* of the bucket's bulk
    membership, so consulting it can only ever skip work, never answers:

      * ``radius`` — an upper bound on the distance from the bucket's points
        to their centroid (f64 max, rounded *up* into f32). ``2 * radius``
        bounds the diameter of any subset drawn from the bucket, letting the
        dispatcher substitute an infinite pruning radius (the all-pairs-join
        fast path) when the bound already beats the live ``r_k``. The
        centroid itself is a build-time intermediate and is not retained —
        persisting it per scale would rival the corpus itself in size.
      * ``attr_min`` / ``attr_max`` — per numeric attribute column, the
        bucket's value range; a conjunctive :class:`~repro.core.filters.Filter`
        clause provably empty against the range prunes the bucket before any
        eligibility bitmask (or the bucket's member list) is materialised.
      * ``tenant_min`` / ``tenant_max`` — same idea for tenant-scoped queries.

    Empty buckets carry ``radius = 0`` and inverted ranges (min=+inf,
    max=-inf), which every prune rule rejects harmlessly.
    """

    counts: np.ndarray                          # (n_buckets,) int32
    radius: np.ndarray                          # (n_buckets,) float32, >= true
    attr_min: dict                              # name -> (n_buckets,) float64
    attr_max: dict                              # name -> (n_buckets,) float64
    tenant_min: np.ndarray | None = None        # (n_buckets,) int32
    tenant_max: np.ndarray | None = None

    def nbytes(self) -> int:
        total = self.counts.nbytes + self.radius.nbytes
        total += sum(a.nbytes for a in self.attr_min.values())
        total += sum(a.nbytes for a in self.attr_max.values())
        if self.tenant_min is not None:
            total += self.tenant_min.nbytes + self.tenant_max.nbytes
        return total


def build_synopsis(dataset: KeywordDataset, table: CSR, n_buckets: int, *,
                   chunk: int = 1 << 21) -> BucketSynopsis:
    """Build the per-bucket synopsis of one scale's hashtable.

    Two vectorised ``reduceat`` passes over the member array (chunked so the
    d-dimensional gather never materialises more than ~``chunk`` rows): one
    for per-bucket centroids (sums / counts), one for the max distance to the
    centroid. Restricting the reduceat starts to *nonempty* buckets makes
    consecutive segments exactly bucket boundaries — empty buckets between
    two nonempty ones contribute no entries to ``table.values``, so the
    slice between their offsets is precisely the selected buckets' members.
    """
    counts = np.diff(table.offsets).astype(np.int64)
    radius = np.zeros(n_buckets, dtype=np.float32)
    nonempty = np.flatnonzero(counts > 0)
    pts = dataset.points
    if len(nonempty):
        csum = np.cumsum(counts[nonempty])
        b0 = 0
        while b0 < len(nonempty):
            base = int(csum[b0 - 1]) if b0 else 0
            b1 = int(np.searchsorted(csum, base + chunk, side="left")) + 1
            b1 = min(max(b1, b0 + 1), len(nonempty))
            sel = nonempty[b0:b1]
            lo = int(table.offsets[sel[0]])
            hi = int(table.offsets[sel[-1] + 1])
            rows = pts[table.values[lo:hi]].astype(np.float64)
            starts = (table.offsets[sel] - lo).astype(np.int64)
            cent = np.add.reduceat(rows, starts, axis=0) \
                / counts[sel][:, None]
            ent = np.repeat(np.arange(len(sel)), counts[sel])
            diff = rows - cent[ent]
            dist = np.sqrt(np.einsum("ij,ij->i", diff, diff))
            rmax = np.maximum.reduceat(dist, starts).astype(np.float32)
            # Round up so the f32 bound still dominates the f64 max.
            radius[sel] = np.nextafter(rmax, np.float32(np.inf))
            b0 = b1

    def _minmax(col: np.ndarray, lo_fill, hi_fill, dtype):
        vals = col[table.values]
        amin = np.full(n_buckets, lo_fill, dtype=dtype)
        amax = np.full(n_buckets, hi_fill, dtype=dtype)
        if len(nonempty):
            starts = table.offsets[nonempty].astype(np.int64)
            amin[nonempty] = np.minimum.reduceat(vals, starts)
            amax[nonempty] = np.maximum.reduceat(vals, starts)
        return amin, amax

    attr_min: dict = {}
    attr_max: dict = {}
    for name, col in (dataset.attrs or {}).items():
        if not np.issubdtype(np.asarray(col).dtype, np.number):
            continue                      # categorical strings: no zone map
        attr_min[name], attr_max[name] = _minmax(
            np.asarray(col, dtype=np.float64), np.inf, -np.inf, np.float64)
    tenant_min = tenant_max = None
    if dataset.tenant_of is not None:
        tenant_min, tenant_max = _minmax(
            dataset.tenant_of.astype(np.int32),
            np.iinfo(np.int32).max, np.iinfo(np.int32).min, np.int32)
    return BucketSynopsis(counts=counts.astype(np.int32), radius=radius,
                          attr_min=attr_min, attr_max=attr_max,
                          tenant_min=tenant_min, tenant_max=tenant_max)


@dataclasses.dataclass(frozen=True)
class HIStructure:
    """Hashtable + keyword->bucket inverted index at one scale."""

    scale: int
    width: float
    n_buckets: int
    table: CSR      # bucket -> point ids (a point appears once per distinct bucket)
    khb: CSR        # keyword -> bucket ids containing >=1 point with that keyword
    synopsis: BucketSynopsis | None = None      # zone maps (out-of-core builds)

    def nbytes(self) -> int:
        total = self.table.nbytes() + self.khb.nbytes()
        if self.synopsis is not None:
            total += self.synopsis.nbytes()
        return total


@dataclasses.dataclass(frozen=True)
class PromishIndex:
    """The full multi-scale index (either flavour).

    exact=True  -> ProMiSH-E (overlapping bins, 2^m signatures/point)
    exact=False -> ProMiSH-A (disjoint bins, 1 signature/point)
    """

    z: np.ndarray                  # (m, d) unit random vectors
    w0: float
    n_scales: int
    exact: bool
    structures: tuple[HIStructure, ...]
    p_max: float

    @property
    def m(self) -> int:
        return int(self.z.shape[0])

    def width_at(self, s: int) -> float:
        return self.w0 * (2.0 ** s)

    def nbytes(self) -> int:
        return self.z.nbytes + sum(h.nbytes() for h in self.structures)


def _build_scale(dataset: KeywordDataset, projected: np.ndarray, scale: int,
                 width: float, n_buckets: int, exact: bool,
                 synopsis: bool = False) -> HIStructure:
    n = dataset.n
    if exact:
        keys2 = proj.bin_keys_overlapping(projected, width)
        buckets = sig.bucket_ids_overlapping(keys2, n_buckets)       # (N, 2^m)
        point_ids = np.repeat(np.arange(n, dtype=np.int32), buckets.shape[1])
        flat_buckets = buckets.reshape(-1)
    else:
        keys = proj.bin_keys_disjoint(projected, width)
        flat_buckets = sig.bucket_ids_disjoint(keys, n_buckets)       # (N,)
        point_ids = np.arange(n, dtype=np.int32)

    # A point may receive duplicate bucket ids from distinct signatures
    # (overlap or hash collision) — dedup so each bucket lists a point once.
    table = csr_from_pairs(flat_buckets, point_ids, n_buckets, dedup=True)

    # I_khb: for every (bucket, point) entry expand the point's keywords and
    # dedup (keyword, bucket) pairs (vectorised: gather each point's kw slice).
    pts = table.values                                                # points in bucket order
    bkt_of_entry = np.repeat(np.arange(n_buckets, dtype=np.int64), np.diff(table.offsets))
    kw_counts = np.diff(dataset.kw.offsets)[pts]                      # kws per entry
    bk_rep = np.repeat(bkt_of_entry, kw_counts)
    starts = dataset.kw.offsets[pts]
    # ragged gather of keyword slices
    total = int(kw_counts.sum())
    idx = np.repeat(starts, kw_counts) + _ragged_arange(kw_counts, total)
    kws = dataset.kw.values[idx].astype(np.int64)
    khb = csr_from_pairs(kws, bk_rep.astype(np.int32),
                         dataset.n_keywords, dedup=True)
    syn = build_synopsis(dataset, table, n_buckets) if synopsis else None
    return HIStructure(scale=scale, width=width, n_buckets=n_buckets,
                       table=table, khb=khb, synopsis=syn)


# Shared CSR row-slicing gather index; now lives in ``repro.utils.csr``.
_ragged_arange = ragged_arange


def build_index(dataset: KeywordDataset, *, m: int = 2, n_scales: int = 5,
                w0: float | None = None, exact: bool = True,
                buckets_per_point: float = 1.0,
                n_buckets: int | None = None,
                seed: int = 0, synopsis: bool = False) -> PromishIndex:
    """Build a ProMiSH index (paper defaults: m=2, L=5, w0=pMax/2^L).

    ``buckets_per_point`` sizes the hashtable: n_buckets ~= N * factor
    (the paper uses a fixed table size; we scale with N, power-of-two).
    An explicit ``n_buckets`` (and ``w0``) pins the hash geometry
    independently of N — a streaming engine passes both so the bucket ids
    of points absorbed later, and of every rebuild at compaction, stay
    comparable with a fresh build over the same corpus.

    ``synopsis=True`` additionally builds the per-bucket
    :class:`BucketSynopsis` tables (zone maps + bounding radii) consumed by
    the out-of-core planner; compaction rebuilds them automatically because
    the flag rides in the engine's pinned build params.
    """
    rng = np.random.default_rng(seed)
    z = proj.sample_unit_vectors(rng, m, dataset.dim)
    projected = proj.project(dataset.points, z)
    p_max = proj.projection_span(projected)
    if w0 is None:
        w0 = p_max / (2.0 ** n_scales)
    if n_buckets is None:
        n_buckets = max(64, 1 << int(np.ceil(np.log2(max(dataset.n * buckets_per_point, 1)))))
    structures = []
    for s in range(n_scales):
        width = w0 * (2.0 ** s)
        # Fewer, larger buckets are expected at coarse scales; halve the table.
        nb = max(64, n_buckets >> s) if not exact else n_buckets
        structures.append(_build_scale(dataset, projected, s, width, nb,
                                       exact, synopsis=synopsis))
    return PromishIndex(z=z, w0=float(w0), n_scales=n_scales, exact=exact,
                        structures=tuple(structures), p_max=p_max)


# ------------------------------------------------------------ streaming delta
class IndexDelta:
    """Incremental companion of one frozen :class:`PromishIndex`.

    The bulk index is built once and never mutated; this buffer absorbs the
    stream on top of it:

      * **inserts** — each absorbed point is projected with the bulk's ``z``
        and binned with the bulk's per-scale ``(width, n_buckets)`` (the same
        eq. 1-2 / signature-hash path the build uses), so the bucket id a
        delta point lands in is exactly the bucket a full rebuild would put
        it in. Assignments are stored per scale as (n_delta, n_sig) bucket
        matrices (2^m signatures for ProMiSH-E, one for ProMiSH-A).
      * **bulk deletes** — tombstones live on the corpus; here we only track
        which (keyword, bucket) coverage entries became *suspect* (the
        deleted point may have been the bucket's last live holder of that
        keyword), so query-time coverage can re-verify just those buckets
        instead of scanning the bulk index.

    Query-time, :meth:`covering_buckets` and :meth:`scale_pairs` give the
    plan layer the bulk ∪ delta view of one scale: identical coverage and
    bucket contents to a fresh index over the live corpus (given the same
    ``z``/``w0``/``n_buckets``), which is what the streaming parity
    guarantee rests on.
    """

    def __init__(self, index: PromishIndex, corpus):
        self.index = index
        self.corpus = corpus            # StreamingCorpus (bulk + delta view)
        self.n_bulk = corpus.bulk.n
        L = index.n_scales
        self._chunks: list[list[np.ndarray]] = [[] for _ in range(L)]
        self._mat: list[np.ndarray | None] = [None] * L
        # scale -> keyword -> set of suspect bucket ids (bulk deletes only):
        # buckets whose (keyword, bucket) coverage must be re-verified at
        # query time. Verdicts are monotone under a grow-only tombstone set,
        # so verified buckets leave the suspect set — dead ones permanently
        # into ``_dead`` (a bucket cannot come back to life), live ones
        # dropped until a later retire() touches them again.
        self._suspect: list[dict[int, set[int]]] = [{} for _ in range(L)]
        self._dead: list[dict[int, set[int]]] = [{} for _ in range(L)]

    # ------------------------------------------------------------- absorb
    def _bucket_ids(self, projected: np.ndarray, hi: HIStructure) -> np.ndarray:
        """(B, n_sig) bucket ids of projected rows at one scale — the same
        binning the bulk build ran (``_build_scale``)."""
        if self.index.exact:
            keys2 = proj.bin_keys_overlapping(projected, hi.width)
            return sig.bucket_ids_overlapping(keys2, hi.n_buckets)
        keys = proj.bin_keys_disjoint(projected, hi.width)
        return sig.bucket_ids_disjoint(keys, hi.n_buckets)[:, None]

    def absorb(self, points: np.ndarray,
               projected: np.ndarray | None = None) -> None:
        """Bin a batch of new points at every scale (append-only).

        ``projected`` short-circuits the projection matmul when the caller
        already projected the batch with this index's ``z`` (see
        :func:`absorb_into` — an engine's E and A indices draw identical
        ``z`` from the same seed, so the stream pays one matmul, not two)."""
        if projected is None:
            projected = proj.project(np.ascontiguousarray(points, np.float32),
                                     self.index.z)
        for s, hi in enumerate(self.index.structures):
            self._chunks[s].append(self._bucket_ids(projected, hi))
            self._mat[s] = None

    def retire(self, bulk_ids: np.ndarray) -> None:
        """Record bulk deletions: mark every (keyword, bucket) pair the
        deleted points contributed to as suspect for coverage."""
        bulk_ids = np.asarray(bulk_ids, dtype=np.int64)
        bulk_ids = bulk_ids[bulk_ids < self.n_bulk]
        if not len(bulk_ids):
            return      # delta deletions are handled by the corpus tombstones
        rows = self.corpus.bulk.points[bulk_ids]
        projected = proj.project(rows, self.index.z)
        for s, hi in enumerate(self.index.structures):
            buckets = self._bucket_ids(projected, hi)
            suspect = self._suspect[s]
            for i, pid in enumerate(bulk_ids):
                bset = set(int(b) for b in buckets[i])
                for v in self.corpus.bulk.kw.row(int(pid)):
                    suspect.setdefault(int(v), set()).update(bset)

    def bucket_matrix(self, scale: int) -> np.ndarray:
        """(n_delta, n_sig) bucket assignments at ``scale``."""
        mat = self._mat[scale]
        if mat is None or len(mat) != self.corpus.n_delta:
            chunks = self._chunks[scale]
            n_sig = (1 << self.index.m) if self.index.exact else 1
            mat = np.concatenate(chunks, axis=0) if chunks else \
                np.empty((0, n_sig), dtype=np.int64)
            self._mat[scale] = mat
        return mat

    # ------------------------------------------------------------ query side
    def _delta_buckets_with(self, scale: int, v_kw: int) -> np.ndarray:
        """Buckets at ``scale`` holding >=1 live delta point tagged v_kw."""
        ids = self.corpus.delta_ids_with(v_kw)
        if not len(ids):
            return np.empty(0, dtype=np.int64)
        mat = self.bucket_matrix(scale)
        return np.unique(mat[ids - self.n_bulk])

    def verify_suspects(self, scale: int, keywords) -> int:
        """Batch-resolve suspect (keyword, bucket) coverage entries at one
        scale for every keyword in ``keywords``; returns the number of pairs
        verified.

        This is the coalesced-batch form of the re-verification that
        :meth:`covering_buckets` used to run inline per query: the
        keyword's live posting list is materialised *once* and reused across
        all of its suspect buckets (and, via the batch plan layer, across
        every query in a coalesced batch that shares the keyword), instead
        of re-fetching ``ikp.row`` + tombstone mask per (query, bucket).
        Verdicts are monotone under the grow-only tombstone set, so resolved
        pairs leave the suspect map exactly as before — dead buckets
        permanently into ``_dead``, live ones dropped until a later
        ``retire()`` touches them again."""
        suspect = self._suspect[scale]
        if not suspect:
            return 0
        hi = self.index.structures[scale]
        verified = 0
        for v in {int(v) for v in keywords}:
            buckets = suspect.get(v)
            if not buckets:
                continue
            vpts = self.corpus.bulk.ikp.row(v)
            live_v = vpts[~self.corpus.tombstoned(vpts)]
            newly_dead = {b for b in buckets
                          if not len(live_v)
                          or not sorted_member(hi.table.row(int(b)),
                                               live_v).any()}
            verified += len(buckets)
            buckets.clear()                # live-verified; retire() re-adds
            if newly_dead:
                self._dead[scale].setdefault(v, set()).update(newly_dead)
        return verified

    def covering_buckets(self, scale: int, query) -> np.ndarray:
        """Buckets containing all query keywords across bulk ∪ delta, live
        points only — the streaming replacement for
        :func:`repro.core.plan.covering_buckets` (same ascending order)."""
        self.verify_suspects(scale, query)
        per_kw = []
        hi = self.index.structures[scale]
        for v in query:
            kb = hi.khb.row(int(v)).astype(np.int64)
            dead = self._dead[scale].get(int(v))
            if dead:
                kb = kb[~sorted_member(
                    kb, np.asarray(sorted(dead), dtype=np.int64))]
            dv = self._delta_buckets_with(scale, int(v))
            per_kw.append(np.union1d(kb, dv) if len(dv) else kb)
        stacked = np.concatenate(per_kw) if per_kw else np.empty(0, np.int64)
        u, counts = np.unique(stacked, return_counts=True)
        return u[counts == len(per_kw)]

    def scale_pairs(self, scale: int,
                    bitset: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Relevant live delta membership at one scale, as parallel
        ``(buckets, ids)`` arrays sorted by (bucket, id) and deduped (a
        ProMiSH-E point may draw the same bucket from distinct signatures).
        The plan layer slices per covering bucket with searchsorted."""
        rel = np.flatnonzero(bitset[self.n_bulk:])
        if not len(rel):
            empty = np.empty(0, dtype=np.int64)
            return empty, empty
        mat = self.bucket_matrix(scale)[rel]                    # (R, n_sig)
        ids = np.repeat(rel.astype(np.int64) + self.n_bulk, mat.shape[1])
        buckets = mat.reshape(-1).astype(np.int64)
        order = np.lexsort((ids, buckets))
        buckets, ids = buckets[order], ids[order]
        keep = np.ones(len(buckets), dtype=bool)
        keep[1:] = (buckets[1:] != buckets[:-1]) | (ids[1:] != ids[:-1])
        return buckets[keep], ids[keep]


def absorb_into(deltas, points: np.ndarray) -> None:
    """Absorb one insert batch into several :class:`IndexDelta` buffers,
    sharing the projection matmul between deltas whose indices drew the same
    ``z`` (an engine's exact and approx indices both sample it first from
    ``default_rng(seed)``, so the common case projects once)."""
    points = np.ascontiguousarray(points, np.float32)
    z_ref: np.ndarray | None = None
    projected: np.ndarray | None = None
    for d in deltas:
        if z_ref is None or d.index.z is not z_ref \
                and not np.array_equal(d.index.z, z_ref):
            z_ref = d.index.z
            projected = proj.project(points, z_ref)
        d.absorb(points, projected=projected)
