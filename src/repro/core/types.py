"""Core datatypes for NKS (nearest keyword set) search.

A :class:`KeywordDataset` is the paper's ``D``: ``N`` points in ``R^d``, each
tagged with a keyword set drawn from a dictionary of size ``U``. Keywords are
integer ids; the mapping to strings lives in the application layer.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.utils.csr import (CSR, csr_from_lists, invert_csr, ragged_arange,
                             sorted_member)


@dataclasses.dataclass(frozen=True)
class TenantNamespace:
    """Per-tenant keyword namespaces over one shared global dictionary.

    Tenant ``t`` owns the contiguous global keyword slots
    ``[kw_offsets[t], kw_offsets[t+1])``; its *local* dictionary is
    ``[0, kw_offsets[t+1] - kw_offsets[t])``. :meth:`resolve` maps a tenant's
    local keyword ids into global slots — the serving layer runs it before
    planning, so the whole search pipeline stays namespace-oblivious (global
    ids only) while tenants can never name each other's keywords.
    """

    names: tuple[str, ...]
    kw_offsets: np.ndarray        # (T + 1,) int64, ascending

    @property
    def n_tenants(self) -> int:
        return len(self.names)

    def id_of(self, tenant: str | int) -> int:
        if isinstance(tenant, str):
            try:
                return self.names.index(tenant)
            except ValueError:
                raise KeyError(f"unknown tenant {tenant!r} "
                               f"(known: {list(self.names)})") from None
        t = int(tenant)
        if not 0 <= t < self.n_tenants:
            raise KeyError(f"tenant id {t} out of range [0, {self.n_tenants})")
        return t

    def dict_size(self, tenant: str | int) -> int:
        t = self.id_of(tenant)
        return int(self.kw_offsets[t + 1] - self.kw_offsets[t])

    def resolve(self, tenant: str | int, local_kws) -> list[int]:
        """Tenant-local keyword ids -> global dictionary slots (validated)."""
        t = self.id_of(tenant)
        size = self.dict_size(t)
        out = []
        for v in local_kws:
            v = int(v)
            if not 0 <= v < size:
                raise ValueError(
                    f"keyword {v} outside tenant {self.names[t]!r} dictionary "
                    f"(size {size})")
            out.append(int(self.kw_offsets[t]) + v)
        return out


def _check_attrs(attrs: "dict[str, np.ndarray] | None", n: int
                 ) -> "dict[str, np.ndarray] | None":
    if attrs is None:
        return None
    out = {}
    for name, col in attrs.items():
        col = np.ascontiguousarray(col)
        if col.shape != (n,):
            raise ValueError(f"attribute {name!r} must be ({n},), "
                             f"got {col.shape}")
        out[str(name)] = col
    return out


@dataclasses.dataclass(frozen=True)
class KeywordDataset:
    """The paper's tagged multi-dimensional dataset.

    points     : (N, d) float32 — the embedded objects.
    kw         : CSR point -> sorted keyword ids (the paper's sigma(o)).
    ikp        : CSR keyword -> sorted point ids (the paper's I_kp inverted index).
    n_keywords : dictionary size U.
    attrs      : optional per-point attribute columns (name -> (N,) array;
                 numeric dtypes take the ordered predicate ops, any dtype the
                 equality/set ops — see ``core.filters``).
    tenant_of  : optional (N,) int tenant id per point (multi-tenant corpora).
    tenants    : optional per-tenant keyword namespace over the dictionary.
    """

    points: np.ndarray
    kw: CSR
    ikp: CSR
    n_keywords: int
    attrs: dict | None = None
    tenant_of: np.ndarray | None = None
    tenants: TenantNamespace | None = None

    @property
    def n(self) -> int:
        return int(self.points.shape[0])

    @property
    def dim(self) -> int:
        return int(self.points.shape[1])

    def keywords_of(self, point_id: int) -> np.ndarray:
        return self.kw.row(point_id)

    def points_with(self, keyword: int) -> np.ndarray:
        """I_kp lookup: ids of points tagged with ``keyword``."""
        return self.ikp.row(keyword)

    def has_keyword(self, point_id: int, keyword: int) -> bool:
        row = self.kw.row(point_id)
        j = np.searchsorted(row, keyword)
        return bool(j < len(row) and row[j] == keyword)

    # ------------------------------------------------------ attribute surface
    def attr_column(self, name: str) -> np.ndarray:
        """(N,) attribute column for predicate evaluation."""
        if not self.attrs or name not in self.attrs:
            have = sorted(self.attrs) if self.attrs else []
            raise KeyError(f"unknown attribute {name!r} (corpus has: {have})")
        return self.attrs[name]

    @property
    def tenant_ids(self) -> np.ndarray | None:
        """(N,) tenant id per point, or None on a single-tenant corpus."""
        return self.tenant_of

    def nbytes(self) -> int:
        extra = sum(c.nbytes for c in (self.attrs or {}).values())
        if self.tenant_of is not None:
            extra += self.tenant_of.nbytes
        return self.points.nbytes + self.kw.nbytes() + self.ikp.nbytes() + extra


def make_dataset(points: np.ndarray, keywords: Sequence[Sequence[int]],
                 n_keywords: int | None = None, *,
                 attrs: dict | None = None,
                 tenant_of: np.ndarray | None = None,
                 tenants: TenantNamespace | None = None) -> KeywordDataset:
    points = np.ascontiguousarray(points, dtype=np.float32)
    keywords = [sorted(set(int(v) for v in ks)) for ks in keywords]
    if len(keywords) != len(points):
        raise ValueError(f"{len(points)} points but {len(keywords)} keyword sets")
    if n_keywords is None:
        n_keywords = 1 + max((max(ks) for ks in keywords if ks), default=-1)
    attrs = _check_attrs(attrs, len(points))
    if tenant_of is not None:
        tenant_of = np.ascontiguousarray(tenant_of, dtype=np.int32)
        if tenant_of.shape != (len(points),):
            raise ValueError(f"tenant_of must be ({len(points)},), "
                             f"got {tenant_of.shape}")
    kw = csr_from_lists(keywords)
    ikp = invert_csr(kw, n_keywords)
    return KeywordDataset(points=points, kw=kw, ikp=ikp,
                          n_keywords=int(n_keywords), attrs=attrs,
                          tenant_of=tenant_of, tenants=tenants)


def merge_tenants(corpora: "dict[str, dict]") -> KeywordDataset:
    """Pack per-tenant corpora into one multi-tenant :class:`KeywordDataset`.

    ``corpora`` maps tenant name -> ``{"points": (n_t, d), "keywords":
    [[local ids...]], "n_keywords": local dict size, "attrs": optional
    per-tenant columns}``. Each tenant keeps a private keyword namespace:
    local id ``v`` of tenant ``t`` lands in global slot ``offset[t] + v``, so
    identical local ids of different tenants never collide and a
    tenant-scoped query can only ever reach its own postings. Attribute
    schemas must agree across tenants (or be absent everywhere).
    """
    if not corpora:
        raise ValueError("merge_tenants: no tenants")
    names = tuple(corpora)
    sizes = []
    for name in names:
        spec = corpora[name]
        nk = spec.get("n_keywords")
        if nk is None:
            nk = 1 + max((max(ks) for ks in spec["keywords"] if ks), default=-1)
        sizes.append(int(nk))
    offsets = np.zeros(len(names) + 1, dtype=np.int64)
    np.cumsum(sizes, out=offsets[1:])
    ns = TenantNamespace(names=names, kw_offsets=offsets)

    points, keywords, tenant_of = [], [], []
    schemas = [frozenset(corpora[name].get("attrs") or ()) for name in names]
    if len(set(schemas)) > 1:
        raise ValueError(f"attribute schemas differ across tenants: "
                         f"{[sorted(s) for s in set(schemas)]}")
    attr_chunks: dict[str, list] = {k: [] for k in schemas[0]}
    for t, name in enumerate(names):
        spec = corpora[name]
        pts = np.asarray(spec["points"], dtype=np.float32)
        if pts.ndim != 2 or (points and pts.shape[1] != points[0].shape[1]):
            raise ValueError(f"tenant {name!r}: inconsistent point dims")
        points.append(pts)
        keywords.extend(ns.resolve(t, ks) for ks in spec["keywords"])
        tenant_of.append(np.full(len(pts), t, dtype=np.int32))
        for k in attr_chunks:
            col = np.asarray(spec["attrs"][k])
            if col.shape != (len(pts),):
                raise ValueError(f"tenant {name!r}: attribute {k!r} must be "
                                 f"({len(pts)},), got {col.shape}")
            attr_chunks[k].append(col)
    attrs = {k: np.concatenate(v) for k, v in attr_chunks.items()} or None
    return make_dataset(np.concatenate(points, axis=0), keywords,
                        n_keywords=int(offsets[-1]), attrs=attrs,
                        tenant_of=np.concatenate(tenant_of), tenants=ns)


class _MergedKw:
    """``kw`` adapter of a :class:`StreamingCorpus`: point -> keyword ids."""

    def __init__(self, view: "StreamingCorpus"):
        self._view = view

    def row(self, i: int) -> np.ndarray:
        v = self._view
        if i < v.bulk.n:
            return v.bulk.kw.row(i)
        return v._kw[i - v.bulk.n]


class _MergedIkp:
    """``ikp`` adapter of a :class:`StreamingCorpus`: keyword -> point ids.

    Rows are the *union* of the bulk CSR row and the delta postings —
    tombstoned points are NOT filtered here (the engine clears them from the
    query bitset once per batch, which is cheaper than filtering every
    lookup); :meth:`StreamingCorpus.points_with` is the live-filtered variant
    the device tier packs from. Delta ids are assigned in increasing order
    and all exceed bulk ids, so the concatenated row stays sorted — the
    searchsorted membership tests in ``subset_search`` rely on that.
    """

    def __init__(self, view: "StreamingCorpus"):
        self._view = view

    def row(self, v_kw: int) -> np.ndarray:
        view = self._view
        base = view.bulk.ikp.row(v_kw)
        extra = view._delta_postings(v_kw)
        if not len(extra):
            return base
        return np.concatenate([base.astype(np.int64), extra])


class StreamingCorpus:
    """Mutable merged corpus: immutable bulk + append-only delta - tombstones.

    Duck-types the :class:`KeywordDataset` surface the search pipeline
    touches (``points``, ``kw.row``, ``ikp.row``, ``n``, ``dim``,
    ``n_keywords``, ``points_with``) so the plan/backend/enumeration stages
    run unchanged over a streaming corpus. Internal point ids are bulk rows
    ``[0, bulk.n)`` followed by delta rows in absorption order; deletes are
    tombstones (ids stay allocated until the engine compacts into a fresh
    bulk). The point buffer grows by capacity doubling, so absorbing a batch
    is amortised O(batch), not O(corpus).
    """

    def __init__(self, bulk: KeywordDataset):
        self.bulk = bulk
        self.n_keywords = bulk.n_keywords
        self.n_delta = 0
        self._kw: list[np.ndarray] = []            # per delta point, sorted kws
        self._ikp: dict[int, list[int]] = {}       # kw -> delta ids (ascending)
        self._ikp_memo: dict[int, np.ndarray] = {}
        self._tomb: set[int] = set()
        self._tomb_sorted = np.empty(0, dtype=np.int64)
        self._buf: np.ndarray | None = None        # growable point storage
        self._filled = 0
        # Attribute / tenant columns of the delta, per absorbed batch; merged
        # views are memoised until the next absorb.
        self._attr_chunks: dict[str, list[np.ndarray]] = \
            {k: [] for k in (bulk.attrs or {})}
        self._tenant_chunks: list[np.ndarray] = []
        self._col_memo: dict[str, np.ndarray] = {}

    # ------------------------------------------------------------- geometry
    @property
    def n(self) -> int:
        return self.bulk.n + self.n_delta

    @property
    def dim(self) -> int:
        return self.bulk.dim

    @property
    def kw(self) -> _MergedKw:
        return _MergedKw(self)

    @property
    def ikp(self) -> _MergedIkp:
        return _MergedIkp(self)

    def _ensure_capacity(self, need: int) -> None:
        """Grow the point buffer to hold ``need`` rows (capacity doubling)."""
        if self._buf is None:
            cap = max(1024, 2 * need)
            self._buf = np.empty((cap, self.dim), dtype=np.float32)
            self._buf[: self.bulk.n] = self.bulk.points
            self._filled = self.bulk.n
        elif len(self._buf) < need:
            cap = max(2 * len(self._buf), need)
            grown = np.empty((cap, self.dim), dtype=np.float32)
            grown[: self._filled] = self._buf[: self._filled]
            self._buf = grown

    @property
    def points(self) -> np.ndarray:
        """(n, d) float32 view over the merged corpus (bulk rows first).
        Delete-only streams never copy the bulk: the buffer materialises on
        the first absorb, not here."""
        if self.n_delta == 0:
            return self.bulk.points
        self._ensure_capacity(self.n)
        return self._buf[: self.n]

    # ------------------------------------------------------------ mutation
    def absorb(self, points: np.ndarray,
               keywords: Sequence[Sequence[int]],
               attrs: dict | None = None,
               tenant: "int | str | np.ndarray | None" = None) -> np.ndarray:
        """Append a batch; returns the assigned internal ids (ascending).

        ``attrs``/``tenant`` must match the bulk corpus schema: a corpus with
        attribute columns requires the same columns on every batch (length =
        batch size); a multi-tenant corpus requires a tenant (one scalar for
        the whole batch, or a per-point array). Tenant names resolve through
        the corpus namespace. A schema-less corpus rejects both.
        """
        points = np.ascontiguousarray(points, dtype=np.float32)
        if points.ndim != 2 or points.shape[1] != self.dim:
            raise ValueError(f"expected (*, {self.dim}) points, got {points.shape}")
        if len(points) != len(keywords):
            raise ValueError(f"{len(points)} points but {len(keywords)} keyword sets")
        # Validate the whole batch before mutating anything: absorption is
        # atomic — queries see all of a batch or none of it, including when
        # an insert fails mid-validation.
        norm = [sorted(set(int(v) for v in ks)) for ks in keywords]
        for ks in norm:
            if ks and (ks[0] < 0 or ks[-1] >= self.n_keywords):
                raise ValueError("keyword outside dictionary")
        attr_cols = self._check_batch_attrs(attrs, len(points))
        tenant_col = self._check_batch_tenant(tenant, len(points))
        start = self.n
        need = start + len(points)
        self._ensure_capacity(need)
        self._buf[start:need] = points
        self._filled = need
        for j, ks in enumerate(norm):
            self._kw.append(np.asarray(ks, dtype=np.int32))
            for v in ks:
                self._ikp.setdefault(v, []).append(start + j)
                self._ikp_memo.pop(v, None)
        for name, col in attr_cols.items():
            self._attr_chunks[name].append(col)
        if tenant_col is not None:
            self._tenant_chunks.append(tenant_col)
        self._col_memo.clear()
        self.n_delta += len(points)
        return np.arange(start, start + len(points), dtype=np.int64)

    def _check_batch_attrs(self, attrs: dict | None, batch: int) -> dict:
        schema = set(self._attr_chunks)
        got = set(attrs or ())
        if got != schema:
            raise ValueError(f"attribute batch keys {sorted(got)} != corpus "
                             f"schema {sorted(schema)}")
        out = {}
        for name in schema:
            col = np.ascontiguousarray(attrs[name])
            if col.shape != (batch,):
                raise ValueError(f"attribute {name!r} must be ({batch},), "
                                 f"got {col.shape}")
            out[name] = col.astype(self.bulk.attrs[name].dtype, copy=False)
        return out

    def _check_batch_tenant(self, tenant, batch: int) -> np.ndarray | None:
        if self.bulk.tenant_of is None:
            if tenant is not None:
                raise ValueError("tenant given but the corpus has no tenant "
                                 "column")
            return None
        if tenant is None:
            raise ValueError("multi-tenant corpus: every absorbed batch "
                             "needs a tenant")
        ns = self.bulk.tenants
        if isinstance(tenant, (str, int, np.integer)):
            tid = ns.id_of(tenant) if ns is not None else int(tenant)
            return np.full(batch, tid, dtype=np.int32)
        col = np.asarray([ns.id_of(t) if ns is not None else int(t)
                          for t in tenant], dtype=np.int32)
        if col.shape != (batch,):
            raise ValueError(f"tenant column must be ({batch},), got {col.shape}")
        return col

    def delete(self, ids: np.ndarray) -> None:
        """Tombstone internal ids (bulk or delta); idempotence is the
        caller's job — the engine validates liveness before calling."""
        self._tomb.update(int(i) for i in ids)
        # True merge: O(T + b log b) — sort only the small batch and splice
        # it into the already-sorted array.
        new = np.asarray(sorted(set(int(i) for i in ids)), dtype=np.int64)
        pos = np.searchsorted(self._tomb_sorted, new)
        self._tomb_sorted = np.insert(self._tomb_sorted, pos, new)

    # -------------------------------------------------------------- queries
    @property
    def dirty(self) -> bool:
        return self.n_delta > 0 or bool(self._tomb)

    @property
    def n_tombstones(self) -> int:
        return len(self._tomb)

    def is_live(self, i: int) -> bool:
        return i not in self._tomb

    def tombstoned(self, ids: np.ndarray) -> np.ndarray:
        """Boolean mask: which of ``ids`` are deleted."""
        return sorted_member(np.asarray(ids, dtype=np.int64),
                             self._tomb_sorted)

    def mask_tombstones(self, bitset: np.ndarray) -> None:
        """Clear deleted points from a query bitset (plan + fallback see only
        live points; this is where tombstones filter enumeration)."""
        if len(self._tomb_sorted):
            bitset[self._tomb_sorted] = False

    def live_internal_ids(self) -> np.ndarray:
        """Sorted internal ids of every live point (compaction order)."""
        alive = np.ones(self.n, dtype=bool)
        if len(self._tomb_sorted):
            alive[self._tomb_sorted] = False
        return np.flatnonzero(alive).astype(np.int64)

    def _delta_postings(self, v_kw: int) -> np.ndarray:
        lst = self._ikp.get(int(v_kw))
        if not lst:
            return np.empty(0, dtype=np.int64)
        arr = self._ikp_memo.get(int(v_kw))
        if arr is None or len(arr) != len(lst):
            arr = np.asarray(lst, dtype=np.int64)
            self._ikp_memo[int(v_kw)] = arr
        return arr

    def delta_ids_with(self, v_kw: int) -> np.ndarray:
        """Live delta ids tagged with ``v_kw`` (sorted)."""
        ids = self._delta_postings(v_kw)
        if not len(ids):
            return ids
        return ids[~self.tombstoned(ids)]

    def points_with(self, keyword: int) -> np.ndarray:
        """Live merged I_kp lookup (the device tier packs from this)."""
        merged = self.ikp.row(keyword)
        dead = self.tombstoned(merged)
        return merged[~dead] if dead.any() else merged

    # --------------------------------------------------- attribute surface
    @property
    def attrs(self) -> dict | None:
        """Attribute schema marker (duck-types ``KeywordDataset.attrs`` for
        presence checks; columns come from :meth:`attr_column`)."""
        return self.bulk.attrs

    @property
    def tenants(self) -> "TenantNamespace | None":
        return self.bulk.tenants

    def attr_column(self, name: str) -> np.ndarray:
        """Merged (n,) attribute column: bulk rows then delta rows.
        Tombstoned rows keep their values — eligibility is ANDed with
        liveness downstream, never consulted for dead points."""
        if name not in self._attr_chunks and (
                not self.bulk.attrs or name not in self.bulk.attrs):
            return self.bulk.attr_column(name)      # raises the KeyError
        col = self._col_memo.get(name)
        if col is None:
            col = np.concatenate([self.bulk.attr_column(name)]
                                 + self._attr_chunks[name]) \
                if self._attr_chunks[name] else self.bulk.attr_column(name)
            self._col_memo[name] = col
        return col

    @property
    def tenant_ids(self) -> np.ndarray | None:
        if self.bulk.tenant_of is None:
            return None
        col = self._col_memo.get("__tenant__")
        if col is None:
            col = np.concatenate([self.bulk.tenant_of] + self._tenant_chunks) \
                if self._tenant_chunks else self.bulk.tenant_of
            self._col_memo["__tenant__"] = col
        return col

    def keywords_of(self, point_id: int) -> np.ndarray:
        return self.kw.row(point_id)

    def has_keyword(self, point_id: int, keyword: int) -> bool:
        row = self.kw.row(point_id)
        j = np.searchsorted(row, keyword)
        return bool(j < len(row) and row[j] == keyword)

    def compacted_dataset(self) -> KeywordDataset:
        """The live corpus as a fresh frozen :class:`KeywordDataset`
        (compaction's rebuild input), points and keyword rows in internal-id
        order. Keyword rows are sliced vectorised from the bulk CSR plus the
        delta arrays — every row is already sorted unique, so the result is
        identical to ``make_dataset`` over the same rows without the
        per-point Python pass."""
        live = self.live_internal_ids()
        points = np.ascontiguousarray(self.points[live])
        live_bulk = live[live < self.bulk.n]
        live_delta = live[live >= self.bulk.n] - self.bulk.n
        kwcsr = self.bulk.kw
        counts = np.diff(kwcsr.offsets)[live_bulk]
        idx = np.repeat(kwcsr.offsets[live_bulk], counts) + \
            ragged_arange(counts)
        delta_rows = [self._kw[i] for i in live_delta]
        values = np.concatenate(
            [kwcsr.values[idx].astype(np.int32)]
            + [r.astype(np.int32) for r in delta_rows]) if len(live) else \
            np.empty(0, dtype=np.int32)
        lens = np.concatenate(
            [counts, np.fromiter((len(r) for r in delta_rows), np.int64,
                                 count=len(delta_rows))])
        offsets = np.zeros(len(live) + 1, dtype=np.int64)
        np.cumsum(lens, out=offsets[1:])
        kw = CSR(offsets=offsets, values=values)
        ikp = invert_csr(kw, self.n_keywords)
        attrs = {name: np.ascontiguousarray(self.attr_column(name)[live])
                 for name in (self.bulk.attrs or {})} or None
        tenant_of = None
        if self.bulk.tenant_of is not None:
            tenant_of = np.ascontiguousarray(self.tenant_ids[live])
        return KeywordDataset(points=points, kw=kw, ikp=ikp,
                              n_keywords=self.n_keywords, attrs=attrs,
                              tenant_of=tenant_of, tenants=self.bulk.tenants)

    def nbytes(self) -> int:
        delta_pts = (self._buf.nbytes if self._buf is not None else 0)
        delta_attrs = sum(c.nbytes for chunks in self._attr_chunks.values()
                          for c in chunks)
        return self.bulk.nbytes() + delta_pts + delta_attrs + \
            sum(a.nbytes for a in self._kw) + 8 * len(self._tomb)


@dataclasses.dataclass(frozen=True)
class Candidate:
    """A query result: a minimal point set covering Q, ranked by diameter then
    cardinality (the paper's tie-break).

    Under flexible semantics (``core.semantics``) ``diameter`` holds the
    *weighted* cost — identical to the geometric diameter with unit weights —
    and scored mode stamps ``score`` (None everywhere else, so the classic
    result shape is unchanged)."""

    ids: tuple[int, ...]          # sorted, unique point ids
    diameter: float
    score: float | None = None

    def key(self) -> tuple[float, int, tuple[int, ...]]:
        return (self.diameter, len(self.ids), self.ids)


class TopK:
    """The paper's priority queue PQ of top-k results.

    ProMiSH-E semantics: initialised with k sentinel entries of diameter +inf
    (so ``kth_diameter`` is +inf until k real results exist). ProMiSH-A
    semantics (``init_full=False``): starts empty.

    ``tie_open=True`` (flexible-semantics queues only) inflates the reported
    k-th diameter by one ulp. The enumeration gates prune with strict
    ``diam < r_k`` comparisons, which in classic mode never drops a result —
    diameters are continuous, so exact ties are measure-zero. m-of-k
    coverage breaks that: subqueries admit many *equal-cost* candidates
    (notably cost-0 singletons), where a strict gate would discard a
    late-arriving equal whose (cost, cardinality, ids) key beats the
    incumbent. The one-ulp inflation lets exact ties through to ``offer``,
    whose total-order key settles them; pruning and Lemma-2 termination only
    become (infinitesimally) more conservative.
    """

    def __init__(self, k: int, init_full: bool = True,
                 tie_open: bool = False):
        self.k = int(k)
        self._items: list[Candidate] = []
        self._seen: set[tuple[int, ...]] = set()
        self._init_full = init_full
        self._tie_open = tie_open

    def __len__(self) -> int:
        return len(self._items)

    @property
    def items(self) -> list[Candidate]:
        return list(self._items)

    def kth_diameter(self) -> float:
        if len(self._items) < self.k:
            return float("inf")
        kth = self._items[self.k - 1].diameter
        return math.nextafter(kth, math.inf) if self._tie_open else kth

    def offer(self, cand: Candidate) -> bool:
        """Insert if it improves the top-k; dedup by point-id set."""
        if cand.ids in self._seen:
            return False
        if len(self._items) >= self.k and cand.key() >= self._items[self.k - 1].key():
            return False
        self._items.append(cand)
        self._seen.add(cand.ids)
        self._items.sort(key=Candidate.key)
        if len(self._items) > self.k:
            drop = self._items.pop()
            self._seen.discard(drop.ids)
        return True

    def full(self) -> bool:
        return len(self._items) >= self.k


class ScoredTopK:
    """Scored-mode priority queue: rank by ``score = coverage / (1 + alpha *
    cost)`` — descending score, then the classic (cost, cardinality, ids)
    tie-break. Duck-types :class:`TopK` (``offer`` / ``kth_diameter`` /
    ``full`` / ``items``) so every search loop and enumeration stage runs
    unchanged.

    ``kth_diameter`` is the contract's load-bearing half: callers use it as
    a *cost* pruning bound, so it converts the k-th score back into the
    largest cost any still-admissible candidate could have. Coverage is at
    most ``total_weight``, hence a candidate beats the k-th score only if
    ``total_weight / (1 + alpha * cost) >= kth_score``, i.e. ``cost <=
    (total_weight / kth_score - 1) / alpha``. The bound is nudged one ulp up
    so equal-score candidates (which can still win on the tie-break) survive
    the strict ``<`` prefilters; a one-ulp-looser prune only ever admits
    extra work. Lemma-2 termination stays sound: weighted cost dominates
    geometric diameter (weights >= 1), so once the bound drops below the
    scale radius every admissible candidate was already explored.

    Offers arrive as plain ``Candidate(ids, cost)`` from the enumeration
    stages; the queue computes the score itself (``coverage`` is the
    semantics-supplied ids -> covered-weight function) and stamps it on the
    stored candidate.
    """

    def __init__(self, k: int, *, total_weight: float, alpha: float,
                 coverage, init_full: bool = True):
        self.k = int(k)
        self.total_weight = float(total_weight)
        self.alpha = float(alpha)
        self._coverage = coverage
        self._items: list[Candidate] = []
        self._seen: set[tuple[int, ...]] = set()
        self._init_full = init_full

    def __len__(self) -> int:
        return len(self._items)

    @property
    def items(self) -> list[Candidate]:
        return list(self._items)

    @staticmethod
    def _key(cand: Candidate) -> tuple:
        return (-cand.score, cand.diameter, len(cand.ids), cand.ids)

    def kth_diameter(self) -> float:
        if len(self._items) < self.k:
            return float("inf")
        kth = self._items[self.k - 1].score
        if kth <= 0.0:
            return float("inf")
        bound = (self.total_weight / kth - 1.0) / self.alpha
        return math.nextafter(max(bound, 0.0), math.inf)

    def offer(self, cand: Candidate) -> bool:
        """Insert if it improves the top-k; dedup by point-id set. The score
        is derived here, so the candidate's cost (``diameter``) is all the
        enumeration has to settle."""
        if cand.ids in self._seen:
            return False
        cov = float(self._coverage(cand.ids))
        cand = dataclasses.replace(
            cand, score=cov / (1.0 + self.alpha * cand.diameter))
        if len(self._items) >= self.k \
                and self._key(cand) >= self._key(self._items[self.k - 1]):
            return False
        self._items.append(cand)
        self._seen.add(cand.ids)
        self._items.sort(key=self._key)
        if len(self._items) > self.k:
            drop = self._items.pop()
            self._seen.discard(drop.ids)
        return True

    def full(self) -> bool:
        return len(self._items) >= self.k
