"""Core datatypes for NKS (nearest keyword set) search.

A :class:`KeywordDataset` is the paper's ``D``: ``N`` points in ``R^d``, each
tagged with a keyword set drawn from a dictionary of size ``U``. Keywords are
integer ids; the mapping to strings lives in the application layer.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.utils.csr import CSR, csr_from_lists, invert_csr


@dataclasses.dataclass(frozen=True)
class KeywordDataset:
    """The paper's tagged multi-dimensional dataset.

    points     : (N, d) float32 — the embedded objects.
    kw         : CSR point -> sorted keyword ids (the paper's sigma(o)).
    ikp        : CSR keyword -> sorted point ids (the paper's I_kp inverted index).
    n_keywords : dictionary size U.
    """

    points: np.ndarray
    kw: CSR
    ikp: CSR
    n_keywords: int

    @property
    def n(self) -> int:
        return int(self.points.shape[0])

    @property
    def dim(self) -> int:
        return int(self.points.shape[1])

    def keywords_of(self, point_id: int) -> np.ndarray:
        return self.kw.row(point_id)

    def points_with(self, keyword: int) -> np.ndarray:
        """I_kp lookup: ids of points tagged with ``keyword``."""
        return self.ikp.row(keyword)

    def has_keyword(self, point_id: int, keyword: int) -> bool:
        row = self.kw.row(point_id)
        j = np.searchsorted(row, keyword)
        return bool(j < len(row) and row[j] == keyword)

    def nbytes(self) -> int:
        return self.points.nbytes + self.kw.nbytes() + self.ikp.nbytes()


def make_dataset(points: np.ndarray, keywords: Sequence[Sequence[int]],
                 n_keywords: int | None = None) -> KeywordDataset:
    points = np.ascontiguousarray(points, dtype=np.float32)
    keywords = [sorted(set(int(v) for v in ks)) for ks in keywords]
    if len(keywords) != len(points):
        raise ValueError(f"{len(points)} points but {len(keywords)} keyword sets")
    if n_keywords is None:
        n_keywords = 1 + max((max(ks) for ks in keywords if ks), default=-1)
    kw = csr_from_lists(keywords)
    ikp = invert_csr(kw, n_keywords)
    return KeywordDataset(points=points, kw=kw, ikp=ikp, n_keywords=int(n_keywords))


@dataclasses.dataclass(frozen=True)
class Candidate:
    """A query result: a minimal point set covering Q, ranked by diameter then
    cardinality (the paper's tie-break)."""

    ids: tuple[int, ...]          # sorted, unique point ids
    diameter: float

    def key(self) -> tuple[float, int, tuple[int, ...]]:
        return (self.diameter, len(self.ids), self.ids)


class TopK:
    """The paper's priority queue PQ of top-k results.

    ProMiSH-E semantics: initialised with k sentinel entries of diameter +inf
    (so ``kth_diameter`` is +inf until k real results exist). ProMiSH-A
    semantics (``init_full=False``): starts empty.
    """

    def __init__(self, k: int, init_full: bool = True):
        self.k = int(k)
        self._items: list[Candidate] = []
        self._seen: set[tuple[int, ...]] = set()
        self._init_full = init_full

    def __len__(self) -> int:
        return len(self._items)

    @property
    def items(self) -> list[Candidate]:
        return list(self._items)

    def kth_diameter(self) -> float:
        if len(self._items) < self.k and self._init_full:
            return float("inf")
        if len(self._items) < self.k:
            return float("inf")
        return self._items[self.k - 1].diameter

    def offer(self, cand: Candidate) -> bool:
        """Insert if it improves the top-k; dedup by point-id set."""
        if cand.ids in self._seen:
            return False
        if len(self._items) >= self.k and cand.key() >= self._items[self.k - 1].key():
            return False
        self._items.append(cand)
        self._seen.add(cand.ids)
        self._items.sort(key=Candidate.key)
        if len(self._items) > self.k:
            drop = self._items.pop()
            self._seen.discard(drop.ids)
        return True

    def full(self) -> bool:
        return len(self._items) >= self.k
