"""Attribute predicates and tenant scoping for filtered NKS.

The paper's query model is pure keyword-set tightness; a serving deployment
immediately needs the *filtered* variant — "tightest group matching these
keywords **where** price < 50 and tenant = acme". This module is the predicate
grammar and its one-pass evaluator:

  * :class:`Clause` — one ``attr op value`` comparison over a per-point
    attribute column (``KeywordDataset.attrs`` / the streaming merged view).
    Ops: ``< <= > >= == != in between``. Numeric columns take the ordered
    ops; any column takes the equality/set ops.
  * :class:`Filter` — a conjunction of clauses plus optional tenant scoping
    (``tenant="acme"`` restricts to points whose ``tenant_of`` matches; names
    resolve through the dataset's :class:`~repro.core.types.TenantNamespace`).

``Filter.evaluate`` runs **once per query batch** and produces the (N,) bool
*point-eligibility mask* the whole pipeline consumes: the plan layer prunes
covering-bucket subsets with no eligible member, keyword groups restrict to
eligible rows before enumeration, and the device backend folds the mask into
the packed join bitmask on device (see ``core.backend``) — subsets and their
packed tiles stay filter-independent, so the LRU caches are shared across
filters.

Evaluation is deliberately eager and total: an unknown attribute, a
type-incompatible op, or tenant scoping on a tenant-less corpus raises at
evaluate time (a serving frontend wants the 4xx, not a silently empty
answer).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

_ORDERED_OPS = {
    "<": np.less,
    "<=": np.less_equal,
    ">": np.greater,
    ">=": np.greater_equal,
}
_EQUALITY_OPS = {"==", "!="}
_SET_OPS = {"in", "between"}
OPS = tuple(_ORDERED_OPS) + tuple(sorted(_EQUALITY_OPS | _SET_OPS))


@dataclasses.dataclass(frozen=True)
class Clause:
    """One ``attr op value`` predicate over a per-point attribute column."""

    attr: str
    op: str
    value: object

    def __post_init__(self):
        if self.op not in OPS:
            raise ValueError(f"unknown predicate op {self.op!r} "
                             f"(supported: {', '.join(OPS)})")
        if self.op == "in":
            if not isinstance(self.value, (list, tuple, set, frozenset, np.ndarray)):
                raise ValueError(f"'in' needs a value list, got {self.value!r}")
            object.__setattr__(self, "value",
                               tuple(sorted(set(self.value))))
        elif self.op == "between":
            v = self.value
            if not (isinstance(v, (list, tuple)) and len(v) == 2):
                raise ValueError(f"'between' needs (lo, hi), got {v!r}")
            object.__setattr__(self, "value", (v[0], v[1]))

    def evaluate(self, column: np.ndarray) -> np.ndarray:
        """(N,) bool mask of rows satisfying the clause."""
        if self.op in _ORDERED_OPS:
            if not np.issubdtype(column.dtype, np.number):
                raise ValueError(
                    f"ordered op {self.op!r} on non-numeric column "
                    f"{self.attr!r} (dtype {column.dtype})")
            return _ORDERED_OPS[self.op](column, self.value)
        if self.op == "==":
            return column == self.value
        if self.op == "!=":
            return column != self.value
        if self.op == "between":
            lo, hi = self.value
            return (column >= lo) & (column <= hi)
        # "in": sorted-unique membership (values normalised in __post_init__)
        return np.isin(column, np.asarray(self.value))

    def as_json(self) -> list:
        v = list(self.value) if isinstance(self.value, tuple) else self.value
        return [self.attr, self.op, v]


@dataclasses.dataclass(frozen=True)
class Filter:
    """A conjunction of attribute clauses plus optional tenant scoping.

    ``tenant`` is a tenant name (resolved through the corpus
    :class:`~repro.core.types.TenantNamespace`) or a raw tenant id. The empty
    filter (no clauses, no tenant) evaluates to all-eligible and is
    equivalent to no filter at all.
    """

    clauses: tuple[Clause, ...] = ()
    tenant: str | int | None = None

    def __bool__(self) -> bool:
        return bool(self.clauses) or self.tenant is not None

    def evaluate(self, dataset) -> np.ndarray:
        """The (N,) bool point-eligibility mask over ``dataset``.

        ``dataset`` is any corpus exposing the attribute surface
        (``KeywordDataset`` or the streaming merged view): ``n``,
        ``attr_column(name)``, ``tenant_ids``, ``tenants``.
        """
        eligible = np.ones(dataset.n, dtype=bool)
        if self.tenant is not None:
            tids = dataset.tenant_ids
            if tids is None:
                raise ValueError(
                    f"filter scopes to tenant {self.tenant!r} but the corpus "
                    f"has no tenant column")
            ns = dataset.tenants
            tid = ns.id_of(self.tenant) if ns is not None else int(self.tenant)
            eligible &= tids == tid
        for c in self.clauses:
            eligible &= c.evaluate(dataset.attr_column(c.attr))
        return eligible

    def selectivity(self, dataset) -> float:
        n = dataset.n
        return float(self.evaluate(dataset).sum()) / n if n else 0.0

    # ----------------------------------------------------------- conversions
    @classmethod
    def from_json(cls, spec: dict) -> "Filter":
        """Parse the serving-layer JSON form:
        ``{"tenant": "acme", "where": [["price", "<", 50], ...]}``."""
        if not isinstance(spec, dict):
            raise ValueError(f"filter spec must be an object, got {spec!r}")
        unknown = set(spec) - {"tenant", "where"}
        if unknown:
            raise ValueError(f"unknown filter keys: {sorted(unknown)}")
        clauses = []
        for item in spec.get("where", []):
            if len(item) != 3:
                raise ValueError(f"clause must be [attr, op, value]: {item!r}")
            clauses.append(Clause(str(item[0]), str(item[1]), item[2]))
        return cls(clauses=tuple(clauses), tenant=spec.get("tenant"))

    def as_json(self) -> dict:
        out: dict = {}
        if self.tenant is not None:
            out["tenant"] = self.tenant
        if self.clauses:
            out["where"] = [c.as_json() for c in self.clauses]
        return out

    @staticmethod
    def coerce(spec) -> "Filter | None":
        """Accept a Filter, a JSON dict, or None (engine entry points)."""
        if spec is None:
            return None
        if isinstance(spec, Filter):
            return spec if spec else None
        flt = Filter.from_json(spec)
        return flt if flt else None


def where(*clauses: Sequence, tenant: str | int | None = None) -> Filter:
    """Terse constructor: ``where(("price", "<", 50), tenant="acme")``."""
    return Filter(clauses=tuple(Clause(a, op, v) for a, op, v in clauses),
                  tenant=tenant)
