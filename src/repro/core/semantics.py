"""Flexible query semantics: m-of-k partial coverage, per-keyword weights,
and scored ranking (ISSUE 9).

Classic NKS is all-or-nothing — a candidate must cover *every* query keyword
and ranks by geometric diameter alone. Real search traffic is softer; this
module is the single definition of the three relaxations the whole pipeline
(oracle, per-query searches, batched engine, runtime, JSONL launcher)
shares:

* **m-of-k coverage** (the Flexible Group Spatial Keyword Query's subgroup
  query): a result may cover any ``m`` of the ``k=len(Q)`` query keywords.
  Mechanically a query expands into *subqueries* — every keyword subset
  ``S ⊆ Q`` with ``m <= |S| <= |Q|`` — each planned and enumerated through
  the existing Algorithm-2 machinery unchanged (its own bitset, its own
  dedup set), all feeding one shared top-k queue. The candidate universe is
  exactly "groups minimal with respect to *some* subset of >= m query
  keywords"; with ``m = |Q|`` the only subquery is Q itself and everything
  degenerates to classic NKS.

* **per-keyword weights** (the ``title^4`` field-boost idiom): each query
  keyword carries a weight ``w >= 1``; a point's weight is the *largest*
  weight among the query keywords it is tagged with (set-determined — no
  assignment problem, so id-set dedup and minimality are untouched), and the
  objective becomes the weighted diameter ``max sqrt(d2(a,b) * w(a) * w(b))``
  over the group's pairs. The ``w >= 1`` floor is load-bearing twice over:
  weighted cost dominates geometric diameter, so (a) the geometric join
  mask at radius ``r_k`` stays a *superset* of the weighted-joining pairs —
  no kernel or backend changes — and (b) Lemma 2's termination test remains
  sound (a candidate with cost below the scale bound has geometric diameter
  below it too, hence was contained in some explored bucket).

* **scored top-k**: rank by ``score = coverage / (1 + alpha * cost)`` where
  ``coverage`` is the summed weight of the query keywords the group covers
  and ``cost`` the weighted diameter — tighter and better-covering groups
  both win. :class:`~repro.core.types.ScoredTopK` duck-types ``TopK`` and
  converts the k-th score back into a *cost* pruning bound, so every
  existing ``kth_diameter``-driven prune and the Lemma-2 termination keep
  working unchanged.

The canonical weighted arithmetic — multiply *squared* float64 distances by
the weight product, then ``sqrt`` of the max — is shared by the brute-force
oracle, the vectorized frontier, and the recursion fallback, so differential
suites compare like with like.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Sequence

import numpy as np

from repro.core.types import KeywordDataset, ScoredTopK, TopK

# Hard cap on subqueries per original query: k-choose-m explodes for long
# queries with small m; past this the request is a planning DoS, not a
# search. NKS queries are short (the paper sweeps q <= 9), so the cap is
# far above any legitimate expansion.
MAX_SUBQUERIES = 512

_ALLOWED_KEYS = frozenset(("m", "weights", "score", "alpha"))


@dataclasses.dataclass(frozen=True)
class QuerySemantics:
    """The request-level semantics knobs, validated at construction.

    ``m`` — minimum query keywords a result must cover (None = all of them).
    ``weights`` — keyword id -> weight, every weight >= 1 (boost semantics).
    ``score`` — rank by blended score instead of pure cost.
    ``alpha`` — the score's cost-sensitivity (> 0); ignored unless ``score``.
    """

    m: int | None = None
    weights: dict[int, float] | None = None
    score: bool = False
    alpha: float = 1.0

    def __post_init__(self):
        if self.m is not None and (not isinstance(self.m, int)
                                   or isinstance(self.m, bool) or self.m < 1):
            raise ValueError(f"semantics.m must be a positive int, got {self.m!r}")
        if self.weights is not None:
            for kw, w in self.weights.items():
                if not np.isfinite(w) or w < 1.0:
                    raise ValueError(
                        f"keyword weight must be a finite value >= 1 "
                        f"(boost semantics), got {kw}^{w}")
        if not (np.isfinite(self.alpha) and self.alpha > 0):
            raise ValueError(f"semantics.alpha must be > 0, got {self.alpha}")

    # ------------------------------------------------------------- coercion
    @classmethod
    def coerce(cls, obj) -> "QuerySemantics | None":
        """None / QuerySemantics / JSON-dict -> validated QuerySemantics.

        The dict form is the wire shape the runtime and launcher speak:
        ``{"m": 2, "weights": {"3": 4.0}, "score": true, "alpha": 0.5}``
        (JSON object keys are strings; they coerce to keyword ids here).
        """
        if obj is None or isinstance(obj, cls):
            return obj
        if not isinstance(obj, dict):
            raise ValueError(f"semantics must be a dict or QuerySemantics, "
                             f"got {type(obj).__name__}")
        unknown = set(obj) - _ALLOWED_KEYS
        if unknown:
            raise ValueError(f"unknown semantics key(s): {sorted(unknown)}")
        weights = obj.get("weights")
        if weights is not None:
            weights = {int(kw): float(w) for kw, w in weights.items()}
        m = obj.get("m")
        return cls(m=int(m) if m is not None else None, weights=weights,
                   score=bool(obj.get("score", False)),
                   alpha=float(obj.get("alpha", 1.0)))

    def canonical_key(self) -> str:
        """Deterministic string form — the runtime's batch-coalescing key
        component (requests may only share a ``query_batch`` call when their
        semantics agree)."""
        w = sorted((self.weights or {}).items())
        return f"m={self.m};w={w};s={self.score};a={self.alpha}"

    def resolve_keywords(self, mapper: Callable[[int], int]) -> "QuerySemantics":
        """Map weight keys through a keyword-id translation (tenant-local ->
        global dictionary slots, same convention as query keywords)."""
        if not self.weights:
            return self
        return dataclasses.replace(
            self, weights={int(mapper(kw)): w
                           for kw, w in self.weights.items()})

    # ----------------------------------------------------------- degeneracy
    def trivial_for(self, query: Sequence[int]) -> bool:
        """True when these semantics cannot change the classic answer for
        ``query``: full coverage required, no non-unit weight touches the
        query, no scoring. Validates ``m`` against the query length."""
        q = [int(v) for v in query]
        if self.m is not None and self.m > len(q):
            raise ValueError(
                f"semantics.m={self.m} exceeds the query's {len(q)} keywords")
        if self.score:
            return False
        if self.m is not None and self.m < len(q):
            return False
        w = self.weights or {}
        return all(float(w.get(v, 1.0)) == 1.0 for v in q)

    # ------------------------------------------------------------ expansion
    def expand_subqueries(self, query: Sequence[int]) -> list[list[int]]:
        """Every keyword subset S with ``m <= |S| <= |Q|``, largest first
        (the full query leads, so the degenerate expansion is ``[Q]``).
        Subset order only affects exploration order, never results: the
        shared queue's key is a total order on id sets."""
        q = sorted(set(int(v) for v in query))
        m = len(q) if self.m is None else int(self.m)
        if not 1 <= m <= len(q):
            raise ValueError(
                f"semantics.m={m} out of range for a {len(q)}-keyword query")
        # closed-form count guards the cap before materialising anything
        total = sum(_n_choose(len(q), size) for size in range(m, len(q) + 1))
        if total > MAX_SUBQUERIES:
            raise ValueError(
                f"semantics.m={m} expands a {len(q)}-keyword query into "
                f"{total} subqueries (cap {MAX_SUBQUERIES}); raise m")
        out: list[list[int]] = []
        for size in range(len(q), m - 1, -1):
            out.extend(list(c) for c in itertools.combinations(q, size))
        return out

    # -------------------------------------------------------------- weights
    def weight_vector(self, dataset: KeywordDataset,
                      query: Sequence[int]) -> np.ndarray | None:
        """(N,) float64 per-point weights for ``query``, or None when every
        relevant weight is 1 (the caller then skips weighting entirely —
        the unweighted hot path stays bit-identical).

        ``w(p) = max{ weight(v) : v in kw(p) ∩ Q }`` — set-determined, so a
        candidate's cost depends only on its id set and the query, never on
        which subquery enumerated it (id-set dedup stays sound)."""
        w = self.weights or {}
        boosted = [(int(v), float(w[v])) for v in query
                   if float(w.get(v, 1.0)) != 1.0]
        if not boosted:
            return None
        wvec = np.ones(dataset.n, dtype=np.float64)
        for v, wv in boosted:
            rows = dataset.ikp.row(v)
            wvec[rows] = np.maximum(wvec[rows], wv)
        return wvec

    def total_weight(self, query: Sequence[int]) -> float:
        w = self.weights or {}
        return float(sum(float(w.get(int(v), 1.0)) for v in query))

    def coverage_fn(self, dataset: KeywordDataset,
                    query: Sequence[int]) -> Callable[[Sequence[int]], float]:
        """ids -> summed weight of the query keywords the group covers (the
        scored mode's numerator)."""
        qset = {int(v) for v in query}
        w = self.weights or {}

        def cov(ids: Sequence[int]) -> float:
            covered: set[int] = set()
            for p in ids:
                covered.update(
                    v for v in (int(x) for x in dataset.kw.row(int(p)))
                    if v in qset)
            return float(sum(float(w.get(v, 1.0)) for v in covered))

        return cov

    # ------------------------------------------------------------------ pq
    def make_pq(self, dataset: KeywordDataset, query: Sequence[int],
                k: int, init_full: bool) -> "TopK | ScoredTopK":
        """The per-query result queue: classic ``TopK`` unless scoring.
        Flex queues are tie-open: m-of-k coverage admits equal-cost
        candidates (cost-0 singletons especially), which the strict
        enumeration gates must let through to the key-based tie-break."""
        if not self.score:
            return TopK(k, init_full=init_full, tie_open=True)
        return ScoredTopK(k, total_weight=self.total_weight(query),
                          alpha=self.alpha,
                          coverage=self.coverage_fn(dataset, query),
                          init_full=init_full)


def _n_choose(n: int, r: int) -> int:
    out = 1
    for i in range(r):
        out = out * (n - i) // (i + 1)
    return out


def weighted_pair_sq(d2: np.ndarray, w: np.ndarray) -> np.ndarray:
    """Canonical weighting: squared distances times the pair's weight
    product. Shared by the oracle's scan and the fast path's float64 tables
    so both sides of every differential suite run identical arithmetic."""
    return d2 * (w[:, None] * w[None, :])


def parse_weighted_keywords(raw: Sequence) -> tuple[list[int], dict[int, float]]:
    """The launcher's weight grammar: each ``keywords`` entry is either a
    keyword id or a ``"<id>^<weight>"`` boost string (the ``title^4``
    idiom). Returns (keyword ids, weights for the boosted ones).

        ["3", "7^4", 12]  ->  ([3, 7, 12], {7: 4.0})
    """
    kws: list[int] = []
    weights: dict[int, float] = {}
    for entry in raw:
        if isinstance(entry, str) and "^" in entry:
            kw_s, _, w_s = entry.partition("^")
            kw = int(kw_s)
            weights[kw] = float(w_s)
        else:
            kw = int(entry)
        kws.append(kw)
    return kws, weights
