"""The device plane: one mesh/placement layer for every serving tier.

Before this layer existed the repo had two parallel universes: the batched
bitmask-join pipeline (``core.backend``) dispatched every packed (S, P, d)
bin on a single device, while the shard_map anchor-star tier lived alone in
``core.distributed`` behind a separate engine code path. :class:`DevicePlane`
makes multi-device execution a property of the backend instead:

  * **mesh acquisition** — a plane wraps a jax mesh (``launch.mesh``
    constructors, ``REPRO_MESH_OVERRIDE`` honored) and exposes the serving
    axis contract: the ``data`` axis shards subsets/groups; ``model`` is
    unused by serving.
  * **sharded batched join** — :meth:`join_batched_masked` runs the packed
    masked self-join as a ``shard_map`` over ``data``: each shard computes
    its (S/n, P, d) slab locally through the same lowering as the
    single-device path (``kernels.ops.join_batched_masked_local`` — Mosaic
    on TPU, XLA elsewhere), packed bitmasks + join counts gather back on
    readback. The join is embarrassingly parallel over S, so the per-shard
    math is *identical* to the single-device dispatch and the bitmasks are
    bit-exact (the parity suite asserts this).
  * **group/tile packing** — :func:`pack_groups` (moved here from
    ``core.distributed``) pads keyword groups to an MXU/shard-aligned (q, R,
    d) block and now reports truncation instead of silently dropping points.
  * **replicated top-k merge** — :func:`replicated_topk_merge` is the
    phase-C collective every sharded tier ends on; ``nks_topk`` rebuilds the
    anchor-star tier (``distributed_nks_topk``) on it.

``PallasBackend(plane=...)`` routes size-binned dispatches here when a bin
packs at least one subset per shard; remainder bins (S < mesh size) fall
back to its single-device dispatch. ``serve.engine.NKSEngine(mesh=...)``
builds the plane once and threads it through all three tiers.

Corpus generations (streaming ingest): the plane's jit program caches
(``_join_fns``/``_nks_fns``) are keyed on *shapes and tile params only* —
they hold compiled programs, never corpus data, so they survive delta
absorbs and compactions untouched. Corpus-dependent state (packed subset
rows, device-committed tiles) lives in the backend's LRU, which the engine
scopes to its ``corpus_generation`` token: absorbs retain entries, a
compaction (id remap) purges them. Nothing on the plane needs invalidation
when the corpus changes.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass
class PackedGroups:
    """Padded (q, R, d) group tensor + mask + ids for one query.

    Iterates as the classic ``(groups, mask, ids)`` triple so existing
    callers keep unpacking it; ``truncated`` counts relevant points silently
    dropped because a keyword group exceeded ``r_max`` (0 when every group
    fit), and ``group_sizes`` records the pre-truncation group sizes.
    """

    groups: np.ndarray          # (q, R, d) float32
    mask: np.ndarray            # (q, R) bool
    ids: np.ndarray             # (q, R) int32
    truncated: int
    group_sizes: list[int]

    def __iter__(self):
        return iter((self.groups, self.mask, self.ids))


def pack_groups(dataset, query, r_max: int | None = None, *,
                strict: bool = False, align: int = 128,
                eligible: np.ndarray | None = None) -> PackedGroups:
    """Host packing of per-keyword relevant groups for the device tiers.

    R defaults to the largest group size rounded up to ``align`` (128 = MXU
    lane alignment; planes round it up further to a shard multiple). A group
    larger than an explicit ``r_max`` is truncated to the first ``r_max``
    points — counted in ``PackedGroups.truncated`` and fatal under
    ``strict=True`` (candidates containing a dropped point are unreachable,
    so a strict caller wants the signal, not a quietly degraded answer).
    ``eligible`` (a filtered query's (N,) point mask) restricts each group
    before packing, so the anchor-star tier never ships an ineligible point.
    """
    groups = [dataset.points_with(v) for v in query]
    if eligible is not None:
        groups = [g[eligible[g]] for g in groups]
    sizes = [len(g) for g in groups]
    if r_max is None:
        r_max = max(align, int(np.ceil(max(max(sizes), 1) / align)) * align)
    truncated = sum(max(s - r_max, 0) for s in sizes)
    if strict and truncated:
        raise ValueError(
            f"pack_groups: {truncated} relevant points truncated beyond "
            f"r_max={r_max} (group sizes {sizes}); raise r_max or drop strict")
    q = len(query)
    out = np.zeros((q, r_max, dataset.dim), np.float32)
    mask = np.zeros((q, r_max), bool)
    ids = np.zeros((q, r_max), np.int32)
    for j, g in enumerate(groups):
        g = g[:r_max]
        out[j, :len(g)] = dataset.points[g]
        mask[j, :len(g)] = True
        ids[j, :len(g)] = g
    return PackedGroups(out, mask, ids, truncated, sizes)


def replicated_topk_merge(axis: str, diams, cand_ids, k: int):
    """Phase-C collective: merge per-shard top-k into a replicated global one.

    ``diams`` (k,) ascending per shard, ``cand_ids`` (k, q). all_gathers both
    over ``axis`` and re-selects the k smallest — every shard returns the
    identical merged (diams (k,), ids (k, q))."""
    d_all = jax.lax.all_gather(diams, axis, tiled=True)            # (n*k,)
    c_all = jax.lax.all_gather(cand_ids, axis, axis=0, tiled=True)  # (n*k, q)
    neg, sel = jax.lax.top_k(-d_all, k)
    return -neg, c_all[sel]


def balance_order(lengths: np.ndarray, n_shards: int) -> np.ndarray:
    """Work-levelling shard placement: a permutation of ``range(len(lengths))``
    that deals subsets round-robin in descending size order.

    The plane assigns shard i the contiguous slab [i*S/n, (i+1)*S/n), so a
    length-sorted batch (the size-binned packer emits near-sorted bins) piles
    the big subsets onto the first shards. Dealing the descending sort
    across the n slabs in boustrophedon (snake) order — forward on even
    passes, backward on odd — pairs each shard's large draws with small
    ones, keeping slab work sums within one subset of each other (plain
    round-robin systematically favours low shard ids). The sort key is the *packed* work
    proxy (valid length; eligible counts when a filter packs eligible-dense),
    not the pruning radius: placement must stay radius-independent because
    committed tiles are reused across radii, so the ISSUE's "radius-sorted"
    placement is realised as size-sorted — the quantity that actually sets
    per-shard join cost. ``len(lengths)`` must be a shard multiple (callers
    pad first); returns ``perm`` such that ``x[perm]`` is the levelled order
    and ``out[np.argsort(perm)]`` restores dispatch order on readback.
    """
    s = len(lengths)
    assert s % n_shards == 0, (s, n_shards)
    order = np.argsort(-np.asarray(lengths, np.int64), kind="stable")
    ranks = order.reshape(-1, n_shards).copy()   # row = one dealing pass
    ranks[1::2] = ranks[1::2, ::-1]              # snake: reverse odd passes
    # shard i's contiguous slab = column i across passes
    return np.ascontiguousarray(ranks.T).reshape(-1)


class DevicePlane:
    """One mesh + the serving-axis contract, shared by every sharded tier."""

    def __init__(self, mesh: Mesh | None = None, *, axis: str = "data"):
        if mesh is None:
            from repro.launch.mesh import make_serving_mesh
            mesh = make_serving_mesh()
        if axis not in mesh.axis_names:
            raise ValueError(f"mesh has no {axis!r} axis: {mesh.axis_names}")
        self.mesh = mesh
        self.axis = axis
        self._join_fns: dict[tuple, object] = {}
        self._nks_fns: dict[tuple, object] = {}

    @property
    def n_shards(self) -> int:
        return int(self.mesh.shape[self.axis])

    def shard_pad(self, n: int) -> int:
        """Round ``n`` up to a multiple of the shard count (shard_map needs
        the sharded axis evenly divisible)."""
        s = self.n_shards
        return ((n + s - 1) // s) * s

    def sharding(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    # ------------------------------------------------------------ sharded join
    def _join_fn(self, bm: int, bn: int, impl: str | None,
                 interpret: bool | None, with_elig: bool):
        key = (bm, bn, impl, interpret, with_elig)
        fn = self._join_fns.get(key)
        if fn is None:
            from repro.kernels import ops
            ax = self.axis

            if with_elig:
                def body(x_loc, len_loc, r_loc, e_loc):
                    return ops.join_batched_masked_local(
                        x_loc, len_loc, r_loc, e_loc, bm=bm, bn=bn,
                        impl=impl, interpret=interpret)
            else:
                def body(x_loc, len_loc, r_loc):
                    return ops.join_batched_masked_local(
                        x_loc, len_loc, r_loc, bm=bm, bn=bn,
                        impl=impl, interpret=interpret)

            n_in = 4 if with_elig else 3
            sharded = shard_map(body, mesh=self.mesh,
                                in_specs=(P(ax),) * n_in,
                                out_specs=(P(ax), P(ax)),
                                check_rep=False)
            fn = jax.jit(sharded,
                         in_shardings=(self.sharding(P(ax)),) * n_in)
            self._join_fns[key] = fn
        return fn

    def join_batched_masked(self, x, lengths, r, elig=None, *, bm: int = 128,
                            bn: int = 128, impl: str | None = None,
                            interpret: bool | None = None):
        """Sharded masked batched self-join: (S, P, d) sharded on S over the
        ``data`` axis, one local join per shard, no cross-shard collectives.

        Returns (mask (S, P, ceil(P/32)) uint32, counts (S,) int32) with the
        same contract as ``ops.pairwise_l2_join_batched_masked`` — including
        the optional packed per-subset eligibility words ``elig``
        ((S, ceil(P/32)) uint32), sharded on S like everything else: each
        shard folds eligibility into its local slab's mask, so filtered
        dispatches stay bit-exact with the single-device route. S must be a
        multiple of :attr:`n_shards` (callers pad with zero-length subsets,
        which produce all-zero mask rows and zero counts)."""
        s = x.shape[0]
        if s % self.n_shards:
            raise ValueError(
                f"sharded join needs S % n_shards == 0, got S={s} over "
                f"{self.n_shards} shards (pad with zero-length subsets)")
        fn = self._join_fn(bm, bn, impl, interpret, elig is not None)
        if elig is None:
            return fn(x, lengths, r)
        return fn(x, lengths, r, elig)

    def _counts_fn(self, dtype: str, bm: int, bn: int, impl: str | None,
                   interpret: bool | None, with_elig: bool):
        key = ("counts", dtype, bm, bn, impl, interpret, with_elig)
        fn = self._join_fns.get(key)
        if fn is None:
            from repro.kernels import ops
            ax = self.axis

            if with_elig:
                def body(x_loc, len_loc, r_loc, e_loc):
                    return ops.join_batched_counts_local(
                        x_loc, len_loc, r_loc, e_loc, dtype=dtype, bm=bm,
                        bn=bn, impl=impl, interpret=interpret)
            else:
                def body(x_loc, len_loc, r_loc):
                    return ops.join_batched_counts_local(
                        x_loc, len_loc, r_loc, dtype=dtype, bm=bm, bn=bn,
                        impl=impl, interpret=interpret)

            n_in = 4 if with_elig else 3
            sharded = shard_map(body, mesh=self.mesh,
                                in_specs=(P(ax),) * n_in,
                                out_specs=P(ax),
                                check_rep=False)
            fn = jax.jit(sharded,
                         in_shardings=(self.sharding(P(ax)),) * n_in)
            self._join_fns[key] = fn
        return fn

    def join_batched_counts(self, x, lengths, r, elig=None, *,
                            dtype: str = "bf16", bm: int = 128, bn: int = 128,
                            impl: str | None = None,
                            interpret: bool | None = None):
        """Sharded coarse prune-tier counts: the cascade's tier 0 on the
        plane. Same sharding contract as :meth:`join_batched_masked` — S
        sharded over ``data``, one local counts pass per shard, no
        collectives — but the readback is S int32 words instead of the packed
        mask, so the prune decision costs almost no D2H. ``elig`` uses the
        packed uint32 word layout."""
        s = x.shape[0]
        if s % self.n_shards:
            raise ValueError(
                f"sharded counts need S % n_shards == 0, got S={s} over "
                f"{self.n_shards} shards (pad with zero-length subsets)")
        fn = self._counts_fn(dtype, bm, bn, impl, interpret, elig is not None)
        if elig is None:
            return fn(x, lengths, r)
        return fn(x, lengths, r, elig)

    def put_sharded(self, *arrays):
        """Commit host arrays to the mesh, sharded on dim 0 over ``data``."""
        sh = self.sharding(P(self.axis))
        return tuple(jax.device_put(a, sh) for a in arrays)

    def shard_cells(self, lengths: np.ndarray, p_pad: int
                    ) -> tuple[list[int], list[int]]:
        """Per-shard (valid, total) join-block cell counts for one dispatch.

        ``lengths`` is the padded (S,) valid-point vector the dispatch
        shipped; shard i owns the contiguous slab [i*S/n, (i+1)*S/n). Valid
        cells are sum(len^2) over the slab, total is slab * P^2 — the
        utilisation ratio the stats report per shard."""
        n = self.n_shards
        per = len(lengths) // n
        lens = np.asarray(lengths, np.int64)
        valid = [int((lens[i * per:(i + 1) * per] ** 2).sum())
                 for i in range(n)]
        total = [per * p_pad * p_pad] * n
        return valid, total

    # --------------------------------------------------------- anchor-star tier
    def _nks_fn(self, k: int):
        fn = self._nks_fns.get(k)
        if fn is None:
            from repro.core.distributed import nks_anchor_topk
            ax = self.axis

            def body(g_loc, m_loc, i_loc):
                # phase A: gather the full relevant set (small by eq. 4
                # selectivity); phase B: anchors stay partitioned — each
                # shard scores its local slice of group 0.
                g_all = jax.lax.all_gather(g_loc, ax, axis=1, tiled=True)
                m_all = jax.lax.all_gather(m_loc, ax, axis=1, tiled=True)
                i_all = jax.lax.all_gather(i_loc, ax, axis=1, tiled=True)
                diams, cids = nks_anchor_topk(
                    g_all, m_all, i_all, k,
                    anchors=g_loc[0], anchor_mask=m_loc[0],
                    anchor_ids=i_loc[0])
                # phase C: replicated global top-k
                return replicated_topk_merge(ax, diams, cids, k)

            spec_in = P(None, self.axis, None)
            fn = jax.jit(shard_map(body, mesh=self.mesh,
                                   in_specs=(spec_in, P(None, self.axis),
                                             P(None, self.axis)),
                                   out_specs=(P(), P()),
                                   check_rep=False))
            self._nks_fns[k] = fn
        return fn

    def nks_topk(self, groups, mask, ids, k: int):
        """Anchor-star NKS top-k over the plane: ``groups`` (q, R, d) sharded
        on R over ``data``; returns (diams (k,), ids (k, q)) replicated."""
        if groups.shape[1] % self.n_shards:
            raise ValueError(
                f"nks_topk needs R % n_shards == 0, got R={groups.shape[1]} "
                f"over {self.n_shards} shards (pack with a shard-aligned r_max)")
        return self._nks_fn(k)(groups, mask, ids)

    def pack_groups(self, dataset, query, r_max: int | None = None, *,
                    strict: bool = False,
                    eligible: np.ndarray | None = None) -> PackedGroups:
        """:func:`pack_groups` with R rounded up to a shard multiple so the
        result feeds :meth:`nks_topk` directly."""
        pg = pack_groups(dataset, query, r_max, strict=strict,
                         eligible=eligible)
        r_pad = self.shard_pad(pg.groups.shape[1])
        if r_pad != pg.groups.shape[1]:
            extra = r_pad - pg.groups.shape[1]
            pg = PackedGroups(
                np.pad(pg.groups, ((0, 0), (0, extra), (0, 0))),
                np.pad(pg.mask, ((0, 0), (0, extra))),
                np.pad(pg.ids, ((0, 0), (0, extra))),
                pg.truncated, pg.group_sizes)
        return pg


def get_plane(mesh=None, *, axis: str = "data") -> DevicePlane:
    """Resolve a plane spec: an existing plane, a jax Mesh, or None/"auto"
    (acquire the serving mesh from the environment)."""
    if isinstance(mesh, DevicePlane):
        return mesh
    if mesh is None or mesh == "auto":
        return DevicePlane(axis=axis)
    return DevicePlane(mesh, axis=axis)
