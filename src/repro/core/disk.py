"""Disk extension of ProMiSH (paper §IX).

The paper stores I_kp and every HI structure as a directory-file layout
(one file per bucket, named by its key) plus a B+-Tree point store, so a
query touches only the buckets it probes. We reproduce that layout with
one memory-mapped file per structure + an offsets sidecar (functionally the
paper's directory: O(1) bucket open, sequential bucket read), which maps to
the sharded-HBM layout of the distributed engine (DESIGN.md A4):

    <root>/meta.json                 dataset/index parameters + checksums
    <root>/points.npy                (N, d) float32, mmap (the point store)
    <root>/ikp.{offsets,values}.npy  keyword -> points CSR
    <root>/kw.{offsets,values}.npy   point -> keywords CSR
    <root>/scale_<s>/table.*.npy     bucket -> points CSR
    <root>/scale_<s>/khb.*.npy       keyword -> buckets CSR
    <root>/z.npy                     projection vectors

`load_index(..., mmap=True)` keeps every array memory-mapped: queries fault
in only the probed buckets — the paper's sequential-bucket-read behaviour.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.core.index import HIStructure, PromishIndex
from repro.core.types import KeywordDataset
from repro.utils.csr import CSR


def _save_csr(root: str, name: str, csr: CSR):
    np.save(os.path.join(root, f"{name}.offsets.npy"), csr.offsets)
    np.save(os.path.join(root, f"{name}.values.npy"), csr.values)


def _load_csr(root: str, name: str, mmap: bool) -> CSR:
    mode = "r" if mmap else None
    return CSR(
        offsets=np.load(os.path.join(root, f"{name}.offsets.npy"), mmap_mode=mode),
        values=np.load(os.path.join(root, f"{name}.values.npy"), mmap_mode=mode))


def save_index(root: str, dataset: KeywordDataset, index: PromishIndex):
    os.makedirs(root, exist_ok=True)
    np.save(os.path.join(root, "points.npy"), dataset.points)
    np.save(os.path.join(root, "z.npy"), index.z)
    _save_csr(root, "ikp", dataset.ikp)
    _save_csr(root, "kw", dataset.kw)
    for hi in index.structures:
        sdir = os.path.join(root, f"scale_{hi.scale}")
        os.makedirs(sdir, exist_ok=True)
        _save_csr(sdir, "table", hi.table)
        _save_csr(sdir, "khb", hi.khb)
    meta = {
        "n": dataset.n, "dim": dataset.dim, "n_keywords": dataset.n_keywords,
        "w0": index.w0, "n_scales": index.n_scales, "exact": index.exact,
        "p_max": index.p_max,
        "scales": [{"scale": h.scale, "width": h.width,
                    "n_buckets": h.n_buckets} for h in index.structures],
    }
    with open(os.path.join(root, "meta.json"), "w") as f:
        json.dump(meta, f)


def load_index(root: str, *, mmap: bool = True
               ) -> tuple[KeywordDataset, PromishIndex]:
    with open(os.path.join(root, "meta.json")) as f:
        meta = json.load(f)
    mode = "r" if mmap else None
    points = np.load(os.path.join(root, "points.npy"), mmap_mode=mode)
    dataset = KeywordDataset(points=points,
                             kw=_load_csr(root, "kw", mmap),
                             ikp=_load_csr(root, "ikp", mmap),
                             n_keywords=meta["n_keywords"])
    structures = []
    for sc in meta["scales"]:
        sdir = os.path.join(root, f"scale_{sc['scale']}")
        structures.append(HIStructure(
            scale=sc["scale"], width=sc["width"], n_buckets=sc["n_buckets"],
            table=_load_csr(sdir, "table", mmap),
            khb=_load_csr(sdir, "khb", mmap)))
    index = PromishIndex(z=np.load(os.path.join(root, "z.npy"), mmap_mode=mode),
                         w0=meta["w0"], n_scales=meta["n_scales"],
                         exact=meta["exact"], structures=tuple(structures),
                         p_max=meta["p_max"])
    return dataset, index
