"""Brute-force NKS oracle — exhaustive enumeration of all minimal candidates.

Ground truth for correctness tests and for the paper's quality metrics
(AAR denominators, Table II's N_n). Exponential in q; use on small data only.

Every entry point takes an optional ``eligible`` (N,) bool mask — the
filtered-NKS oracle restricts per-keyword groups to eligible points, which is
*definitionally* the search over the filtered sub-corpus (every candidate is
a set of eligible points covering Q, minimality judged on keyword sets, which
filtering does not change). :func:`search_filtered` is the serving-shaped
wrapper: it evaluates a ``core.filters.Filter`` (predicates + tenant scoping)
into the mask first, so differential suites can drive the oracle with the
exact filter object the engine receives.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import TYPE_CHECKING, Sequence

import numpy as np

from repro.core import semantics as semantics_mod
from repro.core.subset_search import is_minimal_candidate, pairwise_l2_numpy
from repro.core.types import Candidate, KeywordDataset, TopK

if TYPE_CHECKING:
    from repro.core.semantics import QuerySemantics


def set_diameter(ids: Sequence[int], dataset: KeywordDataset) -> float:
    ids = list(ids)
    if len(ids) <= 1:
        return 0.0
    pts = dataset.points[np.asarray(ids)]
    return float(pairwise_l2_numpy(pts, pts).max())


def _query_groups(dataset: KeywordDataset, query: Sequence[int],
                  eligible: np.ndarray | None) -> list[np.ndarray]:
    """Per-keyword candidate groups, restricted to eligible points."""
    groups = [dataset.ikp.row(v) for v in query]
    if eligible is not None:
        groups = [g[eligible[g]] for g in groups]
    return groups


def enumerate_candidates(dataset: KeywordDataset, query: Sequence[int],
                         eligible: np.ndarray | None = None):
    """Yield every distinct minimal candidate set (as a sorted id tuple)."""
    query = sorted(set(int(v) for v in query))
    groups = _query_groups(dataset, query, eligible)
    if any(len(g) == 0 for g in groups):
        return
    seen: set[tuple[int, ...]] = set()
    for combo in itertools.product(*groups):
        ids = tuple(sorted(set(int(c) for c in combo)))
        if ids in seen:
            continue
        seen.add(ids)
        if is_minimal_candidate(ids, query, dataset):
            yield ids


def search(dataset: KeywordDataset, query: Sequence[int], k: int = 1,
           chunk: int = 250_000, max_tuples: float = 5e7,
           eligible: np.ndarray | None = None) -> TopK:
    """Exact top-k by full enumeration (vectorised).

    Enumerates the full cartesian product of per-keyword groups, computes all
    tuple diameters in chunked numpy, then scans tuples in diameter order
    applying the dedup + minimality filters until the top-k is stable. Any
    minimal candidate arises from at least one tuple with equal diameter, so
    the scan is exhaustive.

    ``eligible`` restricts the per-keyword groups before the product — the
    filtered oracle is the unfiltered oracle over the eligible sub-corpus.
    Refuses instances beyond ``max_tuples`` (the oracle is exponential in q
    by design — use ProMiSH-E as ground truth at scale, as the paper does).
    """
    query = sorted(set(int(v) for v in query))
    groups = _query_groups(dataset, query, eligible)
    if any(len(g) == 0 for g in groups):
        return TopK(k, init_full=True)
    total_est = 1.0
    for g in groups:
        total_est *= len(g)
    if total_est > max_tuples:
        raise ValueError(
            f"brute-force oracle infeasible: {total_est:.2e} tuples "
            f"(> {max_tuples:.0e}); use promish_e as ground truth")
    grids = np.meshgrid(*groups, indexing="ij")
    tuples = np.stack([g.ravel() for g in grids], axis=1).astype(np.int64)  # (T, q)
    t_total = len(tuples)
    diams = np.empty(t_total, dtype=np.float32)
    pts = dataset.points
    for lo in range(0, t_total, chunk):
        x = pts[tuples[lo:lo + chunk]].astype(np.float64)    # (C, q, d)
        diff = x[:, :, None, :] - x[:, None, :, :]
        sq = np.einsum("cijd,cijd->cij", diff, diff)
        diams[lo:lo + chunk] = np.sqrt(np.maximum(sq, 0.0)).max(axis=(1, 2))

    pq = TopK(k, init_full=True)
    order = np.argsort(diams, kind="stable")
    for idx in order:
        d = float(diams[idx])
        if pq.full() and d > pq.kth_diameter():
            break
        ids = tuple(sorted(set(int(p) for p in tuples[idx])))
        if is_minimal_candidate(ids, query, dataset):
            pq.offer(Candidate(ids=ids, diameter=d))
    return pq


def search_filtered(dataset: KeywordDataset, query: Sequence[int],
                    flt, k: int = 1, **kw) -> TopK:
    """Filtered/tenant-scoped oracle: evaluate a ``core.filters.Filter`` into
    the eligibility mask, resolve tenant-local keywords through the corpus
    namespace when the filter is tenant-scoped, and run :func:`search` over
    the eligible sub-corpus — the differential ground truth for the engine's
    ``query_batch(..., filter=...)`` path."""
    from repro.core.filters import Filter
    flt = Filter.coerce(flt)
    if flt is None:
        return search(dataset, query, k=k, **kw)
    if flt.tenant is not None and dataset.tenants is not None:
        query = dataset.tenants.resolve(flt.tenant, query)
    return search(dataset, query, k=k, eligible=flt.evaluate(dataset), **kw)


def count_candidates(dataset: KeywordDataset, query: Sequence[int],
                     eligible: np.ndarray | None = None) -> int:
    """N_n of eq. 4 (measured, not modelled)."""
    return sum(1 for _ in enumerate_candidates(dataset, query,
                                               eligible=eligible))


# ------------------------------------------------------- flexible semantics
def weighted_set_cost(ids: Sequence[int], dataset: KeywordDataset,
                      wvec: np.ndarray | None) -> float:
    """Weighted diameter of a group: ``max sqrt(d2(a,b) * w(a) * w(b))``.

    The canonical arithmetic (difference-based float64 squared distances,
    weight product applied to the *squared* table, sqrt of the max) matches
    the fast path's frontier tables exactly — with ``wvec=None`` this is the
    plain geometric diameter."""
    ids = [int(i) for i in ids]
    if len(ids) <= 1:
        return 0.0
    pts = dataset.points[np.asarray(ids)].astype(np.float64)
    diff = pts[:, None, :] - pts[None, :, :]
    d2 = np.einsum("ijd,ijd->ij", diff, diff)
    if wvec is not None:
        d2 = semantics_mod.weighted_pair_sq(d2, wvec[np.asarray(ids)])
    return float(np.sqrt(d2.max()))


def enumerate_candidates_flex(dataset: KeywordDataset, query: Sequence[int],
                              sem: "QuerySemantics",
                              eligible: np.ndarray | None = None):
    """The flexible candidate universe: every distinct id set that is a
    minimal candidate for *some* keyword subset ``S ⊆ Q`` with ``|S| >= m``
    (classic minimal candidates when ``m = |Q|``). Yields sorted id tuples,
    deduped across subqueries — cost and coverage depend only on (ids, Q),
    never on which subquery produced the set."""
    seen: set[tuple[int, ...]] = set()
    for sub in sem.expand_subqueries(query):
        for ids in enumerate_candidates(dataset, sub, eligible=eligible):
            if ids not in seen:
                seen.add(ids)
                yield ids


def search_flex(dataset: KeywordDataset, query: Sequence[int], k: int = 1,
                *, semantics=None, eligible: np.ndarray | None = None
                ) -> list[Candidate]:
    """Flexible-semantics oracle: exhaustive enumeration over the m-of-k
    candidate universe, weighted costs, optional scored ranking — the ground
    truth for every ``semantics=...`` differential suite. Returns the top-k
    as a plain candidate list (scored mode stamps ``Candidate.score``).

    Ranking matches the fast path's queues exactly: ``(cost, |ids|, ids)``
    ascending, or ``(-score, cost, |ids|, ids)`` in scored mode. With
    degenerate semantics (``m = |Q|``, unit weights, no scoring) this
    reduces to :func:`search`'s result set by construction.
    """
    sem = semantics_mod.QuerySemantics.coerce(semantics) \
        or semantics_mod.QuerySemantics()
    query = sorted(set(int(v) for v in query))
    wvec = sem.weight_vector(dataset, query)
    cands = []
    for ids in enumerate_candidates_flex(dataset, query, sem,
                                         eligible=eligible):
        cands.append(Candidate(
            ids=ids, diameter=weighted_set_cost(ids, dataset, wvec)))
    if sem.score:
        cov = sem.coverage_fn(dataset, query)
        cands = [dataclasses.replace(
                     c, score=cov(c.ids) / (1.0 + sem.alpha * c.diameter))
                 for c in cands]
        cands.sort(key=lambda c: (-c.score, c.diameter, len(c.ids), c.ids))
    else:
        cands.sort(key=Candidate.key)
    return cands[:k]
