"""Distance backends for the subset-search pipeline.

The §V inner joins and Algorithm 4 predicates consume one dense self-distance
matrix per covering-bucket subset. This module routes that distance production:

  * :class:`NumpyBackend` — float64 on the control plane; distances are exact,
    so enumeration needs no slack and no rescoring. One "dispatch" per subset
    (the per-query loop the paper measures).
  * :class:`PallasBackend` — packs every subset of a batch into one dense
    (S, P, d) tile block and issues **one** fused
    ``kernels.ops.pairwise_l2_join_batched`` dispatch, with per-subset radii
    riding in SMEM. fp32 on device is a *pruning filter*: each block carries an
    absolute distance slack bounding the fp32 cancellation error, and the
    enumeration stage re-scores surviving tuples through the float64 path
    before they enter the queue (see ``subset_search.enumerate_with_distances``).

Backends are deliberately jax-free at import time: the Pallas stack loads only
when a PallasBackend actually dispatches, keeping the numpy control plane
importable everywhere.
"""
from __future__ import annotations

import abc
import dataclasses
from typing import Sequence

import numpy as np

from repro.core.subset_search import pairwise_l2_numpy

_EPS32 = float(np.finfo(np.float32).eps)


@dataclasses.dataclass
class BackendStats:
    """Dispatch accounting for the pipeline stats (§VII-style instrumentation)."""

    dispatches: int = 0        # device/loop calls issued
    subsets: int = 0           # distance blocks produced
    points_packed: int = 0     # total valid points shipped
    points_padded: int = 0     # pad waste (packed tile points - valid points)
    join_pairs: int = 0        # threshold-join survivors across all subsets


@dataclasses.dataclass(frozen=True)
class DistanceBlock:
    """One subset's distances plus the contract needed to consume them.

    dist  : (n, n) pairwise L2 distances.
    slack : absolute distance error bound; enumeration prunes at r + slack.
    rescore : True when ``dist`` is approximate and accepted tuples must be
              re-scored in float64 before entering the top-k queue.
    join_count : #{pairs with dist <= r} at the requested radius (stats).
    """

    dist: np.ndarray
    slack: float
    rescore: bool
    join_count: int


class DistanceBackend(abc.ABC):
    """Produces per-subset self-distance blocks for the enumeration stage."""

    name: str = "abstract"

    def __init__(self) -> None:
        self.stats = BackendStats()

    @abc.abstractmethod
    def pairwise(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        """Dense (n, m) distance matrix for one pair of point sets."""

    @abc.abstractmethod
    def self_join_blocks(self, blocks: Sequence[np.ndarray],
                         radii: Sequence[float]) -> list[DistanceBlock]:
        """Self-distance blocks for a batch of subsets at per-subset radii."""


class NumpyBackend(DistanceBackend):
    """float64 control-plane backend: exact, loops subset by subset."""

    name = "numpy"

    def pairwise(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        self.stats.dispatches += 1
        return pairwise_l2_numpy(a, b)

    def self_join_blocks(self, blocks: Sequence[np.ndarray],
                         radii: Sequence[float]) -> list[DistanceBlock]:
        out = []
        for pts, r in zip(blocks, radii):
            dist = self.pairwise(pts, pts)
            count = int((dist <= r).sum()) if np.isfinite(r) else dist.size
            self.stats.subsets += 1
            self.stats.points_packed += len(pts)
            self.stats.join_pairs += count
            out.append(DistanceBlock(dist=dist, slack=0.0, rescore=False,
                                     join_count=count))
        return out


class PallasBackend(DistanceBackend):
    """Fused device backend: one batched threshold-join dispatch per call.

    Subset counts and pad widths are rounded up (``quantum``) so repeated
    scales reuse compiled programs instead of retracing per shape. A call
    whose packed (S, P, P) result block would exceed ``max_block_bytes``
    (the fallback stage can pack near-corpus-sized subsets for many queries
    at once) is split into size-bounded chunks — still one dispatch per
    chunk, and a single dispatch in the common per-scale case.
    """

    name = "pallas"

    def __init__(self, *, bm: int = 128, bn: int = 128,
                 interpret: bool | None = None, quantum: int = 8,
                 max_block_bytes: int = 256 << 20) -> None:
        super().__init__()
        self.bm = bm
        self.bn = bn
        self.interpret = interpret
        self.quantum = quantum
        self.max_block_bytes = max_block_bytes

    @staticmethod
    def _slack(pts: np.ndarray) -> float:
        """Absolute L2 error bound for the fp32 ||a||^2+||b||^2-2ab identity.

        The squared-distance error is dominated by cancellation at the
        squared-norm scale S: |err_sq| <= c*eps32*S with c a small constant
        times the reduction depth (the kernel tests bound the diagonal at
        32*eps*S). sqrt is monotone, so |err_dist| <= sqrt(err_sq); we take
        c = 64 + 4d for headroom across accumulation orders.
        """
        if pts.size == 0:
            return 0.0
        d = pts.shape[1]
        s_norm = float((pts.astype(np.float64) ** 2).sum(axis=1).max())
        return float(np.sqrt((64.0 + 4.0 * d) * _EPS32 * s_norm))

    def pairwise(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        from repro.kernels import ops
        self.stats.dispatches += 1
        sq, _ = ops.pairwise_l2_join(np.asarray(a, np.float32),
                                     np.asarray(b, np.float32),
                                     bm=self.bm, bn=self.bn,
                                     interpret=self.interpret)
        return np.sqrt(np.asarray(sq, np.float64))

    def _round(self, n: int) -> int:
        q = self.quantum
        return max(q, ((n + q - 1) // q) * q)

    def self_join_blocks(self, blocks: Sequence[np.ndarray],
                         radii: Sequence[float]) -> list[DistanceBlock]:
        if not blocks:
            return []
        # Chunk so one dispatch's padded fp32 sq output (S, P, P) stays under
        # the memory budget (order preserved; one chunk in the common case).
        budget = max(1, self.max_block_bytes // 4)
        out: list[DistanceBlock] = []
        start = 0
        while start < len(blocks):
            end = start + 1
            p_max = self._round(max(len(blocks[start]), 1))
            while end < len(blocks):
                p_new = max(p_max, self._round(len(blocks[end])))
                if self._round(end + 1 - start) * p_new * p_new > budget:
                    break
                p_max = p_new
                end += 1
            out.extend(self._dispatch(blocks[start:end], radii[start:end]))
            start = end
        return out

    def _dispatch(self, blocks: Sequence[np.ndarray],
                  radii: Sequence[float]) -> list[DistanceBlock]:
        from repro.kernels import ops
        n_subsets = len(blocks)
        d = blocks[0].shape[1]
        lengths = np.fromiter((len(b) for b in blocks), np.int32,
                              count=n_subsets)
        s_pad = self._round(n_subsets)
        p_pad = self._round(int(lengths.max()))
        x = np.zeros((s_pad, p_pad, d), np.float32)
        for i, pts in enumerate(blocks):
            x[i, : len(pts)] = pts
        lens_pad = np.zeros(s_pad, np.int32)
        lens_pad[:n_subsets] = lengths
        r = np.zeros(s_pad, np.float32)
        r[:n_subsets] = np.asarray(radii, np.float32)

        sq, cnt = ops.pairwise_l2_join_batched(x, lens_pad, r, bm=self.bm,
                                               bn=self.bn,
                                               interpret=self.interpret)
        sq = np.asarray(sq)
        counts = np.asarray(cnt).sum(axis=(1, 2))
        self.stats.dispatches += 1
        self.stats.subsets += n_subsets
        self.stats.points_packed += int(lengths.sum())
        self.stats.points_padded += s_pad * p_pad - int(lengths.sum())
        self.stats.join_pairs += int(counts[:n_subsets].sum())

        out = []
        for i, pts in enumerate(blocks):
            n = len(pts)
            dist = np.sqrt(sq[i, :n, :n].astype(np.float64))
            out.append(DistanceBlock(dist=dist, slack=self._slack(pts),
                                     rescore=True,
                                     join_count=int(counts[i])))
        return out


def get_backend(spec: str | DistanceBackend, **kw) -> DistanceBackend:
    """Resolve a backend name ("numpy" | "pallas") or pass an instance through."""
    if isinstance(spec, DistanceBackend):
        return spec
    if spec == "numpy":
        return NumpyBackend()
    if spec == "pallas":
        return PallasBackend(**kw)
    raise ValueError(f"unknown distance backend: {spec!r}")
