"""Distance backends for the subset-search pipeline.

The §V inner joins and Algorithm 4 predicates consume one *join structure*
per covering-bucket subset. This module routes that production:

  * :class:`NumpyBackend` — float64 on the control plane; distances are exact,
    so enumeration needs no slack and no rescoring. Emits dense distance
    blocks; the enumeration stage packs its own bitmask at the live r_k. One
    "dispatch" per subset (the per-query loop the paper measures).
  * :class:`PallasBackend` — packs every subset of a batch into one dense
    (S, P, d) tile block and issues **one** fused
    ``kernels.ops.pairwise_l2_join_batched_masked`` dispatch, with per-subset
    pruning radii riding in SMEM. The result shipped back to the host is the
    **packed adjacency bitmask** (S, P, ceil(P/32)) — a 32x smaller D2H
    readback than the dense fp32 block, which is no longer materialised on
    the host at all. fp32 on device is a *pruning filter*: the per-subset
    radius is widened by an absolute slack bounding fp32 cancellation error,
    and the enumeration stage re-scores surviving tuples through the float64
    path before they enter the queue (``subset_search.enumerate_with_block``).

The block contract (:class:`DistanceBlock`) carries either ``dist`` (dense
float64, numpy) or ``mask`` (packed uint32 at the dispatch-time pruning
radius, device), plus ``join_count`` — the kernel's inner-join cardinality,
which the enumeration stage uses to skip subsets whose join is empty before
any host work (the adaptive-radii feedback loop).

``PallasBackend`` keeps a byte-bounded LRU cache keyed on the Algorithm-2
subset hash (the sorted-id bytes): per-subset packed fp32 rows + slack, and
whole packed dispatch tiles already committed to the device — steady-state
repeated subsets skip gather, packing, and H2D entirely.

Backends are deliberately jax-free at import time: the device stack loads
only when a PallasBackend actually dispatches, keeping the numpy control
plane importable everywhere.
"""
from __future__ import annotations

import abc
import dataclasses
import time
from collections import OrderedDict
from typing import Sequence

import numpy as np

from repro.core.subset_search import (_sq_dists_f64, pack_join_mask,
                                      pairwise_l2_numpy)

_EPS32 = float(np.finfo(np.float32).eps)
_F32_MAX = float(np.finfo(np.float32).max)


@dataclasses.dataclass
class BackendStats:
    """Dispatch accounting for the pipeline stats (§VII-style instrumentation)."""

    dispatches: int = 0        # device/loop calls issued
    subsets: int = 0           # join blocks produced
    points_packed: int = 0     # total valid points shipped
    points_padded: int = 0     # pad waste (packed tile points - valid points)
    join_pairs: int = 0        # threshold-join survivors across all subsets
    t_pack_s: float = 0.0      # host time: gather + tile packing
    t_dispatch_s: float = 0.0  # device time: dispatch + D2H readback
    cache_hits: int = 0        # packed-subset/tile LRU hits
    cache_misses: int = 0
    cache_evictions: int = 0
    generation_purges: int = 0  # cache invalidations on corpus-generation bump
    # Transfer accounting (device backends): host->device bytes shipped
    # (tiles + lengths + radii + packed eligibility words) and device->host
    # bytes read back (packed masks + join counts). The filtered-NKS
    # contract — eligibility folds into the existing packed mask, adding no
    # new D2H — is asserted on these counters.
    h2d_bytes: int = 0
    d2h_bytes: int = 0
    # Sharded-dispatch accounting (populated when a DevicePlane routes the
    # dispatch over the mesh; lists are indexed by shard/device position on
    # the plane's data axis and sized lazily on first device dispatch).
    sharded_dispatches: int = 0            # dispatches routed via shard_map
    t_collective_s: float = 0.0            # wall inside sharded dispatches
    shard_dispatches: list = dataclasses.field(default_factory=list)
    shard_valid_cells: list = dataclasses.field(default_factory=list)
    shard_total_cells: list = dataclasses.field(default_factory=list)
    # Cascade / routing accounting (PallasBackend): the coarse mixed-precision
    # prune tier and the cost-model host route. ``t_prune_s`` and ``t_host_s``
    # are *components* of ``t_dispatch_s`` (the engine subtracts them out to
    # report the fp32 join share). ``bin_points`` maps each size-class edge to
    # cumulative (valid, padded) point totals packed under it.
    prune_tier_dispatches: int = 0         # coarse counts passes issued
    cells_pruned: int = 0                  # fp32 tile cells skipped via prune
    t_prune_s: float = 0.0                 # wall inside coarse counts passes
    host_routed_dispatches: int = 0        # bins routed to the host backend
    host_routed_subsets: int = 0           # subsets served by host routing
    t_host_s: float = 0.0                  # wall inside host-routed bins
    bin_points: dict = dataclasses.field(default_factory=dict)
    # Out-of-core accounting: bytes gathered out of a memory-mapped corpus
    # (the cold tier under the packed-row/tile LRU). Each counted gather is
    # an upper bound on the pages faulted in — rows already resident in the
    # page cache cost nothing at runtime but are still counted, so the
    # number reads as "bytes served from below the hot tier".
    cold_bytes_read: int = 0

    def ensure_shards(self, n: int) -> None:
        for lst in (self.shard_dispatches, self.shard_valid_cells,
                    self.shard_total_cells):
            lst.extend([0] * (n - len(lst)))


@dataclasses.dataclass(frozen=True)
class DistanceBlock:
    """One subset's join structure plus the contract needed to consume it.

    n          : number of valid points in the subset.
    dist       : (n, n) float64 pairwise L2 distances, or None for mask-only
                 device blocks.
    mask       : (n, ceil(n/32)) uint32 packed adjacency at the dispatch-time
                 pruning radius (bit j%32 of word j//32 set iff points i, j
                 join). None for dense blocks — and for device blocks whose
                 radius was infinite (every pair joins by construction; the
                 backend skips the dispatch and enumeration treats the
                 adjacency as all-ones).
    slack      : absolute distance error bound; dense approximate blocks are
                 pruned at r + slack (mask blocks bake it into the radius).
    rescore    : True when the block is approximate and accepted tuples must
                 be re-scored in float64 before entering the top-k queue.
    join_count : #{pairs joining at the pruning radius}, diagonal included —
                 ``join_count <= n`` proves the inner join empty, letting the
                 enumeration stage skip the subset (adaptive radii).
    n_eligible : number of subset points satisfying the query's predicate
                 mask, or None on an unfiltered call. When set, ``mask`` and
                 ``join_count`` cover eligible pairs only (the eligibility
                 fold), so the empty-join test becomes
                 ``join_count <= n_eligible``.
    rows       : eligible-dense packing (low-selectivity filtered dispatch):
                 sorted subset-local row positions actually packed into the
                 device tile. ``mask`` then covers only those rows — the
                 enumeration stage remaps its keyword groups into the packed
                 row space (``subset_search.enumerate_with_block``). None on
                 the standard full-subset pack.
    """

    n: int
    slack: float
    rescore: bool
    join_count: int
    dist: np.ndarray | None = None
    mask: np.ndarray | None = None
    n_eligible: int | None = None
    rows: np.ndarray | None = None


class DistanceBackend(abc.ABC):
    """Produces per-subset self-join blocks for the enumeration stage."""

    name: str = "abstract"

    def __init__(self) -> None:
        self.stats = BackendStats()

    def _note_cold_read(self, points: np.ndarray, n_rows: int) -> None:
        """Count a row gather against the cold tier when ``points`` is a
        memory-mapped store leaf (resident corpora cost nothing)."""
        if isinstance(points, np.memmap):
            self.stats.cold_bytes_read += \
                int(n_rows) * int(points.shape[1]) * points.itemsize

    @abc.abstractmethod
    def pairwise(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        """Dense (n, m) distance matrix for one pair of point sets."""

    @abc.abstractmethod
    def self_join_blocks(self, points: np.ndarray,
                         id_lists: Sequence[np.ndarray],
                         radii: Sequence[float],
                         keys: Sequence[bytes] | None = None,
                         generation: int | None = None,
                         eligible: np.ndarray | None = None
                         ) -> list[DistanceBlock]:
        """Self-join blocks for a batch of subsets at per-subset radii.

        ``points`` is the full corpus; each ``id_lists[i]`` selects one
        subset's rows (sorted unique ids). ``keys`` are the Algorithm-2
        subset hashes (sorted-id bytes) used as cache keys; pass None to
        bypass caching. ``generation`` is the caller's corpus-generation
        token: calls under the same token may share cache entries even if
        the ``points`` array object changed (streaming absorbs are
        append-only, so existing rows are immutable within a generation);
        a token change invalidates everything (compaction remapped ids).

        ``eligible`` is a filtered query's (N,) bool point mask: the emitted
        blocks scope their mask/counts to eligible pairs (``n_eligible``
        set), while subsets, cache keys, and packed tiles stay
        filter-independent — the same dispatch under a different filter
        reuses every cache entry and ships only fresh eligibility words."""


@dataclasses.dataclass(frozen=True)
class DispatchCostModel:
    """Measured crossover model for dispatch routing (calibrated at warmup).

    Costs are a two-point linear fit per route: a fixed per-dispatch term
    plus a per-join-cell term, probed at the corpus dimensionality the
    backend actually serves (so no cross-d extrapolation). ``prune_cell_s``
    is the coarse counts-pass cost per cell; the prune tier only pays off
    where the coarse gemm is genuinely cheaper than the fp32 one (the TPU
    MXU's double-rate bf16 path — on CPU/XLA there is no such discount, so
    ``prune_profitable`` is False off-TPU regardless of timings).
    """

    platform: str
    d: int
    dev_fixed_s: float     # per-dispatch overhead (trace/launch/readback)
    dev_cell_s: float      # fp32 masked join, per padded tile cell
    prune_cell_s: float    # coarse counts pass, per padded tile cell
    host_fixed_s: float    # numpy route, per subset
    host_cell_s: float     # numpy float64 join, per valid cell
    settle_cell_s: float = 0.0   # expected host f64 settlement of a device
    settle_fixed_s: float = 0.0  # block (unpack + table + expansion), per
    #                              valid cell / per subset

    def device_cost(self, padded_cells: int, valid_cells: int = 0,
                    n_subsets: int = 0) -> float:
        # A device block is not free after readback: subsets whose join is
        # non-empty settle on the host in float64 — work a host-routed block
        # (which ships exact distances) never repeats. The settle terms make
        # the two routes comparable as *end-to-end* costs; on accelerators
        # the dev term shrinks by orders of magnitude (and the prune tier
        # kills most settlements), which is exactly the measured crossover.
        return self.dev_fixed_s + self.dev_cell_s * padded_cells \
            + self.settle_cell_s * valid_cells \
            + self.settle_fixed_s * n_subsets

    def host_cost(self, n_subsets: int, valid_cells: int) -> float:
        return self.host_fixed_s * n_subsets + self.host_cell_s * valid_cells

    @property
    def prune_profitable(self) -> bool:
        return (self.platform == "tpu"
                and self.prune_cell_s < 0.7 * self.dev_cell_s)


_COST_MODELS: dict[tuple, DispatchCostModel] = {}


def calibrate_cost_model(d: int, *, bm: int = 128, bn: int = 128,
                         interpret: bool | None = None) -> DispatchCostModel:
    """Measure the device/host crossover at dimensionality ``d`` (memoized
    per process). Probes the warm path: each probe is compiled + warmed once,
    then timed best-of-3, so jit tracing never lands in the model."""
    import jax
    from repro.kernels import ops

    platform = jax.default_backend()
    key = (platform, d, bm, bn, interpret)
    model = _COST_MODELS.get(key)
    if model is not None:
        return model

    def best(f, reps=3):
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            f()
            ts.append(time.perf_counter() - t0)
        return min(ts)

    x_s = np.zeros((8, 32, d), np.float32)
    x_b = np.zeros((8, 256, d), np.float32)
    l_s = np.full(8, 32, np.int32)
    l_b = np.full(8, 256, np.int32)
    r = np.ones(8, np.float32)

    def dev(x, lens):
        mask, cnt = ops.pairwise_l2_join_batched_masked(
            x, lens, r, bm=bm, bn=bn, interpret=interpret)
        np.asarray(cnt)

    def prune(x, lens):
        np.asarray(ops.pairwise_l2_join_batched_counts(
            x, lens, r, bm=bm, bn=bn, interpret=interpret))

    dev(x_s, l_s)
    dev(x_b, l_b)
    prune(x_b, l_b)
    t_ds, t_db = best(lambda: dev(x_s, l_s)), best(lambda: dev(x_b, l_b))
    cells_s, cells_b = 8 * 32 * 32, 8 * 256 * 256
    dev_cell = max((t_db - t_ds) / (cells_b - cells_s), 1e-13)
    dev_fixed = max(t_ds - dev_cell * cells_s, 0.0)
    prune_cell = max((best(lambda: prune(x_b, l_b)) - dev_fixed) / cells_b,
                     1e-13)

    p_s = np.zeros((32, d))
    p_b = np.zeros((256, d))

    def host(pts):
        dist = pairwise_l2_numpy(pts, pts)
        (dist <= 1.0).sum()

    host(p_s)
    t_hs, t_hb = best(lambda: host(p_s)), best(lambda: host(p_b))
    host_cell = max((t_hb - t_hs) / (cells_b // 8 - cells_s // 8), 1e-13)
    host_fixed = max(t_hs - host_cell * (cells_s // 8), 0.0)

    # Settlement share of a device block's end-to-end cost, as a fraction of
    # the equivalent host join. Without an accelerator the fp32 dispatch buys
    # no arithmetic advantage, every settled subset re-pays host-f64 work on
    # top of the dispatch, and measured end-to-end rates show the host route
    # winning (the exact-tier inversion this model exists to fix) — so the
    # full host cost is charged. On TPU the prune tier removes most
    # settlements and the dispatch term collapses, so half is charged.
    settle_frac = 0.5 if platform == "tpu" else 1.0
    model = DispatchCostModel(
        platform=platform, d=d, dev_fixed_s=dev_fixed, dev_cell_s=dev_cell,
        prune_cell_s=prune_cell, host_fixed_s=host_fixed,
        host_cell_s=host_cell,
        settle_cell_s=settle_frac * host_cell,
        settle_fixed_s=settle_frac * host_fixed)
    _COST_MODELS[key] = model
    return model


def _dp_segment(values: np.ndarray, counts: np.ndarray,
                cap: int) -> np.ndarray:
    """Waste-minimizing size-class edges over a length histogram.

    ``values`` are distinct (rounded) subset lengths, ``counts`` their
    multiplicities. A segmentation assigns every value to the segment's top
    value (the bin edge each member pads to); its cost is total padded tile
    cells ``sum(edge^2 * members)`` plus ``lam`` per segment. The O(u^2) DP
    is exact for a given ``lam``; ``lam`` escalates x4 from one cell until
    the optimum uses at most ``cap`` segments, so edges are deterministic —
    no timing enters the choice."""
    u = len(values)
    if u <= cap:
        return values.copy()
    v2 = values.astype(np.float64) ** 2
    csum = np.concatenate([[0.0], np.cumsum(counts.astype(np.float64))])
    lam = 1.0
    while True:
        dp = np.zeros(u + 1)
        prev = np.zeros(u + 1, np.int64)
        nseg = np.zeros(u + 1, np.int64)
        for j in range(1, u + 1):
            cost = dp[:j] + v2[j - 1] * (csum[j] - csum[:j]) + lam
            bi = int(np.argmin(cost))
            dp[j], prev[j], nseg[j] = cost[bi], bi, nseg[bi] + 1
        if nseg[u] <= cap:
            edges = []
            j = u
            while j > 0:
                edges.append(int(values[j - 1]))
                j = prev[j]
            return np.asarray(sorted(edges), dtype=values.dtype)
        lam *= 4.0


class NumpyBackend(DistanceBackend):
    """float64 control-plane backend: exact, loops subset by subset."""

    name = "numpy"

    def pairwise(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        self.stats.dispatches += 1
        return pairwise_l2_numpy(a, b)

    def self_join_blocks(self, points: np.ndarray,
                         id_lists: Sequence[np.ndarray],
                         radii: Sequence[float],
                         keys: Sequence[bytes] | None = None,
                         generation: int | None = None,
                         eligible: np.ndarray | None = None
                         ) -> list[DistanceBlock]:
        t0 = time.perf_counter()
        out = []
        for ids, r in zip(id_lists, radii):
            pts = points[ids]
            self._note_cold_read(points, len(ids))
            dist = self.pairwise(pts, pts)
            n_elig = None
            if eligible is None:
                count = int((dist <= r).sum()) if np.isfinite(r) else dist.size
            else:
                # Mirror the device fold: counts cover eligible pairs only,
                # so the empty-join signal fires at the filtered selectivity.
                el = eligible[ids]
                n_elig = int(el.sum())
                pair_ok = el[:, None] & el[None, :]
                count = int(((dist <= r) & pair_ok).sum()) \
                    if np.isfinite(r) else int(pair_ok.sum())
            self.stats.subsets += 1
            self.stats.points_packed += len(ids)
            self.stats.join_pairs += count
            out.append(DistanceBlock(n=len(ids), dist=dist, slack=0.0,
                                     rescore=False, join_count=count,
                                     n_eligible=n_elig))
        self.stats.t_dispatch_s += time.perf_counter() - t0
        return out


class PallasBackend(DistanceBackend):
    """Fused device backend: one batched threshold-join dispatch per call.

    Subset counts and pad widths are rounded up (``quantum``) so repeated
    scales reuse compiled programs instead of retracing per shape. A call
    whose packed (S, P, P) on-device join block would exceed
    ``max_block_bytes`` (the fallback stage can pack near-corpus-sized
    subsets for many queries at once) is split into size-bounded chunks —
    still one dispatch per chunk, and a single dispatch in the common
    per-scale case.

    Off-TPU the fused dispatch lowers through XLA (``kernels.ops`` routes by
    backend; the Pallas program is the Mosaic artifact, its interpreter a
    debugging tool). ``cache_bytes`` bounds the packed-subset/tile LRU.

    ``plane`` (a :class:`~repro.core.device_plane.DevicePlane`) makes
    multi-device execution a property of this backend: a size-binned dispatch
    that packs at least one subset per mesh shard is routed through the
    plane's ``shard_map`` join — subsets sharded on S over the ``data`` axis,
    packed bitmasks + join counts gathered back on readback, per-shard
    utilisation recorded in the stats. Remainder bins (fewer subsets than
    shards) keep the single-device dispatch; the per-shard math is identical
    either way, so blocks are bit-exact across routes.
    """

    name = "pallas"

    def __init__(self, *, bm: int = 128, bn: int = 128,
                 interpret: bool | None = None, quantum: int = 8,
                 max_block_bytes: int = 256 << 20,
                 cache_bytes: int = 128 << 20,
                 plane=None,
                 bin_strategy: str = "quantile",
                 n_classes: int = 6,
                 route: str = "auto",
                 prune_tier: str = "auto",
                 prune_dtype: str = "bf16",
                 prune_eps: float = 0.05,
                 elig_pack_threshold: float = 0.25,
                 placement: str = "sorted",
                 cost_model: DispatchCostModel | None = None) -> None:
        super().__init__()
        self.bm = bm
        self.bn = bn
        self.interpret = interpret
        self.quantum = quantum
        self.max_block_bytes = max_block_bytes
        self.cache_bytes = cache_bytes
        self.plane = plane
        # --- raw-speed campaign knobs (see README "Performance tuning") ---
        # bin_strategy: "quantile" fits size-class edges to the planned
        #   subset-length distribution per call (deterministic DP, at most
        #   n_classes edges, never more padded cells than "pow2").
        # route: "auto" sends bins below the measured Pallas break-even to
        #   the exact host path (one dispatch per bin either way); "device"
        #   pins every finite-radius bin on the device.
        # prune_tier: "on"/"off"/"auto" — the coarse bf16/int8 counts pass
        #   ahead of the fp32 masked join; "auto" enables it only where the
        #   calibrated model shows a coarse-gemm discount (TPU).
        # elig_pack_threshold: below this filter selectivity, tiles pack
        #   eligible rows densely instead of folding an eligibility mask.
        # placement: "sorted" deals sharded bins to shards in snake order of
        #   packed size so slab work stays level; "none" keeps plan order.
        if bin_strategy not in ("quantile", "pow2"):
            raise ValueError(f"unknown bin_strategy: {bin_strategy!r}")
        if route not in ("auto", "device"):
            raise ValueError(f"unknown route: {route!r}")
        if prune_tier not in ("auto", "on", "off"):
            raise ValueError(f"unknown prune_tier: {prune_tier!r}")
        if prune_dtype not in ("bf16", "int8"):
            raise ValueError(f"unknown prune_dtype: {prune_dtype!r}")
        if placement not in ("sorted", "none"):
            raise ValueError(f"unknown placement: {placement!r}")
        self.bin_strategy = bin_strategy
        self.n_classes = n_classes
        self.route = route
        self.prune_tier = prune_tier
        self.prune_dtype = prune_dtype
        self.prune_eps = prune_eps
        self.elig_pack_threshold = elig_pack_threshold
        self.placement = placement
        self._model = cost_model
        self._edge_cache: dict[bytes, np.ndarray] = {}
        # LRU over both per-subset packed rows and whole device-committed
        # dispatch tiles; values are (nbytes, payload). Entries are only
        # valid for one corpus *generation*: subset keys are id bytes, so a
        # backend re-used against a remapped id space must drop the cache
        # (see ``self_join_blocks``). Within a generation the id space is
        # append-only (streaming absorbs/tombstones), so entries survive
        # corpus growth — a tombstoned id never recurs in a subset key, and
        # existing rows are immutable.
        self._cache: OrderedDict[tuple, tuple[int, tuple]] = OrderedDict()
        self._cache_nbytes = 0
        self._corpus: np.ndarray | None = None
        self._generation: int | None = None
        self._min_class: int | None = None

    # ------------------------------------------------------------------ cache
    def _cache_get(self, key: tuple):
        entry = self._cache.get(key)
        if entry is None:
            return None
        self._cache.move_to_end(key)
        return entry[1]

    def _cache_put(self, key: tuple, payload: tuple, nbytes: int) -> None:
        if nbytes > self.cache_bytes:
            return
        old = self._cache.pop(key, None)
        if old is not None:
            self._cache_nbytes -= old[0]
        self._cache[key] = (nbytes, payload)
        self._cache_nbytes += nbytes
        while self._cache_nbytes > self.cache_bytes:
            _, (dropped, _) = self._cache.popitem(last=False)
            self._cache_nbytes -= dropped
            self.stats.cache_evictions += 1

    @staticmethod
    def _slack(pts: np.ndarray) -> float:
        """Absolute L2 error bound for the fp32 ||a||^2+||b||^2-2ab identity.

        The squared-distance error is dominated by cancellation at the
        squared-norm scale S: |err_sq| <= c*eps32*S with c a small constant
        times the reduction depth (the kernel tests bound the diagonal at
        32*eps*S). sqrt is monotone, so |err_dist| <= sqrt(err_sq); we take
        c = 64 + 4d for headroom across accumulation orders.
        """
        if pts.size == 0:
            return 0.0
        d = pts.shape[1]
        s_norm = float((pts.astype(np.float64) ** 2).sum(axis=1).max())
        return float(np.sqrt((64.0 + 4.0 * d) * _EPS32 * s_norm))

    def pairwise(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        from repro.kernels import ops
        self.stats.dispatches += 1
        sq, _ = ops.pairwise_l2_join(np.asarray(a, np.float32),
                                     np.asarray(b, np.float32),
                                     bm=self.bm, bn=self.bn,
                                     interpret=self.interpret)
        return np.sqrt(np.asarray(sq, np.float64))

    def _round(self, n: int) -> int:
        q = self.quantum
        return max(q, ((n + q - 1) // q) * q)

    def _subset_rows(self, points: np.ndarray, ids: np.ndarray,
                     key: bytes | None) -> tuple[np.ndarray, float]:
        """fp32 rows + fp32 slack for one subset, through the LRU."""
        if key is not None:
            hit = self._cache_get(("subset", key))
            if hit is not None:
                self.stats.cache_hits += 1
                return hit
        rows = np.ascontiguousarray(points[ids], dtype=np.float32)
        self._note_cold_read(points, len(ids))
        payload = (rows, self._slack(rows))
        if key is not None:
            self.stats.cache_misses += 1
            self._cache_put(("subset", key), payload, rows.nbytes)
        return payload

    def _class_pad(self, n: int) -> int:
        """Size class for one subset: next power of two >= max(n, floor).
        Pow2 classes bound both pad waste (< 2x the valid points) and the
        number of compiled program shapes. On TPU the floor is the kernel
        tile ``bm`` (Mosaic pads every block to it anyway, so sub-tile
        classes would only add dispatches); the XLA lowering uses exact
        shapes, so small classes genuinely save compute there."""
        if self._min_class is None:
            import jax
            self._min_class = self.bm if jax.default_backend() == "tpu" \
                else max(self.quantum, 1)
        p = self._min_class
        while p < n:
            p <<= 1
        return p

    def _cost_model(self, d: int) -> DispatchCostModel:
        if self._model is None:
            self._model = calibrate_cost_model(
                d, bm=self.bm, bn=self.bn, interpret=self.interpret)
        return self._model

    def _prune_active(self, d: int) -> bool:
        if self.prune_tier == "on":
            return True
        if self.prune_tier == "off":
            return False
        # "auto": only where the coarse gemm is actually discounted. Off-TPU
        # the answer is a platform property, so skip the calibration probes.
        import jax
        if jax.default_backend() != "tpu":
            return False
        return self._cost_model(d).prune_profitable

    def _quantile_edges(self, sizes: np.ndarray) -> np.ndarray:
        """Data-driven size-class edges for one call's subset lengths.

        Lengths are rounded up to the quantum (shape reuse) and floored at
        the platform min class, then segmented by the waste-minimizing DP
        (:func:`_dp_segment`) capped at ``n_classes`` edges — or the pow2
        class count if that is larger, which makes the pow2 segmentation a
        *feasible* DP choice and hence quantile padded cells <= pow2 padded
        cells on every call (the guard below enforces it exactly). Edges are
        cached per sorted-length signature; the cache lives inside one
        corpus generation (purged with the LRU)."""
        self._class_pad(1)                      # resolve _min_class
        q = self.quantum
        vals = np.maximum(((np.maximum(sizes, 1) + q - 1) // q) * q,
                          self._min_class).astype(np.int64)
        svals = np.sort(vals)
        sig = svals.tobytes()
        hit = self._edge_cache.get(sig)
        if hit is not None:
            return hit
        distinct, counts = np.unique(svals, return_counts=True)
        pow2_edges = np.unique([self._class_pad(int(v)) for v in distinct])
        cap = max(self.n_classes, len(pow2_edges))
        edges = _dp_segment(distinct, counts, cap)

        def total_cells(e):
            cls = e[np.searchsorted(e, distinct)]
            return int((counts * cls.astype(np.int64) ** 2).sum())

        if total_cells(edges) > total_cells(pow2_edges):
            edges = pow2_edges
        if len(self._edge_cache) > 128:
            self._edge_cache.clear()
        self._edge_cache[sig] = edges
        return edges

    def _purge_cache(self, generation_bump: bool) -> None:
        if self._cache:
            self.stats.generation_purges += int(generation_bump)
        self._cache.clear()
        self._cache_nbytes = 0
        self._edge_cache.clear()

    def self_join_blocks(self, points: np.ndarray,
                         id_lists: Sequence[np.ndarray],
                         radii: Sequence[float],
                         keys: Sequence[bytes] | None = None,
                         generation: int | None = None,
                         eligible: np.ndarray | None = None
                         ) -> list[DistanceBlock]:
        if not len(id_lists):
            return []
        if keys is None:
            keys = [None] * len(id_lists)
        # Cache entries are keyed on subset-id bytes, which only identify
        # points *within one corpus generation*. A generation-aware caller
        # (the streaming engine) keeps entries live across absorbs — the
        # merged points array is re-realized per batch, but ids are
        # append-only and rows immutable until a compaction bumps the token.
        # Legacy callers (no token) fall back to array-identity invalidation.
        if generation is not None:
            if generation != self._generation:
                self._purge_cache(generation_bump=self._generation is not None)
                self._generation = generation
            self._corpus = points
        elif self._corpus is not points:
            self._purge_cache(generation_bump=False)
            self._generation = None
            self._corpus = points
        # Size-binned dispatch: padding every subset of a scale to the batch
        # max wastes quadratically (a single near-corpus subset makes every
        # tiny one pay its P^2). Size-class edges come from the bin strategy:
        # "quantile" fits them to this call's length distribution (DP over
        # the histogram, <= n_classes edges, never more padded cells than
        # pow2), "pow2" keeps the classic powers of two. Within a class,
        # chunk so one dispatch's (S, P, P) on-device join block stays under
        # the memory budget, then route each chunk: bins whose estimated
        # device cost exceeds the measured host cost go to the exact numpy
        # path (route="auto"), the rest dispatch on device. Result order
        # matches the task order.
        blocks: list[DistanceBlock | None] = [None] * len(id_lists)
        finite: list[int] = []
        for i, ids in enumerate(id_lists):
            if not np.isfinite(radii[i]):
                # An infinite pruning radius joins every pair by construction
                # (fresh queues at scale 0): the mask is all-ones, so skip the
                # device round-trip and synthesize the trivial block. The
                # enumeration stage prunes with its live r_k instead. Under a
                # filter the all-ones adjacency covers eligible pairs only —
                # same contract as the device fold.
                n = len(ids)
                n_elig = None if eligible is None else int(eligible[ids].sum())
                pairs = n * n if n_elig is None else n_elig * n_elig
                self.stats.subsets += 1
                self.stats.points_packed += n
                self.stats.join_pairs += pairs
                blocks[i] = DistanceBlock(n=n, slack=0.0, rescore=True,
                                          join_count=pairs, n_eligible=n_elig)
                continue
            finite.append(i)
        if not finite:
            return blocks
        lens = np.fromiter((len(id_lists[i]) for i in finite), np.int64,
                           count=len(finite))
        # Eligible-dense packing: when a filter keeps only a thin slice of
        # each subset, folding an eligibility mask into a full-width tile
        # wastes ~1/selectivity^2 of the join cells. Below the threshold the
        # tiles pack eligible rows densely instead — sized by eligible
        # counts, uncached (the pack is filter-dependent), blocks carrying
        # the packed row map for the enumeration stage.
        elig_dense = False
        if eligible is not None and len(lens):
            el_counts = np.fromiter(
                (int(eligible[id_lists[i]].sum()) for i in finite), np.int64,
                count=len(finite))
            tot = int(lens.sum())
            elig_dense = tot > 0 and \
                int(el_counts.sum()) < self.elig_pack_threshold * tot
        sizes = el_counts if elig_dense else lens
        if self.bin_strategy == "quantile":
            edges = self._quantile_edges(sizes)
            cls = edges[np.searchsorted(edges, np.maximum(sizes, 1))]
        else:
            cls = np.array([self._class_pad(int(max(s, 1))) for s in sizes])
        classes: dict[int, list[int]] = {}
        for pos, i in enumerate(finite):
            classes.setdefault(int(cls[pos]), []).append(pos)
        model = None
        if self.route == "auto":
            model = self._cost_model(points.shape[1])
        budget = max(1, self.max_block_bytes // 4)
        for p_pad, poss in sorted(classes.items()):
            # Budget the *padded* subset count: _dispatch rounds it up to
            # quantum for shape reuse, so floor max_s to a quantum multiple
            # (falling back to unrounded single-subset dispatches when even
            # one quantum of this class would blow the budget).
            max_s = budget // (p_pad * p_pad)
            if max_s >= self.quantum:
                max_s = (max_s // self.quantum) * self.quantum
            max_s = max(1, max_s)
            for c0 in range(0, len(poss), max_s):
                chunk = poss[c0:c0 + max_s]
                idxs = [finite[p] for p in chunk]
                if model is not None:
                    padded_cells = self._round(len(chunk)) * p_pad * p_pad
                    valid_cells = int((sizes[chunk] ** 2).sum())
                    if model.host_cost(len(chunk), valid_cells) \
                            < model.device_cost(padded_cells, valid_cells,
                                                len(chunk)):
                        out = self._host_dispatch(
                            points, [id_lists[i] for i in idxs],
                            [radii[i] for i in idxs], eligible,
                            keys=[keys[i] for i in idxs])
                        for i, b in zip(idxs, out):
                            blocks[i] = b
                        continue
                out = self._dispatch(points, [id_lists[i] for i in idxs],
                                     [radii[i] for i in idxs],
                                     [keys[i] for i in idxs], p_pad,
                                     eligible, elig_dense=elig_dense)
                for i, b in zip(idxs, out):
                    blocks[i] = b
        return blocks

    def _host_dispatch(self, points: np.ndarray,
                       id_lists: Sequence[np.ndarray],
                       radii: Sequence[float],
                       eligible: np.ndarray | None,
                       keys: Sequence[bytes | None] | None = None
                       ) -> list[DistanceBlock]:
        """Cost-model host route: one bin served by the exact float64 path.

        Blocks carry dense float64 distances (no slack, no rescore) computed
        with the *same* difference-based arithmetic the enumeration stage's
        float64 settlement uses (``sqrt`` of ``_sq_dists_f64``) — not the
        norms identity of :class:`NumpyBackend`, which rounds differently at
        the last ulp. That keeps the routing decision invisible in the
        output: a bin served here yields bitwise the same diameters the
        device route's rescore would have produced, so the cost model can
        flip a bin between routes without changing a single result. The
        whole bin counts as one dispatch — the same accounting unit as the
        device route it replaces.

        Distance tables are LRU-cached per subset key (generation-scoped,
        like the device tiles): distances are radius- and filter-independent,
        so a steady-state host-routed bin recomputes nothing — only the
        threshold count per call. This is the host route's analogue of the
        device tile cache, and what makes auto routing faster than a pure
        :class:`NumpyBackend` pass at the same results."""
        t0 = time.perf_counter()
        if keys is None:
            keys = [None] * len(id_lists)
        out = []
        for ids, r, key in zip(id_lists, radii, keys):
            ck = None if key is None else ("hostdist", key)
            dist = self._cache_get(ck) if ck is not None else None
            if dist is None:
                pts = points[ids]
                self._note_cold_read(points, len(ids))
                dist = np.sqrt(_sq_dists_f64(np.asarray(pts, np.float64)))
                if ck is not None:
                    self.stats.cache_misses += 1
                    self._cache_put(ck, dist, dist.nbytes)
            else:
                self.stats.cache_hits += 1
            n_elig = None
            if eligible is None:
                count = int((dist <= r).sum())
            else:
                el = eligible[ids]
                n_elig = int(el.sum())
                count = int(((dist <= r) & el[:, None] & el[None, :]).sum())
            self.stats.subsets += 1
            self.stats.points_packed += len(ids)
            self.stats.join_pairs += count
            out.append(DistanceBlock(n=len(ids), dist=dist, slack=0.0,
                                     rescore=False, join_count=count,
                                     n_eligible=n_elig))
        dt = time.perf_counter() - t0
        self.stats.dispatches += 1
        self.stats.host_routed_dispatches += 1
        self.stats.host_routed_subsets += len(id_lists)
        self.stats.t_host_s += dt
        self.stats.t_dispatch_s += dt
        return out

    def _dispatch(self, points: np.ndarray, id_lists: Sequence[np.ndarray],
                  radii: Sequence[float], keys: Sequence[bytes | None],
                  p_pad: int,
                  eligible: np.ndarray | None = None, *,
                  elig_dense: bool = False) -> list[DistanceBlock]:
        from repro.kernels import ops
        import jax.numpy as jnp

        t0 = time.perf_counter()
        n_subsets = len(id_lists)
        # Eligible-dense packing: tiles hold only the eligible rows; the
        # block carries the packed row map. The pack is filter-dependent, so
        # both the subset-row cache and the tile cache are bypassed.
        if elig_dense:
            row_lists = [np.flatnonzero(eligible[ids]) for ids in id_lists]
            lengths = np.fromiter((len(rw) for rw in row_lists), np.int32,
                                  count=n_subsets)
        else:
            row_lists = None
            lengths = np.fromiter((len(ids) for ids in id_lists), np.int32,
                                  count=n_subsets)
        # Route over the device plane when the bin packs at least one subset
        # per shard; thinner bins (the remainder after chunking) stay on a
        # single device — sharding them would only ship empty slabs.
        plane = self.plane
        sharded = plane is not None and n_subsets >= plane.n_shards
        s_pad = self._round(n_subsets)
        if sharded:
            s_pad = plane.shard_pad(s_pad)
        budget_cells = max(1, self.max_block_bytes // 4)
        if s_pad * p_pad * p_pad > budget_cells:
            # Shape-reuse rounding must not blow the budget. Sharding needs a
            # shard multiple; if even the minimal one is over budget, the bin
            # drops to the single-device route at its exact size.
            s_pad = plane.shard_pad(n_subsets) if sharded else n_subsets
            if sharded and s_pad * p_pad * p_pad > budget_cells:
                sharded = False
                s_pad = n_subsets

        lens_pad = np.zeros(s_pad, np.int32)
        lens_pad[:n_subsets] = lengths
        # Shard placement: deal subsets to tile slots in snake order of
        # packed size so each shard's contiguous slab carries level work
        # (``device_plane.balance_order``). The permutation is a pure
        # function of the packed lengths — radius-independent, so cached
        # tiles (which are reused across radii) stay valid — and slot->shard
        # is what ``shard_cells`` reports, so ``shard_utilisation`` reads the
        # levelled layout directly. ``inv[i]`` is subset i's tile slot.
        inv = None
        if sharded and self.placement == "sorted":
            from repro.core.device_plane import balance_order
            perm = balance_order(lens_pad, plane.n_shards)
            inv = np.empty(s_pad, np.int64)
            inv[perm] = np.arange(s_pad)

        def slot(i: int) -> int:
            return i if inv is None else int(inv[i])

        def to_slots(arr):
            if inv is None:
                return arr
            out = np.zeros_like(arr)
            out[inv] = arr
            return out

        lens_ship = to_slots(lens_pad)
        tile_key = None
        if not elig_dense and not any(k is None for k in keys):
            tile_key = ("tile", tuple(keys), s_pad, p_pad, sharded,
                        self.placement if sharded else "none")
        cached_tile = self._cache_get(tile_key) if tile_key else None
        if cached_tile is not None:
            # Packed tiles already live on the device: skip gather, packing,
            # and H2D entirely; only the radii change between calls. Slacks
            # ride in the payload, so the hit path touches no per-subset
            # state at all. Hit/miss counters are per *subset* (a tile hit
            # serves every subset it packs), so cache_hit_rate reads as the
            # fraction of subset packs avoided.
            self.stats.cache_hits += n_subsets
            x_dev, lens_dev, slacks = cached_tile
            # Keep the per-subset row entries warm too: a long streak of
            # tile hits must not LRU-starve them, or a later re-binning
            # (chunk boundaries shift when radii tighten) re-packs rows the
            # cache nominally still held. Recency touch only — the hit
            # counter above already accounts for these subsets.
            for key in keys:
                if ("subset", key) in self._cache:
                    self._cache.move_to_end(("subset", key))
        else:
            slacks = np.zeros(n_subsets, np.float64)
            d = points.shape[1]
            x = np.zeros((s_pad, p_pad, d), np.float32)
            for i, (ids, key) in enumerate(zip(id_lists, keys)):
                if elig_dense:
                    rows = np.ascontiguousarray(
                        points[ids[row_lists[i]]], dtype=np.float32)
                    self._note_cold_read(points, len(row_lists[i]))
                    slacks[i] = self._slack(rows)
                else:
                    rows, slacks[i] = self._subset_rows(points, ids, key)
                x[slot(i), : lengths[i]] = rows
            if sharded:
                # Commit the tile scattered over the mesh's data axis so the
                # sharded dispatch starts from the right placement (a cached
                # sharded tile stays resident exactly where it will be used).
                x_dev, lens_dev = plane.put_sharded(x, lens_ship)
            else:
                x_dev = jnp.asarray(x)
                lens_dev = jnp.asarray(lens_ship)
            if tile_key is not None:
                self._cache_put(tile_key, (x_dev, lens_dev, slacks),
                                x.nbytes + slacks.nbytes)

        # Pruning radius r + slack, rounded *up* to fp32 so the device
        # comparison can never be tighter than the published slack contract.
        # ``r_orig`` is indexed by subset, the shipped vectors by tile slot.
        r_orig = np.zeros(s_pad, np.float32)
        r_mask = np.asarray(radii, np.float64) + slacks
        with np.errstate(over="ignore"):    # nextafter(f32max) saturates to inf
            r_orig[:n_subsets] = np.nextafter(r_mask.astype(np.float32),
                                              np.float32(np.inf))
        r_orig[:n_subsets][~np.isfinite(r_mask)] = np.float32(np.inf)
        r = to_slots(r_orig)
        # Filtered dispatch (fold mode): pack each subset's eligibility bits
        # into the mask word layout. These words are the *only* extra traffic
        # a filter adds — the tile (cached or not) is filter-independent, and
        # the readback stays the same packed mask. Eligible-dense tiles skip
        # the fold (every packed row is eligible by construction).
        elig_words = el_counts = None
        if eligible is not None and not elig_dense:
            el = np.zeros((s_pad, p_pad), dtype=bool)
            el_counts = np.zeros(n_subsets, np.int64)
            for i, ids in enumerate(id_lists):
                eli = eligible[ids]
                el[slot(i), : len(ids)] = eli
                el_counts[i] = int(eli.sum())
            elig_words = pack_join_mask(el)        # (s_pad, ceil(p_pad/32))
        self.stats.t_pack_s += time.perf_counter() - t0
        self.stats.h2d_bytes += r.nbytes + \
            (elig_words.nbytes if elig_words is not None else 0) + \
            (0 if cached_tile is not None
             else x.nbytes + lens_ship.nbytes)

        # n_live: the diagonal bound the enumeration stage's empty-join test
        # uses — eligible counts under a fold, packed lengths otherwise.
        n_live = lengths.astype(np.int64) if el_counts is None else el_counts
        # ---- tier 0: coarse mixed-precision prune (counts only) ----
        pruned = None
        cc = None
        if self._prune_active(points.shape[1]):
            # Coarse radius: the fp32 pruning radius widened by the coarse
            # tier's own error budget — a second fp32-identity slack (the
            # coarse pass accumulates in fp32 too) plus the bf16 coordinate
            # rounding (2 * eps16 * max-norm, eps16 = 2^-8; the max norm is
            # recovered from the cached slack, sqrt(S_norm) = slack /
            # sqrt((64+4d)*eps32)), all scaled by (1 + prune_eps) headroom.
            # Any pair the fp32 tier could join is therefore inside the
            # coarse radius: coarse count <= diagonal bound proves the fp32
            # join empty, and the singleton path the enumeration stage takes
            # is decided by that bound alone — results stay bit-identical
            # whether or not the fp32 tier ran. int8 adds its quantization
            # slack inside the op itself.
            d = points.shape[1]
            eps16 = 2.0 ** -8
            rtnorm = slacks / np.sqrt((64.0 + 4.0 * d) * _EPS32)
            r_c = (r_mask + slacks + 2.0 * eps16 * rtnorm) \
                * (1.0 + self.prune_eps)
            rc_orig = np.zeros(s_pad, np.float32)
            with np.errstate(over="ignore"):
                rc_orig[:n_subsets] = np.nextafter(
                    r_c.astype(np.float32), np.float32(np.inf))
            rc = to_slots(rc_orig)
            t_p = time.perf_counter()
            if sharded:
                cnt_c = plane.join_batched_counts(
                    x_dev, lens_dev, rc, elig_words, dtype=self.prune_dtype,
                    bm=self.bm, bn=self.bn, interpret=self.interpret)
            else:
                cnt_c = ops.pairwise_l2_join_batched_counts(
                    x_dev, lens_dev, rc, elig_words, dtype=self.prune_dtype,
                    bm=self.bm, bn=self.bn, interpret=self.interpret)
            counts_c = np.asarray(cnt_c)
            dtp = time.perf_counter() - t_p
            self.stats.t_prune_s += dtp
            self.stats.t_dispatch_s += dtp
            self.stats.prune_tier_dispatches += 1
            self.stats.h2d_bytes += rc.nbytes
            self.stats.d2h_bytes += counts_c.nbytes
            cc = counts_c[:n_subsets] if inv is None \
                else counts_c[inv[:n_subsets]]
            pruned = cc <= n_live
            self.stats.cells_pruned += int(pruned.sum()) * p_pad * p_pad

        # ---- tier 1: fp32 masked join on surviving subsets ----
        mask = counts = None
        sub_slots = None
        if pruned is None or not pruned.all():
            t1 = time.perf_counter()
            if pruned is not None and pruned.any():
                # Survivor sub-dispatch: gather surviving slots out of the
                # committed tile on device (no re-pack, no H2D of rows).
                surv = np.flatnonzero(~pruned)
                slots_surv = surv if inv is None else inv[surv]
                n_surv = len(surv)
                s_sub = self._round(n_surv)
                sub_sharded = sharded and n_surv >= plane.n_shards
                if sub_sharded:
                    s_sub = plane.shard_pad(s_sub)
                idx_pad = np.zeros(s_sub, np.int64)
                idx_pad[:n_surv] = slots_surv
                lens_sub = np.zeros(s_sub, np.int32)
                lens_sub[:n_surv] = lengths[surv]
                r_sub = np.zeros(s_sub, np.float32)
                r_sub[:n_surv] = r_orig[surv]
                elig_sub = None
                if elig_words is not None:
                    elig_sub = np.zeros((s_sub, elig_words.shape[1]),
                                        np.uint32)
                    elig_sub[:n_surv] = elig_words[slots_surv]
                x_sub = jnp.take(x_dev, jnp.asarray(idx_pad), axis=0)
                if sub_sharded:
                    m, c = plane.join_batched_masked(
                        x_sub, lens_sub, r_sub, elig_sub, bm=self.bm,
                        bn=self.bn, interpret=self.interpret)
                else:
                    m, c = ops.pairwise_l2_join_batched_masked(
                        x_sub, lens_sub, r_sub, elig_sub, bm=self.bm,
                        bn=self.bn, interpret=self.interpret)
                sub_slots = {int(i): j for j, i in enumerate(surv)}
            else:
                if sharded:
                    m, c = plane.join_batched_masked(
                        x_dev, lens_dev, r, elig_words, bm=self.bm,
                        bn=self.bn, interpret=self.interpret)
                else:
                    m, c = ops.pairwise_l2_join_batched_masked(
                        x_dev, lens_dev, r, elig_words, bm=self.bm,
                        bn=self.bn, interpret=self.interpret)
            mask = np.asarray(m)
            counts = np.asarray(c)
            dt = time.perf_counter() - t1
            self.stats.t_dispatch_s += dt
            self.stats.d2h_bytes += mask.nbytes + counts.nbytes
            if sharded:
                self.stats.t_collective_s += dt

        self.stats.dispatches += 1
        self.stats.subsets += n_subsets
        self.stats.points_packed += int(lengths.sum())
        self.stats.points_padded += s_pad * p_pad - int(lengths.sum())
        bp = self.stats.bin_points.get(p_pad, (0, 0))
        self.stats.bin_points[p_pad] = (
            bp[0] + int(lengths.sum()),
            bp[1] + s_pad * p_pad - int(lengths.sum()))
        if sharded:
            # Per-shard accounting: every device participated; utilisation is
            # valid vs total join-block cells on each shard's slab (computed
            # on the shipped, i.e. placement-permuted, lengths).
            self.stats.sharded_dispatches += 1
            n_sh = plane.n_shards
            self.stats.ensure_shards(n_sh)
            valid, total = plane.shard_cells(lens_ship, p_pad)
            for i in range(n_sh):
                self.stats.shard_dispatches[i] += 1
                self.stats.shard_valid_cells[i] += valid[i]
                self.stats.shard_total_cells[i] += total[i]
        else:
            # Single-device dispatch lands on the default device (shard 0 of
            # the plane when one is attached).
            self.stats.ensure_shards(max(1, plane.n_shards if plane else 1))
            self.stats.shard_dispatches[0] += 1
            self.stats.shard_valid_cells[0] += int(
                (lengths.astype(np.int64) ** 2).sum())
            self.stats.shard_total_cells[0] += s_pad * p_pad * p_pad

        out = []
        for i, ids in enumerate(id_lists):
            n = len(ids)
            n_elig = None
            if elig_dense:
                n_elig = int(lengths[i])
            elif el_counts is not None:
                n_elig = int(el_counts[i])
            rows_i = None
            if elig_dense:
                rows_i = row_lists[i]
            if pruned is not None and pruned[i]:
                # Coarse count at or below the diagonal bound: the fp32 join
                # is provably empty off-diagonal, emit the mask-free block
                # (the enumeration stage's singleton path never unpacks it).
                self.stats.join_pairs += int(cc[i])
                out.append(DistanceBlock(
                    n=n, slack=float(slacks[i]), rescore=True,
                    join_count=int(cc[i]), mask=None, n_eligible=n_elig,
                    rows=rows_i))
                continue
            row = i if sub_slots is None else sub_slots[i]
            row = slot(row) if sub_slots is None else row
            npk = int(lengths[i])
            words = (npk + 31) // 32
            self.stats.join_pairs += int(counts[row])
            out.append(DistanceBlock(
                n=n, mask=mask[row, :npk, :words], slack=float(slacks[i]),
                rescore=True, join_count=int(counts[row]),
                n_eligible=n_elig, rows=rows_i))
        return out


def get_backend(spec: str | DistanceBackend, **kw) -> DistanceBackend:
    """Resolve a backend name ("numpy" | "pallas") or pass an instance through."""
    if isinstance(spec, DistanceBackend):
        return spec
    if spec == "numpy":
        return NumpyBackend()
    if spec == "pallas":
        return PallasBackend(**kw)
    raise ValueError(f"unknown distance backend: {spec!r}")
