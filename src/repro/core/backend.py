"""Distance backends for the subset-search pipeline.

The §V inner joins and Algorithm 4 predicates consume one *join structure*
per covering-bucket subset. This module routes that production:

  * :class:`NumpyBackend` — float64 on the control plane; distances are exact,
    so enumeration needs no slack and no rescoring. Emits dense distance
    blocks; the enumeration stage packs its own bitmask at the live r_k. One
    "dispatch" per subset (the per-query loop the paper measures).
  * :class:`PallasBackend` — packs every subset of a batch into one dense
    (S, P, d) tile block and issues **one** fused
    ``kernels.ops.pairwise_l2_join_batched_masked`` dispatch, with per-subset
    pruning radii riding in SMEM. The result shipped back to the host is the
    **packed adjacency bitmask** (S, P, ceil(P/32)) — a 32x smaller D2H
    readback than the dense fp32 block, which is no longer materialised on
    the host at all. fp32 on device is a *pruning filter*: the per-subset
    radius is widened by an absolute slack bounding fp32 cancellation error,
    and the enumeration stage re-scores surviving tuples through the float64
    path before they enter the queue (``subset_search.enumerate_with_block``).

The block contract (:class:`DistanceBlock`) carries either ``dist`` (dense
float64, numpy) or ``mask`` (packed uint32 at the dispatch-time pruning
radius, device), plus ``join_count`` — the kernel's inner-join cardinality,
which the enumeration stage uses to skip subsets whose join is empty before
any host work (the adaptive-radii feedback loop).

``PallasBackend`` keeps a byte-bounded LRU cache keyed on the Algorithm-2
subset hash (the sorted-id bytes): per-subset packed fp32 rows + slack, and
whole packed dispatch tiles already committed to the device — steady-state
repeated subsets skip gather, packing, and H2D entirely.

Backends are deliberately jax-free at import time: the device stack loads
only when a PallasBackend actually dispatches, keeping the numpy control
plane importable everywhere.
"""
from __future__ import annotations

import abc
import dataclasses
import time
from collections import OrderedDict
from typing import Sequence

import numpy as np

from repro.core.subset_search import pack_join_mask, pairwise_l2_numpy

_EPS32 = float(np.finfo(np.float32).eps)
_F32_MAX = float(np.finfo(np.float32).max)


@dataclasses.dataclass
class BackendStats:
    """Dispatch accounting for the pipeline stats (§VII-style instrumentation)."""

    dispatches: int = 0        # device/loop calls issued
    subsets: int = 0           # join blocks produced
    points_packed: int = 0     # total valid points shipped
    points_padded: int = 0     # pad waste (packed tile points - valid points)
    join_pairs: int = 0        # threshold-join survivors across all subsets
    t_pack_s: float = 0.0      # host time: gather + tile packing
    t_dispatch_s: float = 0.0  # device time: dispatch + D2H readback
    cache_hits: int = 0        # packed-subset/tile LRU hits
    cache_misses: int = 0
    cache_evictions: int = 0
    generation_purges: int = 0  # cache invalidations on corpus-generation bump
    # Transfer accounting (device backends): host->device bytes shipped
    # (tiles + lengths + radii + packed eligibility words) and device->host
    # bytes read back (packed masks + join counts). The filtered-NKS
    # contract — eligibility folds into the existing packed mask, adding no
    # new D2H — is asserted on these counters.
    h2d_bytes: int = 0
    d2h_bytes: int = 0
    # Sharded-dispatch accounting (populated when a DevicePlane routes the
    # dispatch over the mesh; lists are indexed by shard/device position on
    # the plane's data axis and sized lazily on first device dispatch).
    sharded_dispatches: int = 0            # dispatches routed via shard_map
    t_collective_s: float = 0.0            # wall inside sharded dispatches
    shard_dispatches: list = dataclasses.field(default_factory=list)
    shard_valid_cells: list = dataclasses.field(default_factory=list)
    shard_total_cells: list = dataclasses.field(default_factory=list)

    def ensure_shards(self, n: int) -> None:
        for lst in (self.shard_dispatches, self.shard_valid_cells,
                    self.shard_total_cells):
            lst.extend([0] * (n - len(lst)))


@dataclasses.dataclass(frozen=True)
class DistanceBlock:
    """One subset's join structure plus the contract needed to consume it.

    n          : number of valid points in the subset.
    dist       : (n, n) float64 pairwise L2 distances, or None for mask-only
                 device blocks.
    mask       : (n, ceil(n/32)) uint32 packed adjacency at the dispatch-time
                 pruning radius (bit j%32 of word j//32 set iff points i, j
                 join). None for dense blocks — and for device blocks whose
                 radius was infinite (every pair joins by construction; the
                 backend skips the dispatch and enumeration treats the
                 adjacency as all-ones).
    slack      : absolute distance error bound; dense approximate blocks are
                 pruned at r + slack (mask blocks bake it into the radius).
    rescore    : True when the block is approximate and accepted tuples must
                 be re-scored in float64 before entering the top-k queue.
    join_count : #{pairs joining at the pruning radius}, diagonal included —
                 ``join_count <= n`` proves the inner join empty, letting the
                 enumeration stage skip the subset (adaptive radii).
    n_eligible : number of subset points satisfying the query's predicate
                 mask, or None on an unfiltered call. When set, ``mask`` and
                 ``join_count`` cover eligible pairs only (the eligibility
                 fold), so the empty-join test becomes
                 ``join_count <= n_eligible``.
    """

    n: int
    slack: float
    rescore: bool
    join_count: int
    dist: np.ndarray | None = None
    mask: np.ndarray | None = None
    n_eligible: int | None = None


class DistanceBackend(abc.ABC):
    """Produces per-subset self-join blocks for the enumeration stage."""

    name: str = "abstract"

    def __init__(self) -> None:
        self.stats = BackendStats()

    @abc.abstractmethod
    def pairwise(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        """Dense (n, m) distance matrix for one pair of point sets."""

    @abc.abstractmethod
    def self_join_blocks(self, points: np.ndarray,
                         id_lists: Sequence[np.ndarray],
                         radii: Sequence[float],
                         keys: Sequence[bytes] | None = None,
                         generation: int | None = None,
                         eligible: np.ndarray | None = None
                         ) -> list[DistanceBlock]:
        """Self-join blocks for a batch of subsets at per-subset radii.

        ``points`` is the full corpus; each ``id_lists[i]`` selects one
        subset's rows (sorted unique ids). ``keys`` are the Algorithm-2
        subset hashes (sorted-id bytes) used as cache keys; pass None to
        bypass caching. ``generation`` is the caller's corpus-generation
        token: calls under the same token may share cache entries even if
        the ``points`` array object changed (streaming absorbs are
        append-only, so existing rows are immutable within a generation);
        a token change invalidates everything (compaction remapped ids).

        ``eligible`` is a filtered query's (N,) bool point mask: the emitted
        blocks scope their mask/counts to eligible pairs (``n_eligible``
        set), while subsets, cache keys, and packed tiles stay
        filter-independent — the same dispatch under a different filter
        reuses every cache entry and ships only fresh eligibility words."""


class NumpyBackend(DistanceBackend):
    """float64 control-plane backend: exact, loops subset by subset."""

    name = "numpy"

    def pairwise(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        self.stats.dispatches += 1
        return pairwise_l2_numpy(a, b)

    def self_join_blocks(self, points: np.ndarray,
                         id_lists: Sequence[np.ndarray],
                         radii: Sequence[float],
                         keys: Sequence[bytes] | None = None,
                         generation: int | None = None,
                         eligible: np.ndarray | None = None
                         ) -> list[DistanceBlock]:
        t0 = time.perf_counter()
        out = []
        for ids, r in zip(id_lists, radii):
            pts = points[ids]
            dist = self.pairwise(pts, pts)
            n_elig = None
            if eligible is None:
                count = int((dist <= r).sum()) if np.isfinite(r) else dist.size
            else:
                # Mirror the device fold: counts cover eligible pairs only,
                # so the empty-join signal fires at the filtered selectivity.
                el = eligible[ids]
                n_elig = int(el.sum())
                pair_ok = el[:, None] & el[None, :]
                count = int(((dist <= r) & pair_ok).sum()) \
                    if np.isfinite(r) else int(pair_ok.sum())
            self.stats.subsets += 1
            self.stats.points_packed += len(pts)
            self.stats.join_pairs += count
            out.append(DistanceBlock(n=len(pts), dist=dist, slack=0.0,
                                     rescore=False, join_count=count,
                                     n_eligible=n_elig))
        self.stats.t_dispatch_s += time.perf_counter() - t0
        return out


class PallasBackend(DistanceBackend):
    """Fused device backend: one batched threshold-join dispatch per call.

    Subset counts and pad widths are rounded up (``quantum``) so repeated
    scales reuse compiled programs instead of retracing per shape. A call
    whose packed (S, P, P) on-device join block would exceed
    ``max_block_bytes`` (the fallback stage can pack near-corpus-sized
    subsets for many queries at once) is split into size-bounded chunks —
    still one dispatch per chunk, and a single dispatch in the common
    per-scale case.

    Off-TPU the fused dispatch lowers through XLA (``kernels.ops`` routes by
    backend; the Pallas program is the Mosaic artifact, its interpreter a
    debugging tool). ``cache_bytes`` bounds the packed-subset/tile LRU.

    ``plane`` (a :class:`~repro.core.device_plane.DevicePlane`) makes
    multi-device execution a property of this backend: a size-binned dispatch
    that packs at least one subset per mesh shard is routed through the
    plane's ``shard_map`` join — subsets sharded on S over the ``data`` axis,
    packed bitmasks + join counts gathered back on readback, per-shard
    utilisation recorded in the stats. Remainder bins (fewer subsets than
    shards) keep the single-device dispatch; the per-shard math is identical
    either way, so blocks are bit-exact across routes.
    """

    name = "pallas"

    def __init__(self, *, bm: int = 128, bn: int = 128,
                 interpret: bool | None = None, quantum: int = 8,
                 max_block_bytes: int = 256 << 20,
                 cache_bytes: int = 128 << 20,
                 plane=None) -> None:
        super().__init__()
        self.bm = bm
        self.bn = bn
        self.interpret = interpret
        self.quantum = quantum
        self.max_block_bytes = max_block_bytes
        self.cache_bytes = cache_bytes
        self.plane = plane
        # LRU over both per-subset packed rows and whole device-committed
        # dispatch tiles; values are (nbytes, payload). Entries are only
        # valid for one corpus *generation*: subset keys are id bytes, so a
        # backend re-used against a remapped id space must drop the cache
        # (see ``self_join_blocks``). Within a generation the id space is
        # append-only (streaming absorbs/tombstones), so entries survive
        # corpus growth — a tombstoned id never recurs in a subset key, and
        # existing rows are immutable.
        self._cache: OrderedDict[tuple, tuple[int, tuple]] = OrderedDict()
        self._cache_nbytes = 0
        self._corpus: np.ndarray | None = None
        self._generation: int | None = None
        self._min_class: int | None = None

    # ------------------------------------------------------------------ cache
    def _cache_get(self, key: tuple):
        entry = self._cache.get(key)
        if entry is None:
            return None
        self._cache.move_to_end(key)
        return entry[1]

    def _cache_put(self, key: tuple, payload: tuple, nbytes: int) -> None:
        if nbytes > self.cache_bytes:
            return
        old = self._cache.pop(key, None)
        if old is not None:
            self._cache_nbytes -= old[0]
        self._cache[key] = (nbytes, payload)
        self._cache_nbytes += nbytes
        while self._cache_nbytes > self.cache_bytes:
            _, (dropped, _) = self._cache.popitem(last=False)
            self._cache_nbytes -= dropped
            self.stats.cache_evictions += 1

    @staticmethod
    def _slack(pts: np.ndarray) -> float:
        """Absolute L2 error bound for the fp32 ||a||^2+||b||^2-2ab identity.

        The squared-distance error is dominated by cancellation at the
        squared-norm scale S: |err_sq| <= c*eps32*S with c a small constant
        times the reduction depth (the kernel tests bound the diagonal at
        32*eps*S). sqrt is monotone, so |err_dist| <= sqrt(err_sq); we take
        c = 64 + 4d for headroom across accumulation orders.
        """
        if pts.size == 0:
            return 0.0
        d = pts.shape[1]
        s_norm = float((pts.astype(np.float64) ** 2).sum(axis=1).max())
        return float(np.sqrt((64.0 + 4.0 * d) * _EPS32 * s_norm))

    def pairwise(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        from repro.kernels import ops
        self.stats.dispatches += 1
        sq, _ = ops.pairwise_l2_join(np.asarray(a, np.float32),
                                     np.asarray(b, np.float32),
                                     bm=self.bm, bn=self.bn,
                                     interpret=self.interpret)
        return np.sqrt(np.asarray(sq, np.float64))

    def _round(self, n: int) -> int:
        q = self.quantum
        return max(q, ((n + q - 1) // q) * q)

    def _subset_rows(self, points: np.ndarray, ids: np.ndarray,
                     key: bytes | None) -> tuple[np.ndarray, float]:
        """fp32 rows + fp32 slack for one subset, through the LRU."""
        if key is not None:
            hit = self._cache_get(("subset", key))
            if hit is not None:
                self.stats.cache_hits += 1
                return hit
        rows = np.ascontiguousarray(points[ids], dtype=np.float32)
        payload = (rows, self._slack(rows))
        if key is not None:
            self.stats.cache_misses += 1
            self._cache_put(("subset", key), payload, rows.nbytes)
        return payload

    def _class_pad(self, n: int) -> int:
        """Size class for one subset: next power of two >= max(n, floor).
        Pow2 classes bound both pad waste (< 2x the valid points) and the
        number of compiled program shapes. On TPU the floor is the kernel
        tile ``bm`` (Mosaic pads every block to it anyway, so sub-tile
        classes would only add dispatches); the XLA lowering uses exact
        shapes, so small classes genuinely save compute there."""
        if self._min_class is None:
            import jax
            self._min_class = self.bm if jax.default_backend() == "tpu" \
                else max(self.quantum, 1)
        p = self._min_class
        while p < n:
            p <<= 1
        return p

    def _purge_cache(self, generation_bump: bool) -> None:
        if self._cache:
            self.stats.generation_purges += int(generation_bump)
        self._cache.clear()
        self._cache_nbytes = 0

    def self_join_blocks(self, points: np.ndarray,
                         id_lists: Sequence[np.ndarray],
                         radii: Sequence[float],
                         keys: Sequence[bytes] | None = None,
                         generation: int | None = None,
                         eligible: np.ndarray | None = None
                         ) -> list[DistanceBlock]:
        if not len(id_lists):
            return []
        if keys is None:
            keys = [None] * len(id_lists)
        # Cache entries are keyed on subset-id bytes, which only identify
        # points *within one corpus generation*. A generation-aware caller
        # (the streaming engine) keeps entries live across absorbs — the
        # merged points array is re-realized per batch, but ids are
        # append-only and rows immutable until a compaction bumps the token.
        # Legacy callers (no token) fall back to array-identity invalidation.
        if generation is not None:
            if generation != self._generation:
                self._purge_cache(generation_bump=self._generation is not None)
                self._generation = generation
            self._corpus = points
        elif self._corpus is not points:
            self._purge_cache(generation_bump=False)
            self._generation = None
            self._corpus = points
        # Size-binned dispatch: padding every subset of a scale to the batch
        # max wastes quadratically (a single near-corpus subset makes every
        # tiny one pay its P^2); pow2 size classes keep padded cells < 4x the
        # valid ones at a handful of dispatches per scale. Within a class,
        # chunk so one dispatch's (S, P, P) on-device join block stays under
        # the memory budget. Result order matches the task order.
        classes: dict[int, list[int]] = {}
        blocks: list[DistanceBlock | None] = [None] * len(id_lists)
        for i, ids in enumerate(id_lists):
            if not np.isfinite(radii[i]):
                # An infinite pruning radius joins every pair by construction
                # (fresh queues at scale 0): the mask is all-ones, so skip the
                # device round-trip and synthesize the trivial block. The
                # enumeration stage prunes with its live r_k instead. Under a
                # filter the all-ones adjacency covers eligible pairs only —
                # same contract as the device fold.
                n = len(ids)
                n_elig = None if eligible is None else int(eligible[ids].sum())
                pairs = n * n if n_elig is None else n_elig * n_elig
                self.stats.subsets += 1
                self.stats.points_packed += n
                self.stats.join_pairs += pairs
                blocks[i] = DistanceBlock(n=n, slack=0.0, rescore=True,
                                          join_count=pairs, n_eligible=n_elig)
                continue
            classes.setdefault(self._class_pad(len(ids)), []).append(i)
        budget = max(1, self.max_block_bytes // 4)
        for p_pad, idxs in sorted(classes.items()):
            # Budget the *padded* subset count: _dispatch rounds it up to
            # quantum for shape reuse, so floor max_s to a quantum multiple
            # (falling back to unrounded single-subset dispatches when even
            # one quantum of this class would blow the budget).
            max_s = budget // (p_pad * p_pad)
            if max_s >= self.quantum:
                max_s = (max_s // self.quantum) * self.quantum
            max_s = max(1, max_s)
            for c0 in range(0, len(idxs), max_s):
                chunk = idxs[c0:c0 + max_s]
                out = self._dispatch(points, [id_lists[i] for i in chunk],
                                     [radii[i] for i in chunk],
                                     [keys[i] for i in chunk], p_pad,
                                     eligible)
                for i, b in zip(chunk, out):
                    blocks[i] = b
        return blocks

    def _dispatch(self, points: np.ndarray, id_lists: Sequence[np.ndarray],
                  radii: Sequence[float], keys: Sequence[bytes | None],
                  p_pad: int,
                  eligible: np.ndarray | None = None) -> list[DistanceBlock]:
        from repro.kernels import ops
        import jax.numpy as jnp

        t0 = time.perf_counter()
        n_subsets = len(id_lists)
        lengths = np.fromiter((len(ids) for ids in id_lists), np.int32,
                              count=n_subsets)
        # Route over the device plane when the bin packs at least one subset
        # per shard; thinner bins (the remainder after chunking) stay on a
        # single device — sharding them would only ship empty slabs.
        plane = self.plane
        sharded = plane is not None and n_subsets >= plane.n_shards
        s_pad = self._round(n_subsets)
        if sharded:
            s_pad = plane.shard_pad(s_pad)
        budget_cells = max(1, self.max_block_bytes // 4)
        if s_pad * p_pad * p_pad > budget_cells:
            # Shape-reuse rounding must not blow the budget. Sharding needs a
            # shard multiple; if even the minimal one is over budget, the bin
            # drops to the single-device route at its exact size.
            s_pad = plane.shard_pad(n_subsets) if sharded else n_subsets
            if sharded and s_pad * p_pad * p_pad > budget_cells:
                sharded = False
                s_pad = n_subsets

        tile_key = None if any(k is None for k in keys) \
            else ("tile", tuple(keys), s_pad, p_pad, sharded)
        lens_pad = np.zeros(s_pad, np.int32)
        lens_pad[:n_subsets] = lengths
        cached_tile = self._cache_get(tile_key) if tile_key else None
        if cached_tile is not None:
            # Packed tiles already live on the device: skip gather, packing,
            # and H2D entirely; only the radii change between calls. Slacks
            # ride in the payload, so the hit path touches no per-subset
            # state at all. Hit/miss counters are per *subset* (a tile hit
            # serves every subset it packs), so cache_hit_rate reads as the
            # fraction of subset packs avoided.
            self.stats.cache_hits += n_subsets
            x_dev, lens_dev, slacks = cached_tile
            # Keep the per-subset row entries warm too: a long streak of
            # tile hits must not LRU-starve them, or a later re-binning
            # (chunk boundaries shift when radii tighten) re-packs rows the
            # cache nominally still held. Recency touch only — the hit
            # counter above already accounts for these subsets.
            for key in keys:
                if ("subset", key) in self._cache:
                    self._cache.move_to_end(("subset", key))
        else:
            slacks = np.zeros(n_subsets, np.float64)
            d = points.shape[1]
            x = np.zeros((s_pad, p_pad, d), np.float32)
            for i, (ids, key) in enumerate(zip(id_lists, keys)):
                rows, slacks[i] = self._subset_rows(points, ids, key)
                x[i, : len(ids)] = rows
            if sharded:
                # Commit the tile scattered over the mesh's data axis so the
                # sharded dispatch starts from the right placement (a cached
                # sharded tile stays resident exactly where it will be used).
                x_dev, lens_dev = plane.put_sharded(x, lens_pad)
            else:
                x_dev = jnp.asarray(x)
                lens_dev = jnp.asarray(lens_pad)
            if tile_key is not None:
                self._cache_put(tile_key, (x_dev, lens_dev, slacks),
                                x.nbytes + slacks.nbytes)

        # Pruning radius r + slack, rounded *up* to fp32 so the device
        # comparison can never be tighter than the published slack contract.
        r = np.zeros(s_pad, np.float32)
        r_mask = np.asarray(radii, np.float64) + slacks
        with np.errstate(over="ignore"):    # nextafter(f32max) saturates to inf
            r[:n_subsets] = np.nextafter(r_mask.astype(np.float32),
                                         np.float32(np.inf))
        r[:n_subsets][~np.isfinite(r_mask)] = np.float32(np.inf)
        # Filtered dispatch: pack each subset's eligibility bits into the
        # mask word layout. These words are the *only* extra traffic a filter
        # adds — the tile (cached or not) is filter-independent, and the
        # readback stays the same packed mask.
        elig_words = el_counts = None
        if eligible is not None:
            el = np.zeros((s_pad, p_pad), dtype=bool)
            for i, ids in enumerate(id_lists):
                el[i, : len(ids)] = eligible[ids]
            el_counts = el.sum(axis=1).astype(np.int64)
            elig_words = pack_join_mask(el)        # (s_pad, ceil(p_pad/32))
        self.stats.t_pack_s += time.perf_counter() - t0
        self.stats.h2d_bytes += r.nbytes + \
            (elig_words.nbytes if elig_words is not None else 0) + \
            (0 if cached_tile is not None
             else x.nbytes + lens_pad.nbytes)

        t1 = time.perf_counter()
        if sharded:
            mask, cnt = plane.join_batched_masked(
                x_dev, lens_dev, r, elig_words, bm=self.bm, bn=self.bn,
                interpret=self.interpret)
        else:
            mask, cnt = ops.pairwise_l2_join_batched_masked(
                x_dev, lens_dev, r, elig_words, bm=self.bm, bn=self.bn,
                interpret=self.interpret)
        mask = np.asarray(mask)
        counts = np.asarray(cnt)
        dt = time.perf_counter() - t1
        self.stats.t_dispatch_s += dt
        self.stats.d2h_bytes += mask.nbytes + counts.nbytes

        self.stats.dispatches += 1
        self.stats.subsets += n_subsets
        self.stats.points_packed += int(lengths.sum())
        self.stats.points_padded += s_pad * p_pad - int(lengths.sum())
        self.stats.join_pairs += int(counts[:n_subsets].sum())
        if sharded:
            # Per-shard accounting: every device participated; utilisation is
            # valid vs total join-block cells on each shard's slab.
            self.stats.sharded_dispatches += 1
            self.stats.t_collective_s += dt
            n_sh = plane.n_shards
            self.stats.ensure_shards(n_sh)
            valid, total = plane.shard_cells(lens_pad, p_pad)
            for i in range(n_sh):
                self.stats.shard_dispatches[i] += 1
                self.stats.shard_valid_cells[i] += valid[i]
                self.stats.shard_total_cells[i] += total[i]
        else:
            # Single-device dispatch lands on the default device (shard 0 of
            # the plane when one is attached).
            self.stats.ensure_shards(max(1, plane.n_shards if plane else 1))
            self.stats.shard_dispatches[0] += 1
            self.stats.shard_valid_cells[0] += int(
                (lengths.astype(np.int64) ** 2).sum())
            self.stats.shard_total_cells[0] += s_pad * p_pad * p_pad

        out = []
        for i, ids in enumerate(id_lists):
            n = len(ids)
            words = (n + 31) // 32
            out.append(DistanceBlock(
                n=n, mask=mask[i, :n, :words], slack=float(slacks[i]),
                rescore=True, join_count=int(counts[i]),
                n_eligible=None if el_counts is None else int(el_counts[i])))
        return out


def get_backend(spec: str | DistanceBackend, **kw) -> DistanceBackend:
    """Resolve a backend name ("numpy" | "pallas") or pass an instance through."""
    if isinstance(spec, DistanceBackend):
        return spec
    if spec == "numpy":
        return NumpyBackend()
    if spec == "pallas":
        return PallasBackend(**kw)
    raise ValueError(f"unknown distance backend: {spec!r}")
