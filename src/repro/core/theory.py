"""Statistical models from the paper (§VI approximation bound, §VII pruning).

Implements eqs. 4-7 plus the Monte-Carlo estimators the paper used to
instantiate them (candidate-diameter pmf f_r, bin-containment probability
Pr(A|r)). Drives benchmarks `tab2_pruning` and the ProMiSH-A ratio bound.
"""
from __future__ import annotations

import numpy as np

from repro.core import brute_force
from repro.core.types import KeywordDataset


def keyword_pmf(dataset: KeywordDataset) -> np.ndarray:
    """f_v: empirical keyword probability mass function."""
    counts = np.diff(dataset.ikp.offsets).astype(np.float64)
    return counts / max(counts.sum(), 1.0)


def total_candidates(dataset: KeywordDataset, query) -> float:
    """Eq. 4: N_n = prod_i f_v(v_Qi) * N  (the paper's t=1 model)."""
    f_v = keyword_pmf(dataset)
    out = float(dataset.n)
    for v in query:
        out *= float(f_v[v])
    return out


def candidate_diameter_pmf(dataset: KeywordDataset, query, bins: int = 50,
                           max_candidates: int = 200_000, seed: int = 0):
    """f_r: histogram of candidate diameters, normalised to [0, 1] diameters.

    Enumerates (or samples, beyond ``max_candidates``) candidates and returns
    (bin_centers, pmf, r_star, diam_scale).
    """
    rng = np.random.default_rng(seed)
    groups = [dataset.ikp.row(v) for v in query]
    sizes = np.array([len(g) for g in groups], dtype=np.int64)
    if (sizes == 0).any():
        raise ValueError("query keyword with no points")
    total = int(np.prod(sizes.astype(np.float64)))
    diams = []
    if total <= max_candidates:
        for ids in brute_force.enumerate_candidates(dataset, query):
            diams.append(brute_force.set_diameter(ids, dataset))
    else:
        for _ in range(max_candidates):
            ids = tuple(sorted(set(int(rng.choice(g)) for g in groups)))
            diams.append(brute_force.set_diameter(ids, dataset))
    diams = np.asarray(diams, dtype=np.float64)
    r_star = float(diams.min())
    scale = float(diams.max()) or 1.0
    hist, edges = np.histogram(diams / scale, bins=bins, range=(0.0, 1.0))
    pmf = hist / max(hist.sum(), 1)
    centers = 0.5 * (edges[:-1] + edges[1:])
    return centers, pmf, r_star, scale


def containment_probability(points: np.ndarray, width: float, n_vectors: int = 4096,
                            overlapping: bool = False, seed: int = 0) -> float:
    """Pr(A|r): probability over random unit vectors that all points of A fall
    in one bin of width ``width``.

    Non-overlapping bins (ProMiSH-A / §VI model): same floor(p/w) for all.
    Overlapping bins (ProMiSH-E): containment in either bin plane.
    """
    rng = np.random.default_rng(seed)
    d = points.shape[1]
    z = rng.standard_normal((n_vectors, d)).astype(np.float64)
    z /= np.linalg.norm(z, axis=1, keepdims=True)
    p = points.astype(np.float64) @ z.T                     # (|A|, V)
    b1 = np.floor(p / width)
    same1 = (b1 == b1[:1]).all(axis=0)
    if not overlapping:
        return float(same1.mean())
    b2 = np.floor((p - width / 2.0) / width)
    same2 = (b2 == b2[:1]).all(axis=0)
    return float((same1 | same2).mean())


def expected_explored(dataset: KeywordDataset, query, m: int, width: float,
                      n_vectors: int = 1024, max_candidates: int = 20_000,
                      seed: int = 0) -> tuple[float, float]:
    """Eq. 7: N_p = sum_r Pr(A|r)^m * N_r, returned with measured N_n.

    Estimated by summing Pr(A|r)^m over enumerated/sampled candidates directly
    (the histogram of eq. 5 taken at its finest granularity).
    """
    rng = np.random.default_rng(seed)
    cands = list(brute_force.enumerate_candidates(dataset, query))
    if len(cands) > max_candidates:
        sel = rng.choice(len(cands), size=max_candidates, replace=False)
        sample = [cands[i] for i in sel]
        scale_up = len(cands) / max_candidates
    else:
        sample = cands
        scale_up = 1.0
    n_p = 0.0
    for ids in sample:
        pr = containment_probability(dataset.points[np.asarray(ids)], width,
                                     n_vectors=n_vectors, seed=seed)
        n_p += pr ** m
    return n_p * scale_up, float(len(cands))


def retrieval_probability(diams: np.ndarray, pr_fn, m: int, r_star: float,
                          r_prime: float) -> float:
    """Eq. 6: P(r') = 1 - prod_{r* <= r <= r'} (1 - Pr(A|r)^m)^{N_r}.

    ``diams`` are candidate diameters; ``pr_fn(r)`` evaluates Pr(A|r).
    """
    mask = (diams >= r_star) & (diams <= r_prime)
    log_miss = 0.0
    for r in np.unique(diams[mask]):
        n_r = int((diams == r).sum())
        p = min(max(pr_fn(float(r)) ** m, 0.0), 1.0 - 1e-12)
        log_miss += n_r * np.log1p(-p)
    return 1.0 - float(np.exp(log_miss))


def approximation_ratio_bound(dataset: KeywordDataset, query, m: int, width: float,
                              lam: float = 0.8, n_vectors: int = 512,
                              seed: int = 0) -> float:
    """rho* = r'/r* for the smallest r' with P(r') >= lambda (§VI)."""
    cands = list(brute_force.enumerate_candidates(dataset, query))
    diams = np.array([brute_force.set_diameter(ids, dataset) for ids in cands])
    order = np.argsort(diams)
    diams_sorted = diams[order]
    cands_sorted = [cands[i] for i in order]
    r_star = float(diams_sorted[0]) or 1e-9
    cache: dict[int, float] = {}

    def pr_fn_idx(i: int) -> float:
        if i not in cache:
            cache[i] = containment_probability(
                dataset.points[np.asarray(cands_sorted[i])], width,
                n_vectors=n_vectors, seed=seed)
        return cache[i]

    log_miss = 0.0
    for i, r in enumerate(diams_sorted):
        p = min(max(pr_fn_idx(i) ** m, 0.0), 1.0 - 1e-12)
        log_miss += np.log1p(-p)
        if 1.0 - np.exp(log_miss) >= lam:
            return float(max(r, r_star) / r_star)
    return float(diams_sorted[-1] / r_star)
