"""repro.core — the paper's contribution: ProMiSH NKS search.

Public API:
    make_dataset, KeywordDataset, Candidate, TopK
    merge_tenants, TenantNamespace (multi-tenant corpora)
    Filter, Clause, where (attribute predicates / filtered NKS)
    build_index, PromishIndex
    promish_e.search / promish_a.search / brute_force.search
    plan (batched bucket planning) / backend (distance backends)
    VirtualBRTree (reference baseline)
"""
from repro.core.types import (Candidate, KeywordDataset, TenantNamespace,  # noqa: F401
                              TopK, make_dataset, merge_tenants)
from repro.core.filters import Clause, Filter, where  # noqa: F401
from repro.core.index import HIStructure, PromishIndex, build_index  # noqa: F401
from repro.core import backend, plan, promish_e, promish_a, brute_force, theory  # noqa: F401
from repro.core.baseline_tree import VirtualBRTree  # noqa: F401
from repro.core.subset_search import search_in_subset  # noqa: F401
