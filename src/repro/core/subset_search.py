"""Search within a subset of points (paper §V, Algorithms 3-4).

Given a subset F' (points from one hash bucket filtered by the query bitset),
find all candidates tighter than the current k-th diameter:

  1. group F' by query keyword                      (step 2-5 of Alg. 3)
  2. pairwise inner joins at threshold r_k          (steps 6-18) — this is the
     dense hot spot; the distance matrix comes from a
     ``repro.core.backend.DistanceBackend`` (numpy on the control plane, the
     fused Pallas threshold-join kernel on device),
  3. greedy least-edge group ordering               (steps 19-30; optimal is NP-hard),
  4. pruned nested-loop multi-way join              (Alg. 4), updating the
     top-k queue as tighter candidates appear.

The module is split into two stages so a batch pipeline can run them apart:

  * a *distance stage* — the backend produces one dense self-distance block
    per subset (batched into a single device dispatch by the Pallas backend);
  * a *host enumeration stage* — :func:`enumerate_with_distances` consumes a
    precomputed block. Approximate (fp32) blocks carry a pruning ``slack`` and
    set ``rescore``, in which case surviving tuples are re-scored through the
    exact float64 path before entering the queue, keeping results bit-equal to
    the pure-numpy pipeline.

:func:`search_in_subset` composes both stages for the classic per-query path.
"""
from __future__ import annotations

from typing import Callable, Sequence

import numpy as np

from repro.core.types import Candidate, KeywordDataset, TopK

# distance backend fn: (A:(n,d), B:(m,d)) -> (n,m) float L2 distances
DistanceFn = Callable[[np.ndarray, np.ndarray], np.ndarray]


def pairwise_l2_numpy(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Reference pairwise Euclidean distance (control-plane backend).

    float64 throughout: the ||a||^2+||b||^2-2ab identity cancels
    catastrophically in float32 for coordinates ~1e4 (diagonal errors up to
    ~sqrt(40)); the fp32 Pallas kernel is therefore used only as a *pruning*
    filter, with candidate diameters re-scored through this exact path.
    """
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    sq = (a * a).sum(1)[:, None] + (b * b).sum(1)[None, :] - 2.0 * (a @ b.T)
    np.maximum(sq, 0.0, out=sq)
    return np.sqrt(sq, out=sq)


def group_by_keyword(f_ids: np.ndarray, query: Sequence[int],
                     dataset: KeywordDataset) -> list[np.ndarray]:
    """SL: one id-array per query keyword (a point may appear in several)."""
    groups = []
    for v in query:
        tagged = dataset.ikp.row(v)
        groups.append(f_ids[np.isin(f_ids, tagged, assume_unique=False)])
    return groups


def local_groups(f_ids: np.ndarray, query: Sequence[int],
                 dataset: KeywordDataset) -> list[np.ndarray] | None:
    """Keyword groups as *row indices into f_ids* (Alg. 3 steps 2-5), or None
    when some query keyword has no representative in the subset (no candidate
    can exist — Alg. 3 bails before any distance work)."""
    groups = group_by_keyword(f_ids, query, dataset)
    if any(len(g) == 0 for g in groups):
        return None
    local = {int(p): i for i, p in enumerate(f_ids)}
    return [np.array([local[int(p)] for p in g], dtype=np.int64) for g in groups]


def greedy_group_order(m_counts: np.ndarray) -> list[int]:
    """Greedy least-weight-edge ordering (Alg. 3 steps 19-30).

    ``m_counts[i, j]`` = number of point pairs surviving the inner join of
    groups i and j. Repeatedly take the globally smallest remaining edge and
    append its unvisited endpoints.
    """
    q = m_counts.shape[0]
    if q == 1:
        return [0]
    order: list[int] = []
    edges = [(int(m_counts[i, j]), i, j) for i in range(q) for j in range(i + 1, q)]
    edges.sort()
    for _, i, j in edges:
        if i not in order:
            order.append(i)
        if j not in order:
            order.append(j)
        if len(order) == q:
            break
    for i in range(q):          # isolated groups (no surviving pairs)
        if i not in order:
            order.append(i)
    return order


def is_minimal_candidate(ids: Sequence[int], query: Sequence[int],
                         dataset: KeywordDataset) -> bool:
    """Paper's candidate definition: covers Q and no proper subset does.
    Equivalent test: every point contributes >=1 query keyword that no other
    point in the set contributes."""
    kws = [set(int(x) for x in dataset.kw.row(p)) & set(query) for p in ids]
    for i in range(len(ids)):
        others = set().union(*(kws[j] for j in range(len(ids)) if j != i)) if len(ids) > 1 else set()
        if not (kws[i] - others):
            return False
    return True


def enumerate_with_distances(f_ids: np.ndarray, gl: list[np.ndarray],
                             query: Sequence[int], dataset: KeywordDataset,
                             pq: TopK, dist: np.ndarray, *,
                             slack: float = 0.0,
                             rescore: bool = False) -> int:
    """Host enumeration stage: Alg. 3 steps 6-30 + Alg. 4 over a precomputed
    self-distance block ``dist`` for ``f_ids``.

    ``slack`` widens every distance predicate to ``r_k + slack`` so an
    approximate (fp32 device) block never prunes a true candidate; with
    ``rescore`` the diameter of each surviving tuple is recomputed in float64
    before it is offered, so approximate blocks only ever admit *extra* work,
    never wrong results. Mutates ``pq``; returns the number of candidate
    tuples fully materialised (the N_p statistic of §VII).
    """
    q = len(query)

    r_k = pq.kth_diameter()

    # --- pairwise inner joins: count survivors per group pair ---------------
    m_counts = np.zeros((q, q), dtype=np.int64)
    for i in range(q):
        for j in range(i + 1, q):
            sub = dist[np.ix_(gl[i], gl[j])]
            m_counts[i, j] = m_counts[j, i] = int((sub <= r_k + slack).sum()) \
                if np.isfinite(r_k) else sub.size

    # --- greedy ordering -----------------------------------------------------
    order = greedy_group_order(m_counts)
    ordered_groups = [gl[i] for i in order]

    # --- nested loops with pruning (Alg. 4) ----------------------------------
    explored = 0
    # Lazy float64 self-distances for rescoring: built once per subset, on the
    # first completed tuple (a per-tuple exact_diameter would re-run the
    # pairwise build inside the innermost loop for every N_p materialisation).
    exact_dist: np.ndarray | None = None

    def offer(cur: list[int], cur_r: float, r_k: float) -> float:
        nonlocal explored, exact_dist
        explored += 1
        ids = tuple(sorted(set(int(f_ids[c]) for c in cur)))
        if rescore:
            if exact_dist is None:
                pts = dataset.points[f_ids]
                exact_dist = pairwise_l2_numpy(pts, pts)
            diam = max((float(exact_dist[a, b]) for i, a in enumerate(cur)
                        for b in cur[i + 1:]), default=0.0)
        else:
            diam = float(cur_r)
        if diam < r_k and is_minimal_candidate(ids, query, dataset):
            if pq.offer(Candidate(ids=ids, diameter=diam)):
                return pq.kth_diameter()
        return r_k

    def recurse(idx: int, cur: list[int], cur_r: float, r_k: float) -> float:
        if idx == q:
            return offer(cur, cur_r, r_k)
        last = cur[-1]
        for o in ordered_groups[idx]:
            dlast = dist[last, o]
            if dlast > r_k + slack:
                continue
            new_r = cur_r
            ok = True
            for c in cur:
                dd = dist[c, o]
                if dd > r_k + slack:
                    ok = False
                    break
                if dd > new_r:
                    new_r = dd
            if ok:
                cur.append(int(o))
                r_k = recurse(idx + 1, cur, new_r, r_k)
                cur.pop()
        return r_k

    for o in ordered_groups[0]:
        if q > 1:
            r_k = recurse(1, [int(o)], 0.0, r_k)
        else:
            ids = (int(f_ids[o]),)
            if pq.offer(Candidate(ids=ids, diameter=0.0)):
                r_k = pq.kth_diameter()
            explored += 1
    return explored


def search_in_subset(f_ids: np.ndarray, query: Sequence[int],
                     dataset: KeywordDataset, pq: TopK,
                     distance_fn: DistanceFn = pairwise_l2_numpy) -> int:
    """Algorithms 3+4, both stages fused (the per-query path). Mutates ``pq``;
    returns the number of candidate tuples fully materialised."""
    f_ids = np.unique(np.asarray(f_ids, dtype=np.int64))
    if len(f_ids) == 0:
        return 0
    gl = local_groups(f_ids, query, dataset)
    if gl is None:
        return 0
    pts = dataset.points[f_ids]
    dist = distance_fn(pts, pts)                      # (|F'|, |F'|)
    return enumerate_with_distances(f_ids, gl, query, dataset, pq, dist)
