"""Search within a subset of points (paper §V, Algorithms 3-4).

Given a subset F' (points from one hash bucket filtered by the query bitset),
find all candidates tighter than the current k-th diameter:

  1. group F' by query keyword                      (step 2-5 of Alg. 3)
  2. pairwise inner joins at threshold r_k          (steps 6-18) — this is the
     dense hot spot; the join comes from a ``repro.core.backend``
     ``DistanceBackend`` (numpy float64 on the control plane, the fused
     Pallas/XLA threshold-join on device),
  3. greedy least-edge group ordering               (steps 19-30; optimal is NP-hard),
  4. pruned multi-way join (Alg. 4), updating the top-k queue.

The join contract between the distance stage and enumeration is a **packed
adjacency bitmask**: ``mask[i, j // 32]`` bit ``j % 32`` (LSB-first) is set
iff points i and j of the subset join at the pruning radius ``r_k + slack``.
The device backend emits the mask directly (a 32x smaller readback than the
dense fp32 block); the numpy backend packs it on the host from exact float64
distances at the *current* r_k.

Algorithm 4 itself is a **vectorized frontier expansion** over that bitmask
(:func:`_frontier_tuples`): candidate prefixes live in numpy blocks, each
prefix carries the bitwise-AND of its members' adjacency rows, and extending
by the next keyword group is one bit-gather + ``np.nonzero`` — no per-element
Python until the final offers. Completed tuples are re-scored in batched
float64 (:func:`tuple_diameters_f64`, the host twin of the
``kernels.tuple_diameters`` device kernel) instead of rebuilding a dense
(|F'|, |F'|) float64 matrix per subset. Above ``frontier_limit`` materialised
prefixes the stage falls back to the classic pruned recursion
(:func:`_enumerate_recursive`), whose shrinking-r_k pruning bounds worst-case
blowup; approximate blocks only ever admit *extra* work, never wrong results.

:func:`search_in_subset` composes both stages for the classic per-query path.
"""
from __future__ import annotations

import time
from typing import Callable, Sequence

import numpy as np

from repro.core.types import Candidate, KeywordDataset, TopK
from repro.utils.csr import sorted_member

# distance backend fn: (A:(n,d), B:(m,d)) -> (n,m) float L2 distances
DistanceFn = Callable[[np.ndarray, np.ndarray], np.ndarray]

# Frontier rows above which Alg. 4 falls back to the pruned recursion: the
# frontier prunes at the (stale) dispatch-time radius, so a loose radius over
# a big subset can materialise far more prefixes than the recursion would
# visit with its live r_k.
DEFAULT_FRONTIER_LIMIT = 100_000

_BIT_SHIFTS = np.arange(32, dtype=np.uint32)


def pairwise_l2_numpy(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Reference pairwise Euclidean distance (control-plane backend).

    float64 throughout: the ||a||^2+||b||^2-2ab identity cancels
    catastrophically in float32 for coordinates ~1e4 (diagonal errors up to
    ~sqrt(40)); the fp32 Pallas kernel is therefore used only as a *pruning*
    filter, with candidate diameters re-scored through this exact path.

    Self-distance calls (``b is a``) get an exact-zero diagonal: even in
    float64 the identity leaves ~sqrt(ulp) diagonal residue, which both
    inflates repeated-point tuple diameters and excludes them from joins
    once r_k reaches 0 — the all-tie races flexible semantics must resolve
    exactly.
    """
    same = b is a
    a = np.asarray(a, dtype=np.float64)
    b = a if same else np.asarray(b, dtype=np.float64)
    sq = (a * a).sum(1)[:, None] + (b * b).sum(1)[None, :] - 2.0 * (a @ b.T)
    if same:
        np.fill_diagonal(sq, 0.0)
    np.maximum(sq, 0.0, out=sq)
    return np.sqrt(sq, out=sq)


# Shared with the index layer (tombstone masks, coverage re-verification):
# the searchsorted membership primitive now lives in ``repro.utils.csr``.
_sorted_member = sorted_member


def group_by_keyword(f_ids: np.ndarray, query: Sequence[int],
                     dataset: KeywordDataset, ctx=None) -> list[np.ndarray]:
    """SL: one id-array per query keyword (a point may appear in several).
    ``f_ids`` must be sorted (plan emits sorted unique ids); membership runs
    through searchsorted against each keyword's sorted I_kp row, or — with a
    ``ctx`` (:class:`repro.core.plan.BatchPlanContext`) — through the
    context's per-keyword corpus masks, built once per batch instead of a
    searchsorted per (subset, keyword)."""
    if ctx is not None:
        return [f_ids[ctx.kw_mask(v)[f_ids]] for v in query]
    return [f_ids[_sorted_member(f_ids, dataset.ikp.row(v))] for v in query]


def local_groups(f_ids: np.ndarray, query: Sequence[int],
                 dataset: KeywordDataset,
                 eligible: np.ndarray | None = None,
                 ctx=None) -> list[np.ndarray] | None:
    """Keyword groups as *row indices into f_ids* (Alg. 3 steps 2-5), or None
    when some query keyword has no representative in the subset (no candidate
    can exist — Alg. 3 bails before any distance work). Row indices come from
    ``np.searchsorted`` over the already-sorted ``f_ids``, or directly from
    the batch context's keyword masks when one is supplied (same rows, no
    per-task searchsorted).

    ``eligible`` (the (N,) predicate mask of a filtered query) restricts each
    group to eligible points. Enumeration only ever indexes adjacency rows
    through the groups, so this single restriction is what makes the whole
    Alg. 3/4 stage "respect the mask": ineligible points can sit in the
    subset (keeping pack/cache keys filter-independent) yet never enter a
    candidate. A group emptied by the filter bails exactly like a missing
    keyword — no eligible candidate can exist in this subset.
    """
    if ctx is not None:
        groups = []
        for v in query:
            rows = np.flatnonzero(ctx.kw_mask(v)[f_ids])
            if eligible is not None:
                rows = rows[eligible[f_ids[rows]]]
            if len(rows) == 0:
                return None
            groups.append(rows)
        return groups
    groups = group_by_keyword(f_ids, query, dataset)
    if eligible is not None:
        groups = [g[eligible[g]] for g in groups]
    if any(len(g) == 0 for g in groups):
        return None
    return [np.searchsorted(f_ids, g) for g in groups]


def greedy_group_order(m_counts: np.ndarray) -> list[int]:
    """Greedy least-weight-edge ordering (Alg. 3 steps 19-30).

    ``m_counts[i, j]`` = number of point pairs surviving the inner join of
    groups i and j. Repeatedly take the globally smallest remaining edge and
    append its unvisited endpoints.
    """
    q = m_counts.shape[0]
    if q == 1:
        return [0]
    iu, ju = _triu_indices(q)
    # stable argsort on the edge weights reproduces the classic
    # (count, i, j) tuple sort: ties keep the lexicographic (i, j) order
    # _triu_indices generates them in.
    order: list[int] = []
    seen = [False] * q
    for e in np.argsort(m_counts[iu, ju], kind="stable"):
        for v in (int(iu[e]), int(ju[e])):
            if not seen[v]:
                seen[v] = True
                order.append(v)
        if len(order) == q:
            break
    for i in range(q):          # isolated groups (no surviving pairs)
        if not seen[i]:
            order.append(i)
    return order


def is_minimal_candidate(ids: Sequence[int], query: Sequence[int],
                         dataset: KeywordDataset) -> bool:
    """Paper's candidate definition: covers Q and no proper subset does.
    Equivalent test: every point contributes >=1 query keyword that no other
    point in the set contributes."""
    kws = [set(int(x) for x in dataset.kw.row(p)) & set(query) for p in ids]
    for i in range(len(ids)):
        others = set().union(*(kws[j] for j in range(len(ids)) if j != i)) if len(ids) > 1 else set()
        if not (kws[i] - others):
            return False
    return True


# --------------------------------------------------------------- bitmask join
def pack_join_mask(adj: np.ndarray) -> np.ndarray:
    """(n, m) bool adjacency -> (n, ceil(m/32)) uint32, LSB-first per word.

    The host-side twin of the kernel's packed-mask output: bit ``j % 32`` of
    ``mask[i, j // 32]`` is ``adj[i, j]``; bits past ``m`` are zero.
    """
    n, m = adj.shape
    w = max((m + 31) // 32, 1)
    bits = np.zeros((n, w * 32), dtype=np.uint32)
    bits[:, :m] = adj
    return (bits.reshape(n, w, 32) << _BIT_SHIFTS).sum(axis=2, dtype=np.uint32)


def unpack_join_mask(mask: np.ndarray, n_cols: int) -> np.ndarray:
    """(n, W) uint32 packed adjacency -> (n, n_cols) uint8 0/1 matrix.

    One ``np.unpackbits`` call: the little-endian byte view of each uint32
    word yields bits in exactly column order (LSB-first contract)."""
    bytes_view = np.ascontiguousarray(mask).view(np.uint8)
    return np.unpackbits(bytes_view, axis=1, bitorder="little",
                         count=n_cols)


_TRIU_CACHE: dict[int, tuple[np.ndarray, np.ndarray]] = {}


def _triu_indices(q: int) -> tuple[np.ndarray, np.ndarray]:
    out = _TRIU_CACHE.get(q)
    if out is None:
        out = _TRIU_CACHE[q] = np.triu_indices(q, 1)
    return out


def pair_counts(adj: np.ndarray, groups: list[np.ndarray]) -> np.ndarray:
    """Inner-join edge weights M[vi, vj] (Alg. 3 steps 6-18): survivors of
    the join between each group pair, counted on the 0/1 adjacency. One
    column-sum per group over its adjacency rows, then a gather per pair —
    O(q*n + q^2*|g|) instead of a (|gi|, |gj|) slice per pair."""
    q = len(groups)
    m_counts = np.zeros((q, q), dtype=np.int64)
    if q < 2:
        return m_counts
    colsum = [adj[g].sum(axis=0, dtype=np.int64) for g in groups]
    for i in range(q):
        ci = colsum[i]
        for j in range(i + 1, q):
            m_counts[i, j] = m_counts[j, i] = int(ci[groups[j]].sum())
    return m_counts


def _frontier_tuples(adj: np.ndarray, ordered_groups: list[np.ndarray],
                     limit: int, pts: np.ndarray | None = None,
                     thr: float = np.inf, d2: np.ndarray | None = None,
                     w: np.ndarray | None = None
                     ) -> tuple[np.ndarray, np.ndarray | None] | None:
    """Vectorized Alg. 4: expand candidate prefixes group-by-group over the
    join adjacency. Each frontier row keeps the bitwise-AND of its members'
    adjacency rows, so the extension test for the next group is one column
    gather; ``np.nonzero``'s row-major order preserves the recursion's
    lexicographic enumeration order.

    With ``pts`` (float64 subset coordinates), every adjacency-surviving
    extension is additionally *refined* against exact float64 distances at
    ``thr`` — the live r_k at subset start. This recovers the recursion's
    live-radius pruning that a dispatch-time mask cannot encode (the mask
    radius is a stale upper bound), and yields each completed tuple's
    diameter for free as the running max of refined pair distances.

    ``d2`` (a precomputed (n, n) float64 *squared*-distance matrix over the
    subset) replaces the per-extension einsum with a table gather — cheaper
    than recomputing coordinate differences whenever total candidate pairs
    exceed the n^2 build cost, which the caller decides by subset size.

    ``w`` (per-row weights for the streaming ``pts`` path; a weighted caller
    using ``d2`` pre-scales the table instead) folds flexible-semantics
    keyword weights into the refinement: each squared pair distance is
    multiplied by the pair's weight product before the max/threshold, so the
    returned diameters are weighted costs — identical arithmetic to the
    pre-scaled table and the oracle.

    Returns ``(tuples (T, q), diams (T,) | None)``, or None once the frontier
    exceeds ``limit`` (caller falls back to the pruned recursion)."""
    g0 = np.asarray(ordered_groups[0], dtype=np.int64)
    prefix = g0[:, None]
    compat = adj[g0]
    thr2 = thr * thr
    refine = pts is not None or d2 is not None
    d2max = np.zeros(len(g0)) if refine else None
    for g in ordered_groups[1:]:
        g = np.asarray(g, dtype=np.int64)
        fi, gj = np.nonzero(compat[:, g])
        if fi.size > limit:
            return None
        cand = g[gj]
        if refine:
            if d2 is not None:
                d2new = d2[prefix[fi], cand[:, None]].max(axis=1)   # (C, i) -> (C,)
            else:
                diff = pts[prefix[fi]] - pts[cand][:, None, :]      # (C, i, d)
                d2new = np.einsum("cid,cid->ci", diff, diff)
                if w is not None:
                    d2new = d2new * (w[prefix[fi]] * w[cand][:, None])
                d2new = d2new.max(axis=1)
            d2new = np.maximum(d2new, d2max[fi])
            keep = d2new <= thr2
            fi, cand, d2max = fi[keep], cand[keep], d2new[keep]
        prefix = np.concatenate([prefix[fi], cand[:, None]], axis=1)
        compat = compat[fi] & adj[cand]
    return prefix, (np.sqrt(d2max) if refine else None)


def tuple_diameters_f64(pts: np.ndarray) -> np.ndarray:
    """(T, q, d) float64 -> (T,) max pairwise L2 distances.

    Batched float64 rescore for frontier tuples — the host twin of the
    ``kernels.tuple_diameters`` device kernel, kept in float64 because the
    enumeration contract requires exact diameters before the top-k queue.
    """
    pts = np.asarray(pts, dtype=np.float64)
    sq = np.einsum("tqd,tqd->tq", pts, pts)
    gram = np.einsum("tqd,trd->tqr", pts, pts)
    d2 = np.maximum(sq[:, :, None] + sq[:, None, :] - 2.0 * gram, 0.0)
    return np.sqrt(d2.max(axis=(1, 2)))


# ------------------------------------------------------------------- offers
def _offer_singletons(rows: np.ndarray, f_ids: np.ndarray,
                      query: Sequence[int], dataset: KeywordDataset,
                      pq: TopK, gate: bool) -> int:
    """Offer one-point candidates (diameter 0) for every row whose point
    covers the whole query — the only tuples Alg. 4 can produce when the
    inner join has no off-diagonal pairs. ``gate`` applies the recursion's
    offer predicate (diam < r_k plus minimality); the q=1 fast path offers
    ungated, exactly as Alg. 4's base case does."""
    for o in rows:
        ids = (int(f_ids[o]),)
        if not gate:
            pq.offer(Candidate(ids=ids, diameter=0.0))
        elif 0.0 < pq.kth_diameter() and is_minimal_candidate(ids, query, dataset):
            pq.offer(Candidate(ids=ids, diameter=0.0))
    return len(rows)


def _offer_tuples(tuples: np.ndarray, diams: np.ndarray, f_ids: np.ndarray,
                  query: Sequence[int], dataset: KeywordDataset,
                  pq: TopK) -> None:
    """Offer completed tuples in enumeration order. The vectorized prefilter
    uses the entry r_k (an upper bound of the running r_k); the live gate
    re-checks against the current k-th diameter exactly as the recursion's
    ``offer`` does."""
    for i in np.flatnonzero(diams < pq.kth_diameter()):
        diam = float(diams[i])
        if diam >= pq.kth_diameter():
            continue
        ids = tuple(sorted(set(int(x) for x in f_ids[tuples[i]])))
        if is_minimal_candidate(ids, query, dataset):
            pq.offer(Candidate(ids=ids, diameter=diam))


# ----------------------------------------------------- recursion (fallback)
def _enumerate_recursive(f_ids: np.ndarray, ordered_groups: list[np.ndarray],
                         query: Sequence[int], dataset: KeywordDataset,
                         pq: TopK, dist: np.ndarray, slack: float,
                         rescore: bool) -> int:
    """Alg. 4's pruned nested loops — the above-``frontier_limit`` fallback.
    Prunes with the *live* r_k (tightening after every successful offer), so
    worst-case blowup stays bounded where the frontier's dispatch-time radius
    would not."""
    q = len(query)
    r_k = pq.kth_diameter()
    explored = 0
    # Lazy float64 self-distances for rescoring: built once per subset, on the
    # first completed tuple.
    exact_dist: np.ndarray | None = None

    def offer(cur: list[int], cur_r: float, r_k: float) -> float:
        nonlocal explored, exact_dist
        explored += 1
        ids = tuple(sorted(set(int(f_ids[c]) for c in cur)))
        if rescore:
            if exact_dist is None:
                pts = dataset.points[f_ids]
                exact_dist = pairwise_l2_numpy(pts, pts)
            diam = max((float(exact_dist[a, b]) for i, a in enumerate(cur)
                        for b in cur[i + 1:]), default=0.0)
        else:
            diam = float(cur_r)
        if diam < r_k and is_minimal_candidate(ids, query, dataset):
            if pq.offer(Candidate(ids=ids, diameter=diam)):
                return pq.kth_diameter()
        return r_k

    def recurse(idx: int, cur: list[int], cur_r: float, r_k: float) -> float:
        if idx == q:
            return offer(cur, cur_r, r_k)
        last = cur[-1]
        for o in ordered_groups[idx]:
            dlast = dist[last, o]
            if dlast > r_k + slack:
                continue
            new_r = cur_r
            ok = True
            for c in cur:
                dd = dist[c, o]
                if dd > r_k + slack:
                    ok = False
                    break
                if dd > new_r:
                    new_r = dd
            if ok:
                cur.append(int(o))
                r_k = recurse(idx + 1, cur, new_r, r_k)
                cur.pop()
        return r_k

    for o in ordered_groups[0]:
        r_k = recurse(1, [int(o)], 0.0, r_k)
    return explored


# ------------------------------------------------------- enumeration stages
def enumerate_with_distances(f_ids: np.ndarray, gl: list[np.ndarray],
                             query: Sequence[int], dataset: KeywordDataset,
                             pq: TopK, dist: np.ndarray, *,
                             slack: float = 0.0,
                             rescore: bool = False,
                             frontier_limit: int = DEFAULT_FRONTIER_LIMIT,
                             weights: np.ndarray | None = None) -> int:
    """Host enumeration over a dense self-distance block ``dist``.

    Packs the join mask at the *current* ``r_k + slack`` and runs the
    vectorized frontier; ``slack`` widens the predicate so an approximate
    (fp32 device) block never prunes a true candidate, and ``rescore``
    recomputes surviving diameters in float64 so approximate blocks only ever
    admit *extra* work, never wrong results. Mutates ``pq``; returns the
    number of candidate tuples fully materialised (the N_p statistic of
    §VII).

    ``weights`` ((N,) float64 per-point keyword weights, all >= 1) switches
    the objective to the weighted cost: the *geometric* ``dist``-derived
    mask keeps pruning (it is a superset of the weighted join — weighted
    cost dominates geometric diameter), while settlement runs through
    :func:`_enumerate_weighted`'s float64 weighted tables, exactly like the
    mask path.
    """
    q = len(query)
    if q == 1:
        return _offer_singletons(gl[0], f_ids, query, dataset, pq,
                                  gate=False)

    r_k = pq.kth_diameter()
    thr = r_k + slack
    adj = dist <= thr if np.isfinite(thr) \
        else np.ones(dist.shape, dtype=bool)
    # Self-distances are exactly 0, but the norms-identity arithmetic leaves
    # ~sqrt(ulp) noise on the diagonal of ``dist`` — enough to exclude
    # repeated-point (singleton) tuples once r_k reaches 0. Those tuples only
    # matter in all-tie races, but flexible semantics resolve ties by key,
    # so the diagonal must reflect the true zero.
    np.fill_diagonal(adj, True)
    order = greedy_group_order(pair_counts(adj, gl))
    ordered_groups = [gl[i] for i in order]

    if weights is not None:
        return _enumerate_weighted(f_ids, adj, ordered_groups, query,
                                   dataset, pq, weights, frontier_limit)
    out = _frontier_tuples(adj, ordered_groups, frontier_limit)
    if out is None:
        return _enumerate_recursive(f_ids, ordered_groups, query, dataset,
                                    pq, dist, slack, rescore)
    tuples, _ = out
    if rescore:
        diams = tuple_diameters_f64(dataset.points[f_ids][tuples])
    else:
        diams = dist[tuples[:, :, None], tuples[:, None, :]].max(axis=(1, 2))
    _offer_tuples(tuples, diams, f_ids, query, dataset, pq)
    return len(tuples)


# Subset size below which the mask path precomputes the full float64
# squared-distance table for frontier refinement: the n^2*d build is cheaper
# than per-extension coordinate einsums as soon as the frontier materialises
# more candidate pairs than n^2, which small/mid subsets essentially always
# do. Large subsets keep the streaming einsum (no quadratic materialisation).
_D2_TABLE_MAX_N = 512


def _sq_dists_f64(pts: np.ndarray) -> np.ndarray:
    """(n, d) float64 -> (n, n) squared L2 distances.

    Difference-based (not the norms identity): the table must be *bitwise*
    interchangeable with the frontier's per-extension coordinate einsum, so
    it uses the same subtract-then-einsum arithmetic, chunked to bound the
    (rows, n, d) temporary."""
    n, d = pts.shape
    d2 = np.empty((n, n), dtype=np.float64)
    step = max(1, (1 << 22) // max(1, n * d))
    for i in range(0, n, step):
        diff = pts[i:i + step, None, :] - pts[None, :, :]
        d2[i:i + step] = np.einsum("ijd,ijd->ij", diff, diff)
    return d2


def _enumerate_weighted(f_ids: np.ndarray, adj: np.ndarray,
                        ordered_groups: list[np.ndarray],
                        query: Sequence[int], dataset: KeywordDataset,
                        pq: TopK, weights: np.ndarray,
                        frontier_limit: int) -> int:
    """Weighted-cost settlement over a *geometric* adjacency superset.

    ``adj`` was packed at the geometric pruning radius; with all weights
    >= 1 the weighted cost dominates the geometric diameter, so every
    weighted-joining pair is present and the mask only over-admits. The
    float64 squared-distance tables are pre-scaled by the pair weight
    product (:func:`repro.core.semantics.weighted_pair_sq` arithmetic), so
    the frontier's refine-at-live-r_k and the recursion fallback both prune
    and settle directly in weighted cost."""
    pts = np.asarray(dataset.points[f_ids], dtype=np.float64)
    wloc = np.asarray(weights, dtype=np.float64)[f_ids]
    d2 = None
    if len(f_ids) <= _D2_TABLE_MAX_N:
        d2 = _sq_dists_f64(pts) * (wloc[:, None] * wloc[None, :])
    out = _frontier_tuples(adj, ordered_groups, frontier_limit,
                           pts=None if d2 is not None else pts,
                           thr=pq.kth_diameter(), d2=d2,
                           w=None if d2 is not None else wloc)
    if out is None:
        if d2 is None:
            d2 = _sq_dists_f64(pts) * (wloc[:, None] * wloc[None, :])
        return _enumerate_recursive(f_ids, ordered_groups, query, dataset,
                                    pq, np.sqrt(d2), 0.0, False)
    tuples, diams = out
    _offer_tuples(tuples, diams, f_ids, query, dataset, pq)
    return len(tuples)


def enumerate_with_block(f_ids: np.ndarray, gl: list[np.ndarray],
                         query: Sequence[int], dataset: KeywordDataset,
                         pq: TopK, block, *,
                         frontier_limit: int = DEFAULT_FRONTIER_LIMIT,
                         timers: dict | None = None,
                         weights: np.ndarray | None = None) -> int:
    """Host enumeration over a backend ``DistanceBlock``.

    Dense blocks re-pack the mask at the live r_k; mask-only device blocks
    are consumed as-is (their mask is fixed at the dispatch-time pruning
    radius, a safe superset of the live one). A block whose inner join has no
    off-diagonal pair at the dispatch radius short-circuits to the singleton
    scan — the adaptive-radii feedback that skips host enumeration for
    subsets the kernel already proved empty (the coarse bf16 prune tier
    lands here too: a pruned block carries ``join_count <= n_live`` and is
    never unpacked). Mutates ``pq``; returns N_p.

    ``block.rows`` marks an eligible-dense device block (low-selectivity
    packing): the mask covers only the subset-local eligible row positions
    in ``rows``, so groups — already restricted to eligible points — are
    remapped into that packed row space before the adjacency is consumed.

    ``timers`` (optional dict) accumulates ``rescore_s``: wall time in the
    float64 settlement of surviving tuples (table build + refine/recursion),
    the cascade's exact tier.

    ``weights`` ((N,) per-point keyword weights, all >= 1) routes settlement
    through :func:`_enumerate_weighted` — the geometric mask stays a valid
    superset of the weighted join, all the short-circuits below (diagonal
    bound, singleton scan) are weight-invariant, and the unweighted path is
    byte-identical to before.
    """
    if block.dist is not None:
        return enumerate_with_distances(
            f_ids, gl, query, dataset, pq, block.dist, slack=block.slack,
            rescore=block.rescore, frontier_limit=frontier_limit,
            weights=weights)

    q = len(query)
    if q == 1:
        return _offer_singletons(gl[0], f_ids, query, dataset, pq,
                                  gate=False)

    n_live = block.n if getattr(block, "n_eligible", None) is None \
        else block.n_eligible
    if block.join_count <= n_live:
        # Only diagonal (self) pairs join: the multi-way join can only emit
        # single repeated points, i.e. points present in every keyword group.
        # With an eligibility mask folded into the block, counts cover only
        # eligible pairs, so the diagonal bound is the *eligible* point count.
        common = gl[0]
        for g in gl[1:]:
            common = common[_sorted_member(common, g)]
        return _offer_singletons(common, f_ids, query, dataset, pq,
                                  gate=True)

    rows = getattr(block, "rows", None)
    if rows is not None:
        # Eligible-dense block: translate groups (subset-local rows, all
        # eligible by construction) into the packed eligible-row space and
        # restrict the id/coordinate view to the packed rows.
        gl = [np.searchsorted(rows, g) for g in gl]
        f_ids = f_ids[rows]
    n_adj = block.n if rows is None else len(rows)
    # mask=None marks an infinite-radius block (all pairs join by
    # construction; the backend skipped the device round-trip).
    adj = np.ones((n_adj, n_adj), dtype=np.uint8) if block.mask is None \
        else unpack_join_mask(block.mask, n_adj)
    # Device-packed masks can drop the diagonal to fp32 noise at near-zero
    # dispatch radii; self-pairs always join (d(p,p) = 0), and the all-tie
    # races of flexible semantics depend on the resulting singleton tuples.
    np.fill_diagonal(adj, 1)
    # Live-row restriction: the expansion only ever consults rows that are
    # members of some keyword group — the rest of the subset exists solely
    # to have joined on the device. Restricting the adjacency, coordinates,
    # and the float64 table to the group union shrinks the dominant
    # settlement cost from |subset|^2 to |live|^2 without changing a single
    # value (every distance entry depends only on its own row pair).
    live = np.unique(np.concatenate(gl))
    if len(live) < n_adj:
        remap = np.empty(n_adj, np.int64)
        remap[live] = np.arange(len(live))
        gl = [remap[g] for g in gl]
        f_ids = f_ids[live]
        adj = adj[np.ix_(live, live)]
        n_adj = len(live)
    order = greedy_group_order(pair_counts(adj, gl))
    ordered_groups = [gl[i] for i in order]
    t0 = time.perf_counter() if timers is not None else 0.0
    if weights is not None:
        explored = _enumerate_weighted(f_ids, adj, ordered_groups, query,
                                       dataset, pq, weights, frontier_limit)
        if timers is not None:
            timers["rescore_s"] = timers.get("rescore_s", 0.0) \
                + time.perf_counter() - t0
        return explored
    pts = np.asarray(dataset.points[f_ids], dtype=np.float64)
    d2 = _sq_dists_f64(pts) if n_adj <= _D2_TABLE_MAX_N else None
    # The mask prunes at the (stale) dispatch radius; the float64 refine
    # inside the expansion re-prunes at the live r_k and hands back exact
    # diameters, subsuming the batched rescore.
    out = _frontier_tuples(adj, ordered_groups, frontier_limit,
                           pts=None if d2 is not None else pts,
                           thr=pq.kth_diameter(), d2=d2)
    if out is None:
        # Mask too loose for vectorized expansion: rebuild exact float64
        # distances and run the live-r_k recursion (no slack, no rescore).
        # Always through pairwise_l2_numpy — the recursion's historical
        # distance source — so fallback results stay bit-identical.
        dist = pairwise_l2_numpy(pts, pts)
        explored = _enumerate_recursive(f_ids, ordered_groups, query, dataset,
                                        pq, dist, 0.0, False)
        if timers is not None:
            timers["rescore_s"] = timers.get("rescore_s", 0.0) \
                + time.perf_counter() - t0
        return explored
    tuples, diams = out
    if timers is not None:
        timers["rescore_s"] = timers.get("rescore_s", 0.0) \
            + time.perf_counter() - t0
    _offer_tuples(tuples, diams, f_ids, query, dataset, pq)
    return len(tuples)


def search_in_subset(f_ids: np.ndarray, query: Sequence[int],
                     dataset: KeywordDataset, pq: TopK,
                     distance_fn: DistanceFn = pairwise_l2_numpy,
                     eligible: np.ndarray | None = None,
                     weights: np.ndarray | None = None) -> int:
    """Algorithms 3+4, both stages fused (the per-query path). Mutates ``pq``;
    returns the number of candidate tuples fully materialised. ``eligible``
    applies a filtered query's point-eligibility mask (see
    :func:`local_groups`); ``weights`` switches settlement to the weighted
    cost (see :func:`enumerate_with_distances`)."""
    f_ids = np.unique(np.asarray(f_ids, dtype=np.int64))
    if len(f_ids) == 0:
        return 0
    gl = local_groups(f_ids, query, dataset, eligible=eligible)
    if gl is None:
        return 0
    pts = dataset.points[f_ids]
    dist = distance_fn(pts, pts)                      # (|F'|, |F'|)
    return enumerate_with_distances(f_ids, gl, query, dataset, pq, dist,
                                    weights=weights)
