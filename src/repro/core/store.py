"""Out-of-core columnar store: mmap-tiered corpus + index + zone maps.

The paper's scalability claim (§VIII: linear to millions of points) needs the
bulk structures out of RAM. This module owns the on-disk layout and the
query-time synopsis consultation:

  * **Columnar leaves** — one ``.npy`` per flat array (points, CSR keyword
    lists and their offsets sidecars, per-scale bucket tables), fsync'd at
    write and loadable either resident or memory-mapped
    (``np.load(mmap_mode="r")``). A memmapped leaf is the *cold tier*: the
    OS pages in only the rows a query's bucket gathers touch, and the
    backend's byte-bounded packed-tile LRU is the hot tier above it.
  * **Per-bucket synopses** (:class:`~repro.core.index.BucketSynopsis`) —
    point counts, bounding radii, and per-attribute min/max zone maps, built
    at ``build_index(synopsis=True)`` time and persisted as small resident
    leaves. :class:`ZoneMapPruner` turns a query's
    :class:`~repro.core.filters.Filter` into per-bucket reject verdicts the
    planner applies *before* materialising member lists or eligibility
    bitmasks.
  * **Atomic store trees** — ``save_store``/``load_store`` write/read a full
    ``{dataset, index_e, index_a, build_params}`` tree with the same
    write-to-temp + fsync + rename discipline as WAL snapshots (the snapshot
    code in ``serve.wal`` builds on the same leaf helpers, which live here).

Everything the pruner consults is a conservative superset of the bucket's
bulk contents, so pruning can only skip work: a zone-rejected bucket provably
holds no eligible point, and a bucket whose diameter bound already beats the
live ``r_k`` joins all-pairs anyway (the dispatcher's infinite-radius fast
path) — results are bit-identical with pruning on or off.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile

import numpy as np

from repro.core.index import (BucketSynopsis, HIStructure, PromishIndex,
                              build_index)
from repro.core.types import KeywordDataset, TenantNamespace
from repro.utils.csr import CSR


def fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


# ------------------------------------------------------------------- leaf I/O
def save_arr(root: str, name: str, arr: np.ndarray, manifest: dict) -> None:
    arr = np.ascontiguousarray(arr)
    # fsync each leaf: the tree's atomicity story is write-to-temp + fsync +
    # rename, and once an older epoch is GC'd a page-cached-only leaf would
    # be the sole copy of acknowledged data.
    with open(os.path.join(root, f"{name}.npy"), "wb") as f:
        np.save(f, arr)
        f.flush()
        os.fsync(f.fileno())
    manifest[name] = {"sha256": hashlib.sha256(arr.tobytes()).hexdigest(),
                      "dtype": arr.dtype.str, "shape": list(arr.shape)}


def load_arr(root: str, name: str, manifest: dict, *, mmap: bool,
             verify: bool) -> np.ndarray:
    path = os.path.join(root, f"{name}.npy")
    try:
        arr = np.load(path, mmap_mode="r" if mmap else None)
    except (OSError, ValueError, EOFError) as e:
        # Missing, truncated, or header-corrupt leaf: surface one exception
        # type with enough context to name the damaged file.
        raise IOError(f"store leaf {name!r} unreadable at {path}: {e}") from e
    ent = manifest.get(name)
    if ent is not None and list(arr.shape) != list(ent["shape"]):
        raise IOError(f"store leaf {name!r} at {path} has shape "
                      f"{list(arr.shape)}, manifest says {ent['shape']} "
                      f"(truncated or tampered)")
    if verify:
        got = hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()
        if got != manifest[name]["sha256"]:
            raise IOError(f"store leaf {name!r} failed its checksum "
                          f"(root={root})")
    return arr


def save_csr(root: str, name: str, csr: CSR, manifest: dict) -> None:
    save_arr(root, f"{name}.offsets", csr.offsets, manifest)
    save_arr(root, f"{name}.values", csr.values, manifest)


def load_csr(root: str, name: str, manifest: dict, *, mmap: bool,
             verify: bool) -> CSR:
    return CSR(offsets=load_arr(root, f"{name}.offsets", manifest,
                                mmap=mmap, verify=verify),
               values=load_arr(root, f"{name}.values", manifest,
                               mmap=mmap, verify=verify))


# ------------------------------------------------------------ dataset / index
def save_dataset(root: str, dataset: KeywordDataset, manifest: dict) -> dict:
    """Persist a frozen corpus into ``root``; returns its meta dict."""
    save_arr(root, "points", dataset.points, manifest)
    save_csr(root, "kw", dataset.kw, manifest)
    save_csr(root, "ikp", dataset.ikp, manifest)
    meta = {"n": dataset.n, "dim": dataset.dim,
            "n_keywords": dataset.n_keywords,
            "attrs": sorted(dataset.attrs) if dataset.attrs else [],
            "tenant_of": dataset.tenant_of is not None, "tenants": None}
    for name in meta["attrs"]:
        save_arr(root, f"attr_{name}", dataset.attrs[name], manifest)
    if dataset.tenant_of is not None:
        save_arr(root, "tenant_of", dataset.tenant_of, manifest)
    if dataset.tenants is not None:
        meta["tenants"] = {
            "names": list(dataset.tenants.names),
            "kw_offsets": [int(v) for v in dataset.tenants.kw_offsets]}
    return meta


def load_dataset(root: str, meta: dict, manifest: dict, *, mmap: bool,
                 verify: bool) -> KeywordDataset:
    attrs = {name: np.asarray(load_arr(root, f"attr_{name}", manifest,
                                       mmap=mmap, verify=verify))
             for name in meta["attrs"]} or None
    tenant_of = load_arr(root, "tenant_of", manifest, mmap=mmap,
                         verify=verify) if meta["tenant_of"] else None
    tenants = None
    if meta["tenants"]:
        tenants = TenantNamespace(
            names=tuple(meta["tenants"]["names"]),
            kw_offsets=np.asarray(meta["tenants"]["kw_offsets"], np.int64))
    return KeywordDataset(
        points=load_arr(root, "points", manifest, mmap=mmap, verify=verify),
        kw=load_csr(root, "kw", manifest, mmap=mmap, verify=verify),
        ikp=load_csr(root, "ikp", manifest, mmap=mmap, verify=verify),
        n_keywords=int(meta["n_keywords"]), attrs=attrs,
        tenant_of=tenant_of, tenants=tenants)


def save_index(root: str, prefix: str, index: PromishIndex,
               manifest: dict) -> dict:
    """Persist one frozen index flavour under ``root`` with ``prefix``."""
    save_arr(root, f"{prefix}.z", index.z, manifest)
    scales = []
    for hi in index.structures:
        save_csr(root, f"{prefix}.s{hi.scale}.table", hi.table, manifest)
        save_csr(root, f"{prefix}.s{hi.scale}.khb", hi.khb, manifest)
        syn_meta = None
        if hi.synopsis is not None:
            syn = hi.synopsis
            base = f"{prefix}.s{hi.scale}.syn"
            save_arr(root, f"{base}.counts", syn.counts, manifest)
            save_arr(root, f"{base}.radius", syn.radius, manifest)
            for name in sorted(syn.attr_min):
                save_arr(root, f"{base}.min_{name}", syn.attr_min[name],
                         manifest)
                save_arr(root, f"{base}.max_{name}", syn.attr_max[name],
                         manifest)
            has_tenant = syn.tenant_min is not None
            if has_tenant:
                save_arr(root, f"{base}.tenant_min", syn.tenant_min, manifest)
                save_arr(root, f"{base}.tenant_max", syn.tenant_max, manifest)
            syn_meta = {"attrs": sorted(syn.attr_min), "tenant": has_tenant}
        scales.append({"scale": hi.scale, "width": hi.width,
                       "n_buckets": hi.n_buckets, "synopsis": syn_meta})
    return {"w0": index.w0, "n_scales": index.n_scales, "exact": index.exact,
            "p_max": index.p_max, "scales": scales}


def _load_synopsis(root: str, base: str, syn_meta: dict,
                   manifest: dict, *, verify: bool) -> BucketSynopsis:
    # Synopses are consulted per covering bucket on every query — always
    # resident (they are tiny next to the leaves they let us skip).
    def _r(name):
        return np.asarray(load_arr(root, f"{base}.{name}", manifest,
                                   mmap=False, verify=verify))
    attr_min = {name: _r(f"min_{name}") for name in syn_meta["attrs"]}
    attr_max = {name: _r(f"max_{name}") for name in syn_meta["attrs"]}
    tenant_min = tenant_max = None
    if syn_meta["tenant"]:
        tenant_min, tenant_max = _r("tenant_min"), _r("tenant_max")
    return BucketSynopsis(counts=_r("counts"), radius=_r("radius"),
                          attr_min=attr_min, attr_max=attr_max,
                          tenant_min=tenant_min, tenant_max=tenant_max)


def load_index(root: str, prefix: str, meta: dict, manifest: dict, *,
               mmap: bool, verify: bool) -> PromishIndex:
    structures = []
    for sc in meta["scales"]:
        syn_meta = sc.get("synopsis")
        syn = _load_synopsis(root, f"{prefix}.s{sc['scale']}.syn", syn_meta,
                             manifest, verify=verify) \
            if syn_meta is not None else None
        structures.append(HIStructure(
            scale=sc["scale"], width=sc["width"], n_buckets=sc["n_buckets"],
            table=load_csr(root, f"{prefix}.s{sc['scale']}.table", manifest,
                           mmap=mmap, verify=verify),
            khb=load_csr(root, f"{prefix}.s{sc['scale']}.khb", manifest,
                         mmap=mmap, verify=verify),
            synopsis=syn))
    return PromishIndex(
        z=load_arr(root, f"{prefix}.z", manifest, mmap=mmap, verify=verify),
        w0=meta["w0"], n_scales=meta["n_scales"], exact=meta["exact"],
        structures=tuple(structures), p_max=meta["p_max"])


# ------------------------------------------------------------ store trees
def save_store(directory: str, *, dataset: KeywordDataset,
               index_e: PromishIndex | None = None,
               index_a: PromishIndex | None = None,
               build_params: dict | None = None) -> str:
    """Atomically write a corpus + index tree to ``directory``.

    Same discipline as WAL snapshots: write-to-temp + per-leaf fsync +
    rename, so a crash mid-write can never leave a half store that
    ``load_store`` would pick up.
    """
    parent = os.path.dirname(os.path.abspath(directory)) or "."
    os.makedirs(parent, exist_ok=True)
    tmp = tempfile.mkdtemp(prefix=".tmp-store-", dir=parent)
    try:
        manifest: dict = {}
        meta = {
            "format": 1,
            "kind": "store",
            "dataset": save_dataset(tmp, dataset, manifest),
            "index_e": (save_index(tmp, "e", index_e, manifest)
                        if index_e is not None else None),
            "index_a": (save_index(tmp, "a", index_a, manifest)
                        if index_a is not None else None),
            "build_params": dict(build_params or {}),
            "leaves": manifest,
        }
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        fsync_dir(tmp)
        if os.path.exists(directory):
            shutil.rmtree(directory)
        os.rename(tmp, directory)
        fsync_dir(parent)
        return directory
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def load_store(directory: str, *, mmap: bool = True,
               verify: bool = False) -> dict:
    """Load a store tree -> {dataset, index_e, index_a, build_params}.

    ``mmap=True`` (the default — the whole point of the store) maps every
    bulk leaf instead of reading it resident; ``verify=True`` checksums each
    leaf against the manifest (a full read, defeating laziness — meant for
    integrity audits, not serving).
    """
    meta_path = os.path.join(directory, "meta.json")
    try:
        with open(meta_path) as f:
            meta = json.load(f)
    except (OSError, ValueError) as e:
        raise IOError(f"store meta unreadable at {meta_path}: {e}") from e
    manifest = meta["leaves"]
    out = {
        "dataset": load_dataset(directory, meta["dataset"], manifest,
                                mmap=mmap, verify=verify),
        "index_e": None, "index_a": None,
        "build_params": meta.get("build_params", {}),
    }
    for flavour in ("e", "a"):
        imeta = meta[f"index_{flavour}"]
        if imeta is not None:
            out[f"index_{flavour}"] = load_index(
                directory, flavour, imeta, manifest, mmap=mmap, verify=verify)
    return out


def build_store(directory: str, dataset: KeywordDataset, *, m: int = 2,
                n_scales: int = 5, seed: int = 0, w0: float | None = None,
                n_buckets: int | None = None, build_exact: bool = True,
                build_approx: bool = True, synopsis: bool = True) -> str:
    """Build both index flavours (with synopses) over ``dataset`` and persist
    the whole tree — the bulk-load path of the out-of-core engine.

    The recorded ``build_params`` are exactly the engine's pinned geometry
    (``m``/``n_scales``/``seed``/``w0``/``n_buckets``/``synopsis``), so an
    engine opened with :meth:`~repro.serve.engine.NKSEngine.from_store`
    streams and compacts bit-identically to a RAM engine built with the same
    parameters.
    """
    bp = dict(m=m, n_scales=n_scales, seed=seed, w0=w0, n_buckets=n_buckets,
              synopsis=synopsis)
    index_e = build_index(dataset, exact=True, **bp) if build_exact else None
    index_a = build_index(dataset, exact=False, **bp) if build_approx else None
    return save_store(directory, dataset=dataset, index_e=index_e,
                      index_a=index_a, build_params=bp)


def store_nbytes(directory: str) -> int:
    """Total on-disk size of the store's leaves (the cold-tier footprint)."""
    total = 0
    for name in os.listdir(directory):
        if name.endswith(".npy"):
            total += os.path.getsize(os.path.join(directory, name))
    return total


# ------------------------------------------------------------- zone-map prune
def _as_number(v) -> float | None:
    if isinstance(v, bool) or not isinstance(v, (int, float, np.integer,
                                                 np.floating)):
        return None
    return float(v)


class ZoneMapPruner:
    """Per-bucket reject verdicts for one filtered batch.

    Built once per ``query_batch`` from the batch's
    :class:`~repro.core.filters.Filter`; :meth:`reject` is then consulted per
    scale with the covering-bucket list. A bucket is rejected only when some
    conjunctive clause is *provably empty* against the bucket's zone map —
    e.g. ``price < v`` rejects a bucket whose ``min(price) >= v``. Non-numeric
    clauses (categorical equality on string columns) and attributes without a
    zone map simply never reject; NaN bounds compare ``False`` everywhere, so
    they never reject either. Empty buckets carry inverted ranges
    (min=+inf, max=-inf) and reject under every clause — harmless, the
    planner would have skipped them on emptiness anyway.
    """

    def __init__(self, flt, dataset):
        self._clauses = []
        for c in (flt.clauses or ()):
            if c.op == "between":
                lo, hi = c.value
                ok = _as_number(lo) is not None and _as_number(hi) is not None
            elif c.op == "in":
                vals = list(c.value)
                ok = bool(vals) and all(_as_number(v) is not None
                                        for v in vals)
            else:
                ok = _as_number(c.value) is not None
            if ok:
                self._clauses.append(c)
        self._tenant: int | None = None
        if flt.tenant is not None:
            try:
                ns = getattr(dataset, "tenants", None)
                self._tenant = int(ns.id_of(flt.tenant)) if ns is not None \
                    else int(flt.tenant)
            except (KeyError, TypeError, ValueError):
                self._tenant = None      # evaluate() is the authority; no prune

    @property
    def active(self) -> bool:
        return bool(self._clauses) or self._tenant is not None

    def reject(self, synopsis: BucketSynopsis | None,
               buckets) -> np.ndarray | None:
        """Boolean reject mask aligned with ``buckets`` (True = provably no
        eligible point in the bucket's bulk part), or None when this scale
        has no synopsis to consult."""
        if synopsis is None or not self.active:
            return None
        b = np.asarray(buckets, dtype=np.int64)
        rej = np.zeros(len(b), dtype=bool)
        for c in self._clauses:
            amin_col = synopsis.attr_min.get(c.attr)
            if amin_col is None:
                continue
            amin, amax = amin_col[b], synopsis.attr_max[c.attr][b]
            op, v = c.op, c.value
            if op == "<":
                r = amin >= v
            elif op == "<=":
                r = amin > v
            elif op == ">":
                r = amax <= v
            elif op == ">=":
                r = amax < v
            elif op == "==":
                r = (v < amin) | (v > amax)
            elif op == "!=":
                # Only provably empty when the bucket is constant at v.
                r = (amin == v) & (amax == v)
            elif op == "between":
                lo, hi = c.value
                r = (amax < lo) | (amin > hi)
            else:                        # "in" (values normalised + sorted)
                r = (amax < c.value[0]) | (amin > c.value[-1])
            rej |= r
        if self._tenant is not None and synopsis.tenant_min is not None:
            rej |= (synopsis.tenant_max[b] < self._tenant) \
                | (synopsis.tenant_min[b] > self._tenant)
        return rej
