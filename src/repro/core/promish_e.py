"""ProMiSH-E: exact NKS search (paper §IV, Algorithm 1).

Scale loop over the HI structures; per scale:
  * the plan layer (:mod:`repro.core.plan`) selects covering buckets, filters
    them through the query bitset BS, and dedups subsets (Algorithm 2
    semantics — an exact set-hash on the sorted id bytes, which is Algorithm 2
    with a perfect hash: identical semantics, no false positives),
  * each planned subset runs subset search (§V).
Terminates at the first scale where the k-th diameter r_k <= w/2 = w0*2^(s-1);
Lemma 2 then guarantees every tighter candidate was already contained in some
explored bucket. Falls back to a full search over the relevant points if no
scale terminates (steps 33-39).

This is the single-query path (a plan batch of one). The batched serving
pipeline in ``repro.serve.engine`` shares the same plan layer and fuses all
subsets of a scale into one device dispatch.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core import plan
from repro.core.index import PromishIndex
from repro.core.semantics import QuerySemantics
from repro.core.subset_search import DistanceFn, pairwise_l2_numpy, search_in_subset
from repro.core.types import KeywordDataset, TopK

# Re-exported for callers that predate the plan layer.
query_bitset = plan.query_bitset
_covering_buckets = plan.covering_buckets


@dataclasses.dataclass
class SearchStats:
    """Instrumentation for the paper's §VII/§VIII measurements."""

    buckets_selected: int = 0
    subsets_searched: int = 0
    duplicate_subsets: int = 0
    filtered_subsets: int = 0      # predicate-pruned subsets (filtered NKS)
    buckets_pruned_zonemap: int = 0  # zone-map-skipped buckets (plan layer)
    candidates_explored: int = 0   # N_p
    scales_visited: int = 0
    fallback: bool = False


def search(dataset: KeywordDataset, index: PromishIndex, query: Sequence[int],
           k: int = 1, distance_fn: DistanceFn = pairwise_l2_numpy,
           stats: SearchStats | None = None,
           eligible: np.ndarray | None = None,
           semantics=None) -> TopK:
    """Exact top-k NKS search. Returns the priority queue PQ.

    ``eligible`` is an (N,) bool point-eligibility mask (from
    ``core.filters.Filter.evaluate``): the search then answers over the
    filtered sub-corpus exactly — ineligible points are pruned from planning
    (whole subsets when fully ineligible) and from every keyword group, so
    they can never enter a candidate, while the Lemma-2 termination bound is
    unaffected (the filtered corpus is a subset of the indexed one, so every
    tight candidate still lies in some explored bucket).

    ``semantics`` (a :class:`repro.core.semantics.QuerySemantics` or its
    wire-dict form) enables m-of-k coverage, keyword weights, and scored
    ranking via :func:`_search_flex`; degenerate semantics (full coverage,
    unit weights, no scoring) fall straight through to the classic loop, so
    results stay bit-identical to a plain call.
    """
    if not index.exact:
        raise ValueError("ProMiSH-E requires an exact (overlapping-bin) index")
    query = sorted(set(int(v) for v in query))
    if any(v < 0 or v >= dataset.n_keywords for v in query):
        raise ValueError("query keyword outside dictionary")
    stats = stats if stats is not None else SearchStats()
    sem = QuerySemantics.coerce(semantics)
    if sem is not None and not sem.trivial_for(query):
        return _search_flex(dataset, index, query, k, sem,
                            distance_fn, stats, eligible, exact=True)

    pq = TopK(k, init_full=True)
    bitsets = [query_bitset(dataset, query)]
    explored: dict[int, set[bytes]] = {0: set()}   # HC of Algorithm 2

    for s in range(index.n_scales):
        stats.scales_visited += 1
        for task in plan.plan_scale(index, s, [query], bitsets, [0],
                                    explored, stats, eligible=eligible):
            stats.subsets_searched += 1
            stats.candidates_explored += search_in_subset(
                task.f_ids, query, dataset, pq, distance_fn=distance_fn,
                eligible=eligible)
        # Termination (steps 29-31): r_k <= w0 * 2^(s-1)
        if pq.kth_diameter() <= index.w0 * (2.0 ** (s - 1)):
            return pq

    # Fallback: search all relevant points (steps 33-39).
    stats.fallback = True
    for task in plan.fallback_tasks(bitsets, [0], eligible=eligible):
        stats.candidates_explored += search_in_subset(
            task.f_ids, query, dataset, pq, distance_fn=distance_fn,
            eligible=eligible)
    return pq


def _search_flex(dataset: KeywordDataset, index: PromishIndex,
                 query: list[int], k: int, sem: QuerySemantics,
                 distance_fn: DistanceFn, stats: SearchStats,
                 eligible: np.ndarray | None, exact: bool):
    """Flexible-semantics scale loop shared by ProMiSH-E and ProMiSH-A.

    The query expands into its m-of-k subqueries; each runs the existing
    plan/subset-search machinery verbatim — its own bitset, its own
    Algorithm-2 explored set (E only), minimality judged against its own
    keyword subset — all feeding ONE shared queue (classic or scored, from
    ``sem.make_pq``). Candidate costs and coverage depend only on (ids, Q),
    so the queue's id-set dedup resolves cross-subquery duplicates exactly.

    Termination is unchanged: weighted costs dominate geometric diameters
    (weights >= 1), so a candidate with cost below the Lemma-2 scale bound
    has geometric diameter below it too and was contained in some explored
    bucket of its subquery; ``ScoredTopK.kth_diameter`` converts the k-th
    score into the equivalent cost bound.
    """
    subqueries = sem.expand_subqueries(query)
    wvec = sem.weight_vector(dataset, query)
    pq = sem.make_pq(dataset, query, k, init_full=exact)
    bitsets = [plan.query_bitset(dataset, sub) for sub in subqueries]
    active = list(range(len(subqueries)))
    explored = {i: set() for i in active} if exact else None

    for s in range(index.n_scales):
        stats.scales_visited += 1
        for task in plan.plan_scale(index, s, subqueries, bitsets, active,
                                    explored, stats, eligible=eligible):
            stats.subsets_searched += 1
            stats.candidates_explored += search_in_subset(
                task.f_ids, subqueries[task.qidx], dataset, pq,
                distance_fn=distance_fn, eligible=eligible, weights=wvec)
        if exact:
            if pq.kth_diameter() <= index.w0 * (2.0 ** (s - 1)):
                return pq
        elif pq.full():
            return pq

    stats.fallback = True
    for task in plan.fallback_tasks(bitsets, active, eligible=eligible):
        stats.candidates_explored += search_in_subset(
            task.f_ids, subqueries[task.qidx], dataset, pq,
            distance_fn=distance_fn, eligible=eligible, weights=wvec)
    return pq
