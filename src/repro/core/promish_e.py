"""ProMiSH-E: exact NKS search (paper §IV, Algorithm 1).

Scale loop over the HI structures; per scale:
  * select hash buckets whose keyword set covers the whole query
    (inverted-index intersection, steps 10-16),
  * filter each bucket through the query bitset BS to get a subset F'
    (steps 17-22),
  * dedup subsets (Algorithm 2 semantics — we key an exact set-hash on the
    sorted id bytes, which is Algorithm 2 with a perfect hash: identical
    semantics, no false positives) and run subset search (§V).
Terminates at the first scale where the k-th diameter r_k <= w/2 = w0*2^(s-1);
Lemma 2 then guarantees every tighter candidate was already contained in some
explored bucket. Falls back to a full search over the relevant points if no
scale terminates (steps 33-39).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.index import PromishIndex
from repro.core.subset_search import DistanceFn, pairwise_l2_numpy, search_in_subset
from repro.core.types import KeywordDataset, TopK


@dataclasses.dataclass
class SearchStats:
    """Instrumentation for the paper's §VII/§VIII measurements."""

    buckets_selected: int = 0
    subsets_searched: int = 0
    duplicate_subsets: int = 0
    candidates_explored: int = 0   # N_p
    scales_visited: int = 0
    fallback: bool = False


def query_bitset(dataset: KeywordDataset, query: Sequence[int]) -> np.ndarray:
    """BS: mark every point tagged with >=1 query keyword (Alg. 1 steps 4-6)."""
    bs = np.zeros(dataset.n, dtype=bool)
    for v in query:
        bs[dataset.ikp.row(v)] = True
    return bs


def _covering_buckets(hi, query: Sequence[int]) -> np.ndarray:
    """Buckets containing all query keywords: intersect I_khb rows by counting."""
    counts = np.zeros(hi.n_buckets, dtype=np.int32)
    for v in query:
        counts[hi.khb.row(v)] += 1
    return np.flatnonzero(counts == len(query))


def search(dataset: KeywordDataset, index: PromishIndex, query: Sequence[int],
           k: int = 1, distance_fn: DistanceFn = pairwise_l2_numpy,
           stats: SearchStats | None = None) -> TopK:
    """Exact top-k NKS search. Returns the priority queue PQ."""
    if not index.exact:
        raise ValueError("ProMiSH-E requires an exact (overlapping-bin) index")
    query = sorted(set(int(v) for v in query))
    if any(v < 0 or v >= dataset.n_keywords for v in query):
        raise ValueError("query keyword outside dictionary")
    stats = stats if stats is not None else SearchStats()

    pq = TopK(k, init_full=True)
    bs = query_bitset(dataset, query)
    explored: set[bytes] = set()   # HC of Algorithm 2

    for s in range(index.n_scales):
        stats.scales_visited += 1
        hi = index.structures[s]
        for b in _covering_buckets(hi, query):
            stats.buckets_selected += 1
            pts = hi.table.row(int(b))
            f = pts[bs[pts]]
            if len(f) == 0:
                continue
            key = np.sort(f).astype(np.int64).tobytes()
            if key in explored:
                stats.duplicate_subsets += 1
                continue
            explored.add(key)
            stats.subsets_searched += 1
            stats.candidates_explored += search_in_subset(
                f, query, dataset, pq, distance_fn=distance_fn)
        # Termination (steps 29-31): r_k <= w0 * 2^(s-1)
        if pq.kth_diameter() <= index.w0 * (2.0 ** (s - 1)):
            return pq

    # Fallback: search all relevant points (steps 33-39).
    stats.fallback = True
    f = np.flatnonzero(bs)
    stats.candidates_explored += search_in_subset(f, query, dataset, pq,
                                                  distance_fn=distance_fn)
    return pq
