"""Wall-clock timing helper used by the benchmark harness."""
from __future__ import annotations

import time


class Timer:
    """Context manager measuring elapsed seconds; repeats-aware helpers."""

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.elapsed = time.perf_counter() - self.t0
        return False


def bench(fn, *args, repeats: int = 3, warmup: int = 1, **kwargs) -> float:
    """Return median seconds per call."""
    for _ in range(warmup):
        fn(*args, **kwargs)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args, **kwargs)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]
