"""Small shared utilities (timing, PRNG helpers, CSR helpers)."""
from repro.utils.csr import CSR, csr_from_lists, invert_csr  # noqa: F401
from repro.utils.timing import Timer  # noqa: F401
