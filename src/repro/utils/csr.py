"""Compressed-sparse-row helpers used by every index structure in the framework.

A ``CSR`` maps ``row id -> sorted int array of values``. It is the TPU-friendly
replacement for the paper's pointer-based hashtables / inverted indices: two
flat arrays (``offsets``, ``values``) that can be gathered on device.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class CSR:
    """offsets: (n_rows+1,) int64; values: (nnz,) int32/int64."""

    offsets: np.ndarray
    values: np.ndarray

    @property
    def n_rows(self) -> int:
        return len(self.offsets) - 1

    @property
    def nnz(self) -> int:
        return int(len(self.values))

    def row(self, i: int) -> np.ndarray:
        return self.values[self.offsets[i] : self.offsets[i + 1]]

    def row_len(self, i: int) -> int:
        return int(self.offsets[i + 1] - self.offsets[i])

    def rows(self, idx: Iterable[int]) -> list[np.ndarray]:
        return [self.row(i) for i in idx]

    def nbytes(self) -> int:
        return self.offsets.nbytes + self.values.nbytes


def csr_from_lists(lists: Sequence[Sequence[int]], dtype=np.int32) -> CSR:
    """Build a CSR from a python list-of-lists."""
    lens = np.fromiter((len(row) for row in lists), dtype=np.int64, count=len(lists))
    offsets = np.zeros(len(lists) + 1, dtype=np.int64)
    np.cumsum(lens, out=offsets[1:])
    values = np.empty(offsets[-1], dtype=dtype)
    for i, row in enumerate(lists):
        values[offsets[i] : offsets[i + 1]] = np.asarray(row, dtype=dtype)
    return CSR(offsets=offsets, values=values)


def csr_from_pairs(rows: np.ndarray, vals: np.ndarray, n_rows: int, dedup: bool = False) -> CSR:
    """Build a CSR from (row, value) pairs via a single sort.

    This is how every hashtable in the framework is assembled: the device
    produces flat (bucket_id, point_id) pairs; one sort yields the CSR.
    """
    rows = np.asarray(rows)
    vals = np.asarray(vals)
    if dedup and len(rows):
        key = rows.astype(np.int64) * (int(vals.max()) + 1 if len(vals) else 1) + vals.astype(np.int64)
        _, uniq = np.unique(key, return_index=True)
        rows, vals = rows[uniq], vals[uniq]
    order = np.argsort(rows, kind="stable")
    rows_s, vals_s = rows[order], vals[order]
    counts = np.bincount(rows_s, minlength=n_rows).astype(np.int64)
    offsets = np.zeros(n_rows + 1, dtype=np.int64)
    np.cumsum(counts, out=offsets[1:])
    return CSR(offsets=offsets, values=np.ascontiguousarray(vals_s))


def invert_csr(csr: CSR, n_values: int) -> CSR:
    """Invert a row->values CSR into value->rows (e.g. point->keywords into
    keyword->points, the paper's I_kp)."""
    rows = np.repeat(np.arange(csr.n_rows, dtype=np.int64), np.diff(csr.offsets))
    return csr_from_pairs(csr.values.astype(np.int64), rows.astype(np.int32), n_values)


def ragged_arange(counts: np.ndarray, total: int | None = None) -> np.ndarray:
    """[0..c0), [0..c1), ... concatenated — the gather index for slicing many
    CSR rows at once."""
    counts = np.asarray(counts, dtype=np.int64)
    if total is None:
        total = int(counts.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    ends = np.cumsum(counts)
    starts = ends - counts
    out = np.arange(total, dtype=np.int64)
    out -= np.repeat(starts, counts)
    return out


def sorted_member(values: np.ndarray, sorted_ref: np.ndarray) -> np.ndarray:
    """Boolean membership of ``values`` in sorted ``sorted_ref`` (both int),
    via searchsorted — no hashing, no np.unique. The membership primitive of
    every flat-array index structure here (subset grouping, tombstone masks,
    coverage re-verification)."""
    if len(sorted_ref) == 0 or len(values) == 0:
        return np.zeros(len(values), dtype=bool)
    idx = np.searchsorted(sorted_ref, values)
    idx[idx == len(sorted_ref)] = 0
    return sorted_ref[idx] == values
