"""Fault-tolerant serving runtime: admission queue, coalesced batches,
deadlines, degradation, and off-thread compaction.

``launch/serve.py``'s synchronous loop answers one request at a time and
stalls everything for the O(N) compaction rebuild. This runtime is the
production shape sitting between a frontend and :class:`NKSEngine`:

  * **bounded admission queue** — ``submit`` enqueues a request and returns a
    :class:`Ticket` (a future). A full queue *rejects immediately*
    (backpressure beats unbounded latency); per-request deadlines expire
    queued work before it wastes a dispatch, and an expired request gets a
    ``timeout`` response, never silence.
  * **coalescing worker** — one thread drives the engine. Queued queries
    with the same (tier, k, filter) are coalesced into a single
    ``query_batch`` call, amortising the plan stage exactly the way the
    batched pipeline amortises dispatch; a short batch window lets
    near-simultaneous arrivals merge.
  * **retry with backoff** — a transient dispatch failure retries up to
    ``max_retries`` with exponential backoff; retries are bounded, and a
    batch that keeps failing degrades to per-request execution so one
    poisoned request cannot sink its batchmates.
  * **graceful degradation** — past the ``degrade_watermark`` queue depth,
    exact-tier requests are shed to the approx tier (recorded per-response
    as ``degraded``) instead of letting the queue collapse.
  * **off-thread compaction** — the cadence-triggered rebuild runs on a
    background thread against the frozen view (``compact_prepare``), then
    swaps atomically under the engine lock (``compact_commit``). Queries
    never stall; ingest ops arriving mid-rebuild are *deferred* (admission
    order preserved) and flushed after the swap, so the prepared bulk can
    never silently drop an interleaved write.

Consistency model (weaker than the synchronous loop, standard for async
serving): an **acknowledged** write is visible to every query submitted
after the ack, and — with a WAL attached — survives process death. Ordering
between a query and a write whose ack the client has not yet seen is
unspecified (deferred ingest may land after a later-submitted query runs).

Fault injection (``serve.faults``) threads one deterministic
:class:`FaultPlan` through the runtime (``dispatch``), the engine
(``compact``), and the WAL (``wal_ack``); an :class:`InjectedCrash` anywhere
marks the runtime dead — every in-flight ticket resolves with status
``crashed`` and recovery happens via ``NKSEngine.recover``, exactly as a real
process death would.
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time
from collections import deque

from repro.serve.engine import NKSEngine
from repro.serve.faults import NO_FAULTS, FaultPlan, InjectedCrash, InjectedFault


class TransientDispatchError(RuntimeError):
    """Raise-to-retry marker for genuinely transient dispatch failures."""


_RETRYABLE = (InjectedFault, TransientDispatchError)


@dataclasses.dataclass
class RuntimeConfig:
    max_queue: int = 256            # admission bound (backpressure past it)
    max_batch: int = 32             # coalesced query batch cap
    batch_window_s: float = 0.002   # wait this long to let arrivals coalesce
    default_deadline_s: float | None = None   # None = no deadline
    max_retries: int = 3            # transient dispatch retries per batch
    retry_backoff_s: float = 0.005  # base backoff (doubles per attempt)
    degrade_watermark: float = 0.75  # queue fraction past which exact sheds
    tier: str = "approx"            # default tier for requests without one
    k: int = 1                      # default top-k
    backend: str = "numpy"          # distance backend for coalesced batches


@dataclasses.dataclass
class RuntimeStats:
    submitted: int = 0
    admitted: int = 0
    rejected_full: int = 0
    expired: int = 0
    completed: int = 0
    errors: int = 0
    crashed: int = 0
    batches: int = 0
    batched_queries: int = 0
    degraded_queries: int = 0
    dispatch_retries: int = 0
    dispatch_failures: int = 0      # batches that exhausted their retries
    single_fallbacks: int = 0       # per-request isolation runs
    ingest_ops: int = 0
    ingest_runs: int = 0            # multi-op runs group-committed together
    deferred_ingest: int = 0
    bg_compactions: int = 0
    bg_compaction_faults: int = 0
    bg_compaction_errors: int = 0   # unexpected rebuild exceptions survived

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    @property
    def mean_batch(self) -> float:
        return self.batched_queries / self.batches if self.batches else 0.0


@dataclasses.dataclass
class RuntimeResponse:
    """What a :class:`Ticket` resolves to.

    ``status``: ``ok`` | ``rejected`` | ``timeout`` | ``error`` | ``crashed``.
    ``payload`` carries the op-specific result (``candidates`` for queries —
    :class:`~repro.core.types.Candidate` objects, externalized ids — or the
    ingest-state dict for mutating ops). ``degraded`` marks an exact-tier
    request served at the approx tier under overload."""

    op: str
    status: str
    payload: dict = dataclasses.field(default_factory=dict)
    error: str | None = None
    degraded: bool = False
    tier: str | None = None
    latency_s: float = 0.0

    @property
    def ok(self) -> bool:
        return self.status == "ok"


class Ticket:
    """Single-use future handed back by :meth:`ServingRuntime.submit`."""

    __slots__ = ("request", "deadline", "submitted_at", "_event", "response")

    def __init__(self, request: dict, deadline: float | None):
        self.request = request
        self.deadline = deadline
        self.submitted_at = time.monotonic()
        self._event = threading.Event()
        self.response: RuntimeResponse | None = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None) -> RuntimeResponse:
        if not self._event.wait(timeout):
            raise TimeoutError("ticket not resolved within wait timeout")
        return self.response

    def _resolve(self, response: RuntimeResponse) -> None:
        response.latency_s = time.monotonic() - self.submitted_at
        self.response = response
        self._event.set()


def _filter_key(flt) -> str:
    if flt is None:
        return ""
    return json.dumps(flt, sort_keys=True) if isinstance(flt, dict) else repr(flt)


def _semantics_key(sem) -> str:
    """Canonical batch-key component for the request's flexible semantics:
    requests may only coalesce into one ``query_batch`` call when their
    m/weights/score/alpha knobs agree exactly."""
    if sem is None:
        return ""
    return json.dumps(sem, sort_keys=True) if isinstance(sem, dict) \
        else sem.canonical_key()


_INGEST_OPS = frozenset(("insert", "delete", "compact", "snapshot"))


class ServingRuntime:
    """One engine, one worker thread, one background compactor.

    The runtime takes over compaction cadence from the engine
    (``auto_compact`` is disabled while attached and restored on close):
    the same churn threshold now triggers the *background* rebuild.
    """

    def __init__(self, engine: NKSEngine, config: RuntimeConfig | None = None,
                 faults: FaultPlan | None = None):
        self.engine = engine
        self.cfg = config or RuntimeConfig()
        self.faults = faults or getattr(engine, "_faults", None) or NO_FAULTS
        self.stats = RuntimeStats()
        self._queue: deque[Ticket] = deque()
        self._deferred: list[Ticket] = []   # ingest parked during a rebuild
        self._lock = threading.Lock()           # guards queue + flags
        self._work = threading.Condition(self._lock)
        self._engine_lock = threading.Lock()    # serialises engine mutation
        self._stop = False
        self._drain = True
        self._crashed: InjectedCrash | None = None
        self._compacting = False
        self._last_compaction_error: str | None = None
        self._compact_req = threading.Event()
        self._auto_compact_was = engine.auto_compact
        engine.auto_compact = False
        self._worker = threading.Thread(target=self._worker_loop,
                                        name="nks-runtime-worker", daemon=True)
        self._compactor = threading.Thread(target=self._compactor_loop,
                                           name="nks-runtime-compactor",
                                           daemon=True)
        self._worker.start()
        self._compactor.start()

    # -------------------------------------------------------------- frontend
    def submit(self, request: dict,
               deadline_s: float | None = None) -> Ticket:
        """Admit one request; always returns a ticket (a rejected request's
        ticket is already resolved — the caller never blocks to learn of
        backpressure)."""
        op = request.get("op", "query")
        deadline = deadline_s if deadline_s is not None \
            else request.get("deadline_s", self.cfg.default_deadline_s)
        ticket = Ticket(request, time.monotonic() + deadline
                        if deadline is not None else None)
        self.stats.submitted += 1
        if op == "health":
            ticket._resolve(RuntimeResponse(op="health", status="ok",
                                            payload=self.health()))
            self.stats.completed += 1
            return ticket
        with self._lock:
            if self._crashed is not None or self._stop:
                self.stats.rejected_full += 1
                ticket._resolve(RuntimeResponse(
                    op=op, status="rejected",
                    error="runtime is down" if self._crashed is not None
                    else "runtime is shutting down"))
                return ticket
            if len(self._queue) + len(self._deferred) >= self.cfg.max_queue:
                self.stats.rejected_full += 1
                ticket._resolve(RuntimeResponse(
                    op=op, status="rejected",
                    error=f"admission queue full ({self.cfg.max_queue})"))
                return ticket
            self.stats.admitted += 1
            self._queue.append(ticket)
            self._work.notify_all()
        return ticket

    def health(self) -> dict:
        """Queue / generation / degradation snapshot (lock-free reads of
        monotone counters — advisory, not transactional)."""
        depth = len(self._queue)
        return {
            "queue_depth": depth,
            "deferred_ingest": len(self._deferred),
            "max_queue": self.cfg.max_queue,
            "degraded": self._overloaded(depth),
            "compaction_inflight": self._compacting,
            "last_compaction_error": self._last_compaction_error,
            "crashed": self._crashed is not None,
            "generation": self.engine.corpus_generation,
            "delta_points": self.engine.delta_points,
            "tombstones": self.engine.tombstone_count,
            "wal_attached": self.engine.wal_stats is not None,
            "stats": self.stats.as_dict(),
        }

    def close(self, timeout: float = 30.0, drain: bool = True) -> None:
        """Stop the runtime; ``drain`` processes the queue first. Restores
        the engine's auto-compaction."""
        with self._lock:
            self._stop = True
            self._drain = drain
            self._work.notify_all()
        self._compact_req.set()
        self._worker.join(timeout)
        self._compactor.join(timeout)
        self.engine.auto_compact = self._auto_compact_was
        # Unconditionally resolve whatever the threads left behind. Even a
        # draining close can strand tickets: ingest deferred behind an
        # in-flight compaction is flushed back into the queue by the
        # compactor's finally block *after* the worker has already drained
        # and exited — a caller blocked in ticket.result() with no timeout
        # would otherwise hang forever.
        self._fail_pending("rejected", "runtime is shutting down")

    def __enter__(self) -> "ServingRuntime":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ---------------------------------------------------------------- worker
    def _overloaded(self, depth: int) -> bool:
        return depth >= self.cfg.degrade_watermark * self.cfg.max_queue

    def _expire(self, now: float) -> None:
        """Resolve queued tickets whose deadline passed (in place)."""
        if not any(t.deadline is not None and t.deadline < now
                   for t in self._queue):
            return
        keep = deque()
        for t in self._queue:
            if t.deadline is not None and t.deadline < now:
                self.stats.expired += 1
                t._resolve(RuntimeResponse(
                    op=t.request.get("op", "query"), status="timeout",
                    error="deadline exceeded before execution"))
            else:
                keep.append(t)
        self._queue = keep

    def _worker_loop(self) -> None:
        run: list[Ticket] | None = None
        batch: list[Ticket] | None = None
        try:
            while True:
                run = batch = None
                with self._lock:
                    while not self._queue and not self._stop:
                        self._work.wait(0.05)
                        self._flush_deferred_locked()
                    if self._stop and (not self._drain or not self._queue):
                        break
                    self._expire(time.monotonic())
                    if not self._queue:
                        continue
                    head = self._queue[0]
                    hop = head.request.get("op", "query")
                    if hop in _INGEST_OPS:
                        if self._compacting:
                            # Park it: the rebuild prepared against the
                            # frozen view; an interleaved mutation would be
                            # silently dropped by the swap.
                            self._queue.popleft()
                            self._deferred.append(head)
                            self.stats.deferred_ingest += 1
                            continue
                        # A consecutive run of ingest ops at the head shares
                        # one WAL group commit: every op's record hits the
                        # log, one fsync makes the run durable, then every
                        # ack fires. Admission order is preserved — queries
                        # behind the run still see all of it.
                        run = self._gather_ingest_locked()
                    else:
                        batch = self._gather_locked()
                if run is not None:
                    self._exec_ingest_run(run)
                elif batch:
                    self._exec_query_batch(batch)
                # else: the batch-window wait inside _gather_locked released
                # the lock and the compactor flushed deferred ingest to the
                # queue front — the ingest barrier kept everything, so there
                # is nothing to dispatch. Go around; the ingest op is now the
                # head and the next iteration serves it.
        except InjectedCrash as crash:
            # The op in flight died mid-execution: like a real process death
            # its caller gets no ack — resolve it as crashed so waiters
            # unblock, then take the whole runtime down. (A grouped ingest
            # run that crashed at its group barrier may have made records
            # durable — recovery replays them; the callers never saw an ack,
            # so at-least-once on unacknowledged writes holds, same as the
            # per-op fsync window.)
            inflight = batch if batch is not None \
                else (run if run is not None else [])
            for t in inflight:
                if not t.done():
                    self.stats.crashed += 1
                    t._resolve(RuntimeResponse(
                        op=t.request.get("op", "query"), status="crashed",
                        error=str(crash)))
            self._die(crash)

    def _flush_deferred_locked(self) -> None:
        """Re-admit parked ingest (admission order) once the swap landed."""
        if self._deferred and not self._compacting:
            self._queue.extendleft(reversed(self._deferred))
            self._deferred.clear()

    def _gather_locked(self) -> list[Ticket]:
        """Pop a coalescable run of query tickets (same tier/k/filter)."""
        head = self._queue[0]
        key = self._batch_key(head.request)
        if len(self._queue) < self.cfg.max_batch \
                and self.cfg.batch_window_s > 0 \
                and time.monotonic() - head.submitted_at \
                < self.cfg.batch_window_s:
            # Young head: give near-simultaneous arrivals one window to
            # coalesce before dispatching a tiny batch.
            self._work.wait(self.cfg.batch_window_s)
        batch, keep = [], deque()
        pending = list(self._queue)
        for i, t in enumerate(pending):
            if t.request.get("op", "query") in _INGEST_OPS:
                # Ingest barrier: a query admitted after a write must not be
                # hoisted past it — coalescing only reorders queries among
                # themselves (observationally equivalent).
                keep.extend(pending[i:])
                break
            if len(batch) < self.cfg.max_batch \
                    and self._batch_key(t.request) == key:
                batch.append(t)
            else:
                keep.append(t)
        self._queue = keep
        return batch

    def _gather_ingest_locked(self) -> list[Ticket]:
        """Pop the consecutive ingest run at the queue head (caller holds the
        lock, head is known to be an ingest op). Capped at ``max_batch`` so a
        deep write burst cannot starve queries behind it indefinitely."""
        run: list[Ticket] = []
        while self._queue and len(run) < self.cfg.max_batch \
                and self._queue[0].request.get("op", "query") in _INGEST_OPS:
            run.append(self._queue.popleft())
        return run

    def _batch_key(self, req: dict) -> tuple:
        return (req.get("tier", self.cfg.tier), int(req.get("k", self.cfg.k)),
                _filter_key(req.get("filter")),
                _semantics_key(req.get("semantics")))

    # -------------------------------------------------------------- execution
    def _exec_query_batch(self, batch: list[Ticket]) -> None:
        tier, k, _, _ = self._batch_key(batch[0].request)
        flt = batch[0].request.get("filter")
        sem = batch[0].request.get("semantics")
        degraded = False
        eff_tier = tier
        if tier == "exact" and self.engine.index_a is not None \
                and self._overloaded(len(self._queue) + len(batch)):
            # Load shedding: past the watermark an exact request costs more
            # than the queue can afford; the approx tier is the paper's own
            # fast path, and the response says so.
            eff_tier, degraded = "approx", True
        queries = [t.request["keywords"] for t in batch]
        self.stats.batches += 1
        self.stats.batched_queries += len(batch)
        attempt = 0
        while True:
            try:
                self.faults.check("dispatch")
                with self._engine_lock:
                    results = self.engine.query_batch(
                        queries, k=k, tier=eff_tier,
                        backend=self.cfg.backend, filter=flt,
                        semantics=sem)
                break
            except _RETRYABLE as e:
                self.stats.dispatch_retries += 1
                attempt += 1
                if attempt > self.cfg.max_retries:
                    self.stats.dispatch_failures += 1
                    self._fail_batch(batch, f"dispatch failed after "
                                     f"{attempt} attempts: {e}")
                    return
                time.sleep(self.cfg.retry_backoff_s * (2 ** (attempt - 1)))
            except InjectedCrash:
                raise
            except Exception as e:
                # Not transient: isolate — one malformed request must not
                # sink its batchmates.
                if len(batch) == 1:
                    self.stats.errors += 1
                    batch[0]._resolve(RuntimeResponse(
                        op="query", status="error", tier=eff_tier,
                        error=f"{type(e).__name__}: {e}"))
                    return
                for t in batch:
                    self.stats.single_fallbacks += 1
                    self._exec_query_batch([t])
                return
        if degraded:
            self.stats.degraded_queries += len(batch)
        for t, res in zip(batch, results):
            self.stats.completed += 1
            t._resolve(RuntimeResponse(
                op="query", status="ok", tier=eff_tier, degraded=degraded,
                payload={"candidates": res.candidates}))

    def _apply_ingest(self, req: dict) -> RuntimeResponse:
        """Apply one ingest op (caller holds the engine lock — and, for
        grouped runs, the engine's ``ingest_group`` scope). Builds the
        response but does NOT resolve it: inside a group the ack must wait
        for the group's durability barrier. A failed op never reached its
        WAL append (validation precedes mutation), so rejecting it inside a
        group leaves the group's durable record set exactly the applied ops."""
        op = req.get("op")
        try:
            if op == "insert":
                ids = self.engine.insert(
                    req["points"], req["keywords"],
                    attrs=req.get("attrs"), tenant=req.get("tenant"))
                payload = {"ids": [int(i) for i in ids]}
            elif op == "delete":
                payload = {"deleted": self.engine.delete(req["ids"])}
            elif op == "compact":
                payload = {"compacted": self.engine.compact()}
            elif op == "snapshot":
                payload = {"snapshot": self.engine.snapshot()}
            else:
                raise ValueError(f"unknown ingest op {op!r}")
            payload.update(generation=self.engine.corpus_generation,
                           delta_points=self.engine.delta_points,
                           tombstones=self.engine.tombstone_count,
                           compactions=self.engine.ingest.compactions)
        except InjectedCrash:
            raise
        except Exception as e:
            return RuntimeResponse(op=op, status="error",
                                   error=f"{type(e).__name__}: {e}")
        return RuntimeResponse(op=op, status="ok", payload=payload)

    def _exec_ingest_run(self, run: list[Ticket]) -> None:
        """Execute a consecutive ingest run under one WAL group commit.

        Every op in the run appends its WAL record with the fsync deferred;
        the ``ingest_group`` exit issues one barrier covering all of them,
        and only then do the acks fire — fsync-before-ack at run
        granularity. A run of one degrades to exactly the old per-op path
        (``ingest_group`` around a single append syncs once)."""
        resolved: list[tuple[Ticket, RuntimeResponse]] = []
        with self._engine_lock:
            with self.engine.ingest_group():
                for t in run:
                    resolved.append((t, self._apply_ingest(t.request)))
            # the group barrier has returned: every applied op is durable
        if len(run) > 1:
            self.stats.ingest_runs += 1
        for ticket, resp in resolved:
            if resp.ok:
                self.stats.ingest_ops += 1
                self.stats.completed += 1
            else:
                self.stats.errors += 1
            ticket._resolve(resp)
        self._maybe_trigger_compaction()

    # ------------------------------------------------------------- compaction
    def _maybe_trigger_compaction(self) -> None:
        eng = self.engine
        if self._compacting or eng._view is None:
            return
        if eng._view.n_tombstones >= eng._view.n:
            return
        churn = eng.delta_points + eng.tombstone_count
        if churn >= max(eng.compact_min, eng.compact_ratio * eng._bulk.n):
            with self._lock:
                self._compacting = True
            self._compact_req.set()

    def _compactor_loop(self) -> None:
        while True:
            self._compact_req.wait()
            self._compact_req.clear()
            if self._stop:
                return
            try:
                prep = self.engine.compact_prepare()
                with self._engine_lock:
                    self.engine.compact_commit(prep)
                self.stats.bg_compactions += 1
            except InjectedFault:
                # Transient rebuild failure: the old generation is fully
                # intact (nothing swapped); the next churn trigger retries.
                self.stats.bg_compaction_faults += 1
            except InjectedCrash as crash:
                self._die(crash)
                return
            except Exception as e:
                # A real rebuild bug (stale-compaction race, OOM, a
                # build_index defect) must not kill the compactor thread:
                # nothing swapped, the old generation keeps serving, and the
                # next churn trigger retries. Surface it in stats/health so
                # it cannot fail silently.
                self.stats.bg_compaction_errors += 1
                self._last_compaction_error = f"{type(e).__name__}: {e}"
            finally:
                with self._lock:
                    self._compacting = False
                    self._flush_deferred_locked()
                    self._work.notify_all()

    # ------------------------------------------------------------------ death
    def _die(self, crash: InjectedCrash) -> None:
        """Simulated process death: resolve everything as crashed, stop."""
        with self._lock:
            self._crashed = crash
            self._stop = True
            self._work.notify_all()
        self._compact_req.set()
        self._fail_pending("crashed", str(crash))

    def _fail_pending(self, status: str, message: str) -> None:
        with self._lock:
            pending = list(self._queue) + self._deferred
            self._queue.clear()
            self._deferred.clear()
        for t in pending:
            if not t.done():
                self.stats.crashed += 1 if status == "crashed" else 0
                t._resolve(RuntimeResponse(
                    op=t.request.get("op", "query"), status=status,
                    error=message))

    def _fail_batch(self, batch: list[Ticket], message: str) -> None:
        for t in batch:
            self.stats.errors += 1
            t._resolve(RuntimeResponse(op="query", status="error",
                                       error=message))
