"""Deterministic fault injection for the serving runtime.

The fault-tolerance test suite needs *repeatable* failures: "the 2nd device
dispatch raises", "the process dies between the WAL append and the ack",
"compaction crashes mid-rebuild". A :class:`FaultPlan` arms named fault
points at specific 1-based hit counts; production code threads one plan
through the runtime / WAL / engine and calls :meth:`FaultPlan.check` at each
point. The default :data:`NO_FAULTS` plan makes every check a counter bump,
so the hooks cost nothing in normal serving.

Two failure flavours map to two exception types:

  * :class:`InjectedFault` — a *transient* error (a flaky device dispatch):
    the runtime's retry-with-backoff treats it as retryable.
  * :class:`InjectedCrash` — simulated *process death* (kill -9 between WAL
    append and ack, compaction crash): nothing may catch-and-continue the
    in-process state; recovery happens by replaying the WAL into a fresh
    engine. InjectedCrash deliberately subclasses BaseException so a stray
    ``except Exception`` in the serving path cannot swallow a "death".

Named points used by the suite (tests/test_runtime.py, tests/test_wal.py):

  ``dispatch``      runtime query-batch device dispatch (transient)
  ``compact``       mid-rebuild, after the compacted dataset is materialised
                    but before the new indices exist (crash or transient)
  ``wal_ack``       after a WAL record is durably on disk, before the engine
                    acknowledges the op to the caller (crash)

Ingestion-pipeline worker sites (tests/test_ingest_pipeline.py; one per
state-machine window in ``data/ingest.py``):

  ``claim``         batch leased, nothing embedded — recovery is lease
                    expiry + reclaim (crash)
  ``embed``         records exist in worker memory only; the journal still
                    says claimed — recovery re-embeds deterministically
                    after the lease expires (crash or transient)
  ``insert``        insert intent durable, engine untouched — recovery
                    reverts the intent (id horizon short) (crash/transient)
  ``ack``           batch past its WAL group-commit barrier but the job
                    store never heard — recovery acks from the id horizon
                    without re-inserting (exactly-once) (crash/transient)

Queue overflow is not a fault point: it is the admission queue's designed
backpressure behaviour, exercised naturally with a small ``max_queue``.
"""
from __future__ import annotations

from collections import Counter
from typing import Iterable


class InjectedFault(RuntimeError):
    """A transient injected failure (retryable)."""

    def __init__(self, point: str, hit: int):
        super().__init__(f"injected fault at {point!r} (hit #{hit})")
        self.point = point
        self.hit = hit


class InjectedCrash(BaseException):
    """Simulated process death — must not be handled as a normal error."""

    def __init__(self, point: str, hit: int):
        super().__init__(f"injected crash at {point!r} (hit #{hit})")
        self.point = point
        self.hit = hit


class FaultPlan:
    """Arms named fault points at deterministic hit counts.

    ``transient`` / ``crash`` map point name -> 1-based hit indices (an int
    is shorthand for a single hit). A point may appear in either dict, not
    both. ``hits`` counts every check (fired or not), ``fired`` only the
    injections — both are per-point Counters the tests assert on.
    """

    def __init__(self,
                 transient: "dict[str, int | Iterable[int]] | None" = None,
                 crash: "dict[str, int | Iterable[int]] | None" = None):
        def norm(plan):
            out = {}
            for point, when in (plan or {}).items():
                if isinstance(when, int):
                    when = (when,)
                out[str(point)] = frozenset(int(w) for w in when)
            return out
        self._transient = norm(transient)
        self._crash = norm(crash)
        dup = set(self._transient) & set(self._crash)
        if dup:
            raise ValueError(f"points armed as both transient and crash: "
                             f"{sorted(dup)}")
        self.hits: Counter = Counter()
        self.fired: Counter = Counter()

    def check(self, point: str) -> None:
        """Count a pass through ``point``; raise if this hit is armed."""
        self.hits[point] += 1
        hit = self.hits[point]
        if hit in self._crash.get(point, ()):
            self.fired[point] += 1
            raise InjectedCrash(point, hit)
        if hit in self._transient.get(point, ()):
            self.fired[point] += 1
            raise InjectedFault(point, hit)


#: Shared no-op plan: every check is a counter bump, nothing ever fires.
NO_FAULTS = FaultPlan()
