"""Durable ingest: write-ahead log + atomic engine snapshots.

The serving contract (EMBANKS-style durable-on-disk half): **an acknowledged
write survives process death**. Every mutating op (insert / delete / compact)
is appended to the WAL — framed, checksummed, fsync'd — *before* the engine
acknowledges it; :meth:`repro.serve.engine.NKSEngine.recover` replays the log
on top of the latest snapshot into a state whose answers are bit-identical to
an uninterrupted run over the same acknowledged op sequence.

Crash semantics fall out of the framing:

  * crash *before* the append completes → the tail record is torn (short or
    checksum-mismatched); replay stops cleanly at the last whole record. The
    op was never acknowledged, so losing it is allowed.
  * crash *after* the fsync, before the ack → the record is durable and
    replay applies it. The client never saw an ack, so applying it is also
    allowed (at-least-once on unacknowledged tails, exactly-once on acks).

Record framing: ``<u32 payload_len><u32 crc32(payload)><payload>`` where the
payload is UTF-8 JSON; numpy arrays ride as ``{"__nd__": dtype, shape, b64}``.

Snapshots roll the log. A snapshot captures the *frozen* engine state — the
paper's bulk dataset + both index flavours + the external-id map and ingest
counters — written to a temp dir, fsync'd, and atomically renamed; the root
``MANIFEST.json`` (also atomically replaced) names the live epoch. A dirty
engine compacts first (folding the delta), so a snapshot is always a clean
generation boundary and the fresh WAL segment starts empty:

    <root>/MANIFEST.json      {"epoch": E}
    <root>/snap-<E>/          snapshot for epoch E (meta.json + .npy leaves,
                              per-leaf sha256 in the meta manifest)
    <root>/wal-<E>.log        ops acknowledged since snapshot E

The index/dataset leaf serialisation (one ``.npy`` per flat array + an
offsets sidecar per CSR, optionally memory-mapped on load — the paper's §IX
directory-file layout) now lives in :mod:`repro.core.store`, shared between
snapshots here and the out-of-core bulk store; this module re-exports the
helpers for its snapshot trees and keeps the WAL itself.

**Group commit**: ``append(record, sync=False)`` defers the fsync so a run
of ops acknowledged together (the runtime's ingest batch window) pays one
barrier — :meth:`WriteAheadLog.sync` — instead of one fsync per op. The
fsync-before-ack contract is unchanged: the caller must not ack any deferred
record until ``sync()`` returns.
"""
from __future__ import annotations

import base64
import dataclasses
import json
import os
import shutil
import struct
import tempfile
import zlib
from typing import Iterator

import numpy as np

from repro.core.index import PromishIndex
from repro.core.store import fsync_dir as _fsync_dir
from repro.core.store import (load_dataset, load_index, save_dataset,
                              save_index)
from repro.core.types import KeywordDataset
from repro.serve.faults import NO_FAULTS, FaultPlan

_FRAME = struct.Struct("<II")          # (payload_len, crc32)


# --------------------------------------------------------------------- arrays
def encode_array(arr: np.ndarray) -> dict:
    """JSON-safe numpy array: dtype string + shape + base64 payload."""
    arr = np.ascontiguousarray(arr)
    return {"__nd__": arr.dtype.str, "shape": list(arr.shape),
            "b64": base64.b64encode(arr.tobytes()).decode("ascii")}


def decode_array(obj: dict) -> np.ndarray:
    raw = base64.b64decode(obj["b64"])
    return np.frombuffer(raw, dtype=np.dtype(obj["__nd__"])) \
        .reshape(obj["shape"]).copy()


# ------------------------------------------------------------------------ WAL
class TornRecordError(ValueError):
    """A WAL record failed its length/CRC check mid-stream (not at the tail)."""


@dataclasses.dataclass
class WalStats:
    appends: int = 0
    bytes: int = 0
    replayed: int = 0
    torn_tail: bool = False     # last replay ended on a torn record
    valid_bytes: int = 0        # byte offset just past the last whole record
    fsyncs: int = 0             # durability barriers actually issued
    group_commits: int = 0      # sync() barriers covering >= 1 deferred record
    group_committed: int = 0    # records made durable by those barriers

    @property
    def group_commit_batch(self) -> float | None:
        """Mean records per group-commit barrier (None before the first)."""
        if not self.group_commits:
            return None
        return self.group_committed / self.group_commits


class WriteAheadLog:
    """Append-only framed record log with fsync-before-ack durability.

    ``faults`` injects the ``wal_ack`` crash point *after* the record is
    durable but before the caller could ack it — in :meth:`append` on the
    per-op path, in :meth:`sync` on the group-commit path (the deferred
    records become durable there). Either way the kill window the recovery
    suite exercises sits between durability and ack.
    """

    def __init__(self, path: str, faults: FaultPlan | None = None):
        self.path = path
        self._faults = faults or NO_FAULTS
        self._f = open(path, "ab")
        self._pending = 0           # records written but not yet fsync'd
        self.stats = WalStats()

    def append(self, record: dict, *, sync: bool = True) -> int:
        """Frame + write one record; make it durable unless ``sync=False``.

        ``sync=False`` is the group-commit half: the record is buffered (and
        flushed to the OS) but the fsync barrier is deferred to the next
        :meth:`sync`. The caller owns the contract that no deferred record is
        acknowledged before that barrier returns.
        """
        payload = json.dumps(record, separators=(",", ":")).encode("utf-8")
        frame = _FRAME.pack(len(payload), zlib.crc32(payload)) + payload
        self._f.write(frame)
        self._f.flush()
        self.stats.appends += 1
        self.stats.bytes += len(frame)
        if sync:
            os.fsync(self._f.fileno())
            self.stats.fsyncs += 1
            # The record is durable from here on; a crash in this window
            # loses the ack but never the write.
            self._faults.check("wal_ack")
        else:
            self._pending += 1
        return len(frame)

    def sync(self) -> int:
        """Group-commit barrier: one fsync covering every deferred append.
        Returns the number of records it made durable (0 = nothing pending,
        no fsync issued)."""
        pending, self._pending = self._pending, 0
        if not pending:
            return 0
        os.fsync(self._f.fileno())
        self.stats.fsyncs += 1
        self.stats.group_commits += 1
        self.stats.group_committed += pending
        # Durable now — same kill-between-durability-and-ack window as the
        # per-op path, covering the whole group's acks at once.
        self._faults.check("wal_ack")
        return pending

    def close(self) -> None:
        if not self._f.closed:
            if self._pending:
                # Defensive: a close with deferred records must not leave
                # them page-cache-only (e.g. snapshot() rolling the segment).
                self.sync()
            self._f.close()

    # ------------------------------------------------------------- replay
    @staticmethod
    def replay(path: str, stats: WalStats | None = None) -> Iterator[dict]:
        """Yield whole records in append order; stop cleanly at a torn tail.

        A short or checksum-mismatched record that is *not* the last one in
        the file raises :class:`TornRecordError` — mid-file corruption is
        data loss of acknowledged writes and must never be silently skipped.
        """
        if not os.path.exists(path):
            return
        with open(path, "rb") as f:
            data = f.read()
        off, n = 0, len(data)
        while off < n:
            if off + _FRAME.size > n:
                if stats is not None:
                    stats.torn_tail = True
                return
            length, crc = _FRAME.unpack_from(data, off)
            payload = data[off + _FRAME.size: off + _FRAME.size + length]
            if len(payload) < length or zlib.crc32(payload) != crc:
                if off + _FRAME.size + length >= n:
                    if stats is not None:
                        stats.torn_tail = True
                    return
                raise TornRecordError(
                    f"corrupt WAL record at byte {off} of {path} "
                    f"(not at tail — acknowledged data is damaged)")
            if stats is not None:
                stats.replayed += 1
            yield json.loads(payload.decode("utf-8"))
            off += _FRAME.size + length
            if stats is not None:
                # Only advanced after the consumer fully processed the
                # record: recovery truncates a torn tail to this offset.
                stats.valid_bytes = off


# ------------------------------------------------------------------ snapshots
# (leaf I/O — save_dataset/load_dataset/save_index/load_index — lives in
# repro.core.store, shared with the out-of-core bulk store)
def save_snapshot(directory: str, *, dataset: KeywordDataset,
                  index_e: PromishIndex | None,
                  index_a: PromishIndex | None,
                  build_params: dict, engine_meta: dict) -> str:
    """Atomically write a full engine snapshot to ``directory``.

    Write-to-temp + fsync + rename: a crash mid-snapshot can never leave a
    half snapshot that recovery would pick up. ``engine_meta`` carries the
    streaming counters (external-id map, generation, ingest totals) so a
    recovered engine continues the id sequence exactly.
    """
    parent = os.path.dirname(os.path.abspath(directory)) or "."
    os.makedirs(parent, exist_ok=True)
    tmp = tempfile.mkdtemp(prefix=".tmp-snap-", dir=parent)
    try:
        manifest: dict = {}
        meta = {
            "format": 1,
            "dataset": save_dataset(tmp, dataset, manifest),
            "index_e": (save_index(tmp, "e", index_e, manifest)
                        if index_e is not None else None),
            "index_a": (save_index(tmp, "a", index_a, manifest)
                        if index_a is not None else None),
            "build_params": build_params,
            "engine": engine_meta,
            "leaves": manifest,
        }
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        _fsync_dir(tmp)
        if os.path.exists(directory):
            shutil.rmtree(directory)
        os.rename(tmp, directory)
        _fsync_dir(parent)
        return directory
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def load_snapshot(directory: str, *, mmap: bool = False,
                  verify: bool = True) -> dict:
    """Load a snapshot dir -> {dataset, index_e, index_a, build_params,
    engine} (indices None when the engine was built without that flavour)."""
    with open(os.path.join(directory, "meta.json")) as f:
        meta = json.load(f)
    manifest = meta["leaves"]
    out = {
        "dataset": load_dataset(directory, meta["dataset"], manifest,
                                mmap=mmap, verify=verify),
        "index_e": None, "index_a": None,
        "build_params": meta["build_params"],
        "engine": meta["engine"],
    }
    for flavour in ("e", "a"):
        imeta = meta[f"index_{flavour}"]
        if imeta is not None:
            out[f"index_{flavour}"] = load_index(
                directory, flavour, imeta, manifest, mmap=mmap, verify=verify)
    return out


# ----------------------------------------------------------------- WAL roots
def manifest_path(root: str) -> str:
    return os.path.join(root, "MANIFEST.json")


def snap_dir(root: str, epoch: int) -> str:
    return os.path.join(root, f"snap-{epoch:05d}")


def wal_path(root: str, epoch: int) -> str:
    return os.path.join(root, f"wal-{epoch:05d}.log")


def read_manifest(root: str) -> dict:
    with open(manifest_path(root)) as f:
        return json.load(f)


def write_manifest(root: str, epoch: int) -> None:
    """Atomically point the root at ``epoch`` (tmp file + rename)."""
    fd, tmp = tempfile.mkstemp(prefix=".tmp-manifest-", dir=root)
    try:
        with os.fdopen(fd, "w") as f:
            json.dump({"epoch": epoch}, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, manifest_path(root))
        _fsync_dir(root)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def gc_epochs(root: str, keep_epoch: int) -> None:
    """Drop snapshot dirs / WAL segments older than ``keep_epoch`` (run
    after the manifest swap; a crash before this leaves stale-but-harmless
    files that the next snapshot sweeps)."""
    for name in os.listdir(root):
        for prefix, strip in (("snap-", len("snap-")),
                              ("wal-", len("wal-"))):
            if name.startswith(prefix):
                try:
                    epoch = int(name[strip:].split(".")[0])
                except ValueError:
                    continue
                if epoch < keep_epoch:
                    full = os.path.join(root, name)
                    if os.path.isdir(full):
                        shutil.rmtree(full, ignore_errors=True)
                    else:
                        os.unlink(full)
