"""Batched NKS serving engine.

Production shape: a frontend batches keyword-set queries; the engine answers
from a ProMiSH index over an embedding corpus. Three quality/latency tiers:

  * ``exact``   — ProMiSH-E (100% accuracy, Lemma-2 guarantee);
  * ``approx``  — ProMiSH-A (the paper's fast tier);
  * ``device``  — the anchor-star device kernel (repro.core.distributed),
                  batched and shardable over the mesh; used when the corpus
                  is sharded across chips.

``query_batch`` runs the exact/approx tiers as a **staged batched pipeline**
on the plan/backend layers: per scale, bucket selection for the whole batch
is amortised through ``core.plan.plan_scale`` (shared per-query Algorithm-2
dedup), surviving subsets are packed into a handful of size-binned fused
Pallas threshold-join dispatches (``backend="pallas"``, each emitting the
packed join bitmask; subsets whose pruning radius is still infinite skip the
device entirely) or looped through float64 numpy (``backend="numpy"``), and
the host enumeration stage consumes the join blocks through the vectorized
frontier of ``subset_search.enumerate_with_block``. Per-scale device traffic,
phase timings, and packed-subset cache hits are recorded in
:class:`PipelineStats` (``engine.last_batch_stats``).

The corpus can be ingested directly (points + keywords) or produced by any
assigned architecture through ``ingest_embeddings`` (models.api.embed ->
ProMiSH points — the paper's Flickr use case with learned features).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import numpy as np

from repro.core import plan, promish_a, promish_e
from repro.core.backend import DistanceBackend, get_backend
from repro.core.distributed import nks_anchor_topk, pack_groups
from repro.core.index import PromishIndex, build_index
from repro.core.subset_search import enumerate_with_block, local_groups
from repro.core.types import Candidate, KeywordDataset, TopK, make_dataset


@dataclasses.dataclass
class QueryResult:
    query: list[int]
    candidates: list[Candidate]
    latency_s: float
    tier: str


@dataclasses.dataclass
class ScaleStats:
    """One pipeline stage = one scale of the multi-scale index."""

    scale: int
    active_queries: int = 0
    buckets_selected: int = 0
    duplicate_subsets: int = 0
    tasks_planned: int = 0
    tasks_searched: int = 0      # tasks with all keyword groups non-empty
    dispatches: int = 0          # device/loop distance dispatches this scale
    join_pairs: int = 0
    queries_finished: int = 0


@dataclasses.dataclass
class PipelineStats:
    """End-to-end accounting for one ``query_batch`` call.

    The four phase timers split the batch wall time the way the ISSUE-2 perf
    work carves the pipeline: ``plan`` (bucket selection + keyword grouping),
    ``pack`` (host gather/tile packing, backend-side), ``dispatch`` (device
    dispatch + D2H readback), ``enumerate`` (host Alg. 4 over the join
    masks). Cache counters mirror the backend's packed-subset LRU.
    """

    batch_size: int
    tier: str
    backend: str
    scales: list[ScaleStats] = dataclasses.field(default_factory=list)
    fallback_queries: int = 0
    fallback_dispatches: int = 0
    candidates_explored: int = 0
    t_plan_s: float = 0.0
    t_pack_s: float = 0.0
    t_dispatch_s: float = 0.0
    t_enumerate_s: float = 0.0
    cache_hits: int = 0
    cache_misses: int = 0

    @property
    def dispatches_per_scale(self) -> list[int]:
        return [s.dispatches for s in self.scales]

    @property
    def total_dispatches(self) -> int:
        return sum(s.dispatches for s in self.scales) + self.fallback_dispatches

    @property
    def phases(self) -> dict:
        """JSON-ready phase breakdown for the benchmark trajectory."""
        probed = self.cache_hits + self.cache_misses
        return {
            "plan_s": round(self.t_plan_s, 6),
            "pack_s": round(self.t_pack_s, 6),
            "dispatch_s": round(self.t_dispatch_s, 6),
            "enumerate_s": round(self.t_enumerate_s, 6),
            "cache_hit_rate": round(self.cache_hits / probed, 4) if probed else None,
        }


class NKSEngine:
    def __init__(self, dataset: KeywordDataset, *, m: int = 2, n_scales: int = 5,
                 seed: int = 0, build_exact: bool = True, build_approx: bool = True):
        self.dataset = dataset
        self.index_e: PromishIndex | None = None
        self.index_a: PromishIndex | None = None
        self.last_batch_stats: PipelineStats | None = None
        if build_exact:
            self.index_e = build_index(dataset, m=m, n_scales=n_scales,
                                       exact=True, seed=seed)
        if build_approx:
            self.index_a = build_index(dataset, m=m, n_scales=n_scales,
                                       exact=False, seed=seed)

    @classmethod
    def ingest_embeddings(cls, api, params, batches: Sequence[dict],
                          keywords: Sequence[Sequence[int]], **kw) -> "NKSEngine":
        """Build the corpus from model embeddings (any assigned arch)."""
        import jax.numpy as jnp
        embs = [np.asarray(api.embed(params, b), np.float32) for b in batches]
        points = np.concatenate(embs, axis=0)
        return cls(make_dataset(points, keywords), **kw)

    def query(self, keywords: Sequence[int], k: int = 1,
              tier: str = "approx") -> QueryResult:
        t0 = time.perf_counter()
        if tier == "exact":
            pq = promish_e.search(self.dataset, self.index_e, keywords, k=k)
        elif tier == "approx":
            pq = promish_a.search(self.dataset, self.index_a, keywords, k=k)
        elif tier == "device":
            import jax.numpy as jnp
            groups, mask, ids = pack_groups(self.dataset, list(keywords))
            diams, cids = nks_anchor_topk(jnp.asarray(groups),
                                          jnp.asarray(mask),
                                          jnp.asarray(ids), k)
            cands = []
            for i in range(k):
                if not np.isfinite(float(diams[i])):
                    continue
                ids_i = tuple(sorted(set(int(x) for x in cids[i])))
                cands.append(Candidate(ids=ids_i, diameter=float(diams[i])))
            return QueryResult(list(keywords), cands,
                               time.perf_counter() - t0, tier)
        else:
            raise ValueError(tier)
        return QueryResult(list(keywords), pq.items,
                           time.perf_counter() - t0, tier)

    # ------------------------------------------------------------- batched path
    def _validate_queries(self, queries: Sequence[Sequence[int]]
                          ) -> list[list[int]]:
        out = []
        for q in queries:
            q = sorted(set(int(v) for v in q))
            if any(v < 0 or v >= self.dataset.n_keywords for v in q):
                raise ValueError("query keyword outside dictionary")
            out.append(q)
        return out

    def _run_tasks(self, tasks: list[plan.SubsetTask], queries: list[list[int]],
                   pqs: list[TopK], backend: DistanceBackend,
                   stats: PipelineStats) -> tuple[int, int, int]:
        """Distance stage + enumeration stage for one batch of subset tasks.

        Returns (tasks_searched, dispatches_issued, join_pairs)."""
        t0 = time.perf_counter()
        prepared = []
        for t in tasks:
            gl = local_groups(t.f_ids, queries[t.qidx], self.dataset)
            if gl is not None:
                prepared.append((t, gl))
        stats.t_plan_s += time.perf_counter() - t0
        if not prepared:
            return 0, 0, 0
        d0 = backend.stats.dispatches
        blocks = backend.self_join_blocks(
            self.dataset.points,
            [t.f_ids for t, _ in prepared],
            [pqs[t.qidx].kth_diameter() for t, _ in prepared],
            keys=[t.f_ids.tobytes() for t, _ in prepared])
        t1 = time.perf_counter()
        join_pairs = 0
        for (t, gl), db in zip(prepared, blocks):
            join_pairs += db.join_count
            stats.candidates_explored += enumerate_with_block(
                t.f_ids, gl, queries[t.qidx], self.dataset, pqs[t.qidx], db)
        stats.t_enumerate_s += time.perf_counter() - t1
        return len(prepared), backend.stats.dispatches - d0, join_pairs

    def _batch_search(self, queries: list[list[int]], k: int, tier: str,
                      backend: DistanceBackend) -> tuple[list[TopK], PipelineStats]:
        exact = tier == "exact"
        index = self.index_e if exact else self.index_a
        if index is None:
            raise ValueError(f"engine built without the {tier!r} index")
        stats = PipelineStats(batch_size=len(queries), tier=tier,
                              backend=backend.name)
        b0 = dataclasses.replace(backend.stats)
        pqs = [TopK(k, init_full=exact) for _ in queries]
        t0 = time.perf_counter()
        bitsets = [plan.query_bitset(self.dataset, q) for q in queries]
        stats.t_plan_s += time.perf_counter() - t0
        explored = {i: set() for i in range(len(queries))} if exact else None
        active = list(range(len(queries)))

        for s in range(index.n_scales):
            if not active:
                break
            sstats = ScaleStats(scale=s, active_queries=len(active))
            pstats = plan.PlanStats()
            t0 = time.perf_counter()
            tasks = plan.plan_scale(index, s, queries, bitsets, active,
                                    explored, pstats)
            stats.t_plan_s += time.perf_counter() - t0
            sstats.buckets_selected = pstats.buckets_selected
            sstats.duplicate_subsets = pstats.duplicate_subsets
            sstats.tasks_planned = len(tasks)
            searched, dispatches, pairs = self._run_tasks(
                tasks, queries, pqs, backend, stats)
            sstats.tasks_searched = searched
            sstats.dispatches = dispatches
            sstats.join_pairs = pairs
            # Per-query termination, exactly as the per-query searches do it:
            # E: Lemma-2 radius test after the scale; A: first full PQ.
            still = []
            for qidx in active:
                if exact:
                    done = pqs[qidx].kth_diameter() <= index.w0 * (2.0 ** (s - 1))
                else:
                    done = pqs[qidx].full()
                if done:
                    sstats.queries_finished += 1
                else:
                    still.append(qidx)
            active = still
            stats.scales.append(sstats)

        if active:
            stats.fallback_queries = len(active)
            tasks = plan.fallback_tasks(bitsets, active)
            _, stats.fallback_dispatches, _ = self._run_tasks(
                tasks, queries, pqs, backend, stats)
        stats.t_pack_s = backend.stats.t_pack_s - b0.t_pack_s
        stats.t_dispatch_s = backend.stats.t_dispatch_s - b0.t_dispatch_s
        stats.cache_hits = backend.stats.cache_hits - b0.cache_hits
        stats.cache_misses = backend.stats.cache_misses - b0.cache_misses
        return pqs, stats

    def query_batch(self, queries: Sequence[Sequence[int]], k: int = 1,
                    tier: str = "approx",
                    backend: str | DistanceBackend = "numpy"
                    ) -> list[QueryResult]:
        """Answer a batch of queries through the staged pipeline.

        Bucket selection, Algorithm-2 dedup, and device dispatch are amortised
        across the batch: with ``backend="pallas"`` each scale issues a few
        size-binned fused threshold-join dispatches covering all live subsets
        (subsets at an infinite pruning radius skip the device — their join
        mask is all-ones by construction). The ``device`` tier keeps its
        per-query kernel loop. Per-result latency is the batch wall time
        divided by the batch size (attribution inside a fused dispatch is
        meaningless). Pipeline accounting lands in ``self.last_batch_stats``.
        """
        if tier == "device":
            self.last_batch_stats = None    # no pipeline ran; don't leave stale stats
            return [self.query(q, k=k, tier=tier) for q in queries]
        if tier not in ("exact", "approx"):
            raise ValueError(tier)
        t0 = time.perf_counter()
        qlists = self._validate_queries(queries)
        pqs, stats = self._batch_search(qlists, k, tier, get_backend(backend))
        self.last_batch_stats = stats
        per_q = (time.perf_counter() - t0) / max(len(qlists), 1)
        return [QueryResult(list(q), pq.items, per_q, tier)
                for q, pq in zip(queries, pqs)]
