"""Batched NKS serving engine.

Production shape: a frontend batches keyword-set queries; the engine answers
from a ProMiSH index over an embedding corpus. Three quality/latency tiers:

  * ``exact``   — ProMiSH-E (100% accuracy, Lemma-2 guarantee);
  * ``approx``  — ProMiSH-A (the paper's fast tier);
  * ``device``  — the anchor-star device kernel (repro.core.distributed),
                  batched and shardable over the mesh; used when the corpus
                  is sharded across chips.

All three tiers flow through one device plane (``core.device_plane``) when
the engine is built with ``mesh=...``: the exact/approx pipeline routes its
size-binned join dispatches through the plane's shard_map (subsets sharded
on S over the ``data`` axis), and the device tier dispatches the anchor-star
shard_map program on the same mesh. Without a mesh everything runs
single-device — multi-device execution is a property of the backend, not a
separate code path.

``query_batch`` runs the exact/approx tiers as a **staged batched pipeline**
on the plan/backend layers: per scale, bucket selection for the whole batch
is amortised through ``core.plan.plan_scale`` (shared per-query Algorithm-2
dedup), surviving subsets are packed into a handful of size-binned fused
Pallas threshold-join dispatches (``backend="pallas"``, each emitting the
packed join bitmask; subsets whose pruning radius is still infinite skip the
device entirely) or looped through float64 numpy (``backend="numpy"``), and
the host enumeration stage consumes the join blocks through the vectorized
frontier of ``subset_search.enumerate_with_block``. Per-scale device traffic,
phase timings, and packed-subset cache hits are recorded in
:class:`PipelineStats` (``engine.last_batch_stats``).

The corpus can be ingested directly (points + keywords) or produced by any
assigned architecture through ``ingest_embeddings`` (models.api.embed ->
ProMiSH points — the paper's Flickr use case with learned features).

**Streaming ingest** (``insert`` / ``delete`` / ``compact``): the engine
serves while the corpus changes. Inserts land in an append-only delta
(:class:`~repro.core.types.StreamingCorpus` +
:class:`~repro.core.index.IndexDelta` per index flavour) binned with the
bulk index's hash geometry; deletes are tombstones; a size/ratio-triggered
compaction (``compact_ratio``/``compact_min``) folds everything into a fresh
immutable index, swapped atomically, bumping ``corpus_generation`` — the
token the backend LRU caches are scoped to (absorbs keep caches warm, only
compaction invalidates). Consistency model: a query issued after an ingest
call returns sees all of that call's batch and every earlier one — never a
partial batch; results carry *external* ids that stay stable across
compactions. ``PipelineStats`` records generation/delta/tombstone state per
batch, ``engine.ingest`` the lifetime counters.
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
import os
import time
from typing import Sequence

import numpy as np

from repro.core import plan, promish_a, promish_e
from repro.core import store as storemod
from repro.core.backend import DistanceBackend, get_backend
from repro.core.filters import Filter
from repro.core.index import IndexDelta, PromishIndex, absorb_into, build_index
from repro.core.semantics import QuerySemantics
from repro.core.subset_search import enumerate_with_block, local_groups
from repro.core.types import (Candidate, KeywordDataset, StreamingCorpus,
                              TopK, make_dataset)
from repro.serve import wal as walmod
from repro.serve.faults import NO_FAULTS, FaultPlan

# Process-global corpus-generation tokens: every (engine, compaction) pair
# gets a unique token, so a DistanceBackend shared across engines can never
# serve one engine's packed rows to another (generation numbers restart at 0
# per engine; tokens do not).
_CORPUS_TOKENS = itertools.count(1)

# repro.core.distributed / device_plane import the jax device stack; they are
# loaded lazily so the numpy control plane stays importable everywhere and
# XLA_FLAGS can still be set after importing this module.


@dataclasses.dataclass
class QueryResult:
    query: list[int]
    candidates: list[Candidate]
    latency_s: float
    tier: str


@dataclasses.dataclass
class ScaleStats:
    """One pipeline stage = one scale of the multi-scale index."""

    scale: int
    active_queries: int = 0
    buckets_selected: int = 0
    duplicate_subsets: int = 0
    filtered_subsets: int = 0    # predicate-pruned before pack/dispatch
    tasks_planned: int = 0
    tasks_searched: int = 0      # tasks with all keyword groups non-empty
    dispatches: int = 0          # device/loop distance dispatches this scale
    join_pairs: int = 0
    queries_finished: int = 0
    # Out-of-core pruning (zone maps / bounding radii; zero without synopses):
    buckets_pruned_zonemap: int = 0
    buckets_pruned_radius: int = 0


@dataclasses.dataclass
class PipelineStats:
    """End-to-end accounting for one ``query_batch`` call.

    The four phase timers split the batch wall time the way the ISSUE-2 perf
    work carves the pipeline: ``plan`` (bucket selection + keyword grouping),
    ``pack`` (host gather/tile packing, backend-side), ``dispatch`` (device
    dispatch + D2H readback), ``enumerate`` (host Alg. 4 over the join
    masks). Cache counters mirror the backend's packed-subset LRU.
    """

    batch_size: int
    tier: str
    backend: str
    scales: list[ScaleStats] = dataclasses.field(default_factory=list)
    fallback_queries: int = 0
    fallback_dispatches: int = 0
    candidates_explored: int = 0
    t_plan_s: float = 0.0
    t_pack_s: float = 0.0
    t_dispatch_s: float = 0.0
    t_enumerate_s: float = 0.0
    cache_hits: int = 0
    cache_misses: int = 0
    # Device-plane accounting (empty / zero when no mesh is attached):
    # ``shard_dispatches[i]`` counts dispatches device i participated in
    # (single-device dispatches land on shard 0), the cell counters measure
    # per-shard join-block utilisation (valid vs padded cells on each
    # shard's slab), and ``t_collective_s`` is the wall time spent inside
    # shard_map dispatches (device compute + cross-device gather-back).
    sharded_dispatches: int = 0
    t_collective_s: float = 0.0
    shard_dispatches: list[int] = dataclasses.field(default_factory=list)
    shard_valid_cells: list[int] = dataclasses.field(default_factory=list)
    shard_total_cells: list[int] = dataclasses.field(default_factory=list)
    # Streaming-ingest accounting: the corpus generation the batch ran
    # against (bumped by compaction only), the delta/tombstone sizes at
    # dispatch time, and the engine's lifetime compaction count.
    corpus_generation: int = 0
    delta_points: int = 0
    tombstones: int = 0
    compactions: int = 0
    # Filtered-NKS accounting: eligible_points/selectivity describe the
    # batch's predicate mask (None on an unfiltered batch); filtered_subsets
    # counts planned subsets pruned because no member satisfied the
    # predicate; h2d/d2h_bytes are the backend's transfer deltas for this
    # batch — the "no new D2H" contract of the eligibility fold is asserted
    # on d2h_bytes.
    eligible_points: int | None = None
    filter_selectivity: float | None = None
    filtered_subsets: int = 0
    h2d_bytes: int = 0
    d2h_bytes: int = 0
    # Cascade accounting (ISSUE 6): the three-tier distance cascade splits
    # device time into the coarse bf16 count pass (``t_prune_s``), the fp32
    # masked join (the remainder of ``t_dispatch_s``), and the host float64
    # settlement of surviving tuples (``t_rescore_s``, measured inside the
    # enumeration stage). ``cells_pruned`` counts fp32 join cells the coarse
    # tier proved empty and never dispatched. Cost-model routing lands in
    # ``host_routed_dispatches`` (bins the crossover model sent to the f64
    # host loop instead of the device). ``bin_occupancy`` maps each size
    # class (padded width) to [valid, padded] packed point counts, and
    # ``bin_strategy`` names the binning that produced it.
    prune_tier_dispatches: int = 0
    cells_pruned: int = 0
    t_prune_s: float = 0.0
    t_rescore_s: float = 0.0
    t_host_s: float = 0.0
    host_routed_dispatches: int = 0
    host_routed_subsets: int = 0
    bin_occupancy: dict = dataclasses.field(default_factory=dict)
    bin_strategy: str = ""
    # Out-of-core tiering (ISSUE 8): buckets the planner skipped because the
    # filter was provably disjoint from their zone maps, subsets dispatched
    # through the all-ones fast path because their bucket's diameter bound
    # already beat the live r_k, and bytes gathered from a memory-mapped
    # (cold-tier) corpus. All zero on a resident engine without synopses.
    buckets_pruned_zonemap: int = 0
    buckets_pruned_radius: int = 0
    cold_bytes_read: int = 0
    # Flexible semantics (ISSUE 9): planned subqueries after m-of-k
    # expansion (== batch_size on a classic batch — one subquery per query).
    subqueries: int = 0

    @property
    def dispatches_per_scale(self) -> list[int]:
        return [s.dispatches for s in self.scales]

    @property
    def total_dispatches(self) -> int:
        return sum(s.dispatches for s in self.scales) + self.fallback_dispatches

    @property
    def shard_utilisation(self) -> list[float]:
        """Valid-cell fraction of each shard's packed join blocks (the
        complement is pad waste shipped to that device)."""
        return [round(v / t, 4) if t else 0.0
                for v, t in zip(self.shard_valid_cells, self.shard_total_cells)]

    @property
    def phases(self) -> dict:
        """JSON-ready phase breakdown for the benchmark trajectory."""
        probed = self.cache_hits + self.cache_misses
        return {
            "plan_s": round(self.t_plan_s, 6),
            "pack_s": round(self.t_pack_s, 6),
            "dispatch_s": round(self.t_dispatch_s, 6),
            "enumerate_s": round(self.t_enumerate_s, 6),
            "collective_s": round(self.t_collective_s, 6),
            "cache_hit_rate": round(self.cache_hits / probed, 4) if probed else None,
        }

    @property
    def padded_cell_ratio(self) -> float | None:
        """Fraction of dispatched join-block cells that were padding (the
        quantity size-binning exists to minimise); None with no dispatches."""
        total = sum(self.shard_total_cells)
        if not total:
            return None
        return round(1.0 - sum(self.shard_valid_cells) / total, 6)

    @property
    def cascade(self) -> dict:
        """JSON-ready per-tier cascade summary for the benchmark trajectory."""
        return {
            "prune_tier_dispatches": self.prune_tier_dispatches,
            "cells_pruned": self.cells_pruned,
            "prune_s": round(self.t_prune_s, 6),
            "join_s": round(max(self.t_dispatch_s - self.t_prune_s
                                - self.t_host_s, 0.0), 6),
            "rescore_s": round(self.t_rescore_s, 6),
            "host_routed_dispatches": self.host_routed_dispatches,
            "host_routed_subsets": self.host_routed_subsets,
            "host_s": round(self.t_host_s, 6),
        }

    @property
    def binning(self) -> dict:
        """JSON-ready size-class occupancy for the benchmark trajectory."""
        return {
            "strategy": self.bin_strategy,
            "padded_cell_ratio": self.padded_cell_ratio,
            "bins": {str(k): {"points": v[0], "padded": v[1]}
                     for k, v in sorted(self.bin_occupancy.items())},
        }

    @property
    def sharding(self) -> dict:
        """JSON-ready device-plane summary for the benchmark trajectory."""
        return {
            "sharded_dispatches": self.sharded_dispatches,
            "shard_dispatches": list(self.shard_dispatches),
            "shard_utilisation": self.shard_utilisation,
            "collective_s": round(self.t_collective_s, 6),
        }

    @property
    def ingest(self) -> dict:
        """JSON-ready streaming-ingest summary for the benchmark trajectory."""
        return {
            "generation": self.corpus_generation,
            "delta_points": self.delta_points,
            "tombstones": self.tombstones,
            "compactions": self.compactions,
        }

    @property
    def filtering(self) -> dict:
        """JSON-ready filtered-NKS summary for the benchmark trajectory."""
        return {
            "eligible_points": self.eligible_points,
            "selectivity": self.filter_selectivity,
            "filtered_subsets": self.filtered_subsets,
            "h2d_bytes": self.h2d_bytes,
            "d2h_bytes": self.d2h_bytes,
        }

    @property
    def tiering(self) -> dict:
        """JSON-ready out-of-core tiering summary for the benchmark
        trajectory."""
        return {
            "buckets_pruned_zonemap": self.buckets_pruned_zonemap,
            "buckets_pruned_radius": self.buckets_pruned_radius,
            "cold_bytes_read": self.cold_bytes_read,
        }


@dataclasses.dataclass
class IngestStats:
    """Lifetime streaming counters for one engine (``engine.ingest``)."""

    inserts: int = 0            # insert calls absorbed
    points_inserted: int = 0
    deletes: int = 0            # delete calls absorbed
    points_deleted: int = 0
    compactions: int = 0
    generation: int = 0         # == engine.corpus_generation
    wal_appends: int = 0        # ops made durable before their ack
    replayed_ops: int = 0       # ops re-applied by the last recover()
    snapshots: int = 0          # log-rolling snapshots taken

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class StaleCompactionError(RuntimeError):
    """A prepared compaction no longer matches the live streaming state —
    an ingest op slipped in between prepare and commit. The runtime prevents
    this by deferring ingest while a rebuild is in flight; hitting it means
    the caller broke that protocol, so the commit refuses rather than swap
    in a bulk that silently drops the interleaved ops."""


@dataclasses.dataclass
class PreparedCompaction:
    """The O(N) half of a compaction, computed off-thread: the folded bulk
    dataset, freshly built indices, and the external-id remap. ``version``
    pins the streaming state it was prepared against; commit re-checks it."""

    version: tuple[int, int]            # (corpus rows, tombstones) at prepare
    bulk: KeywordDataset
    index_e: PromishIndex | None
    index_a: PromishIndex | None
    live: np.ndarray
    ext: np.ndarray


class NKSEngine:
    def __init__(self, dataset: KeywordDataset, *, m: int = 2, n_scales: int = 5,
                 seed: int = 0, build_exact: bool = True, build_approx: bool = True,
                 mesh=None, w0: float | None = None, n_buckets: int | None = None,
                 compact_ratio: float = 0.25, compact_min: int = 4096,
                 auto_compact: bool = True, faults: FaultPlan | None = None,
                 synopsis: bool = False,
                 resident_budget_bytes: int | None = None,
                 _indices: tuple | None = None):
        """``mesh`` attaches a device plane: a jax Mesh (with a ``data``
        axis), an existing :class:`~repro.core.device_plane.DevicePlane`, or
        ``"auto"`` to acquire the serving mesh from the environment
        (``REPRO_MESH_OVERRIDE`` / all local devices). With a plane attached,
        ``backend="pallas"`` dispatches shard over the mesh and the device
        tier runs the sharded anchor-star program; ``mesh=None`` (default)
        keeps every tier single-device.

        Streaming knobs: ``w0``/``n_buckets`` pin the hash geometry across
        compactions (None derives both from the corpus, per the paper);
        ``compact_ratio``/``compact_min`` set the rebuild cadence — after an
        insert or delete, the delta is folded into a fresh bulk index once
        ``delta_points + tombstones >= max(compact_min, compact_ratio * N)``
        (``auto_compact=False`` leaves compaction to explicit
        :meth:`compact` calls)."""
        self._bulk = dataset
        self.index_e: PromishIndex | None = None
        self.index_a: PromishIndex | None = None
        self.last_batch_stats: PipelineStats | None = None
        self.plane = None
        if mesh is not None:
            from repro.core.device_plane import get_plane
            self.plane = get_plane(mesh)
        self._build_params = dict(m=m, n_scales=n_scales, seed=seed,
                                  w0=w0, n_buckets=n_buckets,
                                  synopsis=synopsis)
        # Hot-tier budget for out-of-core serving: caps the pallas backend's
        # packed-tile LRU so a memory-mapped corpus stays within its
        # configured resident footprint (None = backend default).
        self.resident_budget_bytes = resident_budget_bytes
        if _indices is not None:
            # Recovery path: the snapshot already holds the built structures.
            self.index_e, self.index_a = _indices
        else:
            if build_exact:
                self.index_e = build_index(dataset, exact=True,
                                           **self._build_params)
            if build_approx:
                self.index_a = build_index(dataset, exact=False,
                                           **self._build_params)
        # Streaming-ingest state: lazy — a never-mutated engine keeps the
        # frozen KeywordDataset and the classic single-corpus code paths.
        self._view: StreamingCorpus | None = None
        self._deltas: dict[str, IndexDelta] = {}
        # internal -> external id map, stored in a capacity-doubled buffer so
        # absorbing a batch appends in O(batch), not O(corpus).
        self._ext_buf = np.arange(dataset.n, dtype=np.int64)
        self._ext_len = dataset.n
        self._next_ext = dataset.n
        self._identity_ids = True
        self.corpus_generation = 0
        self._corpus_token = next(_CORPUS_TOKENS)
        self.compact_ratio = float(compact_ratio)
        self.compact_min = int(compact_min)
        self.auto_compact = bool(auto_compact)
        self.ingest = IngestStats()
        # Durability (attach_wal / recover): every mutating op is appended —
        # and fsync'd — before its ack. None = volatile engine (the default).
        self._faults = faults or NO_FAULTS
        self._wal: walmod.WriteAheadLog | None = None
        self._wal_root: str | None = None
        self._wal_epoch = 0
        self._wal_group = 0         # ingest_group() nesting depth
        self._replaying = False

    # ------------------------------------------------------------- streaming
    @property
    def dataset(self):
        """The corpus the engine currently serves: the merged streaming view
        while a delta/tombstone set is live, the frozen bulk otherwise."""
        return self._view if self._view is not None else self._bulk

    @property
    def delta_points(self) -> int:
        return self._view.n_delta if self._view is not None else 0

    @property
    def tombstone_count(self) -> int:
        return self._view.n_tombstones if self._view is not None else 0

    def _streaming_dirty(self) -> bool:
        return self._view is not None and self._view.dirty

    @property
    def next_external_id(self) -> int:
        """The id the next inserted point will receive. External ids are
        assigned strictly sequentially, so this horizon lets an ingest
        pipeline decide after a crash whether an intended batch landed
        (``data/ingest.py`` reconciliation)."""
        return int(self._next_ext)

    @property
    def _ext_of(self) -> np.ndarray:
        return self._ext_buf[: self._ext_len]

    def _ext_append(self, ext: np.ndarray) -> None:
        need = self._ext_len + len(ext)
        if len(self._ext_buf) < need:
            grown = np.empty(max(2 * len(self._ext_buf), need), dtype=np.int64)
            grown[: self._ext_len] = self._ext_buf[: self._ext_len]
            self._ext_buf = grown
        self._ext_buf[self._ext_len:need] = ext
        self._ext_len = need

    def _streaming_state(self) -> tuple[StreamingCorpus, dict[str, IndexDelta]]:
        """The live streaming state, or a freshly built (uncommitted) one —
        callers assign it back via ``_commit_streaming`` only after the
        mutation succeeded, so a rejected op leaves the engine on the frozen
        bulk path."""
        if self._view is not None:
            return self._view, self._deltas
        view = StreamingCorpus(self._bulk)
        deltas = {}
        if self.index_e is not None:
            deltas["e"] = IndexDelta(self.index_e, view)
        if self.index_a is not None:
            deltas["a"] = IndexDelta(self.index_a, view)
        return view, deltas

    def _commit_streaming(self, view: StreamingCorpus,
                          deltas: dict[str, IndexDelta]) -> None:
        self._view = view
        self._deltas = deltas

    def insert(self, points: np.ndarray,
               keywords: Sequence[Sequence[int]],
               attrs: dict | None = None,
               tenant=None) -> np.ndarray:
        """Absorb a batch of tagged points; returns their external ids.

        The batch is visible to every query issued after this call returns
        (absorbed atomically: queries see all of it or none of it — there is
        no partial-batch state, and a rejected batch changes nothing). Cost
        is O(batch * scales), never O(corpus); the bulk index is untouched
        until compaction folds the delta in.

        ``attrs``/``tenant`` carry the batch's per-point attribute columns
        and tenant assignment; a corpus built with attributes (or tenants)
        requires them on every insert, and a corpus without rejects them —
        the streaming schema is fixed at build time, so filtered queries
        never see a half-attributed corpus. ``keywords`` are *global*
        dictionary ids at this layer; a frontend speaking tenant-local ids
        resolves them through ``dataset.tenants`` first (``launch/serve.py``
        does this for its JSONL insert op).
        """
        view, deltas = self._streaming_state()
        # validates schema + keywords before any mutation
        ids = view.absorb(points, keywords, attrs=attrs, tenant=tenant)
        absorb_into(deltas.values(), view.points[ids])
        self._commit_streaming(view, deltas)
        ext = np.arange(self._next_ext, self._next_ext + len(ids),
                        dtype=np.int64)
        self._next_ext += len(ids)
        self._ext_append(ext)
        self.ingest.inserts += 1
        self.ingest.points_inserted += len(ids)
        # Durability point: the op is in memory; make it survive process
        # death *before* anything downstream (auto-compaction, the ack) runs.
        self._wal_append({
            "op": "insert",
            "points": walmod.encode_array(
                np.ascontiguousarray(points, np.float32)),
            "keywords": [[int(v) for v in ks] for ks in keywords],
            "attrs": ({name: walmod.encode_array(np.asarray(col))
                       for name, col in attrs.items()}
                      if attrs is not None else None),
            "tenant": (walmod.encode_array(tenant)
                       if isinstance(tenant, np.ndarray) else tenant),
            "first_ext": int(ext[0]) if len(ext) else int(self._next_ext),
            "count": len(ext),
        })
        self._maybe_compact()
        return ext

    def delete(self, external_ids: Sequence[int]) -> int:
        """Tombstone points by external id; returns the number deleted.
        Unknown, duplicate, or already-deleted ids raise without applying
        anything (the caller's view of the corpus is stale — a serving
        frontend should surface that, not mask it)."""
        ext = np.asarray(list(external_ids), dtype=np.int64)
        if not len(ext):
            return 0
        if len(np.unique(ext)) != len(ext):
            raise KeyError(f"duplicate ids in delete batch: {ext.tolist()}")
        internal = np.searchsorted(self._ext_of, ext)
        bad = (internal >= len(self._ext_of)) | (self._ext_of[np.minimum(
            internal, len(self._ext_of) - 1)] != ext)
        if bad.any():
            raise KeyError(f"unknown external ids: {ext[bad].tolist()}")
        view, deltas = self._streaming_state()
        dead = view.tombstoned(internal)
        if dead.any():
            raise KeyError(f"already deleted: {ext[dead].tolist()}")
        for d in deltas.values():
            d.retire(internal)
        view.delete(internal)
        self._commit_streaming(view, deltas)
        self.ingest.deletes += 1
        self.ingest.points_deleted += len(ext)
        self._wal_append({"op": "delete", "ids": [int(i) for i in ext]})
        self._maybe_compact()
        return len(ext)

    def compact_prepare(self) -> PreparedCompaction | None:
        """The O(N) half of :meth:`compact`, safe to run off-thread.

        Reads (never mutates) the live streaming view: folds bulk ∪ delta
        into a fresh frozen dataset and builds the new indices. Serving
        continues against the old generation the whole time — the swap is
        :meth:`compact_commit`, a cheap pointer exchange. The caller must
        hold ingest still between prepare and commit (the runtime defers
        ingest ops while a rebuild is in flight); commit verifies that via
        ``version``. Returns None when nothing is dirty."""
        if not self._streaming_dirty():
            return None
        view = self._view
        live = view.live_internal_ids()
        if not len(live):
            # An all-deleted corpus has no projection span to rebuild from;
            # keep serving from tombstones until something is inserted.
            raise ValueError("compact: corpus would be empty — insert points "
                             "before compacting away the last live one")
        version = (view.n, view.n_tombstones)
        bulk = view.compacted_dataset()
        # Mid-rebuild fault point: the compacted dataset exists, the new
        # indices do not — a crash here must leave the old generation fully
        # intact (nothing has been swapped yet).
        self._faults.check("compact")
        index_e = build_index(bulk, exact=True, **self._build_params) \
            if self.index_e is not None else None
        index_a = build_index(bulk, exact=False, **self._build_params) \
            if self.index_a is not None else None
        return PreparedCompaction(version=version, bulk=bulk,
                                  index_e=index_e, index_a=index_a, live=live,
                                  ext=np.ascontiguousarray(self._ext_of[live]))

    def compact_commit(self, prep: PreparedCompaction | None) -> bool:
        """Atomically swap a prepared compaction in (the double-buffer flip).

        Cheap — pointer swaps plus the generation bump that scopes the
        backend LRU caches. Raises :class:`StaleCompactionError` when the
        streaming state moved since prepare (an interleaved ingest op)."""
        if prep is None:
            return False
        if self._view is None or \
                (self._view.n, self._view.n_tombstones) != prep.version:
            raise StaleCompactionError(
                f"streaming state moved since prepare "
                f"(prepared @ rows,tombstones={prep.version}, live="
                f"{(self._view.n, self._view.n_tombstones) if self._view is not None else None})")
        self._bulk = prep.bulk
        if self.index_e is not None:
            self.index_e = prep.index_e
        if self.index_a is not None:
            self.index_a = prep.index_a
        self._ext_buf = prep.ext
        self._ext_len = len(prep.live)
        # The map is identity iff no id was ever retired: ext values are
        # strictly increasing in [0, _next_ext), so full size == identity.
        # (_next_ext must participate: a compaction that trimmed only
        # *trailing* ids leaves ext_buf == arange, yet the next insert gets
        # external id _next_ext != its internal row.)
        self._identity_ids = self._ext_len == self._next_ext
        self._view = None
        self._deltas = {}
        self.corpus_generation += 1
        self._corpus_token = next(_CORPUS_TOKENS)
        self.ingest.compactions += 1
        self.ingest.generation = self.corpus_generation
        self._wal_append({"op": "compact",
                          "generation": self.corpus_generation})
        return True

    def compact(self) -> bool:
        """Fold the delta into a fresh immutable bulk index (atomic swap).

        Rebuilds with the constructor's build params over the live points in
        external-id order, remaps internal ids, bumps ``corpus_generation``
        (invalidating backend packed-subset/tile caches), and resets the
        delta. No-op (returns False) when nothing is dirty. Synchronous
        convenience over the prepare/commit split the runtime uses for
        off-thread rebuilds."""
        return self.compact_commit(self.compact_prepare())

    def _maybe_compact(self) -> None:
        if not self.auto_compact or self._view is None or self._replaying:
            # During WAL replay the logged compact records drive compaction —
            # the cadence already fired once, at its logged position.
            return
        if self._view.n_tombstones >= self._view.n:
            # Everything is dead: nothing to rebuild from. The delete that
            # got us here already succeeded — stay on tombstones until an
            # insert brings the corpus back (explicit compact() still raises).
            return
        churn = self._view.n_delta + self._view.n_tombstones
        if churn >= max(self.compact_min, self.compact_ratio * self._bulk.n):
            self.compact()

    def _externalize(self, cands: list[Candidate]) -> list[Candidate]:
        """Map internal candidate ids to stable external ids (identity until
        a compaction leaves holes in the id space)."""
        if self._identity_ids:
            return cands
        return [dataclasses.replace(
                    c, ids=tuple(int(self._ext_of[i]) for i in c.ids))
                for c in cands]

    def _record_ingest(self, stats: PipelineStats) -> None:
        stats.corpus_generation = self.corpus_generation
        stats.delta_points = self.delta_points
        stats.tombstones = self.tombstone_count
        stats.compactions = self.ingest.compactions

    # ------------------------------------------------------------ durability
    def _wal_append(self, record: dict) -> None:
        if self._wal is None or self._replaying:
            return
        # Inside an ingest_group() the fsync is deferred to the group barrier
        # (one fsync per batch window); the ack ordering contract moves with
        # it — callers must not ack grouped ops until the group exits.
        self._wal.append(record, sync=self._wal_group == 0)
        self.ingest.wal_appends += 1

    @contextlib.contextmanager
    def ingest_group(self):
        """Group-commit scope: WAL appends inside the block defer their fsync
        to one barrier at exit (``WriteAheadLog.sync``), so a run of ingest
        ops acknowledged together pays a single durability barrier.

        The fsync-before-ack contract is preserved at the group granularity:
        every record in the group is durable before the ``with`` block
        returns, so a caller that acks only after the block (the runtime's
        ingest-run path) never acks a volatile write. Nests harmlessly — only
        the outermost exit issues the barrier. A volatile engine (no WAL)
        degrades to a no-op scope."""
        self._wal_group += 1
        try:
            yield self
        finally:
            self._wal_group -= 1
            if self._wal_group == 0 and self._wal is not None \
                    and not self._replaying:
                # InjectedCrash from the wal_ack fault point propagates from
                # here — after the fsync, before any caller could ack.
                self._wal.sync()

    def _engine_meta(self) -> dict:
        return {
            "next_ext": int(self._next_ext),
            "identity_ids": bool(self._identity_ids),
            "corpus_generation": int(self.corpus_generation),
            "compact_ratio": self.compact_ratio,
            "compact_min": self.compact_min,
            "auto_compact": self.auto_compact,
            "build_exact": self.index_e is not None,
            "build_approx": self.index_a is not None,
            "ingest": self.ingest.as_dict(),
        }

    def attach_wal(self, root: str, faults: FaultPlan | None = None) -> None:
        """Make the engine durable under ``root`` (see ``serve.wal``).

        Writes the genesis snapshot (epoch 0: the current frozen state, so
        recovery always has a base corpus) and opens the WAL segment; from
        here every insert/delete/compact is fsync'd before its ack. A dirty
        engine compacts first — a snapshot is a clean generation boundary."""
        if self._wal is not None:
            raise RuntimeError(f"WAL already attached at {self._wal_root}")
        if faults is not None:
            self._faults = faults
        if self._streaming_dirty():
            self.compact()
        os.makedirs(root, exist_ok=True)
        self._wal_root = root
        self._wal_epoch = 0
        self._write_snapshot(0)
        walmod.write_manifest(root, 0)
        self._wal = walmod.WriteAheadLog(walmod.wal_path(root, 0),
                                         faults=self._faults)

    def _write_snapshot(self, epoch: int) -> None:
        walmod.save_snapshot(
            walmod.snap_dir(self._wal_root, epoch),
            dataset=self._bulk, index_e=self.index_e, index_a=self.index_a,
            build_params=self._build_params,
            engine_meta={**self._engine_meta(),
                         "ext": walmod.encode_array(
                             np.ascontiguousarray(self._ext_of))})

    def snapshot(self) -> str:
        """Roll the log: fold the delta (if dirty), persist the full engine
        state as the next epoch's snapshot, and start an empty WAL segment.
        After this, recovery replays nothing — the ack horizon moves from
        "snapshot + log suffix" to "snapshot". Returns the snapshot dir."""
        if self._wal is None:
            raise RuntimeError("snapshot() requires an attached WAL "
                               "(attach_wal first)")
        if self._streaming_dirty():
            self.compact()
        epoch = self._wal_epoch + 1
        self._write_snapshot(epoch)
        self._wal.close()
        # Ordering: the new (empty) segment must exist before the manifest
        # names its epoch — recovery reads the manifest first.
        new_wal = walmod.WriteAheadLog(walmod.wal_path(self._wal_root, epoch),
                                       faults=self._faults)
        walmod.write_manifest(self._wal_root, epoch)
        self._wal = new_wal
        self._wal_epoch = epoch
        self.ingest.snapshots += 1
        walmod.gc_epochs(self._wal_root, epoch)
        return walmod.snap_dir(self._wal_root, epoch)

    def _replay_record(self, rec: dict) -> None:
        op = rec["op"]
        if op == "insert":
            attrs = rec["attrs"]
            if attrs is not None:
                attrs = {name: walmod.decode_array(col)
                         for name, col in attrs.items()}
            tenant = rec["tenant"]
            if isinstance(tenant, dict) and "__nd__" in tenant:
                tenant = walmod.decode_array(tenant)
            ext = self.insert(walmod.decode_array(rec["points"]),
                              rec["keywords"], attrs=attrs, tenant=tenant)
            if len(ext) != rec["count"] or \
                    (len(ext) and int(ext[0]) != rec["first_ext"]):
                raise IOError(
                    f"WAL replay diverged: insert assigned ids "
                    f"{int(ext[0]) if len(ext) else None}+{len(ext)}, log "
                    f"recorded {rec['first_ext']}+{rec['count']}")
        elif op == "delete":
            self.delete(rec["ids"])
        elif op == "compact":
            self.compact()
            if self.corpus_generation != rec["generation"]:
                raise IOError(
                    f"WAL replay diverged: compact reached generation "
                    f"{self.corpus_generation}, log recorded "
                    f"{rec['generation']}")
        else:
            raise IOError(f"unknown WAL record op {op!r}")

    @classmethod
    def recover(cls, root: str, *, mesh=None, verify: bool = True,
                faults: FaultPlan | None = None) -> "NKSEngine":
        """Rebuild an engine from its WAL root: latest snapshot + log replay.

        The recovered engine answers **bit-identically** to an uninterrupted
        engine that executed the same acknowledged op sequence (the snapshot
        stores the built index structures verbatim, and replay re-runs the
        deterministic ingest path, including logged compactions at their
        logged positions). The WAL stays attached — the engine keeps
        appending to the recovered segment."""
        man = walmod.read_manifest(root)
        epoch = int(man["epoch"])
        snap = walmod.load_snapshot(walmod.snap_dir(root, epoch),
                                    verify=verify)
        bp, em = snap["build_params"], snap["engine"]
        engine = cls(snap["dataset"],
                     m=bp["m"], n_scales=bp["n_scales"], seed=bp["seed"],
                     w0=bp["w0"], n_buckets=bp["n_buckets"],
                     synopsis=bp.get("synopsis", False),
                     build_exact=em["build_exact"],
                     build_approx=em["build_approx"], mesh=mesh,
                     compact_ratio=em["compact_ratio"],
                     compact_min=em["compact_min"],
                     auto_compact=em["auto_compact"], faults=faults,
                     _indices=(snap["index_e"], snap["index_a"]))
        engine._ext_buf = walmod.decode_array(em["ext"])
        engine._ext_len = len(engine._ext_buf)
        engine._next_ext = em["next_ext"]
        engine._identity_ids = em["identity_ids"]
        engine.corpus_generation = em["corpus_generation"]
        for field, value in em["ingest"].items():
            setattr(engine.ingest, field, value)
        engine.ingest.replayed_ops = 0
        engine._wal_root = root
        engine._wal_epoch = epoch
        wal_file = walmod.wal_path(root, epoch)
        rstats = walmod.WalStats()
        engine._replaying = True
        try:
            for rec in walmod.WriteAheadLog.replay(wal_file, rstats):
                engine._replay_record(rec)
                engine.ingest.replayed_ops += 1
        finally:
            engine._replaying = False
        if rstats.torn_tail:
            # A torn tail is an unacknowledged op and replay skipped it, but
            # its bytes are still on disk: appending after them would plant a
            # CRC mismatch mid-file, and the *next* recovery would raise
            # TornRecordError — losing every write acknowledged after this
            # recovery. Truncate to the last whole record before reopening.
            with open(wal_file, "rb+") as f:
                f.truncate(rstats.valid_bytes)
                f.flush()
                os.fsync(f.fileno())
        engine._wal = walmod.WriteAheadLog(wal_file, faults=engine._faults)
        engine._wal.stats.replayed = rstats.replayed
        engine._wal.stats.torn_tail = rstats.torn_tail
        return engine

    @classmethod
    def from_store(cls, directory: str, *, mesh=None, mmap: bool = True,
                   verify: bool = False,
                   resident_budget_bytes: int | None = None,
                   **kw) -> "NKSEngine":
        """Open an engine over an out-of-core bulk store (``core.store``).

        With ``mmap=True`` (the default, and the point) the corpus points,
        keyword CSRs, and index bucket tables stay on disk as memory-mapped
        leaves — only touched pages become resident, the per-bucket synopses
        load eagerly (they are tiny and consulted per plan), and
        ``resident_budget_bytes`` caps the backend's hot-tier tile cache.
        Answers are bit-identical to an in-RAM engine built with the store's
        recorded ``build_params``: the store pins the hash geometry, so
        streaming absorbs and compactions continue the exact same sequence.
        """
        st = storemod.load_store(directory, mmap=mmap, verify=verify)
        bp = st["build_params"] or {}
        return cls(st["dataset"],
                   m=bp.get("m", 2), n_scales=bp.get("n_scales", 5),
                   seed=bp.get("seed", 0), w0=bp.get("w0"),
                   n_buckets=bp.get("n_buckets"),
                   synopsis=bp.get("synopsis", False),
                   build_exact=st["index_e"] is not None,
                   build_approx=st["index_a"] is not None,
                   mesh=mesh, resident_budget_bytes=resident_budget_bytes,
                   _indices=(st["index_e"], st["index_a"]), **kw)

    @property
    def wal_stats(self) -> "walmod.WalStats | None":
        return self._wal.stats if self._wal is not None else None

    def close(self) -> None:
        if self._wal is not None:
            self._wal.close()

    @classmethod
    def ingest_embeddings(cls, api, params, batches: Sequence[dict],
                          keywords: Sequence[Sequence[int]], **kw) -> "NKSEngine":
        """Build the corpus from model embeddings (any assigned arch)."""
        import jax.numpy as jnp
        embs = [np.asarray(api.embed(params, b), np.float32) for b in batches]
        points = np.concatenate(embs, axis=0)
        return cls(make_dataset(points, keywords), **kw)

    def _device_topk(self, keywords: Sequence[int], k: int,
                     stats: PipelineStats | None = None,
                     eligible: np.ndarray | None = None) -> list[Candidate]:
        """One anchor-star dispatch through the plane (sharded) or the
        single-device kernel — the device tier's unit of work. ``eligible``
        (a filtered query's point mask) restricts the packed groups; a group
        the filter empties means no feasible candidate, so the dispatch is
        skipped outright."""
        import jax.numpy as jnp
        from repro.core.distributed import nks_anchor_topk
        if eligible is not None:
            if any(not eligible[self.dataset.points_with(v)].any()
                   for v in keywords):
                return []
        t0 = time.perf_counter()
        if self.plane is not None:
            pg = self.plane.pack_groups(self.dataset, list(keywords),
                                        eligible=eligible)
            t1 = time.perf_counter()
            diams, cids = self.plane.nks_topk(jnp.asarray(pg.groups),
                                              jnp.asarray(pg.mask),
                                              jnp.asarray(pg.ids), k)
            diams = np.asarray(diams)
            if stats is not None:
                stats.sharded_dispatches += 1
                stats.t_collective_s += time.perf_counter() - t1
                for i in range(self.plane.n_shards):
                    stats.shard_dispatches[i] += 1
        else:
            from repro.core.device_plane import pack_groups
            groups, mask, ids = pack_groups(self.dataset, list(keywords),
                                            eligible=eligible)
            t1 = time.perf_counter()
            diams, cids = nks_anchor_topk(jnp.asarray(groups),
                                          jnp.asarray(mask),
                                          jnp.asarray(ids), k)
            diams = np.asarray(diams)
            if stats is not None:
                stats.shard_dispatches[0] += 1
        if stats is not None:
            stats.t_pack_s += t1 - t0
            stats.t_dispatch_s += time.perf_counter() - t1
        cands = []
        for i in range(k):
            if not np.isfinite(float(diams[i])):
                continue
            ids_i = tuple(sorted(set(int(x) for x in cids[i])))
            cands.append(Candidate(ids=ids_i, diameter=float(diams[i])))
        return cands

    def _resolve_filter(self, filter) -> "Filter | None":
        return Filter.coerce(filter)

    def _resolve_namespace(self, queries: Sequence[Sequence[int]],
                           flt: "Filter | None") -> list[list[int]]:
        """Per-tenant dictionary resolution, run before planning: a
        tenant-scoped query on a namespaced corpus speaks *tenant-local*
        keyword ids, mapped into the tenant's global dictionary slots here
        (out-of-range local ids raise — the tenant cannot name, let alone
        reach, another tenant's keywords)."""
        if flt is None or flt.tenant is None or self.dataset.tenants is None:
            return [list(q) for q in queries]
        ns = self.dataset.tenants
        return [ns.resolve(flt.tenant, q) for q in queries]

    def query(self, keywords: Sequence[int], k: int = 1,
              tier: str = "approx", filter=None,
              semantics=None) -> QueryResult:
        t0 = time.perf_counter()
        # Same API-boundary validation as query_batch: every entry path
        # (clean per-query searches included) rejects out-of-dictionary
        # keywords with the same ValueError instead of a numpy IndexError
        # from inside the search.
        self._validate_queries([keywords])
        flt = self._resolve_filter(filter)
        sem = QuerySemantics.coerce(semantics)
        flex = sem is not None and not sem.trivial_for(
            sorted(set(int(v) for v in keywords)))
        if tier == "device" and flex:
            raise ValueError(
                "device tier does not support flexible semantics; "
                "use tier='exact' or 'approx'")
        if tier in ("exact", "approx") and (self._streaming_dirty()
                                            or flt is not None or flex):
            # The per-query searches walk a frozen index; with a live delta
            # the batched pipeline (a batch of one reproduces them exactly,
            # per the PR-1 parity suite) is the delta-aware path — and the
            # filtered path, which evaluates the predicate once and threads
            # the eligibility mask through every stage. Flexible semantics
            # ride the same batched path (m-of-k expansion, weights, scored
            # queues live in ``_batch_search``).
            res = self.query_batch([keywords], k=k, tier=tier,
                                   backend="numpy", filter=flt,
                                   semantics=sem)[0]
            return dataclasses.replace(res, latency_s=time.perf_counter() - t0)
        if tier == "exact":
            pq = promish_e.search(self.dataset, self.index_e, keywords, k=k)
        elif tier == "approx":
            pq = promish_a.search(self.dataset, self.index_a, keywords, k=k)
        elif tier == "device":
            eligible = None
            resolved = list(keywords)
            if flt is not None:
                resolved = self._resolve_namespace([keywords], flt)[0]
                eligible = flt.evaluate(self.dataset)
                if self._view is not None:
                    self._view.mask_tombstones(eligible)
            cands = self._externalize(
                self._device_topk(resolved, k, eligible=eligible))
            return QueryResult(list(keywords), cands,
                               time.perf_counter() - t0, tier)
        else:
            raise ValueError(tier)
        return QueryResult(list(keywords), self._externalize(pq.items),
                           time.perf_counter() - t0, tier)

    # ------------------------------------------------------------- batched path
    def _validate_queries(self, queries: Sequence[Sequence[int]]
                          ) -> list[list[int]]:
        out = []
        for q in queries:
            q = sorted(set(int(v) for v in q))
            if any(v < 0 or v >= self.dataset.n_keywords for v in q):
                raise ValueError("query keyword outside dictionary")
            out.append(q)
        return out

    def _run_tasks(self, tasks: list[plan.SubsetTask], queries: list[list[int]],
                   pqs: list[TopK], backend: DistanceBackend,
                   stats: PipelineStats,
                   eligible: np.ndarray | None = None,
                   ctx: "plan.BatchPlanContext | None" = None,
                   timers: dict | None = None,
                   weights: "list[np.ndarray | None] | None" = None
                   ) -> tuple[int, int, int]:
        """Distance stage + enumeration stage for one batch of subset tasks.

        ``eligible`` is the batch's predicate mask: keyword groups restrict
        to eligible rows (a task whose filtered groups lose a keyword is
        dropped before any pack), and the backend folds the mask into the
        device-side join bitmask. ``ctx`` carries the batch's keyword-mask
        memoization; ``timers`` accumulates the enumeration stage's float64
        rescore wall time. ``weights`` maps each task's ``qidx`` to the
        query's (N,) keyword-weight vector (or None — unweighted): the
        dispatch/pack stages are weight-blind (the geometric join is a
        superset of the weighted one), only host settlement consumes it.
        Returns (tasks_searched, dispatches_issued, join_pairs)."""
        t0 = time.perf_counter()
        prepared = []
        for t in tasks:
            gl = local_groups(t.f_ids, queries[t.qidx], self.dataset,
                              eligible=eligible, ctx=ctx)
            if gl is not None:
                prepared.append((t, gl))
        stats.t_plan_s += time.perf_counter() - t0
        if not prepared:
            return 0, 0, 0
        d0 = backend.stats.dispatches
        # Radius substitution: when the source bucket's diameter bound
        # already beats the query's live r_k, every pair in the subset joins
        # — the backend's infinite-radius path synthesizes the identical
        # all-ones join without touching the (possibly cold) point rows.
        # Result- and join_count-preserving for both backends.
        radii = []
        for t, _ in prepared:
            r = pqs[t.qidx].kth_diameter()
            if np.isfinite(r) and t.diam_ub <= r:
                r = float("inf")
                stats.buckets_pruned_radius += 1
            radii.append(r)
        blocks = backend.self_join_blocks(
            self.dataset.points,
            [t.f_ids for t, _ in prepared],
            radii,
            keys=[t.f_ids.tobytes() for t, _ in prepared],
            generation=self._corpus_token,
            eligible=eligible)
        t1 = time.perf_counter()
        join_pairs = 0
        for (t, gl), db in zip(prepared, blocks):
            join_pairs += db.join_count
            stats.candidates_explored += enumerate_with_block(
                t.f_ids, gl, queries[t.qidx], self.dataset, pqs[t.qidx], db,
                timers=timers,
                weights=None if weights is None else weights[t.qidx])
        stats.t_enumerate_s += time.perf_counter() - t1
        return len(prepared), backend.stats.dispatches - d0, join_pairs

    def _batch_search(self, queries: list[list[int]], k: int, tier: str,
                      backend: DistanceBackend,
                      flt: "Filter | None" = None,
                      sem: "QuerySemantics | None" = None
                      ) -> tuple[list[TopK], PipelineStats]:
        exact = tier == "exact"
        index = self.index_e if exact else self.index_a
        if index is None:
            raise ValueError(f"engine built without the {tier!r} index")
        stats = PipelineStats(batch_size=len(queries), tier=tier,
                              backend=backend.name)
        b0 = dataclasses.replace(backend.stats)
        # dataclasses.replace shares the list fields — snapshot them by value
        # so the end-of-batch delta below is meaningful.
        b0_shards = (list(backend.stats.shard_dispatches),
                     list(backend.stats.shard_valid_cells),
                     list(backend.stats.shard_total_cells))
        b0_bins = dict(getattr(backend.stats, "bin_points", None) or {})
        # Flexible semantics: each query's m-of-k subqueries run the
        # plan/dispatch/enumerate loop as independent *execution* entries
        # that share the original query's queue (and weight vector) — the
        # queue's id-set dedup resolves cross-subquery duplicates, since a
        # candidate's cost and coverage depend only on (ids, Q). A classic
        # batch (``sem`` None) expands to itself: one execution entry per
        # query, plain TopK queues, no weights — every index below then
        # degenerates to the old per-query one, keeping results
        # bit-identical.
        if sem is None:
            pqs = [TopK(k, init_full=exact) for _ in queries]
            exec_queries: list[list[int]] = list(queries)
            exec_orig = list(range(len(queries)))
            exec_pqs, exec_weights = pqs, None
        else:
            pqs = [sem.make_pq(self.dataset, q, k, init_full=exact)
                   for q in queries]
            wvecs = [sem.weight_vector(self.dataset, q) for q in queries]
            exec_queries, exec_orig = [], []
            for o, q in enumerate(queries):
                for sub in sem.expand_subqueries(q):
                    exec_queries.append(sub)
                    exec_orig.append(o)
            exec_pqs = [pqs[o] for o in exec_orig]
            exec_weights = [wvecs[o] for o in exec_orig]
        stats.subqueries = len(exec_queries)
        # Streaming: plan over bulk ∪ delta, tombstones cleared from every
        # bitset (the subsets the backend packs and the enumeration walks
        # then contain live points only).
        delta = None
        if self._streaming_dirty():
            delta = self._deltas["e" if exact else "a"]
        t0 = time.perf_counter()
        # Filtered batch: evaluate the predicate/tenant mask ONCE here; every
        # downstream stage (plan pruning, group restriction, device fold)
        # consumes this same array. Tombstoned points are cleared from the
        # mask too, so eligibility always implies liveness.
        eligible = None
        if flt is not None:
            eligible = flt.evaluate(self.dataset)
            if self._view is not None:
                self._view.mask_tombstones(eligible)
            stats.eligible_points = int(eligible.sum())
            live = self.dataset.n - self.tombstone_count
            stats.filter_selectivity = round(
                stats.eligible_points / live, 6) if live else 0.0
        # Zone-map pruning: with per-bucket synopses built (synopsis=True /
        # a disk store) and a filter in play, the planner can skip buckets
        # whose zone maps are provably disjoint from the predicate before
        # their member lists are gathered. Pure accounting win — results are
        # bit-identical with the pruner on or off.
        zone = None
        if flt is not None and eligible is not None \
                and index.structures[0].synopsis is not None:
            zp = storemod.ZoneMapPruner(flt, self.dataset)
            zone = zp if zp.active else None
        # One BatchPlanContext per batch: keyword masks and covering-bucket
        # selections are memoized for the batch's lifetime (the corpus is
        # frozen while the batch runs).
        pctx = plan.BatchPlanContext(self.dataset)
        bitsets = [pctx.query_bitset(q) for q in exec_queries]
        if delta is not None:
            for bs in bitsets:
                self._view.mask_tombstones(bs)
        stats.t_plan_s += time.perf_counter() - t0
        explored = {i: set() for i in range(len(exec_queries))} if exact \
            else None
        active = list(range(len(exec_queries)))
        timers = {"rescore_s": 0.0}

        for s in range(index.n_scales):
            if not active:
                break
            sstats = ScaleStats(scale=s, active_queries=len(active))
            pstats = plan.PlanStats()
            t0 = time.perf_counter()
            tasks = plan.plan_scale(index, s, exec_queries, bitsets, active,
                                    explored, pstats, delta=delta,
                                    eligible=eligible, ctx=pctx, zone=zone)
            stats.t_plan_s += time.perf_counter() - t0
            sstats.buckets_selected = pstats.buckets_selected
            sstats.duplicate_subsets = pstats.duplicate_subsets
            sstats.filtered_subsets = pstats.filtered_subsets
            stats.filtered_subsets += pstats.filtered_subsets
            sstats.buckets_pruned_zonemap = pstats.buckets_pruned_zonemap
            stats.buckets_pruned_zonemap += pstats.buckets_pruned_zonemap
            sstats.tasks_planned = len(tasks)
            pr0 = stats.buckets_pruned_radius
            searched, dispatches, pairs = self._run_tasks(
                tasks, exec_queries, exec_pqs, backend, stats,
                eligible=eligible, ctx=pctx, timers=timers,
                weights=exec_weights)
            sstats.tasks_searched = searched
            sstats.dispatches = dispatches
            sstats.join_pairs = pairs
            sstats.buckets_pruned_radius = stats.buckets_pruned_radius - pr0
            # Per-query termination, exactly as the per-query searches do it:
            # E: Lemma-2 radius test after the scale; A: first full PQ.
            # Termination is a property of the ORIGINAL query's shared queue,
            # so one decision per original deactivates all its subqueries.
            still = []
            done_orig: dict[int, bool] = {}
            for qidx in active:
                o = exec_orig[qidx]
                if o not in done_orig:
                    if exact:
                        done_orig[o] = pqs[o].kth_diameter() \
                            <= index.w0 * (2.0 ** (s - 1))
                    else:
                        done_orig[o] = pqs[o].full()
                    if done_orig[o]:
                        sstats.queries_finished += 1
                if not done_orig[o]:
                    still.append(qidx)
            active = still
            stats.scales.append(sstats)

        if active:
            stats.fallback_queries = len(active)
            tasks = plan.fallback_tasks(bitsets, active, eligible=eligible)
            _, stats.fallback_dispatches, _ = self._run_tasks(
                tasks, exec_queries, exec_pqs, backend, stats,
                eligible=eligible, ctx=pctx, timers=timers,
                weights=exec_weights)
        stats.t_rescore_s = timers["rescore_s"]
        stats.t_pack_s = backend.stats.t_pack_s - b0.t_pack_s
        stats.t_dispatch_s = backend.stats.t_dispatch_s - b0.t_dispatch_s
        stats.cache_hits = backend.stats.cache_hits - b0.cache_hits
        stats.cache_misses = backend.stats.cache_misses - b0.cache_misses
        stats.h2d_bytes = backend.stats.h2d_bytes - b0.h2d_bytes
        stats.d2h_bytes = backend.stats.d2h_bytes - b0.d2h_bytes
        stats.cold_bytes_read = (backend.stats.cold_bytes_read
                                 - b0.cold_bytes_read)
        stats.sharded_dispatches = (backend.stats.sharded_dispatches
                                    - b0.sharded_dispatches)
        stats.t_collective_s = backend.stats.t_collective_s - b0.t_collective_s
        for dst, now, before in zip(
                (stats.shard_dispatches, stats.shard_valid_cells,
                 stats.shard_total_cells),
                (backend.stats.shard_dispatches, backend.stats.shard_valid_cells,
                 backend.stats.shard_total_cells), b0_shards):
            dst.extend(v - (before[i] if i < len(before) else 0)
                       for i, v in enumerate(now))
        # Cascade / routing counters (zero on backends without the fields).
        for f in ("prune_tier_dispatches", "cells_pruned",
                  "host_routed_dispatches", "host_routed_subsets"):
            setattr(stats, f, getattr(backend.stats, f, 0) - getattr(b0, f, 0))
        for f in ("t_prune_s", "t_host_s"):
            setattr(stats, f,
                    getattr(backend.stats, f, 0.0) - getattr(b0, f, 0.0))
        stats.bin_strategy = getattr(backend, "bin_strategy", "")
        for edge, (pts, padded) in (getattr(backend.stats, "bin_points", None)
                                    or {}).items():
            before = b0_bins.get(edge, (0, 0))
            dp, dpad = pts - before[0], padded - before[1]
            if dp or dpad:
                stats.bin_occupancy[edge] = (dp, dpad)
        return pqs, stats

    def query_batch(self, queries: Sequence[Sequence[int]], k: int = 1,
                    tier: str = "approx",
                    backend: str | DistanceBackend = "numpy",
                    filter=None, semantics=None) -> list[QueryResult]:
        """Answer a batch of queries through the staged pipeline.

        Bucket selection, Algorithm-2 dedup, and device dispatch are amortised
        across the batch: with ``backend="pallas"`` each scale issues a few
        size-binned fused threshold-join dispatches covering all live subsets
        (subsets at an infinite pruning radius skip the device — their join
        mask is all-ones by construction); on a mesh-attached engine those
        dispatches shard over the device plane. The ``device`` tier issues
        one anchor-star dispatch per query — through the plane's shard_map
        program when a mesh is attached, the single-device kernel otherwise —
        and records the same PipelineStats. Per-result latency is the batch
        wall time divided by the batch size (attribution inside a fused
        dispatch is meaningless). Pipeline accounting lands in
        ``self.last_batch_stats``.

        ``filter`` (a :class:`~repro.core.filters.Filter` or its JSON dict
        form) applies attribute predicates and tenant scoping to the whole
        batch: the mask is evaluated once, planning prunes fully-ineligible
        subsets, the device folds eligibility into the packed join bitmask
        (no new D2H), and every candidate is drawn from eligible points only.
        On a namespaced multi-tenant corpus a tenant-scoped batch speaks
        tenant-local keyword ids, resolved through the tenant's dictionary
        before planning.

        ``semantics`` (a :class:`~repro.core.semantics.QuerySemantics` or
        its JSON dict form ``{"m": ..., "weights": {...}, "score": ...,
        "alpha": ...}``) applies m-of-k partial coverage, per-keyword
        weights, and scored ranking to the whole batch. Degenerate semantics
        (full coverage, unit weights, no scoring) are dropped before
        planning, so results stay bit-identical to a plain call; the device
        tier rejects non-trivial semantics.
        """
        flt = self._resolve_filter(filter)
        sem = QuerySemantics.coerce(semantics)
        if sem is not None and tier == "device":
            if any(not sem.trivial_for(sorted(set(int(v) for v in q)))
                   for q in queries):
                raise ValueError(
                    "device tier does not support flexible semantics; "
                    "use tier='exact' or 'approx'")
            sem = None
        if tier == "device":
            t0 = time.perf_counter()
            stats = PipelineStats(
                batch_size=len(queries), tier=tier,
                backend="device-plane" if self.plane is not None else "anchor")
            stats.shard_dispatches = [0] * (
                self.plane.n_shards if self.plane is not None else 1)
            eligible = None
            resolved = [list(q) for q in queries]
            if flt is not None:
                resolved = self._resolve_namespace(queries, flt)
                eligible = flt.evaluate(self.dataset)
                if self._view is not None:
                    self._view.mask_tombstones(eligible)
                stats.eligible_points = int(eligible.sum())
            out = []
            for q, rq in zip(queries, resolved):
                cands = self._externalize(
                    self._device_topk(rq, k, stats, eligible=eligible))
                # echo the caller's keywords (tenant-local on a namespaced
                # corpus), never the resolved global slots
                out.append(QueryResult(list(q), cands, 0.0, tier))
            per_q = (time.perf_counter() - t0) / max(len(queries), 1)
            out = [dataclasses.replace(r, latency_s=per_q) for r in out]
            self._record_ingest(stats)
            self.last_batch_stats = stats
            return out
        if tier not in ("exact", "approx"):
            raise ValueError(tier)
        t0 = time.perf_counter()
        qlists = self._validate_queries(self._resolve_namespace(queries, flt))
        if sem is not None:
            if flt is not None and flt.tenant is not None \
                    and self.dataset.tenants is not None:
                # Weight keys speak the same tenant-local ids as the query
                # keywords — resolve them through the same namespace.
                ns, tenant = self.dataset.tenants, flt.tenant
                sem = sem.resolve_keywords(
                    lambda kw: ns.resolve(tenant, [kw])[0])
            # Degenerate semantics normalise away entirely: the classic
            # pipeline below is then byte-for-byte the pre-semantics one.
            if all(sem.trivial_for(q) for q in qlists):
                sem = None
        pqs, stats = self._batch_search(qlists, k, tier,
                                        self._resolve_backend(backend),
                                        flt=flt, sem=sem)
        self._record_ingest(stats)
        self.last_batch_stats = stats
        per_q = (time.perf_counter() - t0) / max(len(qlists), 1)
        # results echo the caller's keyword lists verbatim — resolved global
        # slots (tenant namespaces) and normalization stay internal
        return [QueryResult(list(q), self._externalize(pq.items), per_q, tier)
                for q, pq in zip(queries, pqs)]

    def _resolve_backend(self, backend: str | DistanceBackend) -> DistanceBackend:
        """Backend resolution is where the plane plugs in: a string
        ``"pallas"`` on a mesh-attached engine gets the sharded dispatch
        route, and an out-of-core engine's ``resident_budget_bytes`` caps
        the hot-tier tile LRU; instances pass through untouched (caller's
        placement — and cache sizing — wins)."""
        if backend == "pallas":
            kw = {}
            if self.plane is not None:
                kw["plane"] = self.plane
            if self.resident_budget_bytes is not None:
                kw["cache_bytes"] = self.resident_budget_bytes
            return get_backend(backend, **kw)
        return get_backend(backend)
