"""Batched NKS serving engine.

Production shape: a frontend batches keyword-set queries; the engine answers
from a ProMiSH index over an embedding corpus. Three quality/latency tiers:

  * ``exact``   — ProMiSH-E (100% accuracy, Lemma-2 guarantee);
  * ``approx``  — ProMiSH-A (the paper's fast tier);
  * ``device``  — the anchor-star device kernel (repro.core.distributed),
                  batched and shardable over the mesh; used when the corpus
                  is sharded across chips.

The corpus can be ingested directly (points + keywords) or produced by any
assigned architecture through ``ingest_embeddings`` (models.api.embed ->
ProMiSH points — the paper's Flickr use case with learned features).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import numpy as np

from repro.core import promish_a, promish_e
from repro.core.distributed import nks_anchor_topk, pack_groups
from repro.core.index import PromishIndex, build_index
from repro.core.types import Candidate, KeywordDataset, make_dataset


@dataclasses.dataclass
class QueryResult:
    query: list[int]
    candidates: list[Candidate]
    latency_s: float
    tier: str


class NKSEngine:
    def __init__(self, dataset: KeywordDataset, *, m: int = 2, n_scales: int = 5,
                 seed: int = 0, build_exact: bool = True, build_approx: bool = True):
        self.dataset = dataset
        self.index_e: PromishIndex | None = None
        self.index_a: PromishIndex | None = None
        if build_exact:
            self.index_e = build_index(dataset, m=m, n_scales=n_scales,
                                       exact=True, seed=seed)
        if build_approx:
            self.index_a = build_index(dataset, m=m, n_scales=n_scales,
                                       exact=False, seed=seed)

    @classmethod
    def ingest_embeddings(cls, api, params, batches: Sequence[dict],
                          keywords: Sequence[Sequence[int]], **kw) -> "NKSEngine":
        """Build the corpus from model embeddings (any assigned arch)."""
        import jax.numpy as jnp
        embs = [np.asarray(api.embed(params, b), np.float32) for b in batches]
        points = np.concatenate(embs, axis=0)
        return cls(make_dataset(points, keywords), **kw)

    def query(self, keywords: Sequence[int], k: int = 1,
              tier: str = "approx") -> QueryResult:
        t0 = time.perf_counter()
        if tier == "exact":
            pq = promish_e.search(self.dataset, self.index_e, keywords, k=k)
        elif tier == "approx":
            pq = promish_a.search(self.dataset, self.index_a, keywords, k=k)
        elif tier == "device":
            import jax.numpy as jnp
            groups, mask, ids = pack_groups(self.dataset, list(keywords))
            diams, cids = nks_anchor_topk(jnp.asarray(groups),
                                          jnp.asarray(mask),
                                          jnp.asarray(ids), k)
            cands = []
            for i in range(k):
                if not np.isfinite(float(diams[i])):
                    continue
                ids_i = tuple(sorted(set(int(x) for x in cids[i])))
                cands.append(Candidate(ids=ids_i, diameter=float(diams[i])))
            return QueryResult(list(keywords), cands,
                               time.perf_counter() - t0, tier)
        else:
            raise ValueError(tier)
        return QueryResult(list(keywords), pq.items,
                           time.perf_counter() - t0, tier)

    def query_batch(self, queries: Sequence[Sequence[int]], k: int = 1,
                    tier: str = "approx") -> list[QueryResult]:
        return [self.query(q, k=k, tier=tier) for q in queries]
