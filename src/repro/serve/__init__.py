"""repro.serve — batched NKS serving engine."""
from repro.serve.engine import NKSEngine  # noqa: F401
