"""Decoder-only transformer assembly for dense / moe / ssm / hybrid / vlm
families.

Layers are **scan-stacked**: parameters for homogeneous layer groups carry a
leading layer axis and the forward pass is one `jax.lax.scan` over it, so the
traced graph holds one layer body regardless of depth (compile-time and
HLO-size control for the 40-cell dry-run). Heterogeneous interleavings
(llama4's dense/MoE alternation, the VLM's every-5th cross-attention layer)
scan over *super-blocks* containing one instance of each member.

Three explicit drivers share the layer functions:
  * ``forward_train``  — no cache I/O, remat-able, returns (logits, aux);
  * ``prefill``        — builds the stacked KV/SSM cache, returns (logits, cache);
  * ``decode``         — one-token step, cache in/out.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.common import (ACT_DTYPE, AttnSpec, Params, apply_mlp,
                                 apply_norm, cross_attention, cross_kv,
                                 dense_init, embed_tokens, init_attention,
                                 init_embed, init_mlp, init_norm,
                                 self_attention, split_keys, unembed)

HYMBA_WINDOW = 1024     # sliding-window width for hybrid attention heads


def attn_spec(cfg: ArchConfig, *, window: int | None = None,
              causal: bool = True) -> AttnSpec:
    return AttnSpec(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                    head_dim=cfg.resolved_head_dim, d_model=cfg.d_model,
                    qk_norm=cfg.qk_norm, bias=cfg.attn_bias, causal=causal,
                    window=window, rope_theta=cfg.rope_theta)


# ------------------------------------------------------------ layer defs
def init_self_layer(key, cfg: ArchConfig, *, use_moe: bool) -> Params:
    ks = split_keys(key, 4)
    p: Params = {
        "ln1": init_norm(ks[0], cfg.d_model, cfg.norm),
        "attn": init_attention(ks[1], attn_spec(cfg)),
        "ln2": init_norm(ks[2], cfg.d_model, cfg.norm),
    }
    if use_moe:
        p["moe"] = moe_lib.init_moe(ks[3], cfg.d_model, cfg.moe)
    else:
        p["mlp"] = init_mlp(ks[3], cfg.d_model, cfg.d_ff, cfg.mlp)
    return p


def apply_self_layer(p: Params, cfg: ArchConfig, x, positions, *,
                     cache=None, use_moe: bool, window: int | None = None):
    """Returns (x, kv {"k","v"}, aux)."""
    spec = attn_spec(cfg, window=window)
    h = apply_norm(p["ln1"], x, cfg.norm, cfg.norm_eps)
    att, kv = self_attention(p["attn"], spec, h, positions, cache=cache)
    x = x + att
    h = apply_norm(p["ln2"], x, cfg.norm, cfg.norm_eps)
    if use_moe:
        out, aux = moe_lib.apply_moe(p["moe"], h, cfg.moe)
    else:
        out, aux = apply_mlp(p["mlp"], h, cfg.mlp), jnp.float32(0.0)
    return x + out, kv, aux


def init_ssm_layer(key, cfg: ArchConfig) -> Params:
    ks = split_keys(key, 2)
    return {"ln1": init_norm(ks[0], cfg.d_model, cfg.norm),
            "ssm": ssm_lib.init_ssm(ks[1], cfg.d_model, cfg.ssm)}


def apply_ssm_layer(p: Params, cfg: ArchConfig, x, *, state=None):
    h = apply_norm(p["ln1"], x, cfg.norm, cfg.norm_eps)
    out, new_state = ssm_lib.apply_ssm(p["ssm"], h, cfg.ssm, state=state)
    return x + out, new_state


def init_hybrid_layer(key, cfg: ArchConfig) -> Params:
    """Hymba: parallel attention + SSM heads fused by per-branch norms."""
    ks = split_keys(key, 7)
    return {
        "ln1": init_norm(ks[0], cfg.d_model, cfg.norm),
        "attn": init_attention(ks[1], attn_spec(cfg, window=HYMBA_WINDOW)),
        "ssm": ssm_lib.init_ssm(ks[2], cfg.d_model, cfg.ssm),
        "na": init_norm(ks[3], cfg.d_model, cfg.norm),
        "ns": init_norm(ks[4], cfg.d_model, cfg.norm),
        "ln2": init_norm(ks[5], cfg.d_model, cfg.norm),
        "mlp": init_mlp(ks[6], cfg.d_model, cfg.d_ff, cfg.mlp),
    }


def apply_hybrid_layer(p: Params, cfg: ArchConfig, x, positions, *,
                       cache=None, state=None):
    spec = attn_spec(cfg, window=HYMBA_WINDOW)
    h = apply_norm(p["ln1"], x, cfg.norm, cfg.norm_eps)
    att, kv = self_attention(p["attn"], spec, h, positions, cache=cache)
    ssm_out, new_state = ssm_lib.apply_ssm(p["ssm"], h, cfg.ssm, state=state)
    fused = 0.5 * (apply_norm(p["na"], att, cfg.norm, cfg.norm_eps)
                   + apply_norm(p["ns"], ssm_out, cfg.norm, cfg.norm_eps))
    x = x + fused
    h = apply_norm(p["ln2"], x, cfg.norm, cfg.norm_eps)
    return x + apply_mlp(p["mlp"], h, cfg.mlp), kv, new_state


def init_cross_layer(key, cfg: ArchConfig) -> Params:
    """Gated vision cross-attention layer (llama-3.2-vision style)."""
    ks = split_keys(key, 4)
    return {
        "ln1": init_norm(ks[0], cfg.d_model, cfg.norm),
        "xattn": init_attention(ks[1], attn_spec(cfg, causal=False)),
        "gate_a": jnp.zeros((), jnp.float32),
        "ln2": init_norm(ks[2], cfg.d_model, cfg.norm),
        "mlp": init_mlp(ks[3], cfg.d_model, cfg.d_ff, cfg.mlp),
        "gate_m": jnp.zeros((), jnp.float32),
    }


def apply_cross_layer(p: Params, cfg: ArchConfig, x, *, kv_src=None, k=None, v=None):
    spec = attn_spec(cfg, causal=False)
    h = apply_norm(p["ln1"], x, cfg.norm, cfg.norm_eps)
    att = cross_attention(p["xattn"], spec, h, kv_src, k=k, v=v)
    x = x + jnp.tanh(p["gate_a"]).astype(x.dtype) * att
    h = apply_norm(p["ln2"], x, cfg.norm, cfg.norm_eps)
    return x + jnp.tanh(p["gate_m"]).astype(x.dtype) * apply_mlp(p["mlp"], h, cfg.mlp)


# ----------------------------------------------------------- param assembly
def _stacked(init_fn, key, n: int):
    """vmap the per-layer init over n keys -> leading layer axis on leaves."""
    return jax.vmap(init_fn)(jax.random.split(key, n))


def n_blocks(cfg: ArchConfig) -> int:
    if cfg.family == "moe" and cfg.moe.every > 1:
        return cfg.n_layers // cfg.moe.every
    if cfg.family == "vlm":
        return cfg.n_layers // cfg.cross_attn_every
    return cfg.n_layers


def init_params(cfg: ArchConfig, key) -> Params:
    ks = split_keys(key, 4)
    p: Params = {"embed": init_embed(ks[0], cfg.vocab_size, cfg.d_model,
                                     cfg.tie_embeddings),
                 "final_norm": init_norm(ks[1], cfg.d_model, cfg.norm)}
    fam = cfg.family
    if fam == "dense":
        p["layers"] = _stacked(lambda k: init_self_layer(k, cfg, use_moe=False),
                               ks[2], cfg.n_layers)
    elif fam == "moe" and cfg.moe.every == 1:
        p["layers"] = _stacked(lambda k: init_self_layer(k, cfg, use_moe=True),
                               ks[2], cfg.n_layers)
    elif fam == "moe":
        every = cfg.moe.every
        p["layers"] = _stacked(
            lambda k: {
                "dense": jax.vmap(
                    lambda kk: init_self_layer(kk, cfg, use_moe=False))(
                        jax.random.split(k, every - 1)),
                "moe": init_self_layer(jax.random.fold_in(k, 1), cfg,
                                       use_moe=True),
            }, ks[2], n_blocks(cfg))
    elif fam == "ssm":
        p["layers"] = _stacked(lambda k: init_ssm_layer(k, cfg), ks[2],
                               cfg.n_layers)
    elif fam == "hybrid":
        p["layers"] = _stacked(lambda k: init_hybrid_layer(k, cfg), ks[2],
                               cfg.n_layers)
    elif fam == "vlm":
        every = cfg.cross_attn_every
        p["layers"] = _stacked(
            lambda k: {
                "self": jax.vmap(
                    lambda kk: init_self_layer(kk, cfg, use_moe=False))(
                        jax.random.split(k, every - 1)),
                "cross": init_cross_layer(jax.random.fold_in(k, 1), cfg),
            }, ks[2], n_blocks(cfg))
        p["vis_proj"] = dense_init(ks[3], (cfg.vision_dim, cfg.d_model))
    else:
        raise ValueError(f"family {fam} is handled by models.audio")
    return p


def _positions(bsz, s, pos0=None):
    base = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (bsz, s))
    return base if pos0 is None else base + pos0[None, None]


def _kvc(kv_stackslice, pos0):
    return {"k": kv_stackslice["k"], "v": kv_stackslice["v"], "pos": pos0}


# --------------------------------------------------------------- train
def forward_train(params: Params, cfg: ArchConfig, tokens, *, extra=None,
                  remat: bool = True, return_hidden: bool = False):
    """(B,S) tokens -> (logits (B,S,V), aux loss). No cache I/O.
    ``return_hidden`` swaps logits for final-norm hidden states (B,S,D) —
    the embedding trunk that feeds ProMiSH."""
    bsz, s = tokens.shape
    x = embed_tokens(params["embed"], tokens)
    positions = _positions(bsz, s)
    fam = cfg.family
    aux0 = jnp.float32(0.0)

    if fam == "vlm":
        vis = extra["patches"].astype(ACT_DTYPE) @ params["vis_proj"].astype(ACT_DTYPE)

    if fam == "dense" or (fam == "moe" and cfg.moe.every == 1):
        use_moe = fam == "moe"

        def body(carry, p_l):
            x, aux = carry
            x, _, a = apply_self_layer(p_l, cfg, x, positions, use_moe=use_moe)
            return (x, aux + a), None
    elif fam == "moe":
        def body(carry, p_b):
            x, aux = carry

            def inner(c, p_d):
                xx, aa = c
                xx, _, a = apply_self_layer(p_d, cfg, xx, positions, use_moe=False)
                return (xx, aa + a), None

            (x, aux), _ = jax.lax.scan(inner, (x, aux), p_b["dense"])
            x, _, a = apply_self_layer(p_b["moe"], cfg, x, positions, use_moe=True)
            return (x, aux + a), None
    elif fam == "ssm":
        def body(carry, p_l):
            x, aux = carry
            x, _ = apply_ssm_layer(p_l, cfg, x)
            return (x, aux), None
    elif fam == "hybrid":
        def body(carry, p_l):
            x, aux = carry
            x, _, _ = apply_hybrid_layer(p_l, cfg, x, positions)
            return (x, aux), None
    elif fam == "vlm":
        def body(carry, p_b):
            x, aux = carry

            def inner(xx, p_d):
                xx, _, _ = apply_self_layer(p_d, cfg, xx, positions, use_moe=False)
                return xx, None

            x, _ = jax.lax.scan(inner, x, p_b["self"])
            x = apply_cross_layer(p_b["cross"], cfg, x, kv_src=vis)
            return (x, aux), None
    else:
        raise ValueError(fam)

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(body, (x, aux0), params["layers"])
    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    if return_hidden:
        return x, aux
    return unembed(params["embed"], x, cfg.vocab_size), aux


# --------------------------------------------------------------- prefill
def prefill(params: Params, cfg: ArchConfig, tokens, *, extra=None,
            max_seq: int | None = None):
    """Builds the serving cache. Returns (last-token logits (B,V), cache).

    The KV cache is allocated at ``max_seq`` (>= S) so subsequent decode
    steps update it in place.
    """
    bsz, s = tokens.shape
    max_seq = s if max_seq is None else max_seq
    x = embed_tokens(params["embed"], tokens)
    positions = _positions(bsz, s)
    fam = cfg.family
    pad = max_seq - s

    def pad_kv(kv):
        return jax.tree.map(
            lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0))), kv)

    if fam == "vlm":
        vis = extra["patches"].astype(ACT_DTYPE) @ params["vis_proj"].astype(ACT_DTYPE)
        spec = attn_spec(cfg, causal=False)

    if fam == "dense" or (fam == "moe" and cfg.moe.every == 1):
        use_moe = fam == "moe"

        def body(x, p_l):
            x, kv, _ = apply_self_layer(p_l, cfg, x, positions, use_moe=use_moe)
            return x, pad_kv(kv)

        x, kvs = jax.lax.scan(body, x, params["layers"])
        cache_layers = kvs
    elif fam == "moe":
        def body(x, p_b):
            def inner(xx, p_d):
                xx, kv, _ = apply_self_layer(p_d, cfg, xx, positions, use_moe=False)
                return xx, pad_kv(kv)

            x, kv_dense = jax.lax.scan(inner, x, p_b["dense"])
            x, kv_moe, _ = apply_self_layer(p_b["moe"], cfg, x, positions,
                                            use_moe=True)
            return x, {"dense": kv_dense, "moe": pad_kv(kv_moe)}

        x, cache_layers = jax.lax.scan(body, x, params["layers"])
    elif fam == "ssm":
        def body(x, p_l):
            x, st = apply_ssm_layer(p_l, cfg, x)
            return x, st

        x, cache_layers = jax.lax.scan(body, x, params["layers"])
    elif fam == "hybrid":
        def body(x, p_l):
            x, kv, st = apply_hybrid_layer(p_l, cfg, x, positions)
            return x, {**pad_kv(kv), "state": st}

        x, cache_layers = jax.lax.scan(body, x, params["layers"])
    elif fam == "vlm":
        def body(x, p_b):
            def inner(xx, p_d):
                xx, kv, _ = apply_self_layer(p_d, cfg, xx, positions, use_moe=False)
                return xx, pad_kv(kv)

            x, kv_self = jax.lax.scan(inner, x, p_b["self"])
            xk, xv = cross_kv(p_b["cross"]["xattn"], spec, vis)
            x = apply_cross_layer(p_b["cross"], cfg, x, k=xk, v=xv)
            return x, {"self": kv_self, "xk": xk, "xv": xv}

        x, cache_layers = jax.lax.scan(body, x, params["layers"])
    else:
        raise ValueError(fam)

    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    logits = unembed(params["embed"], x[:, -1:, :], cfg.vocab_size)[:, 0, :]
    cache = {"layers": cache_layers, "pos": jnp.asarray(s, jnp.int32)}
    return logits, cache


# --------------------------------------------------------------- decode
def decode(params: Params, cfg: ArchConfig, cache: Params, tokens):
    """One serving step: tokens (B,1) -> (logits (B,V), updated cache)."""
    bsz, s = tokens.shape
    pos0 = cache["pos"]
    x = embed_tokens(params["embed"], tokens)
    positions = _positions(bsz, s, pos0)
    fam = cfg.family

    if fam == "dense" or (fam == "moe" and cfg.moe.every == 1):
        use_moe = fam == "moe"

        def body(x, inp):
            p_l, kv = inp
            x, nkv, _ = apply_self_layer(p_l, cfg, x, positions,
                                         cache=_kvc(kv, pos0), use_moe=use_moe)
            return x, nkv

        x, new_layers = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
    elif fam == "moe":
        def body(x, inp):
            p_b, kv_b = inp

            def inner(xx, pin):
                p_d, kv_d = pin
                xx, nkv, _ = apply_self_layer(p_d, cfg, xx, positions,
                                              cache=_kvc(kv_d, pos0),
                                              use_moe=False)
                return xx, nkv

            x, nkv_dense = jax.lax.scan(inner, x, (p_b["dense"], kv_b["dense"]))
            x, nkv_moe, _ = apply_self_layer(p_b["moe"], cfg, x, positions,
                                             cache=_kvc(kv_b["moe"], pos0),
                                             use_moe=True)
            return x, {"dense": nkv_dense, "moe": nkv_moe}

        x, new_layers = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
    elif fam == "ssm":
        def body(x, inp):
            p_l, st = inp
            x, nst = apply_ssm_layer(p_l, cfg, x, state=st)
            return x, nst

        x, new_layers = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
    elif fam == "hybrid":
        def body(x, inp):
            p_l, c_l = inp
            st = c_l["state"]
            x, nkv, nst = apply_hybrid_layer(p_l, cfg, x, positions,
                                             cache=_kvc(c_l, pos0), state=st)
            return x, {**nkv, "state": nst}

        x, new_layers = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
    elif fam == "vlm":
        def body(x, inp):
            p_b, c_b = inp

            def inner(xx, pin):
                p_d, kv_d = pin
                xx, nkv, _ = apply_self_layer(p_d, cfg, xx, positions,
                                              cache=_kvc(kv_d, pos0),
                                              use_moe=False)
                return xx, nkv

            x, nkv_self = jax.lax.scan(inner, x, (p_b["self"], c_b["self"]))
            x = apply_cross_layer(p_b["cross"], cfg, x, k=c_b["xk"], v=c_b["xv"])
            return x, {"self": nkv_self, "xk": c_b["xk"], "xv": c_b["xv"]}

        x, new_layers = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
    else:
        raise ValueError(fam)

    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg.vocab_size)[:, -1, :]
    return logits, {"layers": new_layers, "pos": pos0 + s}


# --------------------------------------------------------------- cache init
def init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype=ACT_DTYPE) -> Params:
    """Empty decode cache (used when lowering decode_* cells directly)."""
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    fam = cfg.family
    kv_shape = (batch, max_seq, kv, hd)

    def kv_stack(n, extra_lead=()):
        return {"k": jnp.zeros((n, *extra_lead, *kv_shape), dtype),
                "v": jnp.zeros((n, *extra_lead, *kv_shape), dtype)}

    if fam == "dense" or (fam == "moe" and cfg.moe.every == 1):
        layers = kv_stack(cfg.n_layers)
    elif fam == "moe":
        nb, every = n_blocks(cfg), cfg.moe.every
        layers = {"dense": kv_stack(nb, (every - 1,)), "moe": kv_stack(nb)}
    elif fam == "ssm":
        st = ssm_lib.init_state(batch, cfg.d_model, cfg.ssm)
        layers = jax.tree.map(
            lambda a: jnp.zeros((cfg.n_layers, *a.shape), a.dtype), st)
    elif fam == "hybrid":
        st = ssm_lib.init_state(batch, cfg.d_model, cfg.ssm)
        layers = {**kv_stack(cfg.n_layers),
                  "state": jax.tree.map(
                      lambda a: jnp.zeros((cfg.n_layers, *a.shape), a.dtype), st)}
    elif fam == "vlm":
        nb, every = n_blocks(cfg), cfg.cross_attn_every
        layers = {"self": kv_stack(nb, (every - 1,)),
                  "xk": jnp.zeros((nb, batch, cfg.vision_tokens, kv, hd), dtype),
                  "xv": jnp.zeros((nb, batch, cfg.vision_tokens, kv, hd), dtype)}
    else:
        raise ValueError(fam)
    return {"layers": layers, "pos": jnp.zeros((), jnp.int32)}
