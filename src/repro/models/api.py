"""Unified model API — one entry point for all 10 assigned architectures.

    api = model_api(get_config("qwen3-32b"))
    params = api.init(jax.random.PRNGKey(0))
    loss, metrics = api.loss(params, batch)
    logits, cache = api.prefill(params, batch)
    logits, cache = api.decode(params, cache, tokens)
    emb = api.embed(params, batch)           # (B, d_model) -> ProMiSH points

``input_specs(cfg, cell)`` returns ShapeDtypeStructs for every model input of
an assigned shape cell (weak-type-correct, shardable, no allocation) — the
multi-pod dry-run contract.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeCell
from repro.models import audio as audio_lib
from repro.models import transformer as tf_lib
from repro.models.common import ACT_DTYPE, Params

Batch = dict[str, Any]


def _xent(logits, targets, mask=None):
    """Stable token-mean cross-entropy; fp32 log-sum-exp."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ArchConfig
    init: Callable
    loss: Callable
    prefill: Callable
    decode: Callable
    init_cache: Callable
    embed: Callable


def _extra_of(cfg: ArchConfig, batch: Batch):
    if cfg.family == "vlm":
        return {"patches": batch["patches"]}
    if cfg.family == "audio":
        return {"frames": batch["frames"]}
    return None


def model_api(cfg: ArchConfig) -> ModelAPI:
    is_audio = cfg.family == "audio"
    mod = audio_lib if is_audio else tf_lib

    def init(key):
        return mod.init_params(cfg, key)

    def loss(params: Params, batch: Batch, *, remat: bool = True):
        extra = _extra_of(cfg, batch)
        logits, aux = mod.forward_train(params, cfg, batch["tokens"],
                                        extra=extra, remat=remat)
        xent = _xent(logits, batch["targets"], batch.get("mask"))
        return xent + aux, {"xent": xent, "aux": aux}

    def prefill(params: Params, batch: Batch, *, max_seq: int | None = None):
        extra = _extra_of(cfg, batch)
        return mod.prefill(params, cfg, batch["tokens"], extra=extra,
                           max_seq=max_seq)

    def decode(params: Params, cache: Params, tokens):
        return mod.decode(params, cfg, cache, tokens)

    def init_cache(batch: int, max_seq: int, dtype=ACT_DTYPE):
        return mod.init_cache(cfg, batch, max_seq, dtype)

    def embed(params: Params, batch: Batch):
        """Mean-pooled final hidden states -> (B, d_model) ProMiSH points."""
        extra = _extra_of(cfg, batch)
        hidden, _ = mod.forward_train(params, cfg, batch["tokens"], extra=extra,
                                      remat=False, return_hidden=True)
        mask = batch.get("mask")
        if mask is None:
            return hidden.mean(axis=1)
        m = mask.astype(hidden.dtype)[..., None]
        return (hidden * m).sum(1) / jnp.maximum(m.sum(1), 1.0)

    return ModelAPI(cfg=cfg, init=init, loss=loss, prefill=prefill,
                    decode=decode, init_cache=init_cache, embed=embed)


# ------------------------------------------------------------- input specs
def input_specs(cfg: ArchConfig, cell: ShapeCell) -> Batch:
    """ShapeDtypeStruct stand-ins for every input of (arch x cell)."""
    b, s = cell.global_batch, cell.seq_len
    i32 = jnp.int32

    def sds(shape, dtype):
        return jax.ShapeDtypeStruct(shape, dtype)

    if cell.kind == "train":
        batch: Batch = {"tokens": sds((b, s), i32), "targets": sds((b, s), i32)}
    elif cell.kind == "prefill":
        batch = {"tokens": sds((b, s), i32)}
    else:                                  # decode: one new token, cache of s
        batch = {"tokens": sds((b, 1), i32)}
    if cfg.family == "vlm":
        batch["patches"] = sds((b, cfg.vision_tokens, cfg.vision_dim), ACT_DTYPE)
    if cfg.family == "audio":
        batch["frames"] = sds((b, cfg.audio_frames, cfg.d_model), ACT_DTYPE)
    return batch


def cache_specs(cfg: ArchConfig, cell: ShapeCell) -> Params:
    """ShapeDtypeStructs of the decode cache at this cell (seq_len entries)."""
    api = model_api(cfg)
    return jax.eval_shape(lambda: api.init_cache(cell.global_batch, cell.seq_len))


def params_specs(cfg: ArchConfig) -> Params:
    api = model_api(cfg)
    return jax.eval_shape(lambda: api.init(jax.random.PRNGKey(0)))


def count_params(cfg: ArchConfig) -> int:
    import math
    specs = params_specs(cfg)
    return sum(math.prod(leaf.shape) if leaf.shape else 1
               for leaf in jax.tree.leaves(specs))


def active_params(cfg: ArchConfig) -> int:
    """Active parameters per token (MoE: top-k of the expert pool)."""
    total = count_params(cfg)
    if cfg.moe is None:
        return total
    e, k, f, d = (cfg.moe.n_experts, cfg.moe.top_k, cfg.moe.d_ff_expert,
                  cfg.d_model)
    n_moe_layers = cfg.n_layers // cfg.moe.every
    expert_params = 3 * d * f                       # swiglu expert
    inactive = n_moe_layers * (e - k) * expert_params
    return total - inactive
