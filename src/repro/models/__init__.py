"""repro.models — the 10 assigned architecture families."""
from repro.models.api import (ModelAPI, active_params, cache_specs,  # noqa: F401
                              count_params, input_specs, model_api,
                              params_specs)
