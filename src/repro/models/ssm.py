"""Mamba2 (SSD — state-space duality) sequence mixer.

The SSD algorithm (Dao & Gu 2024) is TPU-native by construction: the sequence
is split into chunks of length Q; within a chunk the recurrence is expanded
into a (Q, Q) lower-triangular "attention" computed on the MXU, and chunks are
stitched with a tiny (B, H, P, N) state recurrence (lax.scan). This is
exactly the hardware-adaptation story of DESIGN.md: quadratic-in-chunk matmul
work, linear-in-sequence state work.

TP note: the fused in_proj of the reference implementation is split into
separate z/x/B/C/dt projections so every output dim is head- (or state-)
aligned and shards over the ``model`` axis without resharding across concat
boundaries. Same math, same FLOPs (the matmuls share the input and fuse).

Decode is O(1): one state update per token, no KV cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.common import Params, dense_init, rmsnorm, split_keys
from repro.models.hints import hint


def dims(d_model: int, cfg: SSMConfig) -> dict:
    d_in = cfg.expand * d_model
    n_heads = d_in // cfg.head_dim
    return {"d_in": d_in, "n_heads": n_heads, "gn": cfg.n_groups * cfg.d_state}


def init_ssm(key, d_model: int, cfg: SSMConfig) -> Params:
    dm = dims(d_model, cfg)
    d_in, h, gn = dm["d_in"], dm["n_heads"], dm["gn"]
    ks = split_keys(key, 6)
    return {
        "z_proj": dense_init(ks[0], (d_model, d_in)),
        "x_proj": dense_init(ks[1], (d_model, d_in)),
        "b_proj": dense_init(ks[2], (d_model, gn)),
        "c_proj": dense_init(ks[3], (d_model, gn)),
        "dt_proj": dense_init(ks[4], (d_model, h)),
        "conv_x": dense_init(jax.random.fold_in(key, 10), (cfg.d_conv, d_in), scale=0.1),
        "conv_b": dense_init(jax.random.fold_in(key, 11), (cfg.d_conv, gn), scale=0.1),
        "conv_c": dense_init(jax.random.fold_in(key, 12), (cfg.d_conv, gn), scale=0.1),
        "conv_bias_x": jnp.zeros((d_in,), jnp.float32),
        "conv_bias_b": jnp.zeros((gn,), jnp.float32),
        "conv_bias_c": jnp.zeros((gn,), jnp.float32),
        "a_log": jnp.zeros((h,), jnp.float32),          # A = -exp(a_log) = -1
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm_w": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[5], (d_in, d_model)),
    }


def _causal_conv(u, w, bias):
    """Depthwise causal conv over (B, S, C), width K (K-1 left pad)."""
    k = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(u)
    for i in range(k):                                   # K=4: unrolled taps
        out = out + pad[:, i:i + u.shape[1], :] * w[i].astype(u.dtype)
    return jax.nn.silu(out + bias.astype(u.dtype))


def _conv_step(u_t, window, w, bias):
    """One-token conv: window (B, K-1, C) raw history, u_t (B, C) raw input.
    Returns (activated (B, C), new window)."""
    win = jnp.concatenate([window, u_t[:, None, :]], axis=1)     # (B, K, C)
    out = jnp.einsum("bkc,kc->bc", win.astype(jnp.float32),
                     w.astype(jnp.float32))
    return jax.nn.silu(out + bias.astype(jnp.float32)), win[:, 1:]


def ssd_chunked(x, dt, b_in, c_in, a, *, chunk: int):
    """Chunked SSD scan.

    x (B,S,H,P); dt (B,S,H) (post-softplus); b_in/c_in (B,S,G,N); a (H,) < 0.
    Returns y (B,S,H,P) and final state (B,H,P,N).
    """
    bsz, s, h, p = x.shape
    g, n = b_in.shape[2], b_in.shape[3]
    rep = h // g
    q = min(chunk, s)
    nc = -(-s // q)
    pad = nc * q - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_in = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_in = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0), (0, 0)))

    xc = hint(x.reshape(bsz, nc, q, h, p), "dp", None, None, "tp", None)
    dtc = hint(dt.reshape(bsz, nc, q, h).astype(jnp.float32),
               "dp", None, None, "tp")
    bc = b_in.reshape(bsz, nc, q, g, n)
    cc = c_in.reshape(bsz, nc, q, g, n)

    da = dtc * a.astype(jnp.float32)                     # (B,nc,Q,H), negative
    cum = jnp.cumsum(da, axis=2)                          # inclusive
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Qi,Qj,H)
    tri = jnp.tril(jnp.ones((q, q), bool))
    l_mat = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)

    # intra-chunk: y[i] = sum_{j<=i} (C_i.B_j) L_ij dt_j x_j
    # bf16 operands + fp32 accumulation on every big einsum (MXU-native;
    # the decay/softplus statistics stay fp32) — §Perf iter 4.
    bf = jnp.bfloat16
    cb = jnp.einsum("bcign,bcjgn->bcijg", cc.astype(bf), bc.astype(bf),
                    preferred_element_type=jnp.float32)   # (B,nc,Qi,Qj,G)
    cb = jnp.repeat(cb, rep, axis=4)                      # (B,nc,Qi,Qj,H)
    m_mat = (cb * l_mat * dtc[:, :, None, :, :]).astype(bf)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", m_mat, xc.astype(bf),
                         preferred_element_type=jnp.float32)

    # chunk-end states: S_c = sum_j exp(cum_end - cum_j) dt_j B_j x_j^T
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)          # (B,nc,Q,H)
    bg = jnp.repeat(bc, rep, axis=3).astype(jnp.float32)  # (B,nc,Q,H,N)
    w_j = decay_end * dtc
    states = jnp.einsum("bcjhn,bcjhp->bchpn",
                        (bg * w_j[..., None]).astype(bf), xc.astype(bf),
                        preferred_element_type=jnp.float32)  # (B,nc,H,P,N)

    chunk_decay = jnp.exp(cum[:, :, -1, :])               # (B,nc,H)

    def scan_fn(carry, inp):
        dec, st_new = inp
        out = carry
        nxt = carry * dec[:, :, None, None] + st_new
        return nxt, out

    init = hint(jnp.zeros((bsz, h, p, n), jnp.float32), "dp", "tp", None, None)
    final, prev_states = jax.lax.scan(
        scan_fn, init,
        (chunk_decay.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)    # (B,nc,H,P,N)

    cg = jnp.repeat(cc, rep, axis=3).astype(jnp.float32)  # (B,nc,Q,H,N)
    y_inter = jnp.einsum("bcihn,bchpn->bcihp",
                         (cg * jnp.exp(cum)[..., None]).astype(bf),
                         prev_states.astype(bf),
                         preferred_element_type=jnp.float32)

    y = (y_intra + y_inter).reshape(bsz, nc * q, h, p)[:, :s]
    return y, final


def apply_ssm(p: Params, x, cfg: SSMConfig, *, state: Params | None = None):
    """Mamba2 mixer. x (B,S,D). Train/prefill when ``state`` is None; one-token
    decode when state = {"cx","cb","cc" (conv windows), "ssm"}.
    Returns (out (B,S,D), new_state)."""
    bsz, s, d_model = x.shape
    dm = dims(d_model, cfg)
    d_in, h, gn = dm["d_in"], dm["n_heads"], dm["gn"]
    g, n, pdim = cfg.n_groups, cfg.d_state, cfg.head_dim

    z = x @ p["z_proj"].astype(x.dtype)
    xr = x @ p["x_proj"].astype(x.dtype)                  # raw (pre-conv)
    br = x @ p["b_proj"].astype(x.dtype)
    cr = x @ p["c_proj"].astype(x.dtype)
    dt_raw = x @ p["dt_proj"].astype(x.dtype)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))

    if state is None:
        xs = _causal_conv(xr, p["conv_x"], p["conv_bias_x"])
        bs_ = _causal_conv(br, p["conv_b"], p["conv_bias_b"])
        cs = _causal_conv(cr, p["conv_c"], p["conv_bias_c"])
        # pin SSD heads to the TP axis through the chunked scan
        xs = hint(xs.reshape(bsz, s, h, pdim), "dp", None, "tp", None)
        b_in = bs_.reshape(bsz, s, g, n)
        c_in = cs.reshape(bsz, s, g, n)
        y, fin = ssd_chunked(xs, dt, b_in, c_in, a, chunk=cfg.chunk)
        k = cfg.d_conv
        def tail(u):
            return jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))[:, -(k - 1):, :]
        new_state = {"cx": tail(xr), "cb": tail(br), "cc": tail(cr), "ssm": fin}
    else:
        xs_t, ncx = _conv_step(xr[:, 0], state["cx"], p["conv_x"], p["conv_bias_x"])
        b_t, ncb = _conv_step(br[:, 0], state["cb"], p["conv_b"], p["conv_bias_b"])
        c_t, ncc = _conv_step(cr[:, 0], state["cc"], p["conv_c"], p["conv_bias_c"])
        rep = h // g
        xs0 = xs_t.reshape(bsz, h, pdim)
        bg = jnp.repeat(b_t.reshape(bsz, g, n), rep, axis=1)   # (B,H,N)
        cg = jnp.repeat(c_t.reshape(bsz, g, n), rep, axis=1)
        da = jnp.exp(dt[:, 0] * a)                             # (B,H)
        st = state["ssm"] * da[:, :, None, None] + \
            (dt[:, 0, :, None] * xs0)[..., None] * bg[:, :, None, :]
        y = jnp.einsum("bhn,bhpn->bhp", cg, st)[:, None]       # (B,1,H,P)
        xs = xs0[:, None]                                      # for the skip
        new_state = {"cx": ncx, "cb": ncb, "cc": ncc, "ssm": st}

    y = y + xs.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(bsz, s, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, p["norm_w"])
    return y @ p["out_proj"].astype(x.dtype), new_state


def init_state(bsz: int, d_model: int, cfg: SSMConfig, dtype=jnp.bfloat16) -> Params:
    dm = dims(d_model, cfg)
    return {
        "cx": jnp.zeros((bsz, cfg.d_conv - 1, dm["d_in"]), dtype),
        "cb": jnp.zeros((bsz, cfg.d_conv - 1, dm["gn"]), dtype),
        "cc": jnp.zeros((bsz, cfg.d_conv - 1, dm["gn"]), dtype),
        "ssm": jnp.zeros((bsz, dm["n_heads"], cfg.head_dim, cfg.d_state),
                         jnp.float32),
    }
