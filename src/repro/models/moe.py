"""Mixture-of-Experts FFN with capacity-based sorted dispatch.

TPU/EP-native formulation: tokens are ranked per expert via one sort, packed
into a dense (E, C, D) buffer (capacity C, overflow dropped — Switch/GShard
semantics), pushed through batched expert matmuls (MXU), and combined back
with the top-k router weights. The (E, ...) dims shard over the ``model``
mesh axis (expert parallelism); GSPMD inserts the all_to_alls.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.common import Params, dense_init, init_mlp, apply_mlp, split_keys
from repro.models.hints import hint


def init_moe(key, d: int, cfg: MoEConfig) -> Params:
    ks = split_keys(key, 5)
    e, f = cfg.n_experts, cfg.d_ff_expert
    p: Params = {
        "router": dense_init(ks[0], (d, e)),
        "w1": dense_init(ks[1], (e, d, f)),
        "w3": dense_init(ks[2], (e, d, f)),
        "w2": dense_init(ks[3], (e, f, d)),
    }
    if cfg.shared_expert:
        p["shared"] = init_mlp(ks[4], d, f, "swiglu")
    return p


def apply_moe(p: Params, x: jax.Array, cfg: MoEConfig
              ) -> tuple[jax.Array, jax.Array]:
    """x (B, S, D) -> (out (B, S, D), aux load-balance loss scalar).

    Dispatch is **per batch row** (vmapped sort over S*k slots), not global:
    a global argsort over B*S*k slots is a distributed sort under pjit —
    measured at ~10x the collective bytes of the whole rest of the step
    (EXPERIMENTS.md §Perf iter 5). Per-row dispatch keeps routing local to
    the row's data shard (this is what SPMD EP systems do — each DP rank
    dispatches its own tokens); the only cross-device traffic left is the
    unavoidable token->expert all_to_all implied by the EP einsums.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = int(max(1, round(s * k / e * cfg.capacity_factor)))

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                    # (B, S, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance aux (Switch): E * sum_e f_e * P_e -------------------
    f_e = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(1.0) \
        / (b * s * k)
    p_e = probs.mean(axis=(0, 1))
    aux = cfg.aux_loss_weight * e * jnp.sum(f_e * p_e)

    # ---- per-row sorted capacity dispatch ----------------------------------
    def dispatch_row(xr, te, tw):
        """xr (S,D); te/tw (S,k) -> (buf (E,cap,D), st, sw, keep, dest)."""
        slot_e = te.reshape(-1)                               # (S*k,)
        slot_t = jnp.repeat(jnp.arange(s, dtype=jnp.int32), k)
        slot_w = tw.reshape(-1)
        order = jnp.argsort(slot_e)
        se, st, sw = slot_e[order], slot_t[order], slot_w[order]
        counts = jnp.zeros((e,), jnp.int32).at[se].add(1)
        starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                  jnp.cumsum(counts)[:-1].astype(jnp.int32)])
        pos = jnp.arange(s * k, dtype=jnp.int32) - starts[se]
        keep = pos < cap
        dest = jnp.where(keep, se * cap + pos, e * cap)       # overflow row
        buf = jnp.zeros((e * cap + 1, d), x.dtype).at[dest].add(xr[st])
        return buf[:-1].reshape(e, cap, d), st, sw, keep, dest

    buf, st, sw, keep, dest = jax.vmap(dispatch_row)(x, top_e, top_w)
    # buf (B,E,cap,D)

    # EP regime (§Perf iters 2/5): pin experts to TP only for heavy-expert
    # MoEs; light-expert MoEs replicate experts and keep tokens local.
    use_ep = cfg.expert_parallel(d)
    ep = (lambda z: hint(z, "dp", "tp", None, None)) if use_ep else (lambda z: z)

    buf = ep(buf)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, p["w1"].astype(x.dtype)))
    h = h * jnp.einsum("becd,edf->becf", buf, p["w3"].astype(x.dtype))
    h = ep(h)
    out_e = ep(jnp.einsum("becf,efd->becd", h, p["w2"].astype(x.dtype)))

    def combine_row(flat_e, str_, swr, keepr, destr):
        flat = flat_e.reshape(e * cap, d)
        gathered = jnp.where(keepr[:, None],
                             flat[jnp.minimum(destr, e * cap - 1)], 0.0)
        return jnp.zeros((s, d), x.dtype).at[str_].add(
            gathered * swr[:, None].astype(x.dtype))

    out = jax.vmap(combine_row)(out_e, st, sw, keep, dest)    # (B,S,D)

    if "shared" in p:
        out = out + apply_mlp(p["shared"], x, "swiglu")
    return out, aux
