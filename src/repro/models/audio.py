"""Whisper-style encoder-decoder (audio family).

The conv frontend is a STUB per the assignment: ``input_specs`` supplies
precomputed frame embeddings (B, audio_frames, d_model) — the log-mel +
Conv1d stack is upstream preprocessing. The transformer backbone is complete:
  * encoder: learned positions, non-causal self-attention, GELU MLP, pre-LN;
  * decoder: learned positions, causal self-attention, cross-attention to the
    encoder output, GELU MLP.
Serving caches decoder self-KV plus per-layer cross-KV projected once from
the encoder output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import (ACT_DTYPE, AttnSpec, Params, apply_mlp,
                                 apply_norm, cross_attention, cross_kv,
                                 dense_init, embed_tokens, init_attention,
                                 init_embed, init_mlp, init_norm,
                                 self_attention, split_keys, unembed)

MAX_TEXT_POS = 32_768 + 8      # learned decoder positions (covers decode_32k)


def _spec(cfg: ArchConfig, causal: bool) -> AttnSpec:
    return AttnSpec(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                    head_dim=cfg.resolved_head_dim, d_model=cfg.d_model,
                    qk_norm=False, bias=cfg.attn_bias, causal=causal,
                    window=None, rope_theta=None)


def init_enc_layer(key, cfg: ArchConfig) -> Params:
    ks = split_keys(key, 4)
    return {"ln1": init_norm(ks[0], cfg.d_model, cfg.norm),
            "attn": init_attention(ks[1], _spec(cfg, causal=False)),
            "ln2": init_norm(ks[2], cfg.d_model, cfg.norm),
            "mlp": init_mlp(ks[3], cfg.d_model, cfg.d_ff, cfg.mlp)}


def init_dec_layer(key, cfg: ArchConfig) -> Params:
    ks = split_keys(key, 6)
    return {"ln1": init_norm(ks[0], cfg.d_model, cfg.norm),
            "attn": init_attention(ks[1], _spec(cfg, causal=True)),
            "lnx": init_norm(ks[2], cfg.d_model, cfg.norm),
            "xattn": init_attention(ks[3], _spec(cfg, causal=False)),
            "ln2": init_norm(ks[4], cfg.d_model, cfg.norm),
            "mlp": init_mlp(ks[5], cfg.d_model, cfg.d_ff, cfg.mlp)}


def init_params(cfg: ArchConfig, key) -> Params:
    ks = split_keys(key, 6)
    return {
        "embed": init_embed(ks[0], cfg.vocab_size, cfg.d_model, cfg.tie_embeddings),
        "enc_pos": dense_init(ks[1], (cfg.audio_frames, cfg.d_model), scale=0.01),
        "dec_pos": dense_init(ks[2], (MAX_TEXT_POS, cfg.d_model), scale=0.01),
        "enc_layers": jax.vmap(lambda k: init_enc_layer(k, cfg))(
            jax.random.split(ks[3], cfg.enc_layers)),
        "dec_layers": jax.vmap(lambda k: init_dec_layer(k, cfg))(
            jax.random.split(ks[4], cfg.n_layers)),
        "enc_norm": init_norm(ks[5], cfg.d_model, cfg.norm),
        "final_norm": init_norm(jax.random.fold_in(key, 9), cfg.d_model, cfg.norm),
    }


def encode(params: Params, cfg: ArchConfig, frames, *, remat: bool = False):
    """frames (B, T, D) stub-frontend output -> encoder states (B, T, D)."""
    b, t, _ = frames.shape
    x = frames.astype(ACT_DTYPE) + params["enc_pos"][:t].astype(ACT_DTYPE)
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))

    def body(x, p_l):
        h = apply_norm(p_l["ln1"], x, cfg.norm, cfg.norm_eps)
        att, _ = self_attention(p_l["attn"], _spec(cfg, causal=False), h, positions)
        x = x + att
        h = apply_norm(p_l["ln2"], x, cfg.norm, cfg.norm_eps)
        return x + apply_mlp(p_l["mlp"], h, cfg.mlp), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return apply_norm(params["enc_norm"], x, cfg.norm, cfg.norm_eps)


def _dec_layer(p_l, cfg, x, positions, enc_out=None, *, kv=None, pos0=None,
               xk=None, xv=None):
    h = apply_norm(p_l["ln1"], x, cfg.norm, cfg.norm_eps)
    cache = None if kv is None else {"k": kv["k"], "v": kv["v"], "pos": pos0}
    att, nkv = self_attention(p_l["attn"], _spec(cfg, causal=True), h, positions,
                              cache=cache)
    x = x + att
    h = apply_norm(p_l["lnx"], x, cfg.norm, cfg.norm_eps)
    x = x + cross_attention(p_l["xattn"], _spec(cfg, causal=False), h,
                            kv_src=enc_out, k=xk, v=xv)
    h = apply_norm(p_l["ln2"], x, cfg.norm, cfg.norm_eps)
    return x + apply_mlp(p_l["mlp"], h, cfg.mlp), nkv


def forward_train(params: Params, cfg: ArchConfig, tokens, *, extra,
                  remat: bool = True, return_hidden: bool = False):
    """tokens (B,S) + extra["frames"] (B,T,D) -> (logits, aux=0)."""
    b, s = tokens.shape
    enc_out = encode(params, cfg, extra["frames"], remat=remat)
    x = embed_tokens(params["embed"], tokens) + params["dec_pos"][:s].astype(ACT_DTYPE)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(x, p_l):
        x, _ = _dec_layer(p_l, cfg, x, positions, enc_out)
        return x, None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    if return_hidden:
        return x, jnp.float32(0.0)
    return unembed(params["embed"], x, cfg.vocab_size), jnp.float32(0.0)


def prefill(params: Params, cfg: ArchConfig, tokens, *, extra,
            max_seq: int | None = None):
    b, s = tokens.shape
    max_seq = s if max_seq is None else max_seq
    pad = max_seq - s
    enc_out = encode(params, cfg, extra["frames"])
    x = embed_tokens(params["embed"], tokens) + params["dec_pos"][:s].astype(ACT_DTYPE)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    spec_x = _spec(cfg, causal=False)

    def body(x, p_l):
        xk, xv = cross_kv(p_l["xattn"], spec_x, enc_out)
        x, kv = _dec_layer(p_l, cfg, x, positions, xk=xk, xv=xv)
        kv = jax.tree.map(lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0))), kv)
        return x, {"k": kv["k"], "v": kv["v"], "xk": xk, "xv": xv}

    x, layers = jax.lax.scan(body, x, params["dec_layers"])
    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    logits = unembed(params["embed"], x[:, -1:, :], cfg.vocab_size)[:, 0, :]
    return logits, {"layers": layers, "pos": jnp.asarray(s, jnp.int32)}


def decode(params: Params, cfg: ArchConfig, cache: Params, tokens):
    b, s = tokens.shape
    pos0 = cache["pos"]
    x = embed_tokens(params["embed"], tokens) + \
        jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos0, s, 0).astype(ACT_DTYPE)
    positions = pos0[None, None] + jnp.broadcast_to(
        jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(x, inp):
        p_l, c_l = inp
        x, nkv = _dec_layer(p_l, cfg, x, positions, kv=c_l, pos0=pos0,
                            xk=c_l["xk"], xv=c_l["xv"])
        return x, {"k": nkv["k"], "v": nkv["v"], "xk": c_l["xk"], "xv": c_l["xv"]}

    x, layers = jax.lax.scan(body, x, (params["dec_layers"], cache["layers"]))
    x = apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    return unembed(params["embed"], x, cfg.vocab_size)[:, -1, :], {"layers": layers, "pos": pos0 + s}


def init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype=ACT_DTYPE) -> Params:
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    kv_shape = (cfg.n_layers, batch, max_seq, kv, hd)
    x_shape = (cfg.n_layers, batch, cfg.audio_frames, kv, hd)
    return {"layers": {"k": jnp.zeros(kv_shape, dtype),
                       "v": jnp.zeros(kv_shape, dtype),
                       "xk": jnp.zeros(x_shape, dtype),
                       "xv": jnp.zeros(x_shape, dtype)},
            "pos": jnp.zeros((), jnp.int32)}
