"""Shared model components (pure-function JAX, param pytrees, no framework).

Conventions:
  * activations bf16, parameters fp32 (cast at use — mixed precision),
    softmax/log-sum-exp accumulation fp32;
  * attention is **blockwise online-softmax** over KV chunks (lax.scan):
    O(S * C) live memory instead of O(S^2), which is what lets prefill_32k
    and train_4k fit per-device HBM without a custom kernel;
  * GQA everywhere: q heads grouped over n_kv_heads; n_heads need not divide
    the TP axis (GSPMD pads uneven shards).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.hints import hint, tp_size

Params = dict[str, Any]
ACT_DTYPE = jnp.bfloat16


# --------------------------------------------------------------------- init
def dense_init(key, shape, scale: float = 0.02, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


# -------------------------------------------------------------------- norms
def rmsnorm(x, w, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, w, b, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def init_norm(key, d: int, kind: str) -> Params:
    if kind == "rmsnorm":
        return {"w": jnp.ones((d,), jnp.float32)}
    return {"w": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def apply_norm(p: Params, x, kind: str, eps: float):
    if kind == "rmsnorm":
        return rmsnorm(x, p["w"], eps)
    return layernorm(x, p["w"], p["b"], eps)


# --------------------------------------------------------------------- RoPE
def rope_angles(positions, head_dim: int, theta: float):
    """positions (..., S) int32 -> (cos, sin) each (..., S, head_dim/2) fp32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (..., S, H, hd); cos/sin (..., S, hd/2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------- attention
@dataclasses.dataclass(frozen=True)
class AttnSpec:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_model: int
    qk_norm: bool = False
    bias: bool = False
    causal: bool = True
    window: int | None = None       # sliding-window width (tokens) or None
    rope_theta: float | None = 10_000.0


def init_attention(key, spec: AttnSpec) -> Params:
    """Head-axis-explicit weight layout (D, H, hd): the head axis is a real
    tensor axis so TP sharding is head-aligned (GSPMD pads uneven H/TP)."""
    ks = split_keys(key, 4)
    h, kv, hd, d = spec.n_heads, spec.n_kv_heads, spec.head_dim, spec.d_model
    p: Params = {
        "wq": dense_init(ks[0], (d, h, hd)),
        "wk": dense_init(ks[1], (d, kv, hd)),
        "wv": dense_init(ks[2], (d, kv, hd)),
        "wo": dense_init(ks[3], (h, hd, d)),
    }
    if spec.bias:
        p["bq"] = jnp.zeros((h, hd), jnp.float32)
        p["bk"] = jnp.zeros((kv, hd), jnp.float32)
        p["bv"] = jnp.zeros((kv, hd), jnp.float32)
    if spec.qk_norm:
        p["qn"] = jnp.ones((hd,), jnp.float32)
        p["kn"] = jnp.ones((hd,), jnp.float32)
    return p


def _project_qkv(p: Params, spec: AttnSpec, x, positions):
    """x (B,S,D) -> q (B,S,H,hd), k/v (B,S,Kv,hd), rope applied."""
    h, kv, hd = spec.n_heads, spec.n_kv_heads, spec.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if spec.bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if spec.qk_norm:
        q = rmsnorm(q, p["qn"])
        k = rmsnorm(k, p["kn"])
    if spec.rope_theta is not None:
        cos, sin = rope_angles(positions, hd, spec.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    # pin heads to the TP axis — GSPMD loses this through the attention scan
    q = hint(q, "dp", None, "tp", None)
    k = hint(k, "dp", None, "tp", None)
    v = hint(v, "dp", None, "tp", None)
    return q, k, v


def _try_flash(q, k, v, g: int, *, causal: bool, window: int | None):
    """Dispatch to the fused Pallas flash kernel when viable (TPU backend, or
    interpret mode under REPRO_FLASH_INTERPRET=1 for tests). Returns None to
    fall through to the jnp scan."""
    import os
    interpret = os.environ.get("REPRO_FLASH_INTERPRET") == "1"
    if jax.default_backend() != "tpu" and not interpret:
        return None
    import functools

    from repro.kernels.flash_attention import flash_attention
    from repro.models import hints as hints_mod

    kf = jnp.repeat(k, g, axis=2) if g > 1 else k
    vf = jnp.repeat(v, g, axis=2) if g > 1 else v
    b, s, h, hd = q.shape
    if kf.shape[1] != s:
        return None                       # flash path assumes self-attention
    fn = functools.partial(flash_attention, causal=causal, window=window,
                           interpret=interpret,
                           bq=min(512, s), bk=min(512, s))
    ctx = hints_mod.active()
    mesh = (ctx or {}).get("mesh")
    if mesh is None:
        return fn(q, kf, vf)
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    dp, tp = ctx["dp"], ctx["tp"]
    dp_n = hints_mod._axis_size(dp)
    tp_n = hints_mod._axis_size(tp)
    if b % dp_n or h % tp_n:
        return None
    spec = P(dp, None, tp, None)
    sm = shard_map(fn, mesh=mesh, in_specs=(spec, spec, spec),
                   out_specs=spec, check_rep=False)
    return sm(q, kf, vf)


def blockwise_attention(q, k, v, q_pos, kv_pos, *, causal: bool,
                        window: int | None, kv_mask=None, block: int = 1024):
    """Online-softmax attention over KV blocks.

    q (B,S,H,hd); k,v (B,T,Kv,hd); q_pos (B,S); kv_pos (B,T).
    Returns (B,S,H,hd).

    Numerics: dots run in the input dtype (bf16) with fp32 accumulation
    (``preferred_element_type`` — MXU-native); softmax statistics in fp32.
    Memory: the KV loop is an index-carried scan with ``dynamic_slice``
    gathers and masks computed inline from the loop counter — passing stacked
    per-block masks as scan inputs lets XLA hoist one pred[nblk,B,S,Kv,g,C]
    tensor out of the loop (~4 GB/device at 32k; EXPERIMENTS.md §Perf iter 3).
    """
    b, s, h, hd = q.shape
    t = k.shape[1]
    kv_heads = k.shape[2]
    g = h // kv_heads

    # GQA head expansion (§Perf iter 6): when kv_heads doesn't divide TP but
    # the q-head count does, the grouped (Kv, g) layout can't shard — the
    # whole score computation replicates across the model axis (measured 16x
    # on qwen3 prefill). Expanding K/V to per-q-head layout costs a g-fold
    # K/V copy (sharded h/TP ways, so per-device bytes stay ~flat) and makes
    # every attention tensor shard on the head dim. Decode (s == 1) keeps
    # the grouped layout: expanding would multiply cache reads by g.
    tp = tp_size()
    if s > 1 and g > 1 and kv_heads % tp != 0 and h % tp == 0:
        k = hint(jnp.repeat(k, g, axis=2), "dp", None, "tp", None)
        v = hint(jnp.repeat(v, g, axis=2), "dp", None, "tp", None)
        kv_heads, g = h, 1

    # Fused flash kernel (§Perf iter 7) on TPU: scores/probabilities stay in
    # VMEM instead of round-tripping HBM every KV block (the single largest
    # memory-term contributor measured on prefill_32k). pallas_call is opaque
    # to GSPMD, so it is shard_map-wrapped over (dp: batch, tp: heads); falls
    # through to the jnp scan when shapes don't divide the mesh or on CPU.
    if s > 1 and kv_mask is None:
        out = _try_flash(q, k, v, g, causal=causal, window=window)
        if out is not None:
            return out

    qg = q.reshape(b, s, kv_heads, g, hd)
    scale = jnp.float32(1.0 / float(hd) ** 0.5)
    f32 = jnp.float32

    def qk(qq, kk):
        # (B,S,Kv,g,hd) x (B,C,Kv,hd) -> (B,Kv,S,g,C), fp32 accumulation
        return jax.lax.dot_general(
            qq, kk, (((4,), (3,)), ((0, 2), (0, 2))),
            preferred_element_type=f32)

    def pv(p_att, vv):
        # (B,Kv,S,g,C) x (B,C,Kv,hd) -> (B,Kv,S,g,hd)
        return jax.lax.dot_general(
            p_att.astype(vv.dtype), vv, (((4,), (1,)), ((0, 1), (0, 2))),
            preferred_element_type=f32)

    def finish(out):
        return out.transpose(0, 2, 1, 3, 4).reshape(b, s, h, hd).astype(q.dtype)

    if s == 1 or t <= 4 * block:
        # Direct path: decode (one query over the whole cache — keeps the KV
        # seq dim shardable) and short sequences (train_4k): no scan carries,
        # no stacked KV copies, one fused softmax.
        sc = qk(qg, k) * scale                           # (B,Kv,S,g,T)
        valid = (kv_mask if kv_mask is not None else (kv_pos >= 0))[:, None, :]
        if causal:
            valid = valid & (kv_pos[:, None, :] <= q_pos[:, :, None])
        if window is not None:
            valid = valid & (kv_pos[:, None, :] > q_pos[:, :, None] - window)
        sc = jnp.where(valid[:, None, :, None, :], sc, f32(-1e30))
        p_att = jax.nn.softmax(sc, axis=-1)
        return finish(pv(p_att, v))

    nblk = -(-t // block)
    pad = nblk * block - t
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    posp = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
    maskp = jnp.pad(kv_mask, ((0, 0), (0, pad)), constant_values=False) \
        if kv_mask is not None else None

    neg = f32(-1e30)

    def step(carry, i):
        m_run, l_run, acc = carry
        k_c = jax.lax.dynamic_slice_in_dim(kp, i * block, block, 1)
        v_c = jax.lax.dynamic_slice_in_dim(vp, i * block, block, 1)
        p_c = jax.lax.dynamic_slice_in_dim(posp, i * block, block, 1)
        sc = qk(qg, k_c) * scale                         # (B,Kv,S,g,C)
        valid = p_c[:, None, :] >= 0
        if maskp is not None:
            valid = valid & jax.lax.dynamic_slice_in_dim(
                maskp, i * block, block, 1)[:, None, :]
        if causal:
            valid = valid & (p_c[:, None, :] <= q_pos[:, :, None])
        if window is not None:
            valid = valid & (p_c[:, None, :] > q_pos[:, :, None] - window)
        sc = jnp.where(valid[:, None, :, None, :], sc, neg)
        m_new = jnp.maximum(m_run, sc.max(axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p_att = jnp.exp(sc - m_new[..., None])
        l_new = l_run * alpha + p_att.sum(axis=-1)
        acc_new = acc * alpha[..., None] + pv(p_att, v_c)
        return (m_new, l_new, acc_new), None

    m0 = hint(jnp.full((b, kv_heads, s, g), -jnp.inf, f32),
              "dp", "tp", None, None)
    l0 = hint(jnp.zeros((b, kv_heads, s, g), f32), "dp", "tp", None, None)
    a0 = hint(jnp.zeros((b, kv_heads, s, g, hd), f32),
              "dp", "tp", None, None, None)
    (m_f, l_f, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                      jnp.arange(nblk, dtype=jnp.int32))
    out = acc / jnp.maximum(l_f, 1e-30)[..., None]       # (B,Kv,S,g,hd)
    return finish(out)


def self_attention(p: Params, spec: AttnSpec, x, positions, *,
                   cache: Params | None = None, block: int = 1024):
    """Full self-attention (train/prefill when cache is None; one-step decode
    when cache holds {"k","v","pos"}). Returns (out (B,S,D), new_cache)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, spec, x, positions)
    if cache is None:
        out = blockwise_attention(q, k, v, positions, positions,
                                  causal=spec.causal, window=spec.window,
                                  block=block)
        new_cache = {"k": k, "v": v}
    else:
        pos = cache["pos"]                               # scalar int32
        k_all = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                             (0, pos, 0, 0))
        v_all = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                             (0, pos, 0, 0))
        t = k_all.shape[1]
        kv_pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
        kv_mask = kv_pos[0] <= pos                       # (t,)
        out = blockwise_attention(q, k_all, v_all, positions, kv_pos,
                                  causal=spec.causal, window=spec.window,
                                  kv_mask=jnp.broadcast_to(kv_mask[None], (b, t)),
                                  block=block)
        new_cache = {"k": k_all, "v": v_all}
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, new_cache


def cross_kv(p: Params, spec: AttnSpec, kv_src):
    """Project cross-attention keys/values from memory tokens (B,T,D) —
    cached once per request in serving."""
    b, t, _ = kv_src.shape
    kv, hd = spec.n_kv_heads, spec.head_dim
    k = jnp.einsum("btd,dhk->bthk", kv_src, p["wk"].astype(kv_src.dtype))
    v = jnp.einsum("btd,dhk->bthk", kv_src, p["wv"].astype(kv_src.dtype))
    if spec.qk_norm:
        k = rmsnorm(k, p["kn"])
    return k, v


def cross_attention(p: Params, spec: AttnSpec, x, kv_src=None, *, k=None,
                    v=None, block: int = 1024):
    """Cross-attention: queries from x (B,S,D), keys/values from kv_src
    (B,T,D) or precomputed (k, v) — no RoPE, no causality."""
    b, s, _ = x.shape
    h, kv_h, hd = spec.n_heads, spec.n_kv_heads, spec.head_dim
    if k is None:
        k, v = cross_kv(p, spec, kv_src)
    t = k.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    if spec.qk_norm:
        q = rmsnorm(q, p["qn"])
    pos_q = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    pos_k = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    out = blockwise_attention(q, k, v, pos_q, pos_k, causal=False, window=None,
                              block=block)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------------- MLP
def init_mlp(key, d: int, f: int, kind: str) -> Params:
    ks = split_keys(key, 3)
    if kind == "swiglu":
        return {"w1": dense_init(ks[0], (d, f)), "w3": dense_init(ks[1], (d, f)),
                "w2": dense_init(ks[2], (f, d))}
    return {"w1": dense_init(ks[0], (d, f)), "b1": jnp.zeros((f,), jnp.float32),
            "w2": dense_init(ks[1], (f, d)), "b2": jnp.zeros((d,), jnp.float32)}


def _hint_hidden(h):
    return hint(h, "dp", "tp") if h.ndim == 2 else hint(h, "dp", None, "tp")


def apply_mlp(p: Params, x, kind: str):
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["w1"].astype(x.dtype)) * (x @ p["w3"].astype(x.dtype))
        return _hint_hidden(h) @ p["w2"].astype(x.dtype)
    h = jax.nn.gelu(x @ p["w1"].astype(x.dtype) + p["b1"].astype(x.dtype))
    return _hint_hidden(h) @ p["w2"].astype(x.dtype) + p["b2"].astype(x.dtype)


# ------------------------------------------------------------- embed / head
VOCAB_ALIGN = 128   # pad vocab to a TP- and MXU-aligned multiple (Megatron-style)


def padded_vocab(vocab: int) -> int:
    return ((vocab + VOCAB_ALIGN - 1) // VOCAB_ALIGN) * VOCAB_ALIGN


def init_embed(key, vocab: int, d: int, tie: bool) -> Params:
    """Embedding table padded to VOCAB_ALIGN; padded logit columns are masked
    to -inf in unembed so losses/samplers never see them."""
    ks = split_keys(key, 2)
    vp = padded_vocab(vocab)
    p = {"tok": dense_init(ks[0], (vp, d))}
    if not tie:
        p["head"] = dense_init(ks[1], (d, vp))
    return p


def embed_tokens(p: Params, tokens):
    return p["tok"].astype(ACT_DTYPE)[tokens]


def unembed(p: Params, x, vocab: int):
    if "head" in p:
        logits = x @ p["head"].astype(x.dtype)
    else:
        logits = x @ p["tok"].astype(x.dtype).T
    vp = logits.shape[-1]
    if vp != vocab:
        mask = (jnp.arange(vp) >= vocab) * jnp.asarray(-1e30, logits.dtype)
        logits = logits + mask
    return logits
