"""Activation sharding hints (GSPMD constraint annotations).

GSPMD propagates weight shardings poorly through scan carries and reshapes —
measured concretely in the dry-run: without constraints the blockwise
attention ran with ALL heads replicated on every device (16x wasted MXU time;
see EXPERIMENTS.md §Perf iteration 1). These hints pin the head/expert axes
of key activations to the ``model`` axis and the batch axis to the dp axes.

The launch layer enables hints for mesh runs (``enable_hints``); single-device
tests never enable them, so model code stays mesh-free. A hint silently
skips any dim that does not divide its mesh axes (uneven activation sharding
of e.g. 36 heads over 16 devices would force padding on every op — worse than
replication).
"""
from __future__ import annotations

import math

import jax
from jax.sharding import PartitionSpec as P

_ACTIVE: dict | None = None


def enable_hints(dp_axes: tuple[str, ...], tp_axis: str, mesh=None):
    global _ACTIVE
    sizes = {}
    if mesh is not None:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    _ACTIVE = {"dp": tuple(dp_axes), "tp": tp_axis, "sizes": sizes}


def disable_hints():
    global _ACTIVE
    _ACTIVE = None


def _axis_size(axes) -> int:
    if _ACTIVE is None or not _ACTIVE["sizes"]:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return math.prod(_ACTIVE["sizes"].get(a, 1) for a in axes)


def tp_size() -> int:
    """Size of the tensor-parallel axis (1 when hints are disabled)."""
    return _axis_size(_ACTIVE["tp"]) if _ACTIVE else 1


def active() -> dict | None:
    """The active hint context {dp, tp, sizes, mesh} or None."""
    return _ACTIVE


def enable_hints_mesh(mesh, dp_axes_: tuple[str, ...], tp_axis: str):
    """enable_hints + retain the concrete mesh (needed to shard_map-wrap
    Pallas kernels, which are opaque to GSPMD)."""
    enable_hints(dp_axes_, tp_axis, mesh)
    _ACTIVE["mesh"] = mesh


def hint(x, *dims):
    """dims entries: "dp", "tp", or None — symbolic per-dimension axes."""
    if _ACTIVE is None:
        return x
    spec = []
    for size, d in zip(x.shape, dims):
        if d is None:
            spec.append(None)
            continue
        axes = _ACTIVE["dp"] if d == "dp" else _ACTIVE["tp"]
        if _axis_size(axes) > 1 and size % _axis_size(axes) == 0:
            spec.append(axes)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, P(*spec))
