"""Jittable step functions (train / prefill / decode) shared by the real
launcher and the dry-run."""
from __future__ import annotations

import jax

from repro.configs.base import ArchConfig
from repro.models.api import model_api
from repro.train.optimizer import OptimizerConfig, adamw_update


def make_train_step(cfg: ArchConfig, opt_cfg: OptimizerConfig, *,
                    remat: bool = True):
    api = model_api(cfg)

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return api.loss(p, batch, remat=remat)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        new_params, new_opt, om = adamw_update(params, grads, opt_state, opt_cfg)
        return new_params, new_opt, {"loss": loss, **metrics, **om}

    return train_step


def make_prefill_step(cfg: ArchConfig):
    api = model_api(cfg)

    def prefill_step(params, batch):
        return api.prefill(params, batch)

    return prefill_step


def make_decode_step(cfg: ArchConfig):
    api = model_api(cfg)

    def decode_step(params, cache, tokens):
        return api.decode(params, cache, tokens)

    return decode_step
