"""Per-leaf PartitionSpec rules for every architecture (DP/FSDP/TP/EP/SP).

Strategy (DESIGN.md §5):
  * params: FSDP over ``data`` x TP over ``model``; expert tensors shard
    experts over ``model`` (EP) and d_model over ``data``;
  * batch: sharded over (pod, data); when global_batch < dp_size (long_500k)
    the batch replicates and the KV-cache *sequence* dim shards over ``data``
    instead (sequence parallelism for the cache);
  * optimizer state mirrors the param specs;
  * KV caches: batch over (pod, data); kv-head dim over ``model`` when it
    divides evenly (GQA kv >= TP), else replicated heads.

Rules are name-based on the LAST dims of each leaf; leading stack axes
(layer, block, inner-block) are padded with None automatically — this is what
makes one rule table cover scan-stacked params of every family.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCell
from repro.launch.mesh import dp_axes, dp_size

TP = "model"


def _fsdp(mesh: Mesh):
    return "data" if "data" in mesh.axis_names else None


# name -> (base_ndim, tail spec builder). F=fsdp axis name (or None).
def _rule(name: str, path_names: list[str], ndim: int, mesh: Mesh,
          moe_ep: bool = True):
    f = _fsdp(mesh)
    in_moe = "moe" in path_names and "shared" not in path_names
    table: dict[str, tuple[int, tuple]] = {
        "tok": (2, (TP, f)),
        "head": (2, (f, TP)),
        "wq": (3, (f, TP, None)),
        "wk": (3, (f, TP, None)),
        "wv": (3, (f, TP, None)),
        "wo": (3, (TP, None, f)),
        "bq": (2, (TP, None)),
        "bk": (2, (TP, None)),
        "bv": (2, (TP, None)),
        "qn": (1, (None,)),
        "kn": (1, (None,)),
        "w1": (2, (f, TP)),
        "w3": (2, (f, TP)),
        "w2": (2, (TP, f)),
        "b1": (1, (TP,)),
        "b2": (1, (None,)),
        "router": (2, (f, None)),
        "z_proj": (2, (f, TP)),
        "x_proj": (2, (f, TP)),
        "b_proj": (2, (f, None)),
        "c_proj": (2, (f, None)),
        "dt_proj": (2, (f, TP)),
        "conv_x": (2, (None, TP)),
        "conv_b": (2, (None, None)),
        "conv_c": (2, (None, None)),
        "conv_bias_x": (1, (TP,)),
        "conv_bias_b": (1, (None,)),
        "conv_bias_c": (1, (None,)),
        "a_log": (1, (TP,)),
        "d_skip": (1, (TP,)),
        "dt_bias": (1, (TP,)),
        "norm_w": (1, (TP,)),
        "out_proj": (2, (TP, f)),
        "vis_proj": (2, (f, TP)),
        "enc_pos": (2, (None, None)),
        "dec_pos": (2, (None, None)),
        "w": (1, (None,)),            # norm scale
        "b": (1, (None,)),            # norm bias
        "gate_a": (0, ()),
        "gate_m": (0, ()),
    }
    if in_moe and name in ("w1", "w3"):
        # EP regime: experts over TP; token-parallel regime: experts fully
        # replicated over TP (FSDP-only weights) so tokens never move and no
        # per-layer TP all-reduce exists (§Perf iters 5b/5c).
        base = (3, (TP, f, None)) if moe_ep else (3, (None, f, None))
    elif in_moe and name == "w2":
        base = (3, (TP, None, f)) if moe_ep else (3, (None, None, f))
    elif name in table:
        base = table[name]
    else:
        raise KeyError(f"no sharding rule for param leaf '{'/'.join(path_names)}'")
    base_ndim, tail = base
    lead = ndim - base_ndim
    if lead < 0:
        raise ValueError(f"leaf {'/'.join(path_names)} ndim {ndim} < rule {base_ndim}")
    return (None,) * lead + tuple(tail)


def _divisible(spec: tuple, shape: tuple, mesh: Mesh) -> P:
    """Null out axes that do not divide their dim evenly (jit in_shardings
    require exact divisibility; e.g. 36 heads over TP=16 -> replicate)."""
    fixed = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            fixed.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        fixed.append(ax if dim % size == 0 else None)
    return P(*fixed)


def param_specs(params_shape, mesh: Mesh, *, moe_ep: bool = True):
    """Pytree of PartitionSpec matching a params (or ShapeDtypeStruct) tree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = []
    for path, leaf in flat:
        names = [str(p.key) if hasattr(p, "key") else str(p.idx) for p in path]
        raw = _rule(names[-1], names, len(leaf.shape), mesh, moe_ep=moe_ep)
        specs.append(_divisible(raw, leaf.shape, mesh))
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_specs(params_shape, mesh: Mesh, *, moe_ep: bool = True):
    ps = param_specs(params_shape, mesh, moe_ep=moe_ep)
    return {"m": ps, "v": ps, "step": P()}


def batch_specs(cfg: ArchConfig, cell: ShapeCell, mesh: Mesh):
    """Specs for the input batch dict of (arch x cell)."""
    dp = dp_axes(mesh)
    shard_batch = cell.global_batch % dp_size(mesh) == 0
    bspec = P(dp) if shard_batch else P()
    out: dict[str, Any] = {"tokens": P(*bspec, None)}
    if cell.kind == "train":
        out["targets"] = P(*bspec, None)
    if cfg.family == "vlm":
        out["patches"] = P(*bspec, None, None)
    if cfg.family == "audio":
        out["frames"] = P(*bspec, None, None)
    return out


def cache_specs_tree(cfg: ArchConfig, cell: ShapeCell, mesh: Mesh, cache_shape):
    """Specs for the decode cache pytree (shapes from jax.eval_shape)."""
    dp = dp_axes(mesh)
    shard_batch = cell.global_batch % dp_size(mesh) == 0
    bsp = dp if shard_batch else None
    # SP: when the batch can't shard (long_500k B=1), shard the cache seq dim.
    ssp = None if shard_batch else "data"
    kv_tp = TP if cfg.n_kv_heads % mesh.shape[TP] == 0 else None
    # When kv heads can't shard over TP (GQA kv < TP), shard the cache SEQ
    # dim over TP instead — decode attention reduces partial softmax terms
    # across seq shards (§Perf iter 8: qwen3 decode cache 34->2.1 GB/device).
    ssp_kv = ssp if kv_tp is not None else (TP if ssp is None else ssp)

    def rule(path, leaf):
        names = [str(p.key) if hasattr(p, "key") else str(p.idx) for p in path]
        name = names[-1]
        nd = len(leaf.shape)
        if name == "pos":
            return P()
        if name in ("k", "v"):          # (..., B, S, kv, hd)
            tail = (bsp, ssp_kv, kv_tp, None)
        elif name in ("xk", "xv"):      # (..., B, T, kv, hd) cross KV
            tail = (bsp, None, kv_tp, None)
        elif name == "ssm":             # (..., B, H, P, N)
            tail = (bsp, TP, None, None)
        elif name == "cx":              # (..., B, K-1, d_in)
            tail = (bsp, None, TP)
        elif name in ("cb", "cc"):      # (..., B, K-1, G*N)
            tail = (bsp, None, None)
        else:
            raise KeyError(f"no cache rule for {'/'.join(names)}")
        lead = nd - len(tail)
        return _divisible((None,) * lead + tail, leaf.shape, mesh)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)
    return jax.tree_util.tree_unflatten(
        treedef, [rule(path, leaf) for path, leaf in flat])


def named(tree_specs, mesh: Mesh):
    """PartitionSpec pytree -> NamedSharding pytree."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
