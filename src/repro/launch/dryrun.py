import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("REPRO_DRYRUN_DEVICES", "512")).strip()
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run (deliverable e).

For every (architecture x shape cell) and both production meshes
(single-pod 16x16, multi-pod 2x16x16) this driver:

    with mesh:
        lowered  = jax.jit(step, in_shardings=..., donate...).lower(*specs)
        compiled = lowered.compile()
        memory_analysis / cost_analysis / collective census

and writes one JSON artifact per cell to ``--out`` (default
``artifacts/dryrun``). ShapeDtypeStructs only — nothing is allocated.
Failures (sharding mismatch, OOM-at-compile, unsupported collective) are
bugs; the artifact records the traceback.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --cell train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--skip-done]
"""
import argparse
import gzip
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import ALL_CELLS, ShapeCell, supported_cells
from repro.launch import shardings as sh
from repro.launch.mesh import make_production_mesh
from repro.launch.step import make_decode_step, make_prefill_step, make_train_step
from repro.models.api import input_specs, model_api
from repro.train.optimizer import OptimizerConfig, init_opt_state

from repro.launch.hlo_census import census as collective_census  # noqa: E402
from repro.launch.mesh import dp_axes  # noqa: E402
from repro.models.hints import enable_hints  # noqa: E402


def _bf16(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, jnp.bfloat16)
        if x.dtype == jnp.float32 else x, tree)


def _with_sharding(struct_tree, spec_tree, mesh):
    named = sh.named(spec_tree, mesh)
    return jax.tree.map(
        lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s),
        struct_tree, named)


def run_cell(arch: str, cell: ShapeCell, multi_pod: bool, out_dir: str,
             save_hlo: bool = False) -> dict:
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2" if multi_pod else "pod1"
    api = model_api(cfg)
    rec: dict = {"arch": arch, "cell": cell.name, "mesh": mesh_name,
                 "devices": int(len(jax.devices())), "ok": False}
    os.makedirs(out_dir, exist_ok=True)
    t0 = time.time()
    try:
        enable_hints(dp_axes(mesh), "model", mesh)
        params_struct = jax.eval_shape(api.init, jax.random.PRNGKey(0))
        # Expert WEIGHTS stay EP-sharded for every MoE: both alternatives were
        # measured and refuted (§Perf iters 5b: F-over-TP 1.5x worse; 5c:
        # TP-replicated 4.6x worse — GSPMD then computes all experts per
        # token). Only the activation hints follow the light/heavy regime.
        moe_ep = True
        pspecs = sh.param_specs(params_struct, mesh, moe_ep=moe_ep)
        batch_struct = input_specs(cfg, cell)
        bspecs = sh.batch_specs(cfg, cell, mesh)

        with mesh:
            if cell.kind == "train":
                opt_cfg = OptimizerConfig(
                    state_dtype="bfloat16", total_steps=1000)
                opt_struct = jax.eval_shape(
                    lambda p: init_opt_state(p, opt_cfg), params_struct)
                ospecs = sh.opt_specs(params_struct, mesh, moe_ep=moe_ep)
                step = make_train_step(cfg, opt_cfg)
                args = (
                    _with_sharding(params_struct, pspecs, mesh),
                    _with_sharding(opt_struct, ospecs, mesh),
                    _with_sharding(batch_struct, bspecs, mesh),
                )
                jitted = jax.jit(
                    step,
                    in_shardings=(sh.named(pspecs, mesh),
                                  sh.named(ospecs, mesh),
                                  sh.named(bspecs, mesh)),
                    out_shardings=(sh.named(pspecs, mesh),
                                   sh.named(ospecs, mesh), None),
                    donate_argnums=(0, 1))
            elif cell.kind == "prefill":
                params_struct = _bf16(params_struct)
                step = make_prefill_step(cfg)
                args = (_with_sharding(params_struct, pspecs, mesh),
                        _with_sharding(batch_struct, bspecs, mesh))
                jitted = jax.jit(step,
                                 in_shardings=(sh.named(pspecs, mesh),
                                               sh.named(bspecs, mesh)))
            else:                                          # decode
                params_struct = _bf16(params_struct)
                cache_struct = jax.eval_shape(
                    lambda: api.init_cache(cell.global_batch, cell.seq_len))
                cspecs = sh.cache_specs_tree(cfg, cell, mesh, cache_struct)
                step = make_decode_step(cfg)
                args = (_with_sharding(params_struct, pspecs, mesh),
                        _with_sharding(cache_struct, cspecs, mesh),
                        _with_sharding(batch_struct["tokens"],
                                       bspecs["tokens"], mesh))
                jitted = jax.jit(step,
                                 in_shardings=(sh.named(pspecs, mesh),
                                               sh.named(cspecs, mesh),
                                               sh.named(bspecs["tokens"], mesh)),
                                 out_shardings=(None,
                                                sh.named(cspecs, mesh)),
                                 donate_argnums=(1,))

            lowered = jitted.lower(*args)
            rec["lower_s"] = round(time.time() - t0, 2)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 2)

            ma = compiled.memory_analysis()
            if ma is not None:
                for field in ("argument_size_in_bytes", "output_size_in_bytes",
                              "temp_size_in_bytes", "alias_size_in_bytes",
                              "generated_code_size_in_bytes"):
                    rec.setdefault("memory", {})[field] = int(
                        getattr(ma, field, 0) or 0)
            ca = compiled.cost_analysis()
            if ca:
                rec["cost"] = {k: float(v) for k, v in ca.items()
                               if isinstance(v, (int, float))}
            hlo = compiled.as_text()
            rec["collectives"] = collective_census(hlo)
            rec["hlo_bytes"] = len(hlo)
            if save_hlo:
                with gzip.open(os.path.join(
                        out_dir, f"{arch}__{cell.name}__{mesh_name}.hlo.gz"),
                        "wt") as f:
                    f.write(hlo)
            rec["ok"] = True
            print(f"OK  {arch:28s} {cell.name:12s} {mesh_name} "
                  f"lower={rec['lower_s']}s compile={rec['compile_s']}s "
                  f"flops={rec.get('cost', {}).get('flops', 0):.3e}")
            print("  memory_analysis:", rec.get("memory"))
            print("  collectives:", rec["collectives"]["bytes_scaled"])
    except Exception:
        rec["error"] = traceback.format_exc()
        print(f"FAIL {arch} {cell.name} {mesh_name}\n{rec['error']}")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir,
                           f"{arch}__{cell.name}__{mesh_name}.json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--cell", default=None,
                    choices=[c.name for c in ALL_CELLS])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    for arch in archs:
        cfg = get_config(arch)
        cells = supported_cells(cfg)
        if args.cell:
            cells = [c for c in ALL_CELLS if c.name == args.cell]
        for cell in cells:
            for mp in meshes:
                tag = f"{arch}__{cell.name}__{'pod2' if mp else 'pod1'}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_done and os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("ok"):
                            print("skip", tag)
                            continue
                results.append(run_cell(arch, cell, mp, args.out,
                                        save_hlo=args.save_hlo))
    n_ok = sum(r["ok"] for r in results)
    print(f"\n{n_ok}/{len(results)} cells OK")
    if n_ok < len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
