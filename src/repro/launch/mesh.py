"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
touches no jax device state — the dry-run process must set XLA_FLAGS before
the first jax call, and tests must keep seeing 1 CPU device.

Axes:
  * ``pod``   — inter-pod (DCN/optical) axis: pure DP (optionally compressed
                gradient all-reduce) or pipeline stages;
  * ``data``  — intra-pod DP/FSDP axis (batch + parameter/optimizer shards);
  * ``model`` — TP/EP axis (heads, FFN hidden, vocab, experts, SSM heads).

Serving contract (``core.device_plane``): the NKS serving plane shards work
(packed join subsets, relevant-point groups) over ``data`` only — ``model``
is unused by serving and stays size 1 on serving meshes.
``REPRO_MESH_OVERRIDE`` (comma-separated axis sizes, e.g. ``8,1``) is the
debug override read by :func:`make_production_mesh` (full shape) and by
:func:`make_serving_mesh` when no explicit ``data`` size is passed (first
value); :func:`make_local_mesh` always uses its explicit arguments.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    import os
    override = os.environ.get("REPRO_MESH_OVERRIDE")          # debug only
    if override:
        shape = tuple(int(x) for x in override.split(","))
        axes = ("pod", "data", "model")[-len(shape):] if multi_pod or \
            len(shape) == 3 else ("data", "model")
        return jax.make_mesh(shape, axes)
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_serving_mesh(data: int | None = None):
    """(data, model=1) mesh for the NKS serving plane.

    ``data`` defaults to ``REPRO_MESH_OVERRIDE``'s first axis size when set,
    else every local device. Serving shards subsets over ``data``; ``model``
    exists only so the mesh satisfies the production axis contract."""
    import os
    if data is None:
        override = os.environ.get("REPRO_MESH_OVERRIDE")
        data = int(override.split(",")[0]) if override \
            else jax.local_device_count()
    return jax.make_mesh((data, 1), ("data", "model"))


def make_local_mesh(data: int = 1, model: int = 1, pod: int | None = None):
    """Small mesh for CPU tests (requires forced host device count)."""
    if pod is not None:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def dp_axes(mesh) -> tuple[str, ...]:
    """The batch/data-parallel axes of a mesh (pod included when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh) -> int:
    out = 1
    for a in dp_axes(mesh):
        out *= mesh.shape[a]
    return out
