"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
touches no jax device state — the dry-run process must set XLA_FLAGS before
the first jax call, and tests must keep seeing 1 CPU device.

Axes:
  * ``pod``   — inter-pod (DCN/optical) axis: pure DP (optionally compressed
                gradient all-reduce) or pipeline stages;
  * ``data``  — intra-pod DP/FSDP axis (batch + parameter/optimizer shards);
  * ``model`` — TP/EP axis (heads, FFN hidden, vocab, experts, SSM heads).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    import os
    override = os.environ.get("REPRO_MESH_OVERRIDE")          # debug only
    if override:
        shape = tuple(int(x) for x in override.split(","))
        axes = ("pod", "data", "model")[-len(shape):] if multi_pod or \
            len(shape) == 3 else ("data", "model")
        return jax.make_mesh(shape, axes)
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1, pod: int | None = None):
    """Small mesh for CPU tests (requires forced host device count)."""
    if pod is not None:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def dp_axes(mesh) -> tuple[str, ...]:
    """The batch/data-parallel axes of a mesh (pod included when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh) -> int:
    out = 1
    for a in dp_axes(mesh):
        out *= mesh.shape[a]
    return out
